"""End-to-end: partition -> run graph analytics -> measure the win.

Reproduces the mechanism of the paper's Section 5.6 (Figure 8 / Table 4):
the same PageRank/SSSP/WCC computation, executed over hash- vs
Spinner-partitioned layouts, with per-partition load and cross-partition
message accounting.

    PYTHONPATH=src python examples/partition_and_analyze.py
"""
import numpy as np

from repro.core import SpinnerConfig, generators, partition, pregel

k = 32
graph = generators.powerlaw_ba(30_000, 8, seed=2)   # hub-heavy, Twitter-like
print(f"graph: {graph.num_vertices} vertices, "
      f"{graph.num_undirected_edges} edges (power-law)")

res = partition(graph, SpinnerConfig(k=k, seed=0), record_history=False,
                engine="fused")   # one device dispatch for the whole run
hash_labels = (np.arange(graph.num_vertices) * 2654435761 % k
               ).astype(np.int32)

for app in ("pagerank", "sssp", "wcc"):
    kw = {"iters": 10} if app == "pagerank" else {}
    cmp = pregel.compare_partitionings(graph, k, hash_labels, res.labels,
                                       app, **kw)
    print(f"{app:9s} speedup={cmp['speedup_b_over_a']:.2f}x  "
          f"remote messages: {cmp['remote_msgs_a']:,} -> "
          f"{cmp['remote_msgs_b']:,} (-{cmp['msg_reduction']:.0%})")

# incremental adaptation: the graph grows, the partitioning follows
from repro.core import adapt, metrics
from repro.core.graph import add_edges

rng = np.random.default_rng(0)
m = int(0.01 * graph.num_undirected_edges)
grown = add_edges(graph, rng.integers(0, graph.num_vertices, m),
                  rng.integers(0, graph.num_vertices, m))
res2 = adapt(grown, res.labels, SpinnerConfig(k=k, seed=0),
             record_history=False, engine="fused")
moved = metrics.partitioning_difference(res.labels, res2.labels)
print(f"\n+1% edges: adapted in {res2.iterations} iterations, "
      f"moved {moved:.1%} of vertices "
      f"(phi={metrics.phi(grown, res2.labels):.3f})")
