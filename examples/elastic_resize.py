"""Elastic repartitioning (Section 3.5): scale a running partitioning
16 -> 20 -> 12 partitions without recomputing from scratch, exactly what a
cluster does when nodes join or are preempted.

    PYTHONPATH=src python examples/elastic_resize.py
"""
import numpy as np

from repro.core import SpinnerConfig, generators, metrics, partition, resize

graph = generators.watts_strogatz(30_000, 16, 0.3, seed=4)
print(f"graph: {graph.num_vertices} vertices, "
      f"{graph.num_undirected_edges} edges\n")

k = 16
# fused engine: the full run (and every elastic restart below) is a single
# lax.while_loop device dispatch
res = partition(graph, SpinnerConfig(k=k, seed=0), record_history=False,
                engine="fused")
print(f"initial k={k}: phi={metrics.phi(graph, res.labels):.3f} "
      f"rho={metrics.rho(graph, res.labels, k):.3f} "
      f"({res.iterations} iters)")

for k_new, event in ((20, "4 nodes join"), (12, "8 nodes preempted")):
    cfg = SpinnerConfig(k=k_new, seed=1)
    res_new, relabeled = resize(graph, res.labels, cfg, k_old=k,
                                record_history=False, engine="fused")
    moved = metrics.partitioning_difference(res.labels, res_new.labels)
    print(f"{event}: k={k} -> {k_new}  "
          f"adapted in {res_new.iterations} iters, moved {moved:.1%}  "
          f"phi={metrics.phi(graph, res_new.labels):.3f} "
          f"rho={metrics.rho(graph, res_new.labels, k_new):.3f}")
    res, k = res_new, k_new
