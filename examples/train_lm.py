"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Uses the same ModelAPI/train-step/data/checkpoint stack as the production
launcher, on a single host.  Loss on the synthetic motif language drops
from ~ln(V) to near the motif entropy within a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint
from repro.configs import ARCHS
from repro.data import pipeline
from repro.models import build, init_params
from repro.optim import adamw
from repro.train import steps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    # ~100M params: stablelm family scaled down
    cfg = dataclasses.replace(
        ARCHS["stablelm-1.6b"], n_layers=8, d_model=768, n_heads=12,
        n_kv_heads=12, d_ff=2048, vocab=32_000, attn_chunk_q=256,
        attn_chunk_kv=256)
    api = build(cfg)
    print(f"model: {api.num_params / 1e6:.1f}M params")

    params = init_params(api, jax.random.PRNGKey(0))
    state = steps.init_train_state(params)
    opt_cfg = adamw.AdamWConfig(lr=6e-4, warmup_steps=30,
                                total_steps=args.steps, weight_decay=0.1)
    train_step = jax.jit(steps.make_train_step(api, opt_cfg),
                         donate_argnums=(0,))
    data_cfg = pipeline.DataConfig(vocab=cfg.vocab, seq_len=256,
                                   global_batch=8, seed=0)

    start = checkpoint.latest_step(args.ckpt_dir) or 0
    if start:
        state = checkpoint.restore(args.ckpt_dir, state)
        print(f"resumed from step {start}")

    t0 = time.time()
    for step in range(start, args.steps):
        batch = jax.tree.map(jnp.asarray, pipeline.batch_at(data_cfg, step))
        state, stats = train_step(state, batch)
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss={float(stats['loss']):.4f}  "
                  f"gnorm={float(stats['grad_norm']):.2f}  "
                  f"lr={float(stats['lr']):.2e}  "
                  f"({(time.time() - t0):.0f}s)")
        if (step + 1) % args.ckpt_every == 0:
            checkpoint.save(args.ckpt_dir, step + 1, state)
            checkpoint.gc_old(args.ckpt_dir, keep=2)
    print("done")


if __name__ == "__main__":
    main()
