"""Quickstart: partition a graph with Spinner and inspect quality.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (SpinnerConfig, generators, metrics, open_session,
                        partition)
from repro.core.graph import add_edges

# a small-world graph (the paper's synthetic workload family)
graph = generators.watts_strogatz(n=20_000, k_nbrs=20, beta=0.3, seed=1)
print(f"graph: {graph.num_vertices} vertices, "
      f"{graph.num_undirected_edges} edges")

# paper defaults: c = 1.05, eps = 1e-3, w = 5  (Section 5.1)
cfg = SpinnerConfig(k=16, c=1.05, eps=1e-3, halt_window=5, seed=0)
# engine="chunked": the iteration loop runs on device (32 iterations per
# dispatch) with per-iteration history recorded on device.  For the
# single-dispatch lax.while_loop engine (no history), call
# partition(graph, cfg, record_history=False) and let engine="auto" pick
# "fused", or pass engine="fused" explicitly.
result = partition(graph, cfg, engine="chunked")

phi = metrics.phi(graph, result.labels)
rho = metrics.rho(graph, result.labels, cfg.k)
hash_phi = metrics.phi(graph, np.arange(graph.num_vertices) % cfg.k)
print(f"converged in {result.iterations} iterations "
      f"(halting criterion: eps={cfg.eps}, w={cfg.halt_window})")
print(f"locality  phi = {phi:.3f}   (hash partitioning: {hash_phi:.3f}, "
      f"{phi / hash_phi:.1f}x better)")
print(f"balance   rho = {rho:.3f}   (capacity bound c = {cfg.c})")
print("per-iteration trace (first 5):")
for h in result.history[:5]:
    print(f"  iter {h['iteration']:3d}  phi={h['phi']:.3f} "
          f"rho={h['rho']:.3f} migrations={h['migrations']}")

# --- continuous partitioning: the session API (Sections 3.4-3.5) ----------
# A long-lived service holds a PartitionSession: the graph upload and the
# compiled runner live on device, and adapt()/resize() are cheap repeat
# calls -- a grown graph that stays inside its (V, E) shape bucket reuses
# the SAME compiled executable (session.stats()["compiles"] stays flat).
rng = np.random.default_rng(0)
with open_session(graph, cfg) as session:
    base = session.partition(record_history=False)
    grown = add_edges(graph, rng.integers(0, graph.num_vertices, 500),
                      rng.integers(0, graph.num_vertices, 500))
    adapted = session.adapt(grown, record_history=False)    # warm: 0 compiles
    resized = session.resize(cfg.k + 4, record_history=False)
    st = session.stats()
    moved = metrics.partitioning_difference(base.labels, adapted.labels)
    print(f"session: bucket={st['bucket']} runs={st['runs']} "
          f"compiles={st['compiles']}")
    print(f"adapt after 500 new edges: {adapted.iterations} iterations, "
          f"{moved:.1%} of vertices moved (vs ~{1 - 1 / cfg.k:.0%} from "
          f"scratch)")
    print(f"resize {cfg.k} -> {cfg.k + 4}: rho = "
          f"{metrics.rho(grown, resized.labels, cfg.k + 4):.3f}")
