"""Overlap-scheduled sharded engine + staged session uploads (PR 5).

Three pillars:

  * the interior/frontier edge split: numpy reconstruction of the
    ``shard_graph`` layout (segment membership, per-device counts, the
    ``edge_perm`` permutation) on 2/4/8 device shardings, plus the
    ``metrics.comm_volume`` / ``metrics.frontier_fraction`` satellites;
  * overlap-schedule bit parity: ``EngineOptions(overlap="on")``
    reschedules the sharded step as start_exchange -> score_interior ->
    finish_exchange -> score_frontier, and must walk BIT-IDENTICAL
    trajectories to ``overlap="off"`` for every exchange plan and both
    score backends (integer edge weights make the two-phase f32 sums
    exact) -- in-process on a 1-device mesh, and on real 2/4/8-device
    meshes in the subprocess tests;
  * staged (double-buffered) session uploads: ``PartitionSession.stage``
    issues the next snapshot's device transfers ahead of time, so the
    following ``adapt()`` performs zero new compilations and zero
    synchronous copies while staying bit-identical to a synchronous
    ``adapt``.

Each test uses a unique ``max_iters`` so its programs are private in the
global program cache and compile counts cannot be perturbed by other
tests.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (EngineOptions, SpinnerConfig, adapt, engine,
                        generators, metrics, open_session, partition)
from repro.core.distributed import run_sharded_hostloop, shard_graph
from repro.core.graph import add_edges, shape_bucket
from repro.launch.mesh import make_partition_mesh

from test_distributed import run_devices_subprocess


@pytest.fixture(scope="module")
def ws_graph():
    return generators.watts_strogatz(600, 8, 0.2, seed=11)


@pytest.fixture(scope="module")
def mesh1():
    return make_partition_mesh(1)


def _grow(graph, n_edges=30, new_vertices=2, seed=1):
    """A same-bucket growth of ``graph`` (a few edges + vertices)."""
    rng = np.random.default_rng(seed)
    v = graph.num_vertices
    return add_edges(graph, rng.integers(0, v, n_edges),
                     rng.integers(0, v, n_edges),
                     num_vertices=v + new_vertices)


def _assert_same(a, b):
    np.testing.assert_array_equal(a.labels, b.labels)
    np.testing.assert_array_equal(a.loads, b.loads)
    assert a.iterations == b.iterations
    assert a.halted == b.halted


class TestInteriorFrontierLayout:
    """Numpy reconstruction of the [interior | frontier] edge split."""

    @pytest.mark.parametrize("ndev", [2, 4, 8])
    def test_split_reconstructs_edges(self, ws_graph, ndev):
        g = ws_graph
        sg = shard_graph(g, ndev)
        vl = sg.v_per_dev
        # independent classification: an edge is interior iff its dst is
        # owned by the same device as its src
        owner = g.src // vl
        frontier = (g.dst // vl) != owner
        np.testing.assert_array_equal(
            sg.interior_counts, np.bincount(owner[~frontier],
                                            minlength=ndev))
        np.testing.assert_array_equal(
            sg.frontier_counts, np.bincount(owner[frontier],
                                            minlength=ndev))
        e_int = sg.e_interior
        for p in range(ndev):
            real = sg.weight[p] > 0
            # segment membership: interior dsts local, frontier remote
            assert (sg.dst[p, :e_int][real[:e_int]] // vl == p).all()
            assert (sg.dst[p, e_int:][real[e_int:]] // vl != p).all()
            # edge_perm reconstructs the original arrays slot for slot
            pm = sg.edge_perm[p]
            np.testing.assert_array_equal(pm >= 0, real)
            np.testing.assert_array_equal(sg.src_local[p][real] + p * vl,
                                          g.src[pm[real]])
            np.testing.assert_array_equal(sg.dst[p][real], g.dst[pm[real]])
            np.testing.assert_array_equal(sg.weight[p][real],
                                          g.weight[pm[real]])
        # the permutation is a bijection onto the edge set
        used = sg.edge_perm[sg.edge_perm >= 0]
        np.testing.assert_array_equal(np.sort(used),
                                      np.arange(g.num_directed_entries))

    def test_single_device_all_interior(self, ws_graph):
        sg = shard_graph(ws_graph, 1)
        assert int(sg.frontier_counts.sum()) == 0
        assert metrics.frontier_fraction(sg) == 0.0
        # on one device the shard keeps the CSR edge order verbatim
        real = sg.weight[0] > 0
        np.testing.assert_array_equal(
            sg.edge_perm[0][real],
            np.arange(ws_graph.num_directed_entries))

    def test_pad_buckets_each_segment(self, ws_graph):
        raw = shard_graph(ws_graph, 4)
        sg = shard_graph(ws_graph, 4, pad=True)
        assert sg.e_interior == shape_bucket(raw.e_interior, floor=128)
        # frontier: full power-of-two rounding (coarser than the interior
        # quarter-steps, so boundary-set drift rarely crosses a bucket)
        raw_fro = raw.dst.shape[1] - raw.e_interior
        e_fro = sg.dst.shape[1] - sg.e_interior
        assert e_fro == max(128, 1 << (raw_fro - 1).bit_length())
        np.testing.assert_array_equal(sg.interior_counts,
                                      raw.interior_counts)
        np.testing.assert_array_equal(sg.frontier_counts,
                                      raw.frontier_counts)

    def test_counts_exclude_bucket_pad_edges(self, ws_graph):
        """pad_graph's weight-0 filler self-loops get layout slots but
        must not bias the reported interior/frontier counts (and thus
        frontier_fraction) away from the REAL graph."""
        padded, _ = engine.padded_view(ws_graph, engine.EngineOptions())
        assert padded.num_directed_entries > ws_graph.num_directed_entries
        sg = shard_graph(padded, 4, pad=True)
        total = int(sg.interior_counts.sum() + sg.frontier_counts.sum())
        assert total == ws_graph.num_directed_entries
        assert metrics.frontier_fraction(sg) == \
            int(sg.frontier_counts.sum()) / ws_graph.num_directed_entries

    def test_frontier_fraction_grows_with_ndev(self, ws_graph):
        f4 = metrics.frontier_fraction(shard_graph(ws_graph, 4))
        f8 = metrics.frontier_fraction(shard_graph(ws_graph, 8))
        assert 0.0 < f4 <= f8 < 1.0


class TestCommVolume:
    def test_total_matches_phi(self, ws_graph):
        rng = np.random.default_rng(0)
        labels = rng.integers(0, 5, ws_graph.num_vertices)
        cv = metrics.comm_volume(ws_graph, labels, 5)
        assert cv.shape == (5,)
        cut = round((1 - metrics.phi(ws_graph, labels))
                    * ws_graph.num_directed_entries)
        assert int(cv.sum()) == cut

    def test_single_partition_is_free(self, ws_graph):
        labels = np.zeros(ws_graph.num_vertices, np.int32)
        assert int(metrics.comm_volume(ws_graph, labels, 3).sum()) == 0

    def test_summarize_reports_both(self, ws_graph):
        labels = np.zeros(ws_graph.num_vertices, np.int32)
        s = metrics.summarize(ws_graph, labels, 3,
                              sg=shard_graph(ws_graph, 4))
        assert s["comm_volume"] == 0 and s["comm_volume_max"] == 0
        assert 0.0 < s["frontier_fraction"] < 1.0
        assert "frontier_fraction" not in metrics.summarize(ws_graph,
                                                            labels, 3)


class TestOverlapBitParity:
    """overlap="on" must reproduce overlap="off" bit for bit: the split
    schedule only regroups exact integer f32 sums."""

    @pytest.mark.parametrize("backend", ["xla", "pallas"])
    @pytest.mark.parametrize("plan", ["allgather", "halo", "delta"])
    def test_on_off_identical(self, ws_graph, mesh1, backend, plan):
        cfg = SpinnerConfig(k=6, seed=2, max_iters=73)
        res = {}
        for ov in ("off", "on"):
            res[ov] = partition(
                ws_graph, cfg, record_history=False, engine="sharded",
                mesh=mesh1, options=EngineOptions(label_exchange=plan,
                                                  score_backend=backend,
                                                  overlap=ov))
        _assert_same(res["off"], res["on"])
        assert res["off"].exchanged_bytes == res["on"].exchanged_bytes

    def test_overlap_matches_fused_oracle(self, ws_graph, mesh1):
        cfg = SpinnerConfig(k=6, seed=2, max_iters=74)
        fused = partition(ws_graph, cfg, record_history=False,
                          engine="fused")
        on = partition(ws_graph, cfg, record_history=False,
                       engine="sharded", mesh=mesh1,
                       options=EngineOptions(overlap="on"))
        _assert_same(fused, on)

    def test_hostloop_driver_still_matches(self, ws_graph, mesh1):
        """The hostloop baseline is pinned to the non-overlapped
        allgather step inside the one shared ``_sharded_parts`` assembly
        and must keep walking the overlap-on trajectory."""
        cfg = SpinnerConfig(k=6, seed=2, max_iters=75)
        on = partition(ws_graph, cfg, record_history=False,
                       engine="sharded", mesh=mesh1,
                       options=EngineOptions(overlap="on"))
        state = run_sharded_hostloop(ws_graph, cfg, mesh1,
                                     options=EngineOptions(overlap="on"))
        np.testing.assert_array_equal(
            np.asarray(state.labels)[: ws_graph.num_vertices], on.labels)
        assert int(state.iteration) == on.iterations

    def test_auto_resolution_and_validation(self):
        opts = EngineOptions()
        assert opts.resolved_overlap(1) == "off"
        assert opts.resolved_overlap(8) == "on"
        forced = dataclasses.replace(opts, overlap="on")
        assert forced.resolved_overlap(1) == "on"
        with pytest.raises(ValueError, match="overlap"):
            dataclasses.replace(opts, overlap="bogus").resolved_overlap(2)

    def test_overlap_is_a_distinct_cached_program(self, ws_graph, mesh1):
        cfg = SpinnerConfig(k=6, seed=2, max_iters=76)
        on = engine.make_sharded_runner(ws_graph, cfg, mesh1,
                                        opts=EngineOptions(overlap="on"))
        off = engine.make_sharded_runner(ws_graph, cfg, mesh1,
                                         opts=EngineOptions(overlap="off"))
        assert on.program is not off.program
        again = engine.make_sharded_runner(ws_graph, cfg, mesh1,
                                           opts=EngineOptions(overlap="on"))
        assert again.program is on.program


class TestStagedUploads:
    def test_staged_adapt_zero_compiles_bit_parity(self, ws_graph):
        cfg = SpinnerConfig(k=6, seed=2, max_iters=77)
        with open_session(ws_graph, cfg,
                          EngineOptions(engine="fused")) as s:
            base = s.partition(record_history=False)
            g2 = _grow(ws_graph)
            assert engine.graph_buckets(g2) == engine.graph_buckets(
                ws_graph)
            before = s.compiles
            s.stage(g2)
            assert s.stats()["staged"] == g2.num_vertices
            staged = s.adapt(record_history=False)
            assert s.compiles == before, "staged adapt recompiled"
            assert s.stats()["staged"] is None       # consumed
            one = adapt(g2, base.labels, cfg, engine="fused",
                        record_history=False)
            _assert_same(one, staged)

    def test_staged_adapt_on_sharded_mesh(self, ws_graph, mesh1):
        cfg = SpinnerConfig(k=6, seed=2, max_iters=78)
        opts = EngineOptions(engine="sharded", mesh=mesh1, overlap="on")
        with open_session(ws_graph, cfg, opts) as s:
            base = s.partition(record_history=False)
            g2 = _grow(ws_graph)
            before = s.compiles
            s.stage(g2)
            staged = s.adapt(record_history=False)
            assert s.compiles == before
            one = adapt(g2, base.labels, cfg, record_history=False,
                        options=opts)
            _assert_same(one, staged)

    def test_stage_edge_updates(self, ws_graph):
        cfg = SpinnerConfig(k=6, seed=2, max_iters=79)
        with open_session(ws_graph, cfg) as s:
            s.partition(record_history=False)
            v = ws_graph.num_vertices
            s.stage(edge_updates=([v, v + 1], [0, 1]),
                    num_vertices=v + 2)
            res = s.adapt(record_history=False)
            assert res.labels.shape == (v + 2,)
            assert s.graph.num_vertices == v + 2

    def test_restage_replaces_pending(self, ws_graph):
        cfg = SpinnerConfig(k=6, seed=2, max_iters=80)
        with open_session(ws_graph, cfg) as s:
            s.partition(record_history=False)
            g2 = _grow(ws_graph, seed=2)
            g3 = _grow(ws_graph, seed=3, new_vertices=4)
            s.stage(g2)
            s.stage(g3)
            res = s.adapt(record_history=False)
            assert s.graph is g3
            assert res.labels.shape == (g3.num_vertices,)

    def test_other_rebindings_discard_staged(self, ws_graph):
        """update() and explicit adapt() supersede a pending staged
        snapshot -- a later argless adapt() must see the NEWER graph,
        never silently fall back to the stale staged one."""
        cfg = SpinnerConfig(k=6, seed=2, max_iters=81)
        with open_session(ws_graph, cfg) as s:
            s.partition(record_history=False)
            v = ws_graph.num_vertices
            s.stage(_grow(ws_graph, seed=4))
            s.update([v, v + 1], [0, 1], num_vertices=v + 2)
            assert s.stats()["staged"] is None
            res = s.adapt(record_history=False)
            assert res.labels.shape == (v + 2,)
            g_explicit = _grow(ws_graph, seed=5, new_vertices=6)
            s.stage(_grow(ws_graph, seed=6))
            res = s.adapt(g_explicit, record_history=False)
            assert s.graph is g_explicit
            assert s.stats()["staged"] is None
            res = s.adapt(record_history=False)   # re-runs g_explicit
            assert s.graph is g_explicit
            assert res.labels.shape == (g_explicit.num_vertices,)

    def test_stage_argument_validation(self, ws_graph):
        cfg = SpinnerConfig(k=4, seed=0, max_iters=8)
        s = open_session(ws_graph, cfg)
        with pytest.raises(ValueError, match="needs"):
            s.stage()
        with pytest.raises(ValueError, match="at most one"):
            s.stage(_grow(ws_graph), edge_updates=([0], [1]))
        s.close()
        with pytest.raises(RuntimeError, match="closed"):
            s.stage(_grow(ws_graph))


# ---------------------------------------------------------------------------
# Multi-device semantics: subprocess with forced host devices
# ---------------------------------------------------------------------------

OVERLAP_EXCHANGE_PARITY_MULTIDEV = """
import numpy as np
from repro.core import EngineOptions, SpinnerConfig, generators, partition
from repro.launch.mesh import make_partition_mesh

g = generators.clustered_graph(8, 500, 0.02, 0.5, seed=5)
cfg = SpinnerConfig(k=8, seed=1, max_iters=120)
for ndev in (2, 4, 8):
    mesh = make_partition_mesh(ndev)
    for plan in ("allgather", "halo", "delta"):
        off = partition(g, cfg, record_history=False, engine="sharded",
                        mesh=mesh,
                        options=EngineOptions(label_exchange=plan,
                                              overlap="off"))
        on = partition(g, cfg, record_history=False, engine="sharded",
                       mesh=mesh,
                       options=EngineOptions(label_exchange=plan,
                                             overlap="on"))
        np.testing.assert_array_equal(off.labels, on.labels)
        np.testing.assert_array_equal(off.loads, on.loads)
        assert off.iterations == on.iterations, (ndev, plan)
        assert off.halted == on.halted, (ndev, plan)
        assert off.exchanged_bytes == on.exchanged_bytes, (ndev, plan)
        print(f"ndev={ndev} {plan}: iters={on.iterations} "
              f"bytes={on.exchanged_bytes:.0f}")
print("OVERLAP PARITY OK")
"""


OVERLAP_PALLAS_MULTIDEV = """
import numpy as np
from repro.core import EngineOptions, SpinnerConfig, generators, partition
from repro.launch.mesh import make_partition_mesh

g = generators.watts_strogatz(801, 8, 0.2, seed=7)   # 801: padding on 8 dev
cfg = SpinnerConfig(k=8, seed=3, max_iters=40)
mesh = make_partition_mesh()
assert mesh.size == 8
base = partition(g, cfg, record_history=False, engine="sharded", mesh=mesh,
                 options=EngineOptions(overlap="off"))
# halo included: its remapped dst slots feed both per-segment tilings
for plan in ("allgather", "halo", "delta"):
    for backend in ("xla", "pallas"):
        opts = EngineOptions(score_backend=backend, label_exchange=plan,
                             overlap="on")
        res = partition(g, cfg, record_history=False, engine="sharded",
                        mesh=mesh, options=opts)
        np.testing.assert_array_equal(base.labels, res.labels)
        np.testing.assert_array_equal(base.loads, res.loads)
        assert base.iterations == res.iterations, (plan, backend)
print("OVERLAP PALLAS OK")
"""


STAGED_ADAPT_MULTIDEV = """
import numpy as np
from repro.core import (EngineOptions, SpinnerConfig, adapt, generators,
                        open_session)
from repro.core.graph import add_edges
from repro.launch.mesh import make_partition_mesh

g = generators.watts_strogatz(4001, 12, 0.2, seed=3)
cfg = SpinnerConfig(k=8, seed=1, max_iters=120)
mesh = make_partition_mesh()
assert mesh.size == 8
opts = EngineOptions(engine="sharded", mesh=mesh)
s = open_session(g, cfg, opts)
base = s.partition(record_history=False)
rng = np.random.default_rng(1)
g2 = add_edges(g, rng.integers(0, 4001, 40), rng.integers(0, 4001, 40),
               num_vertices=4003)
before = s.compiles
s.stage(g2)
res = s.adapt(record_history=False)
assert s.compiles == before, (s.compiles, before)
one = adapt(g2, base.labels, cfg, record_history=False, options=opts)
np.testing.assert_array_equal(one.labels, res.labels)
assert one.iterations == res.iterations
print("STAGED ADAPT OK")
"""


@pytest.mark.slow
def test_overlap_exchange_parity_2_4_8dev():
    r = run_devices_subprocess(OVERLAP_EXCHANGE_PARITY_MULTIDEV)
    assert "OVERLAP PARITY OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_overlap_pallas_8dev():
    r = run_devices_subprocess(OVERLAP_PALLAS_MULTIDEV)
    assert "OVERLAP PALLAS OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_staged_adapt_8dev():
    r = run_devices_subprocess(STAGED_ADAPT_MULTIDEV)
    assert "STAGED ADAPT OK" in r.stdout, r.stdout + r.stderr
