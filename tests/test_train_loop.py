"""End-to-end training loop: loss decreases, checkpoint/restart bit-exact."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint
from repro.configs import ARCHS
from repro.data import pipeline
from repro.models import build, init_params
from repro.optim import adamw
from repro.train import steps


@pytest.fixture(scope="module")
def setup():
    cfg = ARCHS["stablelm-1.6b"].reduced()
    api = build(cfg)
    params = init_params(api, jax.random.PRNGKey(0))
    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=200,
                                weight_decay=0.01)
    train_step = jax.jit(steps.make_train_step(api, opt_cfg))
    data_cfg = pipeline.DataConfig(vocab=cfg.vocab, seq_len=64,
                                   global_batch=8, seed=1, n_motifs=8)
    return api, train_step, data_cfg, params


def _run(train_step, state, data_cfg, start, n):
    losses = []
    for step in range(start, start + n):
        batch = jax.tree.map(jnp.asarray, pipeline.batch_at(data_cfg, step))
        state, stats = train_step(state, batch)
        losses.append(float(stats["loss"]))
    return state, losses


def test_loss_decreases(setup):
    api, train_step, data_cfg, params = setup
    state = steps.init_train_state(params)
    state, losses = _run(train_step, state, data_cfg, 0, 40)
    assert losses[-1] < 0.5 * losses[0], losses[::8]
    assert int(state.step) == 40


def test_checkpoint_restart_bitexact(setup, tmp_path):
    api, train_step, data_cfg, params = setup
    state = steps.init_train_state(params)
    state, _ = _run(train_step, state, data_cfg, 0, 5)
    checkpoint.save(str(tmp_path), 5, state)

    # continue 5 more steps directly
    cont, losses_a = _run(train_step, state, data_cfg, 5, 5)

    # crash + restart from checkpoint (data is a pure function of step)
    restored = checkpoint.restore(str(tmp_path), state)
    rest, losses_b = _run(train_step, restored, data_cfg, 5, 5)
    assert losses_a == losses_b  # bit-exact restart
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), cont.params, rest.params)


def test_eval_step_matches_loss(setup):
    api, train_step, data_cfg, params = setup
    ev = jax.jit(steps.make_eval_step(api))
    batch = jax.tree.map(jnp.asarray, pipeline.batch_at(data_cfg, 0))
    assert np.isfinite(float(ev(params, batch)))
