"""The optimized() variant must be numerically equivalent to baseline."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import build, init_params


@pytest.mark.parametrize("arch", ["granite-8b", "qwen3-moe-235b-a22b"])
def test_optimized_variant_matches_baseline_loss(arch):
    cfg = ARCHS[arch].reduced()
    opt = cfg.optimized()
    api = build(cfg)
    api_o = build(opt)
    params = init_params(api, jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab)
    batch = {"tokens": tok, "labels": tok}
    base = float(jax.jit(api.loss)(params, batch))
    fast = float(jax.jit(api_o.loss)(params, batch))
    assert base == pytest.approx(fast, rel=2e-2), (base, fast)
    # grads too
    gb = jax.jit(jax.grad(api.loss))(params, batch)
    go = jax.jit(jax.grad(api_o.loss))(params, batch)
    nb = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
             for g in jax.tree.leaves(gb))
    no = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
             for g in jax.tree.leaves(go))
    assert nb == pytest.approx(no, rel=5e-2)


def test_sort_dispatch_matches_cumsum():
    """Same routing -> same buffer contents regardless of ranking algo
    (up to intra-expert position permutation, which the gather undoes)."""
    cfg = ARCHS["qwen3-moe-235b-a22b"].reduced()
    cfg_sorted = dataclasses.replace(cfg, moe_dispatch="sort")
    from repro.models import moe
    from repro.models.common import init_from_specs
    specs = moe.layer_param_specs(cfg, 1)
    params = init_from_specs(specs, jax.random.PRNGKey(3))
    lp = jax.tree.map(lambda p: p[0], params)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 32, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    out_a, aux_a = moe.moe_ffn(x, lp, cfg)
    out_b, aux_b = moe.moe_ffn(x, lp, cfg_sorted)
    np.testing.assert_allclose(np.asarray(out_a, np.float32),
                               np.asarray(out_b, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_microbatch_matches_full_batch():
    import jax
    from repro.optim import adamw
    from repro.train import steps
    from repro.models import init_params
    cfg = ARCHS["granite-8b"].reduced()
    cfg_mb = dataclasses.replace(cfg, microbatch=4)
    api, api_mb = build(cfg), build(cfg_mb)
    params = init_params(api, jax.random.PRNGKey(0))
    opt = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    s0 = steps.init_train_state(params)
    tok = jax.random.randint(jax.random.PRNGKey(5), (8, 32), 0, cfg.vocab)
    batch = {"tokens": tok, "labels": tok}
    s1, st1 = jax.jit(steps.make_train_step(api, opt))(s0, batch)
    s2, st2 = jax.jit(steps.make_train_step(api_mb, opt))(s0, batch)
    assert float(st1["loss"]) == pytest.approx(float(st2["loss"]), rel=1e-2)
    assert float(st1["grad_norm"]) == pytest.approx(
        float(st2["grad_norm"]), rel=2e-2)
