"""Multi-device behaviour (8 host devices) via subprocess tests, plus
sharding-rule unit tests that run on the in-process single device.

The subprocess scripts are the promoted bodies of the old
``_selftest()`` blocks that lived in ``core/distributed.py`` and
``core/pregel_dist.py``; the modules themselves carry no test code
anymore.  Deeper sharded-engine coverage (parity, dispatch counting,
mesh-keyed caches) lives in ``tests/test_sharded_engine.py``.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import SHAPES_BY_NAME
from repro.models import build, input_specs
from repro.parallel import rules

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_devices_subprocess(code: str, ndev: int = 8):
    """Run ``code`` under XLA_FLAGS=--xla_force_host_platform_device_count."""
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={ndev}",
               PYTHONPATH=os.path.join(REPO, "src"))
    return subprocess.run([sys.executable, "-c", code], env=env, cwd=REPO,
                          capture_output=True, text=True, timeout=900)


DISTRIBUTED_SPINNER = """
import numpy as np
from repro.core import SpinnerConfig, generators, metrics, partition
from repro.core.distributed import partition_distributed
from repro.launch.mesh import make_partition_mesh

g = generators.watts_strogatz(4000, 12, 0.2, seed=3)
cfg = SpinnerConfig(k=8, seed=1, max_iters=120)
mesh = make_partition_mesh()
assert mesh.size == 8, mesh
labels, stats = partition_distributed(g, cfg, mesh)
phi = metrics.phi(g, labels)
rho = metrics.rho(g, labels, cfg.k)
print(f"devices=8 iters={stats['iterations']} phi={phi:.3f} rho={rho:.3f} "
      f"shards={stats['edge_shard_sizes']}")
assert phi > 0.3, "distributed LPA failed to find locality"
assert rho < cfg.c + 0.05, "distributed LPA failed balance"
assert sum(stats["edge_shard_sizes"]) == g.num_directed_entries
print("DISTRIBUTED SELFTEST OK")
"""


PREGEL_DIST = """
import numpy as np
from jax.sharding import Mesh
from repro.core import generators, metrics, pregel
from repro.core.pregel_dist import pagerank_distributed
from repro.core.spinner import SpinnerConfig, partition
from repro.launch.mesh import make_partition_mesh

g = generators.watts_strogatz(4000, 12, 0.2, seed=3)
mesh = make_partition_mesh()
ndev = mesh.size
cfg = SpinnerConfig(k=ndev, seed=1)
res = partition(g, cfg, record_history=False)
hash_labels = (np.arange(g.num_vertices) * 2654435761 % ndev).astype(np.int32)

ref = pregel.pagerank(g, res.labels, ndev, iters=10).values
pr_sp, st_sp = pagerank_distributed(g, res.labels, mesh, iters=10)
pr_h, st_h = pagerank_distributed(g, hash_labels, mesh, iters=10)
np.testing.assert_allclose(pr_sp, ref, rtol=1e-4, atol=1e-9)
np.testing.assert_allclose(pr_h, ref, rtol=1e-4, atol=1e-9)
red = 1 - st_sp["halo_true_bytes_per_step"] / st_h["halo_true_bytes_per_step"]
print(f"devices={ndev} halo spinner={st_sp['halo_true_bytes_per_step']}B "
      f"hash={st_h['halo_true_bytes_per_step']}B reduction={red:.1%}")
assert red > 0.3, "spinner should reduce halo traffic"
print("PREGEL_DIST SELFTEST OK")
"""


@pytest.mark.slow
def test_distributed_spinner_8dev():
    r = run_devices_subprocess(DISTRIBUTED_SPINNER)
    assert "DISTRIBUTED SELFTEST OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_distributed_pregel_8dev():
    r = run_devices_subprocess(PREGEL_DIST)
    assert "PREGEL_DIST SELFTEST OK" in r.stdout, r.stdout + r.stderr


class TestShardingRules:
    def _mesh22(self):
        if len(jax.devices()) < 1:
            pytest.skip("no devices")
        import numpy as _np
        dev = _np.asarray(jax.devices()[:1]).reshape(1, 1)
        return jax.sharding.Mesh(dev, ("data", "model"))

    def test_param_rules_cover_all_archs(self):
        mesh = self._mesh22()
        for arch, cfg in ARCHS.items():
            api = build(cfg)
            sh = rules.param_shardings(api.param_specs, mesh)
            n = len(jax.tree.leaves(sh))
            assert n == len(jax.tree.leaves(api.param_specs)), arch

    def test_embed_rule(self):
        mesh = self._mesh22()
        api = build(ARCHS["granite-8b"])
        sh = rules.param_shardings(api.param_specs, mesh)
        spec = sh["embed"].spec
        assert spec[0] == "model"

    def test_batch_rule_replicates_batch1(self):
        # AbstractMesh gives real axis extents without needing 256 devices
        # (jax 0.4.37 signature: a tuple of (axis_name, size) pairs)
        mesh = jax.sharding.AbstractMesh((("data", 16), ("model", 16)))
        import jax.numpy as jnp
        from repro.models.common import spec as mkspec
        b = {"token": mkspec(1, dtype=jnp.int32),
             "tokens": mkspec(128, 64, dtype=jnp.int32)}
        sh = rules.batch_shardings(b, mesh)
        assert sh["token"].spec == jax.sharding.PartitionSpec()
        assert sh["tokens"].spec[0] in ("data", ("data",))

    def test_cache_rule_finds_batch_dim(self):
        mesh = self._mesh22()
        import jax.numpy as jnp
        from repro.models.common import spec as mkspec
        cache = mkspec(36, 128, 32768, 8, 128, dtype=jnp.bfloat16)
        sh = rules.cache_shardings(cache, mesh, batch_size=128)
        s = sh.spec
        # batch at dim 1, model on the largest divisible dim (sequence)
        assert s[1] is not None and s[2] == "model"

    def test_all_dryrun_cells_have_valid_input_specs(self):
        for arch, cfg in ARCHS.items():
            for sname, shape in SHAPES_BY_NAME.items():
                from repro.configs.base import cell_is_runnable
                if not cell_is_runnable(cfg, shape):
                    continue
                batch, cache = input_specs(cfg, shape)
                assert "tokens" in batch or "token" in batch, (arch, sname)
                if shape.kind == "decode":
                    assert cache is not None, (arch, sname)
