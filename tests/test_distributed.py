"""Multi-device behaviour (8 host devices) via subprocess selftests, plus
sharding-rule unit tests that run on the in-process single device."""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import SHAPES_BY_NAME
from repro.models import build, input_specs
from repro.parallel import rules

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_module(mod):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    return subprocess.run([sys.executable, "-m", mod], env=env, cwd=REPO,
                          capture_output=True, text=True, timeout=900)


@pytest.mark.slow
def test_distributed_spinner_selftest():
    r = _run_module("repro.core.distributed")
    assert "DISTRIBUTED SELFTEST OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_distributed_pregel_selftest():
    r = _run_module("repro.core.pregel_dist")
    assert "PREGEL_DIST SELFTEST OK" in r.stdout, r.stdout + r.stderr


class TestShardingRules:
    def _mesh22(self):
        if len(jax.devices()) < 1:
            pytest.skip("no devices")
        import numpy as _np
        dev = _np.asarray(jax.devices()[:1]).reshape(1, 1)
        return jax.sharding.Mesh(dev, ("data", "model"))

    def test_param_rules_cover_all_archs(self):
        mesh = self._mesh22()
        for arch, cfg in ARCHS.items():
            api = build(cfg)
            sh = rules.param_shardings(api.param_specs, mesh)
            n = len(jax.tree.leaves(sh))
            assert n == len(jax.tree.leaves(api.param_specs)), arch

    def test_embed_rule(self):
        mesh = self._mesh22()
        api = build(ARCHS["granite-8b"])
        sh = rules.param_shardings(api.param_specs, mesh)
        spec = sh["embed"].spec
        assert spec[0] == "model"

    def test_batch_rule_replicates_batch1(self):
        # AbstractMesh gives real axis extents without needing 256 devices
        mesh = jax.sharding.AbstractMesh((16, 16), ("data", "model"))
        import jax.numpy as jnp
        from repro.models.common import spec as mkspec
        b = {"token": mkspec(1, dtype=jnp.int32),
             "tokens": mkspec(128, 64, dtype=jnp.int32)}
        sh = rules.batch_shardings(b, mesh)
        assert sh["token"].spec == jax.sharding.PartitionSpec()
        assert sh["tokens"].spec[0] in ("data", ("data",))

    def test_cache_rule_finds_batch_dim(self):
        mesh = self._mesh22()
        import jax.numpy as jnp
        from repro.models.common import spec as mkspec
        cache = mkspec(36, 128, 32768, 8, 128, dtype=jnp.bfloat16)
        sh = rules.cache_shardings(cache, mesh, batch_size=128)
        s = sh.spec
        # batch at dim 1, model on the largest divisible dim (sequence)
        assert s[1] is not None and s[2] == "model"

    def test_all_dryrun_cells_have_valid_input_specs(self):
        for arch, cfg in ARCHS.items():
            for sname, shape in SHAPES_BY_NAME.items():
                from repro.configs.base import cell_is_runnable
                if not cell_is_runnable(cfg, shape):
                    continue
                batch, cache = input_specs(cfg, shape)
                assert "tokens" in batch or "token" in batch, (arch, sname)
                if shape.kind == "decode":
                    assert cache is not None, (arch, sname)
