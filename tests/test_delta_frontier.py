"""Delta-proportional adapt (PR 7): on-device CSR delta merge +
dirty-frontier LPA reconvergence.

Three claims under test (see repro.core.delta / session module docs):

  1. DATA PATH -- a warm ``adapt(edge_updates=...)`` whose batch fits the
     bucketed layout's slack performs ZERO new compiles, no host O(E)
     CSR rebuild and no full-graph re-upload, and is bit-identical to
     the classic ``add_edges`` + re-adapt oracle (integer Eq. 3 weights
     make the appended-slot layout score-exact).
  2. FALLBACK -- a batch overflowing the slack, a grown vertex set, or an
     ineligible configuration falls back to the rebuild path,
     bit-identically, and is counted in ``stats()["delta"]``.
  3. COMPUTE PATH -- ``adapt(..., frontier=True)`` on a converged base
     scores a strictly sub-linear fraction of vertices (reported per
     iteration via ``PartitionResult.scored_per_iter``) and lands on
     labels bit-identical to the full re-adapt oracle, for every
     engine x exchange plan x score backend in the matrix below.

CI split (like tests/test_overlap.py): tests named ``*pallas*`` /
``*exchange*`` run in the pallas-sharded job, the rest in the
multidevice job; the sharded matrices run on 2/4/8 forced host devices
via subprocesses, single-device in-process.
"""
import numpy as np
import pytest

from repro.core import (EngineOptions, SpinnerConfig, add_edges, delta,
                        extend_labels, from_edges, open_session,
                        shape_bucket)
from repro.core.generators import clustered_graph

from test_distributed import run_devices_subprocess


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def base_graph():
    """A random directed-edge graph (mixed w=1/w=2 Eq. 3 weights)."""
    rng = np.random.default_rng(0)
    V, E = 600, 2400
    return from_edges(rng.integers(0, V, E), rng.integers(0, V, E),
                      num_vertices=V)


@pytest.fixture(scope="module")
def fixed_point_graph():
    """Planted communities: LPA reaches a TRUE fixed point (re-adapt
    moves nothing), which is what frontier-parity needs -- on a graph
    that never quiesces the frontier legitimately never drains."""
    return clustered_graph(4, 150, p_in=0.2, p_out_edges_per_v=0.05,
                           seed=2)


def _converged(g, cfg, opts):
    """(session, fixed-point labels): partition, then one adapt to land
    exactly on the fixed point (asserted -- the parity claim is vacuous
    otherwise)."""
    s = open_session(g, cfg, opts)
    s.partition()
    r1 = s.adapt()
    r2 = s.adapt()
    assert np.array_equal(r1.labels, r2.labels), \
        "fixture regression: base labeling is not an LPA fixed point"
    return s, r2


# ---------------------------------------------------------------------------
# satellite: input validation (session.update / adapt / stage)
# ---------------------------------------------------------------------------

class TestEdgeUpdateValidation:
    CFG = SpinnerConfig(k=3, max_iters=7, seed=1)

    def _session(self, base_graph):
        return open_session(base_graph, self.CFG, EngineOptions())

    def test_mismatched_lengths(self, base_graph):
        s = self._session(base_graph)
        with pytest.raises(ValueError, match="length"):
            s.update([1, 2, 3], [4, 5])

    def test_negative_ids(self, base_graph):
        s = self._session(base_graph)
        with pytest.raises(ValueError, match="negative"):
            s.update([1, -2], [3, 4])

    def test_out_of_range_ids(self, base_graph):
        s = self._session(base_graph)
        V = base_graph.num_vertices
        with pytest.raises(ValueError, match="vertices"):
            s.update([1, V], [3, 4])
        # ...but in-range for a GROWN vertex set is fine
        s.update([1, V], [3, 4], num_vertices=V + 1)
        assert s.graph.num_vertices == V + 1

    def test_non_integer_dtype(self, base_graph):
        s = self._session(base_graph)
        with pytest.raises(ValueError, match="integer"):
            s.update(np.array([1.5, 2.0]), np.array([3, 4]))

    def test_non_1d(self, base_graph):
        s = self._session(base_graph)
        with pytest.raises(ValueError, match="1-D"):
            s.update(np.zeros((2, 2), np.int32), np.zeros((2, 2), np.int32))

    def test_adapt_and_stage_validate_too(self, base_graph):
        s = self._session(base_graph)
        s.partition()
        with pytest.raises(ValueError, match="negative"):
            s.adapt(edge_updates=([1], [-1]))
        with pytest.raises(ValueError, match="length"):
            s.stage(edge_updates=([1, 2], [3]))

    def test_check_edge_updates_direct(self):
        src, dst = delta.check_edge_updates([0, 1], [1, 2], 3)
        assert src.dtype == np.int32 and dst.dtype == np.int32
        with pytest.raises(ValueError):
            delta.check_edge_updates([0], [5], 3)
        # growth bound wins when larger
        delta.check_edge_updates([0], [5], 3, new_num_vertices=6)


def test_extend_labels_shrink_raises():
    with pytest.raises(ValueError, match="remove_vertices"):
        extend_labels(np.zeros(10, np.int32), 5)
    out = extend_labels(np.zeros(10, np.int32), 12)
    assert out.shape == (12,) and (out[10:] == -1).all()


# ---------------------------------------------------------------------------
# tentpole data path: on-device delta merge
# ---------------------------------------------------------------------------

class TestDeltaMerge:
    OPTS = EngineOptions(engine="fused")

    def _oracle(self, g, batch, prev, cfg, num_vertices=None):
        g2 = add_edges(g, *batch, num_vertices=num_vertices)
        o = open_session(g2, cfg, self.OPTS)
        return o.adapt(prev=prev), g2

    def test_warm_delta_zero_compiles_no_rebuild_no_reupload(
            self, base_graph):
        cfg = SpinnerConfig(k=4, max_iters=37, seed=3)
        s = open_session(base_graph, cfg, self.OPTS)
        r0 = s.partition()
        rng = np.random.default_rng(1)
        V = base_graph.num_vertices
        full_bytes = 12 * base_graph.num_directed_entries  # src+dst+w f32/i32

        b1 = (rng.integers(0, V, 16), rng.integers(0, V, 16))
        r1 = s.adapt(edge_updates=b1)
        st = s.stats()
        assert st["delta"]["fast_adapts"] == 1
        assert st["delta"]["host_rebuilds"] == 0
        assert st["delta"]["fallback_adapts"] == 0
        assert 0 < st["delta"]["last_upload_bytes"] < full_bytes // 10
        warm_compiles = st["compiles"]

        # second same-bucket batch: ZERO new compiles, still no rebuild
        b2 = (rng.integers(0, V, 16), rng.integers(0, V, 16))
        r2 = s.adapt(edge_updates=b2)
        st = s.stats()
        assert st["compiles"] == warm_compiles, \
            "warm same-bucket delta adapt must not compile"
        assert st["delta"]["fast_adapts"] == 2
        assert st["delta"]["host_rebuilds"] == 0

        # bit-parity with the classic rebuild oracle at every step
        ro1, g1 = self._oracle(base_graph, b1, r0.labels, cfg)
        ro2, _ = self._oracle(g1, b2, ro1.labels, cfg)
        assert np.array_equal(r1.labels, ro1.labels)
        assert np.array_equal(r2.labels, ro2.labels)
        assert st["delta"]["tracked_total_weight"] == \
            add_edges(g1, *b2).total_weight

    def test_duplicate_edges_one_batch(self, base_graph):
        """Duplicates within a batch, reverse-direction upgrades of an
        existing w=1 edge, and self-loops all coalesce exactly like
        ``add_edges`` (union-of-directions semantics)."""
        cfg = SpinnerConfig(k=4, max_iters=31, seed=5)
        s = open_session(base_graph, cfg, self.OPTS)
        r0 = s.partition()
        # an existing single-direction (w=1) edge to upgrade
        w = np.asarray(base_graph.weight)
        src = np.asarray(base_graph.src)
        dst = np.asarray(base_graph.dst)
        one = np.flatnonzero((w == 1) & (src != dst))[0]
        u, v = int(src[one]), int(dst[one])
        batch = (np.array([u, u, v, 7, 9, 9, 11], np.int64),
                 np.array([v, v, u, 7, 10, 10, 12], np.int64))
        # (u,v) dup + (v,u) -> upgrade to w=2; (7,7) self-loop dropped;
        # (9,10) dup; (11,12) plain new
        r1 = s.adapt(edge_updates=batch)
        assert s.stats()["delta"]["fast_adapts"] == 1
        ro, g2 = self._oracle(base_graph, batch, r0.labels, cfg)
        assert np.array_equal(r1.labels, ro.labels)
        assert s.stats()["delta"]["tracked_total_weight"] == g2.total_weight

    def test_overflow_falls_back_bit_identical(self):
        """A delta larger than the bucket slack rebuilds on host --
        same labels, counted as a fallback."""
        V = 500
        g = from_edges(np.arange(V - 1), np.arange(1, V), num_vertices=V,
                       directed=False)   # path graph: tiny E bucket slack
        cfg = SpinnerConfig(k=4, max_iters=29, seed=7)
        slack = shape_bucket(g.num_directed_entries) - g.num_directed_entries
        batch = (np.arange(0, V - 2), np.arange(2, V))  # all-new pairs
        assert 2 * (V - 2) > slack
        s = open_session(g, cfg, self.OPTS)
        r0 = s.partition()
        r1 = s.adapt(edge_updates=batch)
        st = s.stats()["delta"]
        assert st["fast_adapts"] == 0
        assert st["fallback_adapts"] == 1
        assert st["host_rebuilds"] >= 1
        ro, _ = self._oracle(g, batch, r0.labels, cfg)
        assert np.array_equal(r1.labels, ro.labels)

    def test_vertex_growth_falls_back(self, base_graph):
        cfg = SpinnerConfig(k=4, max_iters=23, seed=9)
        V = base_graph.num_vertices
        s = open_session(base_graph, cfg, self.OPTS)
        r0 = s.partition()
        batch = (np.array([1, V + 2]), np.array([V, V + 1]))
        r1 = s.adapt(edge_updates=batch, num_vertices=V + 3)
        assert s.graph.num_vertices == V + 3
        assert s.stats()["delta"]["fast_adapts"] == 0
        ro, _ = self._oracle(base_graph, batch, r0.labels, cfg,
                             num_vertices=V + 3)
        assert np.array_equal(r1.labels, ro.labels)

    def test_update_pending_log_chains_with_fast_adapt(self, base_graph):
        """``update()`` batches join the pending log and are folded into
        the next fast adapt without a host rebuild."""
        cfg = SpinnerConfig(k=4, max_iters=43, seed=11)
        rng = np.random.default_rng(2)
        V = base_graph.num_vertices
        s = open_session(base_graph, cfg, self.OPTS)
        r0 = s.partition()
        b1 = (rng.integers(0, V, 8), rng.integers(0, V, 8))
        b2 = (rng.integers(0, V, 8), rng.integers(0, V, 8))
        s.update(*b1)
        r = s.adapt(edge_updates=b2)
        st = s.stats()["delta"]
        assert st["fast_adapts"] == 1 and st["host_rebuilds"] == 0
        assert st["merged_batches"] == 2
        ro1, g1 = self._oracle(base_graph, b1, r0.labels, cfg)
        del ro1  # update() does not run; only the final state must match
        o = open_session(add_edges(g1, *b2), cfg, self.OPTS)
        ro = o.adapt(prev=r0.labels)
        assert np.array_equal(r.labels, ro.labels)

    def test_stage_interaction(self, base_graph):
        """stage(edge_updates=) materializes the pending log (full host
        Graph) and the staged snapshot is consumed by the next adapt."""
        cfg = SpinnerConfig(k=4, max_iters=47, seed=13)
        rng = np.random.default_rng(3)
        V = base_graph.num_vertices
        s = open_session(base_graph, cfg, self.OPTS)
        r0 = s.partition()
        b1 = (rng.integers(0, V, 8), rng.integers(0, V, 8))
        b2 = (rng.integers(0, V, 8), rng.integers(0, V, 8))
        r1 = s.adapt(edge_updates=b1)          # fast path
        assert s.stats()["delta"]["fast_adapts"] == 1
        s.stage(edge_updates=b2)               # materializes + rebuilds
        st = s.stats()
        assert st["delta"]["host_rebuilds"] >= 1
        assert st["staged"] == V
        r2 = s.adapt()                         # consumes the staged graph
        g1 = add_edges(base_graph, *b1)
        g2 = add_edges(g1, *b2)
        o1 = open_session(g1, cfg, self.OPTS)
        ro1 = o1.adapt(prev=r0.labels)
        o2 = open_session(g2, cfg, self.OPTS)
        ro2 = o2.adapt(prev=ro1.labels)
        assert np.array_equal(r1.labels, ro1.labels)
        assert np.array_equal(r2.labels, ro2.labels)

    def test_pallas_fused_delta_parity_zero_compiles(self, base_graph):
        """The tiled-CSR merge: per-tile slack slots + deg_t + the COO
        mirror, on the Pallas fused backend (interpret on CPU)."""
        cfg = SpinnerConfig(k=4, max_iters=41, seed=15)
        opts = EngineOptions(engine="fused", score_backend="pallas",
                             fused_update="on")
        s = open_session(base_graph, cfg, opts)
        r0 = s.partition()
        rng = np.random.default_rng(4)
        V = base_graph.num_vertices
        b1 = (rng.integers(0, V, 24), rng.integers(0, V, 24))
        r1 = s.adapt(edge_updates=b1)
        st = s.stats()
        assert st["delta"]["fast_adapts"] == 1
        assert st["delta"]["host_rebuilds"] == 0
        warm = st["compiles"]
        b2 = (rng.integers(0, V, 24), rng.integers(0, V, 24))
        r2 = s.adapt(edge_updates=b2)
        assert s.stats()["compiles"] == warm
        g1 = add_edges(base_graph, *b1)
        g2 = add_edges(g1, *b2)
        o1 = open_session(g1, cfg, opts)
        ro1 = o1.adapt(prev=r0.labels)
        o2 = open_session(g2, cfg, opts)
        ro2 = o2.adapt(prev=ro1.labels)
        assert np.array_equal(r1.labels, ro1.labels)
        assert np.array_equal(r2.labels, ro2.labels)


# ---------------------------------------------------------------------------
# tentpole compute path: dirty-frontier reconvergence (single device)
# ---------------------------------------------------------------------------

class TestFrontierSingleDevice:

    def _parity(self, g, cfg, opts, seed=3, nb=8):
        s, r1 = _converged(g, cfg, opts)
        rng = np.random.default_rng(seed)
        V = g.num_vertices
        b = (rng.integers(0, V, nb), rng.integers(0, V, nb))
        rf = s.adapt(edge_updates=b, frontier=True)
        o = open_session(add_edges(g, *b), cfg, opts)
        ro = o.adapt(prev=r1.labels)
        assert np.array_equal(rf.labels, ro.labels), \
            "frontier labels diverge from the full re-adapt oracle"
        # strictly sub-linear scored fraction, reported per iteration
        assert rf.iterations >= 1
        assert len(rf.scored_per_iter) == rf.iterations
        assert rf.scored_vertices == sum(rf.scored_per_iter)
        assert rf.scored_vertices < 0.25 * V * rf.iterations
        return rf

    def test_frontier_parity_xla(self, fixed_point_graph):
        cfg = SpinnerConfig(k=4, max_iters=120, seed=9, c=1.6)
        self._parity(fixed_point_graph, cfg, EngineOptions(engine="fused"))

    def test_frontier_parity_xla_fused_on(self, fixed_point_graph):
        cfg = SpinnerConfig(k=4, max_iters=121, seed=9, c=1.6)
        self._parity(fixed_point_graph, cfg,
                     EngineOptions(engine="fused", fused_update="on"))

    def test_frontier_parity_pallas_fused(self, fixed_point_graph):
        cfg = SpinnerConfig(k=4, max_iters=122, seed=9, c=1.6)
        self._parity(fixed_point_graph, cfg,
                     EngineOptions(engine="fused", score_backend="pallas",
                                   fused_update="on"))

    def test_frontier_full_active_degenerates_to_drain_lpa(
            self, fixed_point_graph):
        """No delta provenance -> every vertex active; on a fixed point
        the frontier drains immediately with unchanged labels."""
        cfg = SpinnerConfig(k=4, max_iters=123, seed=9, c=1.6)
        s, r1 = _converged(fixed_point_graph, cfg,
                           EngineOptions(engine="fused"))
        rf = s.adapt(frontier=True)
        assert np.array_equal(rf.labels, r1.labels)
        assert rf.halted

    def test_frontier_rejects_history_and_chunked(self, fixed_point_graph):
        cfg = SpinnerConfig(k=4, max_iters=124, seed=9, c=1.6)
        s, _ = _converged(fixed_point_graph, cfg,
                          EngineOptions(engine="fused"))
        with pytest.raises(ValueError, match="frontier"):
            s.adapt(frontier=True, record_history=True)
        with pytest.raises(ValueError, match="frontier"):
            s.adapt(frontier=True, callback=lambda i, e: None)
        s2 = open_session(fixed_point_graph, cfg,
                          EngineOptions(engine="chunked"))
        s2.partition()
        with pytest.raises(ValueError, match="while_loop"):
            s2.adapt(frontier=True)


# ---------------------------------------------------------------------------
# sharded matrix: 2/4/8 forced host devices (subprocess), exchange plans
# ---------------------------------------------------------------------------

SHARDED_DELTA_FRONTIER = """
import numpy as np, jax
from jax.sharding import Mesh
import repro.core as core
from repro.core.generators import clustered_graph

ndev = {ndev}
g = clustered_graph(4, 150, p_in=0.2, p_out_edges_per_v=0.05, seed=2)
V = g.num_vertices
cfg = core.SpinnerConfig(k=4, max_iters=83, seed=9, c=1.6)
mesh = Mesh(np.array(jax.devices()), ("data",))
assert mesh.size == ndev, mesh
rng = np.random.default_rng(3)
b = (rng.integers(0, V, 8), rng.integers(0, V, 8))
g1 = core.add_edges(g, *b)

for plan in ("allgather", "delta"):
    for fused in ("off", "on"):
        opts = core.EngineOptions(engine="sharded", mesh=mesh,
                                  label_exchange=plan, overlap="off",
                                  fused_update=fused)
        s = core.open_session(g, cfg, opts)
        s.partition()
        r1 = s.adapt()
        r2 = s.adapt()
        assert np.array_equal(r1.labels, r2.labels), (plan, "fixed point")
        o = core.open_session(g1, cfg, opts)
        ro = o.adapt(prev=r2.labels)
        # data path: on-device merge into the sharded segment slack
        rfast = s.adapt(edge_updates=b)
        st = s.stats()["delta"]
        assert st["fast_adapts"] == 1 and st["host_rebuilds"] == 0, st
        assert np.array_equal(rfast.labels, ro.labels), (plan, fused, "fast")
        # compute path: sharded dirty-frontier reconvergence
        s2 = core.open_session(g, cfg, opts)
        s2.partition(); s2.adapt()
        rf = s2.adapt(edge_updates=b, frontier=True)
        assert np.array_equal(rf.labels, ro.labels), (plan, fused, "frontier")
        assert rf.scored_vertices < 0.25 * V * max(1, rf.iterations), (
            plan, fused, rf.scored_per_iter)

# halo's boundary-slot dst layout is ineligible for the on-device merge:
# the fast path must refuse, the fallback must stay bit-identical, and
# frontier mode must still work through the materialized run
opts = core.EngineOptions(engine="sharded", mesh=mesh,
                          label_exchange="halo", overlap="off")
s = core.open_session(g, cfg, opts)
s.partition()
r1 = s.adapt()
o = core.open_session(g1, cfg, opts)
ro = o.adapt(prev=r1.labels)
rf = s.adapt(edge_updates=b, frontier=True)
st = s.stats()["delta"]
assert st["fast_adapts"] == 0 and st["fallback_adapts"] == 1, st
assert np.array_equal(rf.labels, ro.labels), "halo frontier"
print("SHARDED DELTA/FRONTIER OK", ndev)
"""


@pytest.mark.slow
@pytest.mark.parametrize("ndev", [2, 4, 8])
def test_sharded_delta_frontier_exchange_parity(ndev):
    r = run_devices_subprocess(SHARDED_DELTA_FRONTIER.format(ndev=ndev),
                               ndev=ndev)
    assert r.returncode == 0, r.stderr[-4000:]
    assert f"SHARDED DELTA/FRONTIER OK {ndev}" in r.stdout
