"""Shared fixtures. NOTE: no XLA_FLAGS here -- tests see 1 CPU device;
multi-device behaviour is tested via subprocesses (test_distributed.py)."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def small_world():
    from repro.core import generators
    return generators.watts_strogatz(3000, 10, 0.25, seed=7)


@pytest.fixture(scope="session")
def clustered():
    from repro.core import generators
    return generators.clustered_graph(8, 250, p_in=0.05,
                                      p_out_edges_per_v=1.0, seed=5)


@pytest.fixture(scope="session")
def powerlaw():
    from repro.core import generators
    return generators.powerlaw_ba(2000, 6, seed=9)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
