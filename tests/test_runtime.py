"""Fault tolerance: simulated crash + restart continues bit-exactly;
gradient compression with error feedback stays unbiased."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.data import pipeline
from repro.models import build, init_params
from repro.optim import adamw, compression
from repro.runtime import SupervisorConfig, TrainSupervisor
from repro.train import steps


@pytest.fixture(scope="module")
def small_setup():
    cfg = ARCHS["stablelm-1.6b"].reduced()
    api = build(cfg)
    params = init_params(api, jax.random.PRNGKey(0))
    opt = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=100)
    train_step = jax.jit(steps.make_train_step(api, opt))
    data_cfg = pipeline.DataConfig(vocab=cfg.vocab, seq_len=32,
                                   global_batch=4, seed=2)

    def batch_fn(step):
        return jax.tree.map(jnp.asarray, pipeline.batch_at(data_cfg, step))

    return api, train_step, batch_fn, params


class TestCrashRestart:
    def test_crash_restart_bitexact(self, small_setup, tmp_path):
        api, train_step, batch_fn, params = small_setup
        sup_cfg = SupervisorConfig(ckpt_dir=str(tmp_path), ckpt_every=4)

        # uninterrupted run
        ref = TrainSupervisor(SupervisorConfig(
            ckpt_dir=str(tmp_path / "ref"), ckpt_every=4),
            steps.init_train_state(params))
        final_ref = ref.run(train_step, batch_fn, 10)

        # crashing run: dies at step 7, restarts from ckpt at step 4
        sup = TrainSupervisor(sup_cfg, steps.init_train_state(params))
        with pytest.raises(RuntimeError):
            sup.run(train_step, batch_fn, 10, crash_at=7)
        sup2 = TrainSupervisor(sup_cfg, steps.init_train_state(params))
        assert sup2.start_step == 4
        final = sup2.run(train_step, batch_fn, 10)

        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)),
            final_ref.params, final.params)

    def test_straggler_flagging(self, small_setup, tmp_path):
        import time
        api, train_step, batch_fn, params = small_setup
        sup = TrainSupervisor(SupervisorConfig(
            ckpt_dir=str(tmp_path / "s"), ckpt_every=100,
            straggler_factor=2.0), steps.init_train_state(params))

        calls = {"n": 0}

        def slow_step(state, batch):
            calls["n"] += 1
            if calls["n"] == 9:
                time.sleep(1.0)          # one pathological step
            return train_step(state, batch)

        sup.run(slow_step, batch_fn, 10)
        assert len(sup.flagged_steps) >= 1


class TestCompression:
    def test_roundtrip_small_error(self):
        x = jnp.asarray(np.random.default_rng(0).standard_normal(1000),
                        jnp.float32)
        c = compression.compress(x)
        y = compression.decompress(c, x.shape)
        assert float(jnp.abs(x - y).max()) < 0.05
        assert compression.wire_bytes({"x": c}) < 0.3 * 4 * x.size

    def test_error_feedback_unbiased(self):
        # constant gradient: with error feedback the ACCUMULATED applied
        # update converges to the true sum despite per-step quantization
        g = {"w": jnp.full((300,), 0.01234, jnp.float32)}
        errors = None
        applied = jnp.zeros((300,))
        for _ in range(50):
            comp, errors = compression.compress_tree(g, errors)
            applied = applied + compression.decompress_tree(comp, g)["w"]
        expect = 50 * 0.01234
        np.testing.assert_allclose(np.asarray(applied),
                                   np.full(300, expect), rtol=0.02)

    def test_tree_structure_preserved(self):
        g = {"a": jnp.ones((10, 10)), "b": {"c": jnp.ones(7)}}
        comp, errors = compression.compress_tree(g)
        out = compression.decompress_tree(comp, g)
        assert jax.tree.structure(out) == jax.tree.structure(g)
        assert out["b"]["c"].shape == (7,)
