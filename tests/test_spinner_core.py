"""Core Spinner behaviour: Eq. 3 conversion, quality, balance, halting."""
import numpy as np
import pytest

from repro.core import (EngineOptions, SpinnerConfig, from_edges, metrics,
                        partition)
from repro.core import generators


class TestGraphConversion:
    def test_directed_weights_eq3(self):
        # 0->1 (one-way, w=1); 1<->2 (reciprocal, w=2); self-loop dropped
        g = from_edges([0, 1, 2, 2], [1, 2, 1, 2], 3, directed=True)
        g.validate()
        assert g.num_undirected_edges == 2
        w = {(int(s), int(d)): float(wt)
             for s, d, wt in zip(g.src, g.dst, g.weight)}
        assert w[(0, 1)] == 1.0 and w[(1, 0)] == 1.0
        assert w[(1, 2)] == 2.0 and w[(2, 1)] == 2.0

    def test_duplicate_directed_edges_collapse(self):
        g = from_edges([0, 0, 0], [1, 1, 1], 2, directed=True)
        assert g.num_undirected_edges == 1
        assert float(g.weight.max()) == 1.0

    def test_undirected_input_weight_one(self):
        g = from_edges([0, 1], [1, 0], 2, directed=False)
        assert float(g.weight.max()) == 1.0

    def test_degrees_symmetric(self, small_world):
        small_world.validate()
        assert small_world.deg_w.sum() == pytest.approx(
            2 * small_world.weight[small_world.src < small_world.dst].sum())


class TestPartitionQuality:
    def test_locality_beats_hash(self, small_world):
        cfg = SpinnerConfig(k=8, seed=0)
        res = partition(small_world, cfg, record_history=False)
        hash_labels = np.arange(small_world.num_vertices) % 8
        assert metrics.phi(small_world, res.labels) > \
            5 * metrics.phi(small_world, hash_labels)

    def test_balance_within_capacity(self, small_world):
        cfg = SpinnerConfig(k=8, seed=0)
        res = partition(small_world, cfg, record_history=False)
        # rho <= c with small tolerance for the probabilistic throttle
        assert metrics.rho(small_world, res.labels, 8) < cfg.c + 0.03

    def test_clustered_graph_recovers_locality(self, clustered):
        cfg = SpinnerConfig(k=8, seed=1)
        res = partition(clustered, cfg, record_history=False)
        assert metrics.phi(clustered, res.labels) > 0.55

    def test_halting_fires(self, small_world):
        cfg = SpinnerConfig(k=4, seed=0, max_iters=300)
        res = partition(small_world, cfg, record_history=False)
        assert res.halted and res.iterations < 300

    def test_deterministic_given_seed(self, clustered):
        cfg = SpinnerConfig(k=4, seed=3, max_iters=40)
        a = partition(clustered, cfg, record_history=False)
        b = partition(clustered, cfg, record_history=False)
        np.testing.assert_array_equal(a.labels, b.labels)

    def test_score_improves(self, small_world):
        cfg = SpinnerConfig(k=8, seed=0, max_iters=60)
        res = partition(small_world, cfg)
        scores = [h["score"] for h in res.history]
        assert scores[-1] > scores[0]

    def test_paper_vertex_weighting_variant(self, small_world):
        # Literal Eq. 12 (M counts vertices): the throttle rarely binds, so
        # convergence is measurably worse than degree weighting -- kept as
        # an ablation (see EXPERIMENTS.md "migration weighting").
        cfg = SpinnerConfig(k=8, seed=0, migration_weighting="vertices")
        res = partition(small_world, cfg, record_history=False)
        hash_phi = metrics.phi(small_world,
                               np.arange(small_world.num_vertices) % 8)
        assert metrics.phi(small_world, res.labels) > 1.5 * hash_phi

    def test_kernel_path_equivalent_quality(self, clustered):
        cfg = SpinnerConfig(k=4, seed=2, max_iters=40)
        res = partition(clustered, cfg, record_history=False,
                        options=EngineOptions(score_backend="pallas"))
        assert metrics.phi(clustered, res.labels) > 0.5
        assert metrics.rho(clustered, res.labels, 4) < cfg.c + 0.05


class TestLoadsConsistency:
    def test_loads_match_recompute(self, powerlaw):
        cfg = SpinnerConfig(k=6, seed=0, max_iters=30)
        res = partition(powerlaw, cfg, record_history=False)
        expect = metrics.loads(powerlaw, res.labels, 6)
        np.testing.assert_allclose(res.loads, expect, rtol=1e-4)
