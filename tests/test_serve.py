"""Serving tier: batched same-bucket execution, delta coalescing,
scheduler parity, program sharing, session lifecycle.

The load-bearing claims (ISSUE 8 acceptance):

* every label set produced under the scheduler is bit-identical to
  serial per-session execution for the tested interleavings -- coalesced
  vs one-by-one deltas, batch-of-1 vs the unbatched program -- across
  engines x exchange plans on 1 and (via subprocesses) 8 forced host
  devices;
* two sessions in one (V, E, k) bucket share compiled programs: zero
  new compiles for the second tenant, unbatched AND via the batched
  runner;
* ``close()`` is idempotent and every closed-session entry point raises
  the same RuntimeError.

Each test uses a unique ``max_iters`` so its programs are private to it
(compile counters can't be perturbed by other tests).
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (EngineOptions, SpinnerConfig, generators,
                        open_session)
from repro.core import delta as _delta
from repro.core import engine as _engine
from repro.core.graph import add_edges
from repro.core.spinner import prepare_init
from repro.serve import (KSweepPrecompile, PartitionScheduler,
                         StagePrefetch, Ticket, traffic)

from test_distributed import run_devices_subprocess


def _graph(v, seed):
    return generators.watts_strogatz(v, 8, 0.1, seed=seed)


def _delta_batch(rng, v, n=12):
    src = rng.integers(0, v, n)
    dst = rng.integers(0, v, n)
    m = src != dst
    return src[m], dst[m]


def _assert_same(a, b, what=""):
    assert np.array_equal(a.labels, b.labels), what
    assert a.iterations == b.iterations, what
    assert a.halted == b.halted, what
    assert np.array_equal(a.loads, b.loads), what


def _parts_for(graph, cfg, seed_cfg=None):
    """An (init_state, bind) work item the way run_fused would build it."""
    c = cfg if seed_cfg is None else seed_cfg
    labels, loads, key = prepare_init(graph, c, None)
    opts_t = _engine._autotuned(graph, c, _engine._DEFAULT_OPTS)
    bind, padded = _engine._single_bind(graph, c, opts_t)
    state = _engine.init_state(
        _engine.pad_labels(labels, padded.num_vertices), loads, key)
    return state, bind, opts_t


# ---------------------------------------------------------------------------
# engine.run_batched: the vmap'd same-bucket executor
# ---------------------------------------------------------------------------

class TestBatchedRunner:
    def test_batched_matches_unbatched_per_element(self):
        """3 same-bucket graphs (padded to a batch of 4): every element's
        final state is bit-identical to its own unbatched fused run."""
        cfg = SpinnerConfig(k=8, max_iters=141, seed=3)
        graphs = [_graph(490 + 5 * i, seed=i) for i in range(3)]
        assert len({_engine.graph_buckets(g) for g in graphs}) == 1
        items, refs, opts_t = [], [], None
        for i, g in enumerate(graphs):
            c = dataclasses.replace(cfg, seed=10 + i)
            state, bind, opts_t = _parts_for(g, cfg, c)
            items.append((state, bind))
            labels, loads, key = prepare_init(g, c, None)
            refs.append(_engine.run_fused(g, c, labels, loads, key,
                                          opts=_engine._DEFAULT_OPTS))
        outs = _engine.run_batched(items, cfg, opts_t)
        sigs = {_engine.batch_signature(cfg, opts_t, b) for _, b in items}
        assert len(sigs) == 1
        for g, out, ref in zip(graphs, outs, refs):
            v = g.num_vertices
            assert np.array_equal(np.asarray(out.labels)[:v],
                                  np.asarray(ref.labels))
            assert int(out.iteration) == int(ref.iteration)
            assert bool(out.halted) == bool(ref.halted)
            assert float(out.score) == float(ref.score)
            assert np.array_equal(np.asarray(out.loads),
                                  np.asarray(ref.loads))

    def test_batch_of_one_bit_identical(self):
        cfg = SpinnerConfig(k=6, max_iters=142, seed=1)
        g = _graph(430, seed=4)
        state, bind, opts_t = _parts_for(g, cfg)
        labels, loads, key = prepare_init(g, cfg, None)
        ref = _engine.run_fused(g, cfg, labels, loads, key,
                                opts=_engine._DEFAULT_OPTS)
        (out,) = _engine.run_batched([(state, bind)], cfg, opts_t)
        v = g.num_vertices
        assert np.array_equal(np.asarray(out.labels)[:v],
                              np.asarray(ref.labels))
        assert int(out.iteration) == int(ref.iteration)
        assert float(out.score) == float(ref.score)

    def test_batch_bucket(self):
        assert [_engine.batch_bucket(n) for n in (1, 2, 3, 4, 5, 8, 9)] \
            == [1, 2, 4, 4, 8, 8, 16]


# ---------------------------------------------------------------------------
# session scheduler entry points
# ---------------------------------------------------------------------------

class TestAdaptParts:
    def test_stream_matches_adapt(self, rng):
        """adapt_parts -> run_batched -> commit_adapt walks the same
        stream as adapt(): fast-path deltas, then an argless re-run."""
        cfg = SpinnerConfig(k=8, max_iters=143, seed=5)
        g = _graph(400, seed=0)
        stream = [_delta_batch(rng, 400), _delta_batch(rng, 400), None]
        ref = open_session(g, cfg)
        ref.partition(record_history=False)
        s = open_session(g, cfg)
        s.partition(record_history=False)
        for d in stream:
            r_ref = ref.adapt(edge_updates=d, record_history=False) \
                if d is not None else ref.adapt(record_history=False)
            state, bind, c, opts_t = s.adapt_parts(edge_updates=d)
            (out,) = _engine.run_batched([(state, bind)], c, opts_t)
            _assert_same(s.commit_adapt(out), r_ref, f"delta {d is None}")
        assert s.stats()["delta"]["fast_adapts"] == 2
        assert np.array_equal(s.labels, ref.labels)

    def test_batchable_eligibility(self):
        cfg = SpinnerConfig(k=4, max_iters=144, seed=0)
        g = _graph(300, seed=1)
        assert open_session(g, cfg).batchable()
        for opts in (EngineOptions(engine="chunked"),
                     EngineOptions(engine="host"),
                     EngineOptions(engine="sharded"),
                     EngineOptions(score_backend="pallas")):
            s = open_session(g, cfg, opts)
            assert not s.batchable(), opts
            assert s.adapt_parts() is None, opts

    def test_batch_key_same_bucket(self):
        cfg = SpinnerConfig(k=4, max_iters=144, seed=0)
        assert open_session(_graph(300, seed=1), cfg).batch_key() \
            == open_session(_graph(310, seed=2), cfg).batch_key()
        assert open_session(_graph(300, seed=1), cfg).batch_key() \
            != open_session(_graph(900, seed=2), cfg).batch_key()


# ---------------------------------------------------------------------------
# delta coalescing
# ---------------------------------------------------------------------------

class TestCoalescing:
    def test_coalesce_updates_concat_and_dedupe(self):
        b1 = (np.array([0, 1]), np.array([2, 3]))
        b2 = (np.array([0, 4]), np.array([2, 5]))   # (0->2) repeats
        src, dst = _delta.coalesce_updates([b1, b2])
        assert list(zip(src, dst)) == [(0, 2), (1, 3), (4, 5)]
        src, dst = _delta.coalesce_updates([b1, b2], dedupe=False)
        assert len(src) == 4
        src, dst = _delta.coalesce_updates([])
        assert src.size == 0 and dst.size == 0

    def test_coalesce_updates_direction_canonicalization(self):
        """Eq. 3 canonicalizes weight-1 pairs to lo->hi, so a LATER
        reverse-direction repeat bumps the pair to weight 2 -- the
        coalesced batch must keep both directions for exactly those."""
        rev = (np.array([2]), np.array([0]))        # reverse of canonical
        can = (np.array([0]), np.array([2]))
        # same reverse edge twice across batches: sequential gives w=2
        src, dst = _delta.coalesce_updates([rev, rev])
        assert sorted(zip(src, dst)) == [(0, 2), (2, 0)]
        # reverse then canonical: the later lo->hi is a no-op, w stays 1
        src, dst = _delta.coalesce_updates([rev, can])
        assert list(zip(src, dst)) == [(2, 0)]
        # canonical then reverse: w=2
        src, dst = _delta.coalesce_updates([can, rev])
        assert sorted(zip(src, dst)) == [(0, 2), (2, 0)]
        # canonical repeated: idempotent
        src, dst = _delta.coalesce_updates([can, can])
        assert list(zip(src, dst)) == [(0, 2)]
        # both directions in ONE batch: w=2 from the start
        both = (np.array([0, 2]), np.array([2, 0]))
        src, dst = _delta.coalesce_updates([both])
        assert sorted(zip(src, dst)) == [(0, 2), (2, 0)]
        # self-loops never count
        src, dst = _delta.coalesce_updates([(np.array([3]), np.array([3]))])
        assert src.size == 0

    def test_coalesced_equals_one_by_one(self, rng):
        """One concatenated apply_delta plan == N sequential plans (the
        union weight semantics), down to bit-identical labels -- and both
        equal the host-rebuild oracle."""
        cfg = SpinnerConfig(k=8, max_iters=145, seed=2)
        g = _graph(420, seed=3)
        b1, b2 = _delta_batch(rng, 420), _delta_batch(rng, 420)
        # b3 overlaps b1: the dedupe path must stay exact
        b3 = (np.concatenate([b1[0][:3], _delta_batch(rng, 420, 6)[0]]),
              np.concatenate([b1[1][:3], _delta_batch(rng, 420, 6)[1]]))

        one_by_one = open_session(g, cfg)
        one_by_one.partition(record_history=False)
        one_by_one.update(*b1).update(*b2)
        r_seq = one_by_one.adapt(edge_updates=b3, record_history=False)
        assert one_by_one.stats()["delta"]["fast_adapts"] == 1

        coalesced = open_session(g, cfg)
        coalesced.partition(record_history=False)
        r_coal = coalesced.adapt(
            edge_updates=_delta.coalesce_updates([b1, b2, b3]),
            record_history=False)
        _assert_same(r_seq, r_coal, "coalesced vs one-by-one")

        oracle = open_session(g, cfg)
        oracle.partition(record_history=False)
        g2 = add_edges(add_edges(add_edges(g, *b1), *b2), *b3)
        _assert_same(oracle.adapt(new_graph=g2, record_history=False),
                     r_coal, "coalesced vs rebuild oracle")


# ---------------------------------------------------------------------------
# the scheduler
# ---------------------------------------------------------------------------

class TestScheduler:
    def test_window_coalescing_matches_serial(self, rng):
        """A queued [eu, eu, adapt] window dispatches once; all three
        tickets resolve to the result of update;update;adapt replayed
        serially on a twin session."""
        cfg = SpinnerConfig(k=8, max_iters=146, seed=4)
        g = _graph(410, seed=5)
        b1, b2 = _delta_batch(rng, 410), _delta_batch(rng, 410)
        sched = PartitionScheduler()
        sched.add_tenant("a", g, cfg, partition=True)
        t1 = sched.submit("a", "edge_updates", edge_updates=b1)
        t2 = sched.submit("a", "edge_updates", edge_updates=b2)
        t3 = sched.submit("a", "adapt")
        assert sched.drain() == 3
        assert t1.result is t2.result is t3.result
        assert t3.coalesced == 3 and t3.done and not t3.failed
        assert sched.stats()["coalescing_factor"] == 2.0

        twin = open_session(g, cfg)
        twin.partition(record_history=False)
        twin.update(*b1).update(*b2)
        _assert_same(t3.result, twin.adapt(record_history=False))

    def test_mixed_fleet_parity_engines_and_plans(self, rng):
        """Batched fused tenants + sharded tenants on both exchange
        plans + a chunked tenant, all in one fleet: every ticket's
        labels are bit-identical to direct session calls (1 device)."""
        from repro.launch.mesh import make_partition_mesh
        cfg = SpinnerConfig(k=4, max_iters=147, seed=6)
        mesh = make_partition_mesh(1)
        fleet = {
            "f1": (_graph(400, seed=1), None),
            "f2": (_graph(405, seed=2), None),   # same bucket as f1
            "sh_ag": (_graph(600, seed=3),
                      EngineOptions(engine="sharded", mesh=mesh,
                                    label_exchange="allgather")),
            "sh_dl": (_graph(600, seed=4),
                      EngineOptions(engine="sharded", mesh=mesh,
                                    label_exchange="delta")),
            "ch": (_graph(500, seed=5), EngineOptions(engine="chunked")),
        }
        deltas = {n: _delta_batch(rng, g.num_vertices)
                  for n, (g, _) in fleet.items()}
        sched = PartitionScheduler(max_batch=8, batch_min=2)
        tks = {}
        for n, (g, opts) in fleet.items():
            sched.add_tenant(n, g, cfg, opts, partition=True)
            tks[n] = sched.submit(n, "edge_updates", edge_updates=deltas[n])
        assert sched.drain() == len(fleet)
        st = sched.stats()
        assert st["errors"] == 0, st
        assert st["batched_dispatches"] == 1      # f1 + f2 stacked
        assert st["serial_dispatches"] == 3       # sharded x2 + chunked
        for n, (g, opts) in fleet.items():
            twin = open_session(g, cfg, opts)
            twin.partition(record_history=False)
            ref = twin.adapt(edge_updates=deltas[n], record_history=False)
            _assert_same(tks[n].result, ref, n)

    def test_batch_min_one_forces_batched_path(self, rng):
        """batch_min=1 routes even a lone window through run_batched --
        the batch-of-1 path -- with unchanged results."""
        cfg = SpinnerConfig(k=6, max_iters=148, seed=7)
        g = _graph(440, seed=6)
        d = _delta_batch(rng, 440)
        sched = PartitionScheduler(batch_min=1)
        sched.add_tenant("a", g, cfg, partition=True)
        tk = sched.submit("a", "edge_updates", edge_updates=d)
        assert sched.drain() == 1
        assert sched.stats()["batched_dispatches"] == 1
        twin = open_session(g, cfg)
        twin.partition(record_history=False)
        _assert_same(tk.result,
                     twin.adapt(edge_updates=d, record_history=False))

    def test_priority_and_staleness_order(self):
        clock = {"t": 0.0}
        cfg = SpinnerConfig(k=4, max_iters=149, seed=8)
        sched = PartitionScheduler(max_batch=1, policies=(),
                                   clock=lambda: clock["t"])
        sched.add_tenant("lo", _graph(300, seed=1), cfg, priority=1.0,
                         partition=True)
        sched.add_tenant("hi", _graph(300, seed=2), cfg, priority=5.0,
                         partition=True)
        t_lo = sched.submit("lo", "adapt")
        clock["t"] = 1.0
        t_hi = sched.submit("hi", "adapt")
        clock["t"] = 2.0
        sched.step()   # urgency: hi 5*1 > lo 1*2
        assert t_hi.done and not t_lo.done
        sched.step()
        assert t_lo.done

    def test_preempt_staleness_overrides_priority(self):
        clock = {"t": 0.0}
        cfg = SpinnerConfig(k=4, max_iters=149, seed=9)
        sched = PartitionScheduler(max_batch=1, policies=(),
                                   preempt_staleness=10.0,
                                   clock=lambda: clock["t"])
        sched.add_tenant("lo", _graph(300, seed=3), cfg, priority=1.0,
                         partition=True)
        sched.add_tenant("hi", _graph(300, seed=4), cfg, priority=100.0,
                         partition=True)
        t_lo = sched.submit("lo", "adapt")
        clock["t"] = 11.0
        t_hi = sched.submit("hi", "adapt")
        sched.step()   # lo is past the SLO: jumps the priority queue
        assert t_lo.done and not t_hi.done

    def test_resize_and_errors(self, rng):
        cfg = SpinnerConfig(k=4, max_iters=151, seed=1)
        g = _graph(350, seed=7)
        sched = PartitionScheduler(policies=())
        sched.add_tenant("a", g, cfg, partition=True)
        tk = sched.submit("a", "resize", k=6)
        bad = sched.submit("a", "edge_updates",
                           edge_updates=(np.array([999999]),
                                         np.array([0])))
        sched.drain()
        twin = open_session(g, cfg)
        twin.partition(record_history=False)
        _assert_same(tk.result, twin.resize(6, record_history=False))
        assert bad.failed and isinstance(bad.error, ValueError)
        ok = sched.submit("a", "adapt")      # errors don't wedge the queue
        sched.drain()
        assert ok.done and not ok.failed
        _assert_same(ok.result, twin.adapt(record_history=False))

    def test_remove_tenant_fails_queued_and_is_final(self):
        cfg = SpinnerConfig(k=4, max_iters=152, seed=2)
        sched = PartitionScheduler()
        t = sched.add_tenant("a", _graph(300, seed=8), cfg,
                             partition=True)
        tk = sched.submit("a", "adapt")
        sched.remove_tenant("a")
        assert tk.failed and "retired" in str(tk.error)
        t.session.close()          # double close via scheduler + here: ok
        with pytest.raises(KeyError):
            sched.remove_tenant("a")
        with pytest.raises(KeyError):
            sched.submit("a", "adapt")


# ---------------------------------------------------------------------------
# prefetch policies
# ---------------------------------------------------------------------------

class TestPolicies:
    def test_ksweep_precompile_warms_resize(self, rng):
        cfg = SpinnerConfig(k=4, max_iters=153, seed=3)
        g = _graph(380, seed=9)
        pol = KSweepPrecompile()
        sched = PartitionScheduler(max_batch=1, policies=(pol,))
        sched.add_tenant("a", g, cfg, partition=True)
        sched.add_tenant("b", _graph(380, seed=10), cfg, partition=True)
        sched.submit("a", "edge_updates",
                     edge_updates=_delta_batch(rng, 380))
        tk = sched.submit("b", "resize", k=7)
        sched.step()    # dispatches a; warms b's k=7 program off-path
        assert pol.compiled >= 1 and ("b", 7) in pol.warmed
        prog = _engine._fused_program(
            dataclasses.replace(cfg, k=7),
            _engine._autotuned(g, dataclasses.replace(cfg, k=7),
                               _engine._DEFAULT_OPTS))
        before = prog.compiles()
        sched.drain()
        assert prog.compiles() == before   # resize dispatch: no compile
        twin = open_session(_graph(380, seed=10), cfg)
        twin.partition(record_history=False)
        _assert_same(tk.result, twin.resize(7, record_history=False))

    def test_stage_prefetch_stages_next_rebind(self, rng):
        cfg = SpinnerConfig(k=4, max_iters=154, seed=4)
        g = _graph(360, seed=11)
        g2 = add_edges(g, *_delta_batch(rng, 360, 30))
        pol = StagePrefetch()
        sched = PartitionScheduler(max_batch=1, policies=(pol,))
        sched.add_tenant("a", _graph(360, seed=12), cfg, partition=True)
        sched.add_tenant("b", g, cfg, partition=True)
        sched.submit("a", "adapt")
        tk = sched.submit("b", "adapt", new_graph=g2)
        sched.step()    # dispatches a; stages b's snapshot off-path
        assert pol.staged == 1
        assert sched.tenants["b"].session.stats()["staged"] is not None
        sched.drain()
        twin = open_session(g, cfg)
        twin.partition(record_history=False)
        _assert_same(tk.result,
                     twin.adapt(new_graph=g2, record_history=False))


# ---------------------------------------------------------------------------
# cross-tenant program sharing (satellite: zero compiles for tenant #2)
# ---------------------------------------------------------------------------

class TestProgramSharing:
    def test_second_session_zero_compiles_unbatched(self):
        cfg = SpinnerConfig(k=6, max_iters=156, seed=5)
        s1 = open_session(_graph(460, seed=13), cfg)
        s1.partition(record_history=False)
        assert s1.compiles > 0
        s2 = open_session(_graph(465, seed=14), cfg)   # same bucket
        s2.partition(record_history=False)
        assert s2.compiles == 0

    def test_second_fleet_zero_compiles_batched(self, rng):
        """After one fleet warms the batched program, a FRESH scheduler
        with fresh same-bucket sessions serves a batched round with zero
        compiles anywhere (global _PROGRAM_CACHE hit)."""
        cfg = SpinnerConfig(k=6, max_iters=157, seed=6)
        def fleet(sched, seeds):
            for i, s in enumerate(seeds):
                sched.add_tenant(f"t{i}", _graph(450 + i, seed=s), cfg,
                                 partition=True)
            for i in range(len(seeds)):
                sched.submit(f"t{i}", "edge_updates",
                             edge_updates=_delta_batch(rng, 450))
            sched.drain()
        warm = PartitionScheduler(batch_min=2)
        fleet(warm, [20, 21])
        assert warm.stats()["batched_dispatches"] == 1
        assert warm.compiles > 0
        warm.mark()
        # steady state on the same fleet: zero new compiles
        warm.submit("t0", "edge_updates",
                    edge_updates=_delta_batch(rng, 450))
        warm.submit("t1", "edge_updates",
                    edge_updates=_delta_batch(rng, 450))
        warm.drain()
        assert warm.stats()["compiles_since_mark"] == 0
        # a brand-new fleet in the same bucket: zero compiles, period
        fresh = PartitionScheduler(batch_min=2)
        fleet(fresh, [22, 23])
        st = fresh.stats()
        assert st["batched_dispatches"] == 1 and st["errors"] == 0
        assert fresh.compiles == 0


# ---------------------------------------------------------------------------
# closed-session lifecycle (satellite: idempotent close, one message)
# ---------------------------------------------------------------------------

class TestClosedSession:
    def test_close_idempotent_and_uniform_message(self, rng):
        cfg = SpinnerConfig(k=4, max_iters=158, seed=7)
        s = open_session(_graph(320, seed=15), cfg)
        s.partition(record_history=False)
        s.close()
        s.close()                                  # double close: no-op
        from repro.core.session import _CLOSED_MSG
        entry_points = [
            lambda: s.partition(),
            lambda: s.adapt(),
            lambda: s.resize(8),
            lambda: s.update(np.array([0]), np.array([1])),
            lambda: s.stage(edge_updates=(np.array([0]), np.array([1]))),
            lambda: s.stats(),
            lambda: s.batchable(),
            lambda: s.batch_key(),
            lambda: s.adapt_parts(),
            lambda: s.commit_adapt(None),
        ]
        for fn in entry_points:
            with pytest.raises(RuntimeError) as ei:
                fn()
            assert str(ei.value) == _CLOSED_MSG
        with open_session(_graph(320, seed=15), cfg) as ctx:
            ctx.partition(record_history=False)
        ctx.close()                                # after __exit__: no-op


# ---------------------------------------------------------------------------
# synthetic traffic
# ---------------------------------------------------------------------------

class TestTraffic:
    def test_powerlaw_sizes_bounds_and_determinism(self):
        a = traffic.powerlaw_sizes(50, v_min=256, v_max=4096, seed=3)
        b = traffic.powerlaw_sizes(50, v_min=256, v_max=4096, seed=3)
        assert a == b
        assert all(256 <= v <= 4096 for v in a)
        assert min(a) < 1024 < max(a)   # a tail and a head

    def test_poisson_trace_shape(self):
        ev = traffic.poisson_trace({"a": 300, "b": 400}, duration=5.0,
                                   rate=3.0, k_choices=(4, 8), seed=1)
        assert ev == sorted(ev, key=lambda e: (e.t, e.tenant))
        kinds = {e.kind for e in ev}
        assert kinds <= {"edge_updates", "adapt", "resize"}
        assert "edge_updates" in kinds
        for e in ev:
            if e.kind == "edge_updates":
                src, dst = e.payload["edge_updates"]
                hi = {"a": 300, "b": 400}[e.tenant]
                assert src.size and int(max(src.max(), dst.max())) < hi

    def test_open_loop_replay_smoke(self):
        cfg = SpinnerConfig(k=4, max_iters=159, seed=8)
        names = {"a": 300, "b": 310}
        sched = PartitionScheduler(batch_min=2)
        for n, v in names.items():
            sched.add_tenant(n, _graph(v, seed=ord(n[0])), cfg,
                             partition=True)
        ev = traffic.poisson_trace(names, duration=0.3, rate=20.0,
                                   burst_mean=3.0, mix=(0.9, 0.1, 0.0),
                                   seed=2)
        done = traffic.replay(sched, ev)
        st = sched.stats()
        assert done == len(ev) == st["completed"]
        assert st["errors"] == 0
        assert st["coalescing_factor"] >= 1.0
        assert st["latency"]["p50"] >= 0.0


# ---------------------------------------------------------------------------
# 8 forced host devices (subprocess matrix)
# ---------------------------------------------------------------------------

SCHED_BATCHED_NDEV = """
import numpy as np
from repro.core import SpinnerConfig, generators, open_session
from repro.serve import PartitionScheduler

ndev = {ndev}
cfg = SpinnerConfig(k=8, max_iters=161, seed=2)
gs = [generators.watts_strogatz(1500 + 7 * i, 8, 0.1, seed=i)
      for i in range(3)]
rng = np.random.default_rng(0)
def delta(v, n=14):
    s = rng.integers(0, v, n); d = rng.integers(0, v, n); m = s != d
    return s[m], d[m]
deltas = [delta(g.num_vertices) for g in gs]

sched = PartitionScheduler(max_batch=8, batch_min=2)
for i, g in enumerate(gs):
    sched.add_tenant(f"t{{i}}", g, cfg, partition=True)
tks = [sched.submit(f"t{{i}}", "edge_updates", edge_updates=deltas[i])
       for i in range(3)]
sched.drain()
st = sched.stats()
assert st["errors"] == 0, st
assert st["batched_dispatches"] == 1, st
sched.mark()
tks2 = [sched.submit(f"t{{i}}", "edge_updates",
                     edge_updates=delta(gs[i].num_vertices))
        for i in range(3)]
sched.drain()
assert sched.stats()["compiles_since_mark"] == 0, sched.stats()
for i, g in enumerate(gs):
    s = open_session(g, cfg)
    s.partition(record_history=False)
    r = s.adapt(edge_updates=deltas[i], record_history=False)
    assert np.array_equal(tks[i].result.labels, r.labels), i
    assert tks[i].result.iterations == r.iterations, i
    r2 = s.adapt(edge_updates=(tks2[i].payload["edge_updates"]),
                 record_history=False)
    assert np.array_equal(tks2[i].result.labels, r2.labels), i
print("SCHED BATCHED OK", ndev)
"""


@pytest.mark.slow
@pytest.mark.parametrize("ndev", [8])
def test_scheduler_batched_parity_ndev(ndev):
    r = run_devices_subprocess(SCHED_BATCHED_NDEV.format(ndev=ndev),
                               ndev=ndev)
    assert r.returncode == 0, r.stderr[-4000:]
    assert f"SCHED BATCHED OK {ndev}" in r.stdout


SCHED_SHARDED_EXCHANGE_NDEV = """
import numpy as np
from repro.core import (EngineOptions, SpinnerConfig, generators,
                        open_session)
from repro.launch.mesh import make_partition_mesh
from repro.serve import PartitionScheduler

ndev = {ndev}
mesh = make_partition_mesh(ndev)
cfg = SpinnerConfig(k=8, max_iters=162, seed=4)
rng = np.random.default_rng(1)
def delta(v, n=16):
    s = rng.integers(0, v, n); d = rng.integers(0, v, n); m = s != d
    return s[m], d[m]

fleet = {{}}
for plan in ("allgather", "delta"):
    g = generators.watts_strogatz(2000, 8, 0.15, seed=len(fleet))
    opts = EngineOptions(engine="sharded", mesh=mesh, label_exchange=plan)
    fleet[f"sh_{{plan}}"] = (g, opts, delta(g.num_vertices))
g = generators.watts_strogatz(900, 8, 0.15, seed=9)
fleet["fused"] = (g, None, delta(g.num_vertices))

sched = PartitionScheduler(max_batch=8)
tks = {{}}
for name, (g, opts, d) in fleet.items():
    sched.add_tenant(name, g, cfg, opts, partition=True)
    sched.submit(name, "edge_updates", edge_updates=d)
    tks[name] = sched.submit(name, "adapt")     # coalesces into the eu
sched.drain()
st = sched.stats()
assert st["errors"] == 0, st
assert st["serial_dispatches"] >= 2, st       # the sharded tenants
for name, (g, opts, d) in fleet.items():
    twin = open_session(g, cfg, opts)
    twin.partition(record_history=False)
    twin.update(*d)
    ref = twin.adapt(record_history=False)
    assert np.array_equal(tks[name].result.labels, ref.labels), name
    assert tks[name].result.iterations == ref.iterations, name
print("SCHED EXCHANGE OK", ndev)
"""


@pytest.mark.slow
@pytest.mark.parametrize("ndev", [8])
def test_scheduler_sharded_exchange_parity_ndev(ndev):
    r = run_devices_subprocess(SCHED_SHARDED_EXCHANGE_NDEV.format(ndev=ndev),
                               ndev=ndev)
    assert r.returncode == 0, r.stderr[-4000:]
    assert f"SCHED EXCHANGE OK {ndev}" in r.stdout
