"""repro.cluster: fault-tolerant multi-process partition runtime.

The load-bearing claims (ISSUE 9 acceptance):

* a simulated worker kill recovers with ZERO human intervention, and a
  same-capacity restart replays to a bit-identical final state
  (sessions are deterministic in (graph, cfg, prev labels); the
  subprocess worker's trajectory is additionally independent of the
  world size, so a 2-process run that loses a worker mid-stream ends
  bit-identical to a 1-process uninterrupted reference);
* an 8->4 shrunk restart resumes through the elastic ``resize``
  re-shard and lands within 2% phi of an uninterrupted baseline at the
  rescaled k (subprocess test, 8 forced host devices);
* snapshots are atomic: a crash mid-save leaves only a ``step_*.tmp``
  dir, which reads skip (without deleting -- a fresh tmp may be a save
  in flight) and which the writer-side ``save``/``gc_old`` sweep once
  stale; a corrupted newest snapshot falls back to the previous
  complete one;
* the serving tier recovers too: ``PartitionScheduler(deployment=...)``
  restores a failed tenant from its snapshot and retries the window
  once, including the resized path when deployment capacity shrank.
"""
import json
import os
import time

import numpy as np
import pytest

from repro.ckpt import checkpoint
from repro.cluster import (ClusterDeployment, ClusterSupervisorConfig,
                           PartitionSupervisor, ProcessClusterConfig,
                           ProcessClusterSupervisor, WorkerLost,
                           corrupt_newest_snapshot_at, kill_worker_at,
                           load_local_shard, read_manifest, restore_session,
                           save_snapshot, slow_worker_at, snapshot_steps,
                           write_edge_shards)
from repro.core import EngineOptions, SpinnerConfig, generators, metrics
from repro.core.distributed import shard_graph
from repro.core.session import PartitionSession

from test_distributed import run_devices_subprocess

CFG = dict(k=6, seed=4, max_iters=40)


def _work(n_adapts=3):
    return [("partition", {})] + [("adapt", {})] * n_adapts


# ---------------------------------------------------------------------------
# Satellite: checkpoint tmp-dir GC + crash-mid-save atomicity
# ---------------------------------------------------------------------------

def _backdate(path, seconds=2 * checkpoint.TMP_GC_AGE_S):
    old = time.time() - seconds
    os.utime(path, (old, old))


class TestCheckpointAtomicity:
    def test_latest_step_skips_tmp_without_deleting(self, tmp_path):
        """latest_step is a READ: it must skip a half-written tmp dir
        but never delete it -- a fresh tmp may be a concurrent save
        whose rename is about to land."""
        d = str(tmp_path / "ck")
        tree = {"w": np.arange(5.0), "n": np.int64(3)}
        checkpoint.save(d, 1, tree)
        # a crash between save()'s leaf writes and the atomic rename
        # leaves exactly this: a half-written step_*.tmp dir
        tmp = os.path.join(d, "step_00000002.tmp")
        os.makedirs(tmp)
        np.save(os.path.join(tmp, "w.npy"), np.zeros(5))
        assert checkpoint.latest_step(d) == 1
        assert os.path.exists(tmp), \
            "read APIs must not sweep a possibly in-flight tmp dir"
        back = checkpoint.restore(d, {"w": np.zeros(5), "n": np.int64(0)})
        np.testing.assert_array_equal(back["w"], tree["w"])
        assert int(back["n"]) == 3
        # ... and a save with the tmp's rename still pending succeeds
        checkpoint.save(d, 2, tree)
        assert checkpoint.latest_step(d) == 2

    def test_writers_gc_stale_tmp_only(self, tmp_path):
        d = str(tmp_path / "ck")
        checkpoint.save(d, 1, {"w": np.zeros(3)})
        stale = os.path.join(d, "step_00000002.tmp")
        fresh = os.path.join(d, "step_00000003.tmp")
        os.makedirs(stale), os.makedirs(fresh)
        _backdate(stale)
        checkpoint.gc_old(d, keep=3)
        assert not os.path.exists(stale), "cold crashed save must be GCd"
        assert os.path.exists(fresh), \
            "a fresh tmp (possible concurrent save) must survive GC"
        # save() sweeps stale tmps too (crash-mid-save roundtrip: the
        # next writer cleans up after the crashed one)
        _backdate(fresh)
        checkpoint.save(d, 4, {"w": np.zeros(3)})
        assert not os.path.exists(fresh)
        assert checkpoint.latest_step(d) == 4

    def test_latest_step_empty_and_missing(self, tmp_path):
        assert checkpoint.latest_step(str(tmp_path / "nope")) is None
        d = str(tmp_path / "only_tmp")
        tmp = os.path.join(d, "step_00000001.tmp")
        os.makedirs(tmp)
        assert checkpoint.latest_step(d) is None
        assert os.path.exists(tmp)
        _backdate(tmp)
        checkpoint.gc_old(d, keep=1)
        assert os.listdir(d) == []


# ---------------------------------------------------------------------------
# Satellite: TrainSupervisor.stats()
# ---------------------------------------------------------------------------

def test_train_supervisor_stats(tmp_path):
    from repro.runtime.failures import SupervisorConfig, TrainSupervisor
    sup = TrainSupervisor(
        SupervisorConfig(ckpt_dir=str(tmp_path / "ck"), ckpt_every=2),
        {"x": np.zeros(2)})
    sup.run(lambda s, b: (s, {}), lambda i: i, 4)
    st = sup.stats()
    assert st["steps"] == 4 and st["start_step"] == 0
    assert st["flagged_steps"] == [] and st["median_step_time"] >= 0.0
    assert st["straggler_factor"] == 3.0


# ---------------------------------------------------------------------------
# Session state export/import + snapshot roundtrip (bit-exact, 1 -> 1)
# ---------------------------------------------------------------------------

class TestSnapshotRoundtrip:
    def test_export_import_validation(self, small_world):
        cfg = SpinnerConfig(**CFG)
        with PartitionSession(small_world, cfg) as s:
            with pytest.raises(ValueError):
                s.export_state()           # nothing partitioned yet
            s.partition(record_history=False)
            state = s.export_state()
            assert state["k"] == cfg.k
            assert state["delta_watermark"] == s.delta_watermark
        with PartitionSession(small_world,
                              SpinnerConfig(**{**CFG, "k": 5})) as other:
            with pytest.raises(ValueError, match="k"):
                other.import_state(state)

    def test_same_capacity_restore_is_bit_exact(self, small_world, tmp_path):
        d = str(tmp_path / "snap")
        cfg = SpinnerConfig(**CFG)
        s = PartitionSession(small_world, cfg)
        s.partition(record_history=False)
        save_snapshot(d, s, 1)
        # uninterrupted continuation
        r1 = s.adapt(record_history=False)
        r2 = s.adapt(record_history=False)
        # restored continuation must walk the identical trajectory
        info = restore_session(d, small_world)
        assert info.saved_ndev == info.ndev == 1 and not info.resized
        assert info.step == 1 and info.k == cfg.k
        q1 = info.session.adapt(record_history=False)
        q2 = info.session.adapt(record_history=False)
        assert np.array_equal(r1.labels, q1.labels)
        assert np.array_equal(r2.labels, q2.labels)
        assert np.array_equal(r2.loads, q2.loads)
        s.close(), info.session.close()

    def test_restore_onto_fewer_devices_replays_resize(self, small_world,
                                                       tmp_path):
        """ndev 2 -> 1 restore halves k through the elastic resize and
        still reconverges to comparable quality (the real 8 -> 4 device
        path runs in the subprocess test below)."""
        d = str(tmp_path / "snap")
        cfg = SpinnerConfig(**{**CFG, "k": 8})
        s = PartitionSession(small_world, cfg)
        s.partition(record_history=False)
        phi_before = metrics.phi(small_world, s.labels)
        save_snapshot(d, s, 1, ndev=2)
        info = restore_session(d, small_world, ndev=1)
        assert info.resized and info.k == 4 and info.saved_ndev == 2
        assert info.session.cfg.k == 4
        labels = info.session.labels
        assert labels.max() < 4
        r = metrics.rho(small_world, labels, 4)
        assert r < cfg.c + 0.1, "resize-on-restore must stay balanced"
        base = PartitionSession(small_world, SpinnerConfig(**{**CFG, "k": 4}))
        phi_base = metrics.phi(small_world,
                               base.partition(record_history=False).labels)
        assert metrics.phi(small_world, labels) >= 0.98 * phi_base, \
            (metrics.phi(small_world, labels), phi_base, phi_before)
        s.close(), info.session.close(), base.close()

    def test_scale_k_off_keeps_k(self, small_world, tmp_path):
        d = str(tmp_path / "snap")
        s = PartitionSession(small_world, SpinnerConfig(**CFG))
        s.partition(record_history=False)
        save_snapshot(d, s, 1, ndev=2)
        info = restore_session(d, small_world, ndev=1, scale_k=False)
        assert not info.resized and info.k == CFG["k"]
        s.close(), info.session.close()


# ---------------------------------------------------------------------------
# Per-host edge shards: the local_only load path
# ---------------------------------------------------------------------------

class TestEdgeShards:
    def test_local_rows_match_full_layout(self, tmp_path):
        g = generators.watts_strogatz(512, 6, 0.3, seed=11)
        d = str(tmp_path / "shards")
        H = 4
        man = write_edge_shards(g, d, num_hosts=H)
        assert man["num_vertices"] == g.num_vertices
        assert read_manifest(d)["num_hosts"] == H
        full = shard_graph(g, H)
        for h in range(H):
            loc = load_local_shard(d, h)
            assert loc.local_only == h and loc.src_local.shape[0] == 1
            np.testing.assert_array_equal(loc.src_local[0],
                                          full.src_local[h])
            np.testing.assert_array_equal(loc.dst[0], full.dst[h])
            np.testing.assert_array_equal(loc.weight[0], full.weight[h])
            np.testing.assert_array_equal(loc.deg_w[0], full.deg_w[h])
            assert loc.e_interior == full.e_interior
            assert loc.interior_counts[0] == full.interior_counts[h]
            assert loc.frontier_counts[0] == full.frontier_counts[h]

    def test_shard_files_cover_all_edges_once(self, tmp_path):
        g = generators.watts_strogatz(300, 4, 0.2, seed=2)
        d = str(tmp_path / "shards")
        write_edge_shards(g, d, num_hosts=3)
        total = sum(np.load(os.path.join(d, f"shard_{h}.npz"))["src"].size
                    for h in range(3))
        assert total == g.num_directed_entries


# ---------------------------------------------------------------------------
# PartitionSupervisor: kill / corrupt / straggle, in process
# ---------------------------------------------------------------------------

class TestPartitionSupervisor:
    def _factory(self, graph):
        def factory(ndev):
            return graph, SpinnerConfig(**CFG), None
        return factory

    def test_kill_recovery_is_bit_identical(self, small_world, tmp_path):
        work = _work(3)
        clean = PartitionSupervisor(
            ClusterSupervisorConfig(snapshot_dir=str(tmp_path / "a")),
            self._factory(small_world))
        s1, r1 = clean.run(work)
        assert clean.restarts == 0 and clean.snapshots_restored == 0

        faulty = PartitionSupervisor(
            ClusterSupervisorConfig(snapshot_dir=str(tmp_path / "b")),
            self._factory(small_world))
        s2, r2 = faulty.run(work, faults=[kill_worker_at(2)])
        assert faulty.restarts == 1 and faulty.snapshots_restored == 1
        assert np.array_equal(s1.labels, s2.labels), \
            "same-capacity restart must replay bit-identically"
        assert np.array_equal(r1[-1].labels, r2[-1].labels)
        st = faulty.stats()
        assert st["restarts"] == 1 and len(st["recover_seconds"]) == 1
        assert st["straggler"]["flagged_steps"] == []
        assert snapshot_steps(str(tmp_path / "b"))[-1] == len(work)
        s1.close(), s2.close()

    def test_kill_after_graph_mutations_replays_deltas(self, small_world,
                                                       tmp_path):
        """A restart after graph-mutating items (``update`` /
        ``adapt(edge_updates=...)``) must re-apply those deltas to the
        factory's BASE graph before resuming -- snapshots carry only
        labels/loads plus the delta watermark, so without replay the
        restored session would silently continue on a stale graph."""
        rng = np.random.default_rng(17)
        V = small_world.num_vertices
        d1 = (rng.integers(0, V, 12), rng.integers(0, V, 12))
        d2 = (rng.integers(0, V, 9), rng.integers(0, V, 9))
        work = [
            ("partition", {}),
            ("update", {"edge_src": d1[0], "edge_dst": d1[1]}),
            ("adapt", {}),
            ("adapt", {"edge_updates": d2}),
            ("adapt", {}),
        ]
        clean = PartitionSupervisor(
            ClusterSupervisorConfig(snapshot_dir=str(tmp_path / "a")),
            self._factory(small_world))
        s1, r1 = clean.run(work)
        # kill AFTER both deltas: the restored run must rebuild base +
        # d1 + d2 (watermark 2) before replaying the tail
        faulty = PartitionSupervisor(
            ClusterSupervisorConfig(snapshot_dir=str(tmp_path / "b")),
            self._factory(small_world))
        s2, r2 = faulty.run(work, faults=[kill_worker_at(4)])
        assert faulty.restarts == 1 and faulty.snapshots_restored == 1
        assert s2.delta_watermark == s1.delta_watermark == 2
        assert s2.graph.num_directed_entries == \
            s1.graph.num_directed_entries
        assert np.array_equal(s1.labels, s2.labels), \
            "restart after deltas must replay them bit-identically"
        assert np.array_equal(r1[-1].labels, r2[-1].labels)
        s1.close(), s2.close()

    def test_boot_raises_on_watermark_mismatch(self, small_world,
                                               tmp_path):
        """Snapshots whose delta watermark the work stream cannot
        reproduce must refuse to resume instead of silently continuing
        on a graph missing those deltas."""
        rng = np.random.default_rng(3)
        V = small_world.num_vertices
        with_delta = [
            ("partition", {}),
            ("update", {"edge_src": rng.integers(0, V, 8),
                        "edge_dst": rng.integers(0, V, 8)}),
            ("adapt", {}),
        ]
        d = str(tmp_path / "s")
        sup = PartitionSupervisor(ClusterSupervisorConfig(snapshot_dir=d),
                                  self._factory(small_world))
        s, _ = sup.run(with_delta)
        s.close()
        # resuming the same snapshots with a stream that carries no
        # delta items cannot rebuild the snapshot's logical graph
        stale = PartitionSupervisor(ClusterSupervisorConfig(snapshot_dir=d),
                                    self._factory(small_world))
        with pytest.raises(RuntimeError, match="delta"):
            stale.run(_work(3))

    def test_corrupt_snapshot_falls_back(self, small_world, tmp_path):
        work = _work(3)
        clean = PartitionSupervisor(
            ClusterSupervisorConfig(snapshot_dir=str(tmp_path / "a")),
            self._factory(small_world))
        s1, _ = clean.run(work)
        faulty = PartitionSupervisor(
            ClusterSupervisorConfig(snapshot_dir=str(tmp_path / "b")),
            self._factory(small_world))
        s2, _ = faulty.run(work, faults=[corrupt_newest_snapshot_at(2),
                                         kill_worker_at(2)])
        assert faulty.snapshots_corrupted == 1
        assert faulty.corrupt_skipped >= 1, \
            "restore must walk past the torn snapshot"
        assert np.array_equal(s1.labels, s2.labels)
        s1.close(), s2.close()

    def test_restart_budget_exhausted_raises(self, small_world, tmp_path):
        sup = PartitionSupervisor(
            ClusterSupervisorConfig(snapshot_dir=str(tmp_path / "s"),
                                    max_restarts=0),
            self._factory(small_world))
        with pytest.raises(WorkerLost):
            sup.run(_work(1), faults=[kill_worker_at(1)])

    def test_straggler_flagged_and_heartbeats(self, small_world, tmp_path):
        rng = np.random.default_rng(0)
        ups = [("update", {"edge_src": rng.integers(0, 100, 8),
                           "edge_dst": rng.integers(100, 200, 8)})
               for _ in range(4)]
        work = [("partition", {})] + ups
        sup = PartitionSupervisor(
            ClusterSupervisorConfig(snapshot_dir=str(tmp_path / "s"),
                                    straggler_warmup=3,
                                    heartbeat_deadline=1e9),
            self._factory(small_world))
        s, _ = sup.run(work, faults=[slow_worker_at(4, seconds=1.0)])
        st = sup.stats()
        assert [f[0] for f in st["straggler"]["flagged_steps"]] == [4]
        assert st["stale_workers"] == [] and 0 in st["heartbeat_ages"]
        s.close()


# ---------------------------------------------------------------------------
# Serving tier: deployment mode recovery
# ---------------------------------------------------------------------------

class _Boom(RuntimeError):
    pass


def _poison_once(session, kind="commit_adapt"):
    orig = getattr(session, kind)
    state = {"armed": True}

    def wrapper(*a, **kw):
        if state["armed"]:
            state["armed"] = False
            raise _Boom("injected dispatch failure")
        return orig(*a, **kw)

    setattr(session, kind, wrapper)


class TestSchedulerDeployment:
    def test_failed_dispatch_recovers_and_retries(self, tmp_path):
        from repro.serve import PartitionScheduler
        g = generators.watts_strogatz(1200, 8, 0.1, seed=3)
        cfg = SpinnerConfig(k=6, seed=1, max_iters=41)
        dep = ClusterDeployment(str(tmp_path / "snaps"))
        sched = PartitionScheduler(deployment=dep)
        sched.add_tenant("a", g, cfg)
        tk0 = sched.submit("a", "partition")
        assert sched.drain() == 1 and tk0.done and not tk0.failed
        assert dep.snapshots_written == 1

        _poison_once(sched.tenants["a"].session)
        tk1 = sched.submit("a", "adapt")
        assert sched.drain() == 1
        assert tk1.done and not tk1.failed, tk1.error
        st = sched.stats()
        assert st["recoveries"] == 1 and st["errors"] == 0
        assert st["deployment"]["recoveries"] == 1
        # the recovered session is live and serves the next window
        tk2 = sched.submit("a", "adapt")
        assert sched.drain() == 1 and not tk2.failed

    def test_no_snapshot_fails_normally(self, tmp_path):
        from repro.serve import PartitionScheduler
        g = generators.watts_strogatz(1200, 8, 0.1, seed=3)
        cfg = SpinnerConfig(k=6, seed=1, max_iters=42)
        dep = ClusterDeployment(str(tmp_path / "snaps"))
        sched = PartitionScheduler(deployment=dep)
        sched.add_tenant("a", g, cfg)
        _poison_once(sched.tenants["a"].session, "partition")
        tk = sched.submit("a", "partition")
        assert sched.drain() == 1
        assert tk.failed and isinstance(tk.error, _Boom)
        assert dep.recovery_failures == 1
        assert sched.stats()["recoveries"] == 0

    def test_shrunk_deployment_recovers_resized(self, tmp_path):
        """Snapshot written at capacity 2; recovery at capacity 1 must
        replay the elastic resize (k halves) before the retry."""
        from repro.serve import PartitionScheduler

        class ShrinkingDeployment(ClusterDeployment):
            def __init__(self, root):
                super().__init__(root)
                self._ndev = 2

            @property
            def ndev(self):
                return self._ndev

        g = generators.watts_strogatz(1200, 8, 0.1, seed=3)
        cfg = SpinnerConfig(k=8, seed=1, max_iters=43)
        dep = ShrinkingDeployment(str(tmp_path / "snaps"))
        sched = PartitionScheduler(deployment=dep)
        sched.add_tenant("a", g, cfg)
        sched.submit("a", "partition")
        assert sched.drain() == 1 and dep.snapshots_written == 1

        dep._ndev = 1                      # capacity shrank
        _poison_once(sched.tenants["a"].session)
        tk = sched.submit("a", "adapt")
        assert sched.drain() == 1 and not tk.failed, tk.error
        assert dep.resized_recoveries == 1
        sess = sched.tenants["a"].session
        assert sess.cfg.k == 4 and sess.labels.max() < 4
        assert metrics.rho(g, sess.labels, 4) < 1.2

    def test_recovery_rolls_forward_committed_resize(self, tmp_path):
        """With ``snapshot_every > 1`` a committed ``resize()`` can
        postdate the newest snapshot; a recovery restoring that
        snapshot must roll k forward to the last committed value, not
        silently revert the tenant."""
        from repro.serve import PartitionScheduler
        g = generators.watts_strogatz(1200, 8, 0.1, seed=3)
        cfg = SpinnerConfig(k=6, seed=1, max_iters=44)
        dep = ClusterDeployment(str(tmp_path / "snaps"), snapshot_every=2)
        sched = PartitionScheduler(deployment=dep)
        sched.add_tenant("a", g, cfg)
        sched.submit("a", "partition")
        assert sched.drain() == 1
        sched.submit("a", "adapt")
        assert sched.drain() == 1 and dep.snapshots_written == 1
        # committed AFTER the newest snapshot (commit 3, cadence 2)
        tkr = sched.submit("a", "resize", k=9)
        assert sched.drain() == 1 and not tkr.failed
        assert dep.snapshots_written == 1

        _poison_once(sched.tenants["a"].session)
        tk = sched.submit("a", "adapt")
        assert sched.drain() == 1 and not tk.failed, tk.error
        assert dep.k_roll_forwards == 1
        sess = sched.tenants["a"].session
        assert sess.cfg.k == 9, \
            "recovery must not revert a committed resize"
        assert sess.labels.max() < 9
        assert sched.stats()["deployment"]["k_roll_forwards"] == 1


# ---------------------------------------------------------------------------
# ClusterHandle: sliced blocking waits keep the heartbeat fresh
# ---------------------------------------------------------------------------


class TestKvGetSlicing:
    def _handle(self, fake_client, poll_slice=0.01, rpc_timeout=0.05):
        from repro.cluster.bootstrap import ClusterConfig, ClusterHandle

        class H(ClusterHandle):
            _client = property(lambda self: fake_client)

        return H(ClusterConfig(num_processes=1, rpc_timeout=rpc_timeout,
                               poll_slice=poll_slice))

    def test_on_wait_fires_between_slices(self):
        class Fake:
            def __init__(self):
                self.calls = 0

            def blocking_key_value_get(self, key, ms):
                self.calls += 1
                if self.calls < 3:
                    raise TimeoutError("deadline exceeded")
                return "ok"

        fake = Fake()
        h = self._handle(fake, rpc_timeout=5.0)
        beats = []
        h.on_wait = lambda: beats.append(time.monotonic())
        assert h.kv_get("x") == "ok"
        assert fake.calls == 3
        assert len(beats) == 2, \
            "the heartbeat hook must fire between wait slices"

    def test_exhausted_deadline_raises_peerlost(self):
        from repro.cluster.bootstrap import PeerLost

        class Dead:
            def blocking_key_value_get(self, key, ms):
                raise TimeoutError("deadline exceeded")

        h = self._handle(Dead(), rpc_timeout=0.05)
        with pytest.raises(PeerLost, match="timed out"):
            h.kv_get("gone")

    def test_kv_delete_is_best_effort(self):
        class NoDelete:                 # runtime without key_value_delete
            pass

        class Counting:
            def __init__(self):
                self.deleted = []

            def key_value_delete(self, key):
                self.deleted.append(key)

        self._handle(NoDelete()).kv_delete("g0/t1/")    # must not raise
        c = Counting()
        self._handle(c).kv_delete("g0/t1/")
        assert c.deleted == ["g0/t1/"]


# ---------------------------------------------------------------------------
# Satellite: explicit device list for make_partition_mesh
# ---------------------------------------------------------------------------

def test_make_partition_mesh_explicit_devices():
    import jax
    from repro.launch.mesh import make_partition_mesh
    devs = jax.devices()
    m = make_partition_mesh(devices=devs)
    assert m.devices.size == len(devs)
    with pytest.raises(ValueError):
        make_partition_mesh(num_devices=len(devs) + 1, devices=devs)


# ---------------------------------------------------------------------------
# Subprocess tests: 8 -> 4 shrunk supervisor restart; real 2-process
# cluster with a killed worker
# ---------------------------------------------------------------------------

SHRINK_8_TO_4 = """
import numpy as np
from repro.cluster import (ClusterSupervisorConfig, PartitionSupervisor,
                           kill_worker_at)
from repro.core import EngineOptions, SpinnerConfig, generators, metrics
from repro.core.session import PartitionSession
from repro.launch.mesh import make_partition_mesh
import tempfile

g = generators.watts_strogatz(3000, 10, 0.25, seed=7)
CFG = dict(seed=3, max_iters=60)

def factory(ndev):
    nd = ndev or 8
    mesh = make_partition_mesh(num_devices=nd)
    return g, SpinnerConfig(k=8, **CFG), EngineOptions(mesh=mesh)

snap = tempfile.mkdtemp()
sup = PartitionSupervisor(ClusterSupervisorConfig(snapshot_dir=snap), factory)
work = [("partition", {})] + [("adapt", {})] * 3
session, results = sup.run(work, ndev=8,
                           faults=[kill_worker_at(2, surviving_ndev=4)])
st = sup.stats()
assert st["restarts"] == 1 and st["resized_on_restore"], st
assert st["ndev"] == 4 and st["k"] == 4, st
labels = session.labels
assert labels.max() < 4
phi = metrics.phi(g, labels)

base = PartitionSession(g, SpinnerConfig(k=4, **CFG),
                        EngineOptions(mesh=make_partition_mesh(num_devices=4)))
phi_base = metrics.phi(g, base.partition(record_history=False).labels)
print(f"phi_recovered={phi:.4f} phi_baseline={phi_base:.4f} "
      f"recover_s={st['recover_seconds']}")
assert phi >= 0.98 * phi_base, (phi, phi_base)
rho = metrics.rho(g, labels, 4)
assert rho < 1.2, rho
print("SHRINK OK")
"""


@pytest.mark.slow
def test_supervisor_shrink_8_to_4_devices():
    r = run_devices_subprocess(SHRINK_8_TO_4, ndev=8)
    assert r.returncode == 0, r.stderr[-4000:]
    assert "SHRINK OK" in r.stdout


@pytest.mark.slow
def test_two_process_cluster_worker_kill(tmp_path):
    """Spawn a real 2-process jax.distributed cluster, hard-kill worker 1
    mid-run, and verify the supervisor respawns a 1-process generation
    that resumes from the snapshot and ends bit-identical to an
    uninterrupted 1-process reference."""
    g = generators.watts_strogatz(600, 8, 0.2, seed=5)
    shards = str(tmp_path / "shards")
    write_edge_shards(g, shards, num_hosts=2)
    base_job = {"shard_dir": shards, "k": 4, "seed": 1, "max_iters": 24,
                "snapshot_every": 4, "c": 1.05, "rpc_timeout": 90}

    wd = str(tmp_path / "faulty")
    sup = ProcessClusterSupervisor(
        ProcessClusterConfig(workdir=wd, num_processes=2,
                             poll_interval=0.2),
        {**base_job, "fault": {"gen": 0, "pid": 1, "iteration": 8}})
    out = sup.run()
    assert out["restarts"] == 1, out
    assert out["result"]["gen"] == 1 and out["result"]["world"] == 1, out
    gens = out["generations"]
    assert gens[0]["dead"] == [1] and gens[1]["dead"] == []
    labels = np.load(os.path.join(wd, "labels.npy"))

    wd2 = str(tmp_path / "ref")
    ref = ProcessClusterSupervisor(
        ProcessClusterConfig(workdir=wd2, num_processes=1,
                             poll_interval=0.2), base_job).run()
    assert ref["restarts"] == 0
    labels_ref = np.load(os.path.join(wd2, "labels.npy"))
    assert np.array_equal(labels, labels_ref), \
        "recovered run must be bit-identical to the uninterrupted reference"
    assert out["result"]["phi"] == pytest.approx(ref["result"]["phi"])
    assert out["result"]["phi"] > 0.3, out["result"]
    # the worker reports the weighted phi (message volume staying local)
    assert metrics.phi_weighted(g, labels) == pytest.approx(
        out["result"]["phi"], abs=1e-6)
