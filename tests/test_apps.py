"""The partition-consuming application layer (``repro.apps``).

Fast in-process tests (1 CPU device): oracle parity for every workload
on both combine backends, placement/plan/schedule invariance, cache
and compile-count behaviour, the session entry point, and the
``pregel_dist`` back-compat wrapper.  The 8-forced-device matrix
(hash vs spinner parity across 1/2/4/8-device meshes plus the >= 40%
wire-byte reduction acceptance) runs as a ``slow`` subprocess, the
``test_distributed.py`` idiom.

CI note: tests named ``*pallas*`` / ``*exchange*`` route to the
pallas-sharded split; the rest to multidevice (see ci.yml -k filters).
"""
import numpy as np
import pytest

from repro.core import generators, metrics, pregel
from repro.core.spinner import SpinnerConfig, partition

from tests.test_distributed import run_devices_subprocess


def hash_labels(v: int, k: int) -> np.ndarray:
    return (np.arange(v) * np.int64(2654435761) % k).astype(np.int32)


@pytest.fixture(scope="module")
def apps_graph():
    return generators.clustered_graph(4, 200, p_in=0.05,
                                      p_out_edges_per_v=1.0, seed=5)


@pytest.fixture(scope="module")
def spinner_labels(apps_graph):
    res = partition(apps_graph, SpinnerConfig(k=4, seed=1, max_iters=80),
                    record_history=False)
    return res.labels


class TestLayout:
    def test_placement_equal_chop(self):
        from repro.apps import placement_from_labels
        labels = np.array([2, 0, 1, 0, 2, 1, 0], np.int32)
        perm, counts = placement_from_labels(labels, 2, 4)
        assert counts.tolist() == [4, 3]
        assert sorted(perm.tolist()) == sorted([0, 1, 2, 3, 4, 5, 6])
        # device ranges are contiguous from each device's base
        assert set(perm[labels == 0]) <= {0, 1, 2, 3}

    def test_placement_overflow_raises(self):
        from repro.apps import placement_from_labels
        with pytest.raises(ValueError, match="do not fit"):
            placement_from_labels(np.zeros(10, np.int32), 2, 4)

    def test_layout_roundtrip_and_degrees(self, apps_graph, spinner_labels):
        from repro.apps import build_app_layout
        lay = build_app_layout(apps_graph, spinner_labels, 1)
        v = apps_graph.num_vertices
        # unpermute inverts the placement
        placed = np.zeros(lay.v_pad, np.int64)
        placed[lay.perm] = np.arange(v)
        assert np.array_equal(lay.unpermute(placed), np.arange(v))
        # unweighted out-degree matches the oracle's bincount
        deg = np.bincount(apps_graph.src, minlength=v)
        assert np.array_equal(
            lay.unpermute(lay.deg_cnt.reshape(-1)).astype(np.int64), deg)
        # cached: same (graph, labels, ndev) -> same object
        assert build_app_layout(apps_graph, spinner_labels, 1) is lay

    def test_label_length_mismatch(self, apps_graph):
        from repro.apps import build_app_layout
        with pytest.raises(ValueError, match="labels cover"):
            build_app_layout(apps_graph, np.zeros(3, np.int32), 1)


class TestOracleParity:
    """Engine results == core.pregel numpy oracles (1 device)."""

    def test_pagerank(self, apps_graph, spinner_labels):
        from repro.apps import run_app
        ref = pregel.pagerank(apps_graph, spinner_labels, 4, iters=15).values
        res = run_app(apps_graph, spinner_labels, "pagerank", iters=15)
        np.testing.assert_allclose(res.values, ref, rtol=1e-4, atol=1e-9)
        assert res.supersteps == 15 and res.converged

    def test_wcc(self, apps_graph, spinner_labels):
        from repro.apps import run_app
        ref = pregel.wcc(apps_graph, spinner_labels, 4)
        res = run_app(apps_graph, spinner_labels, "wcc")
        assert np.array_equal(res.values, ref.values)
        assert res.supersteps == ref.supersteps and res.converged

    def test_bfs_and_sssp(self, apps_graph, spinner_labels):
        from repro.apps import run_app
        ref = pregel.sssp(apps_graph, 0, spinner_labels, 4)
        for wl in ("bfs", "sssp"):
            res = run_app(apps_graph, spinner_labels, wl, source=0)
            np.testing.assert_array_equal(res.values, ref.values)
            assert res.supersteps == ref.supersteps and res.converged

    def test_pallas_interpret_combine(self, apps_graph, spinner_labels):
        from repro.apps import run_app
        for wl, kw in (("pagerank", {"iters": 8}), ("wcc", {}),
                       ("bfs", {"source": 0})):
            x = run_app(apps_graph, spinner_labels, wl, combine="xla", **kw)
            p = run_app(apps_graph, spinner_labels, wl, combine="pallas",
                        interpret=True, **kw)
            if wl == "pagerank":
                np.testing.assert_allclose(p.values, x.values,
                                           rtol=1e-4, atol=1e-9)
            else:
                np.testing.assert_array_equal(p.values, x.values)
            assert p.supersteps == x.supersteps


class TestInvariance:
    def test_hash_vs_spinner_placement_parity(self, apps_graph,
                                              spinner_labels):
        """Same graph, two placements -> identical results (f32
        tolerance for PageRank's reassociated sums; bit-exact min)."""
        from repro.apps import run_app
        h = hash_labels(apps_graph.num_vertices, 4)
        for wl in ("pagerank", "wcc", "bfs"):
            a = run_app(apps_graph, spinner_labels, wl, iters=10)
            b = run_app(apps_graph, h, wl, iters=10)
            if wl == "pagerank":
                np.testing.assert_allclose(a.values, b.values,
                                           rtol=1e-4, atol=1e-9)
            else:
                np.testing.assert_array_equal(a.values, b.values)

    def test_exchange_plan_parity(self, apps_graph, spinner_labels):
        """allgather / halo / halo_delta / delta move different bytes
        but must compute identical values."""
        from repro.apps import run_app
        for wl in ("pagerank", "wcc"):
            base = run_app(apps_graph, spinner_labels, wl, plan="allgather",
                           iters=8)
            for plan in ("halo", "halo_delta", "delta"):
                r = run_app(apps_graph, spinner_labels, wl, plan=plan,
                            iters=8)
                if wl == "pagerank":
                    np.testing.assert_allclose(r.values, base.values,
                                               rtol=1e-4, atol=1e-9)
                else:
                    np.testing.assert_array_equal(r.values, base.values)

    def test_overlap_bit_identity(self, apps_graph, spinner_labels):
        from repro.apps import run_app
        for wl in ("pagerank", "wcc"):
            a = run_app(apps_graph, spinner_labels, wl, overlap=True,
                        iters=8)
            b = run_app(apps_graph, spinner_labels, wl, overlap=False,
                        iters=8)
            # same interior/frontier combine either way: BIT identical
            np.testing.assert_array_equal(a.values, b.values)

    def test_warm_rerun_compiles_nothing(self, apps_graph, spinner_labels):
        from repro.apps import run_app
        r1 = run_app(apps_graph, spinner_labels, "pagerank", iters=5)
        warm = r1.program.compiles()
        r2 = run_app(apps_graph, spinner_labels, "pagerank", iters=5)
        assert r2.program is r1.program
        assert r2.program.compiles() == warm
        # the hash A/B on the same graph shares the program too
        r3 = run_app(apps_graph, hash_labels(apps_graph.num_vertices, 4),
                     "pagerank", iters=5)
        assert r3.program is r1.program
        assert r3.program.compiles() == warm


class TestHaloDeltaExchange:
    def test_plan_signature_roundtrip(self, apps_graph, spinner_labels):
        from repro.apps import build_app_layout
        from repro.core import comm
        sg = build_app_layout(apps_graph, spinner_labels, 1).sg
        plan = comm.make_exchange_plan("halo_delta", sg, pad=True)
        view = comm.plan_from_signature(plan.signature())
        assert view.signature() == plan.signature()
        assert type(view) is type(plan)
        assert plan.signature()[0] == "halo_delta"
        # measured plan: no static wire estimate
        assert plan.wire_bytes_per_iter() is None

    def test_halo_delta_registered(self):
        from repro.core import comm
        assert "halo_delta" in comm.EXCHANGE_PLANS


class TestEntryPoints:
    def test_unknown_workload(self, apps_graph):
        from repro.apps import run_app
        with pytest.raises(ValueError, match="unknown workload"):
            run_app(apps_graph, np.zeros(apps_graph.num_vertices, np.int32),
                    "pagerankk")

    def test_bad_combine(self, apps_graph):
        from repro.apps import run_app
        with pytest.raises(ValueError, match="combine must be"):
            run_app(apps_graph, np.zeros(apps_graph.num_vertices, np.int32),
                    "pagerank", combine="tpu")

    def test_session_run_app(self, apps_graph):
        from repro.core.session import PartitionSession
        sess = PartitionSession(apps_graph,
                                SpinnerConfig(k=4, seed=0, max_iters=60))
        with pytest.raises(ValueError, match="no labels yet"):
            sess.run_app("pagerank")
        sess.partition()
        res = sess.run_app("wcc")
        ref = pregel.wcc(apps_graph, sess.labels, 4)
        assert np.array_equal(res.values, ref.values)
        assert sess.compiles >= 1

    def test_pregel_dist_wrapper(self, apps_graph, spinner_labels):
        from repro.core.pregel_dist import pagerank_distributed
        from repro.launch.mesh import make_partition_mesh
        ref = pregel.pagerank(apps_graph, spinner_labels, 4, iters=10).values
        got, stats = pagerank_distributed(
            apps_graph, spinner_labels, make_partition_mesh(1), iters=10)
        np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-9)
        assert stats["halo_true_bytes_per_step"] == 0  # 1 device: no wire
        assert stats["supersteps"] == 10

    def test_expert_placement_case(self):
        from repro.apps import run_app
        from repro.core.placement import expert_placement_case
        g, labels, stats = expert_placement_case(
            n_experts=64, n_tokens=2000, n_shards=4, seed=0)
        assert g.num_vertices == 64 and labels.shape == (64,)
        assert stats["traffic_reduction"] > 0
        res = run_app(g, labels, "pagerank", iters=5)
        ref = pregel.pagerank(g, labels, 4, iters=5).values
        np.testing.assert_allclose(res.values, ref, rtol=1e-4, atol=1e-9)

    def test_comm_volume_predicts_placement(self, apps_graph,
                                            spinner_labels):
        """The static metric the bench logs per row orders placements
        the same way the measured wire bytes will."""
        h = hash_labels(apps_graph.num_vertices, 4)
        cv_sp = metrics.summarize(apps_graph, spinner_labels,
                                  4)["comm_volume"]
        cv_h = metrics.summarize(apps_graph, h, 4)["comm_volume"]
        assert cv_sp < cv_h


APPS_8DEV_MATRIX = """
import numpy as np
from repro.apps import run_app
from repro.core import generators, pregel
from repro.core.spinner import SpinnerConfig, partition
from repro.launch.mesh import make_partition_mesh

g = generators.clustered_graph(8, 250, p_in=0.05, p_out_edges_per_v=1.0,
                               seed=5)
v = g.num_vertices
res = partition(g, SpinnerConfig(k=8, seed=1, max_iters=120),
                record_history=False)
hash_l = (np.arange(v) * np.int64(2654435761) % 8).astype(np.int32)

refs = {
    "pagerank": pregel.pagerank(g, res.labels, 8, iters=10).values,
    "wcc": pregel.wcc(g, res.labels, 8).values,
    "bfs": pregel.sssp(g, 0, res.labels, 8).values,
}

# parity across mesh widths: 1/2/4/8 devices, both placements
for nd in (1, 2, 4, 8):
    mesh = make_partition_mesh(nd)
    for wl, ref in refs.items():
        for labels in (res.labels, hash_l):
            r = run_app(g, labels, wl, mesh=mesh, iters=10)
            if wl == "pagerank":
                np.testing.assert_allclose(r.values, ref, rtol=1e-4,
                                           atol=1e-9)
            else:
                np.testing.assert_array_equal(r.values, ref)

# acceptance: on 8 devices spinner moves strictly fewer wire bytes per
# superstep than hash, >= 40% reduction, on EVERY workload
mesh = make_partition_mesh(8)
for wl in ("pagerank", "wcc", "bfs"):
    sp = run_app(g, res.labels, wl, mesh=mesh, iters=10)
    ha = run_app(g, hash_l, wl, mesh=mesh, iters=10)
    red = 1 - sp.wire_bytes_per_step / ha.wire_bytes_per_step
    print(f"{wl} [{sp.plan}]: hash={ha.wire_bytes_per_step:.0f}B/step "
          f"spinner={sp.wire_bytes_per_step:.0f}B/step reduction={red:.1%} "
          f"skew sp={sp.straggler_skew:.2f} hash={ha.straggler_skew:.2f}")
    assert sp.wire_bytes_per_step < ha.wire_bytes_per_step, wl
    assert red >= 0.40, (wl, red)
print("APPS 8DEV MATRIX OK")
"""


APPS_8DEV_PALLAS = """
import numpy as np
from repro.apps import run_app
from repro.core import generators
from repro.core.spinner import SpinnerConfig, partition
from repro.launch.mesh import make_partition_mesh

g = generators.clustered_graph(8, 250, p_in=0.05, p_out_edges_per_v=1.0,
                               seed=5)
res = partition(g, SpinnerConfig(k=8, seed=1, max_iters=120),
                record_history=False)
mesh = make_partition_mesh(8)
for wl in ("pagerank", "wcc"):
    x = run_app(g, res.labels, wl, mesh=mesh, iters=8, combine="xla")
    p = run_app(g, res.labels, wl, mesh=mesh, iters=8, combine="pallas",
                interpret=True)
    if wl == "pagerank":
        np.testing.assert_allclose(p.values, x.values, rtol=1e-4, atol=1e-9)
    else:
        np.testing.assert_array_equal(p.values, x.values)
    assert p.supersteps == x.supersteps
print("APPS 8DEV PALLAS OK")
"""


@pytest.mark.slow
def test_apps_matrix_8dev():
    r = run_devices_subprocess(APPS_8DEV_MATRIX)
    assert "APPS 8DEV MATRIX OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_apps_pallas_combine_8dev():
    r = run_devices_subprocess(APPS_8DEV_PALLAS)
    assert "APPS 8DEV PALLAS OK" in r.stdout, r.stdout + r.stderr
