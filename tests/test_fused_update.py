"""Fused Pallas vertex-update megakernel (PR 6).

Four pillars:

  * fused-vs-split bit parity: ``EngineOptions(fused_update="on")`` runs
    the whole Eq. 7-8 + Eq. 11-12 vertex update through the backend's
    fused entry (the Pallas megakernel keeps the (V_pad, k_pad) score
    block in VMEM) and must walk BIT-IDENTICAL trajectories to
    ``fused_update="off"`` for every engine, exchange plan and overlap
    schedule -- in-process single-device and on a 1-device mesh here, on
    real 2/4/8-device meshes in the subprocess tests -- including the
    edge cases k not a multiple of 128, hub-heavy degree skew, and
    graphs smaller than one tile;
  * the tile autotuner: deterministic (same graph + seed -> same chosen
    config), memoized per shape bucket, and surfaced through
    ``PartitionSession.stats()`` / ``comm_stats`` -- with a warm
    same-bucket ``adapt()`` still performing zero new compiles;
  * option plumbing: ``fused_update`` / ``autotune`` validation, the
    auto-selection rule (Pallas opts in via ``fused_auto``, XLA stays on
    its scatter path), and a clear error for backends without the fused
    entry;
  * retirement of the legacy ``ScoreBackend.build`` / ``build_sharded``
    closure forms.

Each test uses a unique ``max_iters`` so its programs are private in the
global program cache and compile counts cannot be perturbed by other
tests.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (EngineOptions, SpinnerConfig, engine, generators,
                        partition)
from repro.core.graph import add_edges
from repro.core.session import PartitionSession
from repro.kernels import autotune
from repro.kernels.ops import SCORE_BACKENDS, PallasTiledBackend
from repro.launch.mesh import make_partition_mesh

from test_distributed import run_devices_subprocess


@pytest.fixture(scope="module")
def ws_graph():
    return generators.watts_strogatz(300, 6, 0.2, seed=3)


@pytest.fixture(scope="module")
def mesh1():
    return make_partition_mesh(1)


def _assert_same(a, b):
    np.testing.assert_array_equal(np.asarray(a.labels),
                                  np.asarray(b.labels))
    np.testing.assert_array_equal(np.asarray(a.loads), np.asarray(b.loads))
    assert a.iterations == b.iterations
    assert a.halted == b.halted


def _run_pair(graph, cfg, *, eng="fused", backend="pallas", **opt_kw):
    res = {}
    for fu in ("off", "on"):
        opts = EngineOptions(score_backend=backend, fused_update=fu,
                             **opt_kw)
        res[fu] = partition(graph, cfg, record_history=False, engine=eng,
                            options=opts)
    return res["off"], res["on"]


class TestSingleDeviceParity:
    @pytest.mark.parametrize("eng", ["fused", "chunked", "host"])
    @pytest.mark.parametrize("backend", ["pallas", "xla"])
    def test_engines(self, ws_graph, eng, backend):
        cfg = SpinnerConfig(k=5, max_iters=82, seed=7)
        off, on = _run_pair(ws_graph, cfg, eng=eng, backend=backend)
        _assert_same(off, on)

    def test_hub_heavy_skew(self):
        """Preferential attachment concentrates degree on a few hubs;
        the round-robin tile balancing must keep the megakernel exact."""
        g = generators.powerlaw_ba(500, 5, seed=9)
        cfg = SpinnerConfig(k=7, max_iters=83, seed=2)
        _assert_same(*_run_pair(g, cfg))

    def test_smaller_than_one_tile(self):
        """V=40 < tile_v=128: a single partially-valid tile."""
        g = generators.watts_strogatz(40, 4, 0.3, seed=5)
        cfg = SpinnerConfig(k=3, max_iters=84, seed=1)
        _assert_same(*_run_pair(g, cfg))

    def test_k_not_multiple_of_128(self):
        """k=130 -> k_pad=256: the pad columns must stay masked out of
        the in-kernel argmax and the M(l) partial."""
        g = generators.watts_strogatz(300, 6, 0.2, seed=8)
        cfg = SpinnerConfig(k=130, max_iters=85, seed=4)
        _assert_same(*_run_pair(g, cfg))


class TestMeshParity:
    """1-device mesh: every exchange plan and both overlap schedules must
    reproduce the single-device fused-off trajectory bit for bit."""

    @pytest.mark.parametrize("plan", ["allgather", "halo", "delta"])
    @pytest.mark.parametrize("ov", ["off", "on"])
    def test_plans_and_overlap(self, ws_graph, mesh1, plan, ov):
        cfg = SpinnerConfig(k=5, max_iters=86, seed=7)
        base = partition(ws_graph, cfg, record_history=False,
                         engine="fused",
                         options=EngineOptions(score_backend="pallas",
                                               fused_update="off"))
        for backend in ("pallas", "xla"):
            r = partition(ws_graph, cfg, record_history=False,
                          engine="sharded", mesh=mesh1,
                          options=EngineOptions(score_backend=backend,
                                                label_exchange=plan,
                                                overlap=ov,
                                                fused_update="on"))
            _assert_same(base, r)


class TestOptions:
    def test_bogus_mode_rejected(self):
        with pytest.raises(ValueError, match="fused_update"):
            EngineOptions(fused_update="bogus").resolved_fused_update()
        with pytest.raises(ValueError, match="autotune"):
            EngineOptions(autotune="bogus").resolved_autotune()

    def test_auto_selection(self):
        # Pallas advertises fused_auto; XLA's scatter path gains nothing
        assert EngineOptions(
            score_backend="pallas").resolved_fused_update() == "on"
        assert EngineOptions(
            score_backend="xla").resolved_fused_update() == "off"
        assert EngineOptions(score_backend="xla",
                             fused_update="on"
                             ).resolved_fused_update() == "on"
        assert EngineOptions(score_backend="pallas",
                             fused_update="off"
                             ).resolved_fused_update() == "off"

    def test_backend_without_fused_entry(self):
        class Bare:
            name = "bare"

            def signature(self):
                return ("bare",)

        opts = EngineOptions(score_backend=Bare(), fused_update="on")
        with pytest.raises(ValueError, match="make_fused_update"):
            opts.resolved_fused_update()
        # auto degrades to off instead of raising
        assert dataclasses.replace(
            opts, fused_update="auto").resolved_fused_update() == "off"


class TestLegacyBuildRetired:
    @pytest.mark.parametrize("backend", ["xla", "pallas"])
    def test_build_raises(self, ws_graph, backend):
        b = SCORE_BACKENDS[backend]
        with pytest.raises(NotImplementedError, match="retired"):
            b.build(ws_graph, 4)
        with pytest.raises(NotImplementedError, match="retired"):
            b.build_sharded(None, 4, None)


class TestAutotune:
    def test_deterministic_choice(self):
        g1 = generators.watts_strogatz(700, 8, 0.2, seed=13)
        g2 = generators.watts_strogatz(700, 8, 0.2, seed=13)
        c1 = autotune.choose_tile_config(g1, 8)
        c2 = autotune.choose_tile_config(g2, 8)
        assert c1 == c2
        assert c1[:2] in tuple(c[:2] for c in autotune.CANDIDATES) or \
            c1[:2] in autotune.CANDIDATES
        assert c1[2] == 128

    def test_sweep_covers_candidates(self):
        g = generators.powerlaw_ba(400, 6, seed=3)
        rows = autotune.sweep(g, 16)
        assert len(rows) == len(autotune.CANDIDATES)
        costs = [r["cost_s"] for r in rows]
        chosen = autotune.choose_tile_config(g, 16)
        assert chosen[:2] == (rows[int(np.argmin(costs))]["tile_v"],
                              rows[int(np.argmin(costs))]["tile_e"])

    def test_modeled_traffic_removes_score_roundtrip(self):
        split, fused = autotune.modeled_traffic(1024, 8192, 128)
        vk = 1024 * 128 * 4
        assert sum(split.values()) - sum(fused.values()) == 2 * vk
        assert "score_write" not in fused and "score_read" not in fused

    def test_applied_through_options(self, ws_graph):
        cfg = SpinnerConfig(k=5, max_iters=87, seed=7)
        opts = EngineOptions(score_backend="pallas", autotune="on")
        tuned = engine._autotuned(ws_graph, cfg, opts)
        b = tuned.backend()
        padded, _ = engine.padded_view(ws_graph, opts)
        want = autotune.choose_tile_config(padded, cfg.k)
        assert (b.tile_v, b.tile_e) == want[:2]
        # explicit instances pin their config under "auto"...
        pinned = EngineOptions(
            score_backend=PallasTiledBackend(tile_v=256, tile_e=128))
        assert engine._autotuned(ws_graph, cfg, pinned) is pinned
        # ...and are tuned under "on"
        forced = dataclasses.replace(pinned, autotune="on")
        fb = engine._autotuned(ws_graph, cfg, forced).backend()
        assert (fb.tile_v, fb.tile_e) == want[:2]

    def test_off_leaves_options_alone(self, ws_graph):
        cfg = SpinnerConfig(k=5, max_iters=88, seed=7)
        opts = EngineOptions(score_backend="pallas", autotune="off")
        assert engine._autotuned(ws_graph, cfg, opts) is opts


def _grow(graph, n_edges=30, new_vertices=2, seed=1):
    """A same-bucket growth of ``graph`` (a few edges + vertices)."""
    rng = np.random.default_rng(seed)
    v = graph.num_vertices
    return add_edges(graph, rng.integers(0, v, n_edges),
                     rng.integers(0, v, n_edges),
                     num_vertices=v + new_vertices)


@pytest.fixture(scope="module")
def session_graph():
    # mid-bucket (V, E): _grow() stays in the same shape bucket
    return generators.watts_strogatz(600, 8, 0.2, seed=11)


class TestSessionIntegration:
    def test_warm_adapt_zero_compiles_with_autotune(self, session_graph):
        """Same shape bucket -> same memoized tile choice -> zero new
        compiles on a warm fused+autotuned adapt (the determinism
        guarantee the autotuner exists to protect)."""
        cfg = SpinnerConfig(k=5, max_iters=89, seed=7)
        opts = EngineOptions(score_backend="pallas", fused_update="on",
                             autotune="on")
        with PartitionSession(session_graph, cfg, opts) as s:
            base = s.partition(record_history=False)
            g2 = _grow(session_graph)
            assert (engine.graph_buckets(g2)
                    == engine.graph_buckets(session_graph))
            before = s.compiles
            warm = s.adapt(g2, record_history=False)
            assert s.compiles == before, "autotuned warm adapt recompiled"
            assert warm.iterations > 0 and base.iterations > 0

    def test_stats_surface_tile_config(self, ws_graph):
        cfg = SpinnerConfig(k=5, max_iters=90, seed=7)
        opts = EngineOptions(score_backend="pallas")
        with PartitionSession(ws_graph, cfg, opts) as s:
            d = s.stats()
            assert d["score_backend"] == "pallas"
            assert d["fused_update"] == "on"       # pallas auto-opts in
            tc = d["tile_config"]
            padded, _ = engine.padded_view(ws_graph, opts)
            assert (tc["tile_v"], tc["tile_e"], tc["k_pad"]) == \
                autotune.choose_tile_config(padded, cfg.k)

    def test_mesh_stats_surface_via_comm_stats(self, ws_graph, mesh1):
        cfg = SpinnerConfig(k=5, max_iters=91, seed=7)
        opts = EngineOptions(score_backend="pallas", engine="sharded",
                             mesh=mesh1)
        with PartitionSession(ws_graph, cfg, opts) as s:
            d = s.stats()
            ex = d["exchange"]
            assert ex["score_backend"] == "pallas"
            assert ex["fused_update"] == "on"
            assert set(ex["tile_config"]) == {"tile_v", "tile_e", "k_pad"}

    def test_xla_stats_have_no_tile_config(self, ws_graph):
        cfg = SpinnerConfig(k=5, max_iters=92, seed=7)
        with PartitionSession(ws_graph, cfg,
                              EngineOptions(score_backend="xla")) as s:
            d = s.stats()
            assert d["score_backend"] == "xla"
            assert d["fused_update"] == "off"
            assert "tile_config" not in d


FUSED_MULTIDEV = """
import numpy as np
from repro.core import EngineOptions, SpinnerConfig, generators, partition
from repro.launch.mesh import make_partition_mesh

g = generators.watts_strogatz(401, 8, 0.2, seed=11)
cfg = SpinnerConfig(k=5, max_iters={max_iters}, seed=7)
for ndev in (2, 4, 8):
    mesh = make_partition_mesh(ndev)
    base = partition(g, cfg, record_history=False, engine="sharded",
                     mesh=mesh,
                     options=EngineOptions(score_backend="{backend}",
                                           label_exchange="allgather",
                                           overlap="off",
                                           fused_update="off"))
    for plan in ("allgather", "halo", "delta"):
        for ov in ("off", "on"):
            r = partition(g, cfg, record_history=False, engine="sharded",
                          mesh=mesh,
                          options=EngineOptions(score_backend="{backend}",
                                                label_exchange=plan,
                                                overlap=ov,
                                                fused_update="on"))
            np.testing.assert_array_equal(np.asarray(base.labels),
                                          np.asarray(r.labels))
            np.testing.assert_array_equal(np.asarray(base.loads),
                                          np.asarray(r.loads))
            assert base.iterations == r.iterations, (ndev, plan, ov)
print("FUSED MULTIDEV {backend} OK")
"""


@pytest.mark.slow
def test_fused_multidev_xla():
    r = run_devices_subprocess(FUSED_MULTIDEV.format(backend="xla",
                                                     max_iters=40))
    assert r.returncode == 0, r.stderr
    assert "FUSED MULTIDEV xla OK" in r.stdout


@pytest.mark.slow
def test_fused_multidev_pallas():
    r = run_devices_subprocess(FUSED_MULTIDEV.format(backend="pallas",
                                                     max_iters=18))
    assert r.returncode == 0, r.stderr
    assert "FUSED MULTIDEV pallas OK" in r.stdout
