"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.models import build, init_params, input_specs

SMOKE_TRAIN = ShapeConfig("smoke_train", 32, 2, "train")
SMOKE_DECODE = ShapeConfig("smoke_decode", 32, 2, "decode")


def _materialize(specs, vocab, key):
    out = {}
    for k, s in specs.items():
        if s.dtype == jnp.int32:
            out[k] = (jax.random.randint(key, s.shape, 0, vocab)
                      if len(s.shape) else jnp.int32(3))
        else:
            out[k] = jax.random.normal(key, s.shape, jnp.float32
                                       ).astype(s.dtype)
    return out


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke(arch):
    cfg = ARCHS[arch].reduced()
    api = build(cfg)
    params = init_params(api, jax.random.PRNGKey(0))
    batch_specs, _ = input_specs(cfg, SMOKE_TRAIN)
    batch = _materialize(batch_specs, cfg.vocab, jax.random.PRNGKey(1))

    loss, grads = jax.jit(jax.value_and_grad(api.loss))(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: bad grads"

    # prefill: last-position logits with padded-vocab width
    pf = {k: v for k, v in batch.items() if k != "labels"}
    logits, cache = jax.jit(api.prefill)(params, pf)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab_padded
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    # one decode step against a fresh decode-shaped cache
    _, cache_specs = input_specs(cfg, SMOKE_DECODE)
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), cache_specs)
    dbatch = {"token": batch["tokens"][:, 0], "pos": jnp.int32(3)}
    dl, new_cache = jax.jit(api.decode)(params, dbatch, cache)
    assert dl.shape == (2, 1, cfg.vocab_padded)
    assert np.isfinite(np.asarray(dl, np.float32)).all()
    # cache structure is preserved (serving loop contract)
    jax.tree.map(lambda a, b: None if a.shape == b.shape else
                 pytest.fail(f"{arch}: cache shape changed"),
                 cache, new_cache)


@pytest.mark.parametrize("arch", ["kimi-k2-1t-a32b", "qwen3-moe-235b-a22b"])
def test_moe_router_balance_loss_positive(arch):
    cfg = ARCHS[arch].reduced()
    api = build(cfg)
    params = init_params(api, jax.random.PRNGKey(0))
    from repro.models import moe
    tok = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0, cfg.vocab)
    _, aux = moe.forward(params, tok, cfg)
    assert float(aux) > 0.5   # ~1.0 for uniform routing


def test_param_counts_full_configs():
    """Full (non-reduced) parameter counts are in the right ballpark."""
    expected = {
        "granite-8b": (7e9, 10e9),
        # table dims with SwiGLU (3 MLP mats) -> heavier than the released
        # 2-mat GPT-bigcode checkpoint; we follow the assignment table.
        "granite-20b": (18e9, 30e9),
        "stablelm-1.6b": (1.3e9, 2.1e9),
        "qwen2.5-14b": (12e9, 16e9),
        "kimi-k2-1t-a32b": (0.9e12, 1.2e12),
        "qwen3-moe-235b-a22b": (2.0e11, 2.7e11),
        "rwkv6-1.6b": (1.3e9, 2.2e9),
        "zamba2-7b": (6e9, 9e9),
    }
    for arch, (lo, hi) in expected.items():
        api = build(ARCHS[arch])
        assert lo < api.num_params < hi, (arch, api.num_params)


def test_moe_active_params():
    api = build(ARCHS["kimi-k2-1t-a32b"])
    assert api.num_active_params < 0.06 * api.num_params
    assert api.num_active_params > 20e9
