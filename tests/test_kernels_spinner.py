"""Pallas spinner-scores kernel vs pure-jnp oracle: shape/dtype sweep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import generators, from_edges
from repro.core.graph import build_tiled_csr
from repro.kernels import ops, ref


def _random_graph(v, avg_deg, seed):
    rng = np.random.default_rng(seed)
    m = max(1, int(v * avg_deg / 2))
    return from_edges(rng.integers(0, v, m), rng.integers(0, v, m), v,
                      directed=bool(seed % 2))


@pytest.mark.parametrize("v,deg,k", [
    (1, 0, 2), (5, 2, 3), (127, 4, 2), (128, 4, 16), (200, 6, 17),
    (513, 8, 130), (1000, 10, 64),
])
def test_kernel_matches_oracle_shapes(v, deg, k):
    g = _random_graph(v, deg, seed=v)
    labels = jnp.asarray(
        np.random.default_rng(1).integers(0, k, v), jnp.int32)
    out = ops.spinner_scores(labels, g, k)
    expect = ref.spinner_scores_ref(labels, jnp.asarray(g.src),
                                    jnp.asarray(g.dst),
                                    jnp.asarray(g.weight), v, k)
    assert out.shape == (v, k) and out.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-5)


@pytest.mark.parametrize("tile_v,tile_e", [(8, 8), (8, 128), (128, 8),
                                           (256, 128)])
def test_kernel_tile_shapes(tile_v, tile_e):
    g = generators.powerlaw_ba(500, 4, seed=2)
    k = 9
    labels = jnp.asarray(
        np.random.default_rng(2).integers(0, k, g.num_vertices), jnp.int32)
    out = ops.spinner_scores(labels, g, k, tile_v=tile_v, tile_e=tile_e)
    expect = ref.spinner_scores_ref(labels, jnp.asarray(g.src),
                                    jnp.asarray(g.dst),
                                    jnp.asarray(g.weight),
                                    g.num_vertices, k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-5)


def test_kernel_weighted_directed_graph():
    # reciprocal edges get weight 2 (Eq. 3) and the kernel must honor it
    g = from_edges([0, 1, 1, 2, 3], [1, 0, 2, 3, 1], 4, directed=True)
    k = 3
    labels = jnp.asarray([0, 1, 2, 1], jnp.int32)
    out = ops.spinner_scores(labels, g, k)
    expect = ref.spinner_scores_ref(labels, jnp.asarray(g.src),
                                    jnp.asarray(g.dst),
                                    jnp.asarray(g.weight), 4, k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect))
    # vertex 0's only neighbour is 1 (label 1) with weight 2
    assert float(out[0, 1]) == 2.0


def test_tiled_csr_roundtrip_hub_balance():
    g = generators.powerlaw_ba(700, 5, seed=3)
    t = build_tiled_csr(g, tile_v=64, tile_e=64)
    # every real edge appears exactly once: total weight preserved
    assert t.weight.sum() == pytest.approx(g.weight.sum())
    # degree interleaving keeps per-tile chunk counts near the mean
    per_tile = (t.weight > 0).sum(axis=(1, 2))
    assert per_tile.max() <= 4 * max(1.0, per_tile.mean())


def test_tiled_ref_matches_plain_ref():
    g = generators.watts_strogatz(300, 6, 0.3, seed=4)
    k = 7
    t = build_tiled_csr(g, tile_v=32, tile_e=32)
    labels = jnp.asarray(
        np.random.default_rng(5).integers(0, k, g.num_vertices), jnp.int32)
    tiled = ref.spinner_scores_tiled_ref(labels, jnp.asarray(t.src_local),
                                         jnp.asarray(t.dst),
                                         jnp.asarray(t.weight), t.tile_v, k)
    back = tiled[jnp.asarray(t.perm)]
    plain = ref.spinner_scores_ref(labels, jnp.asarray(g.src),
                                   jnp.asarray(g.dst),
                                   jnp.asarray(g.weight),
                                   g.num_vertices, k)
    np.testing.assert_allclose(np.asarray(back), np.asarray(plain),
                               atol=1e-5)
