"""Property-based tests (hypothesis) for system invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (SpinnerConfig, elastic_relabel, from_edges, metrics,
                        partition)

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


edge_lists = st.integers(5, 60).flatmap(
    lambda v: st.tuples(
        st.just(v),
        st.lists(st.tuples(st.integers(0, v - 1), st.integers(0, v - 1)),
                 min_size=1, max_size=300)))


@given(edge_lists)
def test_symmetrization_invariants(data):
    v, edges = data
    src = [e[0] for e in edges]
    dst = [e[1] for e in edges]
    g = from_edges(src, dst, v, directed=True)
    g.validate()
    # Eq. 3: weights only 1 or 2
    assert set(np.unique(g.weight)) <= {1.0, 2.0}
    # no self loops
    assert not np.any(g.src == g.dst)
    # total weight is even (each undirected edge counted twice)
    assert g.total_weight % 2 == 0


@given(edge_lists, st.integers(2, 6))
def test_partition_labels_in_range_and_loads_conserved(data, k):
    v, edges = data
    g = from_edges([e[0] for e in edges], [e[1] for e in edges], v,
                   directed=False)
    cfg = SpinnerConfig(k=k, seed=1, max_iters=15)
    res = partition(g, cfg, record_history=False)
    assert res.labels.shape == (v,)
    assert res.labels.min() >= 0 and res.labels.max() < k
    # loads sum to total weighted degree regardless of migrations
    np.testing.assert_allclose(float(res.loads.sum()), g.total_weight,
                               rtol=1e-4, atol=1e-3)


@given(st.integers(1, 6), st.integers(1, 6), st.integers(0, 10))
def test_elastic_relabel_ranges(k_old, n_new, seed):
    prev = np.random.default_rng(seed).integers(
        0, k_old, 5000).astype(np.int32)
    out = elastic_relabel(prev, k_old, k_old + n_new, seed=seed)
    assert out.min() >= 0 and out.max() < k_old + n_new
    if n_new == 0:
        np.testing.assert_array_equal(out, prev)
    else:
        # movers go ONLY to new partitions
        moved = out != prev
        assert np.all(out[moved] >= k_old)


@given(st.integers(2, 8), st.integers(0, 5))
def test_partitioning_difference_bounds(k, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, k, 1000).astype(np.int32)
    b = rng.integers(0, k, 1000).astype(np.int32)
    d = metrics.partitioning_difference(a, b)
    assert 0.0 <= d <= 1.0
    assert metrics.partitioning_difference(a, a) == 0.0


@given(edge_lists, st.integers(2, 5))
def test_phi_rho_bounds(data, k):
    v, edges = data
    g = from_edges([e[0] for e in edges], [e[1] for e in edges], v,
                   directed=True)
    labels = np.random.default_rng(0).integers(0, k, v).astype(np.int32)
    assert 0.0 <= metrics.phi(g, labels) <= 1.0
    if g.num_undirected_edges:
        assert metrics.rho(g, labels, k) >= 1.0 - 1e-6
