"""Optimizer, data pipeline, and checkpoint subsystem tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint
from repro.data import pipeline
from repro.optim import adamw


class TestAdamW:
    def test_converges_on_quadratic(self):
        cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=5,
                                total_steps=200)
        params = {"w": jnp.array([3.0, -2.0])}
        state = adamw.init(params)

        @jax.jit
        def step(params, state):
            grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            return adamw.update(cfg, grads, state, params)

        for _ in range(150):
            params, state, stats = step(params, state)
        assert float(jnp.abs(params["w"]).max()) < 0.05

    def test_clip_caps_update(self):
        cfg = adamw.AdamWConfig(lr=1.0, clip_norm=1.0, warmup_steps=0)
        params = {"w": jnp.zeros(4)}
        state = adamw.init(params)
        grads = {"w": jnp.full(4, 100.0)}
        _, state2, stats = adamw.update(cfg, grads, state, params)
        assert float(stats["grad_norm"]) == pytest.approx(200.0)
        # post-clip first moment is bounded by (1-b1)*clip direction
        assert float(jnp.abs(state2.m["w"]).max()) < 0.2

    def test_schedule_warmup_and_decay(self):
        cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                                min_lr_frac=0.1)
        assert float(adamw.schedule(cfg, jnp.int32(5))) == pytest.approx(
            5e-4)
        assert float(adamw.schedule(cfg, jnp.int32(100))) == pytest.approx(
            1e-4, rel=1e-2)


class TestData:
    def test_deterministic(self):
        cfg = pipeline.DataConfig(vocab=100, seq_len=32, global_batch=8,
                                  seed=3)
        a = pipeline.batch_at(cfg, step=7)
        b = pipeline.batch_at(cfg, step=7)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_steps_differ(self):
        cfg = pipeline.DataConfig(vocab=100, seq_len=32, global_batch=8)
        a = pipeline.batch_at(cfg, 0)
        b = pipeline.batch_at(cfg, 1)
        assert not np.array_equal(a["tokens"], b["tokens"])

    def test_shards_partition_batch(self):
        cfg = pipeline.DataConfig(vocab=100, seq_len=16, global_batch=8)
        s0 = pipeline.batch_at(cfg, 0, shard=0, num_shards=2)
        s1 = pipeline.batch_at(cfg, 0, shard=1, num_shards=2)
        assert s0["tokens"].shape == (4, 16)
        assert not np.array_equal(s0["tokens"], s1["tokens"])

    def test_labels_shifted(self):
        cfg = pipeline.DataConfig(vocab=100, seq_len=16, global_batch=2,
                                  noise=0.0)
        b = pipeline.batch_at(cfg, 0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_learnable_structure(self):
        cfg = pipeline.DataConfig(vocab=1000, seq_len=64, global_batch=4,
                                  noise=0.0, n_motifs=4, motif_len=8)
        b = pipeline.batch_at(cfg, 0)
        seq = b["tokens"][0]
        assert np.array_equal(seq[:8], seq[8:16])  # motif repeats


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6).reshape(2, 3),
                "b": {"c": jnp.float32(2.5),
                      "d": np.ones((4,), np.int32)}}
        checkpoint.save(str(tmp_path), 3, tree)
        out = checkpoint.restore(str(tmp_path), tree)
        jax.tree.map(lambda x, y: np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y)), tree, out)

    def test_latest_and_gc(self, tmp_path):
        tree = {"x": jnp.zeros(2)}
        for s in (1, 5, 9):
            checkpoint.save(str(tmp_path), s, tree)
        assert checkpoint.latest_step(str(tmp_path)) == 9
        checkpoint.gc_old(str(tmp_path), keep=2)
        assert checkpoint.latest_step(str(tmp_path)) == 9
        assert len(os.listdir(tmp_path)) == 2

    def test_atomic_no_partial(self, tmp_path):
        tree = {"x": jnp.zeros(2)}
        checkpoint.save(str(tmp_path), 1, tree)
        # a stale tmp dir from a crashed writer must not be visible
        os.makedirs(tmp_path / "step_00000002.tmp")
        assert checkpoint.latest_step(str(tmp_path)) == 1

    def test_restore_into_namedtuple_state(self, tmp_path):
        from repro.train.steps import TrainState, init_train_state
        params = {"w": jnp.ones((3, 3))}
        state = init_train_state(params)
        checkpoint.save(str(tmp_path), 0, state)
        restored = checkpoint.restore(str(tmp_path), state)
        assert isinstance(restored, TrainState)
        np.testing.assert_array_equal(np.asarray(restored.params["w"]),
                                      np.ones((3, 3)))
