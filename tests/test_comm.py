"""Unit coverage for the shared communication layer (core/comm.py).

The halo-plan construction is shared by the sharded LPA engine
(``label_exchange="halo"``) and distributed PageRank; these tests check
the host-side plans against numpy simulations of the exchange, so the
multi-device subprocess tests only have to validate the collectives.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import EngineOptions, comm, generators
from repro.core.distributed import shard_graph
from repro.core.graph import build_sharded_tiled_csr


def _simulate_halo(values, send_idx, ndev, v_per_dev):
    """Numpy model of ``comm.halo_exchange``: per-device lookup arrays."""
    H = send_idx.shape[2]
    exts = []
    for q in range(ndev):
        local = values[q * v_per_dev: (q + 1) * v_per_dev]
        halo = np.zeros((ndev, H), values.dtype)
        for p in range(ndev):
            halo[p] = values[p * v_per_dev: (p + 1) * v_per_dev][
                send_idx[p, q]]
        exts.append(np.concatenate([local, halo.reshape(-1)]))
    return exts


class TestBuildHaloIndex:
    def test_ext_idx_reads_remote_values(self):
        rng = np.random.default_rng(0)
        ndev, v_per_dev = 4, 16
        V = ndev * v_per_dev
        E = 300
        edge_owner = rng.integers(0, ndev, E)
        remote = rng.integers(0, V, E)
        hidx = comm.build_halo_index(edge_owner, remote, ndev, v_per_dev)
        values = rng.integers(0, 1000, V)
        exts = _simulate_halo(values, hidx.send_idx, ndev, v_per_dev)
        for e in range(E):
            assert exts[edge_owner[e]][hidx.ext_idx[e]] == values[remote[e]]

    def test_true_halo_counts_unique_remote_refs(self):
        # device 0 owns every edge; remotes: 3 uniques on dev 1, 1 on dev 2
        edge_owner = np.zeros(6, np.int64)
        remote = np.array([4, 5, 4, 6, 8, 8])
        hidx = comm.build_halo_index(edge_owner, remote, ndev=3, v_per_dev=4)
        assert hidx.true_halo == 4
        assert hidx.halo_size == 3


class TestExchangePlans:
    @pytest.fixture(scope="class")
    def sg(self):
        g = generators.watts_strogatz(403, 8, 0.3, seed=4)
        return shard_graph(g, 4)

    def test_halo_dst_index_reads_global_labels(self, sg):
        plan = comm.make_exchange_plan("halo", sg)
        rng = np.random.default_rng(1)
        labels = rng.integers(0, 7, sg.num_vertices)
        exts = _simulate_halo(labels, np.asarray(plan._send_idx), sg.ndev,
                              sg.v_per_dev)
        for p in range(sg.ndev):
            real = sg.weight[p] > 0
            np.testing.assert_array_equal(
                exts[p][plan.dst_index[p][real]], labels[sg.dst[p][real]])

    def test_halo_cheaper_than_allgather_on_clustered(self):
        # contiguous communities + range partition => small boundary
        g = generators.clustered_graph(8, 200, 0.05, 0.2, seed=2)
        sg = shard_graph(g, 8)
        halo = comm.make_exchange_plan("halo", sg)
        ag = comm.make_exchange_plan("allgather", sg)
        assert halo.wire_bytes_per_iter() < ag.wire_bytes_per_iter()
        assert halo.padded_wire_bytes_per_iter() < ag.wire_bytes_per_iter()

    def test_delta_cap_resolution(self, sg):
        assert comm.make_exchange_plan("delta", sg).cap == sg.v_per_dev // 4
        assert comm.make_exchange_plan("delta", sg, delta_cap=7).cap == 7
        big = comm.make_exchange_plan("delta", sg, delta_cap=10 ** 9)
        assert big.cap == sg.v_per_dev       # clipped to the shard size
        with pytest.raises(ValueError, match="delta_cap"):
            comm.make_exchange_plan("delta", sg, delta_cap=0)

    def test_unknown_plan_rejected(self, sg):
        with pytest.raises(ValueError, match="label exchange"):
            comm.make_exchange_plan("broadcast", sg)

    def test_config_resolution(self):
        opts = EngineOptions()
        assert opts.resolved_label_exchange(1) == "allgather"
        assert opts.resolved_label_exchange(8) == "delta"
        opts2 = dataclasses.replace(opts, label_exchange="halo")
        assert opts2.resolved_label_exchange(1) == "halo"
        with pytest.raises(ValueError, match="label_exchange"):
            dataclasses.replace(
                opts, label_exchange="bogus").resolved_label_exchange(2)
        with pytest.raises(ValueError, match="sharded_noise"):
            dataclasses.replace(
                opts, sharded_noise="bogus").resolved_sharded_noise()

    def test_plan_signature_roundtrip(self, sg):
        """from_signature reconstructs the traced shape ints exactly."""
        for name in ("allgather", "halo", "delta"):
            plan = comm.make_exchange_plan(name, sg)
            view = comm.plan_from_signature(plan.signature())
            assert view.signature() == plan.signature()
            assert type(view) is type(plan)


class TestPregelOnSharedHalo:
    def test_pagerank_distributed_matches_reference_1dev(self):
        """The refactored halo plan drives PageRank to the same values."""
        from repro.core import pregel
        from repro.core.pregel_dist import pagerank_distributed
        from repro.launch.mesh import make_partition_mesh
        g = generators.watts_strogatz(300, 6, 0.3, seed=8)
        labels = np.zeros(g.num_vertices, np.int32)
        ref = pregel.pagerank(g, labels, 1, iters=15).values
        got, stats = pagerank_distributed(g, labels, make_partition_mesh(1),
                                          iters=15)
        np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-9)
        assert stats["halo_true_bytes_per_step"] == 0


class TestShardedTiledCSR:
    def test_tiles_reconstruct_shard_scatter(self):
        """Scatter-adding each shard's tiles == scattering its raw edges."""
        g = generators.powerlaw_ba(300, 4, seed=9)
        sg = shard_graph(g, 4)
        st = build_sharded_tiled_csr(sg, tile_v=64, tile_e=32)
        rng = np.random.default_rng(3)
        k = 5
        labels = rng.integers(0, k, sg.num_vertices)
        for p in range(sg.ndev):
            want = np.zeros((sg.v_per_dev, k), np.float32)
            real = sg.weight[p] > 0
            np.add.at(want, (sg.src_local[p][real],
                             labels[sg.dst[p][real]]), sg.weight[p][real])
            got_tiled = np.zeros((st.num_tiles * st.tile_v, k), np.float32)
            sl = st.src_local[p] + (np.arange(st.num_tiles)[:, None, None]
                                    * st.tile_v)
            np.add.at(got_tiled, (sl.reshape(-1),
                                  labels[st.dst[p].reshape(-1)]),
                      st.weight[p].reshape(-1))
            got = got_tiled[st.perm[p]]
            np.testing.assert_array_equal(got, want)

    def test_halo_dst_index_threads_through_tiling(self):
        g = generators.watts_strogatz(200, 6, 0.2, seed=5)
        sg = shard_graph(g, 2)
        plan = comm.make_exchange_plan("halo", sg)
        st = build_sharded_tiled_csr(sg, dst_index=plan.dst_index,
                                     tile_v=64, tile_e=32)
        # every real tiled edge's dst fits inside the plan's lookup array
        width = sg.v_per_dev + sg.ndev * plan.halo_size
        for p in range(sg.ndev):
            real = st.weight[p] > 0
            assert st.dst[p][real].max(initial=0) < width
