"""PartitionSession coverage (PR 4).

Four pillars:

  * session-vs-one-shot bit parity on every engine (fused / chunked /
    host / sharded-on-a-mesh) and every exchange plan -- the one-shot
    wrappers open throwaway sessions with the same defaults, so a warm
    session call must reproduce them bit for bit;
  * shape-bucketed compile reuse: a warm ``adapt()`` on a grown graph
    that stays inside its (V, E) bucket performs ZERO new compilations
    (asserted via the programs' jit compilation counters), crossing a
    bucket costs exactly one;
  * ``adapt``/``resize``/``update`` through a live session;
  * the SpinnerConfig -> EngineOptions split: deprecated engine knobs on
    the config warn ``SpinnerDeprecationWarning`` and resolve into the
    options object.

Each test uses a unique ``max_iters`` so its programs are private in the
global program cache and compile counts cannot be perturbed by other
tests.
"""
import numpy as np
import pytest

from repro.core import (EngineOptions, PartitionSession, SpinnerConfig,
                        SpinnerDeprecationWarning, adapt, engine, generators,
                        open_session, partition, resize, resolve_options)
from repro.core.graph import add_edges, pad_graph, shape_bucket
from repro.launch.mesh import make_partition_mesh


@pytest.fixture(scope="module")
def ws_graph():
    return generators.watts_strogatz(600, 8, 0.2, seed=11)


def _grow(graph, n_edges=30, new_vertices=2, seed=1):
    """A same-bucket growth of ``graph`` (a few edges + vertices)."""
    rng = np.random.default_rng(seed)
    v = graph.num_vertices
    return add_edges(graph, rng.integers(0, v, n_edges),
                     rng.integers(0, v, n_edges),
                     num_vertices=v + new_vertices)


def _assert_same(a, b):
    np.testing.assert_array_equal(a.labels, b.labels)
    np.testing.assert_array_equal(a.loads, b.loads)
    assert a.iterations == b.iterations
    assert a.halted == b.halted


class TestShapeBuckets:
    def test_power_of_two_ish(self):
        assert shape_bucket(600) == 640
        assert shape_bucket(1024) == 1024
        assert shape_bucket(1025) == 1280
        assert shape_bucket(3) == 64          # floor
        for n in (64, 100, 700, 5000, 12345):
            b = shape_bucket(n)
            assert b >= n
            assert b <= 1.25 * n or n < 64    # <= 25% overhead
            assert b % 8 == 0                 # exact 1/2/4/8-device splits

    def test_pad_graph_is_a_noop_view(self):
        g = generators.powerlaw_ba(300, 4, seed=5)
        vb, eb = engine.graph_buckets(g)
        p = pad_graph(g, vb, eb)
        p.validate()
        assert p.num_vertices == vb
        assert p.num_directed_entries == eb
        # pads are weightless: totals and real degrees unchanged
        assert p.total_weight == g.total_weight
        np.testing.assert_array_equal(p.deg_w[: g.num_vertices], g.deg_w)
        assert (p.deg_w[g.num_vertices:] == 0).all()
        real = p.weight > 0
        np.testing.assert_array_equal(p.src[real], g.src)
        np.testing.assert_array_equal(p.dst[real], g.dst)


class TestSessionOneShotParity:
    @pytest.mark.parametrize("eng", ["fused", "chunked", "host"])
    def test_single_device_engines(self, ws_graph, eng):
        cfg = SpinnerConfig(k=6, seed=2, max_iters=61)
        opts = EngineOptions(engine=eng)
        one = partition(ws_graph, cfg, record_history=False, engine=eng)
        with PartitionSession(ws_graph, cfg, opts) as s:
            res = s.partition(record_history=False)
        _assert_same(one, res)

    def test_sharded_mesh(self, ws_graph):
        cfg = SpinnerConfig(k=6, seed=2, max_iters=62)
        mesh = make_partition_mesh(1)
        one = partition(ws_graph, cfg, record_history=False,
                        engine="sharded", mesh=mesh)
        with PartitionSession(ws_graph, cfg,
                              EngineOptions(engine="sharded",
                                            mesh=mesh)) as s:
            res = s.partition(record_history=False)
        _assert_same(one, res)

    def test_chunked_history_matches(self, ws_graph):
        cfg = SpinnerConfig(k=6, seed=2, max_iters=63)
        one = partition(ws_graph, cfg, record_history=True,
                        engine="chunked", chunk_size=16)
        with PartitionSession(ws_graph, cfg,
                              EngineOptions(engine="chunked",
                                            chunk_size=16)) as s:
            res = s.partition(record_history=True)
        _assert_same(one, res)
        assert one.history == res.history


class TestWarmAdaptBitParity:
    """The acceptance criterion: a warm ``adapt()`` on a same-bucket grown
    graph performs zero new compilations and is bit-identical to one-shot
    ``adapt()`` -- for every engine and every exchange plan."""

    @pytest.mark.parametrize("eng", ["fused", "chunked", "host"])
    def test_engines(self, ws_graph, eng):
        cfg = SpinnerConfig(k=6, seed=2, max_iters=64)
        opts = EngineOptions(engine=eng)
        with PartitionSession(ws_graph, cfg, opts) as s:
            base = s.partition(record_history=False)
            g2 = _grow(ws_graph)
            assert engine.graph_buckets(g2) == engine.graph_buckets(ws_graph)
            before = s.compiles
            warm = s.adapt(g2, record_history=False)
            assert s.compiles == before, \
                f"warm adapt recompiled on engine={eng}"
            one = adapt(g2, base.labels, cfg, engine=eng,
                        record_history=False)
            _assert_same(one, warm)

    @pytest.mark.parametrize("plan", ["allgather", "halo", "delta"])
    def test_sharded_exchange_plans(self, ws_graph, plan):
        cfg = SpinnerConfig(k=6, seed=2, max_iters=65)
        mesh = make_partition_mesh(1)
        opts = EngineOptions(engine="sharded", mesh=mesh,
                             label_exchange=plan)
        with PartitionSession(ws_graph, cfg, opts) as s:
            base = s.partition(record_history=False)
            g2 = _grow(ws_graph)
            before = s.compiles
            warm = s.adapt(g2, record_history=False)
            assert s.compiles == before, \
                f"warm adapt recompiled on plan={plan}"
            one = adapt(g2, base.labels, cfg, record_history=False,
                        options=opts)
            _assert_same(one, warm)

    def test_default_mesh_sharded(self, ws_graph):
        """Sharded session on the default (all local devices) mesh."""
        cfg = SpinnerConfig(k=6, seed=2, max_iters=66)
        mesh = make_partition_mesh()
        opts = EngineOptions(engine="sharded", mesh=mesh)
        with PartitionSession(ws_graph, cfg, opts) as s:
            base = s.partition(record_history=False)
            g2 = _grow(ws_graph)
            before = s.compiles
            warm = s.adapt(g2, record_history=False)
            assert s.compiles == before
            one = adapt(g2, base.labels, cfg, record_history=False,
                        engine="sharded", mesh=mesh)
            _assert_same(one, warm)


class TestBucketReuse:
    def test_cold_run_compiles_once(self, ws_graph):
        cfg = SpinnerConfig(k=6, seed=2, max_iters=67)
        with open_session(ws_graph, cfg) as s:
            assert s.compiles == 0
            s.partition(record_history=False)
            assert s.compiles == 1

    def test_cross_bucket_compiles_exactly_once(self, ws_graph):
        cfg = SpinnerConfig(k=6, seed=2, max_iters=68)
        with open_session(ws_graph, cfg) as s:
            s.partition(record_history=False)
            base = s.compiles
            # grow past the vertex bucket: 600 -> bucket 640; 650 -> 768
            g_big = _grow(ws_graph, n_edges=40,
                          new_vertices=700 - ws_graph.num_vertices, seed=2)
            assert engine.graph_buckets(g_big)[0] != \
                engine.graph_buckets(ws_graph)[0]
            s.adapt(g_big, record_history=False)
            assert s.compiles == base + 1
            # ... and a further same-bucket growth is free again
            g_big2 = _grow(g_big, seed=3)
            assert engine.graph_buckets(g_big2) == engine.graph_buckets(g_big)
            before = s.compiles
            s.adapt(g_big2, record_history=False)
            assert s.compiles == before

    def test_two_sessions_share_programs(self, ws_graph):
        """The program cache is global: a second session over a same-bucket
        graph compiles nothing (cross-session amortization)."""
        cfg = SpinnerConfig(k=6, seed=2, max_iters=69)
        with open_session(ws_graph, cfg) as s1:
            s1.partition(record_history=False)
            assert s1.compiles == 1
        g_other = generators.watts_strogatz(610, 8, 0.2, seed=12)
        assert engine.graph_buckets(g_other) == engine.graph_buckets(ws_graph)
        with open_session(g_other, cfg) as s2:
            s2.partition(record_history=False)
            assert s2.compiles == 0


class TestLiveSession:
    def test_adapt_resize_update_stream(self, ws_graph):
        cfg = SpinnerConfig(k=6, seed=3, max_iters=70)
        with open_session(ws_graph, cfg) as s:
            r0 = s.partition(record_history=False)
            assert s.labels is not None
            # adapt via edge_updates applies add_edges internally
            rng = np.random.default_rng(7)
            r1 = s.adapt(edge_updates=(rng.integers(0, 600, 20),
                                       rng.integers(0, 600, 20)),
                         record_history=False)
            assert r1.labels.shape == (600,)
            # update() stages a delta; the next adapt() sees it
            s.update([600, 601], [0, 1], num_vertices=602)
            r2 = s.adapt(record_history=False)
            assert r2.labels.shape == (602,)
            # resize re-keys the session to the new k
            r3 = s.resize(8, record_history=False)
            assert r3.labels.max() < 8
            assert s.cfg.k == 8
            assert s.stats()["k"] == 8
            # ... and parity with the one-shot elastic path
            one, _ = resize(s.graph, r2.labels,
                            SpinnerConfig(k=8, seed=3, max_iters=70),
                            k_old=6, record_history=False)
            np.testing.assert_array_equal(one.labels, r3.labels)
            assert r0.iterations > 0 and s.stats()["runs"] == 4

    def test_adapt_requires_prev(self, ws_graph):
        cfg = SpinnerConfig(k=4, seed=0, max_iters=8)
        with open_session(ws_graph, cfg) as s:
            with pytest.raises(ValueError, match="previous labels"):
                s.adapt(_grow(ws_graph))
            # the failed adapt must NOT have swapped the session's graph
            assert s.graph is ws_graph

    def test_resize_on_host_engine(self, ws_graph):
        """resize() must run the NEW k on every engine -- the host driver
        takes the per-run cfg, not the session's yet-uncommitted one."""
        cfg = SpinnerConfig(k=4, seed=0, max_iters=12)
        with open_session(ws_graph, cfg, EngineOptions(engine="host")) as s:
            s.partition(record_history=False)
            res = s.resize(6, record_history=False)
            assert res.loads.shape == (6,)
            assert res.labels.max() < 6
            assert s.cfg.k == 6

    def test_failed_resize_does_not_commit_k(self, ws_graph):
        """A rejected resize call (bad engine/history combination) must
        leave the session's config -- and therefore the label range of
        subsequent runs -- untouched."""
        cfg = SpinnerConfig(k=8, seed=0, max_iters=10)
        with open_session(ws_graph, cfg,
                          EngineOptions(engine="fused")) as s:
            s.partition(record_history=False)
            with pytest.raises(ValueError, match="history"):
                s.resize(4, record_history=True)
            assert s.cfg.k == 8
            res = s.adapt(record_history=False)
            assert res.labels.max() < 8 and res.loads.shape == (8,)

    def test_closed_session_raises(self, ws_graph):
        cfg = SpinnerConfig(k=4, seed=0, max_iters=8)
        s = open_session(ws_graph, cfg)
        s.close()
        with pytest.raises(RuntimeError, match="closed"):
            s.partition()

    def test_stats_reports_buckets_and_exchange(self, ws_graph):
        mesh = make_partition_mesh(1)
        cfg = SpinnerConfig(k=6, seed=2, max_iters=71)
        opts = EngineOptions(engine="sharded", mesh=mesh,
                             label_exchange="halo")
        with open_session(ws_graph, cfg, opts) as s:
            s.partition(record_history=False)
            st = s.stats()
            assert st["bucket"] == engine.graph_buckets(ws_graph)
            assert st["padded_shape"][0] == st["bucket"][0]
            assert st["compiles"] >= 1 and st["runs"] == 1
            assert st["exchange"]["label_exchange"] == "halo"
            assert st["last"]["halted"] in (True, False)

    def test_pad_none_keeps_exact_shapes(self, ws_graph):
        """pad='none' is the escape hatch: exact shapes, same quality."""
        cfg = SpinnerConfig(k=6, seed=2, max_iters=72)
        opts = EngineOptions(pad="none")
        with open_session(ws_graph, cfg, opts) as s:
            res = s.partition(record_history=False)
            assert s.stats()["padded_shape"] == (
                ws_graph.num_vertices, ws_graph.num_directed_entries)
            assert res.labels.shape == (ws_graph.num_vertices,)


class TestConfigSplitShim:
    def test_use_kernel_warns_and_resolves(self):
        with pytest.warns(SpinnerDeprecationWarning, match="use_kernel"):
            cfg = SpinnerConfig(k=4, use_kernel=True)
        cfg2, opts = resolve_options(cfg)
        assert opts.score_backend == "pallas"
        assert cfg2.use_kernel is False          # scrubbed downstream

    def test_engine_knobs_warn_and_resolve(self):
        with pytest.warns(SpinnerDeprecationWarning,
                          match="label_exchange"):
            cfg = SpinnerConfig(k=4, label_exchange="halo", delta_cap=9,
                                sharded_noise="folded",
                                score_backend="pallas")
        _, opts = resolve_options(cfg)
        assert opts.label_exchange == "halo"
        assert opts.delta_cap == 9
        assert opts.sharded_noise == "folded"
        assert opts.score_backend == "pallas"

    def test_clean_config_does_not_warn(self):
        import warnings
        with warnings.catch_warnings():
            warnings.simplefilter("error", SpinnerDeprecationWarning)
            cfg = SpinnerConfig(k=4, c=1.1, eps=1e-4, seed=3)
            resolve_options(cfg, EngineOptions(score_backend="pallas"))

    def test_legacy_config_still_runs_identically(self, ws_graph):
        """The shim is behavior-preserving: use_kernel=True equals the
        EngineOptions(score_backend='pallas') spelling bit for bit."""
        with pytest.warns(SpinnerDeprecationWarning):
            cfg_old = SpinnerConfig(k=4, seed=2, max_iters=20,
                                    use_kernel=True)
        cfg_new = SpinnerConfig(k=4, seed=2, max_iters=20)
        a = partition(ws_graph, cfg_old, record_history=False)
        b = partition(ws_graph, cfg_new, record_history=False,
                      options=EngineOptions(score_backend="pallas"))
        _assert_same(a, b)

    def test_per_call_kwargs_win_over_options(self, ws_graph):
        cfg = SpinnerConfig(k=4, seed=0, max_iters=9)
        res = partition(ws_graph, cfg, record_history=False,
                        engine="host",
                        options=EngineOptions(engine="fused"))
        assert res.engine == "host"
