"""Numerical equivalence: chunked/flash paths vs step-by-step oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import chunked_attention
from repro.models.rwkv import wkv_chunked, wkv_ref
from repro.models.ssm import ssd_chunked, ssd_ref


def _ref_attn(q, k, v, causal):
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qf = q.astype(jnp.float32).reshape(b, sq, kvh, g, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qf, k.astype(jnp.float32))
    s = s / hd ** 0.5
    if causal:
        mask = jnp.tril(jnp.ones((sq, k.shape[1]), bool))
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, hd)


@pytest.mark.parametrize("b,sq,skv,h,kv,hd,causal,cq,ck", [
    (2, 64, 64, 4, 2, 16, True, 16, 16),
    (1, 32, 32, 8, 8, 8, True, 32, 8),
    (2, 64, 128, 4, 1, 16, False, 16, 32),
    (1, 48, 80, 4, 4, 8, False, 16, 16),   # non-pow2 kv len via gcd
])
def test_flash_forward_and_grads(b, sq, skv, h, kv, hd, causal, cq, ck):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((b, sq, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, skv, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, skv, kv, hd)), jnp.float32)

    def f(q, k, v):
        return chunked_attention(q, k, v, causal=causal, chunk_q=cq,
                                 chunk_kv=ck)

    out = f(q, k, v)
    expect = _ref_attn(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect), atol=0.05, rtol=0.05)
    co = jnp.asarray(rng.standard_normal(out.shape), jnp.float32)
    g1 = jax.grad(lambda *a: jnp.sum(f(*a).astype(jnp.float32) * co),
                  argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda *a: jnp.sum(_ref_attn(*a, causal) * co),
                  argnums=(0, 1, 2))(q, k, v)
    for a, e in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(e), atol=0.35, rtol=0.1)


@pytest.mark.parametrize("b,s,h,hd,chunk", [
    (2, 32, 2, 8, 8), (1, 64, 4, 16, 16), (2, 48, 1, 8, 16), (1, 16, 2, 4, 16),
])
def test_wkv_chunked_matches_recurrence(b, s, h, hd, chunk):
    rng = np.random.default_rng(1)
    r, k, v = (jnp.asarray(rng.standard_normal((b, s, h, hd)) * 0.5,
                           jnp.float32) for _ in range(3))
    lw = jnp.asarray(-np.exp(rng.standard_normal((b, s, h, hd)) * 0.5 - 1),
                     jnp.float32)
    u = jnp.asarray(rng.standard_normal((h, hd)) * 0.3, jnp.float32)
    s0 = jnp.asarray(rng.standard_normal((b, h, hd, hd)) * 0.1, jnp.float32)
    out_c, s_c = wkv_chunked(r, k, v, lw, u, s0, chunk)
    out_r, s_r = wkv_ref(r, k, v, lw, u, s0)
    np.testing.assert_allclose(np.asarray(out_c, np.float32),
                               np.asarray(out_r, np.float32),
                               atol=0.02, rtol=0.02)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_r),
                               atol=0.02, rtol=0.02)


@pytest.mark.parametrize("b,s,h,hd,n,chunk", [
    (2, 32, 3, 8, 4, 8), (1, 64, 2, 16, 8, 16), (2, 24, 1, 8, 4, 12),
])
def test_ssd_chunked_matches_recurrence(b, s, h, hd, n, chunk):
    rng = np.random.default_rng(2)
    xh = jnp.asarray(rng.standard_normal((b, s, h, hd)) * 0.5, jnp.float32)
    Bc = jnp.asarray(rng.standard_normal((b, s, n)) * 0.5, jnp.float32)
    Cc = jnp.asarray(rng.standard_normal((b, s, n)) * 0.5, jnp.float32)
    dt = jnp.asarray(np.abs(rng.standard_normal((b, s, h))) * 0.5 + 0.01,
                     jnp.float32)
    a_log = jnp.asarray(-np.exp(rng.standard_normal(h) * 0.3), jnp.float32)
    s0 = jnp.asarray(rng.standard_normal((b, h, n, hd)) * 0.1, jnp.float32)
    out_c, s_c = ssd_chunked(xh, Bc, Cc, dt, a_log, s0, chunk)
    out_r, s_r = ssd_ref(xh, Bc, Cc, dt, a_log, s0)
    np.testing.assert_allclose(np.asarray(out_c, np.float32),
                               np.asarray(out_r, np.float32),
                               atol=0.02, rtol=0.02)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_r),
                               atol=0.02, rtol=0.02)


def test_decode_matches_prefill_dense():
    """Token-by-token decode equals teacher-forced forward (dense family)."""
    import dataclasses
    from repro.configs import ARCHS
    from repro.models import build, init_params
    from repro.models import dense as dense_mod

    cfg = ARCHS["qwen2.5-14b"].reduced()   # exercises qkv_bias too
    api = build(cfg)
    params = init_params(api, jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab)
    full = dense_mod.forward(params, tok, cfg)
    logits, cache = dense_mod.prefill(params, tok[:, :16], cfg)
    cache = jax.tree.map(
        lambda c: jnp.pad(c, ((0, 0), (0, 0), (0, 8), (0, 0), (0, 0))),
        cache)
    np.testing.assert_allclose(np.asarray(logits[:, 0], np.float32),
                               np.asarray(full[:, 15], np.float32),
                               atol=0.1, rtol=0.05)
    for t in range(16, 20):
        logits, cache = dense_mod.decode_step(params, tok[:, t],
                                              jnp.int32(t), cache, cfg)
        np.testing.assert_allclose(np.asarray(logits[:, 0], np.float32),
                                   np.asarray(full[:, t], np.float32),
                                   atol=0.1, rtol=0.05)


def test_decode_matches_prefill_rwkv():
    from repro.configs import ARCHS
    from repro.models import rwkv as rwkv_mod
    from repro.models import build, init_params

    cfg = ARCHS["rwkv6-1.6b"].reduced()
    api = build(cfg)
    params = init_params(api, jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    full = rwkv_mod.forward(params, tok, cfg)
    logits, state = rwkv_mod.prefill(params, tok[:, :8], cfg)
    np.testing.assert_allclose(np.asarray(logits[:, 0], np.float32),
                               np.asarray(full[:, 7], np.float32),
                               atol=0.1, rtol=0.05)
    for t in range(8, 12):
        logits, state = rwkv_mod.decode_step(params, tok[:, t], None,
                                             state, cfg)
        np.testing.assert_allclose(np.asarray(logits[:, 0], np.float32),
                                   np.asarray(full[:, t], np.float32),
                                   atol=0.1, rtol=0.05)
