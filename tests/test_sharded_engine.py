"""Sharded engine coverage: 1-device bit parity with the fused engine,
multi-device balance/dispatch semantics (subprocess, 8 host devices),
exchange-plan parity (allgather / halo / delta walk identical
trajectories, with halo/delta strictly fewer bytes on the wire), the
sharded Pallas score backend (bit-identical to the XLA scatter-add),
shape-keyed program-cache reuse, and adapt()/resize() on the sharded path.

The 1-device parity tests are the backbone of the sharded refactor: a
1-device mesh makes every collective the identity over the same padded
layout the fused engine runs, so ``engine="sharded"`` must reproduce
``engine="fused"`` BIT FOR BIT -- labels, loads, iteration counts,
halting flags.  Any drift means the shared ``make_vertex_update`` math
forked.

Engine/runtime knobs (score backend, label exchange, noise mode) are
passed via ``EngineOptions`` -- the deprecated ``SpinnerConfig`` fields
are covered separately by tests/test_session.py's shim tests.
"""
import numpy as np
import pytest

import jax

from repro.core import (EngineOptions, SpinnerConfig, adapt, engine,
                        generators, metrics, partition, resize)
from repro.core.graph import add_edges
from repro.launch.mesh import make_partition_mesh

from test_distributed import run_devices_subprocess


@pytest.fixture(scope="module")
def ws_graph():
    return generators.watts_strogatz(600, 8, 0.2, seed=11)


@pytest.fixture(scope="module")
def pl_graph():
    return generators.powerlaw_ba(400, 5, seed=12)


@pytest.fixture(scope="module")
def mesh1():
    return make_partition_mesh(1)


class TestOneDeviceBitParity:
    def test_watts_strogatz(self, ws_graph, mesh1):
        cfg = SpinnerConfig(k=6, seed=2, max_iters=60)
        fused = partition(ws_graph, cfg, record_history=False,
                          engine="fused")
        sharded = partition(ws_graph, cfg, record_history=False,
                            engine="sharded", mesh=mesh1)
        np.testing.assert_array_equal(fused.labels, sharded.labels)
        np.testing.assert_array_equal(fused.loads, sharded.loads)
        assert fused.iterations == sharded.iterations
        assert fused.halted == sharded.halted
        assert fused.total_messages == sharded.total_messages

    def test_powerlaw(self, pl_graph, mesh1):
        cfg = SpinnerConfig(k=4, seed=3, max_iters=40)
        fused = partition(pl_graph, cfg, record_history=False,
                          engine="fused")
        sharded = partition(pl_graph, cfg, record_history=False,
                            engine="sharded", mesh=mesh1)
        np.testing.assert_array_equal(fused.labels, sharded.labels)
        assert fused.iterations == sharded.iterations

    def test_default_mesh(self, ws_graph):
        """mesh=None builds a mesh over all local devices."""
        cfg = SpinnerConfig(k=6, seed=7, max_iters=30)
        sharded = partition(ws_graph, cfg, record_history=False,
                            engine="sharded")
        assert sharded.engine == "sharded"
        assert sharded.labels.shape == (ws_graph.num_vertices,)
        if len(jax.devices()) == 1:   # bit parity only on a 1-device mesh
            fused = partition(ws_graph, cfg, record_history=False,
                              engine="fused")
            np.testing.assert_array_equal(fused.labels, sharded.labels)
        else:
            assert metrics.rho(ws_graph, sharded.labels, cfg.k) < cfg.c + 0.1

    def test_auto_with_mesh_selects_sharded(self, ws_graph, mesh1):
        cfg = SpinnerConfig(k=6, seed=2, max_iters=30)
        res = partition(ws_graph, cfg, record_history=False, mesh=mesh1)
        assert res.engine == "sharded"

    def test_hostloop_driver_matches(self, ws_graph, mesh1):
        """Per-iteration host driving == single while_loop dispatch."""
        from repro.core.distributed import run_sharded_hostloop
        cfg = SpinnerConfig(k=6, seed=2, max_iters=60)
        res = partition(ws_graph, cfg, record_history=False,
                        engine="sharded", mesh=mesh1)
        state = run_sharded_hostloop(ws_graph, cfg, mesh1)
        np.testing.assert_array_equal(
            np.asarray(state.labels)[: ws_graph.num_vertices], res.labels)
        assert int(state.iteration) == res.iterations


class TestShardedApi:
    def test_rejects_history(self, ws_graph, mesh1):
        cfg = SpinnerConfig(k=4, seed=0, max_iters=5)
        with pytest.raises(ValueError, match="history"):
            partition(ws_graph, cfg, record_history=True, engine="sharded",
                      mesh=mesh1)

    def test_rejects_callback(self, ws_graph, mesh1):
        cfg = SpinnerConfig(k=4, seed=0, max_iters=5)
        with pytest.raises(ValueError, match="callback"):
            partition(ws_graph, cfg, record_history=False, engine="sharded",
                      mesh=mesh1, callback=lambda it, e: None)

    def test_mesh_with_other_engine_rejected(self, ws_graph, mesh1):
        cfg = SpinnerConfig(k=4, seed=0, max_iters=5)
        with pytest.raises(ValueError, match="mesh"):
            partition(ws_graph, cfg, record_history=False, engine="fused",
                      mesh=mesh1)

    def test_pallas_backend_matches_xla_sharded(self, ws_graph, mesh1):
        """The per-shard tiled Pallas kernel is bit-identical to the XLA
        scatter-add on the sharded engine (integer edge weights make the
        f32 sums exact regardless of accumulation order)."""
        cfg = SpinnerConfig(k=6, seed=2, max_iters=60)
        xla = partition(ws_graph, cfg, record_history=False,
                        engine="sharded", mesh=mesh1)
        pal = partition(ws_graph, cfg, record_history=False,
                        engine="sharded", mesh=mesh1,
                        options=EngineOptions(score_backend="pallas"))
        np.testing.assert_array_equal(xla.labels, pal.labels)
        np.testing.assert_array_equal(xla.loads, pal.loads)
        assert xla.iterations == pal.iterations

    def test_pallas_backend_rides_every_exchange_plan(self, pl_graph,
                                                      mesh1):
        cfg = SpinnerConfig(k=4, seed=3, max_iters=40)
        base = partition(pl_graph, cfg, record_history=False,
                         engine="sharded", mesh=mesh1)
        for mode in ("halo", "delta"):
            opts = EngineOptions(score_backend="pallas", label_exchange=mode)
            res = partition(pl_graph, cfg, record_history=False,
                            engine="sharded", mesh=mesh1, options=opts)
            np.testing.assert_array_equal(base.labels, res.labels)
            assert base.iterations == res.iterations


class TestExchangeModes:
    """halo / delta are pure communication strategies: trajectories must
    be bit-identical to the allgather oracle (1-device here; 2/4/8-device
    parity in the subprocess tests below)."""

    def test_all_modes_bit_identical(self, ws_graph, mesh1):
        cfg = SpinnerConfig(k=6, seed=2, max_iters=60)
        results = {}
        for mode in ("allgather", "halo", "delta"):
            results[mode] = partition(
                ws_graph, cfg, record_history=False, engine="sharded",
                mesh=mesh1, options=EngineOptions(label_exchange=mode))
        for mode in ("halo", "delta"):
            np.testing.assert_array_equal(results["allgather"].labels,
                                          results[mode].labels)
            np.testing.assert_array_equal(results["allgather"].loads,
                                          results[mode].loads)
            assert results["allgather"].iterations == \
                results[mode].iterations
            assert results["allgather"].halted == results[mode].halted

    def test_single_device_exchanges_zero_bytes(self, ws_graph, mesh1):
        cfg = SpinnerConfig(k=6, seed=2, max_iters=60)
        for mode in ("allgather", "halo", "delta"):
            res = partition(ws_graph, cfg, record_history=False,
                            engine="sharded", mesh=mesh1,
                            options=EngineOptions(label_exchange=mode))
            assert res.exchanged_bytes == 0.0, mode

    def test_unknown_mode_rejected(self, ws_graph, mesh1):
        cfg = SpinnerConfig(k=4, seed=0, max_iters=5)
        with pytest.raises(ValueError, match="label_exchange"):
            partition(ws_graph, cfg, record_history=False, engine="sharded",
                      mesh=mesh1,
                      options=EngineOptions(label_exchange="bogus"))

    def test_folded_noise_runs_and_balances(self, ws_graph, mesh1):
        """The O(V/ndev) folded noise stream is a different (still
        deterministic) draw: no bit parity, but quality must hold."""
        cfg = SpinnerConfig(k=6, seed=2, max_iters=80)
        opts = EngineOptions(sharded_noise="folded")
        res = partition(ws_graph, cfg, record_history=False,
                        engine="sharded", mesh=mesh1, options=opts)
        res2 = partition(ws_graph, cfg, record_history=False,
                         engine="sharded", mesh=mesh1, options=opts)
        np.testing.assert_array_equal(res.labels, res2.labels)
        assert res.halted
        assert metrics.rho(ws_graph, res.labels, cfg.k) < cfg.c + 0.1

    def test_bad_noise_mode_rejected(self, ws_graph, mesh1):
        cfg = SpinnerConfig(k=4, seed=0, max_iters=5)
        with pytest.raises(ValueError, match="sharded_noise"):
            partition(ws_graph, cfg, record_history=False, engine="sharded",
                      mesh=mesh1,
                      options=EngineOptions(sharded_noise="bogus"))


class TestProgramCache:
    """Compiled sharded programs are cached globally per (cfg statics,
    backend, mesh, axis, plan signature) -- graph data arrives as traced
    arguments, so seed sweeps and repeat runs never re-trace (the PR 4
    successor of the old per-graph runner caches)."""

    def _program(self, graph, cfg, mesh, axis="data"):
        runner = engine.make_sharded_runner(graph, cfg, mesh, axis)
        return runner.program

    def test_cache_keyed_per_mesh(self, ws_graph):
        cfg = SpinnerConfig(k=6, seed=21, max_iters=17)
        mesh_a = make_partition_mesh(1)
        partition(ws_graph, cfg, record_history=False, engine="sharded",
                  mesh=mesh_a)
        prog = self._program(ws_graph, cfg, mesh_a)
        compiles = prog.compiles()
        assert compiles >= 1
        # meshes compare by value: an identical rebuild hits the same entry
        mesh_b = make_partition_mesh(1)
        partition(ws_graph, cfg, record_history=False, engine="sharded",
                  mesh=mesh_b)
        assert self._program(ws_graph, cfg, mesh_b) is prog
        assert prog.compiles() == compiles
        # a different axis name is a different compiled program
        mesh_c = make_partition_mesh(1, axis="vtx")
        partition(ws_graph, cfg, record_history=False, engine="sharded",
                  mesh=mesh_c, axis="vtx")
        assert self._program(ws_graph, cfg, mesh_c, axis="vtx") is not prog

    def test_seed_sweep_shares_runner(self, ws_graph):
        mesh = make_partition_mesh(1)
        cfg_a = SpinnerConfig(k=6, seed=31, max_iters=19)
        cfg_b = SpinnerConfig(k=6, seed=32, max_iters=19)
        partition(ws_graph, cfg_a, record_history=False, engine="sharded",
                  mesh=mesh)
        prog = self._program(ws_graph, cfg_a, mesh)
        compiles = prog.compiles()
        partition(ws_graph, cfg_b, record_history=False, engine="sharded",
                  mesh=mesh)
        assert self._program(ws_graph, cfg_b, mesh) is prog
        assert prog.compiles() == compiles     # no re-trace for a new seed

    def test_bucket_sweep_shares_program(self, mesh1):
        """Two different graphs in one shape bucket share one compiled
        sharded program (the jit cache does not grow)."""
        cfg = SpinnerConfig(k=6, seed=51, max_iters=11)
        g_a = generators.watts_strogatz(600, 8, 0.2, seed=3)
        g_b = generators.watts_strogatz(610, 8, 0.2, seed=4)
        assert engine.graph_buckets(g_a)[0] == engine.graph_buckets(g_b)[0]
        partition(g_a, cfg, record_history=False, engine="sharded",
                  mesh=mesh1)
        prog = self._program(g_a, cfg, mesh1)
        compiles = prog.compiles()
        partition(g_b, cfg, record_history=False, engine="sharded",
                  mesh=mesh1)
        assert self._program(g_b, cfg, mesh1) is prog
        if engine.graph_buckets(g_a) == engine.graph_buckets(g_b):
            assert prog.compiles() == compiles

    def test_single_dispatch(self, ws_graph, monkeypatch):
        """partition(engine='sharded') invokes the runner exactly once."""
        cfg = SpinnerConfig(k=6, seed=41, max_iters=23)
        calls = {"n": 0}
        real = engine.make_sharded_runner

        def counting(graph, cfg_, mesh, axis="data", score_fn=None, **kw):
            run = real(graph, cfg_, mesh, axis, score_fn, **kw)

            def wrapped(state):
                calls["n"] += 1
                return run(state)
            return wrapped

        monkeypatch.setattr(engine, "make_sharded_runner", counting)
        res = partition(ws_graph, cfg, record_history=False,
                        engine="sharded", mesh=make_partition_mesh(1))
        assert res.iterations > 1
        assert calls["n"] == 1


class TestIncrementalOnShardedEngine:
    @pytest.fixture(scope="class")
    def base(self, pl_graph):
        cfg = SpinnerConfig(k=6, seed=0, max_iters=80)
        return cfg, partition(pl_graph, cfg, record_history=False,
                              engine="fused")

    def test_adapt_parity(self, pl_graph, base, mesh1):
        cfg, res = base
        rng = np.random.default_rng(1)
        g2 = add_edges(pl_graph,
                       rng.integers(0, pl_graph.num_vertices, 30),
                       rng.integers(0, pl_graph.num_vertices, 30),
                       num_vertices=pl_graph.num_vertices + 2)
        fused = adapt(g2, res.labels, cfg, record_history=False,
                      engine="fused")
        sharded = adapt(g2, res.labels, cfg, record_history=False,
                        engine="sharded", mesh=mesh1)
        np.testing.assert_array_equal(fused.labels, sharded.labels)
        assert fused.iterations == sharded.iterations

    def test_resize_parity(self, pl_graph, base, mesh1):
        cfg, res = base
        cfg8 = SpinnerConfig(k=8, seed=5, max_iters=80)
        fused, init_f = resize(pl_graph, res.labels, cfg8, k_old=cfg.k,
                               record_history=False, engine="fused")
        sharded, init_s = resize(pl_graph, res.labels, cfg8, k_old=cfg.k,
                                 record_history=False, engine="sharded",
                                 mesh=mesh1)
        np.testing.assert_array_equal(init_f, init_s)
        np.testing.assert_array_equal(fused.labels, sharded.labels)
        assert fused.iterations == sharded.iterations


# ---------------------------------------------------------------------------
# Multi-device semantics: subprocess with 8 forced host devices
# ---------------------------------------------------------------------------

MULTIDEV_BALANCE = """
import numpy as np
from repro.core import SpinnerConfig, generators, metrics, partition
from repro.launch.mesh import make_partition_mesh

cfg = SpinnerConfig(k=8, seed=1, max_iters=120)
# 4001 vertices: indivisible by every mesh size, so padding is exercised
g = generators.watts_strogatz(4001, 12, 0.2, seed=3)
for ndev in (2, 4, 8):
    mesh = make_partition_mesh(ndev)
    res = partition(g, cfg, record_history=False, engine="sharded",
                    mesh=mesh)
    phi = metrics.phi(g, res.labels)
    rho = metrics.rho(g, res.labels, cfg.k)
    print(f"ndev={ndev} iters={res.iterations} phi={phi:.3f} rho={rho:.3f}")
    assert res.labels.shape == (g.num_vertices,)
    assert res.labels.min() >= 0 and res.labels.max() < cfg.k
    assert res.halted, f"ndev={ndev} did not reach the halting criterion"
    assert phi > 0.3, f"ndev={ndev} failed locality"
    assert rho < cfg.c + 0.05, f"ndev={ndev} failed balance (Eq. 5)"
print("BALANCE OK")
"""


SINGLE_DISPATCH_8DEV = """
import numpy as np
from repro.core import SpinnerConfig, engine, generators, partition
from repro.core.distributed import run_sharded_hostloop
from repro.launch.mesh import make_partition_mesh

g = generators.watts_strogatz(4000, 12, 0.2, seed=3)
cfg = SpinnerConfig(k=8, seed=1, max_iters=120)
mesh = make_partition_mesh()
assert mesh.size == 8

calls = {"n": 0}
real = engine.make_sharded_runner
def counting(graph, cfg_, mesh_, axis="data", score_fn=None, **kw):
    run = real(graph, cfg_, mesh_, axis, score_fn, **kw)
    def wrapped(state):
        calls["n"] += 1
        return run(state)
    return wrapped
engine.make_sharded_runner = counting

res = partition(g, cfg, record_history=False, engine="sharded", mesh=mesh)
assert res.iterations > 5, res.iterations
assert calls["n"] == 1, f"expected ONE while_loop dispatch, saw {calls['n']}"

# the per-iteration hostloop driver pays N dispatches but must walk the
# exact same trajectory (same math, same on-device _halting_update)
state = run_sharded_hostloop(g, cfg, mesh)
np.testing.assert_array_equal(
    np.asarray(state.labels)[: g.num_vertices], res.labels)
assert int(state.iteration) == res.iterations
print(f"iters={res.iterations} dispatches={calls['n']}")
print("SINGLE DISPATCH OK")
"""


EXCHANGE_PARITY_MULTIDEV = """
import numpy as np
from repro.core import EngineOptions, SpinnerConfig, generators, partition
from repro.launch.mesh import make_partition_mesh

# clustered graph with contiguous communities: the range partition keeps
# most neighbors local, so the halo is a small boundary set
g = generators.clustered_graph(8, 500, 0.02, 0.5, seed=5)
cfg = SpinnerConfig(k=8, seed=1, max_iters=120)
for ndev in (2, 4, 8):
    mesh = make_partition_mesh(ndev)
    base = partition(g, cfg, record_history=False, engine="sharded",
                     mesh=mesh,
                     options=EngineOptions(label_exchange="allgather"))
    ag_bpi = base.exchanged_bytes / max(1, base.iterations)
    for mode in ("halo", "delta"):
        res = partition(g, cfg, record_history=False, engine="sharded",
                        mesh=mesh,
                        options=EngineOptions(label_exchange=mode))
        np.testing.assert_array_equal(base.labels, res.labels)
        np.testing.assert_array_equal(base.loads, res.loads)
        assert res.iterations == base.iterations, (mode, ndev)
        assert res.halted == base.halted, (mode, ndev)
        bpi = res.exchanged_bytes / max(1, res.iterations)
        assert 0 < bpi < ag_bpi, (mode, ndev, bpi, ag_bpi)
        print(f"ndev={ndev} {mode}: {bpi:.0f} B/iter vs allgather "
              f"{ag_bpi:.0f} B/iter")
# "auto" on a multi-device mesh resolves to delta -- same trajectory
mesh = make_partition_mesh(8)
base = partition(g, cfg, record_history=False, engine="sharded", mesh=mesh,
                 options=EngineOptions(label_exchange="allgather"))
auto = partition(g, cfg, record_history=False, engine="sharded", mesh=mesh)
np.testing.assert_array_equal(base.labels, auto.labels)
assert auto.exchanged_bytes < base.exchanged_bytes
print("EXCHANGE PARITY OK")
"""


PALLAS_SHARDED_MULTIDEV = """
import numpy as np
from repro.core import EngineOptions, SpinnerConfig, generators, partition
from repro.launch.mesh import make_partition_mesh

g = generators.watts_strogatz(801, 8, 0.2, seed=7)   # 801: padding on 8 dev
cfg = SpinnerConfig(k=8, seed=3, max_iters=40)
mesh = make_partition_mesh()
assert mesh.size == 8
xla = partition(g, cfg, record_history=False, engine="sharded", mesh=mesh)
# halo included: its remapped [local | halo] dst slots feed the per-shard
# tiled CSR, a layout the 1-device tests can never produce (true_halo=0)
for mode in ("allgather", "halo", "delta"):
    opts = EngineOptions(score_backend="pallas", label_exchange=mode)
    pal = partition(g, cfg, record_history=False, engine="sharded",
                    mesh=mesh, options=opts)
    np.testing.assert_array_equal(xla.labels, pal.labels)
    np.testing.assert_array_equal(xla.loads, pal.loads)
    assert xla.iterations == pal.iterations, mode
print("PALLAS SHARDED OK")
"""


FOLDED_NOISE_MULTIDEV = """
import numpy as np
from repro.core import EngineOptions, SpinnerConfig, generators, metrics, \\
    partition
from repro.launch.mesh import make_partition_mesh

g = generators.watts_strogatz(4001, 12, 0.2, seed=3)
cfg = SpinnerConfig(k=8, seed=1, max_iters=120)
mesh = make_partition_mesh()
res = partition(g, cfg, record_history=False, engine="sharded", mesh=mesh,
                options=EngineOptions(sharded_noise="folded"))
assert res.halted
assert metrics.phi(g, res.labels) > 0.3
assert metrics.rho(g, res.labels, cfg.k) < cfg.c + 0.05
print("FOLDED NOISE OK")
"""


@pytest.mark.slow
def test_multidev_balance_2_4_8():
    r = run_devices_subprocess(MULTIDEV_BALANCE)
    assert "BALANCE OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_single_while_loop_dispatch_8dev():
    r = run_devices_subprocess(SINGLE_DISPATCH_8DEV)
    assert "SINGLE DISPATCH OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_exchange_parity_2_4_8dev():
    r = run_devices_subprocess(EXCHANGE_PARITY_MULTIDEV)
    assert "EXCHANGE PARITY OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_pallas_sharded_8dev():
    r = run_devices_subprocess(PALLAS_SHARDED_MULTIDEV)
    assert "PALLAS SHARDED OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_folded_noise_8dev():
    r = run_devices_subprocess(FOLDED_NOISE_MULTIDEV)
    assert "FOLDED NOISE OK" in r.stdout, r.stdout + r.stderr
