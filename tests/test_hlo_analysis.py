"""Unit tests for the trip-count-weighted HLO analyzer (roofline input)."""
from repro.launch.hlo_analysis import analyze, type_bytes

SYNTHETIC_HLO = """
HloModule test

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %d = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%d), replica_groups=[2,2]<=[4], to_apply=%add
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]{1,0}) tuple(%i2, %ar)
}

%cond (p2: (s32[], f32[8,16])) -> pred[] {
  %p2 = (s32[], f32[8,16]{1,0}) parameter(0)
  %j = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%j, %n), direction=LT
}

ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,16]{1,0}) tuple(%zero, %a)
  %w2 = (s32[], f32[8,16]{1,0}) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"12"}}
  %g = f32[8,16]{1,0} all-gather(%a), replica_groups=[2,2]<=[4], dimensions={0}
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%w2), index=1
}
"""


def test_type_bytes():
    assert type_bytes("f32[8,16]{1,0}") == 8 * 16 * 4
    assert type_bytes("bf16[4]") == 8
    assert type_bytes("(s32[], f32[2,2]{1,0})") == 4 + 16
    assert type_bytes("pred[]") == 1


def test_while_trip_count_weighting():
    a = analyze(SYNTHETIC_HLO)
    # dot: 2 * 8*16 out * 16 contraction = 4096 flops, x12 trips
    assert a["dot_flops"] == 12 * 2 * 8 * 16 * 16
    # all-reduce charged 2x operand bytes, x12; all-gather once
    ar = a["collectives"]["all-reduce"]
    ag = a["collectives"]["all-gather"]
    assert ar["count"] == 12 and ar["bytes"] == 12 * 2 * 512
    assert ag["count"] == 1 and ag["bytes"] == 512
    assert a["collective_bytes"] == 12 * 1024 + 512


def test_bytes_by_op_subset_of_total():
    a = analyze(SYNTHETIC_HLO)
    assert 0 < a["tpu_bytes"] <= a["hbm_bytes"]
    assert "dot" in a["bytes_by_op"]
