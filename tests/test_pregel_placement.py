"""Mini-Pregel correctness (vs networkx oracles) + Spinner integration."""
import networkx as nx
import numpy as np
import pytest

from repro.core import SpinnerConfig, generators, metrics, partition, pregel
from repro.core.placement import (cross_shard_mass, place_experts,
                                  place_pipeline_stages)


@pytest.fixture(scope="module")
def g_small():
    return generators.watts_strogatz(500, 8, 0.3, seed=11)


def _to_nx(g):
    G = nx.DiGraph()
    G.add_nodes_from(range(g.num_vertices))
    G.add_edges_from(zip(g.src.tolist(), g.dst.tolist()))
    return G


class TestPregelApps:
    def test_pagerank_matches_networkx(self, g_small):
        labels = np.zeros(g_small.num_vertices, np.int32)
        res = pregel.pagerank(g_small, labels, 1, iters=60)
        nxpr = nx.pagerank(_to_nx(g_small), alpha=0.85, max_iter=200,
                           tol=1e-10)
        mine = res.values / res.values.sum()
        theirs = np.array([nxpr[i] for i in range(g_small.num_vertices)])
        np.testing.assert_allclose(mine, theirs, atol=2e-5)

    def test_sssp_matches_networkx(self, g_small):
        labels = np.zeros(g_small.num_vertices, np.int32)
        res = pregel.sssp(g_small, 0, labels, 1)
        lengths = nx.single_source_shortest_path_length(_to_nx(g_small), 0)
        for v in range(0, g_small.num_vertices, 17):
            expect = lengths.get(v, np.inf)
            assert res.values[v] == expect

    def test_wcc_matches_networkx(self):
        g = generators.clustered_graph(4, 50, 0.2, 0.0, seed=1)
        labels = np.zeros(g.num_vertices, np.int32)
        res = pregel.wcc(g, labels, 1)
        comps = list(nx.connected_components(_to_nx(g).to_undirected()))
        for comp in comps:
            ids = res.values[list(comp)]
            assert len(np.unique(ids)) == 1

    def test_spinner_partition_speeds_up_apps(self, g_small):
        k = 8
        res = partition(g_small, SpinnerConfig(k=k, seed=0),
                        record_history=False)
        hash_labels = (np.arange(g_small.num_vertices) * 2654435761 % k
                       ).astype(np.int32)
        for app in ("pagerank", "sssp", "wcc"):
            cmp = pregel.compare_partitionings(
                g_small, k, hash_labels, res.labels, app,
                **({"iters": 5} if app == "pagerank" else {}))
            assert cmp["speedup_b_over_a"] > 1.2, (app, cmp)
            assert cmp["msg_reduction"] > 0.3, (app, cmp)


class TestPlacement:
    def _choices(self, E=64, K=4, T=8000, G=8, noise=0.25, seed=0):
        rng = np.random.default_rng(seed)
        topic = rng.integers(0, G, T)
        scatter = rng.permutation(E)
        pref = scatter[topic[:, None] * (E // G)
                       + rng.integers(0, E // G, (T, K))]
        rand = rng.integers(0, E, (T, K))
        return np.where(rng.random((T, K)) < noise, rand, pref
                        ).astype(np.int32)

    def test_expert_placement_reduces_traffic(self):
        choices = self._choices()
        labels, stats = place_experts(choices, 64, 8, seed=0)
        assert stats["traffic_reduction"] > 0.3
        assert stats["rho"] < 1.15
        # balanced: each shard gets experts
        assert len(np.unique(labels)) == 8

    def test_incremental_replacement_is_stable(self):
        choices = self._choices(seed=0)
        labels, _ = place_experts(choices, 64, 8, seed=0)
        # Drift = same underlying topic->expert structure, more routing
        # noise.  (A different seed would re-permute the expert groups --
        # a brand-new problem where wholesale movement is the CORRECT
        # response, not an instability.)
        drift = self._choices(seed=0, noise=0.35)
        labels2, stats2 = place_experts(drift, 64, 8, seed=1, prev=labels)
        assert stats2["moved_from_prev"] < 0.5
        assert stats2["cross_after"] <= stats2["cross_before"] + 0.02

    def test_pipeline_stage_assignment(self):
        costs = np.ones(48)
        labels, stats = place_pipeline_stages(costs, 4)
        assert labels.shape == (48,)
        assert stats["stage_cost_max_over_mean"] < 1.5
