"""Incremental (Sec 3.4) and elastic (Sec 3.5) repartitioning."""
import numpy as np
import pytest

from repro.core import (SpinnerConfig, adapt, elastic_relabel, metrics,
                        partition, resize)
from repro.core.graph import add_edges


@pytest.fixture(scope="module")
def base(small_world):
    cfg = SpinnerConfig(k=8, seed=0)
    res = partition(small_world, cfg, record_history=False)
    return small_world, cfg, res


class TestIncremental:
    def test_fewer_iterations_than_scratch(self, base):
        g, cfg, res = base
        rng = np.random.default_rng(3)
        m = int(0.01 * g.num_undirected_edges)
        g2 = add_edges(g, rng.integers(0, g.num_vertices, m),
                       rng.integers(0, g.num_vertices, m))
        res2 = adapt(g2, res.labels, cfg, record_history=False)
        assert res2.iterations < 0.5 * res.iterations
        assert metrics.phi(g2, res2.labels) > 0.8 * metrics.phi(g, res.labels)

    def test_stability(self, base):
        g, cfg, res = base
        rng = np.random.default_rng(4)
        m = int(0.01 * g.num_undirected_edges)
        g2 = add_edges(g, rng.integers(0, g.num_vertices, m),
                       rng.integers(0, g.num_vertices, m))
        res2 = adapt(g2, res.labels, cfg, record_history=False)
        diff = metrics.partitioning_difference(res.labels, res2.labels)
        assert diff < 0.15    # paper: 8-11% move vs 95-98% from scratch

    def test_new_vertices_to_least_loaded(self, base):
        g, cfg, res = base
        v0 = g.num_vertices
        g2 = add_edges(g, [v0, v0 + 1], [0, 1], num_vertices=v0 + 2)
        res2 = adapt(g2, res.labels, cfg, record_history=False)
        assert res2.labels.shape[0] == v0 + 2
        assert metrics.rho(g2, res2.labels, cfg.k) < cfg.c + 0.05


class TestElastic:
    def test_grow_migration_probability(self):
        prev = np.zeros(200_000, np.int32)
        out = elastic_relabel(prev, k_old=8, k_new=10, seed=0)
        moved = (out != prev).mean()
        # Eq. 10: p = n/(k+n) = 2/10
        assert abs(moved - 0.2) < 0.01
        assert set(np.unique(out[out != 0])) <= {8, 9}

    def test_shrink_evicts_only_removed(self):
        rng = np.random.default_rng(0)
        prev = rng.integers(0, 8, 100_000).astype(np.int32)
        out = elastic_relabel(prev, k_old=8, k_new=6, seed=0)
        assert out.max() < 6
        stayed = prev < 6
        np.testing.assert_array_equal(out[stayed], prev[stayed])

    def test_resize_recovers_quality(self, base):
        g, cfg, res = base
        cfg10 = SpinnerConfig(k=10, seed=5)
        res2, init = resize(g, res.labels, cfg10, k_old=8,
                            record_history=False)
        assert metrics.rho(g, res2.labels, 10) < cfg10.c + 0.05
        assert metrics.phi(g, res2.labels) > 0.75 * metrics.phi(g, res.labels)
        # elastic start moves far fewer vertices than a random restart would
        diff = metrics.partitioning_difference(res.labels, res2.labels)
        assert diff < 0.55
