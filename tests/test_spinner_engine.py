"""Device-resident engine parity: fused/chunked runners vs the host oracle.

The fused ``lax.while_loop`` runner and the chunked ``lax.scan`` runner
share the exact iteration math with the legacy host loop (see
``engine.make_iteration``), so for a fixed seed all three must produce the
same label trajectory, iteration count, and loads -- for both the XLA
scatter-add and the Pallas kernel score backends.
"""
import numpy as np
import pytest

from repro.core import (EngineOptions, SpinnerConfig, adapt, engine,
                        generators, metrics, partition, prepare_init, resize)
from repro.core.graph import add_edges

BACKENDS = ["xla", "pallas"]


@pytest.fixture(scope="module")
def ws_graph():
    return generators.watts_strogatz(600, 8, 0.2, seed=11)


@pytest.fixture(scope="module")
def pl_graph():
    return generators.powerlaw_ba(400, 5, seed=12)


class TestFusedParity:
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_watts_strogatz(self, ws_graph, backend):
        cfg = SpinnerConfig(k=6, seed=2, max_iters=60)
        opts = EngineOptions(score_backend=backend)
        host = partition(ws_graph, cfg, record_history=False, engine="host",
                         options=opts)
        fused = partition(ws_graph, cfg, record_history=False,
                          engine="fused", options=opts)
        np.testing.assert_array_equal(host.labels, fused.labels)
        np.testing.assert_allclose(host.loads, fused.loads, rtol=1e-5)
        assert host.iterations == fused.iterations
        assert host.halted == fused.halted
        assert host.total_messages == pytest.approx(fused.total_messages,
                                                    rel=1e-5)

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_powerlaw(self, pl_graph, backend):
        cfg = SpinnerConfig(k=4, seed=3, max_iters=40)
        opts = EngineOptions(score_backend=backend)
        host = partition(pl_graph, cfg, record_history=False, engine="host",
                         options=opts)
        fused = partition(pl_graph, cfg, record_history=False,
                          engine="fused", options=opts)
        np.testing.assert_array_equal(host.labels, fused.labels)
        assert host.iterations == fused.iterations
        # quality parity is implied by label equality; spell it out anyway
        assert metrics.phi(pl_graph, fused.labels) == pytest.approx(
            metrics.phi(pl_graph, host.labels))
        assert metrics.rho(pl_graph, fused.labels, cfg.k) == pytest.approx(
            metrics.rho(pl_graph, host.labels, cfg.k))


class TestChunkedParity:
    def test_labels_and_history(self, ws_graph):
        cfg = SpinnerConfig(k=6, seed=2, max_iters=60)
        host = partition(ws_graph, cfg, record_history=True, engine="host")
        chunk = partition(ws_graph, cfg, record_history=True,
                          engine="chunked", chunk_size=16)
        np.testing.assert_array_equal(host.labels, chunk.labels)
        assert host.iterations == chunk.iterations
        assert len(chunk.history) == chunk.iterations
        for h, c in zip(host.history, chunk.history):
            assert h["iteration"] == c["iteration"]
            assert h["migrations"] == c["migrations"]
            # device history is f32, host metrics are f64
            assert h["phi"] == pytest.approx(c["phi"], abs=1e-5)
            assert h["rho"] == pytest.approx(c["rho"], rel=1e-4)
            assert h["score"] == pytest.approx(c["score"], rel=1e-4,
                                               abs=1e-2)

    def test_chunk_size_does_not_change_result(self, ws_graph):
        cfg = SpinnerConfig(k=6, seed=2, max_iters=60)
        a = partition(ws_graph, cfg, record_history=True,
                      engine="chunked", chunk_size=7)
        b = partition(ws_graph, cfg, record_history=True,
                      engine="chunked", chunk_size=64)
        np.testing.assert_array_equal(a.labels, b.labels)
        assert a.iterations == b.iterations
        assert len(a.history) == len(b.history)

    def test_dispatch_budget(self, ws_graph, monkeypatch):
        """Chunked runner issues at most ceil(max_iters/chunk_size) scans."""
        # unique cfg so the compiled-runner cache can't satisfy this run
        # before the monkeypatched builder gets a chance to count
        cfg = SpinnerConfig(k=6, seed=9, max_iters=48)
        calls = {"n": 0}
        real = engine.make_chunked_runner

        def counting(graph, cfg_, chunk_size=engine.DEFAULT_CHUNK,
                     score_fn=None, **kw):
            run = real(graph, cfg_, chunk_size, score_fn, **kw)

            def wrapped(state):
                calls["n"] += 1
                return run(state)
            return wrapped

        monkeypatch.setattr(engine, "make_chunked_runner", counting)
        res = partition(ws_graph, cfg, record_history=True,
                        engine="chunked", chunk_size=16)
        assert calls["n"] <= -(-cfg.max_iters // 16)
        assert calls["n"] == -(-res.iterations // 16)

    def test_runner_cache_reuse(self, ws_graph):
        """Same cfg statics -> one compiled program, shared seed-to-seed
        and run-to-run (the PR 4 global program cache: graph data are
        traced arguments, so the jit cache never grows for a repeat)."""
        cfg = SpinnerConfig(k=6, seed=13, max_iters=20)
        a = partition(ws_graph, cfg, record_history=False, engine="fused")
        prog = engine.make_fused_runner(ws_graph, cfg).program
        compiles = prog.compiles()
        assert compiles >= 1
        b = partition(ws_graph, cfg, record_history=False, engine="fused")
        assert engine.make_fused_runner(ws_graph, cfg).program is prog
        assert prog.compiles() == compiles
        # a different seed reuses the same compiled program
        cfg2 = SpinnerConfig(k=6, seed=14, max_iters=20)
        partition(ws_graph, cfg2, record_history=False, engine="fused")
        assert engine.make_fused_runner(ws_graph, cfg2).program is prog
        assert prog.compiles() == compiles
        np.testing.assert_array_equal(a.labels, b.labels)

    def test_callback_sees_every_iteration(self, ws_graph):
        cfg = SpinnerConfig(k=6, seed=2, max_iters=40)
        seen = []
        res = partition(ws_graph, cfg, record_history=True,
                        engine="chunked", chunk_size=8,
                        callback=lambda it, entry: seen.append(it))
        assert seen == list(range(1, res.iterations + 1))

    def test_no_history_path_matches(self, ws_graph):
        """record_history=False skips the phi trace but not the math."""
        cfg = SpinnerConfig(k=6, seed=2, max_iters=60)
        full = partition(ws_graph, cfg, record_history=True,
                         engine="chunked", chunk_size=16)
        bare = partition(ws_graph, cfg, record_history=False,
                         engine="chunked", chunk_size=16)
        np.testing.assert_array_equal(full.labels, bare.labels)
        assert bare.iterations == full.iterations
        assert bare.history == []


class TestAutoEngine:
    def test_auto_routes_by_history(self, ws_graph):
        cfg = SpinnerConfig(k=6, seed=2, max_iters=40)
        assert partition(ws_graph, cfg,
                         record_history=False).engine == "fused"
        assert partition(ws_graph, cfg,
                         record_history=True).engine == "chunked"

    def test_unknown_engine_raises(self, ws_graph):
        cfg = SpinnerConfig(k=4, seed=0, max_iters=5)
        with pytest.raises(ValueError, match="unknown engine"):
            partition(ws_graph, cfg, engine="turbo")

    def test_fused_rejects_callback(self, ws_graph):
        cfg = SpinnerConfig(k=4, seed=0, max_iters=5)
        with pytest.raises(ValueError, match="callback"):
            partition(ws_graph, cfg, record_history=False, engine="fused",
                      callback=lambda it, e: None)

    def test_fused_rejects_explicit_history(self, ws_graph):
        cfg = SpinnerConfig(k=4, seed=0, max_iters=5)
        with pytest.raises(ValueError, match="history"):
            partition(ws_graph, cfg, record_history=True, engine="fused")
        # default (None) means "no history where the engine can't": fine
        res = partition(ws_graph, cfg, engine="fused")
        assert res.history == []

    def test_unknown_backend_raises(self, ws_graph):
        cfg = SpinnerConfig(k=4, seed=0, max_iters=5)
        with pytest.raises(ValueError, match="unknown score backend"):
            partition(ws_graph, cfg, record_history=False, engine="fused",
                      options=EngineOptions(score_backend="nonexistent"))


class TestIncrementalOnFusedEngine:
    @pytest.fixture(scope="class")
    def base(self, pl_graph):
        cfg = SpinnerConfig(k=6, seed=0, max_iters=80)
        return cfg, partition(pl_graph, cfg, record_history=False,
                              engine="host")

    def test_adapt_parity(self, pl_graph, base):
        cfg, res = base
        rng = np.random.default_rng(1)
        # includes brand-new vertices so the -1 least-loaded fill is covered
        g2 = add_edges(pl_graph,
                       rng.integers(0, pl_graph.num_vertices, 30),
                       rng.integers(0, pl_graph.num_vertices, 30),
                       num_vertices=pl_graph.num_vertices + 2)
        host = adapt(g2, res.labels, cfg, record_history=False,
                     engine="host")
        fused = adapt(g2, res.labels, cfg, record_history=False,
                      engine="fused")
        np.testing.assert_array_equal(host.labels, fused.labels)
        assert host.iterations == fused.iterations

    def test_resize_parity(self, pl_graph, base):
        cfg, res = base
        cfg8 = SpinnerConfig(k=8, seed=5, max_iters=80)
        host, init_h = resize(pl_graph, res.labels, cfg8, k_old=cfg.k,
                              record_history=False, engine="host")
        fused, init_f = resize(pl_graph, res.labels, cfg8, k_old=cfg.k,
                               record_history=False, engine="fused")
        np.testing.assert_array_equal(init_h, init_f)
        np.testing.assert_array_equal(host.labels, fused.labels)
        assert host.iterations == fused.iterations


class TestEngineInternals:
    def test_run_fused_state_matches_partition(self, ws_graph):
        cfg = SpinnerConfig(k=6, seed=2, max_iters=60)
        labels, loads, key = prepare_init(ws_graph, cfg)
        state = engine.run_fused(ws_graph, cfg, labels, loads, key)
        res = partition(ws_graph, cfg, record_history=False, engine="fused")
        np.testing.assert_array_equal(np.asarray(state.labels), res.labels)
        assert int(state.iteration) == res.iterations
        assert bool(state.halted) == res.halted

    def test_fused_respects_max_iters(self, ws_graph):
        cfg = SpinnerConfig(k=6, seed=2, max_iters=3)
        res = partition(ws_graph, cfg, record_history=False, engine="fused")
        assert res.iterations == 3
        assert not res.halted
