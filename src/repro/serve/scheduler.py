"""PartitionScheduler: many PartitionSessions behind one request queue.

Spinner frames partitioning as a continuously running cloud service
(§ dynamicity); this module is that serving tier.  One scheduler holds
many independent tenants (graph + ``PartitionSession``) and drains a
stream of ``partition`` / ``edge_updates`` / ``adapt`` / ``resize``
requests through three performance layers:

1. **Delta coalescing** (``core.delta.coalesce_updates``): each dispatch
   round pops a tenant's leading run of queued edge-update requests (plus
   at most one trailing plain ``adapt``) as ONE window; the coalesced
   delta folds through a single ``apply_delta`` scatter and one
   reconvergence.  ``coalesce_updates`` preserves Eq. 3's
   direction-canonicalized pair weights exactly, so every ticket in the
   window resolves to the same bit-identical result a one-by-one replay
   would reach.

2. **Same-bucket batched execution** (``engine.run_batched``): windows
   from tenants whose padded (V, E) buckets, config statics and backend
   signatures match (``engine.batch_signature``) are stacked along a
   leading batch dimension and run as ONE ``vmap``'d while_loop dispatch.
   Per-element freezing keeps every tenant's trajectory bit-identical to
   its own unbatched program; ineligible windows (``partition``,
   ``resize``, rebinds, frontier adapts, sharded/chunked/host/Pallas
   sessions) fall back to serial dispatch through the session's own
   entry points, so correctness never depends on batch eligibility.

3. **Policy-driven prefetch**: between dispatching a batch and blocking
   on its results (JAX dispatch is asynchronous), the scheduler runs its
   policies off the critical path -- :class:`StagePrefetch` double-buffers
   the next queued snapshot rebind (PR 5's ``stage()`` as a policy) and
   :class:`KSweepPrecompile` speculatively compiles fused programs for
   queued ``resize`` targets by invoking them on a pre-halted state
   (full compile, ~zero execution).

Dispatch order is priority-weighted staleness (age of the tenant's
oldest queued request x tenant priority), with an optional hard
``preempt_staleness`` SLO that jumps an aging tenant to the front of
the round regardless of priority.

::

    from repro.serve import PartitionScheduler

    sched = PartitionScheduler(max_batch=8)
    sched.add_tenant("social", g1, SpinnerConfig(k=16), partition=True)
    sched.add_tenant("web", g2, SpinnerConfig(k=16), partition=True)
    t = sched.submit("social", "edge_updates", edge_updates=(src, dst))
    sched.drain()
    assert t.done and t.result.halted
    print(sched.stats()["coalescing_factor"])
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Dict, List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import delta as _delta
from repro.core import engine as _engine
from repro.core.graph import Graph
from repro.core.session import PartitionSession
from repro.core.spinner import SpinnerConfig

from .requests import KINDS, Tenant, Ticket


class _Work(NamedTuple):
    """A prepared batchable window: the session's work item + its
    stackability signature."""

    state: object
    bind: object
    cfg: object
    opts: object
    sig: tuple


class StagePrefetch:
    """Warm the NEXT queued snapshot rebind off the critical path.

    When a tenant's head-of-queue request is an ``adapt(new_graph=...)``,
    stage the snapshot now: ``PartitionSession.stage`` issues the padded
    view's host->device uploads asynchronously, so they overlap the
    in-flight batch and the eventual serial dispatch starts from
    device-resident arrays (PR 5's double buffering, scheduler-driven)."""

    name = "stage_prefetch"

    def __init__(self) -> None:
        self.staged = 0

    def run(self, sched: "PartitionScheduler") -> None:
        for t in sched.tenants.values():
            if not t.queue:
                continue
            tk = t.queue[0]
            g = tk.payload.get("new_graph")
            if g is None or tk.payload.get("_staged"):
                continue
            t.session.stage(g)
            tk.payload["_staged"] = True
            self.staged += 1
            return                    # one staging per round

    def stats(self) -> dict:
        return {"staged": self.staged}


class KSweepPrecompile:
    """Speculatively compile fused programs for queued ``resize`` targets.

    Scans the queues for resize requests and, once per (tenant, k),
    builds the new-k program and invokes it with a pre-halted state: the
    while_loop's cond is False on entry, so the call costs a full XLA
    compile and essentially zero execution.  By the time the resize
    reaches the head of the queue its dispatch is compile-free -- the
    k-sweep prefetch follow-on as a scheduler policy."""

    name = "ksweep_precompile"

    def __init__(self) -> None:
        self.warmed: set = set()
        self.compiled = 0

    def run(self, sched: "PartitionScheduler") -> None:
        for t in sched.tenants.values():
            for tk in t.queue:
                if tk.kind != "resize":
                    continue
                key = (t.name, tk.payload["k"])
                if key in self.warmed:
                    continue
                self.warmed.add(key)
                self.compiled += self._warm(sched, t, tk.payload["k"])
                return                # one warm compile per round
        return

    def _warm(self, sched: "PartitionScheduler", t: Tenant,
              k_new: int) -> int:
        sess = t.session
        if not sess.batchable():      # fused single-device programs only
            return 0
        graph = sess._graph           # base graph: shapes only
        cfg_new = dataclasses.replace(sess.cfg, k=k_new)
        opts_t = _engine._autotuned(graph, cfg_new, sess.options)
        bind, padded = _engine._single_bind(graph, cfg_new, opts_t)
        prog = _engine._fused_program(cfg_new, opts_t)
        sched._track(prog)
        sess._track(prog)
        before = prog.compiles()
        state = _engine.init_state(
            jnp.zeros((padded.num_vertices,), jnp.int32),
            jnp.zeros((k_new,), jnp.float32),
            jax.random.PRNGKey(0))._replace(halted=jnp.asarray(True))
        prog.run(state, bind)         # cond False on entry: compile only
        return prog.compiles() - before

    def stats(self) -> dict:
        return {"warmed": len(self.warmed), "compiled": self.compiled}


def default_policies() -> tuple:
    return (StagePrefetch(), KSweepPrecompile())


def default_batch_min() -> int:
    """Smallest same-bucket group worth stacking on THIS host.

    A vmapped while_loop iteration does ``nb`` lanes of work and runs
    until the slowest lane halts, so stacking only pays where the lanes
    execute in parallel -- an accelerator, or a multicore CPU host.  On
    a single-core CPU host it is strictly extra work, so the scheduler
    defaults to delta coalescing + serial dispatch there; pass
    ``batch_min`` explicitly to force either path.
    """
    try:
        cores = os.cpu_count() or 1
    except Exception:
        cores = 1
    if cores > 1:
        return 2
    try:
        platform = jax.devices()[0].platform
    except Exception:
        platform = "cpu"
    return 2 if platform != "cpu" else 10 ** 9


class PartitionScheduler:
    """Multi-tenant serving loop over :class:`PartitionSession`\\ s.

    ``max_batch`` bounds how many tenant windows one round dispatches
    (and therefore the widest stacked batch); ``batch_min`` is the
    smallest group that takes the batched runner -- below it a window
    runs through the session's own (already warm) unbatched program,
    which avoids tracing a batch-of-1 program for lone tenants (tests
    set ``batch_min=1`` to force the batch-of-1 path).  It defaults to
    :func:`default_batch_min`: 2 where the host has parallel lanes to
    run stacked work (multicore / accelerator), effectively-off on a
    single-core CPU host where stacking is strictly extra work.
    ``clock`` is injectable for deterministic tests.
    """

    def __init__(self, *, max_batch: int = 8,
                 batch_min: Optional[int] = None,
                 preempt_staleness: Optional[float] = None,
                 policies: Optional[Sequence] = None,
                 deployment=None,
                 clock: Callable[[], float] = time.monotonic) -> None:
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.max_batch = max_batch
        # cluster deployment mode (repro.cluster.deploy.ClusterDeployment):
        # tenants pinned to the deployment mesh, snapshotted on commit,
        # recovered + retried once on dispatch failure
        self.deployment = deployment
        self._recoveries = 0
        self.batch_min = max(1, default_batch_min() if batch_min is None
                             else batch_min)
        self.preempt_staleness = preempt_staleness
        self.policies = tuple(default_policies() if policies is None
                              else policies)
        self.clock = clock
        self.tenants: Dict[str, Tenant] = {}
        self._seq = 0
        self._programs: dict = {}     # id(program) -> (program, base)
        self._mark = 0
        self._submitted = 0
        self._completed = 0
        self._errors = 0
        self._eu_folded = 0           # edge-update tickets folded ...
        self._delta_dispatches = 0    # ... into this many dispatches
        self._batched_dispatches = 0
        self._serial_dispatches = 0
        self._occupancy: List[float] = []
        self._batch_sizes: List[int] = []
        self._latencies: Dict[str, List[float]] = {}
        self._policy_errors: List[str] = []
        self._first_arrival: Optional[float] = None
        self._last_finish: Optional[float] = None

    # -- tenant lifecycle --------------------------------------------------

    def add_tenant(self, name: str, graph: Graph, cfg: SpinnerConfig,
                   options: Optional[_engine.EngineOptions] = None, *,
                   priority: float = 1.0,
                   partition: bool = False) -> Tenant:
        """Admit a tenant.  ``partition=True`` runs the cold first
        partition synchronously on admission (upload + compile paid
        here, not inside the serving loop); otherwise the tenant's
        first request must be ``partition``."""
        if name in self.tenants:
            raise ValueError(f"tenant {name!r} already registered")
        if self.deployment is not None:
            options = self.deployment.admit(name, options)
        t = Tenant(name=name,
                   session=PartitionSession(graph, cfg, options),
                   priority=float(priority))
        self.tenants[name] = t
        if partition:
            t.session.partition(record_history=False)
        return t

    def remove_tenant(self, name: str) -> None:
        """Retire a tenant: fail its queued tickets, close its session
        (idempotent), fold its compile history into the scheduler's."""
        t = self.tenants.pop(name)
        now = self.clock()
        err = RuntimeError(f"tenant {name!r} retired with requests queued")
        while t.queue:
            tk = t.queue.popleft()
            tk.done, tk.error, tk.finish = True, err, now
            self._errors += 1
        # keep compile accounting stable across retirement
        for pid, (prog, base) in t.session._programs.items():
            have = self._programs.get(pid)
            if have is None or have[1] > base:
                self._programs[pid] = (prog, base)
        t.session.close()

    # -- admission ---------------------------------------------------------

    def submit(self, tenant: str, kind: str, *, edge_updates=None,
               new_graph: Optional[Graph] = None, k: Optional[int] = None,
               frontier: bool = False,
               arrival: Optional[float] = None) -> Ticket:
        """Enqueue one request; returns its :class:`Ticket` (resolved in
        place by a later ``step``/``drain``)."""
        if kind not in KINDS:
            raise ValueError(f"unknown request kind {kind!r}; "
                             f"available: {', '.join(KINDS)}")
        t = self.tenants[tenant]
        payload: dict = {}
        if kind == "edge_updates":
            if edge_updates is None:
                raise ValueError("edge_updates request needs "
                                 "edge_updates=(src, dst)")
            payload["edge_updates"] = edge_updates
        elif kind == "resize":
            if k is None:
                raise ValueError("resize request needs k=")
            payload["k"] = int(k)
        elif kind == "adapt":
            if new_graph is not None:
                payload["new_graph"] = new_graph
            if frontier:
                payload["frontier"] = True
        now = self.clock() if arrival is None else arrival
        tk = Ticket(tenant=tenant, kind=kind, seq=self._seq, arrival=now,
                    payload=payload)
        self._seq += 1
        self._submitted += 1
        if self._first_arrival is None:
            self._first_arrival = now
        t.queue.append(tk)
        return tk

    # -- the dispatch loop -------------------------------------------------

    def step(self) -> int:
        """One dispatch round; returns the number of requests completed.

        Picks up to ``max_batch`` tenant windows by priority-weighted
        staleness, groups the batchable ones by stack signature, runs
        each group as one batched device dispatch (serial fallbacks and
        sub-``batch_min`` groups through the sessions' own programs),
        runs the prefetch policies while the batch is in flight, then
        materializes results and resolves every ticket in each window.
        """
        now = self.clock()
        ready = [t for t in self.tenants.values() if t.queue]
        if not ready:
            return 0
        ready.sort(key=lambda t: self._rank(t, now))
        take = ready[: self.max_batch]

        groups: Dict[tuple, list] = {}
        serial: list = []
        completed = 0
        for t in take:
            window = t.next_window()
            n_eu = sum(1 for tk in window if tk.kind == "edge_updates")
            if n_eu:
                self._eu_folded += n_eu
                self._delta_dispatches += 1
            try:
                work = self._prepare(t, window)
            except Exception as e:              # bad request: fail tickets
                completed += self._fail(t, window, e)
                continue
            if work is None:
                serial.append((t, window))
            else:
                groups.setdefault(work.sig, []).append((t, window, work))

        pending: list = []   # (tenant, window, out_state)
        for group in groups.values():
            if len(group) < self.batch_min:
                for t, window, work in group:
                    prog = _engine._fused_program(work.cfg, work.opts)
                    self._track(prog)
                    t.session._track(prog)
                    t.serial_dispatches += 1
                    self._serial_dispatches += 1
                    pending.append((t, window, prog.run(work.state,
                                                        work.bind)))
                continue
            items = [(w.state, w.bind) for _, _, w in group]

            def on_program(prog, group=group):
                self._track(prog)
                for t, _, _ in group:
                    t.session._track(prog)

            outs = _engine.run_batched(items, group[0][2].cfg,
                                       group[0][2].opts,
                                       on_program=on_program)
            self._batched_dispatches += 1
            self._occupancy.append(
                len(group) / _engine.batch_bucket(len(group)))
            self._batch_sizes.append(len(group))
            for (t, window, _w), out in zip(group, outs):
                t.batched_dispatches += 1
                pending.append((t, window, out))

        # the batch is dispatched but not yet materialized: prefetch now
        self._run_policies()

        for t, window, out in pending:
            try:
                completed += self._finish(t, window,
                                          t.session.commit_adapt(out))
            except Exception as e:
                completed += self._resolve_failure(t, window, e)
        for t, window in serial:
            try:
                completed += self._finish(t, window,
                                          self._dispatch_serial(t, window))
            except Exception as e:
                completed += self._resolve_failure(t, window, e)
        return completed

    def drain(self, max_rounds: Optional[int] = None) -> int:
        """Run rounds until every queue is empty; returns completions."""
        completed = 0
        rounds = 0
        while any(t.queue for t in self.tenants.values()):
            if max_rounds is not None and rounds >= max_rounds:
                break
            completed += self.step()
            rounds += 1
        return completed

    # -- internals ---------------------------------------------------------

    def _rank(self, t: Tenant, now: float) -> tuple:
        """Sort key (ascending): SLO-preempted first, then priority x
        staleness, then raw priority, then admission order."""
        stale = t.staleness(now)
        preempt = (self.preempt_staleness is not None
                   and stale >= self.preempt_staleness)
        return (not preempt, -(t.priority * stale), -t.priority,
                t.queue[0].seq)

    def _prepare(self, t: Tenant, window: List[Ticket]
                 ) -> Optional[_Work]:
        """A window's batched work item, or None for serial dispatch."""
        last = window[-1]
        if last.kind in ("partition", "resize"):
            return None
        if last.payload.get("new_graph") is not None \
                or last.payload.get("frontier"):
            return None
        if not t.session.batchable():
            return None
        eu = [tk.payload["edge_updates"] for tk in window
              if tk.kind == "edge_updates"]
        updates = _delta.coalesce_updates(eu) if eu else None
        parts = t.session.adapt_parts(edge_updates=updates)
        if parts is None:
            return None
        state, bind, cfg, opts = parts
        return _Work(state, bind, cfg, opts,
                     _engine.batch_signature(cfg, opts, bind))

    def _dispatch_serial(self, t: Tenant, window: List[Ticket]):
        """Run a non-batchable window through the session's own entry
        points (still coalesced: one adapt per window)."""
        sess = t.session
        last = window[-1]
        t.serial_dispatches += 1
        self._serial_dispatches += 1
        if last.kind == "partition":
            return sess.partition(record_history=False)
        if last.kind == "resize":
            return sess.resize(last.payload["k"], record_history=False)
        kw: dict = {"record_history": False}
        eu = [tk.payload["edge_updates"] for tk in window
              if tk.kind == "edge_updates"]
        if eu:
            kw["edge_updates"] = _delta.coalesce_updates(eu)
        if last.kind == "adapt":
            if last.payload.get("new_graph") is not None:
                kw["new_graph"] = last.payload["new_graph"]
            if last.payload.get("frontier"):
                kw["frontier"] = True
        return sess.adapt(**kw)

    def _run_policies(self) -> None:
        for p in self.policies:
            try:
                p.run(self)
            except Exception as e:    # prefetch must never fail serving
                self._policy_errors.append(
                    f"{getattr(p, 'name', type(p).__name__)}: {e!r}")

    def _finish(self, t: Tenant, window: List[Ticket], res) -> int:
        now = self.clock()
        for tk in window:
            tk.done, tk.result, tk.finish = True, res, now
            tk.coalesced = len(window)
            self._latencies.setdefault(tk.kind, []).append(tk.latency())
        t.completed += len(window)
        self._completed += len(window)
        self._last_finish = now
        if self.deployment is not None:
            self.deployment.after_commit(t.name, t.session)
        return len(window)

    def _resolve_failure(self, t: Tenant, window: List[Ticket],
                         err: BaseException) -> int:
        """A dispatch raised: under a cluster deployment, recover the
        tenant from its newest snapshot and retry the window ONCE;
        otherwise (or when recovery itself cannot proceed) fail the
        tickets.  The recovery graph is the failed session's
        materialized logical graph -- base plus every accepted delta
        batch, INCLUDING this window's (``adapt_parts``/``adapt``
        append to the pending log before dispatching) -- so the retry
        is a plain reconvergence: re-applying the window's
        edge-updates would double-count them.  A resize committed after
        the newest snapshot is rolled forward by ``recover`` (skipped
        when the retried window is itself a resize, which sets k)."""
        if self.deployment is None:
            return self._fail(t, window, err)
        try:
            graph = t.session.graph       # materializes the delta log
            info = self.deployment.recover(
                t.name, graph, options=t.session.options,
                roll_forward_k=window[-1].kind != "resize")
            if info is None:              # no snapshot yet: fail normally
                return self._fail(t, window, err)
            old, t.session = t.session, info.session
            old.close()
            self._recoveries += 1
            last = window[-1]
            t.serial_dispatches += 1
            self._serial_dispatches += 1
            if last.kind == "partition":
                res = t.session.partition(record_history=False)
            elif last.kind == "resize":
                res = t.session.resize(last.payload["k"],
                                       record_history=False)
            else:
                kw: dict = {"record_history": False}
                if last.payload.get("new_graph") is not None:
                    kw["new_graph"] = last.payload["new_graph"]
                res = t.session.adapt(**kw)
            return self._finish(t, window, res)
        except Exception as e:
            return self._fail(t, window, e)

    def _fail(self, t: Tenant, window: List[Ticket],
              err: BaseException) -> int:
        now = self.clock()
        for tk in window:
            tk.done, tk.error, tk.finish = True, err, now
        t.failed += len(window)
        self._errors += len(window)
        return len(window)

    # -- compile tracking / stats -----------------------------------------

    def _track(self, program) -> None:
        if program is not None and id(program) not in self._programs:
            self._programs[id(program)] = (program, program.compiles())

    @property
    def compiles(self) -> int:
        """Compilations this scheduler's serving caused: union of its own
        tracked programs and every live session's, earliest-acquisition
        base, each program counted once however many tenants share it."""
        progs = dict(self._programs)
        for t in self.tenants.values():
            for pid, (prog, base) in t.session._programs.items():
                have = progs.get(pid)
                if have is None or have[1] > base:
                    progs[pid] = (prog, base)
        return sum(max(0, prog.compiles() - base)
                   for prog, base in progs.values())

    def mark(self) -> None:
        """Snapshot the compile counter; ``stats()["compiles_since_mark"]``
        then measures steady-state compiles (0 for a warm fleet)."""
        self._mark = self.compiles

    def stats(self) -> dict:
        """Serving metrics: latency percentiles, throughput, coalescing
        factor, batch occupancy, compile counters, per-policy stats."""

        def pct(xs: List[float], q: float) -> float:
            if not xs:
                return float("nan")
            ys = sorted(xs)
            return ys[min(int(q * len(ys)), len(ys) - 1)]

        def summary(xs: List[float]) -> dict:
            return {"p50": pct(xs, 0.50), "p99": pct(xs, 0.99),
                    "mean": float(np.mean(xs)) if xs else float("nan"),
                    "count": len(xs)}

        lat_all = [x for xs in self._latencies.values() for x in xs]
        lat_adapt = (self._latencies.get("edge_updates", [])
                     + self._latencies.get("adapt", []))
        span = ((self._last_finish - self._first_arrival)
                if self._last_finish is not None
                and self._first_arrival is not None else 0.0)
        return {
            "tenants": len(self.tenants),
            "submitted": self._submitted,
            "completed": self._completed,
            "errors": self._errors,
            "queued": sum(len(t.queue) for t in self.tenants.values()),
            "throughput_rps": (self._completed / span if span > 0
                               else float("nan")),
            "latency": summary(lat_all),
            "adapt_latency": summary(lat_adapt),
            "coalescing_factor": (self._eu_folded
                                  / max(self._delta_dispatches, 1)),
            "batched_dispatches": self._batched_dispatches,
            "serial_dispatches": self._serial_dispatches,
            "batch_occupancy": (float(np.mean(self._occupancy))
                                if self._occupancy else 0.0),
            "mean_batch_size": (float(np.mean(self._batch_sizes))
                                if self._batch_sizes else 0.0),
            "compiles": self.compiles,
            "compiles_since_mark": self.compiles - self._mark,
            "policies": {getattr(p, "name", type(p).__name__):
                         (p.stats() if hasattr(p, "stats") else {})
                         for p in self.policies},
            "policy_errors": list(self._policy_errors),
            "recoveries": self._recoveries,
            "deployment": (self.deployment.stats()
                           if self.deployment is not None else None),
        }
