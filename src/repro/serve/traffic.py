"""Synthetic open-loop traffic for the serving tier (bench + tests).

Models the workload Spinner positions itself for (§ dynamicity): many
independent graphs served from one process, each emitting a continuous
stream of small edge deltas with occasional full reconvergence and
cluster-resize requests.  Tenant graph sizes follow a truncated power
law (a few big graphs, a long tail of small ones -- the multi-tenant
cloud shape); arrivals are a per-tenant Poisson process of BURSTS, each
burst holding a geometric number of back-to-back requests.  Bursts are
what make delta coalescing pay: several edge-update requests land in a
tenant's queue between two scheduler rounds and fold into one
``apply_delta`` plan (``stats()["coalescing_factor"] > 1``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


def powerlaw_sizes(n: int, v_min: int = 256, v_max: int = 4096,
                   alpha: float = 2.2, seed: int = 0) -> List[int]:
    """``n`` vertex counts from a truncated Pareto (inverse-CDF draw)."""
    rng = np.random.default_rng(seed)
    a = 1.0 - float(alpha)
    u = rng.random(n)
    xs = (v_min ** a + u * (v_max ** a - v_min ** a)) ** (1.0 / a)
    return [int(x) for x in xs]


def tenant_graph(num_vertices: int, seed: int = 0, k_nbrs: int = 8):
    """A small-world tenant graph (the bench's per-tenant topology)."""
    from repro.core.generators import watts_strogatz
    return watts_strogatz(num_vertices, k_nbrs, 0.1, seed=seed)


def random_edge_updates(num_vertices: int, n_edges: int,
                        rng: np.random.Generator
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """A random non-self-loop ``(src, dst)`` delta batch."""
    src = rng.integers(0, num_vertices, n_edges)
    dst = rng.integers(0, num_vertices, n_edges)
    mask = src != dst
    if not mask.any():                      # degenerate tiny graph draw
        return (np.asarray([0], np.int64),
                np.asarray([num_vertices - 1], np.int64))
    return src[mask], dst[mask]


@dataclasses.dataclass
class TraceEvent:
    """One request arrival in an open-loop trace."""

    t: float                 # seconds from trace start
    tenant: str
    kind: str                # "edge_updates" | "adapt" | "resize"
    payload: dict = dataclasses.field(default_factory=dict)


def poisson_trace(tenants: Dict[str, int], *, duration: float,
                  rate: float, burst_mean: float = 3.0,
                  mix: Sequence[float] = (0.8, 0.15, 0.05),
                  edges_per_update: int = 16,
                  k_choices: Optional[Sequence[int]] = None,
                  seed: int = 0) -> List[TraceEvent]:
    """Bursty per-tenant Poisson arrivals, merged and time-sorted.

    ``tenants`` maps tenant name -> vertex count (delta batches are drawn
    against it); ``rate`` is bursts/second per tenant; each burst holds
    ``Geometric(1/burst_mean)`` requests arriving at the same instant.
    ``mix`` gives the (edge_updates, adapt, resize) probabilities; resize
    targets cycle through ``k_choices`` (omit for no resizes regardless
    of mix).
    """
    mix = np.asarray(mix, float)
    mix = mix / mix.sum()
    events: List[TraceEvent] = []
    for i, (name, num_vertices) in enumerate(sorted(tenants.items())):
        rng = np.random.default_rng((seed, i))
        t = 0.0
        while True:
            t += rng.exponential(1.0 / rate)
            if t >= duration:
                break
            for _ in range(int(rng.geometric(1.0 / max(burst_mean, 1.0)))):
                kind = ("edge_updates", "adapt", "resize")[
                    rng.choice(3, p=mix)]
                if kind == "edge_updates":
                    src, dst = random_edge_updates(
                        num_vertices, edges_per_update, rng)
                    payload = {"edge_updates": (src, dst)}
                elif kind == "resize":
                    if not k_choices:
                        kind, payload = "adapt", {}
                    else:
                        payload = {"k": int(rng.choice(k_choices))}
                else:
                    payload = {}
                events.append(TraceEvent(t, name, kind, payload))
    events.sort(key=lambda e: (e.t, e.tenant))
    return events


def replay(scheduler, events: Sequence[TraceEvent],
           time_scale: float = 1.0) -> int:
    """Open-loop replay: submit each event at its (scaled) trace time and
    run scheduler rounds whenever the queue is non-empty; returns the
    number of completed requests.  Arrival timestamps come from the
    scheduler's own clock, so latency percentiles include queueing
    delay, which is the point of an open-loop harness: a slow scheduler
    cannot push back on the trace.
    """
    import time as _time
    completed = 0
    t0 = scheduler.clock()
    i = 0
    n = len(events)
    while i < n or any(t.queue for t in scheduler.tenants.values()):
        now = (scheduler.clock() - t0) / time_scale
        while i < n and events[i].t <= now:
            e = events[i]
            scheduler.submit(e.tenant, e.kind, **e.payload)
            i += 1
        done = scheduler.step()
        completed += done
        if done == 0 and i < n:
            # idle until the next arrival (scaled back to wall time)
            _time.sleep(min(max(events[i].t * time_scale
                                - (scheduler.clock() - t0), 0.0), 0.01))
    return completed
