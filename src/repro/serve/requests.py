"""Request/queue layer of the multi-tenant serving tier.

A :class:`Ticket` is the handle returned by ``PartitionScheduler.submit``
for one request against one tenant's graph; a :class:`Tenant` pairs a
named :class:`~repro.core.session.PartitionSession` with its FIFO
admission queue and per-tenant counters.

Dispatch is window-based: ``Tenant.next_window`` pops the unit one device
dispatch serves -- the longest leading run of ``edge_updates`` requests
plus (when one immediately follows) a single plain ``adapt``.  All
requests in a window complete with the SAME result:
``delta.coalesce_updates`` folds the queued batches into one
direction-aware delta that produces bit-identical labels to applying
them one by one and reconverging once, so N queued edge-update requests
cost one ``apply_delta`` scatter plus one reconvergence (the coalescing
the scheduler's ``coalescing_factor`` measures).  ``partition``/``resize`` requests -- and adapts that rebind
to a new graph or ask for frontier reconvergence -- dispatch alone.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Deque, List, Optional

#: Request kinds the scheduler admits.
KINDS = ("partition", "edge_updates", "adapt", "resize")


@dataclasses.dataclass
class Ticket:
    """Handle for one submitted request (resolved in place on dispatch)."""

    tenant: str
    kind: str                     # one of KINDS
    seq: int                      # global admission order
    arrival: float                # scheduler-clock submission time
    payload: dict = dataclasses.field(default_factory=dict)
    done: bool = False
    result: object = None         # PartitionResult on success
    error: Optional[BaseException] = None
    finish: float = math.nan
    coalesced: int = 0            # requests served by the same dispatch

    @property
    def failed(self) -> bool:
        return self.error is not None

    def latency(self) -> float:
        """Seconds from admission to completion (NaN while queued)."""
        return self.finish - self.arrival


@dataclasses.dataclass
class Tenant:
    """One served graph: a session, its admission queue, its counters."""

    name: str
    session: object               # PartitionSession
    priority: float = 1.0
    queue: Deque[Ticket] = dataclasses.field(default_factory=deque)
    completed: int = 0
    failed: int = 0
    batched_dispatches: int = 0
    serial_dispatches: int = 0

    def next_window(self) -> List[Ticket]:
        """Pop the next dispatch unit off the queue (empty list if idle).

        ``edge_updates`` at the head absorb every directly following
        ``edge_updates`` plus at most one plain ``adapt`` (no new graph
        -- a rebind supersedes queued deltas rather than absorbing
        them); anything else dispatches alone.  FIFO order within the
        tenant is preserved, so coalescing never reorders a tenant's
        own requests.
        """
        q = self.queue
        if not q:
            return []
        window = [q.popleft()]
        if window[0].kind == "edge_updates":
            while q and q[0].kind == "edge_updates":
                window.append(q.popleft())
            if q and q[0].kind == "adapt" \
                    and q[0].payload.get("new_graph") is None:
                window.append(q.popleft())
        return window

    def staleness(self, now: float) -> float:
        """Age of the oldest queued request (0.0 when idle)."""
        return (now - self.queue[0].arrival) if self.queue else 0.0
