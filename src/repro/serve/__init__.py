"""repro.serve: the multi-tenant partition serving tier.

Spinner's § dynamicity positions partitioning as a continuously running
cloud service.  This package is that service: a
:class:`~repro.serve.scheduler.PartitionScheduler` holds many
independent graphs (one :class:`~repro.core.session.PartitionSession`
each) and drains a stream of ``partition`` / ``edge_updates`` /
``adapt`` / ``resize`` requests through per-tenant delta coalescing
(``repro.core.coalesce_updates`` -> one ``apply_delta`` scatter per
window), same-bucket batched execution (``repro.core.run_batched`` --
one ``vmap``'d while_loop dispatch for every tenant in a shape bucket,
bit-identical per tenant to its own unbatched program), and prefetch
policies that stage uploads and precompile resize targets off the
critical path.

::

    import numpy as np
    from repro.core import SpinnerConfig
    from repro.serve import PartitionScheduler

    sched = PartitionScheduler(max_batch=8)
    sched.add_tenant("a", graph_a, SpinnerConfig(k=16), partition=True)
    sched.add_tenant("b", graph_b, SpinnerConfig(k=16), partition=True)
    sched.submit("a", "edge_updates", edge_updates=(src, dst))
    sched.submit("a", "edge_updates", edge_updates=(src2, dst2))  # coalesces
    tk = sched.submit("b", "adapt")
    sched.drain()                       # one round, one batched dispatch
    labels = tk.result.labels
    print(sched.stats()["batch_occupancy"])

Synthetic open-loop traffic (Poisson bursts, power-law tenant sizes)
lives in :mod:`repro.serve.traffic`; ``benchmarks/bench_serve.py`` drives
it and reports p50/p99 adapt latency, throughput, coalescing factor and
batch occupancy.

Not to be confused with ``repro.launch.serve_llm``, the unrelated
LLM-inference serving demo on the models side of the repo.
"""
from .requests import KINDS, Tenant, Ticket
from .scheduler import (KSweepPrecompile, PartitionScheduler, StagePrefetch,
                        default_batch_min, default_policies)
from . import traffic

__all__ = [
    "PartitionScheduler", "Ticket", "Tenant", "KINDS",
    "StagePrefetch", "KSweepPrecompile", "default_policies",
    "default_batch_min", "traffic",
]
