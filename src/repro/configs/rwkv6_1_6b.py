"""rwkv6-1.6b [ssm] Finch: attention-free, data-dependent decay [arXiv:2404.05892; unverified]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch="rwkv6-1.6b", family="rwkv", n_layers=24, d_model=2048,
    n_heads=32, n_kv_heads=0, d_ff=7168, vocab=65536, ssm_head_dim=64,
    ssm_state=64, seq_chunk=32)
