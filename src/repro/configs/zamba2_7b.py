"""zamba2-7b [hybrid] Mamba2 backbone + shared attention blocks [arXiv:2411.15242; unverified].

81 Mamba2 blocks; one weight-shared attention(+MLP) block applied after every
6th Mamba2 block (13 applications), d_state = 64.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch="zamba2-7b", family="hybrid", n_layers=81, d_model=3584,
    n_heads=32, n_kv_heads=32, d_ff=14336, vocab=32000, ssm_state=64,
    ssm_head_dim=64, attn_period=6)
