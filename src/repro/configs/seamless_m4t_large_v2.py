"""seamless-m4t-large-v2 [audio] enc-dec backbone [arXiv:2308.11596; hf].

Assigned as the transformer BACKBONE only: the speech/text frontend is a
stub; ``input_specs`` provides precomputed frame embeddings for the encoder.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch="seamless-m4t-large-v2", family="encdec", n_layers=24,
    n_enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab=256206, rope_theta=10_000.0)
