"""Config system: model architectures and input shapes.

Every assigned architecture is a ``ModelConfig`` in its own module under
``repro.configs``; ``reduced()`` yields a same-family shrunken config for CPU
smoke tests.  The four assigned input shapes are ``ShapeConfig`` entries.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str                  # dense | moe | encdec | vlm | rwkv | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    shared_expert_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # --- encoder-decoder ---
    n_enc_layers: int = 0
    # --- VLM ---
    cross_attn_period: int = 0   # every Nth layer is a cross-attention layer
    n_img_tokens: int = 0
    # --- SSM / RWKV ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    # --- hybrid (zamba2-style shared attention) ---
    attn_period: int = 0         # shared attn block after every N ssm blocks
    # --- training-time knobs ---
    remat: bool = True
    attn_chunk_q: int = 1024
    attn_chunk_kv: int = 1024
    seq_chunk: int = 128         # rwkv/ssm chunk length
    # --- beyond-baseline performance knobs (EXPERIMENTS.md, Perf) ---
    cast_params_before_scan: bool = False  # bf16 FSDP all-gathers
    ce_chunked: int = 0          # >0: fused chunked CE, chunk length
    moe_dispatch: str = "cumsum"  # "cumsum" | "sort"
    bf16_reduce: bool = False    # row-parallel dots emit bf16 (Megatron-
                                 # style bf16 partial-sum all-reduce)
    gather_weights: bool = False  # pin FSDP to weight-gather (not psum)
    residual_sharding: str = "auto"  # auto | replicated | seq (Megatron-SP)
    bf16_grads: bool = False     # cast params bf16 for grad: bf16 grad sync
    attn_replicate: bool = False  # replicate q/k/v over 'model' in the
                                  # flash scan (for TP-misaligned heads)
    microbatch: int = 0          # >1: gradient-accumulation microbatches

    def optimized(self) -> "ModelConfig":
        """The beyond-paper optimized variant (see EXPERIMENTS.md Perf)."""
        # validated combination (EXPERIMENTS.md Perf): replicate attention
        # only where head counts are TP-misaligned; sequence-parallel
        # residuals are a separate, situational memory-vs-collective trade
        # (see the granite-8b iteration log).
        return dataclasses.replace(
            self, ce_chunked=512, moe_dispatch="sort", bf16_reduce=True,
            bf16_grads=True,
            attn_replicate=bool(self.n_kv_heads and self.n_kv_heads % 16))

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to a multiple of 256 for clean TP sharding."""
        return -(-self.vocab // 256) * 256

    def reduced(self) -> "ModelConfig":
        """Same-family tiny config for CPU smoke tests."""
        if self.attn_period:          # hybrid: 2 groups + 1 tail layer
            n_layers = min(self.n_layers, 2 * self.attn_period + 1)
        elif self.cross_attn_period:  # vlm: 2 groups of a shrunken period
            n_layers = 4
        else:
            n_layers = 2
        return dataclasses.replace(
            self,
            n_layers=n_layers,
            cross_attn_period=2 if self.cross_attn_period else 0,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=16,
            d_ff=128,
            vocab=512,
            n_experts=min(self.n_experts, 8),
            top_k=min(self.top_k, 2),
            d_expert=32 if self.d_expert else 0,
            shared_expert_ff=32 if self.shared_expert_ff else 0,
            n_enc_layers=min(self.n_enc_layers, 2),
            n_img_tokens=16 if self.n_img_tokens else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            attn_chunk_q=32,
            attn_chunk_kv=32,
            seq_chunk=16,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}

# Families with sub-quadratic decode state; everything else skips long_500k
# (see DESIGN.md Section 7).
LONG_CONTEXT_FAMILIES = ("rwkv", "hybrid")


def cell_is_runnable(model: ModelConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k":
        return model.family in LONG_CONTEXT_FAMILIES
    return True
