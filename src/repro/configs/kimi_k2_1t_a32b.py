"""kimi-k2-1t-a32b [moe] trillion-param MoE, 384e top-8 [arXiv:2501.kimi2; unverified].

Per the assignment table: GQA kv=8 (not MLA), d_expert = 2048, plus one
shared expert of the same width (DeepSeek-V3 lineage).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch="kimi-k2-1t-a32b", family="moe", n_layers=61, d_model=7168,
    n_heads=64, n_kv_heads=8, d_ff=2048, vocab=163840, n_experts=384,
    top_k=8, d_expert=2048, shared_expert_ff=2048, rope_theta=50_000.0)
