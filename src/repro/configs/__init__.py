"""Architecture registry: the 10 assigned architectures + paper configs."""
from . import (granite_8b, granite_20b, kimi_k2_1t_a32b,
               llama_3_2_vision_11b, qwen2_5_14b, qwen3_moe_235b_a22b,
               rwkv6_1_6b, seamless_m4t_large_v2, stablelm_1_6b, zamba2_7b)
from .base import (LONG_CONTEXT_FAMILIES, SHAPES, SHAPES_BY_NAME, ModelConfig,
                   ShapeConfig, cell_is_runnable)

ARCHS = {
    m.CONFIG.arch: m.CONFIG
    for m in (granite_8b, granite_20b, stablelm_1_6b, qwen2_5_14b,
              seamless_m4t_large_v2, kimi_k2_1t_a32b, qwen3_moe_235b_a22b,
              llama_3_2_vision_11b, rwkv6_1_6b, zamba2_7b)
}

__all__ = ["ARCHS", "ModelConfig", "ShapeConfig", "SHAPES", "SHAPES_BY_NAME",
           "LONG_CONTEXT_FAMILIES", "cell_is_runnable"]
