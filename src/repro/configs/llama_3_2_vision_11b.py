"""llama-3.2-vision-11b [vlm] cross-attn image layers [hf:meta-llama/Llama-3.2-11B-Vision; unverified].

Backbone only; the vision tower is a stub -- ``input_specs`` provides
precomputed patch embeddings already projected to d_model.  Every 5th layer
is a gated cross-attention layer (8 of 40), per the Llama-3.2-Vision layout.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch="llama-3.2-vision-11b", family="vlm", n_layers=40, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=128256,
    cross_attn_period=5, n_img_tokens=1600, rope_theta=500_000.0)
