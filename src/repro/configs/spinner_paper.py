"""The paper's own experiment configurations (Section 5).

Algorithm parameters follow Section 5.1: c = 1.05, eps = 0.001, w = 5.
Graph workloads are seeded synthetic stand-ins for the paper's proprietary
datasets (see DESIGN.md Section 6, deviation 3).
"""
from repro.core.spinner import SpinnerConfig


def paper_config(k: int, seed: int = 0, **kw) -> SpinnerConfig:
    return SpinnerConfig(k=k, c=1.05, eps=1e-3, halt_window=5, seed=seed, **kw)


# (name, generator kwargs) quality-benchmark workloads
QUALITY_GRAPHS = {
    "smallworld-100k": ("watts_strogatz",
                        dict(n=100_000, k_nbrs=20, beta=0.3, seed=11)),
    "powerlaw-50k": ("powerlaw_ba", dict(n=50_000, m=8, seed=12)),
    "clustered-64k": ("clustered_graph",
                      dict(num_clusters=64, cluster_size=1000, p_in=0.02,
                           p_out_edges_per_v=2.0, seed=13)),
}

K_SWEEP = (2, 4, 8, 16, 32, 64, 128, 256, 512)
