"""The cluster worker: per-host LPA supersteps over the coordination service.

Each process owns the vertex ranges of the edge-shard hosts mapped to
it (``host % world == pid`` -- so a shrunk generation absorbs the dead
workers' shards) and loads ONLY those hosts' edge files
(:func:`bootstrap.load_edge_shard`).  One superstep per iteration:

1. score my vertices from my local edges against the current global
   labels (a host scatter-add -- O(E_local));
2. ``propose`` / ``finish`` from ``engine.make_update_parts`` -- the
   SAME Eq. 7-8 / 11-12 math every in-process engine runs -- with the
   global reduction ``reduce_`` bound to :meth:`ClusterHandle
   .allreduce_sum` (the (k,) migration-mass aggregator, the load delta
   and the halting scalars ride the distributed KV store; on a 1-process
   generation it degenerates to identity);
3. exchange label slices per owned host range through the KV store;
4. the Section 3.3 halting update, replicated on every host from the
   globally reduced score.

All randomness is drawn from ``fold_in(PRNGKey(seed), iteration)``
over the FULL vertex set on every process, so the trajectory is a
deterministic function of (graph, config, init labels) and INDEPENDENT
of the world size: a generation that resumes from a snapshot with
fewer processes walks the exact iterations the dead generation would
have -- which is what makes same-capacity recovery bit-identical and
lets tests compare any world size against a 1-process reference.

Process 0 snapshots ``(labels, loads, best_score, stall, next_t)``
through ``repro.ckpt`` every ``snapshot_every`` supersteps and writes
``result.json`` + ``labels.npy`` at convergence.  Heartbeats are file
mtimes under ``<workdir>/hb/`` (a dead process can't answer RPCs, but
its stale file still accuses it), touched every superstep AND between
the sliced blocking-wait polls inside ``kv_get`` (via
``ClusterHandle.on_wait``) so a live worker blocked on a slow peer is
never misdeclared stale.  Process 0 deletes iteration ``t-1``'s KV
keys once iteration ``t``'s allreduce completes (proof every peer is
past them), keeping coordinator memory O(V) instead of
O(V x iterations).  Fault injection is declarative in
``job.json`` (``{"fault": {"gen": 0, "pid": 1, "iteration": 6}}`` hard-
exits that process at that superstep, simulating a worker loss).

On the CPU backend the per-step compute runs on the process-local
device (cross-process XLA collectives are unavailable there -- see
``bootstrap``); on accelerator backends the same job can instead run
the engine's ``shard_map`` path over ``ClusterHandle.global_mesh()``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import List, Optional

import numpy as np

# import names, not the submodule: the package re-exports a function
# called ``bootstrap`` that shadows the module attribute
from . import snapshot as _snapshot
from .bootstrap import (ClusterConfig, PeerLost, bootstrap, load_edge_shard,
                        read_manifest)
from repro.ckpt import checkpoint


def _beat(workdir: str, gen: int, pid: int) -> None:
    path = os.path.join(workdir, "hb", f"g{gen}_p{pid}")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(str(time.time()))


def _host_range(h: int, v_per_host: int, V: int) -> tuple:
    return h * v_per_host, min((h + 1) * v_per_host, V)


def run_worker(workdir: str, gen: int, world: int, pid: int,
               port: int) -> int:
    with open(os.path.join(workdir, "job.json")) as f:
        job = json.load(f)
    _beat(workdir, gen, pid)

    import jax
    import jax.numpy as jnp
    from repro.core.engine import make_update_parts

    handle = bootstrap(ClusterConfig(
        port=port, num_processes=world, process_id=pid,
        rpc_timeout=float(job.get("rpc_timeout", 60.0))))
    # beat while blocked in coordination waits too: a superstep
    # legitimately blocks for up to rpc_timeout per read on a slow peer,
    # which would otherwise outlast the supervisor's heartbeat deadline
    handle.on_wait = lambda: _beat(workdir, gen, pid)

    shard_dir = job["shard_dir"]
    snap_dir = job.get("snapshot_dir",
                       os.path.join(workdir, "snaps"))
    manifest = read_manifest(shard_dir)
    H, V = manifest["num_hosts"], manifest["num_vertices"]
    v_per_host = manifest["v_per_host"]
    owned = [h for h in range(H) if h % world == pid]
    views = [load_edge_shard(shard_dir, h)[0] for h in owned]
    src = np.concatenate([v.src for v in views]) if views else \
        np.zeros(0, np.int32)
    dst = np.concatenate([v.dst for v in views]) if views else \
        np.zeros(0, np.int32)
    w = np.concatenate([v.weight for v in views]) if views else \
        np.zeros(0, np.float32)
    deg_w = np.load(os.path.join(shard_dir, "deg_w.npy"))
    own_mask = np.zeros(V, bool)
    for h in owned:
        lo, hi = _host_range(h, v_per_host, V)
        own_mask[lo:hi] = True

    k = int(job["k"])
    cfg = {"c": float(job.get("c", 1.05)),
           "eps": float(job.get("eps", 1e-3)),
           "halt_window": int(job.get("halt_window", 5)),
           "max_iters": int(job.get("max_iters", 120)),
           "seed": int(job.get("seed", 0)),
           "tie_noise": float(job.get("tie_noise", 1e-7)),
           "current_bonus": float(job.get("current_bonus", 1e-6)),
           "migration_weighting": job.get("migration_weighting", "edges")}
    snapshot_every = int(job.get("snapshot_every", 5))
    fault = job.get("fault")
    C = cfg["c"] * manifest["total_weight"] / k

    propose, finish = make_update_parts(
        k, degree_weighted=cfg["migration_weighting"] == "edges",
        current_bonus=cfg["current_bonus"])
    key = jax.random.PRNGKey(cfg["seed"])
    key, k_init = jax.random.split(key)

    # resume from the newest complete snapshot, else deterministic init
    try:
        step0, tree = _snapshot.newest_complete(snap_dir)
        labels = np.asarray(tree["labels"], np.int32)
        loads = np.asarray(tree["loads"], np.float32)
        best_score = float(tree["best_score"])
        stall = int(tree["stall"])
        t0 = int(tree["next_t"])
    except FileNotFoundError:
        labels = np.asarray(jax.random.randint(
            k_init, (V,), 0, k), np.int32)
        loads = np.zeros(k, np.float32)
        np.add.at(loads, labels, deg_w.astype(np.float32))
        best_score, stall, t0 = float("-inf"), 0, 0

    jr = jax.random
    deg_j = jnp.asarray(deg_w.astype(np.float32))
    valid = jnp.asarray(own_mask)
    halted = False
    t = t0
    for t in range(t0, cfg["max_iters"]):
        _beat(workdir, gen, pid)
        if (fault and int(fault.get("gen", 0)) == gen
                and int(fault.get("pid", -1)) == pid
                and int(fault.get("iteration", -1)) == t):
            os._exit(int(fault.get("exit_code", 13)))

        it_key = jr.fold_in(key, t)
        noise = jr.uniform(jr.fold_in(it_key, 0), (V, k), jnp.float32,
                           0.0, cfg["tie_noise"])
        u = jr.uniform(jr.fold_in(it_key, 1), (V,), jnp.float32)

        scores = np.zeros((V, k), np.float32)
        if src.size:
            np.add.at(scores, (src, labels[dst]), w)

        seq = [0]

        def reduce_(x):
            if world == 1:
                return x
            seq[0] += 1
            return jnp.asarray(handle.allreduce_sum(
                f"g{gen}/t{t}/r{seq[0]}", np.asarray(x)))

        best, tot_best, tot_cur, m_partial = propose(
            jnp.asarray(scores), jnp.asarray(labels), deg_j,
            jnp.asarray(loads), noise, valid, C)
        new_labels, new_loads, score_g, _n_mig, _mass = finish(
            best, tot_best, tot_cur, m_partial, jnp.asarray(labels),
            deg_j, jnp.asarray(loads), u, valid, reduce_, C)

        # iteration t's allreduce just completed, so every peer has
        # entered iteration t -- i.e. finished ALL of t-1's label reads
        # -- and t-1's keys are dead: GC them so the coordination
        # service holds O(V) live payload, not O(V x iterations)
        if world > 1 and pid == 0 and t > t0:
            handle.kv_delete(f"g{gen}/t{t - 1}/")

        new_labels = np.asarray(new_labels, np.int32)
        if world > 1:
            for h in owned:
                lo, hi = _host_range(h, v_per_host, V)
                handle.kv_put_array(f"g{gen}/t{t}/lab/{h}",
                                    new_labels[lo:hi])
            merged = labels.copy()
            for h in range(H):
                lo, hi = _host_range(h, v_per_host, V)
                merged[lo:hi] = handle.kv_get_array(
                    f"g{gen}/t{t}/lab/{h}", np.int32, (hi - lo,))
            labels = merged
        else:
            labels = new_labels
        loads = np.asarray(new_loads, np.float32)
        score = float(score_g)

        # Section 3.3 halting, replicated on every host (same float path
        # as engine._halting_update: the first iteration's -inf + inf
        # comparison is False and counts toward the stall window)
        tol = cfg["eps"] * max(1.0, abs(best_score))
        improved = score > best_score + tol
        best_score = max(best_score, score)
        stall = 0 if improved else stall + 1
        halted = stall >= cfg["halt_window"]

        if pid == 0 and ((t + 1) % snapshot_every == 0 or halted):
            checkpoint.save(snap_dir, t + 1, {
                "labels": labels, "loads": loads,
                "best_score": np.float64(best_score),
                "stall": np.int64(stall),
                "next_t": np.int64(t + 1),
                "k": np.int64(k), "ndev": np.int64(world),
                "num_vertices": np.int64(V)})
            checkpoint.gc_old(snap_dir, keep=3)
        if halted:
            break

    # distributed phi: locally-internal edge weight / total, via one
    # final allreduce (each directed edge counted on its owner)
    part = np.asarray([float(w[labels[src] == labels[dst]].sum())
                       if src.size else 0.0,
                       float(w.sum())], np.float64)
    if world > 1:
        part = handle.allreduce_sum(f"g{gen}/final/phi", part)
        if pid == 0:    # everyone reached the phi reduce: t's keys are dead
            handle.kv_delete(f"g{gen}/t{t}/")
    phi = part[0] / max(part[1], 1e-12)

    if pid == 0:
        np.save(os.path.join(workdir, "labels.npy"), labels)
        with open(os.path.join(workdir, "result.json"), "w") as f:
            json.dump({"iterations": t + 1, "halted": bool(halted),
                       "phi": float(phi), "gen": gen, "world": world,
                       "score": best_score}, f)
    if world > 1:
        handle.barrier(f"g{gen}/done")
    handle.shutdown()
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workdir", required=True)
    ap.add_argument("--gen", type=int, default=0)
    ap.add_argument("--world", type=int, required=True)
    ap.add_argument("--pid", type=int, required=True)
    ap.add_argument("--port", type=int, required=True)
    a = ap.parse_args(argv)
    try:
        return run_worker(a.workdir, a.gen, a.world, a.pid, a.port)
    except PeerLost as e:
        print(f"peer lost: {e}", file=sys.stderr)
        return 3


if __name__ == "__main__":
    sys.exit(main())
