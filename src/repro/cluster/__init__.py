"""repro.cluster: the multi-process fault-tolerant partition runtime.

Spinner's § dynamicity claim is partitioning on elastic, UNRELIABLE
cloud capacity.  This package makes the repo's mesh real processes and
makes losing one survivable:

* :mod:`~repro.cluster.bootstrap` -- ``jax.distributed`` bring-up
  (coordinator + N workers, subprocess-spawnable for tests/CI), the
  local / process-spanning meshes, the coordination-service KV +
  barrier surface, and per-host edge-shard IO (``write_edge_shards`` /
  ``load_edge_shard`` feeding ``shard_graph(..., local_only=pid)``)
  so no process materializes the full graph;
* :mod:`~repro.cluster.snapshot` -- ``PartitionSession`` state through
  ``repro.ckpt`` (atomic; format documented in the module docstring),
  restorable onto a DIFFERENT device count by replaying the elastic
  ``resize`` re-shard;
* :mod:`~repro.cluster.supervisor` -- heartbeat/deadline detection,
  injectable fault hooks (worker kill, checkpoint corruption, slow
  worker), and the restart policy: re-bootstrap on the surviving
  capacity, resume from the newest COMPLETE snapshot;
* :mod:`~repro.cluster.worker` -- the spawnable worker loop (per-host
  shards, KV-store label exchange on CPU, snapshot cadence);
* :mod:`~repro.cluster.deploy` -- the serving-tier deployment mode:
  ``PartitionScheduler(deployment=ClusterDeployment(...))`` pins
  tenants to the cluster mesh and recovers failed dispatches from
  snapshots.

Same-capacity recovery is bit-identical to an uninterrupted run
(sessions are deterministic in (graph, cfg, prev labels)); shrunk
capacity resumes through ``resize`` within quality tolerance -- both
asserted in ``tests/test_cluster.py`` and measured by
``benchmarks/bench_elastic.py --fault`` into ``BENCH_cluster.json``.
"""
from .bootstrap import (ClusterConfig, ClusterHandle, PeerLost, bootstrap,
                        free_port, load_edge_shard, load_local_shard,
                        read_manifest, spawn_local_worker, worker_env,
                        write_edge_shards)
from .deploy import ClusterDeployment
from .snapshot import (RestoreInfo, load_snapshot, newest_complete,
                       restore_session, save_snapshot, snapshot_steps,
                       snapshot_tree)
from .supervisor import (ClusterSupervisorConfig, PartitionSupervisor,
                         ProcessClusterConfig, ProcessClusterSupervisor,
                         WorkerLost, corrupt_newest_snapshot_at,
                         kill_worker_at, slow_worker_at)

__all__ = [
    "ClusterConfig", "ClusterHandle", "PeerLost", "bootstrap",
    "free_port", "load_edge_shard", "load_local_shard", "read_manifest",
    "spawn_local_worker", "worker_env", "write_edge_shards",
    "ClusterDeployment",
    "RestoreInfo", "load_snapshot", "newest_complete", "restore_session",
    "save_snapshot", "snapshot_steps", "snapshot_tree",
    "ClusterSupervisorConfig", "PartitionSupervisor",
    "ProcessClusterConfig", "ProcessClusterSupervisor", "WorkerLost",
    "corrupt_newest_snapshot_at", "kill_worker_at", "slow_worker_at",
]
