"""Cluster deployment mode for the serving tier.

``PartitionScheduler(deployment=ClusterDeployment(...))`` turns the
multi-tenant scheduler into the supervised runtime the ROADMAP frames:
every admitted tenant is pinned to the deployment's (possibly
process-spanning) mesh, snapshotted through ``repro.cluster.snapshot``
after every ``snapshot_every``-th committed dispatch, and -- when a
dispatch raises -- recovered from its newest complete snapshot and
retried ONCE, supervisor-style, with zero operator intervention:

* the recovery graph is the failed session's materialized logical graph
  (base + every accepted delta batch, including the failed window's),
  so the retry runs a plain reconvergence instead of re-applying
  deltas;
* the restore capacity is the deployment's CURRENT mesh -- if capacity
  shrank since the snapshot (``deployment.mesh`` reassigned, e.g. by a
  process supervisor after worker loss), ``restore_session`` replays
  the elastic ``resize`` (partitions/device preserved) before the
  retry.

Tenants with no snapshot yet (first ``partition`` failed) fall through
to the scheduler's normal ticket-failure path.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Optional

from . import snapshot as _snapshot


class ClusterDeployment:
    """Mesh pinning + snapshot/recovery policy for scheduler tenants.

    ``mesh=None`` leaves tenants on their own options (single-device
    sessions still get snapshot/recovery); pass a mesh from
    ``ClusterHandle.local_mesh()`` / ``global_mesh()`` (or
    ``launch.mesh.make_partition_mesh(devices=...)``) to pin every
    tenant's sharded programs to it.  Reassigning ``deployment.mesh``
    between rounds models a capacity change: the next recovery restores
    onto the new width.
    """

    def __init__(self, snapshot_root: str, *, mesh=None, axis: str = "data",
                 snapshot_every: int = 1, keep: int = 3,
                 scale_k: bool = True):
        self.snapshot_root = snapshot_root
        self.mesh = mesh
        self.axis = axis
        self.snapshot_every = max(1, snapshot_every)
        self.keep = keep
        self.scale_k = scale_k
        self.snapshots_written = 0
        self.recoveries = 0
        self.recovery_failures = 0
        self.resized_recoveries = 0
        self.k_roll_forwards = 0
        self.snapshot_errors = 0
        self._commits: Dict[str, int] = {}
        # last COMMITTED k per tenant: with snapshot_every > 1 a
        # committed resize() may postdate the newest snapshot, and a
        # recovery restoring that snapshot must roll k forward again
        # instead of silently reverting the tenant
        self._committed_k: Dict[str, int] = {}

    # -- admission ---------------------------------------------------------

    def admit(self, name: str, options):
        """Tenant options with the deployment mesh pinned (a tenant that
        brought its own mesh keeps it)."""
        from repro.core.engine import EngineOptions
        opts = options if options is not None else EngineOptions()
        if self.mesh is not None and opts.mesh is None:
            opts = dataclasses.replace(opts, mesh=self.mesh,
                                       axis=self.axis)
        return opts

    def tenant_dir(self, name: str) -> str:
        return os.path.join(self.snapshot_root, name)

    @property
    def ndev(self) -> int:
        return int(self.mesh.devices.size) if self.mesh is not None else 1

    # -- snapshot cadence --------------------------------------------------

    def after_commit(self, name: str, session) -> None:
        """Called by the scheduler after each committed dispatch; writes
        the tenant's snapshot on cadence.  Never raises into the serving
        loop -- a failed save is counted and the previous snapshot
        stands (it is complete by construction: atomic rename)."""
        n = self._commits.get(name, 0) + 1
        self._commits[name] = n
        self._committed_k[name] = int(session.cfg.k)
        if n % self.snapshot_every or session.labels is None:
            return
        try:
            _snapshot.save_snapshot(self.tenant_dir(name), session, n,
                                    ndev=self.ndev, keep=self.keep)
            self.snapshots_written += 1
        except Exception:
            self.snapshot_errors += 1

    # -- recovery ----------------------------------------------------------

    def recover(self, name: str, graph, options=None, *,
                roll_forward_k: bool = True):
        """A fresh session for tenant ``name`` restored from its newest
        complete snapshot onto the CURRENT capacity, or None when no
        snapshot exists (the caller then fails the window normally).

        With ``snapshot_every > 1`` the snapshot may predate a
        committed ``resize()``; unless ``roll_forward_k`` is off (the
        scheduler turns it off when the retried window is itself a
        resize, which sets k anyway), the restored session is resized
        back to the tenant's last committed k -- rescaled like any
        snapshot k when capacity changed -- so a recovery never
        silently reverts a committed resize."""
        try:
            info = _snapshot.restore_session(
                self.tenant_dir(name), graph,
                options=self.admit(name, options),
                ndev=self.ndev, scale_k=self.scale_k)
        except FileNotFoundError:
            self.recovery_failures += 1
            return None
        self.recoveries += 1
        if info.resized:
            self.resized_recoveries += 1
        committed = self._committed_k.get(name)
        if roll_forward_k and committed is not None:
            want = committed
            if self.scale_k and info.ndev != info.saved_ndev:
                want = max(1, round(committed * info.ndev
                                    / info.saved_ndev))
            if want != info.k:
                info.result = info.session.resize(want,
                                                  record_history=False)
                info.k = want
                info.resized = True
                self.k_roll_forwards += 1
        return info

    def stats(self) -> dict:
        return {
            "ndev": self.ndev,
            "snapshot_every": self.snapshot_every,
            "snapshots_written": self.snapshots_written,
            "snapshot_errors": self.snapshot_errors,
            "recoveries": self.recoveries,
            "resized_recoveries": self.resized_recoveries,
            "k_roll_forwards": self.k_roll_forwards,
            "recovery_failures": self.recovery_failures,
            "tenants_snapshotted": len(self._commits),
        }
