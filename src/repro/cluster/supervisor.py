"""Partition-aware supervision: heartbeats, fault injection, restart policy.

Two supervisors, one per process topology:

* :class:`PartitionSupervisor` generalizes
  ``runtime.failures.TrainSupervisor`` from train steps to partition
  work items.  It drives a stream of ``(kind, kwargs)`` items --
  ``partition`` / ``adapt`` / ``update`` / ``resize`` -- through a
  :class:`~repro.core.session.PartitionSession`, snapshotting through
  ``repro.cluster.snapshot`` every N completed items.  Injectable fault
  hooks simulate worker kill (:func:`kill_worker_at`),
  checkpoint corruption (:func:`corrupt_newest_snapshot_at`) and slow
  workers (:func:`slow_worker_at`); the restart policy re-bootstraps
  the session on the surviving device count (``WorkerLost.surviving_ndev``)
  and resumes from the newest COMPLETE snapshot, skipping corrupt ones.
  Because the base graph plus the work stream are the durable inputs
  and every session run is deterministic in (graph, cfg, prev labels),
  a same-capacity restart replays to a bit-identical final state; a
  shrunk-capacity restart replays the elastic ``resize`` re-shard and
  reconverges (asserted within 2% φ of the uninterrupted baseline in
  tests/benchmarks).

* :class:`ProcessClusterSupervisor` owns real OS processes: it spawns a
  coordinator + workers (``bootstrap.spawn_local_worker``), watches
  exit codes and per-process heartbeat FILES
  (``<workdir>/hb/g<gen>_p<pid>``, touched every superstep -- files
  rather than the KV store, because a dead worker can't answer a
  barrier but its stale mtime still accuses it), and on a death or a
  stale heartbeat kills the generation and respawns on the surviving
  process count with a fresh coordinator port.  Workers resume from
  the newest snapshot on the shared filesystem (see
  ``repro.cluster.worker``).

Both report ``stats()`` dicts carrying restart counts, snapshots
written/restored/corrupt-skipped, recovery times, heartbeat ages, and
the straggler watchdog's ``flagged_steps`` (the satellite surface
``TrainSupervisor.stats()`` now also exposes).
"""
from __future__ import annotations

import dataclasses
import os
import signal
import time
from typing import Callable, Dict, List, Optional, Sequence

# NOTE: import names, not the submodule -- the package re-exports a
# function called ``bootstrap`` that shadows the module attribute
from . import snapshot as _snapshot
from .bootstrap import free_port, spawn_local_worker


class WorkerLost(RuntimeError):
    """A (simulated or real) worker death; carries surviving capacity."""

    def __init__(self, message: str,
                 surviving_ndev: Optional[int] = None):
        super().__init__(message)
        self.surviving_ndev = surviving_ndev


# ---------------------------------------------------------------------------
# Injectable fault hooks (step, supervisor, session) -> None
# ---------------------------------------------------------------------------

def kill_worker_at(step: int, surviving_ndev: Optional[int] = None,
                   worker: int = 0) -> Callable:
    """Raise :class:`WorkerLost` once, just before work item ``step``."""
    state = {"fired": False}

    def hook(i, sup, session):
        if i == step and not state["fired"]:
            state["fired"] = True
            raise WorkerLost(f"simulated kill of worker {worker} at "
                             f"item {i}", surviving_ndev=surviving_ndev)

    return hook


def corrupt_newest_snapshot_at(step: int) -> Callable:
    """Corrupt the newest snapshot once, before item ``step`` runs --
    deletes its manifest, exactly what a torn write looks like.  The
    restart must then fall back to the previous complete snapshot."""
    state = {"fired": False}

    def hook(i, sup, session):
        if i != step or state["fired"]:
            return
        state["fired"] = True
        steps = _snapshot.snapshot_steps(sup.cfg.snapshot_dir)
        if not steps:
            return
        path = os.path.join(sup.cfg.snapshot_dir,
                            f"step_{steps[-1]:08d}", "manifest.msgpack")
        if os.path.exists(path):
            os.remove(path)
            sup.snapshots_corrupted += 1

    return hook


def slow_worker_at(step: int, seconds: float = 0.25) -> Callable:
    """Sleep inside one work item -- the straggler watchdog's bait."""
    state = {"fired": False}

    def hook(i, sup, session):
        if i == step and not state["fired"]:
            state["fired"] = True
            time.sleep(seconds)

    return hook


# ---------------------------------------------------------------------------
# In-process supervisor over a PartitionSession
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ClusterSupervisorConfig:
    snapshot_dir: str
    snapshot_every: int = 1        # snapshot per N completed work items
    keep: int = 3
    straggler_factor: float = 3.0  # flag items slower than Nx median
    straggler_warmup: int = 3      # ... once this many items timed
    heartbeat_deadline: float = 30.0
    max_restarts: int = 3
    scale_k: bool = True           # rescale k with capacity on restore


class PartitionSupervisor:
    """Checkpointed, fault-tolerant execution of partition work items.

    ``session_factory(ndev)`` returns ``(graph, cfg, options)`` for a
    session bootstrapped on ``ndev`` devices (None = caller default) --
    the factory IS the re-bootstrap: after a failure it is invoked
    again with the surviving count, and the newest complete snapshot is
    restored onto whatever it builds (``snapshot.restore_session``
    replays the elastic ``resize`` when capacity changed).

    Work items are ``(kind, kwargs)``: ``("partition", {})``,
    ``("adapt", {...})``, ``("update", {...})``, ``("resize",
    {"k": n})``.  The stream plus the factory's base graph are the
    durable inputs; restart re-applies the completed prefix's graph
    mutations (``update`` / ``adapt(edge_updates=...)`` deltas,
    verified against the snapshot's ``delta_watermark``) to the
    rebuilt base graph, then resumes at the snapshot's item index and
    replays the tail, bit-identically on unchanged capacity.
    """

    def __init__(self, cfg: ClusterSupervisorConfig,
                 session_factory: Callable):
        self.cfg = cfg
        self.factory = session_factory
        self.restarts = 0
        self.snapshots_written = 0
        self.snapshots_restored = 0
        self.snapshots_corrupted = 0   # by injected faults
        self.corrupt_skipped = 0       # skipped during restore
        self.recover_seconds: List[float] = []
        self.step_times: List[float] = []
        self.flagged_steps: List[tuple] = []
        self._hb: Dict[int, float] = {}
        self.ndev: Optional[int] = None
        self.k: Optional[int] = None
        self.resized_on_restore = False

    # -- heartbeats --------------------------------------------------------

    def heartbeat(self, worker: int = 0) -> None:
        self._hb[worker] = time.monotonic()

    def heartbeat_ages(self) -> Dict[int, float]:
        now = time.monotonic()
        return {w: now - t for w, t in self._hb.items()}

    def stale_workers(self) -> List[int]:
        return [w for w, age in self.heartbeat_ages().items()
                if age > self.cfg.heartbeat_deadline]

    # -- the supervised run ------------------------------------------------

    @staticmethod
    def replay_graph_mutations(graph, work: Sequence[tuple], step: int):
        """Re-apply the graph mutations carried by ``work[:step]`` to the
        factory's base graph: ``update`` items, ``adapt`` items with
        ``edge_updates=`` (both delta batches -- ``add_edges`` weight
        semantics are order-independent, so per-item replay is exact)
        and ``adapt(new_graph=...)`` rebinds.  Returns ``(graph,
        n_delta_batches)``; the count must match the snapshot's
        ``delta_watermark`` for the rebuilt graph to be the logical
        graph the snapshot's labels reflect."""
        from repro.core.graph import add_edges
        n_delta = 0
        for kind, kw in list(work)[:step]:
            if kind == "update":
                graph = add_edges(graph, kw["edge_src"], kw["edge_dst"],
                                  directed=kw.get("directed", True),
                                  num_vertices=kw.get("num_vertices"))
                n_delta += 1
            elif kind == "adapt":
                if kw.get("edge_updates") is not None:
                    e_src, e_dst = kw["edge_updates"]
                    graph = add_edges(graph, e_src, e_dst,
                                      num_vertices=kw.get("num_vertices"))
                    n_delta += 1
                elif kw.get("new_graph") is not None:
                    graph = kw["new_graph"]
        return graph, n_delta

    def _boot(self, ndev: Optional[int], work: Sequence[tuple] = ()):
        """(session, items_completed): a fresh session, fast-forwarded
        to the newest complete snapshot if one exists.  The factory
        returns the BASE graph, so before restoring, the graph
        mutations of the already-completed ``work[:step]`` prefix are
        replayed onto it (cross-checked against the snapshot's
        ``delta_watermark``) -- a snapshot's labels reflect those
        deltas, and resuming on a stale graph would silently diverge
        from the documented bit-identical replay."""
        graph, cfg, options = self.factory(ndev)
        if _snapshot.snapshot_steps(self.cfg.snapshot_dir):
            skipped: List[int] = []
            step, tree = _snapshot.newest_complete(
                self.cfg.snapshot_dir,
                on_corrupt=lambda s, e: skipped.append(s))
            graph, n_delta = self.replay_graph_mutations(graph, work, step)
            watermark = int(tree["delta_watermark"]) \
                if "delta_watermark" in tree else n_delta
            if n_delta != watermark:
                raise RuntimeError(
                    f"snapshot step {step} reflects {watermark} delta "
                    f"batches but work[:{step}] carries {n_delta}; the "
                    f"snapshot's logical graph cannot be rebuilt from "
                    f"the factory's base graph plus this work stream")
            info = _snapshot.restore_session(
                self.cfg.snapshot_dir, graph, options=options,
                ndev=ndev, scale_k=self.cfg.scale_k, step=step)
            self.corrupt_skipped += len(skipped)
            self.snapshots_restored += 1
            self.resized_on_restore |= info.resized
            self.k = info.k
            return info.session, info.step
        from repro.core.session import PartitionSession
        session = PartitionSession(graph, cfg, options)
        self.k = cfg.k
        return session, 0

    def _dispatch(self, session, item):
        kind, kw = item
        if kind == "partition":
            return session.partition(record_history=False, **kw)
        if kind == "adapt":
            return session.adapt(record_history=False, **kw)
        if kind == "resize":
            res = session.resize(kw["k"], record_history=False)
            self.k = kw["k"]
            return res
        if kind == "update":
            session.update(**kw)
            return None
        raise ValueError(f"unknown work item kind {kind!r}")

    def run(self, work: Sequence[tuple], *,
            ndev: Optional[int] = None,
            faults: Sequence[Callable] = ()) -> tuple:
        """Drive ``work`` to completion with snapshots + restarts;
        returns ``(session, results)`` (one result per item, in order;
        replayed prefixes keep the result computed during THIS run's
        replay)."""
        self.ndev = ndev
        session, i = self._boot(ndev, work)
        results: list = [None] * len(work)
        attempts = 0
        while i < len(work):
            try:
                t0 = time.monotonic()   # before hooks: a slow-worker
                for hook in faults:     # fault counts as step walltime
                    hook(i, self, session)
                results[i] = self._dispatch(session, work[i])
                dt = time.monotonic() - t0
                self.step_times.append(dt)
                med = sorted(self.step_times)[len(self.step_times) // 2]
                if (len(self.step_times) > self.cfg.straggler_warmup
                        and dt > self.cfg.straggler_factor * med):
                    self.flagged_steps.append((i, dt, med))
                self.heartbeat(0)
                i += 1
                if (session.labels is not None
                        and i % self.cfg.snapshot_every == 0):
                    _snapshot.save_snapshot(
                        self.cfg.snapshot_dir, session, i,
                        ndev=self.ndev, keep=self.cfg.keep)
                    self.snapshots_written += 1
            except Exception as e:
                attempts += 1
                if attempts > self.cfg.max_restarts:
                    raise
                self.restarts += 1
                t0 = time.monotonic()
                surviving = getattr(e, "surviving_ndev", None)
                if surviving is not None:
                    self.ndev = surviving
                try:
                    session.close()
                except Exception:
                    pass
                session, i = self._boot(self.ndev, work)
                self.recover_seconds.append(time.monotonic() - t0)
        if session.labels is not None:
            _snapshot.save_snapshot(self.cfg.snapshot_dir, session,
                                    len(work), ndev=self.ndev,
                                    keep=self.cfg.keep)
            self.snapshots_written += 1
        return session, results

    def stats(self) -> dict:
        """Restart/snapshot counters, recovery times, heartbeat ages and
        the straggler watchdog report (same shape as
        ``TrainSupervisor.stats()``'s, reported side by side)."""
        times = sorted(self.step_times)
        return {
            "restarts": self.restarts,
            "snapshots_written": self.snapshots_written,
            "snapshots_restored": self.snapshots_restored,
            "snapshots_corrupted": self.snapshots_corrupted,
            "corrupt_skipped": self.corrupt_skipped,
            "recover_seconds": list(self.recover_seconds),
            "ndev": self.ndev,
            "k": self.k,
            "resized_on_restore": self.resized_on_restore,
            "heartbeat_ages": self.heartbeat_ages(),
            "stale_workers": self.stale_workers(),
            "straggler": {
                "steps": len(self.step_times),
                "median_step_time": (times[len(times) // 2]
                                     if times else None),
                "straggler_factor": self.cfg.straggler_factor,
                "flagged_steps": list(self.flagged_steps),
            },
        }


# ---------------------------------------------------------------------------
# Process-level supervisor (real subprocess workers)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ProcessClusterConfig:
    workdir: str
    num_processes: int = 2
    devices_per_process: int = 1
    heartbeat_deadline: float = 60.0
    poll_interval: float = 0.25
    max_restarts: int = 2
    spawn_grace: float = 120.0     # allow slow jax import before beats


class ProcessClusterSupervisor:
    """Generation manager for real coordinator/worker OS processes.

    Each generation: pick a fresh coordinator port, spawn ``world``
    workers (process 0 doubles as coordinator), then watch.  A worker
    that exits nonzero or whose heartbeat file goes stale is declared
    dead; the whole generation is killed (synchronous supersteps cannot
    outlive a peer) and the next one respawns with the survivors'
    count.  Workers resume from the newest snapshot in
    ``<workdir>/snaps`` -- written by the generation's coordinator --
    so recovery needs zero human intervention.
    """

    def __init__(self, cfg: ProcessClusterConfig, job: dict):
        self.cfg = cfg
        self.job = dict(job)
        self.restarts = 0
        self.generations: List[dict] = []
        self.recover_seconds: List[float] = []
        os.makedirs(cfg.workdir, exist_ok=True)
        os.makedirs(os.path.join(cfg.workdir, "hb"), exist_ok=True)

    def _write_job(self) -> None:
        import json
        with open(os.path.join(self.cfg.workdir, "job.json"), "w") as f:
            json.dump(self.job, f)

    def _hb_age(self, gen: int, pid: int, now: float) -> Optional[float]:
        path = os.path.join(self.cfg.workdir, "hb", f"g{gen}_p{pid}")
        try:
            return now - os.path.getmtime(path)
        except OSError:
            return None                       # not born yet

    def _watch(self, gen: int, procs: list, started: float) -> List[int]:
        """Block until the generation finishes; returns the list of
        dead pids ([] = clean success)."""
        while True:
            time.sleep(self.cfg.poll_interval)
            now = time.monotonic()
            rcs = [p.poll() for p in procs]
            dead = [i for i, rc in enumerate(rcs)
                    if rc is not None and rc != 0]
            if dead:
                return dead
            if all(rc == 0 for rc in rcs):
                return []
            if now - started > self.cfg.spawn_grace:
                stale = [i for i, rc in enumerate(rcs) if rc is None
                         and (self._hb_age(gen, i, now) or 0)
                         > self.cfg.heartbeat_deadline]
                if stale:
                    return stale

    def _kill_all(self, procs: list) -> None:
        for p in procs:
            if p.poll() is None:
                try:
                    p.send_signal(signal.SIGKILL)
                except OSError:
                    pass
        for p in procs:
            try:
                p.wait(timeout=30)
            except Exception:
                pass

    def run(self) -> dict:
        """Run generations until the job completes; returns stats plus
        the job's result.json payload."""
        import json
        self._write_job()
        world = self.cfg.num_processes
        gen = 0
        while True:
            port = free_port()
            started = time.monotonic()
            procs = [spawn_local_worker(
                workdir=self.cfg.workdir, gen=gen, world=world, pid=p,
                port=port,
                devices_per_process=self.cfg.devices_per_process)
                for p in range(world)]
            dead = self._watch(gen, procs, started)
            self._kill_all(procs)
            self.generations.append({"gen": gen, "world": world,
                                     "port": port, "dead": dead,
                                     "seconds": time.monotonic() - started})
            if not dead:
                break
            if self.restarts >= self.cfg.max_restarts:
                raise WorkerLost(
                    f"generation {gen}: workers {dead} died and restart "
                    f"budget ({self.cfg.max_restarts}) is exhausted")
            t0 = time.monotonic()
            self.restarts += 1
            world = max(1, world - len(dead))
            gen += 1
            self.recover_seconds.append(time.monotonic() - t0)
        with open(os.path.join(self.cfg.workdir, "result.json")) as f:
            result = json.load(f)
        return {"result": result, "restarts": self.restarts,
                "generations": self.generations,
                "recover_seconds": self.recover_seconds}
