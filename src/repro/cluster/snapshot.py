"""Checkpointed PartitionSession state, restorable onto different capacity.

Snapshot format (one ``repro.ckpt`` checkpoint per snapshot, so writes
are atomic: tmp dir + rename; a crash mid-save never corrupts the
newest complete snapshot, and the next writer-side call --
``ckpt.checkpoint.save`` / ``gc_old`` -- sweeps the stale tmp)::

    <dir>/step_<n>/            n = work items (or iterations) completed
        labels.npy             (V,) int32 previous stable assignment
        loads.npy              (k,) f32 loads those labels imply
        rng_key.npy            (2,) uint32 -- PRNGKey(cfg.seed); recorded
                               for audit (runs re-derive it from seed)
        runs.npy               int64 session run counter
        delta_watermark.npy    int64 delta batches the labels reflect
        k.npy / num_vertices.npy     int64 cross-checks
        ndev.npy               int64 device count at save time
        cfg__*.npy             SpinnerConfig scalars (see _CFG_FIELDS);
                               migration_weighting stored as an index
        snap_version.npy       format version

Restore (:func:`restore_session`) opens a fresh session on the rebuilt
graph with the SAVED config and imports the labels.  If the restore
capacity differs from ``ndev`` at save, the elastic path replays: the
partition count is rescaled proportionally (keeping partitions/device
constant, the paper's "adapting to changes in the compute environment")
and ``session.resize(k_new)`` runs Eq. 10's probabilistic relabel plus
one reconvergence.  Same-capacity restores run nothing: every session
run is a deterministic function of (graph, cfg, prev labels), so the
continuation is bit-identical to an uninterrupted run.

Corrupt snapshots (a fault-injection hook deletes files, or a real
half-written directory) are detected by the read failing and skipped:
:func:`newest_complete` walks steps newest-first and returns the first
one that loads.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, List, Optional, Tuple

import msgpack
import numpy as np

from repro.ckpt import checkpoint

SNAP_VERSION = 1

# SpinnerConfig scalars a snapshot carries; enums stored as indices
_CFG_FIELDS = ("c", "eps", "halt_window", "max_iters", "seed",
               "tie_noise", "current_bonus")
_WEIGHTINGS = ("edges", "vertices")


def snapshot_tree(session, *, ndev: int) -> dict:
    """The flat pytree :func:`save_snapshot` writes: the session's
    ``export_state()`` surface plus the config scalars and the save-time
    device count (what elastic restore compares against)."""
    tree = session.export_state()
    cfg = session.cfg
    for f in _CFG_FIELDS:
        tree[f"cfg__{f}"] = np.float64(getattr(cfg, f))
    tree["cfg__migration_weighting"] = np.int64(
        _WEIGHTINGS.index(cfg.migration_weighting))
    tree["ndev"] = np.int64(ndev)
    tree["snap_version"] = np.int64(SNAP_VERSION)
    return tree


def save_snapshot(directory: str, session, step: int, *,
                  ndev: Optional[int] = None,
                  keep: Optional[int] = None) -> str:
    """Atomically write the session's state as snapshot ``step``.

    ``ndev`` defaults to the session's mesh width (1 off-mesh); ``keep``
    garbage-collects all but the newest ``keep`` snapshots."""
    if ndev is None:
        opts = session.options
        ndev = (opts.mesh.shape[opts.axis]
                if getattr(opts, "mesh", None) is not None else 1)
    path = checkpoint.save(directory, step, snapshot_tree(session,
                                                          ndev=ndev))
    if keep is not None:
        checkpoint.gc_old(directory, keep=keep)
    return path


def snapshot_steps(directory: str) -> List[int]:
    """All complete snapshot steps, ascending (tmp dirs excluded)."""
    if not os.path.isdir(directory):
        return []
    return sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                  if d.startswith("step_") and not d.endswith(".tmp"))


def load_snapshot(directory: str, step: int) -> dict:
    """Read one snapshot's flat tree (raises on a corrupt/missing one).

    Reads the ckpt layout directly -- manifest + per-key ``.npy`` --
    because the tree's leaf shapes (V, k) are not known before reading,
    which ``checkpoint.restore``'s ``like=`` contract requires."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    tree = {}
    for entry in manifest["keys"]:
        arr = np.load(os.path.join(path, entry["file"]))
        if tuple(arr.shape) != tuple(entry["shape"]):
            raise IOError(f"snapshot {path} corrupt: {entry['key']} has "
                          f"shape {arr.shape}, manifest says "
                          f"{entry['shape']}")
        tree[entry["key"]] = arr
    missing = {"labels", "loads", "k", "ndev"} - tree.keys()
    if missing:
        raise IOError(f"snapshot {path} corrupt: missing {sorted(missing)}")
    return tree


def newest_complete(directory: str, step: Optional[int] = None,
                    on_corrupt: Optional[Callable[[int, Exception], None]]
                    = None) -> Tuple[int, dict]:
    """The newest snapshot that actually loads, walking backwards past
    corrupt ones (``on_corrupt(step, err)`` observes each skip -- the
    supervisor counts them).  Raises ``FileNotFoundError`` when none
    survive."""
    steps = snapshot_steps(directory)
    if step is not None:
        steps = [s for s in steps if s <= step]
    for s in reversed(steps):
        try:
            return s, load_snapshot(directory, s)
        except Exception as e:
            if on_corrupt is not None:
                on_corrupt(s, e)
    raise FileNotFoundError(f"no complete snapshot in {directory}")


def decode_cfg(tree: dict):
    """The SpinnerConfig the snapshot was taken under."""
    from repro.core.spinner import SpinnerConfig
    kw = {
        "k": int(tree["k"]),
        "halt_window": int(tree["cfg__halt_window"]),
        "max_iters": int(tree["cfg__max_iters"]),
        "seed": int(tree["cfg__seed"]),
        "migration_weighting": _WEIGHTINGS[
            int(tree["cfg__migration_weighting"])],
    }
    for f in ("c", "eps", "tie_noise", "current_bonus"):
        kw[f] = float(tree[f"cfg__{f}"])
    return SpinnerConfig(**kw)


@dataclasses.dataclass
class RestoreInfo:
    """What :func:`restore_session` did."""
    session: object
    step: int                      # snapshot step restored
    saved_ndev: int                # capacity at save time
    ndev: int                      # capacity restored onto
    k_saved: int
    k: int                         # k after any elastic rescale
    resized: bool                  # True: resize() replayed on restore
    result: object = None          # the resize reconvergence result
    corrupt_skipped: int = 0


def restore_session(directory: str, graph, *, options=None,
                    ndev: Optional[int] = None, k: Optional[int] = None,
                    step: Optional[int] = None,
                    scale_k: bool = True) -> RestoreInfo:
    """Rebuild a live session from the newest complete snapshot.

    ``graph`` is the durable graph at (or past) the snapshot's delta
    watermark -- rebuilt from edge shards or base inputs; snapshots
    never carry O(E) state.  ``ndev`` is the capacity being restored
    onto (default: ``options.mesh`` width, else 1).  When it differs
    from the save-time capacity and ``scale_k`` is set, ``k`` rescales
    proportionally (partitions/device preserved, minimum 1) and the
    elastic ``resize`` replays -- Eq. 10 relabel + reconvergence on the
    new capacity.  Pass ``k=`` to pin the target explicitly.
    """
    from repro.core.session import PartitionSession
    skipped = []
    s, tree = newest_complete(directory, step,
                              on_corrupt=lambda st, e: skipped.append(st))
    cfg = decode_cfg(tree)
    if ndev is None:
        ndev = (options.mesh.shape[options.axis]
                if options is not None
                and getattr(options, "mesh", None) is not None else 1)
    saved_ndev = int(tree["ndev"])
    session = PartitionSession(graph, cfg, options)
    session.import_state(tree)
    k_target = k
    if k_target is None:
        k_target = cfg.k
        if scale_k and ndev != saved_ndev:
            k_target = max(1, round(cfg.k * ndev / saved_ndev))
    result, resized = None, False
    if k_target != cfg.k:
        result = session.resize(k_target, record_history=False)
        resized = True
    return RestoreInfo(session=session, step=s, saved_ndev=saved_ndev,
                       ndev=ndev, k_saved=cfg.k, k=k_target,
                       resized=resized, result=result,
                       corrupt_skipped=len(skipped))
