"""Multi-process cluster bring-up: distributed init, meshes, edge shards.

The paper's § dynamicity scenario is a partitioner running on elastic,
unreliable cloud capacity.  This module stands the capacity up:

* :func:`bootstrap` wraps ``jax.distributed.initialize`` (coordinator +
  N worker processes, each with forced host devices on CPU) and returns
  a :class:`ClusterHandle` exposing the process-local and the
  process-spanning mesh plus the coordination-service primitives (a
  distributed KV store and named barriers) every process can use for
  control-plane traffic.

* :func:`write_edge_shards` / :func:`load_edge_shard` are the per-host
  graph loading path: the directed edge list is split by owning host
  (owner = ``src // v_per_host``, the same range partition
  ``core.distributed.shard_graph`` uses) into one ``.npz`` file per
  host plus a manifest carrying the O(V) vertex state (``deg_w``) and
  the globally agreed raw segment widths.  A worker loads ONLY its
  file and builds its layout row with
  ``shard_graph(view, ndev, local_only=pid, seg_widths=...)`` -- no
  process ever materializes the full O(E) edge set.

* :func:`spawn_local_worker` / :func:`free_port` subprocess-spawn a
  local coordinator + workers for tests and CI (each process pinned to
  its own forced-host-device count via ``XLA_FLAGS``).

Backend note (determined empirically on jax 0.4.37 / CPU): after
``jax.distributed.initialize`` the global device view spans processes
and the coordination service (KV store, barriers) works fully, but
cross-process XLA *computations* raise ``INVALID_ARGUMENT:
Multiprocess computations aren't implemented on the CPU backend``.  So
:meth:`ClusterHandle.global_mesh` is constructible everywhere (and
executable on TPU/GPU backends), while the CPU cluster runtime
(``repro.cluster.worker``) computes on each process's local mesh and
exchanges labels/aggregates through the coordination service.
"""
from __future__ import annotations

import base64
import dataclasses
import json
import os
import socket
import subprocess
import sys
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

REPO_SRC = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))


# ---------------------------------------------------------------------------
# Distributed init + handle
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ClusterConfig:
    """One process's view of the cluster."""
    coordinator_address: str = "127.0.0.1"
    port: int = 0
    num_processes: int = 1
    process_id: int = 0
    # default timeout for blocking KV reads / barriers (seconds); a dead
    # peer surfaces as a timeout here, converted to PeerLost by callers
    rpc_timeout: float = 60.0
    # blocking KV reads wait in slices of this length so the handle's
    # ``on_wait`` hook (the worker's heartbeat) fires while a superstep
    # legitimately blocks on a slow peer -- a live waiter must not look
    # stale to the process supervisor
    poll_slice: float = 5.0

    @property
    def coordinator(self) -> str:
        return f"{self.coordinator_address}:{self.port}"


class PeerLost(RuntimeError):
    """A blocking coordination read timed out -- a peer is presumed dead."""


class ClusterHandle:
    """The live cluster from one process's perspective.

    Wraps the ``jax.distributed`` coordination client: ``kv_put`` /
    ``kv_get`` move small control-plane strings (the CPU worker loop
    encodes label slices and (k,) aggregates through them), ``barrier``
    synchronizes named points, and the mesh accessors build the local
    and the process-spanning device meshes.
    """

    def __init__(self, cfg: ClusterConfig):
        self.cfg = cfg
        import jax
        self._jax = jax
        self.process_id = jax.process_index() if cfg.num_processes > 1 \
            else cfg.process_id
        self.num_processes = cfg.num_processes
        # called between blocking-wait slices in kv_get (the worker
        # binds its heartbeat here): a process still polling the
        # coordination service is alive, however slow its peers are
        self.on_wait: Optional[callable] = None

    # -- meshes ------------------------------------------------------------

    def local_mesh(self, axis: str = "data"):
        """Mesh over THIS process's devices (always executable)."""
        from repro.launch.mesh import make_partition_mesh
        return make_partition_mesh(devices=self._jax.local_devices(),
                                   axis=axis)

    def global_mesh(self, axis: str = "data"):
        """Process-spanning mesh over ``jax.devices()``.

        Constructible on every backend; cross-process execution requires
        an accelerator backend (see the module docstring for the CPU
        limitation).
        """
        from repro.launch.mesh import make_partition_mesh
        return make_partition_mesh(devices=self._jax.devices(), axis=axis)

    # -- coordination service ---------------------------------------------

    @property
    def _client(self):
        from jax._src.distributed import global_state
        client = global_state.client
        if client is None:
            raise RuntimeError("jax.distributed is not initialized; "
                               "call bootstrap() first")
        return client

    def kv_put(self, key: str, value: str) -> None:
        self._client.key_value_set(key, value)

    def kv_get(self, key: str, timeout: Optional[float] = None) -> str:
        """Blocking read with the full ``rpc_timeout`` budget, waited in
        ``poll_slice``-length slices with ``on_wait()`` fired between
        them -- so a worker blocked on a slow peer keeps heartbeating
        and is not misdeclared stale by the process supervisor."""
        total = self.cfg.rpc_timeout if timeout is None else timeout
        deadline = time.monotonic() + total
        err: Optional[Exception] = None
        while True:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise PeerLost(f"kv_get({key!r}) timed out after "
                               f"{total}s: {err}") from err
            ms = max(1, int(1000 * min(self.cfg.poll_slice, remaining)))
            t_slice = time.monotonic()
            try:
                return self._client.blocking_key_value_get(key, ms)
            except Exception as e:                  # XlaRuntimeError etc.
                err = e
                # a non-timeout failure (service down) returns instantly:
                # don't spin hot while the deadline runs out
                if time.monotonic() - t_slice < 0.05:
                    time.sleep(0.05)
            if self.on_wait is not None:
                self.on_wait()

    def kv_put_array(self, key: str, arr: np.ndarray) -> None:
        self.kv_put(key, base64.b64encode(
            np.ascontiguousarray(arr).tobytes()).decode("ascii"))

    def kv_get_array(self, key: str, dtype, shape,
                     timeout: Optional[float] = None) -> np.ndarray:
        raw = base64.b64decode(self.kv_get(key, timeout))
        return np.frombuffer(raw, dtype=dtype).reshape(shape).copy()

    def allreduce_sum(self, tag: str, arr: np.ndarray,
                      timeout: Optional[float] = None) -> np.ndarray:
        """Sum ``arr`` across all processes through the KV store.

        Every process publishes its contribution under a unique
        ``tag/pid`` key and reads all peers' -- one logical collective
        per (iteration, call-site) tag.  O(world) small messages; this
        is control-plane math (the (k,) aggregators and halting
        scalars), not the O(V) data plane.
        """
        arr = np.asarray(arr)
        self.kv_put_array(f"{tag}/{self.process_id}", arr)
        total = np.zeros_like(arr)
        for q in range(self.num_processes):
            total = total + self.kv_get_array(
                f"{tag}/{q}", arr.dtype, arr.shape, timeout)
        return total

    def kv_delete(self, key: str) -> None:
        """Best-effort delete of ``key`` (a trailing ``/`` deletes the
        whole prefix).  The worker GCs iteration ``t-1``'s label/reduce
        keys once iteration ``t``'s allreduce proves every peer is past
        them, bounding coordinator memory to O(V) live keys instead of
        O(V x iterations).  A no-op on runtimes without
        ``key_value_delete``; GC must never kill a worker."""
        try:
            delete = getattr(self._client, "key_value_delete", None)
            if delete is not None:
                delete(key)
        except Exception:
            pass

    def barrier(self, name: str, timeout: Optional[float] = None) -> None:
        ms = int(1000 * (self.cfg.rpc_timeout if timeout is None
                         else timeout))
        try:
            self._client.wait_at_barrier(name, ms)
        except Exception as e:
            raise PeerLost(f"barrier({name!r}) timed out: {e}") from e

    def shutdown(self) -> None:
        try:
            self._jax.distributed.shutdown()
        except Exception:
            pass


def bootstrap(cfg: ClusterConfig) -> ClusterHandle:
    """Initialize ``jax.distributed`` for this process and return the
    handle.  Idempotent per process: a second call with the same config
    returns a fresh handle over the existing service.  Single-process
    configs skip distributed init entirely (the handle's coordination
    surface then requires ``num_processes > 1``; the worker loop guards
    on ``world == 1``)."""
    import jax
    if cfg.num_processes > 1:
        from jax._src.distributed import global_state
        if global_state.client is None:
            jax.distributed.initialize(
                coordinator_address=cfg.coordinator,
                num_processes=cfg.num_processes,
                process_id=cfg.process_id)
    return ClusterHandle(cfg)


def free_port() -> int:
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


# ---------------------------------------------------------------------------
# Per-host edge shards
# ---------------------------------------------------------------------------

_MANIFEST = "manifest.json"


def write_edge_shards(graph, directory: str, num_hosts: int) -> dict:
    """Split a graph's directed edge list into per-host files.

    Layout on disk (the durable graph the cluster boots from)::

        <dir>/manifest.json   num_vertices, num_hosts, v_per_host,
                              total_weight, seg widths, per-host counts
        <dir>/deg_w.npy       full (V,) weighted degrees (O(V) state)
        <dir>/shard_<h>.npz   src/dst/weight of edges with owner h

    Owner = ``src // v_per_host`` -- the identical range partition
    ``shard_graph`` applies, so host ``h``'s file feeds
    ``shard_graph(view, num_hosts, local_only=h, seg_widths=...)`` and
    reproduces row ``h`` of the full layout byte-for-byte.  The raw
    (max-over-hosts) interior/frontier segment widths are computed here
    once, while the whole edge list is still in one place, and recorded
    in the manifest: that is the only global agreement hosts need to
    build compile-shape-compatible rows independently.
    """
    os.makedirs(directory, exist_ok=True)
    v_per_host = -(-graph.num_vertices // num_hosts)
    real = graph.weight > 0
    src, dst, w = graph.src[real], graph.dst[real], graph.weight[real]
    owner = src // v_per_host
    frontier = (dst // v_per_host) != owner
    n_int = np.bincount(owner[~frontier],
                        minlength=num_hosts).astype(np.int64)
    n_fro = np.bincount(owner[frontier],
                        minlength=num_hosts).astype(np.int64)
    for h in range(num_hosts):
        sel = owner == h
        np.savez(os.path.join(directory, f"shard_{h}.npz"),
                 src=src[sel].astype(np.int32),
                 dst=dst[sel].astype(np.int32),
                 weight=w[sel].astype(np.float32))
    np.save(os.path.join(directory, "deg_w.npy"),
            np.asarray(graph.deg_w, np.float32))
    manifest = {
        "num_vertices": int(graph.num_vertices),
        "num_hosts": int(num_hosts),
        "v_per_host": int(v_per_host),
        "total_weight": float(graph.total_weight),
        "seg_interior": int(n_int.max()) if n_int.size else 0,
        "seg_frontier": int(n_fro.max()) if n_fro.size else 0,
        "interior_counts": [int(x) for x in n_int],
        "frontier_counts": [int(x) for x in n_fro],
    }
    with open(os.path.join(directory, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    return manifest


def read_manifest(directory: str) -> dict:
    with open(os.path.join(directory, _MANIFEST)) as f:
        return json.load(f)


def load_edge_shard(directory: str, host: int):
    """One host's :class:`~repro.core.distributed.EdgeShardView`: its
    edge file plus the shared O(V) degree vector -- never the full edge
    set.  Returns ``(view, manifest)``."""
    from repro.core.distributed import EdgeShardView
    manifest = read_manifest(directory)
    z = np.load(os.path.join(directory, f"shard_{host}.npz"))
    deg_w = np.load(os.path.join(directory, "deg_w.npy"))
    view = EdgeShardView(num_vertices=manifest["num_vertices"],
                         src=z["src"], dst=z["dst"], weight=z["weight"],
                         deg_w=deg_w)
    return view, manifest


def load_local_shard(directory: str, host: int, pad: bool = False):
    """Host ``host``'s single-row ``ShardedGraph`` built from its edge
    file alone (the ``local_only`` path), layout-compatible with every
    other host's row via the manifest's agreed segment widths."""
    from repro.core.distributed import shard_graph
    view, manifest = load_edge_shard(directory, host)
    return shard_graph(view, manifest["num_hosts"], pad=pad,
                       local_only=host,
                       seg_widths=(manifest["seg_interior"],
                                   manifest["seg_frontier"]))


# ---------------------------------------------------------------------------
# Local subprocess spawning (tests / CI)
# ---------------------------------------------------------------------------

def worker_env(*, devices_per_process: int = 1,
               extra: Optional[Dict[str, str]] = None) -> Dict[str, str]:
    """Environment for a spawned worker: forced host devices + src on
    the path; ``extra`` entries win."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{devices_per_process}")
    env["PYTHONPATH"] = REPO_SRC + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    if extra:
        env.update(extra)
    return env


def spawn_local_worker(*, workdir: str, gen: int, world: int, pid: int,
                       port: int, devices_per_process: int = 1,
                       extra_env: Optional[Dict[str, str]] = None
                       ) -> subprocess.Popen:
    """Spawn one cluster worker process (``python -m
    repro.cluster.worker``) for the local coordinator/worker topology.
    Process 0 is the coordinator; all read ``<workdir>/job.json``."""
    argv = [sys.executable, "-m", "repro.cluster.worker",
            "--workdir", workdir, "--gen", str(gen),
            "--world", str(world), "--pid", str(pid),
            "--port", str(port)]
    out = open(os.path.join(workdir, f"worker_g{gen}_p{pid}.log"), "wb")
    return subprocess.Popen(argv,
                            env=worker_env(
                                devices_per_process=devices_per_process,
                                extra=extra_env),
                            stdout=out, stderr=subprocess.STDOUT)
