import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x shape x mesh) cell lowers,
compiles, and is shardable on the production meshes -- with no allocation.

Per cell this script records, as JSON:
  * memory_analysis(): per-device argument/output/temp/alias bytes,
  * cost_analysis(): per-device HLO FLOPs and bytes accessed,
  * the collective schedule: per-op-kind operand bytes and counts parsed
    from the compiled HLO (feeds the roofline's collective term).

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out benchmarks/artifacts
"""
import argparse      # noqa: E402
import gzip          # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCHS, SHAPES_BY_NAME, cell_is_runnable  # noqa: E402
from repro.launch.hlo_analysis import analyze  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import build, input_specs         # noqa: E402
from repro.optim import adamw                        # noqa: E402
from repro.parallel import rules                     # noqa: E402
from repro.train import steps                        # noqa: E402

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_COLL_RE = re.compile(
    r"=\s*(?P<type>\([^=]*?\)|\S+)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-device result bytes per collective kind, from compiled HLO.

    all-reduce is charged 2x (ring = reduce-scatter + all-gather phases);
    ``-done`` ops are skipped to avoid double-counting async pairs.
    """
    stats = {}
    for m in _COLL_RE.finditer(hlo_text):
        op = m.group("op")
        nbytes = _type_bytes(m.group("type"))
        if op == "all-reduce":
            nbytes *= 2
        e = stats.setdefault(op, {"count": 0, "bytes": 0})
        e["count"] += 1
        e["bytes"] += nbytes
    stats["total_bytes"] = sum(v["bytes"] for k, v in stats.items()
                               if isinstance(v, dict))
    return stats


def _make_fn_and_args(arch: str, shape_name: str, mesh,
                      variant: str = "base"):
    """Returns (fn, arg_specs, in_shardings, out_shardings, donate)."""
    cfg = ARCHS[arch]
    if variant == "opt":
        cfg = cfg.optimized()
    elif variant.startswith("knob:"):
        # e.g. knob:cast_params_before_scan=True,ce_chunked=512
        import dataclasses as _dc
        kv = {}
        for part in variant[5:].split(","):
            k, v = part.split("=")
            kv[k] = eval(v)  # ints/bools/strings from trusted CLI
        cfg = _dc.replace(cfg, **kv)
    shape = SHAPES_BY_NAME[shape_name]
    api = build(cfg)
    batch_specs, cache_specs = input_specs(cfg, shape)
    p_sh = rules.param_shardings(api.param_specs, mesh)
    b_sh = rules.batch_shardings(batch_specs, mesh)

    if shape.kind == "train":
        state_specs = steps.train_state_specs(api.param_specs)
        state_sh = steps.TrainState(params=p_sh,
                                    opt=adamw.AdamWState(
                                        step=rules.replicated(mesh),
                                        m=p_sh, v=p_sh),
                                    step=rules.replicated(mesh))
        opt_cfg = adamw.AdamWConfig()
        fn = steps.make_train_step(api, opt_cfg)
        return (fn, (state_specs, batch_specs), (state_sh, b_sh),
                (state_sh, None), (0,))
    if shape.kind == "prefill":
        fn = steps.make_prefill_step(api)
        return (fn, (api.param_specs, batch_specs), (p_sh, b_sh),
                None, ())
    # decode
    c_sh = rules.cache_shardings(cache_specs, mesh, shape.global_batch)
    fn = steps.make_decode_step(api)
    return (fn, (api.param_specs, batch_specs, cache_specs),
            (p_sh, b_sh, c_sh), (None, c_sh), (2,))


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             hlo_dir: str = None, variant: str = "base") -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {"arch": arch, "shape": shape_name, "variant": variant,
           "mesh": "pod2x16x16" if multi_pod else "pod16x16",
           "n_devices": mesh.size}
    t0 = time.time()
    fn, arg_specs, in_sh, out_sh, donate = _make_fn_and_args(
        arch, shape_name, mesh, variant)
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*arg_specs)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)
    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
    }
    ca = compiled.cost_analysis() or {}
    rec["flops"] = float(ca.get("flops", 0.0))
    rec["bytes_accessed"] = float(ca.get("bytes accessed", 0.0))
    hlo_text = compiled.as_text()
    if hlo_dir:
        os.makedirs(hlo_dir, exist_ok=True)
        vtag = "" if variant == "base" else f"__{variant.replace(':','-').replace(',','-').replace('=','-')}"
        tag = (f"{arch}__{shape_name}__"
               f"{'multi' if multi_pod else 'single'}{vtag}.hlo.gz")
        with gzip.open(os.path.join(hlo_dir, tag), "wt") as f:
            f.write(hlo_text)
    rec["collectives"] = collective_stats(hlo_text)
    t2 = time.time()
    rec["analyzed"] = analyze(hlo_text)   # trip-count-weighted (see module)
    rec["analyze_s"] = round(time.time() - t2, 2)
    api = build(ARCHS[arch])
    rec["num_params"] = api.num_params
    rec["num_active_params"] = api.num_active_params
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", default="base",
                    help="base | opt | knob:field=value,...")
    args = ap.parse_args()

    cells = []
    archs = list(ARCHS) if (args.all or args.arch is None) else [args.arch]
    shapes = (list(SHAPES_BY_NAME) if (args.all or args.shape is None)
              else [args.shape])
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    for a in archs:
        for s in shapes:
            if not cell_is_runnable(ARCHS[a], SHAPES_BY_NAME[s]):
                continue
            for mp in meshes:
                cells.append((a, s, mp))

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for a, s, mp in cells:
        vtag = ("" if args.variant == "base" else
                "__" + args.variant.replace(":", "-").replace(",", "-")
                .replace("=", "-"))
        tag = f"{a}__{s}__{'multi' if mp else 'single'}{vtag}"
        path = os.path.join(args.out, tag + ".json")
        if args.skip_existing and os.path.exists(path):
            print(f"skip {tag}")
            continue
        try:
            rec = run_cell(a, s, mp, hlo_dir=os.path.join(args.out, "hlo"),
                           variant=args.variant)
            status = "OK"
        except Exception as e:  # record the failure; the suite must be green
            rec = {"arch": a, "shape": s,
                   "mesh": "multi" if mp else "single",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()}
            status = "FAIL"
            failures += 1
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        extra = ""
        if status == "OK":
            gb = (rec["memory"]["argument_bytes"]
                  + rec["memory"]["temp_bytes"]) / 2**30
            extra = (f" lower={rec['lower_s']}s compile={rec['compile_s']}s "
                     f"mem/dev={gb:.1f}GiB "
                     f"dotflops={rec['analyzed']['dot_flops']:.3g} "
                     f"hbm={rec['analyzed']['hbm_bytes']:.3g} "
                     f"coll={rec['analyzed']['collective_bytes']:.3g}B")
        print(f"{status} {tag}{extra}", flush=True)
    print(f"done: {len(cells)} cells, {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
