"""Trip-count-aware analysis of compiled HLO modules.

XLA's ``cost_analysis()`` counts a ``while`` body ONCE, so any scanned
(layer-stacked) model is undercounted by ~the layer count.  This module
re-derives roofline inputs from ``compiled.as_text()`` with correct loop
weighting:

  * dot FLOPs       2 * prod(result dims) * prod(contracting dims), per dot,
                    weighted by the product of enclosing-loop trip counts
                    (``known_trip_count`` from the backend config).
  * HBM bytes       per top-level instruction: result + operand bytes
                    (fusions as single units; in-place dynamic-update-slice
                    fusions charged update-size, not buffer-size).
  * collective bytes / counts   per op kind, trip-weighted; all-reduce
                    charged 2x (ring reduce-scatter + all-gather phases).

This is an approximation of a real TPU profile (fusion boundaries on the
CPU backend differ from TPU), but loop structure, dots, and the collective
schedule are decided before backend-specific fusion, so the big terms
carry over.  See EXPERIMENTS.md for validation against analytic FLOPs.
"""
from __future__ import annotations

import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->.*{\s*$")
_INSTR_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(.*?\)|\S+)\s+([\w\-]+)"
    r"\(([^)]*)\)(.*)$")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_SIG_RE = re.compile(r"%?([\w\.\-]+)\s*:\s*([^,()]+(?:\([^)]*\))?)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


class Instr:
    __slots__ = ("name", "type", "op", "args", "attrs")

    def __init__(self, name, type_, op, args, attrs):
        self.name = name
        self.type = type_
        self.op = op
        self.args = args
        self.attrs = attrs


def parse_module(hlo: str):
    """-> (computations: {name: [Instr]}, entry_name, symtab {comp: {name: type}})."""
    comps: Dict[str, List[Instr]] = {}
    symtab: Dict[str, Dict[str, str]] = {}
    entry = None
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m:
                cur = m.group(2)
                comps[cur] = []
                symtab[cur] = {}
                if m.group(1):
                    entry = cur
                for pname, ptype in _SIG_RE.findall(m.group(3)):
                    symtab[cur][pname] = ptype.strip()
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            ins = Instr(m.group(1), m.group(2), m.group(3), m.group(4),
                        m.group(5))
            comps[cur].append(ins)
            symtab[cur][ins.name] = ins.type
    return comps, entry, symtab


def _multipliers(comps, entry) -> Dict[str, float]:
    """Execution-count multiplier per computation (trip-count weighted)."""
    mult: Dict[str, float] = {c: 0.0 for c in comps}
    mult[entry] = 1.0
    # callees are defined before callers; walk callers in definition order
    order = list(comps.keys())
    for comp in reversed(order):
        m = mult.get(comp, 0.0)
        if m == 0.0:
            continue
        for ins in comps[comp]:
            if ins.op == "while":
                trips = 1
                tm = _TRIP_RE.search(ins.attrs)
                if tm:
                    trips = int(tm.group(1))
                bm = _BODY_RE.search(ins.attrs)
                cm = _COND_RE.search(ins.attrs)
                if bm:
                    mult[bm.group(1)] = mult.get(bm.group(1), 0.0) + m * trips
                if cm:
                    mult[cm.group(1)] = mult.get(cm.group(1), 0.0) \
                        + m * (trips + 1)
            else:
                for rx in (_CALLS_RE, _TO_APPLY_RE):
                    mm = rx.search(ins.attrs)
                    if mm:
                        mult[mm.group(1)] = mult.get(mm.group(1), 0.0) + m
    return mult


def _dot_flops(ins: Instr, syms: Dict[str, str]) -> float:
    out_dims = _shape_dims(ins.type)
    lhs_name = ins.args.split(",")[0].strip().lstrip("%")
    lhs_type = syms.get(lhs_name, "")
    lhs_dims = _shape_dims(lhs_type)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
    contract = 1
    if m and lhs_dims:
        for idx in m.group(1).split(","):
            if idx:
                contract *= lhs_dims[int(idx)]
    n = 1
    for d in out_dims:
        n *= d
    return 2.0 * n * contract


_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "while", "conditional", "call",
                   "after-all", "iota"}


def analyze(hlo: str) -> dict:
    comps, entry, symtab = parse_module(hlo)
    mult = _multipliers(comps, entry)
    fusion_root: Dict[str, str] = {}
    for cname, instrs in comps.items():
        if instrs:
            fusion_root[cname] = instrs[-1].op

    dot_flops = 0.0
    hbm_bytes = 0.0       # every top-level instruction's I/O (CPU-fusion
                          # granularity; upper bound for a TPU)
    tpu_bytes = 0.0       # dot/scatter/gather/DUS/copy/collective I/O only
                          # (assumes XLA-TPU fuses all elementwise chains)
    by_op: Dict[str, float] = {}
    coll: Dict[str, dict] = {}
    # fused computations are charged through their fusion instruction for
    # bytes, but their dots count at the fusion's multiplier
    fused_names = set()
    for cname, instrs in comps.items():
        for ins in instrs:
            cm = _CALLS_RE.search(ins.attrs)
            if ins.op == "fusion" and cm:
                fused_names.add(cm.group(1))

    for cname, instrs in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        syms = symtab[cname]
        in_fused = cname in fused_names
        for ins in instrs:
            if ins.op == "dot":
                dot_flops += m * _dot_flops(ins, syms)
            if ins.op in COLLECTIVES or (
                    ins.op.endswith("-start")
                    and ins.op[:-6] in COLLECTIVES):
                op = ins.op[:-6] if ins.op.endswith("-start") else ins.op
                nbytes = type_bytes(ins.type)
                if op == "all-reduce":
                    nbytes *= 2
                e = coll.setdefault(op, {"count": 0.0, "bytes": 0.0})
                e["count"] += m
                e["bytes"] += m * nbytes
            if in_fused or ins.op in _SKIP_BYTES_OPS:
                continue
            # HBM traffic estimate
            operand_names = [a.strip().lstrip("%")
                             for a in ins.args.split(",") if a.strip()]
            op_bytes = [type_bytes(syms.get(nm, "")) for nm in operand_names]
            res = type_bytes(ins.type)
            if ins.op == "dynamic-update-slice":
                upd = op_bytes[1] if len(op_bytes) > 1 else 0
                hbm_bytes += m * 2 * upd
                tpu_bytes += m * 2 * upd
                by_op["dus"] = by_op.get("dus", 0.0) + m * 2 * upd
                continue
            root = None
            if ins.op == "fusion":
                cm = _CALLS_RE.search(ins.attrs)
                root = fusion_root.get(cm.group(1)) if cm else None
            if root == "dynamic-update-slice" and op_bytes:
                big = max(op_bytes)
                b = m * (2 * (sum(op_bytes) - big))
                hbm_bytes += b
                tpu_bytes += b
                by_op["dus"] = by_op.get("dus", 0.0) + b
                continue
            b = m * (res + sum(op_bytes))
            hbm_bytes += b
            if (ins.op in ("dot", "scatter", "gather", "copy",
                           "dynamic-slice")
                    or ins.op in COLLECTIVES or ins.op.endswith("-start")):
                tpu_bytes += b
                key = "dot" if ins.op == "dot" else ins.op
                by_op[key] = by_op.get(key, 0.0) + b

    coll_total = sum(v["bytes"] for v in coll.values())
    return {
        "dot_flops": dot_flops,
        "hbm_bytes": hbm_bytes,
        "tpu_bytes": tpu_bytes,
        "bytes_by_op": by_op,
        "collectives": coll,
        "collective_bytes": coll_total,
        "n_computations": len(comps),
    }


if __name__ == "__main__":
    import sys
    with open(sys.argv[1]) as f:
        print(json.dumps(analyze(f.read()), indent=1))
