"""Production mesh definitions.

A TPU v5e pod is modeled as 256 chips in a (16, 16) ("data", "model")
mesh; the multi-pod configuration stacks 2 pods on a leading "pod" axis
(data-parallel across DCN).  Functions, not module constants: importing
this module never touches jax device state.
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) != need:
        assert len(devices) >= need, (
            f"need {need} devices, have {len(devices)}; run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512")
        devices = devices[:need]
    import numpy as np
    return jax.sharding.Mesh(np.asarray(devices).reshape(shape), axes)


def make_partition_mesh(num_devices: int | None = None,
                        axis: str = "data",
                        devices=None) -> jax.sharding.Mesh:
    """1-D vertex-sharding mesh for the sharded LPA engine.

    ``partition(g, cfg, engine="sharded", mesh=make_partition_mesh())``
    shards the fused loop over the first ``num_devices`` local devices
    (all of them by default).  On a multi-device mesh the per-iteration
    label exchange defaults to the changed-labels-only delta plan
    (``cfg.label_exchange="auto"``; see ``repro.core.comm`` for the
    allgather / halo / delta matrix -- identical trajectories, decreasing
    wire bytes), and both score backends ("xla" and "pallas") run
    sharded.  Run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to exercise
    multi-device semantics on CPU.

    ``devices`` pins an explicit device list instead of the process-local
    default -- the process-spanning case: after
    ``jax.distributed.initialize`` a coordinator builds the global mesh
    with ``make_partition_mesh(devices=jax.devices())`` while each worker
    keeps a local one from ``jax.local_devices()``
    (see ``repro.cluster.bootstrap``).
    """
    import numpy as np
    pool = list(devices) if devices is not None else jax.devices()
    n = len(pool) if num_devices is None else num_devices
    if n > len(pool):    # not an assert: must survive python -O
        raise ValueError(
            f"need {n} devices, have {len(pool)}; run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n}")
    return jax.sharding.Mesh(np.asarray(pool[:n]), (axis,))


def make_host_mesh(model_axis: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    import numpy as np
    devices = jax.devices()
    n = len(devices)
    data = n // model_axis
    return jax.sharding.Mesh(
        np.asarray(devices[: data * model_axis]).reshape(data, model_axis),
        ("data", "model"))
