"""Batched LLM-inference serving demo: prefill a batch of prompts, decode.

This is the MODELS side of the repo (transformer/RWKV archs from
``repro.configs``) and has nothing to do with graph-partition serving --
the multi-tenant partition scheduler lives in ``repro.serve``.  Renamed
from ``repro.launch.serve`` so the two don't collide in docs/imports.

    PYTHONPATH=src python -m repro.launch.serve_llm --arch rwkv6-1.6b \
        --reduced --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.models import build, init_params
from repro.train import steps


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    api = build(cfg)
    params = init_params(api, jax.random.PRNGKey(0))
    print(f"arch={cfg.arch} params={api.num_params / 1e6:.1f}M")

    b, s = args.batch, args.prompt_len
    max_len = s + args.gen
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["src_embed"] = jax.random.normal(
            key, (b, s, cfg.d_model)).astype(jnp.bfloat16)
    if cfg.family == "vlm":
        batch["img_embed"] = jax.random.normal(
            key, (b, cfg.n_img_tokens, cfg.d_model)).astype(jnp.bfloat16)

    prefill = jax.jit(steps.make_prefill_step(api))
    decode = jax.jit(steps.make_decode_step(api), donate_argnums=(2,))

    t0 = time.time()
    logits, cache = prefill(params, batch)
    next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
    print(f"prefill {b}x{s}: {time.time() - t0:.2f}s")

    # grow positional KV caches to max_len (family-aware: recurrent states
    # are positionless; cross-attn caches must NOT be padded)
    def pad_axis(c, axis):
        pad = [(0, 0)] * c.ndim
        pad[axis] = (0, max_len - s)
        return jnp.pad(c, pad)

    fam = cfg.family
    if fam in ("dense", "moe"):
        cache = jax.tree.map(lambda c: pad_axis(c, 2), cache)
    elif fam == "encdec":
        cache = cache._replace(self_kv=jax.tree.map(
            lambda c: pad_axis(c, 2), cache.self_kv))
    elif fam == "vlm":
        cache = cache._replace(self_kv=jax.tree.map(
            lambda c: pad_axis(c, 3), cache.self_kv))
    elif fam == "hybrid":
        cache = cache._replace(attn=jax.tree.map(
            lambda c: pad_axis(c, 2), cache.attn))
    # rwkv: O(1) recurrent state, nothing to grow
    out = [next_tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        dbatch = {"token": next_tok, "pos": jnp.int32(s + i)}
        next_tok, cache = decode(params, dbatch, cache)
        out.append(next_tok)
    dt = time.time() - t0
    toks = jnp.stack(out, axis=1)
    print(f"decoded {args.gen - 1} steps x batch {b}: {dt:.2f}s "
          f"({dt / max(1, args.gen - 1) * 1000:.0f} ms/step)")
    print("sample token ids:", toks[0, :12].tolist())


if __name__ == "__main__":
    main()
