"""Production training driver.

Builds the requested mesh, shards TrainState per the GSPMD rules, and runs
the supervised loop (atomic checkpoints, crash-restart, straggler
flagging).  On this CPU container use ``--reduced --mesh host`` to run a
real loop end-to-end; on a TPU pod slice the same entry point takes
``--mesh single|multi`` (jax.distributed must be initialized by the
launcher environment).

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --reduced --steps 50 --mesh host
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.data import pipeline
from repro.models import build, init_params
from repro.optim import adamw
from repro.parallel import rules
from repro.runtime import SupervisorConfig, TrainSupervisor
from repro.train import steps
from repro.launch.mesh import make_host_mesh, make_production_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="use ModelConfig.optimized() perf variant")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--mesh", choices=["host", "single", "multi"],
                    default="host")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    if args.optimized:
        cfg = cfg.optimized()
    api = build(cfg)
    print(f"arch={cfg.arch} params={api.num_params / 1e6:.1f}M "
          f"(active {api.num_active_params / 1e6:.1f}M)")

    mesh = (make_host_mesh() if args.mesh == "host" else
            make_production_mesh(multi_pod=args.mesh == "multi"))
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=min(
        30, args.steps // 10 + 1), total_steps=args.steps)
    data_cfg = pipeline.DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                                   global_batch=args.global_batch)

    with mesh:
        params = init_params(api, jax.random.PRNGKey(0))
        p_sh = rules.param_shardings(api.param_specs, mesh)
        params = jax.tree.map(jax.device_put, params, p_sh)
        state = steps.init_train_state(params)
        train_step = jax.jit(steps.make_train_step(api, opt_cfg),
                             donate_argnums=(0,))

        def batch_fn(step):
            b = pipeline.batch_at(data_cfg, step)
            extras = pipeline.frontend_stub(
                cfg, ShapeConfig("train", args.seq_len, args.global_batch,
                                 "train"), step)
            if extras is not None:
                key = "src_embed" if cfg.family == "encdec" else "img_embed"
                b[key] = extras.astype(jnp.bfloat16)
            return jax.tree.map(jnp.asarray, b)

        sup = TrainSupervisor(
            SupervisorConfig(ckpt_dir=args.ckpt_dir,
                             ckpt_every=args.ckpt_every), state)
        if sup.start_step:
            print(f"resumed from step {sup.start_step}")
        t0 = time.time()
        last = {"loss": float("nan")}

        def logged_step(st, batch):
            nonlocal last
            st, stats = train_step(st, batch)
            last = stats
            step = int(st.step)
            if step % 10 == 0:
                print(f"step {step:5d} loss={float(stats['loss']):.4f} "
                      f"gnorm={float(stats['grad_norm']):.2f} "
                      f"({time.time() - t0:.0f}s)", flush=True)
            return st, stats

        sup.run(logged_step, batch_fn, args.steps)
        if sup.flagged_steps:
            print(f"straggler steps flagged: {sup.flagged_steps}")
        print(f"done: final loss {float(last['loss']):.4f}")


if __name__ == "__main__":
    main()
