"""AdamW + global-norm clipping + warmup-cosine schedule (pure pytrees).

Optimizer state shards exactly like the parameters (ZeRO: m/v inherit the
FSDP PartitionSpecs), so no extra sharding rules are needed.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jax.Array       # () int32
    m: PyTree
    v: PyTree


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to min_lr_frac * lr."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps),
                    0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params: PyTree) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def state_specs(param_specs: PyTree) -> AdamWState:
    z = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), param_specs)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32), m=z, v=z)


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads: PyTree, state: AdamWState,
           params: PyTree) -> Tuple[PyTree, AdamWState, dict]:
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    m = jax.tree.map(lambda mm, g: cfg.b1 * mm + (1 - cfg.b1) * g,
                     state.m, grads)
    v = jax.tree.map(lambda vv, g: cfg.b2 * vv + (1 - cfg.b2) * g * g,
                     state.v, grads)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)
    lr = schedule(cfg, step)

    def upd(p, mm, vv):
        mh = mm / bc1
        vh = vv / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, AdamWState(step, m, v), {"grad_norm": gnorm, "lr": lr}
