from . import adamw
