"""Gradient compression with error feedback (distributed-optimization trick).

int8 block-quantized all-reduce: gradients are quantized per 256-value
block to int8 with a f32 scale (4.25 bits/value overhead -> ~3.76x wire
compression), the quantization residual is carried into the next step
(error feedback, Karimireddy et al. 2019), which keeps SGD/Adam unbiased
in the long run.  ``compress``/``decompress`` are pure functions usable
inside jit/shard_map around any collective.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = jax.Array  # leaves

BLOCK = 256


class Compressed(NamedTuple):
    q: jax.Array        # int8 (n_blocks, BLOCK)
    scale: jax.Array    # f32 (n_blocks,)
    n: int              # original element count


def compress(x: jax.Array) -> Compressed:
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(flat), axis=1) / 127.0
    q = jnp.round(flat / jnp.maximum(scale, 1e-12)[:, None])
    return Compressed(q.astype(jnp.int8), scale, n)


def decompress(c: Compressed, shape) -> jax.Array:
    flat = c.q.astype(jnp.float32) * c.scale[:, None]
    return flat.reshape(-1)[: c.n].reshape(shape)


def compress_tree(grads, errors=None):
    """Quantize a gradient pytree, carrying error feedback.

    Returns (compressed_tree, new_errors): the caller all-reduces the int8
    payloads, then applies ``decompress_tree``.  new_errors = grad -
    dequant(quant(grad + error)) must be fed into the next call.
    """
    if errors is None:
        errors = jax.tree.map(jnp.zeros_like, grads)
    corrected = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e,
                             grads, errors)
    comp = jax.tree.map(compress, corrected,
                        is_leaf=lambda x: isinstance(x, jax.Array))
    restored = jax.tree.map(
        lambda c, g: decompress(c, g.shape), comp, grads,
        is_leaf=lambda x: isinstance(x, Compressed))
    new_errors = jax.tree.map(lambda c, r: c - r, corrected, restored)
    return comp, new_errors


def decompress_tree(comp, like):
    return jax.tree.map(lambda c, g: decompress(c, g.shape).astype(g.dtype),
                        comp, like,
                        is_leaf=lambda x: isinstance(x, Compressed))


def wire_bytes(comp) -> int:
    total = 0
    for c in jax.tree.leaves(comp, is_leaf=lambda x: isinstance(x, Compressed)):
        total += c.q.size + 4 * c.scale.size
    return total
