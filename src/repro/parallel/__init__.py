from . import rules
from .rules import (batch_shardings, cache_shardings, fsdp_axes,
                    param_shardings, replicated)
