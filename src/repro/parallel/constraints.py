"""Activation sharding constraints (mesh-context aware).

Model code calls ``constrain(x, BATCH, None, ...)`` to anchor GSPMD
propagation at key activations (embedding output, logits).  Outside a mesh
context (CPU smoke tests) these are no-ops, so model code stays
mesh-agnostic.  BATCH resolves to whichever of ("pod", "data") exist in the
active mesh; MODEL to "model".
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

BATCH = "__batch__"
MODEL = "__model__"


def current_mesh() -> Optional[jax.sharding.Mesh]:
    # jax.sharding.get_abstract_mesh only exists on newer jax; older
    # versions track the active mesh solely via thread_resources below.
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    m = get_abstract() if get_abstract is not None else None
    if m is not None and not m.empty and m.axis_names:
        return m
    try:
        from jax._src import mesh as mesh_lib
        pm = mesh_lib.thread_resources.env.physical_mesh
        return None if pm.empty else pm
    except Exception:
        return None


def _resolve(axis, mesh):
    if axis == BATCH:
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        return dp if dp else None
    if axis == MODEL:
        return "model" if "model" in mesh.axis_names else None
    return axis


def constrain(x: jax.Array, *axes) -> jax.Array:
    """with_sharding_constraint if a mesh is active, else identity."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = P(*(_resolve(a, mesh) for a in axes))
    try:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    except (ValueError, TypeError):
        return x
