"""Sharding rules: parameter/cache/batch pytrees -> PartitionSpecs.

Scheme (GSPMD, mesh axes ("pod",) "data", "model"):
  * FSDP: the contraction-side dim of every large matrix is sharded over
    ("pod","data") -- ZeRO-3-style; XLA inserts per-layer all-gathers inside
    the scan and reduce-scatters on the gradient.
  * TP: head / ffn / expert / vocab dims are sharded over "model".
  * EP: MoE expert dim is sharded over "model" (expert parallelism).
  * Small vectors (norm scales, biases of size d, decay LoRAs, gates) are
    replicated.
Activations: batch over ("pod","data"); KV caches shard heads over "model"
when divisible, else the sequence dim.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

PyTree = Any


def fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return fsdp_axes(mesh)


def _param_spec(path: str, ndim: int, fsdp) -> P:
    """PartitionSpec for one parameter leaf, by path name.

    Leading "stacking" dims (layer/group/period axes) are unsharded; the
    rule applies to the trailing dims.
    """
    name = path.split("/")[-1]
    parent = path.split("/")[-2] if "/" in path else ""

    def tail(*axes):
        return P(*([None] * (ndim - len(axes))), *axes)

    if name == "embed":
        return P("model", fsdp)
    if name == "lm_head":
        return P(fsdp, "model")
    if parent in ("attn", "cross"):
        if name in ("wq", "wk", "wv"):
            return tail(fsdp, "model")
        if name == "wo":
            return tail("model", fsdp)
        if name in ("bq", "bk", "bv"):
            return tail("model")
        return tail()
    if name in ("exp_w1", "exp_w3"):         # (L, E, d, fe)
        return tail("model", fsdp, None)
    if name == "exp_w2":                      # (L, E, fe, d)
        return tail("model", None, fsdp)
    if name == "router":
        return tail(fsdp, None)
    if name in ("w1", "w3", "cwk", "wz", "wx", "shared_w1", "shared_w3",
                "wr", "wk", "wv", "wg"):      # (.., d, f|d_in|d)
        return tail(fsdp, "model")
    if name in ("w2", "cwv", "out_proj", "wo", "cwr", "shared_w2"):
        return tail("model", fsdp)
    if name in ("wB", "wC", "wdt", "decay_a"):
        return tail(fsdp, None)
    if name == "conv_w":                      # (.., W, d_in)
        return tail(None, "model")
    if name in ("conv_bias", "gn_scale"):
        return tail("model")
    return tail()                             # norms, mixes, gates: replicate


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def param_shardings(specs: PyTree, mesh: Mesh) -> PyTree:
    fsdp = fsdp_axes(mesh)

    def one(path, leaf):
        return NamedSharding(mesh, _param_spec(_path_str(path),
                                               len(leaf.shape), fsdp))

    return jax.tree_util.tree_map_with_path(one, specs)


def batch_shardings(batch_specs: PyTree, mesh: Mesh) -> PyTree:
    """Token/label/embedding inputs: batch dim over ("pod","data").

    Batch dims not divisible by the dp extent (e.g. global_batch=1
    long-context decode) are replicated.
    """
    dp = batch_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]

    def one(leaf):
        if len(leaf.shape) == 0 or leaf.shape[0] % dp_size != 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(dp, *([None] * (len(leaf.shape) - 1))))

    return jax.tree.map(one, batch_specs)


def _model_size(mesh: Mesh) -> int:
    return mesh.shape["model"] if "model" in mesh.axis_names else 1


def cache_shardings(cache_specs: PyTree, mesh: Mesh, batch_size: int
                    ) -> PyTree:
    """KV caches / recurrent states, shape-driven.

    Per leaf: the batch dim is the first dim equal to ``batch_size`` that is
    divisible by the dp size (if none, batch is replicated -- correct for
    e.g. global_batch=1 long-context decode).  Of the remaining dims the
    LARGEST one divisible by the 'model' size is model-sharded: for KV
    caches that is the sequence dim (sequence-sharded decode attention,
    flash-decode style); for SSM/RWKV states it is the head or channel dim.
    """
    dp = batch_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    msize = _model_size(mesh)

    def one(leaf):
        shp = leaf.shape
        ax: list = [None] * len(shp)
        b_idx = None
        for i, s in enumerate(shp):
            if s == batch_size and s % dp_size == 0:
                b_idx = i
                ax[i] = dp
                break
        cands = [(s, i) for i, s in enumerate(shp)
                 if i != b_idx and s % msize == 0 and s > 1]
        if cands:
            _, m_idx = max(cands)
            ax[m_idx] = "model"
        return NamedSharding(mesh, P(*ax))

    return jax.tree.map(one, cache_specs)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def constrain_compute(layer_tree: PyTree) -> PyTree:
    """FSDP weight gather point: constrain per-layer parameter slices to
    their COMPUTE sharding (the storage rule with the fsdp axes dropped).

    Applied inside the scan body, this pins GSPMD to "all-gather the
    (small) weights over the data axis" instead of its alternative
    "partial dot + all-reduce the (huge) activations" -- see
    EXPERIMENTS.md Perf iteration 3.  No-op outside a mesh context.
    """
    from .constraints import current_mesh
    mesh = current_mesh()
    if mesh is None:
        return layer_tree

    def one(path, leaf):
        if not hasattr(leaf, "ndim") or leaf.ndim < 2:
            return leaf
        spec = _param_spec(_path_str(path), leaf.ndim, ())
        # drop fsdp (empty tuple axes become None)
        axes = [a if a not in ((), None) else None for a in spec]
        try:
            return jax.lax.with_sharding_constraint(
                leaf, NamedSharding(mesh, P(*axes)))
        except (ValueError, TypeError):
            return leaf

    return jax.tree_util.tree_map_with_path(one, layer_tree)
