"""Spinner: k-way balanced label propagation (Sections 3.1-3.3, 4.1).

One LPA iteration is two phases, exactly as the Pregel implementation:

  ComputeScores     scores''(v, l) = sum_{u in N(v)} w(u,v) delta(a(u), l)
                                     / deg_w(v) - pi(l)            (Eq. 8)
  ComputeMigrations probabilistic throttle p(l) = R(l)/M(l)        (Eq. 12)

On TPU, ComputeScores is a sparse-dense matmul with a one-hot right-hand side
(scatter-add over the symmetric edge list); the Pallas kernel in
``repro.kernels`` implements it as tiled one-hot matmuls on the MXU, and the
pure-XLA path here doubles as its oracle.  All counters (B(l), M(l),
score(G)) are dense (k,) vectors -- the analogue of Giraph's sharded
aggregators is a single fused reduction.

Halting (Section 3.3): stop when score(G) has not improved by more than eps
(relative) for more than ``halt_window`` consecutive iterations.

The public API (PR 4) is organized around a device-resident SESSION:

  config   ``SpinnerConfig`` carries ONLY the paper's parameters (k, c,
           eps, halt_window, max_iters, seed, migration weighting, the
           tie-break amplitudes).  Runtime/engine knobs -- which runner,
           which mesh, which score backend, which label-exchange plan,
           the compile-shape policy -- live in
           ``repro.core.engine.EngineOptions``.  The old config fields
           for those knobs survive as a deprecation shim
           (``SpinnerDeprecationWarning``) and are folded into the
           options by ``resolve_options``.
  session  ``repro.core.session.PartitionSession`` is the handle a
           long-lived service holds: ``open -> partition / adapt /
           resize / update -> close``.  Opening uploads the graph once
           and compiles runners against power-of-two-ish padded (V, E)
           shape buckets (``graph.shape_bucket``), so a stream of
           ``adapt()`` calls on a growing graph reuses ONE compiled
           executable until the graph outgrows its bucket -- the
           xDGP/SDP serving pattern: O(E) upload + compile amortized
           across requests.  ``session.stats()`` reports buckets,
           compile counts and exchange-plan volumes.
  engines  four interchangeable runners share the same iteration math
           (``engine.make_vertex_update``; see ``repro.core.engine``):
             * ``engine="fused"``   -- the whole run is ONE device
               dispatch (``lax.while_loop`` with halting in the carry);
             * ``engine="sharded"`` -- the fused loop sharded over a
               device mesh in one ``shard_map(while_loop)`` dispatch,
               with a pluggable label exchange (allgather / halo /
               delta: identical trajectories, decreasing wire bytes)
               and an overlap schedule (``EngineOptions.overlap``) that
               scores interior edges while the exchange is in flight;
             * ``engine="chunked"`` -- ``lax.scan`` over ``chunk_size``
               iterations per dispatch with on-device history;
             * ``engine="host"``    -- the per-iteration host loop,
               kept as the readable oracle.
           For a fixed padded layout all four walk the same trajectory
           bit for bit, and a 1-device mesh reproduces "fused" exactly.

``partition`` (and ``incremental.adapt`` / ``resize``) are thin wrappers
that open a THROWAWAY session with the same default options, so a one-shot
call and a warm session call execute the identical compiled program --
which is what makes session results bit-identical to the one-shot API.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import engine as _engine
from .engine import EngineOptions
from .graph import Graph


class SpinnerDeprecationWarning(DeprecationWarning):
    """Deprecated use of engine/runtime knobs on ``SpinnerConfig``.

    A dedicated subclass so CI can turn exactly the in-repo deprecation
    surface into errors (``-W error::repro.core.spinner.
    SpinnerDeprecationWarning``) without fighting third-party warnings.
    """


# Deprecated engine-era fields and their "unset" sentinels.
_LEGACY_FIELDS = {"use_kernel": False, "score_backend": None,
                  "label_exchange": None, "delta_cap": None,
                  "sharded_noise": None}


@dataclasses.dataclass(frozen=True)
class SpinnerConfig:
    """The paper's algorithm parameters (Sections 3.1-3.5) -- nothing else.

    Engine/runtime knobs (runner choice, mesh, score backend, label
    exchange, chunking, shape padding) live in
    ``repro.core.engine.EngineOptions``.  The trailing fields below are a
    deprecation shim for the pre-session API: setting any of them warns
    ``SpinnerDeprecationWarning`` and ``resolve_options`` folds them into
    the options object.
    """

    k: int
    c: float = 1.05                    # capacity slack (Eq. 5)
    eps: float = 1e-3                  # halting threshold (Section 3.3)
    halt_window: int = 5               # w consecutive non-improving iters
    max_iters: int = 300
    seed: int = 0
    # Eq. 12 literally counts *vertices* in M(l) while R(l) is in edge
    # (weighted-degree) units.  "edges" weighs candidates by degree, which is
    # dimensionally consistent and what balance on skewed graphs needs; the
    # open-source Giraph implementation does the same.  "vertices" is the
    # literal paper text, kept for ablation.
    migration_weighting: str = "edges"
    tie_noise: float = 1e-7            # random tie-break amplitude
    current_bonus: float = 1e-6        # prefer the current label on ties
    # ---- deprecated shim (moved to EngineOptions) ----------------------
    use_kernel: bool = False           # -> EngineOptions(score_backend=...)
    score_backend: Optional[str] = None
    label_exchange: Optional[str] = None
    delta_cap: Optional[int] = None
    sharded_noise: Optional[str] = None

    def __post_init__(self):
        legacy = [f for f, unset in _LEGACY_FIELDS.items()
                  if getattr(self, f) != unset]
        if legacy:
            warnings.warn(
                f"SpinnerConfig({', '.join(legacy)}) is deprecated: "
                "engine/runtime knobs moved to "
                "repro.core.engine.EngineOptions (pass options= to "
                "partition()/PartitionSession)",
                SpinnerDeprecationWarning, stacklevel=3)

    def capacity(self, graph: Graph) -> float:
        """C per Eq. (5), in weighted-degree units (see metrics module)."""
        return self.c * graph.total_weight / self.k


def _scrub_legacy(cfg: SpinnerConfig) -> SpinnerConfig:
    """The config with the deprecated fields reset to their sentinels.

    Everything downstream of ``resolve_options`` sees a scrubbed config,
    so internal ``dataclasses.replace`` calls never re-trigger the shim
    warning and cache keys never vary with deprecated fields.
    """
    if any(getattr(cfg, f) != unset for f, unset in _LEGACY_FIELDS.items()):
        return dataclasses.replace(cfg, **_LEGACY_FIELDS)
    return cfg


def resolve_options(cfg: SpinnerConfig,
                    options: Optional[EngineOptions] = None, *,
                    engine: str = "auto",
                    chunk_size: Optional[int] = None,
                    mesh=None,
                    axis: str = "data",
                    ) -> tuple:
    """Merge (options, per-call kwargs, deprecated config fields).

    Returns ``(scrubbed cfg, resolved EngineOptions)``.  Precedence:
    explicit per-call kwargs > an explicit ``options`` object > the
    deprecated ``SpinnerConfig`` fields (which only fill options still at
    their defaults, and warned at config construction).
    """
    opts = options if options is not None else EngineOptions()
    over = {}
    if engine != "auto":
        over["engine"] = engine
    if chunk_size is not None:
        over["chunk_size"] = chunk_size
    if mesh is not None:
        over["mesh"] = mesh
    if axis != "data":
        over["axis"] = axis
    # deprecated config fields fill in wherever the options are defaulted
    if opts.score_backend == "xla":
        if cfg.score_backend is not None:
            over["score_backend"] = cfg.score_backend
        elif cfg.use_kernel:
            over["score_backend"] = "pallas"
    if cfg.label_exchange is not None and opts.label_exchange == "auto":
        over["label_exchange"] = cfg.label_exchange
    if cfg.delta_cap is not None and opts.delta_cap is None:
        over["delta_cap"] = cfg.delta_cap
    if cfg.sharded_noise is not None and opts.sharded_noise == "replicated":
        over["sharded_noise"] = cfg.sharded_noise
    if over:
        opts = dataclasses.replace(opts, **over)
    return _scrub_legacy(cfg), opts


@dataclasses.dataclass
class PartitionResult:
    labels: np.ndarray                  # (V,) int32 final assignment
    loads: np.ndarray                   # (k,) float32 B(l)
    iterations: int
    halted: bool                        # True if the eps/w criterion fired
    history: List[dict]                 # per-iteration phi/rho/score/migrations
    total_messages: float = 0.0         # sum of migrant degrees (network load)
    engine: str = "host"                # which runner produced this result
    exchanged_bytes: float = 0.0        # cumulative label-exchange wire bytes
                                        # (sharded engine only; see core.comm)
    scored_vertices: float = -1.0       # total vertices scored across the run
                                        # (frontier mode only; -1 = dense run)
    scored_per_iter: tuple = ()         # frontier mode: scored-vertex count
                                        # per iteration (sub-linearity report)


def init_labels(graph: Graph, cfg: SpinnerConfig, key: jax.Array) -> jax.Array:
    """Initializer step: uniform random labels (Section 4.1.1)."""
    return jax.random.randint(key, (graph.num_vertices,), 0, cfg.k,
                              dtype=jnp.int32)


def compute_loads(graph: Graph, labels: jax.Array, k: int) -> jax.Array:
    deg = jnp.asarray(graph.deg_w)
    return jnp.zeros((k,), jnp.float32).at[labels].add(deg)


def make_step(graph: Graph, cfg: SpinnerConfig) -> Callable:
    """Build the jitted two-phase iteration for a fixed graph/config.

    Kept for host-loop and benchmark callers; the math lives in
    ``engine.make_vertex_update`` and is shared with the fused runners,
    and the jitted program is cached globally per (cfg statics, backend)
    so repeated host-engine runs do not re-trace.
    """
    return _engine.cached_jit_step(graph, cfg)


def prepare_init(graph: Graph, cfg: SpinnerConfig,
                 init: Optional[np.ndarray] = None):
    """Shared prologue: initial (labels, loads, key) for every engine.

    ``init`` supplies labels for incremental/elastic restarts (Sections
    3.4-3.5); entries equal to -1 are assigned to the least-loaded partition,
    mirroring the paper's treatment of new vertices.
    """
    key = jax.random.PRNGKey(cfg.seed)
    key, k_init = jax.random.split(key)
    if init is None:
        labels = init_labels(graph, cfg, k_init)
    else:
        init = np.asarray(init, dtype=np.int32)
        assert init.shape == (graph.num_vertices,)
        labels = jnp.asarray(init)
        if (init < 0).any():
            # New vertices -> least loaded partition (Section 3.4).
            known = init >= 0
            loads_np = np.zeros(cfg.k, np.float64)
            np.add.at(loads_np, init[known], graph.deg_w[known])
            fill = np.argsort(loads_np, kind="stable")[
                np.arange(int((~known).sum())) % cfg.k]
            init2 = init.copy()
            init2[~known] = fill.astype(np.int32)
            labels = jnp.asarray(init2)
    loads = compute_loads(graph, labels, cfg.k)
    return labels, loads, key


def partition(graph: Graph,
              cfg: SpinnerConfig,
              init: Optional[np.ndarray] = None,
              record_history: Optional[bool] = None,
              callback: Optional[Callable[[int, dict], None]] = None,
              engine: str = "auto",
              chunk_size: Optional[int] = None,
              mesh=None,
              axis: str = "data",
              options: Optional[EngineOptions] = None,
              ) -> PartitionResult:
    """Run Spinner to a stable state (Sections 3.3, 4.1).

    A thin wrapper that opens a throwaway ``PartitionSession`` with the
    resolved options and runs it once -- so repeat calls share the
    session machinery's compiled programs and uploads, and results are
    bit-identical to the same call through a live session.

    ``engine`` selects the runner (see module docstring): "fused" executes
    the whole run as one ``lax.while_loop`` device dispatch (and therefore
    returns an empty ``history`` -- there is no per-iteration host
    visibility inside the loop), "sharded" is the same single dispatch
    sharded over a device ``mesh`` (``None`` = a 1-D mesh over all local
    devices; ``axis`` names the vertex-sharding mesh axis), "chunked" runs
    ``chunk_size`` iterations per dispatch recording on-device history,
    "host" is the legacy per-iteration loop, and "auto" picks "chunked"
    when ``record_history``/``callback`` need per-iteration traces and
    "fused" otherwise.  ``options`` carries the same knobs (plus score
    backend, label exchange, shape padding) as one object; per-call
    kwargs win over it.

    ``record_history=None`` (default) means "record where the engine can":
    True for host/chunked, False for fused.  Explicitly requesting
    ``record_history=True`` or a ``callback`` together with
    ``engine="fused"`` is an error rather than a silent empty history.
    """
    cfg, opts = resolve_options(cfg, options, engine=engine,
                                chunk_size=chunk_size, mesh=mesh, axis=axis)
    from .session import PartitionSession    # lazy: session imports us
    with PartitionSession(graph, cfg, opts) as session:
        return session.partition(init=init, record_history=record_history,
                                 callback=callback)
