"""Spinner: k-way balanced label propagation (Sections 3.1-3.3, 4.1).

One LPA iteration is two phases, exactly as the Pregel implementation:

  ComputeScores     scores''(v, l) = sum_{u in N(v)} w(u,v) delta(a(u), l)
                                     / deg_w(v) - pi(l)            (Eq. 8)
  ComputeMigrations probabilistic throttle p(l) = R(l)/M(l)        (Eq. 12)

On TPU, ComputeScores is a sparse-dense matmul with a one-hot right-hand side
(scatter-add over the symmetric edge list); the Pallas kernel in
``repro.kernels`` implements it as tiled one-hot matmuls on the MXU, and the
pure-XLA path here doubles as its oracle.  All counters (B(l), M(l),
score(G)) are dense (k,) vectors -- the analogue of Giraph's sharded
aggregators is a single fused reduction.

Halting (Section 3.3): stop when score(G) has not improved by more than eps
(relative) for more than ``halt_window`` consecutive iterations.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import metrics
from .graph import Graph


@dataclasses.dataclass(frozen=True)
class SpinnerConfig:
    k: int
    c: float = 1.05                    # capacity slack (Eq. 5)
    eps: float = 1e-3                  # halting threshold (Section 3.3)
    halt_window: int = 5               # w consecutive non-improving iters
    max_iters: int = 300
    seed: int = 0
    # Eq. 12 literally counts *vertices* in M(l) while R(l) is in edge
    # (weighted-degree) units.  "edges" weighs candidates by degree, which is
    # dimensionally consistent and what balance on skewed graphs needs; the
    # open-source Giraph implementation does the same.  "vertices" is the
    # literal paper text, kept for ablation.
    migration_weighting: str = "edges"
    use_kernel: bool = False           # ComputeScores via the Pallas kernel
    tie_noise: float = 1e-7            # random tie-break amplitude
    current_bonus: float = 1e-6        # prefer the current label on ties

    def capacity(self, graph: Graph) -> float:
        """C per Eq. (5), in weighted-degree units (see metrics module)."""
        return self.c * graph.total_weight / self.k


@dataclasses.dataclass
class PartitionResult:
    labels: np.ndarray                  # (V,) int32 final assignment
    loads: np.ndarray                   # (k,) float32 B(l)
    iterations: int
    halted: bool                        # True if the eps/w criterion fired
    history: List[dict]                 # per-iteration phi/rho/score/migrations
    total_messages: float = 0.0         # sum of migrant degrees (network load)


def init_labels(graph: Graph, cfg: SpinnerConfig, key: jax.Array) -> jax.Array:
    """Initializer step: uniform random labels (Section 4.1.1)."""
    return jax.random.randint(key, (graph.num_vertices,), 0, cfg.k,
                              dtype=jnp.int32)


def compute_loads(graph: Graph, labels: jax.Array, k: int) -> jax.Array:
    deg = jnp.asarray(graph.deg_w)
    return jnp.zeros((k,), jnp.float32).at[labels].add(deg)


def make_step(graph: Graph, cfg: SpinnerConfig) -> Callable:
    """Build the jitted two-phase iteration for a fixed graph/config."""
    src = jnp.asarray(graph.src)
    dst = jnp.asarray(graph.dst)
    w = jnp.asarray(graph.weight)
    deg_w = jnp.asarray(graph.deg_w)
    V, k = graph.num_vertices, cfg.k
    C = jnp.float32(cfg.capacity(graph))
    degree_weighted = cfg.migration_weighting == "edges"

    if cfg.use_kernel:
        from repro.kernels import ops as kernel_ops
        from .graph import build_tiled_csr
        tiled = build_tiled_csr(graph)
        kernel_fn = functools.partial(kernel_ops.spinner_scores_tiled,
                                      tiled=tiled, k=k)

    @jax.jit
    def step(labels: jax.Array, loads: jax.Array, key: jax.Array):
        # ---- ComputeScores (Eq. 8) -------------------------------------
        if cfg.use_kernel:
            scores = kernel_fn(labels)                     # (V, k) f32
        else:
            nbr = labels[dst]
            scores = jnp.zeros((V, k), jnp.float32).at[src, nbr].add(w)
        norm = scores / jnp.maximum(deg_w, 1.0)[:, None]
        penalty = loads / C                                # pi(l) (Eq. 7)
        total = norm - penalty[None, :]

        k_noise, k_mig = jax.random.split(key)
        noise = jax.random.uniform(k_noise, (V, k), jnp.float32,
                                   0.0, cfg.tie_noise)
        bonus = cfg.current_bonus * jax.nn.one_hot(labels, k,
                                                   dtype=jnp.float32)
        best = jnp.argmax(total + noise + bonus, axis=1).astype(jnp.int32)
        want = best != labels

        # ---- ComputeMigrations (Eq. 11-12) -----------------------------
        measure = deg_w if degree_weighted else jnp.ones_like(deg_w)
        M = jnp.zeros((k,), jnp.float32).at[best].add(
            jnp.where(want, measure, 0.0))
        R = jnp.maximum(C - loads, 0.0)                    # Eq. 11
        p = jnp.clip(R / jnp.maximum(M, 1e-9), 0.0, 1.0)   # Eq. 12
        u = jax.random.uniform(k_mig, (V,), jnp.float32)
        migrate = want & (u < p[best])

        new_labels = jnp.where(migrate, best, labels)
        mig_deg = jnp.where(migrate, deg_w, 0.0)
        new_loads = (loads
                     .at[best].add(mig_deg)
                     .at[labels].add(-mig_deg))

        # ---- halting aggregate: score(G) at the new assignment (Eq. 9) --
        sel = jnp.take_along_axis(total, new_labels[:, None], axis=1)[:, 0]
        score_g = jnp.sum(sel)
        # migration mass = sum of migrant degrees = Pregel messages sent
        # (each migrating vertex notifies all neighbors, Section 4.1.3)
        return new_labels, new_loads, score_g, jnp.sum(migrate), \
            jnp.sum(mig_deg)

    return step


def partition(graph: Graph,
              cfg: SpinnerConfig,
              init: Optional[np.ndarray] = None,
              record_history: bool = True,
              callback: Optional[Callable[[int, dict], None]] = None,
              ) -> PartitionResult:
    """Run Spinner to a stable state (Sections 3.3, 4.1).

    ``init`` supplies labels for incremental/elastic restarts (Sections
    3.4-3.5); entries equal to -1 are assigned to the least-loaded partition,
    mirroring the paper's treatment of new vertices.
    """
    key = jax.random.PRNGKey(cfg.seed)
    key, k_init = jax.random.split(key)
    if init is None:
        labels = init_labels(graph, cfg, k_init)
    else:
        init = np.asarray(init, dtype=np.int32)
        assert init.shape == (graph.num_vertices,)
        labels = jnp.asarray(init)
        if (init < 0).any():
            # New vertices -> least loaded partition (Section 3.4).
            known = init >= 0
            loads_np = np.zeros(cfg.k, np.float64)
            np.add.at(loads_np, init[known], graph.deg_w[known])
            fill = np.argsort(loads_np, kind="stable")[
                np.arange(int((~known).sum())) % cfg.k]
            init2 = init.copy()
            init2[~known] = fill.astype(np.int32)
            labels = jnp.asarray(init2)
    loads = compute_loads(graph, labels, cfg.k)

    step = make_step(graph, cfg)
    best_score = -np.inf
    stall = 0
    history: List[dict] = []
    halted = False
    total_messages = 0.0
    it = 0
    for it in range(1, cfg.max_iters + 1):
        key, k_it = jax.random.split(key)
        labels, loads, score_g, n_mig, mig_mass = step(labels, loads, k_it)
        score_g = float(score_g)
        total_messages += float(mig_mass)
        if record_history:
            lab_np = np.asarray(labels)
            entry = {
                "iteration": it,
                "score": score_g,
                "migrations": int(n_mig),
                "message_mass": float(mig_mass),
                "phi": metrics.phi(graph, lab_np),
                "rho": metrics.rho(graph, lab_np, cfg.k),
            }
            history.append(entry)
            if callback is not None:
                callback(it, entry)
        # Halting (Section 3.3): relative improvement below eps for > w iters.
        tol = cfg.eps * max(1.0, abs(best_score))
        if score_g > best_score + tol:
            best_score = max(best_score, score_g)
            stall = 0
        else:
            best_score = max(best_score, score_g)
            stall += 1
            if stall >= cfg.halt_window:
                halted = True
                break

    return PartitionResult(labels=np.asarray(labels),
                           loads=np.asarray(loads),
                           iterations=it, halted=halted, history=history,
                           total_messages=total_messages)
