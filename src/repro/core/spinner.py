"""Spinner: k-way balanced label propagation (Sections 3.1-3.3, 4.1).

One LPA iteration is two phases, exactly as the Pregel implementation:

  ComputeScores     scores''(v, l) = sum_{u in N(v)} w(u,v) delta(a(u), l)
                                     / deg_w(v) - pi(l)            (Eq. 8)
  ComputeMigrations probabilistic throttle p(l) = R(l)/M(l)        (Eq. 12)

On TPU, ComputeScores is a sparse-dense matmul with a one-hot right-hand side
(scatter-add over the symmetric edge list); the Pallas kernel in
``repro.kernels`` implements it as tiled one-hot matmuls on the MXU, and the
pure-XLA path here doubles as its oracle.  All counters (B(l), M(l),
score(G)) are dense (k,) vectors -- the analogue of Giraph's sharded
aggregators is a single fused reduction.

Halting (Section 3.3): stop when score(G) has not improved by more than eps
(relative) for more than ``halt_window`` consecutive iterations.

Engine layering (see ``repro.core.engine`` for the device-resident side):

  state   ``engine.SpinnerState`` -- a pure pytree carrying labels, loads,
          the PRNG key, the Eq. 9 best_score / stall halting aggregates and
          the last iteration's migration statistics.
  step    ``engine.make_iteration`` holds the two-phase math as a pure
          function; ``engine.make_step_fn`` wraps it (PRNG split + on-device
          halting update) into a jittable state transition.  The Eq. 8
          numerator comes from a pluggable score backend
          (``repro.kernels.ops.get_score_backend``): XLA scatter-add or the
          Pallas tiled kernel, chosen once at trace time.
  runner  four interchangeable drivers share that step:
            * ``engine="fused"``   -- the whole run is ONE device dispatch
              (``lax.while_loop`` with the halting criterion in the carry);
            * ``engine="sharded"`` -- the fused loop sharded over a device
              mesh (labels split over the vertex axis via ``shard_map``,
              aggregates psum-reduced in the step): one ``while_loop``
              dispatch drives ALL devices, with no per-iteration host
              sync.  On a 1-device mesh this is a bit-compatible oracle
              of "fused".  The per-iteration label exchange is pluggable
              (``cfg.label_exchange``, see ``repro.core.comm``): full
              all-gather, boundary-only halo, or changed-labels-only
              delta -- identical trajectories, decreasing wire bytes;
            * ``engine="chunked"`` -- ``lax.scan`` over ``chunk_size``
              iterations per dispatch with fixed-size on-device history
              (phi / rho / score / migration traces), one host sync per
              chunk;
            * ``engine="host"``    -- the legacy per-iteration host loop,
              kept as the bit-compatible oracle for the fused paths.
          ``engine="auto"`` (default) picks "chunked" when history or a
          callback is requested and "fused" otherwise.  All four share
          ``engine._halting_update``, so iteration counts agree exactly.

``incremental.adapt`` and ``incremental.resize`` rebase on the same
``partition`` entry point, so dynamic and elastic restarts also execute as
a single fused device call.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import engine as _engine
from . import metrics
from .graph import Graph


@dataclasses.dataclass(frozen=True)
class SpinnerConfig:
    k: int
    c: float = 1.05                    # capacity slack (Eq. 5)
    eps: float = 1e-3                  # halting threshold (Section 3.3)
    halt_window: int = 5               # w consecutive non-improving iters
    max_iters: int = 300
    seed: int = 0
    # Eq. 12 literally counts *vertices* in M(l) while R(l) is in edge
    # (weighted-degree) units.  "edges" weighs candidates by degree, which is
    # dimensionally consistent and what balance on skewed graphs needs; the
    # open-source Giraph implementation does the same.  "vertices" is the
    # literal paper text, kept for ablation.
    migration_weighting: str = "edges"
    use_kernel: bool = False           # legacy alias for score_backend="pallas"
    # ComputeScores backend: "xla" | "pallas" (see repro.kernels.ops).
    # None defers to use_kernel for backward compatibility.
    score_backend: Optional[str] = None
    tie_noise: float = 1e-7            # random tie-break amplitude
    current_bonus: float = 1e-6        # prefer the current label on ties
    # Sharded-engine label exchange (see repro.core.comm): "allgather"
    # ships the full label vector per iteration (the bit-compatible
    # oracle), "halo" only the boundary labels other devices reference,
    # "delta" only labels that changed last iteration (the Figure 7
    # traffic decay).  All three walk identical trajectories; "auto"
    # picks allgather on 1 device and delta on a real mesh.
    label_exchange: str = "auto"
    # Per-device compact-buffer capacity of the delta exchange (entries);
    # None = v_per_dev // 4.  Iterations where any device changes more
    # labels than this fall back to a full all-gather (still bit-equal).
    delta_cap: Optional[int] = None
    # Sharded tie-break noise: "replicated" draws over the full padded
    # vertex set from the replicated key (1-device mesh bit-parity with
    # the fused engine); "folded" folds the device index into the key and
    # draws only the local shard -- O(V/ndev) noise memory for very large
    # V, different (still deterministic) stream.
    sharded_noise: str = "replicated"

    def capacity(self, graph: Graph) -> float:
        """C per Eq. (5), in weighted-degree units (see metrics module)."""
        return self.c * graph.total_weight / self.k

    def resolved_score_backend(self) -> str:
        if self.score_backend is not None:
            return self.score_backend
        return "pallas" if self.use_kernel else "xla"

    def resolved_label_exchange(self, ndev: int) -> str:
        """Exchange plan for an ndev-device mesh (see repro.core.comm)."""
        from .comm import EXCHANGE_PLANS     # the one plan registry
        if self.label_exchange == "auto":
            return "allgather" if ndev == 1 else "delta"
        if self.label_exchange not in EXCHANGE_PLANS:
            raise ValueError(
                f"unknown label_exchange {self.label_exchange!r}; "
                f"available: auto, {', '.join(sorted(EXCHANGE_PLANS))}")
        return self.label_exchange

    def resolved_sharded_noise(self) -> str:
        if self.sharded_noise not in ("replicated", "folded"):
            raise ValueError(
                f"unknown sharded_noise {self.sharded_noise!r}; "
                "available: replicated, folded")
        return self.sharded_noise


@dataclasses.dataclass
class PartitionResult:
    labels: np.ndarray                  # (V,) int32 final assignment
    loads: np.ndarray                   # (k,) float32 B(l)
    iterations: int
    halted: bool                        # True if the eps/w criterion fired
    history: List[dict]                 # per-iteration phi/rho/score/migrations
    total_messages: float = 0.0         # sum of migrant degrees (network load)
    engine: str = "host"                # which runner produced this result
    exchanged_bytes: float = 0.0        # cumulative label-exchange wire bytes
                                        # (sharded engine only; see core.comm)


def init_labels(graph: Graph, cfg: SpinnerConfig, key: jax.Array) -> jax.Array:
    """Initializer step: uniform random labels (Section 4.1.1)."""
    return jax.random.randint(key, (graph.num_vertices,), 0, cfg.k,
                              dtype=jnp.int32)


def compute_loads(graph: Graph, labels: jax.Array, k: int) -> jax.Array:
    deg = jnp.asarray(graph.deg_w)
    return jnp.zeros((k,), jnp.float32).at[labels].add(deg)


def make_step(graph: Graph, cfg: SpinnerConfig) -> Callable:
    """Build the jitted two-phase iteration for a fixed graph/config.

    Kept for host-loop and benchmark callers; the math lives in
    ``engine.make_iteration`` and is shared with the fused runners, and
    the jitted step is cached per (graph, cfg) so repeated host-engine
    runs do not re-trace.
    """
    return _engine.cached_jit_step(graph, cfg)


def prepare_init(graph: Graph, cfg: SpinnerConfig,
                 init: Optional[np.ndarray] = None):
    """Shared prologue: initial (labels, loads, key) for every engine.

    ``init`` supplies labels for incremental/elastic restarts (Sections
    3.4-3.5); entries equal to -1 are assigned to the least-loaded partition,
    mirroring the paper's treatment of new vertices.
    """
    key = jax.random.PRNGKey(cfg.seed)
    key, k_init = jax.random.split(key)
    if init is None:
        labels = init_labels(graph, cfg, k_init)
    else:
        init = np.asarray(init, dtype=np.int32)
        assert init.shape == (graph.num_vertices,)
        labels = jnp.asarray(init)
        if (init < 0).any():
            # New vertices -> least loaded partition (Section 3.4).
            known = init >= 0
            loads_np = np.zeros(cfg.k, np.float64)
            np.add.at(loads_np, init[known], graph.deg_w[known])
            fill = np.argsort(loads_np, kind="stable")[
                np.arange(int((~known).sum())) % cfg.k]
            init2 = init.copy()
            init2[~known] = fill.astype(np.int32)
            labels = jnp.asarray(init2)
    loads = compute_loads(graph, labels, cfg.k)
    return labels, loads, key


def _partition_host(graph: Graph, cfg: SpinnerConfig, labels, loads, key,
                    record_history: bool,
                    callback: Optional[Callable[[int, dict], None]],
                    ) -> PartitionResult:
    """Legacy per-iteration host loop -- the fused engines' oracle.

    The halting compare runs in float32 (matching the on-device
    ``engine._halting_update`` bit for bit), so host and fused engines are
    guaranteed to agree on iteration counts, not just label trajectories.
    """
    step = make_step(graph, cfg)
    best_score = np.float32(-np.inf)
    eps32 = np.float32(cfg.eps)
    stall = 0
    history: List[dict] = []
    halted = False
    total_messages = 0.0
    it = 0
    for it in range(1, cfg.max_iters + 1):
        key, k_it = jax.random.split(key)
        labels, loads, score_g, n_mig, mig_mass = step(labels, loads, k_it)
        score_g = np.float32(score_g)
        total_messages += float(mig_mass)
        if record_history or callback is not None:
            lab_np = np.asarray(labels)
            entry = {
                "iteration": it,
                "score": float(score_g),
                "migrations": int(n_mig),
                "message_mass": float(mig_mass),
                "phi": metrics.phi(graph, lab_np),
                "rho": metrics.rho(graph, lab_np, cfg.k),
            }
            if record_history:
                history.append(entry)
            if callback is not None:
                callback(it, entry)
        # Halting (Section 3.3): relative improvement below eps for > w iters.
        # f32 arithmetic mirroring engine._halting_update; on iteration 1
        # best_score is -inf, tol is inf, best + tol is NaN and the compare
        # is False (the invalid-op warning is expected and suppressed).
        with np.errstate(invalid="ignore"):
            tol = eps32 * np.maximum(np.float32(1.0), np.abs(best_score))
            improved = score_g > best_score + tol
        best_score = np.maximum(best_score, score_g)
        if improved:
            stall = 0
        else:
            stall += 1
            if stall >= cfg.halt_window:
                halted = True
                break

    return PartitionResult(labels=np.asarray(labels),
                           loads=np.asarray(loads),
                           iterations=it, halted=halted, history=history,
                           total_messages=total_messages, engine="host")


def partition(graph: Graph,
              cfg: SpinnerConfig,
              init: Optional[np.ndarray] = None,
              record_history: Optional[bool] = None,
              callback: Optional[Callable[[int, dict], None]] = None,
              engine: str = "auto",
              chunk_size: Optional[int] = None,
              mesh: Optional[jax.sharding.Mesh] = None,
              axis: str = "data",
              ) -> PartitionResult:
    """Run Spinner to a stable state (Sections 3.3, 4.1).

    ``engine`` selects the runner (see module docstring): "fused" executes
    the whole run as one ``lax.while_loop`` device dispatch (and therefore
    returns an empty ``history`` -- there is no per-iteration host
    visibility inside the loop), "sharded" is the same single dispatch
    sharded over a device ``mesh`` (``None`` = a 1-D mesh over all local
    devices; ``axis`` names the vertex-sharding mesh axis), "chunked" runs
    ``chunk_size`` iterations per dispatch recording on-device history,
    "host" is the legacy per-iteration loop, and "auto" picks "chunked"
    when ``record_history``/``callback`` need per-iteration traces and
    "fused" otherwise.

    ``record_history=None`` (default) means "record where the engine can":
    True for host/chunked, False for fused.  Explicitly requesting
    ``record_history=True`` or a ``callback`` together with
    ``engine="fused"`` is an error rather than a silent empty history.
    """
    labels, loads, key = prepare_init(graph, cfg, init)
    if engine == "auto":
        if mesh is not None:
            engine = "sharded"   # an explicit mesh implies the sharded runner
        else:
            engine = "fused" if (record_history is False and callback is None) \
                else "chunked"
    if mesh is not None and engine != "sharded":
        raise ValueError(
            f"mesh= is only meaningful for engine='sharded', got {engine!r}")
    if engine == "host":
        return _partition_host(graph, cfg, labels, loads, key,
                               record_history is not False, callback)

    if engine in ("fused", "sharded"):
        # "chunked" is single-device only, so on a mesh there is no
        # per-iteration visibility at all -- say so instead of pointing at
        # an option the mesh check forbids.
        remedy = ("per-iteration history/callbacks are not available on a "
                  "device mesh; run engine='chunked' without mesh= for "
                  "traces" if engine == "sharded"
                  else "use engine='chunked' (or 'auto') instead")
        if callback is not None:
            raise ValueError(
                f"engine={engine!r} cannot invoke a per-iteration "
                f"callback; {remedy}")
        if record_history is True:
            raise ValueError(
                f"engine={engine!r} cannot record per-iteration history; "
                f"{remedy}")
        if engine == "sharded":
            state = _engine.run_sharded(graph, cfg, labels, loads, key,
                                        mesh=mesh, axis=axis)
        else:
            state = _engine.run_fused(graph, cfg, labels, loads, key)
        history: List[dict] = []
    elif engine == "chunked":
        record = record_history is not False
        state, history = _engine.run_chunked(
            graph, cfg, labels, loads, key,
            chunk_size=chunk_size or _engine.DEFAULT_CHUNK,
            callback=callback, record=record)
        if not record:
            history = []     # callback may have forced recording internally
    else:
        raise ValueError(
            f"unknown engine {engine!r}; "
            "available: auto, fused, sharded, chunked, host")

    # sharded labels come back padded to a multiple of the mesh size
    labels_np = np.asarray(state.labels)[: graph.num_vertices]
    return PartitionResult(labels=labels_np,
                           loads=np.asarray(state.loads),
                           iterations=int(state.iteration),
                           halted=bool(state.halted), history=history,
                           total_messages=float(state.total_messages),
                           engine=engine,
                           exchanged_bytes=float(state.exchanged_bytes))
