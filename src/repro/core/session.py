"""PartitionSession: a device-resident handle for continuous partitioning.

Spinner's pitch is CONTINUOUS partitioning (Sections 3.4-3.5): react to a
stream of graph changes and cluster resizes by restarting from the previous
assignment, not from scratch.  xDGP and SDP frame the same workload as a
long-lived service.  The one-shot ``partition(graph, cfg)`` call hides what
such a service needs to amortize: the O(E) edge upload, the sharded layout
and exchange-plan construction, and -- dominating small-graph latency --
the XLA compile of the fused runner.

``PartitionSession`` makes that state explicit::

    from repro.core import EngineOptions, SpinnerConfig, open_session

    with open_session(g, SpinnerConfig(k=32)) as s:
        res = s.partition()                  # cold: upload + compile
        while serving:
            delta = next_edge_batch()
            res = s.adapt(edge_updates=delta)    # warm: O(|delta|) cost
            if cluster_resized(new_k):
                res = s.resize(new_k)        # new k: exactly one compile

Lifecycle: ``open (upload/bind lazily) -> partition / adapt / resize /
update -> close``.  The session owns the (graph, config, options) triple,
the previous stable labels (``adapt``/``resize`` default to them), and the
set of compiled programs it has touched -- ``stats()`` reports shape
buckets, per-session compile counts (via the programs' jit cache sizes),
the exchange-plan communication volumes, and the delta fast-path counters.
``stage(next_graph)`` double-buffers the upload: it issues the NEXT
snapshot's host->device transfers (asynchronously, overlapping in-flight
device work) so the following ``adapt()`` consumes a device-resident bind
with zero synchronous copies -- the serving-loop pattern ``res =
s.adapt(); s.stage(next); ... ; res = s.adapt()``.

Shape-bucketed compile reuse: with the default ``EngineOptions(pad=
"bucket")`` every engine runs on a power-of-two-ish padded (V, E) layout
(``graph.shape_bucket`` / ``graph.pad_graph``).  Compiled programs take
all graph data as arguments (see ``repro.core.engine``), so an ``adapt``
on a grown graph that stays inside its bucket re-uses the same executable
-- zero re-traces, asserted in tests/test_session.py -- and crossing a
bucket costs exactly one.  Because ``spinner.partition`` opens a throwaway
session with the same defaults, a warm session call is bit-identical to
the one-shot API on every engine and exchange plan.

Delta-proportional adapt (the ``edge_updates`` fast path): a warm
``adapt(edge_updates=(src, dst))`` that fits the layout's slack costs
O(|delta|), not O(E).  The data path scatters the batch into the resident
padded edge arrays on device (``repro.core.delta`` -- zero host CSR
rebuild, zero O(E) re-upload, zero new compiles once the batch-size
bucket is warm); the logical graph update is recorded in a pending log
and only materialized on host when something genuinely needs the Graph
object (a full ``partition()``, ``stage()``, a bucket-crossing delta, or
slack overflow -- in which case the call falls back to the classic
rebuild path, which is bit-identical by construction).  Eligible modes:
single-device fused runs on the XLA backend, the Pallas backend with
``fused_update="on"``, and the sharded engine on the XLA backend with the
allgather/delta exchange plans and the non-overlapped schedule; anything
else (halo's boundary-slot dst layout, the overlap split arrays, chunked/
host engines, per-iteration history) takes the fallback and is counted in
``stats()["delta"]["fallback_adapts"]``.

Frontier reconvergence (``adapt(..., frontier=True)``): scores only the
dirty vertex set -- endpoints of changed edges, expanded one hop per
iteration along edges out of vertices that changed label -- and halts
when no active vertex wants to move (see ``engine._frontier_program``).
On a converged base labeling robust to the delta's load perturbation the
final labels are bit-identical to a full re-adapt; the result carries
``scored_vertices``/``scored_per_iter`` so callers can verify the scored
fraction is sub-linear in V.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import delta as _delta
from . import engine as _engine
from . import metrics
from .engine import EngineOptions
from .graph import Graph, add_edges
from .spinner import (PartitionResult, SpinnerConfig, prepare_init,
                      resolve_options)

_ENGINES = ("auto", "fused", "sharded", "chunked", "host")

# The one closed-session error, shared by every entry point: the serving
# tier (repro.serve) retires sessions aggressively and matches on this
# message, so it must not vary by code path.
_CLOSED_MSG = ("PartitionSession is closed; open a new session "
               "(close() released its state and is idempotent)")


@dataclasses.dataclass
class _DeltaFast:
    """The session's delta fast-path state (see ``repro.core.delta``).

    Built lazily on the first eligible ``adapt(edge_updates=...)`` -- the
    one O(E) cold cost (pair-key index + for Pallas a host retile whose
    geometry mirrors the cached device upload).  ``merged`` counts the
    prefix of the session's pending log already scattered into ``dd``.
    """

    mode: str                         # "single" | "sharded"
    tracker: _delta.DeltaTracker
    dd: _delta.DeviceDelta
    opts_t: EngineOptions             # autotuned options the arrays match
    v_pad: int
    merged: int = 0
    # sharded mode only
    mesh: object = None
    axis: str = "data"
    plan: object = None
    prog_full: object = None          # the regular (non-frontier) program


class PartitionSession:
    """Device-resident handle: open -> partition/adapt/resize/update -> close.

    See the module docstring for the lifecycle.  All runs go through the
    same engine programs as the one-shot API; the session adds the
    previous-labels memory, program/compile tracking, and the rebind
    logic that keeps a growing graph inside its compile-shape bucket.
    """

    def __init__(self, graph: Graph, cfg: SpinnerConfig,
                 options: Optional[EngineOptions] = None):
        cfg, opts = resolve_options(cfg, options)
        self._pending: List[tuple] = []   # validated directed delta batches
        self._dirty: Optional[np.ndarray] = None  # endpoints since last run
        self._delta: Optional[_DeltaFast] = None
        self._fast_adapts = 0
        self._fallback_adapts = 0
        self._host_rebuilds = 0
        self._delta_bytes_last = 0
        self._delta_bytes_total = 0
        self.graph = graph
        self.cfg = cfg
        self.options = opts
        self._prev: Optional[np.ndarray] = None
        self._last: Optional[PartitionResult] = None
        self._staged: Optional[Graph] = None
        self._programs: dict = {}       # id(program) -> (program, base)
        self._runs = 0
        self._delta_seq = 0             # delta batches accepted, ever
        self._closed = False

    # -- the logical graph (base + pending delta log) ----------------------

    @property
    def graph(self) -> Graph:
        """The session's logical graph.  Reading it MATERIALIZES any
        pending edge deltas into a host Graph (one ``add_edges`` rebuild
        -- the cost the fast path defers); ``stats()`` reports the base
        graph plus the pending-log counters without materializing."""
        if self._pending:
            self._materialize()
        return self._graph

    @graph.setter
    def graph(self, g: Graph) -> None:
        self._graph = g
        self._pending = []
        self._dirty = None
        self._delta = None

    def _materialize(self) -> None:
        """Fold the pending delta log into a host Graph.  One coalesced
        ``add_edges`` call: the union-of-directions weight semantics are
        order-independent, so batching is exact."""
        pending, self._pending = self._pending, []
        if not pending:
            return
        src = np.concatenate([b[0] for b in pending])
        dst = np.concatenate([b[1] for b in pending])
        self._graph = add_edges(self._graph, src, dst)
        self._host_rebuilds += 1
        self._delta = None   # device arrays were keyed to the old base

    def _mark_dirty(self, *vertex_sets) -> None:
        if self._dirty is None:
            self._dirty = np.zeros(self._graph.num_vertices, bool)
        for vs in vertex_sets:
            if len(vs):
                self._dirty[np.asarray(vs)] = True

    # -- lifecycle ---------------------------------------------------------

    def close(self) -> None:
        """Release the session's references (programs stay in the global
        cache for other sessions; graph uploads die with the graph).

        Idempotent: closing an already-closed session is a no-op, so
        schedulers that retire tenants aggressively (repro.serve) may
        double-close without tracking state.  Every subsequent entry
        point raises the same ``RuntimeError`` (one fixed message).
        """
        if self._closed:
            return
        self._programs.clear()
        self._prev = None
        self._last = None
        self._staged = None
        self._pending = []
        self._delta = None
        self._dirty = None
        self._closed = True

    def __enter__(self) -> "PartitionSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError(_CLOSED_MSG)

    # -- program / compile tracking ---------------------------------------

    def _track(self, program) -> None:
        if program is None:            # e.g. a monkeypatched test runner
            return
        if id(program) not in self._programs:
            self._programs[id(program)] = (program, program.compiles())

    @property
    def compiles(self) -> int:
        """Compilations this session caused (jit cache growth of the
        programs it ran, measured from first acquisition)."""
        return sum(max(0, prog.compiles() - base)
                   for prog, base in self._programs.values())

    # -- the four drivers --------------------------------------------------

    def partition(self, init: Optional[np.ndarray] = None,
                  record_history: Optional[bool] = None,
                  callback: Optional[Callable[[int, dict], None]] = None,
                  ) -> PartitionResult:
        """Run to a stable state from ``init`` (or a fresh random start)."""
        self._check_open()
        return self._run(init, record_history, callback)

    def adapt(self, new_graph: Optional[Graph] = None,
              prev: Optional[np.ndarray] = None, *,
              edge_updates: Optional[tuple] = None,
              num_vertices: Optional[int] = None,
              record_history: Optional[bool] = None,
              callback: Optional[Callable[[int, dict], None]] = None,
              frontier: Optional[bool] = None,
              ) -> PartitionResult:
        """Incremental restart (Section 3.4) from the previous labels.

        Rebinds the session to ``new_graph`` (or to the current graph
        extended by ``edge_updates=(src, dst)``; neither = the snapshot
        previously ``stage()``-d if one is pending, else re-run on the
        current graph, e.g. after ``update()``), carries ``prev`` labels
        (default: the last result) extending new vertices as -1 ->
        least-loaded, and restarts.  While the new graph stays inside the
        session's shape bucket this performs ZERO new compilations; a
        staged snapshot additionally starts from device-resident edge
        arrays, with zero synchronous host->device copies on this call.

        An ``edge_updates`` delta that fits the resident layout's slack
        takes the O(|delta|) fast path (on-device scatter merge, no host
        CSR rebuild, no O(E) re-upload -- see the module docstring for
        eligibility); otherwise it falls back to the bit-identical
        rebuild.  ``frontier=True`` reconverges only the dirty vertex
        set and drain-halts (see the module docstring); the result's
        ``scored_per_iter`` reports per-iteration scored-vertex counts.
        """
        self._check_open()
        if new_graph is not None and edge_updates is not None:
            raise ValueError("pass at most one of new_graph/edge_updates")
        batch = None
        if edge_updates is not None:
            e_src, e_dst = edge_updates
            e_src, e_dst = _delta.check_edge_updates(
                e_src, e_dst, self._graph.num_vertices, num_vertices)
            self._delta_seq += 1
            grows = (num_vertices is not None
                     and num_vertices > self._graph.num_vertices)
            if not grows:
                prev_arr = self._require_prev(prev)
                res = self._try_fast_adapt(e_src, e_dst, prev_arr,
                                           frontier, record_history,
                                           callback)
                if res is not None:
                    self._staged = None
                    return res
                self._fallback_adapts += 1
            # fallback: the classic host rebuild (bit-identical oracle)
            new_graph = add_edges(self.graph, e_src, e_dst,
                                  num_vertices=num_vertices)
            self._host_rebuilds += 1
            batch = (e_src, e_dst)
        prev = self._require_prev(prev)
        if new_graph is None and self._staged is not None:
            new_graph = self._staged
        dirty, old_v = self._dirty, self._graph.num_vertices
        if new_graph is not None:
            # any rebinding -- staged or explicit -- supersedes a pending
            # staged snapshot, which was built against the graph this call
            # replaces (see stage())
            self._staged = None
            self.graph = new_graph
        from .incremental import extend_labels
        init = extend_labels(prev, self.graph.num_vertices)
        if frontier:
            active = self._frontier_active(dirty, old_v, batch,
                                           full=batch is None)
            return self._run_frontier(init, active, record_history,
                                      callback)
        return self._run(init, record_history, callback)

    def _frontier_active(self, dirty, old_v: int, batch,
                         full: bool) -> np.ndarray:
        """Initial active mask for a frontier fallback run: accumulated
        dirty endpoints + this call's batch endpoints + grown vertices.
        With no delta provenance at all (``full``) every vertex starts
        active and frontier mode degenerates to drain-halting LPA."""
        V = self._graph.num_vertices
        active = np.zeros(V, bool)
        if full and dirty is None:
            active[:] = True
            return active
        if dirty is not None:
            active[:dirty.shape[0]] = dirty
        active[old_v:] = True
        if batch is not None:
            active[batch[0]] = True
            active[batch[1]] = True
        return active

    def stage(self, new_graph: Optional[Graph] = None, *,
              edge_updates: Optional[tuple] = None,
              num_vertices: Optional[int] = None) -> "PartitionSession":
        """Double-buffer the NEXT snapshot: begin its host->device
        uploads now, so a following ``adapt()`` starts from a
        device-resident bind with zero synchronous copies.

        Builds the padded view, sharded layout, exchange plan and
        compiled-program handle for ``new_graph`` (or for the current
        graph extended by ``edge_updates=(src, dst)``) through the
        engine's bind caches, issuing every per-graph device transfer
        immediately.  JAX dispatches transfers asynchronously, so they
        overlap whatever device work is still in flight (e.g. the
        current fused run) and the host-side layout work happens off the
        next ``adapt()``'s critical path.  The staged snapshot is
        consumed by the next argument-less ``adapt()``; staging again
        replaces it, and any other rebinding (``update()``, an explicit
        ``adapt(new_graph=...)``/``adapt(edge_updates=...)``) discards
        it, since it was built against the superseded graph.  Staging
        materializes any pending fast-path deltas first (the staged
        snapshot is a full host Graph).  Chainable.
        """
        self._check_open()
        new_graph = self._graph_delta(new_graph, edge_updates, num_vertices)
        if new_graph is None:
            raise ValueError("stage() needs new_graph or edge_updates")
        self._prestage(new_graph)
        self._staged = new_graph
        return self

    def _graph_delta(self, new_graph: Optional[Graph], edge_updates,
                     num_vertices: Optional[int]) -> Optional[Graph]:
        """Resolve the mutually-exclusive new_graph/edge_updates pair;
        ``edge_updates=(src, dst)`` extends the current graph (validated:
        out-of-range or negative ids and mismatched lengths raise
        ``ValueError`` before any state changes)."""
        if new_graph is not None and edge_updates is not None:
            raise ValueError("pass at most one of new_graph/edge_updates")
        if edge_updates is not None:
            e_src, e_dst = edge_updates
            e_src, e_dst = _delta.check_edge_updates(
                e_src, e_dst, self._graph.num_vertices, num_vertices)
            new_graph = add_edges(self.graph, e_src, e_dst,
                                  num_vertices=num_vertices)
            self._host_rebuilds += 1
        return new_graph

    def _prestage(self, graph: Graph) -> None:
        """Warm every per-graph cache ``_run`` would touch for ``graph``.

        The engine's bind pieces (padded view, edge uploads, score-
        backend arrays, sharded layout + plan) are memoized per graph
        OBJECT, so building them here means the later ``adapt()`` --
        which receives the same object -- finds everything device-
        resident.  The sharded path also resolves (and tracks) its
        program handle; note a CROSS-bucket stage does not pre-pay the
        new program's XLA compile -- jit compiles lazily, so that one
        compile still lands on the first dispatch inside ``adapt()``
        (stage removes the uploads and layout work from that path, not
        the compiler).  A dummy ``prepare_init`` pass
        additionally warms the init-path op compilations (load scatter,
        label pad/concat), which run on the EXACT vertex count and would
        otherwise retrace on every new snapshot shape even when the
        bucketed runner itself is compile-warm.
        """
        opts, cfg = self.options, self.cfg
        if opts.mesh is not None or opts.engine == "sharded":
            mesh = opts.mesh
            if mesh is None:
                mesh = _engine._default_partition_mesh()
            _, _, prog, _ = _engine._sharded_parts(graph, cfg, opts, mesh,
                                                   opts.axis)
            self._track(prog)
            v_pad = _engine.sharded_v_pad(graph, opts, mesh, opts.axis)
        else:
            # warm the arg cache the runner will actually read: the tile
            # autotuner may rebind (tile_v, tile_e) on the backend
            opts_t = _engine._autotuned(graph, cfg, opts)
            _, padded = _engine._single_bind(graph, cfg, opts_t, hist=True)
            v_pad = padded.num_vertices
        labels, _, _ = prepare_init(
            graph, cfg, np.zeros(graph.num_vertices, np.int32))
        _engine.pad_labels(labels, v_pad)

    def resize(self, k_new: int, prev: Optional[np.ndarray] = None,
               seed: Optional[int] = None,
               record_history: Optional[bool] = None,
               callback: Optional[Callable[[int, dict], None]] = None,
               ) -> PartitionResult:
        """Elastic restart (Section 3.5, Eq. 10) to ``k_new`` partitions.

        Relabels the previous assignment probabilistically, updates the
        session's config to the new k, and restarts.  A changed k means
        new (k,) aggregate shapes, so this costs exactly one compile per
        new k (returning to a previous k is free again).
        """
        self._check_open()
        prev = self._require_prev(prev)
        from .incremental import elastic_relabel
        k_old = self.cfg.k
        cfg_new = dataclasses.replace(self.cfg, k=k_new)
        init = elastic_relabel(prev, k_old, k_new,
                               seed=cfg_new.seed if seed is None else seed)
        # run first, commit the new k only on success: a rejected call
        # (bad history/callback combination) must not leave the session
        # with k_new but labels from k_old
        res = self._run(init, record_history, callback, cfg=cfg_new)
        self.cfg = cfg_new
        return res

    def update(self, edge_src, edge_dst, num_vertices: Optional[int] = None,
               directed: bool = True) -> "PartitionSession":
        """Apply a graph delta WITHOUT running; the next ``adapt()`` (or
        ``partition()``) sees the extended graph.  Discards any pending
        staged snapshot (it was built against the graph this call
        replaces).

        Same-vertex-set deltas are appended to the session's pending log
        (validated immediately, materialized lazily) so a following
        ``adapt(edge_updates=...)``/``adapt()`` chain stays on the
        O(|delta|) fast path; a delta that grows the vertex set rebuilds
        the host graph right away.  Chainable."""
        self._check_open()
        self._staged = None
        e_src, e_dst = _delta.check_edge_updates(
            edge_src, edge_dst, self._graph.num_vertices, num_vertices)
        self._delta_seq += 1
        if num_vertices is not None \
                and num_vertices > self._graph.num_vertices:
            self.graph = add_edges(self.graph, e_src, e_dst,
                                   directed=directed,
                                   num_vertices=num_vertices)
            self._host_rebuilds += 1
            return self
        if not directed:
            e_src, e_dst = (np.concatenate([e_src, e_dst]),
                            np.concatenate([e_dst, e_src]))
        self._pending.append((e_src, e_dst))
        self._mark_dirty(e_src, e_dst)   # conservative: all endpoints
        return self

    # -- the delta fast path ----------------------------------------------

    def _fast_mode(self, record_history, callback) -> Optional[tuple]:
        """(mode, mesh) when the session's configuration supports the
        on-device delta merge, else None (-> classic fallback).  See the
        module docstring for the eligible-mode table."""
        opts, cfg = self.options, self.cfg
        if opts.pad != "bucket":
            return None                 # no slack region to merge into
        if callback is not None or record_history is True:
            return None                 # per-iteration visibility paths
        if opts.mesh is not None or opts.engine == "sharded":
            mesh = opts.mesh
            if mesh is None:
                mesh = _engine._default_partition_mesh()
            ndev = mesh.shape[opts.axis]
            opts_t = _engine._autotuned(self._graph, cfg, opts, ndev=ndev)
            if getattr(opts_t.backend(), "name", None) != "xla":
                return None             # sharded pallas retile is host-side
            if opts_t.resolved_overlap(ndev) == "on":
                return None             # overlap's split arrays differ
            if opts_t.resolved_label_exchange(ndev) == "halo":
                return None             # halo dst slots aren't global ids
            return ("sharded", mesh)
        if opts.engine not in ("auto", "fused"):
            return None                 # chunked/host replay per-iteration
        if opts.engine == "auto" and record_history is not False:
            return None                 # auto+history resolves to chunked
        opts_t = _engine._autotuned(self._graph, cfg, opts)
        backend = opts_t.backend()
        if getattr(backend, "name", None) == "pallas" \
                and opts_t.resolved_fused_update() != "on":
            return None                 # split pallas args carry no deg_t
        return ("single", None)

    def _delta_init(self, mode: str, mesh) -> _DeltaFast:
        """Cold-start the fast path from the CURRENT base graph: pair-key
        index + DeviceDelta over the resident (cached) device arrays.
        O(E) host work, paid once per base graph."""
        graph, cfg, opts = self._graph, self.cfg, self.options
        tracker = _delta.DeltaTracker(graph)
        if mode == "single":
            opts_t = _engine._autotuned(graph, cfg, opts)
            bind, padded = _engine._single_bind(graph, cfg, opts_t,
                                                frontier=True)
            backend = opts_t.backend()
            if getattr(backend, "name", None) == "pallas":
                from .graph import build_tiled_csr
                # the host twin of the cached fused upload: same
                # deterministic build, gives perm/fill/geometry
                tiled = build_tiled_csr(
                    padded, tile_v=backend.tile_v, tile_e=backend.tile_e,
                    pad_chunks=4,
                    min_total_slots=padded.num_directed_entries)
                dd = _delta.init_single_pallas(
                    bind.score, bind.deg_w, bind.frontier, tiled,
                    graph.num_directed_entries)
            else:
                dd = _delta.init_single_xla(bind.score, bind.deg_w,
                                            graph.num_directed_entries)
            prog = _engine._fused_program(cfg, opts_t)
            self._track(prog)
            return _DeltaFast(mode="single", tracker=tracker, dd=dd,
                              opts_t=opts_t, v_pad=padded.num_vertices,
                              prog_full=prog)
        ndev = mesh.shape[opts.axis]
        opts_t = _engine._autotuned(graph, cfg, opts, ndev=ndev)
        sg, plan, prog, args = _engine._sharded_parts(graph, cfg, opts_t,
                                                      mesh, opts.axis)
        self._track(prog)
        n_plan = len(plan.device_args())
        score_args = args[3:len(args) - n_plan] if n_plan \
            else args[3:]
        dd = _delta.init_sharded_xla(tuple(score_args), args[2], sg)
        return _DeltaFast(mode="sharded", tracker=tracker, dd=dd,
                          opts_t=opts_t, v_pad=sg.num_vertices,
                          mesh=mesh, axis=opts.axis, plan=plan,
                          prog_full=prog)

    def _fast_prepare(self, e_src, e_dst, prev, record_history,
                      callback) -> Optional[tuple]:
        """The shared first half of the O(|delta|) adapt: merge (pending
        log + this batch) into the resident device delta and build the
        warm restart state.  Returns ``(fs, state)`` or None when
        ineligible / on slack overflow (-> the caller rebuilds)."""
        mode = self._fast_mode(record_history, callback)
        if mode is None:
            return None
        if prev.shape[0] != self._graph.num_vertices:
            return None     # shorter prev needs the -1/least-loaded init
        if self._delta is None:
            self._delta = self._delta_init(*mode)
        fs = self._delta
        mp = _engine._merge_program()
        self._track(mp)
        dd, tracker = fs.dd, fs.tracker
        nbytes = 0
        batches = self._pending[fs.merged:] + [(e_src, e_dst)]
        for bs, bd in batches:
            out = _delta.apply_delta(tracker, dd, bs, bd, mp.run)
            if out is None:
                return None          # slack overflow -> rebuild fallback
            dd, plan, b = out
            nbytes += b
            self._mark_dirty(plan.touched)
        self._pending.append((e_src, e_dst))
        fs.dd, fs.merged = dd, len(self._pending)
        self._delta_bytes_last = nbytes
        self._delta_bytes_total += nbytes
        self._fast_adapts += 1

        key, _ = jax.random.split(jax.random.PRNGKey(self.cfg.seed))
        lp = _engine._loads_program(self.cfg.k)
        self._track(lp)
        labels_p = _engine.pad_labels(jnp.asarray(prev, jnp.int32),
                                      fs.v_pad)
        loads = lp.run(labels_p, fs.dd.deg_w)
        return fs, _engine.init_state(labels_p, loads, key)

    def _fast_bind(self, fs: _DeltaFast,
                   frontier: bool) -> "_engine.GraphBind":
        """The single-device GraphBind over the fast path's resident
        merged arrays (row-for-row what ``_single_bind`` builds from a
        rebuilt host graph)."""
        cfg, dd = self.cfg, fs.dd
        capacity = cfg.c * fs.tracker.total_weight / cfg.k
        exp = dd.coo if dd.mode == "single_pallas" else dd.score[:2]
        return _engine.GraphBind(
            deg_w=dd.deg_w, capacity=jnp.float32(capacity),
            num_real=jnp.int32(self._graph.num_vertices), score=dd.score,
            frontier=exp if frontier else ())

    def _try_fast_adapt(self, e_src, e_dst, prev, frontier,
                        record_history, callback
                        ) -> Optional[PartitionResult]:
        """The O(|delta|) adapt: merge on device, restart warm.  Returns
        None when ineligible or when the batch overflows the layout's
        slack (-> the caller rebuilds, bit-identically)."""
        out = self._fast_prepare(e_src, e_dst, prev, record_history,
                                 callback)
        if out is None:
            return None
        fs, state = out
        cfg = self.cfg
        V = self._graph.num_vertices
        capacity = cfg.c * fs.tracker.total_weight / cfg.k
        dd = fs.dd
        hist = None
        if fs.mode == "single":
            bind = self._fast_bind(fs, bool(frontier))
            if frontier:
                prog = _engine._frontier_program(cfg, fs.opts_t)
                self._track(prog)
                state, hist = prog.run(state, self._active_mask(fs.v_pad),
                                       bind)
            else:
                state = fs.prog_full.run(state, bind)
            eng = "fused"
        else:
            args = (jnp.float32(capacity), jnp.int32(V), dd.deg_w) \
                + tuple(dd.score) + tuple(fs.plan.device_args())
            if frontier:
                fused = fs.opts_t.resolved_fused_update() == "on"
                prog = _engine._sharded_frontier_program(
                    cfg, fs.opts_t, fs.mesh, fs.axis, fs.plan.signature(),
                    len(dd.score), fused=fused)
                self._track(prog)
                state, hist = prog.run(state, self._active_mask(fs.v_pad),
                                       *args)
            else:
                state = fs.prog_full.run(state, *args)
            eng = "sharded"
        res = self._finish_state(state, V, eng, hist)
        self._dirty = None
        return res

    # -- scheduler-driven batched execution (repro.serve) ------------------

    def batchable(self) -> bool:
        """True when this session's adapts can ride the engine's batched
        same-bucket runner (``engine.run_batched``): single-device fused
        while_loop programs on the XLA score backend.  Sharded, chunked
        and host sessions -- and Pallas backends, whose kernels are not
        stacked under ``vmap`` here -- run serially through their own
        programs instead (the scheduler falls back transparently)."""
        self._check_open()
        opts = self.options
        if opts.mesh is not None or opts.engine not in ("auto", "fused"):
            return False
        return getattr(opts.backend(), "name", None) == "xla"

    def batch_key(self) -> tuple:
        """Cheap same-bucket compatibility key: two sessions whose keys
        match produce stackable ``adapt_parts`` work items (one compiled
        batched program, identical traced shapes).  Reads the BASE graph
        (no pending-delta materialization)."""
        self._check_open()
        graph, cfg = self._graph, self.cfg
        opts_t = _engine._autotuned(graph, cfg, self.options)
        padded, _ = _engine.padded_view(graph, opts_t)
        return (_engine._static_cfg(cfg), opts_t.backend().signature(),
                opts_t.resolved_fused_update() == "on",
                padded.num_vertices, padded.num_directed_entries)

    def adapt_parts(self, edge_updates: Optional[tuple] = None,
                    prev: Optional[np.ndarray] = None
                    ) -> Optional[tuple]:
        """Build -- without dispatching -- this session's next adapt as a
        ``(state, bind, cfg, opts)`` work item for the engine's batched
        same-bucket runner; the serving scheduler stacks items whose
        ``engine.batch_signature`` matches and runs them as ONE device
        call.  Returns None when the session is not ``batchable()``.

        Mirrors ``adapt(record_history=False)`` exactly: an eligible
        ``edge_updates`` delta takes the O(|delta|) merged-arrays fast
        path (one ``apply_delta`` scatter for the whole -- possibly
        coalesced -- batch); otherwise the classic rebuild produces the
        same work item from the rebuilt graph's bind, bit-identically.
        Feed the runner's output state to ``commit_adapt``; until then
        the session's previous labels are unchanged.
        """
        self._check_open()
        if not self.batchable():
            return None
        prev_arr = self._require_prev(prev)
        if edge_updates is not None:
            e_src, e_dst = _delta.check_edge_updates(
                edge_updates[0], edge_updates[1],
                self._graph.num_vertices, None)
            self._delta_seq += 1
            out = self._fast_prepare(e_src, e_dst, prev_arr, False, None)
            if out is not None:
                self._staged = None
                fs, state = out
                return state, self._fast_bind(fs, False), self.cfg, \
                    fs.opts_t
            self._fallback_adapts += 1
            new_graph = add_edges(self.graph, e_src, e_dst)
            self._host_rebuilds += 1
            self._staged = None
            self.graph = new_graph
        elif self._staged is not None:
            staged, self._staged = self._staged, None
            self.graph = staged
        graph = self.graph     # materializes any pending delta log
        from .incremental import extend_labels
        init = extend_labels(prev_arr, graph.num_vertices)
        cfg = self.cfg
        labels, loads, key = prepare_init(graph, cfg, init)
        opts_t = _engine._autotuned(graph, cfg, self.options)
        bind, padded = _engine._single_bind(graph, cfg, opts_t)
        state = _engine.init_state(
            _engine.pad_labels(labels, padded.num_vertices), loads, key)
        return state, bind, cfg, opts_t

    def commit_adapt(self, state) -> PartitionResult:
        """Record a batched runner's output state as this session's new
        stable result -- the exact bookkeeping ``adapt`` performs after
        its own dispatch (labels sliced to the real vertex set, previous
        labels advanced, dirty set cleared).  Materializes the state to
        host, so calling it after ``engine.run_batched`` blocks on the
        batch; schedulers run their prefetch policies first."""
        self._check_open()
        res = self._finish_state(state, self._graph.num_vertices,
                                 "fused", None)
        self._dirty = None
        return res

    def _active_mask(self, v_pad: int) -> jax.Array:
        active = np.zeros(v_pad, bool)
        if self._dirty is not None:
            active[:self._dirty.shape[0]] = self._dirty
        return jnp.asarray(active)

    def _finish_state(self, state, num_real: int, eng: str,
                      hist) -> PartitionResult:
        iters = int(state.iteration)
        if hist is not None:
            per_iter = tuple(float(x) for x in np.asarray(hist)[:iters])
            scored = float(sum(per_iter))
        else:
            per_iter, scored = (), -1.0
        res = PartitionResult(
            labels=np.asarray(state.labels)[:num_real],
            loads=np.asarray(state.loads), iterations=iters,
            halted=bool(state.halted), history=[],
            total_messages=float(state.total_messages), engine=eng,
            exchanged_bytes=float(state.exchanged_bytes),
            scored_vertices=scored, scored_per_iter=per_iter)
        self._last = res
        self._prev = res.labels
        self._runs += 1
        return res

    def _run_frontier(self, init, active, record_history,
                      callback) -> PartitionResult:
        """Frontier reconvergence on a materialized graph (the fallback
        compute path; the fast path drives the same programs off its
        resident merged arrays)."""
        if callback is not None or record_history is True:
            raise ValueError(
                "frontier=True records only per-iteration scored-vertex "
                "counts (PartitionResult.scored_per_iter); run without "
                "frontier for history/callbacks")
        graph, opts, cfg = self.graph, self.options, self.cfg
        if opts.engine in ("chunked", "host"):
            raise ValueError(
                f"frontier=True requires a while_loop engine (fused/"
                f"sharded/auto), not engine={opts.engine!r}")
        labels, loads, key = prepare_init(graph, cfg, init)
        if opts.mesh is not None or opts.engine == "sharded":
            state, hist = _engine.run_sharded_frontier(
                graph, cfg, labels, loads, key, active, mesh=opts.mesh,
                axis=opts.axis, opts=opts, on_program=self._track)
            eng = "sharded"
        else:
            state, hist = _engine.run_frontier(
                graph, cfg, labels, loads, key, active, opts=opts,
                on_program=self._track)
            eng = "fused"
        res = self._finish_state(state, graph.num_vertices, eng, hist)
        self._dirty = None
        return res

    def run_app(self, workload: str, labels: Optional[np.ndarray] = None,
                **kwargs) -> "repro.apps.AppResult":
        """Consume this session's partition: run a Pregel application
        (``"pagerank"`` / ``"wcc"`` / ``"bfs"`` / ``"sssp"``) on the
        session graph placed by its labels -- the end-to-end speedup
        measurement of the paper's Section 7, via
        :func:`repro.apps.run_app`.

        ``labels`` defaults to the session's current stable assignment
        (``partition()`` must have run); pass any vector (e.g.
        ``benchmarks.common.hash_labels``) to A/B a baseline placement
        on the same graph with zero recompiles.  Keyword args forward
        to :func:`repro.apps.run_app` (``plan``, ``combine``,
        ``overlap``, ``iters``, ``source``, ...); the mesh defaults to
        the session's ``options.mesh``.  The compiled app program joins
        the session's compile accounting (``session.compiles``).
        """
        self._check_open()
        from repro.apps import run_app as _run_app
        if labels is None:
            labels = self._prev
            if labels is None:
                raise ValueError("no labels yet: run partition() first "
                                 "or pass labels= explicitly")
        if "mesh" not in kwargs and self.options.mesh is not None:
            kwargs["mesh"] = self.options.mesh
        kwargs.setdefault("axis", self.options.axis)
        res = _run_app(self.graph, np.asarray(labels), workload, **kwargs)
        self._track(res.program)
        return res

    # -- introspection -----------------------------------------------------

    @property
    def labels(self) -> Optional[np.ndarray]:
        """The previous stable assignment (None before the first run)."""
        return self._prev

    @property
    def delta_watermark(self) -> int:
        """Monotone count of delta batches this session has accepted
        (``update()`` / ``adapt(edge_updates=)`` / ``adapt_parts``),
        whether merged on device, pending, or already materialized.
        Snapshots record it so a restore knows how many batches the
        saved labels reflect (``repro.cluster.snapshot``)."""
        return self._delta_seq

    def export_state(self) -> dict:
        """The session's partition state as a flat pytree of host arrays
        -- the checkpointable surface ``repro.cluster.snapshot`` saves
        through ``repro.ckpt``.

        O(V + k) only: the previous stable ``labels``, the ``loads``
        they imply, the rng key every run derives from
        (``jax.random.PRNGKey(cfg.seed)`` -- recorded for auditability;
        runs are deterministic functions of (graph, cfg, prev labels),
        which is what makes a restored session's continuation
        bit-identical), and the run / delta-watermark counters.  The
        graph itself is NOT included; it is rebuilt from the durable
        inputs (edge shards / base graph + replayed deltas) on restore.
        """
        self._check_open()
        if self._prev is None:
            raise ValueError("no stable labels to snapshot; run "
                             "partition() first or import_state()")
        if self._last is not None:
            loads = np.asarray(self._last.loads, np.float32)
        else:                  # re-derive exactly as prepare_init does
            loads = np.zeros(self.cfg.k, np.float32)
            np.add.at(loads, self._prev,
                      np.asarray(self._graph.deg_w, np.float32))
        return {
            "labels": np.asarray(self._prev, np.int32),
            "loads": loads,
            "rng_key": np.asarray(jax.random.PRNGKey(self.cfg.seed)),
            "runs": np.int64(self._runs),
            "delta_watermark": np.int64(self._delta_seq),
            "k": np.int64(self.cfg.k),
            "num_vertices": np.int64(self._graph.num_vertices),
        }

    def import_state(self, state: dict) -> "PartitionSession":
        """Restore a snapshot produced by :meth:`export_state` into this
        (freshly opened) session: the next ``adapt()``/``resize()``
        continues from the restored labels exactly as if this session
        had computed them.  The session's graph must already be at the
        snapshot's logical state (same vertices, deltas up to the
        watermark applied); labels for a since-grown vertex set are
        extended by the usual -1 -> least-loaded rule on the next run.
        Chainable."""
        self._check_open()
        labels = np.asarray(state["labels"], np.int32)
        if labels.shape[0] > self._graph.num_vertices:
            raise ValueError(
                f"snapshot has {labels.shape[0]} labels but the session "
                f"graph has {self._graph.num_vertices} vertices; rebuild "
                f"the graph at (or past) the snapshot watermark first")
        if int(state["k"]) != self.cfg.k:
            raise ValueError(
                f"snapshot was taken at k={int(state['k'])} but the "
                f"session is configured with k={self.cfg.k}; open with "
                f"the saved k and resize() afterwards")
        self._prev = labels
        self._last = None
        self._runs = int(state["runs"])
        self._delta_seq = int(state["delta_watermark"])
        self._staged = None
        self._dirty = None
        return self

    def stats(self) -> dict:
        """Session state: shape buckets, compile/run counters, padded
        layout, the delta fast-path counters, and (on a mesh) the
        exchange plan's wire volumes.  Reads the BASE graph -- pending
        fast-path deltas are reported under ``"delta"`` without forcing
        a host materialization."""
        self._check_open()
        graph, opts = self._graph, self.options
        padded, _ = _engine.padded_view(graph, opts)
        fs = self._delta
        d = {
            "num_vertices": graph.num_vertices,
            "num_directed_entries": graph.num_directed_entries,
            "k": self.cfg.k,
            "engine": opts.engine,
            "pad": opts.pad,
            "bucket": (_engine.graph_buckets(graph)
                       if opts.pad == "bucket" else None),
            "padded_shape": (padded.num_vertices,
                             padded.num_directed_entries),
            "runs": self._runs,
            "compiles": self.compiles,
            "programs": len(self._programs),
            "staged": (self._staged.num_vertices
                       if self._staged is not None else None),
            "delta": {
                "watermark": self._delta_seq,
                "pending_batches": len(self._pending),
                "merged_batches": fs.merged if fs is not None else 0,
                "fast_adapts": self._fast_adapts,
                "fallback_adapts": self._fallback_adapts,
                "host_rebuilds": self._host_rebuilds,
                "last_upload_bytes": self._delta_bytes_last,
                "upload_bytes_total": self._delta_bytes_total,
                "tracked_total_weight": (
                    fs.tracker.total_weight if fs is not None
                    else float(graph.total_weight)),
            },
        }
        ndev = (opts.mesh.shape[opts.axis] if opts.mesh is not None else 1)
        opts_t = _engine._autotuned(graph, self.cfg, opts, ndev=ndev)
        backend = opts_t.backend()
        d["score_backend"] = backend.name
        d["fused_update"] = opts_t.resolved_fused_update()
        if backend.name == "pallas":
            from repro.kernels.ops import round_up
            d["tile_config"] = {"tile_v": backend.tile_v,
                                "tile_e": backend.tile_e,
                                "k_pad": round_up(max(self.cfg.k, 1), 128)}
        if self._last is not None:
            d["last"] = {"iterations": self._last.iterations,
                         "halted": self._last.halted,
                         "engine": self._last.engine,
                         "exchanged_bytes": self._last.exchanged_bytes,
                         "scored_vertices": self._last.scored_vertices,
                         "scored_per_iter": self._last.scored_per_iter}
        if opts.mesh is not None:
            from .distributed import comm_stats, shard_layout
            sg = shard_layout(padded, opts.mesh.shape[opts.axis],
                              pad=opts.pad == "bucket")
            d["exchange"] = comm_stats(sg, self.cfg, opts, graph=padded)
        return d

    # -- internals ---------------------------------------------------------

    def _require_prev(self, prev) -> np.ndarray:
        if prev is None:
            prev = self._prev
        if prev is None:
            raise ValueError("no previous labels in this session; run "
                             "partition() first or pass prev=")
        return np.asarray(prev, dtype=np.int32)

    def _run(self, init, record_history, callback,
             cfg: Optional[SpinnerConfig] = None) -> PartitionResult:
        self._check_open()
        graph, opts = self.graph, self.options
        cfg = self.cfg if cfg is None else cfg
        eng = opts.engine
        if eng == "auto":
            if opts.mesh is not None:
                eng = "sharded"   # an explicit mesh implies the sharded runner
            else:
                eng = "fused" if (record_history is False and
                                  callback is None) else "chunked"
        if opts.mesh is not None and eng != "sharded":
            raise ValueError(
                f"mesh= is only meaningful for engine='sharded', got "
                f"{eng!r}")
        if eng not in _ENGINES:
            raise ValueError(
                f"unknown engine {eng!r}; "
                "available: auto, fused, sharded, chunked, host")

        labels, loads, key = prepare_init(graph, cfg, init)
        if eng == "host":
            res = self._run_host(cfg, labels, loads, key,
                                 record_history is not False, callback)
        elif eng in ("fused", "sharded"):
            # "chunked" is single-device only, so on a mesh there is no
            # per-iteration visibility at all -- say so instead of pointing
            # at an option the mesh check forbids.
            remedy = ("per-iteration history/callbacks are not available "
                      "on a device mesh; run engine='chunked' without "
                      "mesh= for traces" if eng == "sharded"
                      else "use engine='chunked' (or 'auto') instead")
            if callback is not None:
                raise ValueError(
                    f"engine={eng!r} cannot invoke a per-iteration "
                    f"callback; {remedy}")
            if record_history is True:
                raise ValueError(
                    f"engine={eng!r} cannot record per-iteration history; "
                    f"{remedy}")
            if eng == "sharded":
                state = _engine.run_sharded(graph, cfg, labels, loads, key,
                                            mesh=opts.mesh, axis=opts.axis,
                                            opts=opts,
                                            on_program=self._track)
            else:
                state = _engine.run_fused(graph, cfg, labels, loads, key,
                                          opts=opts, on_program=self._track)
            history = []
        else:   # chunked
            record = record_history is not False
            state, history = _engine.run_chunked(
                graph, cfg, labels, loads, key,
                chunk_size=opts.chunk_size or _engine.DEFAULT_CHUNK,
                callback=callback, record=record, opts=opts,
                on_program=self._track)
            if not record:
                history = []     # callback may force recording internally
        if eng != "host":
            # sharded labels come back padded to the sharded layout
            res = PartitionResult(
                labels=np.asarray(state.labels)[:graph.num_vertices],
                loads=np.asarray(state.loads),
                iterations=int(state.iteration),
                halted=bool(state.halted), history=history,
                total_messages=float(state.total_messages),
                engine=eng,
                exchanged_bytes=float(state.exchanged_bytes))

        self._last = res
        self._prev = res.labels
        self._runs += 1
        self._dirty = None     # a full run reconverges every vertex
        return res

    def _run_host(self, cfg, labels, loads, key, record_history: bool,
                  callback) -> PartitionResult:
        """Legacy per-iteration host loop -- the fused engines' oracle.

        Runs the same padded layout and jitted step program as the fused
        runner; the halting compare runs in float32 (matching the
        on-device ``engine._halting_update`` bit for bit), so host and
        fused engines agree on iteration counts, not just trajectories.
        ``cfg`` arrives from ``_run`` (resize runs the new k before
        committing it to the session).
        """
        graph, opts = self.graph, self.options
        step = _engine.make_host_step(graph, cfg, opts)
        self._track(step.program)
        num_real = graph.num_vertices
        labels = _engine.pad_labels(labels, step.v_pad)
        best_score = np.float32(-np.inf)
        eps32 = np.float32(cfg.eps)
        stall = 0
        history: List[dict] = []
        halted = False
        total_messages = 0.0
        it = 0
        for it in range(1, cfg.max_iters + 1):
            key, k_it = jax.random.split(key)
            labels, loads, score_g, n_mig, mig_mass = step(labels, loads,
                                                           k_it)
            score_g = np.float32(score_g)
            total_messages += float(mig_mass)
            if record_history or callback is not None:
                lab_np = np.asarray(labels)[:num_real]
                entry = {
                    "iteration": it,
                    "score": float(score_g),
                    "migrations": int(n_mig),
                    "message_mass": float(mig_mass),
                    "phi": metrics.phi(graph, lab_np),
                    "rho": metrics.rho(graph, lab_np, cfg.k),
                }
                if record_history:
                    history.append(entry)
                if callback is not None:
                    callback(it, entry)
            # Halting (Section 3.3): relative improvement below eps for
            # > w iters.  f32 arithmetic mirroring engine._halting_update;
            # on iteration 1 best_score is -inf, tol is inf, best + tol is
            # NaN and the compare is False (the invalid-op warning is
            # expected and suppressed).
            with np.errstate(invalid="ignore"):
                tol = eps32 * np.maximum(np.float32(1.0),
                                         np.abs(best_score))
                improved = score_g > best_score + tol
            best_score = np.maximum(best_score, score_g)
            if improved:
                stall = 0
            else:
                stall += 1
                if stall >= cfg.halt_window:
                    halted = True
                    break

        return PartitionResult(labels=np.asarray(labels)[:num_real],
                               loads=np.asarray(loads),
                               iterations=it, halted=halted,
                               history=history,
                               total_messages=total_messages,
                               engine="host")


def open_session(graph: Graph, cfg: SpinnerConfig,
                 options: Optional[EngineOptions] = None
                 ) -> PartitionSession:
    """Open a device-resident partitioning session (``spinner.open``)."""
    return PartitionSession(graph, cfg, options)
