"""Spinner core: the paper's contribution as a composable JAX module."""
from . import comm, delta, engine, generators, graph, incremental, metrics, \
    session
from .delta import (DeltaTracker, DeviceDelta, apply_delta,
                    check_edge_updates, coalesce_updates)
from .engine import (EngineOptions, SpinnerState, batch_signature,
                     make_fused_runner,
                     make_chunked_runner, make_frontier_runner,
                     make_iteration, make_sharded_runner,
                     make_step_fn, make_vertex_update, run_batched,
                     run_chunked, run_fused,
                     run_frontier, run_sharded, run_sharded_frontier)
from .graph import (Graph, TiledCSR, add_edges, build_tiled_csr, from_edges,
                    pad_graph, shape_bucket)
from .incremental import adapt, elastic_relabel, extend_labels, resize
from .metrics import (comm_volume, frontier_fraction,
                      partitioning_difference, phi, phi_weighted, rho,
                      score_global, summarize)
from .session import PartitionSession, open_session
from .spinner import (PartitionResult, SpinnerConfig,
                      SpinnerDeprecationWarning, compute_loads, init_labels,
                      make_step, partition, prepare_init, resolve_options)

__all__ = [
    "Graph", "TiledCSR", "from_edges", "add_edges", "build_tiled_csr",
    "pad_graph", "shape_bucket",
    "SpinnerConfig", "SpinnerDeprecationWarning", "EngineOptions",
    "PartitionResult", "PartitionSession", "open_session", "SpinnerState",
    "DeltaTracker", "DeviceDelta", "apply_delta", "check_edge_updates",
    "coalesce_updates", "run_batched", "batch_signature",
    "partition", "prepare_init", "resolve_options", "make_step",
    "make_step_fn", "make_iteration", "make_vertex_update",
    "make_fused_runner", "make_chunked_runner", "make_frontier_runner",
    "make_sharded_runner",
    "run_fused", "run_chunked", "run_sharded", "run_frontier",
    "run_sharded_frontier", "init_labels",
    "compute_loads", "adapt", "resize", "elastic_relabel", "extend_labels",
    "phi", "phi_weighted", "rho", "score_global", "comm_volume",
    "frontier_fraction",
    "partitioning_difference", "summarize", "comm", "delta", "engine",
    "generators", "graph", "metrics", "incremental", "session",
]
