"""Spinner core: the paper's contribution as a composable JAX module."""
from . import generators, graph, incremental, metrics
from .graph import Graph, TiledCSR, add_edges, build_tiled_csr, from_edges
from .incremental import adapt, elastic_relabel, extend_labels, resize
from .metrics import (partitioning_difference, phi, phi_weighted, rho,
                      score_global, summarize)
from .spinner import (PartitionResult, SpinnerConfig, compute_loads,
                      init_labels, make_step, partition)

__all__ = [
    "Graph", "TiledCSR", "from_edges", "add_edges", "build_tiled_csr",
    "SpinnerConfig", "PartitionResult", "partition", "make_step",
    "init_labels", "compute_loads", "adapt", "resize", "elastic_relabel",
    "extend_labels", "phi", "phi_weighted", "rho", "score_global",
    "partitioning_difference", "summarize", "generators", "graph",
    "metrics", "incremental",
]
