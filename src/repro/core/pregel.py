"""Mini-Pregel: vectorized vertex programs with partition-aware accounting.

Reproduces the mechanism behind the paper's application experiments
(Figure 8 / Table 4): a synchronous engine where, per superstep,
  * every active vertex sends a value along its out-edges,
  * per-partition compute load = messages processed by that partition,
  * network traffic = messages whose endpoints live in different
    partitions.
The simulated superstep time is  max_p(compute_p) * t_msg  +
remote_msgs * t_net  -- the straggler-at-the-barrier model the paper's
Table 4 measures (unbalance -> idling; cut edges -> network).

Three canonical programs: PageRank, SSSP (BFS on unit weights), WCC.
All are pure numpy: these are the ORACLES the device-resident
application engine (:mod:`repro.apps`) is tested against -- that
engine runs the same programs as one ``shard_map(while_loop)``
dispatch over real placements with measured wire bytes, driven by
``PartitionSession.run_app()`` / ``benchmarks/bench_apps.py``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from .graph import Graph


@dataclasses.dataclass
class SuperstepStats:
    messages: int
    remote_messages: int
    per_partition_msgs: np.ndarray      # (k,) messages processed (by dst)

    def simulated_time(self, t_msg: float = 1.0, t_net: float = 4.0,
                       k: Optional[int] = None) -> float:
        return float(self.per_partition_msgs.max() * t_msg
                     + self.remote_messages * t_net
                     / max(1, len(self.per_partition_msgs)))


@dataclasses.dataclass
class PregelResult:
    values: np.ndarray
    supersteps: int
    stats: List[SuperstepStats]

    def total_messages(self) -> int:
        return sum(s.messages for s in self.stats)

    def total_remote(self) -> int:
        return sum(s.remote_messages for s in self.stats)

    def simulated_runtime(self, **kw) -> float:
        return sum(s.simulated_time(**kw) for s in self.stats)


def _stats(graph: Graph, labels: np.ndarray, k: int, active: np.ndarray
           ) -> SuperstepStats:
    src_active = active[graph.src]
    msgs = int(src_active.sum())
    remote = labels[graph.src] != labels[graph.dst]
    remote_msgs = int((src_active & remote).sum())
    per_part = np.bincount(labels[graph.dst[src_active]], minlength=k
                           ).astype(np.int64)
    return SuperstepStats(messages=msgs, remote_messages=remote_msgs,
                          per_partition_msgs=per_part)


def pagerank(graph: Graph, labels: np.ndarray, k: int, iters: int = 20,
             damping: float = 0.85) -> PregelResult:
    V = graph.num_vertices
    out_deg = np.bincount(graph.src, minlength=V).astype(np.float64)
    pr = np.full(V, 1.0 / V)
    stats = []
    active = np.ones(V, bool)
    for _ in range(iters):
        contrib = np.zeros(V)
        share = pr / np.maximum(out_deg, 1.0)
        np.add.at(contrib, graph.dst, share[graph.src])
        pr = (1 - damping) / V + damping * contrib
        stats.append(_stats(graph, labels, k, active))
    return PregelResult(values=pr, supersteps=iters, stats=stats)


def sssp(graph: Graph, source: int, labels: np.ndarray, k: int,
         max_steps: int = 10_000) -> PregelResult:
    V = graph.num_vertices
    dist = np.full(V, np.inf)
    dist[source] = 0.0
    active = np.zeros(V, bool)
    active[source] = True
    stats = []
    steps = 0
    while active.any() and steps < max_steps:
        stats.append(_stats(graph, labels, k, active))
        cand = np.full(V, np.inf)
        live = active[graph.src]
        np.minimum.at(cand, graph.dst[live], dist[graph.src[live]] + 1.0)
        improved = cand < dist
        dist = np.where(improved, cand, dist)
        active = improved
        steps += 1
    return PregelResult(values=dist, supersteps=steps, stats=stats)


def wcc(graph: Graph, labels: np.ndarray, k: int, max_steps: int = 10_000
        ) -> PregelResult:
    V = graph.num_vertices
    comp = np.arange(V, dtype=np.int64)
    active = np.ones(V, bool)
    stats = []
    steps = 0
    while active.any() and steps < max_steps:
        stats.append(_stats(graph, labels, k, active))
        cand = comp.copy()
        live = active[graph.src]
        np.minimum.at(cand, graph.dst[live], comp[graph.src[live]])
        improved = cand < comp
        comp = np.where(improved, cand, comp)
        active = improved
        steps += 1
    return PregelResult(values=comp, supersteps=steps, stats=stats)


def compare_partitionings(graph: Graph, k: int, labels_a: np.ndarray,
                          labels_b: np.ndarray, app: str = "pagerank",
                          **kw) -> dict:
    """Run one app under two partitionings; report the Fig.8-style ratio."""
    fn = {"pagerank": lambda lab: pagerank(graph, lab, k, **kw),
          "sssp": lambda lab: sssp(graph, 0, lab, k, **kw),
          "wcc": lambda lab: wcc(graph, lab, k, **kw)}[app]
    ra, rb = fn(labels_a), fn(labels_b)
    assert np.allclose(np.nan_to_num(ra.values, posinf=1e18),
                       np.nan_to_num(rb.values, posinf=1e18)), \
        "partitioning must not change results"
    return {
        "app": app,
        "remote_msgs_a": ra.total_remote(),
        "remote_msgs_b": rb.total_remote(),
        "sim_time_a": ra.simulated_runtime(),
        "sim_time_b": rb.simulated_runtime(),
        "speedup_b_over_a": ra.simulated_runtime() / rb.simulated_runtime(),
        "msg_reduction": 1.0 - rb.total_remote() / max(1, ra.total_remote()),
    }
