"""Graph containers and preprocessing for Spinner.

The paper's Giraph substrate stores vertex objects with adjacency lists and
runs two supersteps (NeighborPropagation / NeighborDiscovery) to convert a
directed graph into the weighted undirected form of Eq. (3).  On TPU we adapt
this to a single vectorized symmetrization pass over a structure-of-arrays
COO edge list (sort packed canonical keys, count duplicates -> weight in
{1, 2}), producing a CSR-sorted symmetric representation that every other
module (LPA, Pregel engine, Pallas kernel) consumes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    """Weighted undirected graph in symmetric COO form, CSR-sorted by src.

    Every undirected edge {u, v} appears twice: once as (u, v) and once as
    (v, u), both carrying the Eq. (3) weight w(u, v) in {1, 2}.  This makes
    per-vertex aggregation a pure segment operation over ``src``.
    """

    num_vertices: int
    src: np.ndarray        # int32 (2*E_undirected,)  sorted ascending
    dst: np.ndarray        # int32 (2*E_undirected,)
    weight: np.ndarray     # float32 (2*E_undirected,)
    row_ptr: np.ndarray    # int64 (V+1,)  CSR offsets into src/dst/weight
    deg_w: np.ndarray      # float32 (V,)  weighted degree = sum of incident w

    @property
    def num_directed_entries(self) -> int:
        return int(self.src.shape[0])

    @property
    def num_undirected_edges(self) -> int:
        return int(self.src.shape[0]) // 2

    @property
    def total_weight(self) -> float:
        """Sum of weighted degrees = 2 * (weighted undirected edge count)."""
        return float(self.deg_w.sum())

    def validate(self) -> None:
        assert self.src.shape == self.dst.shape == self.weight.shape
        assert self.row_ptr.shape == (self.num_vertices + 1,)
        assert np.all(np.diff(self.row_ptr) >= 0)
        assert self.src.size == 0 or (
            self.src.min() >= 0 and self.src.max() < self.num_vertices
        )
        # symmetry: the multiset of (dst, src) equals (src, dst)
        fwd = np.stack([self.src, self.dst]), self.weight
        key_f = self.src.astype(np.int64) * self.num_vertices + self.dst
        key_b = self.dst.astype(np.int64) * self.num_vertices + self.src
        assert np.array_equal(np.sort(key_f), np.sort(key_b)), "not symmetric"


def _dedupe(src: np.ndarray, dst: np.ndarray, num_vertices: int
            ) -> Tuple[np.ndarray, np.ndarray]:
    """Remove self-loops and exact duplicate directed edges."""
    keep = src != dst
    src, dst = src[keep], dst[keep]
    key = src.astype(np.int64) * num_vertices + dst.astype(np.int64)
    key = np.unique(key)
    return (key // num_vertices).astype(np.int32), (key % num_vertices).astype(np.int32)


def from_edges(src, dst, num_vertices: int, directed: bool = True) -> Graph:
    """Build the weighted undirected Graph per Eq. (3).

    w(u,v) = 2 if both (u,v) and (v,u) exist in the directed input, else 1.
    Undirected input gets w = 1 everywhere.
    """
    src = np.asarray(src, dtype=np.int32)
    dst = np.asarray(dst, dtype=np.int32)
    if src.size:
        assert int(max(src.max(), dst.max())) < num_vertices
    src, dst = _dedupe(src, dst, num_vertices)

    lo = np.minimum(src, dst).astype(np.int64)
    hi = np.maximum(src, dst).astype(np.int64)
    canon = lo * num_vertices + hi
    uniq, counts = np.unique(canon, return_counts=True)
    u = (uniq // num_vertices).astype(np.int32)
    v = (uniq % num_vertices).astype(np.int32)
    if directed:
        w = counts.astype(np.float32)          # 1 = one direction, 2 = both
    else:
        w = np.ones_like(counts, dtype=np.float32)

    sym_src = np.concatenate([u, v])
    sym_dst = np.concatenate([v, u])
    sym_w = np.concatenate([w, w])
    return _finish(sym_src, sym_dst, sym_w, num_vertices)


def _finish(src, dst, w, num_vertices: int) -> Graph:
    order = np.lexsort((dst, src))
    src, dst, w = src[order], dst[order], w[order].astype(np.float32)
    counts = np.bincount(src, minlength=num_vertices).astype(np.int64)
    row_ptr = np.zeros(num_vertices + 1, dtype=np.int64)
    np.cumsum(counts, out=row_ptr[1:])
    deg_w = np.zeros(num_vertices, dtype=np.float32)
    np.add.at(deg_w, src, w)
    return Graph(num_vertices=num_vertices, src=src.astype(np.int32),
                 dst=dst.astype(np.int32), weight=w, row_ptr=row_ptr,
                 deg_w=deg_w)


def add_edges(graph: Graph, new_src, new_dst, directed: bool = True,
              num_vertices: Optional[int] = None) -> Graph:
    """Incremental growth (Section 3.4): returns the extended graph.

    ``num_vertices`` may exceed the old count to inject new vertices.
    Weights are recomputed for touched pairs; untouched edges keep theirs.
    """
    V = max(num_vertices or 0, graph.num_vertices,
            int(np.max(new_src) + 1) if len(new_src) else 0,
            int(np.max(new_dst) + 1) if len(new_dst) else 0)
    # Reconstruct a directed view of the old graph: an undirected edge of
    # weight 2 stands for both directions, weight 1 for the canonical one.
    half = graph.src < graph.dst
    u, v, w = graph.src[half], graph.dst[half], graph.weight[half]
    both = w >= 2
    old_src = np.concatenate([u, v[both]])
    old_dst = np.concatenate([v, u[both]])
    src = np.concatenate([old_src, np.asarray(new_src, np.int32)])
    dst = np.concatenate([old_dst, np.asarray(new_dst, np.int32)])
    return from_edges(src, dst, V, directed=directed)


def shape_bucket(n: int, floor: int = 64) -> int:
    """Power-of-two-ish rounding for compile-shape buckets.

    Returns the smallest value >= max(n, floor) of the form
    ``m * 2**(e-2)`` with mantissa m in {5, 6, 7, 8} (i.e. quarter steps
    between consecutive powers of two), so padding overhead is at most
    25% while graphs of similar size land in the same bucket and share
    one compiled executable (see ``repro.core.session``).  With the
    default floor every bucket is a multiple of 8, so the sharded
    engine's per-device split stays exact on 1/2/4/8-device meshes.
    """
    n = max(int(n), int(floor), 1)
    p = 1 << (n - 1).bit_length()          # smallest power of two >= n
    half = p // 2
    step = max(half // 4, 1)
    for m in range(1, 5):
        b = half + m * step                # half * {1.25, 1.5, 1.75, 2}
        if b >= n:
            return b
    return p


def pad_graph(graph: Graph, v_pad: int, e_pad: int) -> Graph:
    """Zero-padded view of ``graph`` with bucketed (V, E) compile shapes.

    Pad vertices are isolated (``deg_w`` 0); pad edge slots are
    weight-0 self-loops spread over the pad vertex range (or parked on
    the last vertex when V is already at its bucket), so every score
    backend treats them as exact no-ops: a scatter-add of 0.0 and a
    one-hot matmul against weight 0 both leave the real rows bit-equal.
    The engines mask the pad vertices out of migration and halting
    aggregates with a ``valid`` mask (see ``engine.make_vertex_update``),
    so pads never corrupt the result.  Note the tie-break PRNG draws over
    the PADDED vertex set, so the (equally valid, deterministic)
    trajectory depends on the bucket: bit-reproducibility holds across
    calls that share a padded layout -- which one-shot wrappers and
    sessions do by construction -- not across different buckets or
    ``pad="none"``.
    """
    V, E = graph.num_vertices, graph.num_directed_entries
    if v_pad < V or e_pad < E:
        raise ValueError(f"pad shapes ({v_pad}, {e_pad}) below graph "
                         f"shapes ({V}, {E})")
    if v_pad == V and e_pad == E:
        return graph
    extra = e_pad - E
    if extra and v_pad > V:
        pad_src = np.sort((np.arange(extra, dtype=np.int64)
                           % (v_pad - V)).astype(np.int32) + V)
    else:
        pad_src = np.full(extra, v_pad - 1, np.int32)
    src = np.concatenate([graph.src, pad_src])
    dst = np.concatenate([graph.dst, pad_src])
    w = np.concatenate([graph.weight, np.zeros(extra, np.float32)])
    counts = np.bincount(src, minlength=v_pad).astype(np.int64)
    row_ptr = np.zeros(v_pad + 1, dtype=np.int64)
    np.cumsum(counts, out=row_ptr[1:])
    deg_w = np.concatenate([graph.deg_w, np.zeros(v_pad - V, np.float32)])
    return Graph(num_vertices=v_pad, src=src, dst=dst, weight=w,
                 row_ptr=row_ptr, deg_w=deg_w)


def remove_vertices(graph: Graph, vertices) -> Graph:
    """Drop vertices (keeping ids stable) and their incident edges."""
    drop = np.zeros(graph.num_vertices, dtype=bool)
    drop[np.asarray(vertices)] = True
    keep = ~(drop[graph.src] | drop[graph.dst])
    return _finish(graph.src[keep], graph.dst[keep], graph.weight[keep],
                   graph.num_vertices)


# ---------------------------------------------------------------------------
# Tiled CSR for the Pallas kernel (see kernels/spinner_scores.py)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TiledCSR:
    """Edge chunks grouped by source-vertex tile, padded for the MXU.

    Layout: ``(num_vertex_tiles, max_chunks, tile_e)`` dense arrays.  A pad
    entry has weight 0 and src_local 0, so it contributes nothing.  Degree
    skew across tiles is reduced beforehand by interleaving vertices by
    degree rank (see ``build_tiled_csr``); the permutation is recorded so
    scores can be mapped back.
    """

    tile_v: int
    tile_e: int
    num_tiles: int
    max_chunks: int
    src_local: np.ndarray   # int32 (num_tiles, max_chunks, tile_e)
    dst: np.ndarray         # int32 (num_tiles, max_chunks, tile_e)
    weight: np.ndarray      # float32 (num_tiles, max_chunks, tile_e)
    perm: np.ndarray        # int32 (V,) original vertex -> tiled row
    inv_perm: np.ndarray    # int32 (V_pad,) tiled row -> original vertex (or -1)
    padded_v: int
    deg_t: np.ndarray = None  # f32 (num_tiles, tile_v) weighted degrees in
                              # tiled row order (0 on pad rows) -- the fused
                              # vertex-update kernel's per-tile deg_w view
    fill: np.ndarray = None   # int64 (num_tiles,) occupied slots per tile;
                              # slots [fill[t], max_chunks * tile_e) of tile
                              # t's flat region are weight-0 slack the delta
                              # merge may claim (see repro.core.delta)


def round_robin_perm(deg_w: np.ndarray, tile_v: int) -> np.ndarray:
    """Degree-balanced vertex -> tiled-row permutation.

    Round-robins vertices (sorted by weighted degree, descending) across
    ``ceil(V / tile_v)`` tiles so hub vertices spread out and per-tile edge
    counts even up; ``rank[i]`` (the i-th largest degree) lands at row
    ``(i % num_tiles) * tile_v + (i // num_tiles)``.  Exposed so the
    overlap split can tile the interior and frontier edge segments against
    ONE shared permutation (``ext_perm`` below) and hand the fused kernel a
    single per-tile degree/label/noise layout.
    """
    V = int(np.asarray(deg_w).shape[0])
    num_tiles = max(1, -(-V // tile_v))
    if V <= tile_v:
        return np.arange(V, dtype=np.int32)
    rank = np.argsort(-deg_w, kind="stable")
    # i // num_tiles <= (V-1) // num_tiles < tile_v, so no tile overflows.
    i = np.arange(V, dtype=np.int64)
    rows = np.empty(V, dtype=np.int64)
    rows[rank] = (i % num_tiles) * tile_v + (i // num_tiles)
    return rows.astype(np.int32)


def build_tiled_csr(graph: Graph, tile_v: int = 128, tile_e: int = 128,
                    balance_by_degree: bool = True,
                    pad_chunks: int = 1,
                    min_total_slots: int = 0) -> TiledCSR:
    return _tile_edge_arrays(graph.num_vertices, graph.src, graph.dst,
                             graph.weight, graph.deg_w, tile_v=tile_v,
                             tile_e=tile_e,
                             balance_by_degree=balance_by_degree,
                             pad_chunks=pad_chunks,
                             min_total_slots=min_total_slots)


def _tile_edge_arrays(V: int, src: np.ndarray, dst: np.ndarray,
                      weight: np.ndarray, deg_w: np.ndarray, *,
                      tile_v: int, tile_e: int,
                      balance_by_degree: bool, pad_chunks: int = 1,
                      ext_perm: Optional[np.ndarray] = None,
                      min_total_slots: int = 0
                      ) -> TiledCSR:
    """Tile a raw (src, dst, weight) edge list over ``V`` source rows.

    The core of ``build_tiled_csr``, shared with the per-shard tiling
    (``build_sharded_tiled_csr``), where ``dst`` carries exchange-plan
    lookup indices rather than vertex ids and therefore cannot live in a
    ``Graph`` (whose invariants demand symmetric edges with dst < V).

    ``ext_perm`` overrides the vertex -> tiled-row permutation, so two
    edge segments of the same vertex range (the overlap schedule's
    interior/frontier split) can share one row layout and their kernel
    outputs add without any re-permutation.

    Weight-0 entries (``pad_graph`` bucket filler) are dropped before
    packing: they contribute nothing to any score, and skipping them
    keeps every unused slot at the TAIL of its tile's flat region, so
    the per-tile slack is a contiguous append region the on-device delta
    merge can scatter new edges into.  ``min_total_slots`` floors the
    total slot count (num_tiles * max_chunks * tile_e), guaranteeing the
    layout carries at least the bucketed edge capacity in slack.
    """
    num_tiles = max(1, -(-V // tile_v))
    padded_v = num_tiles * tile_v

    if ext_perm is not None:
        perm = np.asarray(ext_perm, dtype=np.int32)
        assert perm.shape == (V,)
    elif balance_by_degree:
        perm = round_robin_perm(deg_w, tile_v)
    else:
        perm = np.arange(V, dtype=np.int32)

    inv_perm = np.full(padded_v, -1, dtype=np.int32)
    inv_perm[perm] = np.arange(V, dtype=np.int32)

    real = weight > 0
    if not real.all():
        src, dst, weight = src[real], dst[real], weight[real]

    new_src = perm[src]
    order = np.argsort(new_src, kind="stable")
    s = new_src[order]
    d = dst[order]                # dst stays in ORIGINAL ids (labels indexed)
    w = weight[order]

    tile_of = s // tile_v
    counts = np.bincount(tile_of, minlength=num_tiles)
    chunks_per_tile = np.maximum(1, -(-counts // tile_e))
    max_chunks = int(chunks_per_tile.max())
    if min_total_slots:
        floor_chunks = -(-int(min_total_slots) // (num_tiles * tile_e))
        max_chunks = max(max_chunks, floor_chunks)
    # pad_chunks > 1 rounds the chunk count up so the kernel's compile
    # shape stays stable as edges shift between tiles (session reuse)
    max_chunks = -(-max_chunks // pad_chunks) * pad_chunks

    src_local = np.zeros((num_tiles, max_chunks, tile_e), dtype=np.int32)
    dstA = np.zeros((num_tiles, max_chunks, tile_e), dtype=np.int32)
    wA = np.zeros((num_tiles, max_chunks, tile_e), dtype=np.float32)

    starts = np.zeros(num_tiles + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    for t in range(num_tiles):
        lo, hi = starts[t], starts[t + 1]
        n = hi - lo
        if n == 0:
            continue
        flat_sl = (s[lo:hi] - t * tile_v).astype(np.int32)
        flat_d = d[lo:hi]
        flat_w = w[lo:hi]
        nc = -(-n // tile_e)
        pad = nc * tile_e - n
        src_local[t, :nc].reshape(-1)[:n] = flat_sl
        dstA[t, :nc].reshape(-1)[:n] = flat_d
        wA[t, :nc].reshape(-1)[:n] = flat_w
        del pad
    deg_t = np.zeros(padded_v, dtype=np.float32)
    deg_t[perm] = np.asarray(deg_w[:V], dtype=np.float32)
    return TiledCSR(tile_v=tile_v, tile_e=tile_e, num_tiles=num_tiles,
                    max_chunks=max_chunks, src_local=src_local, dst=dstA,
                    weight=wA, perm=perm, inv_perm=inv_perm, padded_v=padded_v,
                    deg_t=deg_t.reshape(num_tiles, tile_v),
                    fill=counts.astype(np.int64))


@dataclasses.dataclass(frozen=True)
class ShardedTiledCSR:
    """Per-edge-shard tilings, stacked for ``shard_map`` (leading dim ndev).

    The sharded counterpart of ``TiledCSR``: each device's edge shard (see
    ``repro.core.distributed.ShardedGraph``) is tiled independently over
    its LOCAL vertex range, then padded to common (num_tiles, max_chunks)
    so the stacked arrays shard evenly over the mesh.  ``dst`` carries
    whatever index the exchange plan's lookup array expects (global vertex
    ids for all-gather/delta, halo-remapped slots for halo); pad entries
    have weight 0 and contribute nothing.
    """

    ndev: int
    tile_v: int
    tile_e: int
    num_tiles: int          # per shard (max across shards)
    max_chunks: int         # max across shards
    src_local: np.ndarray   # int32 (ndev, num_tiles, max_chunks, tile_e)
    dst: np.ndarray         # int32 (ndev, num_tiles, max_chunks, tile_e)
    weight: np.ndarray      # float32 (ndev, num_tiles, max_chunks, tile_e)
    perm: np.ndarray        # int32 (ndev, v_per_dev) local vertex -> tiled row
    inv_perm: np.ndarray = None  # int32 (ndev, num_tiles * tile_v) tiled row
                                 # -> local vertex (or -1 on pad rows)
    deg_t: np.ndarray = None     # f32 (ndev, num_tiles, tile_v) weighted
                                 # degrees in tiled row order (0 on pads)
    fill: np.ndarray = None      # int64 (ndev, num_tiles) occupied slots per
                                 # shard tile (tail slack = delta append room)


def build_sharded_tiled_csr(sg, dst_index: Optional[np.ndarray] = None,
                            tile_v: int = 128, tile_e: int = 128,
                            balance_by_degree: bool = True,
                            pad_chunks: int = 1,
                            ext_perm: Optional[np.ndarray] = None,
                            min_total_slots: int = 0
                            ) -> ShardedTiledCSR:
    """Retile a ``ShardedGraph``'s edge shards for the Pallas kernel.

    ``dst_index`` overrides the global destination ids (e.g. with an
    exchange plan's halo-remapped indices).  Each shard is tiled by
    ``build_tiled_csr`` over a per-shard view (local source ids, the
    shard's slice of the weighted degrees), so the kernel launched inside
    ``shard_map`` sees exactly the layout the single-device kernel does.
    ``ext_perm`` (``(ndev, v_per_dev)``) pins every shard's row
    permutation, letting two edge segments of one shard share a layout
    (see ``_tile_edge_arrays``).
    """
    ndev, vl = sg.ndev, sg.v_per_dev
    dsts = sg.dst if dst_index is None else np.asarray(dst_index)
    tiles = []
    for p in range(ndev):
        real = sg.weight[p] > 0
        tiles.append(_tile_edge_arrays(
            vl, sg.src_local[p][real].astype(np.int32),
            dsts[p][real].astype(np.int32),
            sg.weight[p][real].astype(np.float32), sg.deg_w[p],
            tile_v=tile_v, tile_e=tile_e,
            balance_by_degree=balance_by_degree, pad_chunks=pad_chunks,
            ext_perm=None if ext_perm is None else ext_perm[p],
            min_total_slots=min_total_slots))
    T = max(t.num_tiles for t in tiles)
    C = max(t.max_chunks for t in tiles)
    src_local = np.zeros((ndev, T, C, tile_e), np.int32)
    dstA = np.zeros((ndev, T, C, tile_e), np.int32)
    wA = np.zeros((ndev, T, C, tile_e), np.float32)
    perm = np.zeros((ndev, vl), np.int32)
    inv = np.full((ndev, T * tile_v), -1, np.int32)
    deg_t = np.zeros((ndev, T, tile_v), np.float32)
    fill = np.zeros((ndev, T), np.int64)
    for p, t in enumerate(tiles):
        src_local[p, : t.num_tiles, : t.max_chunks] = t.src_local
        dstA[p, : t.num_tiles, : t.max_chunks] = t.dst
        wA[p, : t.num_tiles, : t.max_chunks] = t.weight
        perm[p] = t.perm
        inv[p, : t.padded_v] = t.inv_perm
        deg_t[p, : t.num_tiles] = t.deg_t
        fill[p, : t.num_tiles] = t.fill
    return ShardedTiledCSR(ndev=ndev, tile_v=tile_v, tile_e=tile_e,
                           num_tiles=T, max_chunks=C, src_local=src_local,
                           dst=dstA, weight=wA, perm=perm, inv_perm=inv,
                           deg_t=deg_t, fill=fill)
