"""On-device CSR delta merge: the ``adapt(edge_updates=...)`` fast path.

Spinner's operational pitch is cheap adaptation -- "efficiently adapts the
partitioning" upon graph changes (Section 3.4) -- but a naive adapt pays a
host-side O(E) rebuild (``graph.add_edges`` -> ``from_edges``) plus an
O(E) re-upload for ANY delta.  This module makes a warm delta cost
O(|delta| log E) on the host and O(|delta|) on the wire:

  * ``DeltaTracker`` -- the host-side pair ledger.  Built once per session
    graph (the one O(E) cold cost: a sorted canonical-pair key index over
    the base edge list), it folds each ``(src, dst)`` batch through the
    EXACT ``add_edges`` weight semantics (Eq. 3 direction counting,
    including the reconstruction convention that a weight-1 pair stands
    for its canonical lo->hi direction) and emits the per-batch
    ``BatchPlan``: the symmetric weight-DELTA entries to append, the
    per-vertex degree increments, and the endpoints whose scores changed.
    Appended entries are PARALLEL edges carrying the weight delta; the
    integer Eq. 3 weights make every scatter-add sum exact, so a layout
    holding ``(u, v, 1)`` in a base slot and ``(u, v, 1)`` in a slack slot
    is score-for-score bit-identical to a rebuilt layout holding
    ``(u, v, 2)``.
  * ``DeviceDelta`` -- the session's resident merged arrays for one
    engine mode, plus the host slot bookkeeping over the layout's slack
    regions (``pad_graph``'s tail filler, the tiled CSR's per-tile tail
    slack, the sharded layout's per-segment tails).  ``plan_slots``
    assigns flat scatter indices for a batch (or reports slack overflow,
    upon which the session falls back to the bit-identical host rebuild)
    and ``apply_batch`` runs the engine's ``("delta_merge",)`` program --
    a shape-bucketed scatter, so every same-sized batch reuses one
    compiled entry and only O(|delta|) bytes cross the wire.

The session layer (``repro.core.session``) owns eligibility, fallback and
the oracle contract; this module is pure mechanism and is the coalescing
primitive the multi-tenant scheduler follow-on builds on.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import Graph, shape_bucket

# Batch arrays are padded to a bucketed length so every same-bucket batch
# shares one compiled merge entry; sentinel indices (== the target's flat
# size) are dropped by the scatter's mode="drop".
BATCH_FLOOR = 64


def check_edge_updates(src, dst, num_vertices: int,
                       new_num_vertices: Optional[int] = None
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Validate an ``edge_updates`` batch; returns int32 (src, dst).

    Rejects mismatched lengths, non-integer dtypes, negative ids and ids
    beyond the (possibly grown) vertex count with a clear ``ValueError``
    -- previously these flowed into the CSR build and either failed
    obscurely or silently grew the vertex set.
    """
    src = np.asarray(src)
    dst = np.asarray(dst)
    if src.ndim != 1 or dst.ndim != 1:
        raise ValueError(
            "edge_updates src/dst must be 1-D index arrays; got shapes "
            f"{src.shape} and {dst.shape}")
    if src.shape[0] != dst.shape[0]:
        raise ValueError(
            f"edge_updates src/dst length mismatch: {src.shape[0]} src "
            f"vs {dst.shape[0]} dst entries")
    for name, a in (("src", src), ("dst", dst)):
        if a.size and not np.issubdtype(a.dtype, np.integer):
            raise ValueError(
                f"edge_updates {name} must be integer vertex ids; got "
                f"dtype {a.dtype}")
    bound = max(int(num_vertices), int(new_num_vertices or 0))
    if src.size:
        lo = int(min(src.min(), dst.min()))
        hi = int(max(src.max(), dst.max()))
        if lo < 0:
            raise ValueError(
                f"edge_updates contain a negative vertex id ({lo})")
        if hi >= bound:
            raise ValueError(
                f"edge_updates reference vertex {hi} but the graph has "
                f"{num_vertices} vertices"
                + ("" if new_num_vertices is None else
                   f" (growing to {new_num_vertices})")
                + "; pass num_vertices to grow the vertex set explicitly")
    return src.astype(np.int32), dst.astype(np.int32)


def coalesce_updates(batches, dedupe: bool = True
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Fold queued ``(src, dst)`` edge-update batches into ONE batch
    whose single ``apply_delta`` is bit-identical to applying the
    batches one by one.

    This is the serving tier's request coalescing (``repro.serve``): N
    queued edge-update requests against one graph collapse into a single
    ``apply_delta`` plan -- one scatter, one reconvergence -- instead of
    N.  Exactness needs care because Eq. 3's pair weights canonicalize
    direction: ``add_edges`` (and the tracker mirroring it) stores a
    weight-1 pair as its canonical ``lo->hi`` edge, so re-submitting the
    SAME ``hi->lo`` edge in a LATER batch reads as the reverse direction
    and bumps the pair to weight 2, while re-submitting ``lo->hi`` is a
    no-op.  A plain concatenation dedupes that distinction away.

    The coalesced batch therefore keeps, per canonical pair, the
    direction(s) of the FIRST batch that contributed it, upgraded to
    BOTH directions when any later batch re-contributes the
    reverse-of-canonical direction.  For every prior pair weight (0, 1
    or 2) this reproduces the sequential chain's final weight exactly,
    so scores stay bit-identical (integer-valued f32 sums).  Self-loops
    are dropped (they never count).  With ``dedupe=False`` the batches
    are simply concatenated -- exact only when no pair repeats across
    batches.
    """
    batches = [b for b in batches if b is not None]
    if not batches:
        return (np.zeros(0, np.int32), np.zeros(0, np.int32))
    srcs = [np.asarray(b[0]) for b in batches]
    dsts = [np.asarray(b[1]) for b in batches]
    if not dedupe:
        return np.concatenate(srcs), np.concatenate(dsts)
    nonempty = [(s, d) for s, d in zip(srcs, dsts) if s.size]
    if not nonempty:
        return (np.zeros(0, np.int32), np.zeros(0, np.int32))
    base = max(int(max(s.max(), d.max())) for s, d in nonempty) + 1
    state: dict = {}               # canonical key -> 1 canon | 2 rev | 3
    order: list = []               # canonical keys, first-arrival order
    for s, d in nonempty:
        s = s.astype(np.int64)
        d = d.astype(np.int64)
        keep = s != d
        s, d = s[keep], d[keep]
        if not s.size:
            continue
        lo = np.minimum(s, d)
        hi = np.maximum(s, d)
        uniq, inv = np.unique(lo * base + hi, return_inverse=True)
        has_c = np.zeros(uniq.size, bool)
        has_r = np.zeros(uniq.size, bool)
        np.logical_or.at(has_c, inv, s < d)
        np.logical_or.at(has_r, inv, s > d)
        for k, hc, hr in zip(uniq.tolist(), has_c.tolist(),
                             has_r.tolist()):
            cur = state.get(k)
            if cur is None:
                state[k] = (1 if hc else 0) | (2 if hr else 0)
                order.append(k)
            elif hr and cur != 3:  # a later reverse edge bumps w 1 -> 2
                state[k] = 3
    out_s: list = []
    out_d: list = []
    for k in order:
        lo, hi = divmod(k, base)
        if state[k] & 1:
            out_s.append(lo)
            out_d.append(hi)
        if state[k] & 2:
            out_s.append(hi)
            out_d.append(lo)
    return np.asarray(out_s, np.int64), np.asarray(out_d, np.int64)


@dataclasses.dataclass
class BatchPlan:
    """One batch folded to its append-delta form (see ``DeltaTracker``)."""

    src: np.ndarray        # int32 (2 * changed_pairs,) entries to append
    dst: np.ndarray        # int32, symmetric counterparts interleaved
    dw: np.ndarray         # f32 weight DELTA carried by each entry
    touched: np.ndarray    # int32 unique endpoints of changed pairs
    pair_keys: np.ndarray  # int64 canonical keys of changed pairs
    pair_w: np.ndarray     # f32 NEW total weight of changed pairs
    tw_delta: float        # total_weight change (2 * sum of pair deltas)

    @property
    def num_entries(self) -> int:
        return int(self.src.shape[0])


class DeltaTracker:
    """Host ledger of pair weights across a session's pending deltas.

    ``plan(src, dst)`` is pure; ``commit(plan)`` folds a successfully
    merged batch into the overlay so later batches see it (sequential
    per-batch semantics, matching a chain of ``add_edges`` calls).
    """

    def __init__(self, graph: Graph):
        V = graph.num_vertices
        half = graph.src < graph.dst
        # graph arrays are lexsorted by (src, dst), so the canonical-half
        # keys come out sorted: one O(E) pass, then O(log E) lookups
        self.num_vertices = V
        self.canon_keys = (graph.src[half].astype(np.int64) * V
                           + graph.dst[half])
        self.canon_w = graph.weight[half].astype(np.float64)
        self.pairs: dict = {}          # canonical key -> overlaid weight
        self.total_weight = float(graph.total_weight)

    def _current_w(self, keys: np.ndarray) -> np.ndarray:
        w = np.zeros(keys.size, np.float64)
        if self.canon_keys.size:
            pos = np.searchsorted(self.canon_keys, keys)
            pos_c = np.minimum(pos, self.canon_keys.size - 1)
            found = self.canon_keys[pos_c] == keys
            w[found] = self.canon_w[pos_c[found]]
        for i, key in enumerate(keys):
            ov = self.pairs.get(int(key))
            if ov is not None:
                w[i] = ov
        return w

    def plan(self, src: np.ndarray, dst: np.ndarray) -> BatchPlan:
        V = self.num_vertices
        keep = src != dst                       # self-loops never count
        src, dst = src[keep], dst[keep]
        empty = BatchPlan(
            src=np.zeros(0, np.int32), dst=np.zeros(0, np.int32),
            dw=np.zeros(0, np.float32), touched=np.zeros(0, np.int32),
            pair_keys=np.zeros(0, np.int64), pair_w=np.zeros(0, np.float32),
            tw_delta=0.0)
        if src.size == 0:
            return empty
        # dedupe directed edges within the batch (from_edges semantics)
        dirkey = np.unique(src.astype(np.int64) * V + dst)
        s = dirkey // V
        d = dirkey % V
        lo = np.minimum(s, d)
        hi = np.maximum(s, d)
        is_canon = s < d
        uniq, inv = np.unique(lo * V + hi, return_inverse=True)
        has_canon = np.zeros(uniq.size, bool)
        has_rev = np.zeros(uniq.size, bool)
        np.logical_or.at(has_canon, inv, is_canon)
        np.logical_or.at(has_rev, inv, ~is_canon)
        w0 = self._current_w(uniq)
        # add_edges reconstructs a weight-1 pair as its canonical lo->hi
        # direction, so: canonical exists iff w0 >= 1, reverse iff w0 == 2
        new_w = (((w0 >= 1) | has_canon).astype(np.float64)
                 + ((w0 >= 2) | has_rev).astype(np.float64))
        change = new_w > w0
        if not change.any():
            return empty
        uniq, w0, new_w = uniq[change], w0[change], new_w[change]
        dw_pair = (new_w - w0).astype(np.float32)
        p_lo = (uniq // V).astype(np.int32)
        p_hi = (uniq % V).astype(np.int32)
        # each changed pair appends BOTH directed entries carrying dw
        e_src = np.stack([p_lo, p_hi], axis=1).reshape(-1)
        e_dst = np.stack([p_hi, p_lo], axis=1).reshape(-1)
        e_dw = np.stack([dw_pair, dw_pair], axis=1).reshape(-1)
        return BatchPlan(
            src=e_src, dst=e_dst, dw=e_dw,
            touched=np.unique(e_src).astype(np.int32),
            pair_keys=uniq, pair_w=new_w.astype(np.float32),
            tw_delta=float(2.0 * dw_pair.sum()))

    def commit(self, plan: BatchPlan) -> None:
        for key, w in zip(plan.pair_keys, plan.pair_w):
            self.pairs[int(key)] = float(w)
        self.total_weight += plan.tw_delta


# ---------------------------------------------------------------------------
# Device-resident merged arrays + slack-slot bookkeeping per engine mode
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DeviceDelta:
    """The session's merged device arrays for one engine mode.

    ``score`` mirrors the score backend's arg tuple structure exactly and
    ``deg_w`` the engine's degree array, so a hand-built ``GraphBind`` /
    sharded arg tuple over these arrays drops into the SAME compiled
    programs the session's regular runs use.  The remaining fields are
    host-side slot state over the layout's slack regions.
    """

    mode: str                  # single_xla | single_pallas | sharded_xla
    score: tuple               # merged backend edge arrays (jnp)
    deg_w: jax.Array           # merged degrees: (v_pad,) or (ndev, v_l)
    coo: tuple = ()            # single_pallas: merged COO (src, dst) for
                               # the frontier expansion index
    # --- single-device COO (and the pallas frontier COO) ---
    next_slot: int = 0         # first free tail slot of the padded COO
    e_capacity: int = 0        # total COO slots (the edge bucket)
    # --- single_pallas tiled layout ---
    tile_v: int = 0
    region: int = 0            # max_chunks * tile_e slots per tile
    perm: Optional[np.ndarray] = None     # (V,) vertex -> tiled row
    fill: Optional[np.ndarray] = None     # (T,) occupied slots per tile
    # --- sharded_xla layout ---
    v_per_dev: int = 0
    e_shard: int = 0
    e_interior: int = 0
    int_fill: Optional[np.ndarray] = None  # (ndev,) abs col of int. slack
    fro_fill: Optional[np.ndarray] = None  # (ndev,) abs col of fro. slack


def init_single_xla(score_args: tuple, deg_w: jax.Array,
                    num_entries: int) -> DeviceDelta:
    """Mode A: the padded COO upload; slack = pad_graph's tail filler."""
    src, dst, w = score_args
    return DeviceDelta(mode="single_xla", score=(src, dst, w), deg_w=deg_w,
                       next_slot=int(num_entries),
                       e_capacity=int(src.shape[0]))


def init_single_pallas(score_args: tuple, deg_w: jax.Array, coo: tuple,
                       tiled_meta, num_entries: int) -> DeviceDelta:
    """Mode B: the fused tiled layout; slack = per-tile tail slots.

    ``tiled_meta`` is the host ``TiledCSR`` whose jnp mirror ``score_args``
    is (same deterministic build); ``coo`` is the padded COO (src, dst)
    pair that doubles as the frontier expansion index, merged in lockstep
    so expansion sees appended edges.
    """
    return DeviceDelta(
        mode="single_pallas", score=tuple(score_args), deg_w=deg_w,
        coo=tuple(coo), next_slot=int(num_entries),
        e_capacity=int(coo[0].shape[0]), tile_v=int(tiled_meta.tile_v),
        region=int(tiled_meta.max_chunks * tiled_meta.tile_e),
        perm=np.asarray(tiled_meta.perm),
        fill=np.asarray(tiled_meta.fill, dtype=np.int64).copy())


def init_sharded_xla(score_args: tuple, deg_w: jax.Array, sg) -> DeviceDelta:
    """Mode C: the sharded [interior | frontier] layout; slack = both
    segment tails of every device row (segment identity is irrelevant off
    the overlap schedule, which the fast path pins off)."""
    return DeviceDelta(
        mode="sharded_xla", score=tuple(score_args), deg_w=deg_w,
        v_per_dev=int(sg.v_per_dev), e_shard=int(sg.src_local.shape[1]),
        e_interior=int(sg.e_interior),
        int_fill=np.asarray(sg.interior_counts, np.int64).copy(),
        fro_fill=(int(sg.e_interior)
                  + np.asarray(sg.frontier_counts, np.int64)).copy())


def _bucket_pad(arrs, n: int, sentinel: int):
    """Pad batch arrays to a shape bucket; index arrays get the dropped
    sentinel, value arrays zero."""
    m = shape_bucket(max(n, 1), BATCH_FLOOR)
    out = []
    for a, is_idx in arrs:
        padded = np.full(m, sentinel if is_idx else 0,
                         dtype=a.dtype if a.size else
                         (np.int64 if is_idx else np.float32))
        padded[:n] = a
        out.append(padded)
    return out


def plan_slots(dd: DeviceDelta, plan: BatchPlan):
    """Flat scatter slots for a batch, or None if slack would overflow.

    Pure: commits nothing.  Returns ``(slots, commit)`` where ``commit()``
    advances the host fill state after a successful device merge.
    """
    n = plan.num_entries
    e_src = plan.src.astype(np.int64)
    if dd.mode == "single_xla":
        if dd.next_slot + n > dd.e_capacity:
            return None
        slots = dd.next_slot + np.arange(n, dtype=np.int64)

        def commit():
            dd.next_slot += n

        return (slots,), commit
    if dd.mode == "single_pallas":
        if dd.next_slot + n > dd.e_capacity:
            return None
        rows = dd.perm[plan.src].astype(np.int64)
        tiles = rows // dd.tile_v
        counts = np.bincount(tiles, minlength=dd.fill.shape[0])
        if np.any(dd.fill + counts > dd.region):
            return None
        order = np.argsort(tiles, kind="stable")
        ts = tiles[order]
        csum = np.cumsum(counts) - counts
        within = np.arange(n, dtype=np.int64) - csum[ts]
        tile_slots = np.empty(n, dtype=np.int64)
        tile_slots[order] = ts * dd.region + dd.fill[ts] + within
        coo_slots = dd.next_slot + np.arange(n, dtype=np.int64)

        def commit():
            dd.fill += counts
            dd.next_slot += n

        return (tile_slots, coo_slots), commit
    if dd.mode == "sharded_xla":
        dev = e_src // dd.v_per_dev
        ndev = dd.int_fill.shape[0]
        counts = np.bincount(dev, minlength=ndev)
        int_avail = dd.e_interior - dd.int_fill
        fro_avail = dd.e_shard - dd.fro_fill
        if np.any(counts > int_avail + fro_avail):
            return None
        order = np.argsort(dev, kind="stable")
        ds = dev[order]
        csum = np.cumsum(counts) - counts
        within = np.arange(n, dtype=np.int64) - csum[ds]
        in_interior = within < int_avail[ds]
        col = np.where(in_interior, dd.int_fill[ds] + within,
                       dd.fro_fill[ds] + within - int_avail[ds])
        slots = np.empty(n, dtype=np.int64)
        slots[order] = ds * dd.e_shard + col

        def commit():
            used_int = np.minimum(counts, int_avail)
            dd.int_fill += used_int
            dd.fro_fill += counts - used_int

        return (slots,), commit
    raise ValueError(f"unknown DeviceDelta mode {dd.mode!r}")


def apply_batch(dd: DeviceDelta, plan: BatchPlan, slotting,
                merge_run) -> Tuple[DeviceDelta, int]:
    """Scatter one planned batch into the merged arrays on device.

    ``merge_run`` is the engine's ``("delta_merge",)`` program callable.
    Returns the updated ``DeviceDelta`` (fresh jnp arrays, functional
    update) and the batch upload byte count -- O(|delta|), the transfer
    the session's ``stats()`` counters account.
    """
    slots, commit = slotting
    n = plan.num_entries
    src32 = plan.src.astype(np.int32)
    dst32 = plan.dst.astype(np.int32)
    dw32 = plan.dw.astype(np.float32)
    host_arrays = []

    def dev(a):
        host_arrays.append(a)
        return jnp.asarray(a)

    if dd.mode == "single_xla":
        (coo_slots,) = slots
        idx = dev(_bucket_pad([(coo_slots, True)], n,
                              int(dd.score[0].size))[0])
        vs, vd, vw = (dev(a) for a in _bucket_pad(
            [(src32, False), (dst32, False), (dw32, False)], n, 0))
        set_groups = ((dd.score, idx, (vs, vd, vw)),)
        didx = dev(_bucket_pad([(plan.src.astype(np.int64), True)], n,
                               int(dd.deg_w.size))[0])
        add_groups = ((dd.deg_w, didx, vw),)
        (new_score,), (new_deg,) = merge_run(set_groups, add_groups)
        out = dataclasses.replace(dd, score=tuple(new_score),
                                  deg_w=new_deg)
    elif dd.mode == "single_pallas":
        tile_slots, coo_slots = slots
        sl_local = (dd.perm[plan.src] % dd.tile_v).astype(np.int32)
        t_idx = dev(_bucket_pad([(tile_slots, True)], n,
                                int(dd.score[0].size))[0])
        c_idx = dev(_bucket_pad([(coo_slots, True)], n,
                                int(dd.coo[0].size))[0])
        v_sl, v_s, v_d, v_w = (dev(a) for a in _bucket_pad(
            [(sl_local, False), (src32, False), (dst32, False),
             (dw32, False)], n, 0))
        # tiled (src_local, dst, weight) share tile slots; the COO mirror
        # (frontier expansion index) shares its own tail slots
        set_groups = (
            ((dd.score[0], dd.score[1], dd.score[2]), t_idx,
             (v_sl, v_d, v_w)),
            (dd.coo, c_idx, (v_s, v_d)),
        )
        row_idx = dev(_bucket_pad(
            [(dd.perm[plan.src].astype(np.int64), True)], n,
            int(dd.score[5].size))[0])
        deg_idx = dev(_bucket_pad([(plan.src.astype(np.int64), True)], n,
                                  int(dd.deg_w.size))[0])
        add_groups = ((dd.score[5], row_idx, v_w),
                      (dd.deg_w, deg_idx, v_w))
        (tiled3, coo2), (new_deg_t, new_deg) = merge_run(set_groups,
                                                         add_groups)
        out = dataclasses.replace(
            dd, score=tuple(tiled3) + dd.score[3:5] + (new_deg_t,),
            coo=tuple(coo2), deg_w=new_deg)
    elif dd.mode == "sharded_xla":
        (flat_slots,) = slots
        sl_local = (plan.src.astype(np.int64) % dd.v_per_dev
                    ).astype(np.int32)
        idx = dev(_bucket_pad([(flat_slots, True)], n,
                              int(dd.score[0].size))[0])
        v_sl, v_d, v_w = (dev(a) for a in _bucket_pad(
            [(sl_local, False), (dst32, False), (dw32, False)], n, 0))
        set_groups = ((dd.score, idx, (v_sl, v_d, v_w)),)
        # deg_w is (ndev, v_per_dev) over contiguous ranges: flat id = u
        didx = dev(_bucket_pad([(plan.src.astype(np.int64), True)], n,
                               int(dd.deg_w.size))[0])
        add_groups = ((dd.deg_w, didx, v_w),)
        (new_score,), (new_deg,) = merge_run(set_groups, add_groups)
        out = dataclasses.replace(dd, score=tuple(new_score),
                                  deg_w=new_deg)
    else:
        raise ValueError(f"unknown DeviceDelta mode {dd.mode!r}")
    # commit AFTER a successful scatter but BEFORE snapshotting the host
    # slot state into the returned DeviceDelta (commit mutates dd's
    # fill/next_slot fields in place)
    commit()
    out = dataclasses.replace(
        out, next_slot=dd.next_slot, fill=dd.fill,
        int_fill=dd.int_fill, fro_fill=dd.fro_fill)
    return out, int(sum(a.nbytes for a in host_arrays))


def apply_delta(tracker: DeltaTracker, dd: DeviceDelta, src, dst,
                merge_run):
    """The one-call coalescing primitive: plan a ``(src, dst)`` batch
    against the pair ledger, assign slack slots, scatter it into the
    resident device arrays, and commit the ledger.

    Returns ``(new_dd, plan, uploaded_bytes)``, or ``None`` when the
    batch would overflow the layout's slack (nothing is committed; the
    caller rebuilds from the logical edge list -- bit-identically,
    because appended delta entries carry exact integer weight sums).
    This is the primitive a multi-tenant delta scheduler coalesces
    through: batches validated with ``check_edge_updates`` fold
    sequentially with ``add_edges`` union semantics.
    """
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    plan = tracker.plan(src, dst)
    nbytes = 0
    if plan.num_entries:
        slotting = plan_slots(dd, plan)
        if slotting is None:
            return None
        dd, nbytes = apply_batch(dd, plan, slotting, merge_run)
    tracker.commit(plan)
    return dd, plan, nbytes
