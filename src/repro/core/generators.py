"""Seeded synthetic graph generators.

The paper evaluates on proprietary social graphs (Tuenti, Twitter, ...) and on
Watts-Strogatz small-world graphs (Section 5.2).  Offline we generate, with
fixed seeds: Watts-Strogatz (their scalability workload), preferential-
attachment power-law graphs (hub structure like Twitter, Section 5.1), and a
few simple topologies for oracles.
"""
from __future__ import annotations

import numpy as np

from .graph import Graph, from_edges


def watts_strogatz(n: int, k_nbrs: int, beta: float, seed: int = 0) -> Graph:
    """Ring lattice with ``k_nbrs`` out-edges per vertex, ``beta`` rewired.

    Matches Section 5.2: directed ring lattice, fraction beta of edge targets
    rewired uniformly at random.
    """
    assert k_nbrs < n
    rng = np.random.default_rng(seed)
    src = np.repeat(np.arange(n, dtype=np.int64), k_nbrs)
    offs = np.tile(np.arange(1, k_nbrs + 1, dtype=np.int64), n)
    dst = (src + offs) % n
    rewire = rng.random(src.shape[0]) < beta
    dst[rewire] = rng.integers(0, n, size=int(rewire.sum()))
    # avoid self loops from rewiring
    self_loop = dst == src
    dst[self_loop] = (dst[self_loop] + 1) % n
    return from_edges(src.astype(np.int32), dst.astype(np.int32), n,
                      directed=True)


def powerlaw_ba(n: int, m: int, seed: int = 0) -> Graph:
    """Barabasi-Albert preferential attachment: power-law degrees (hubs).

    Vectorized repeated-nodes implementation: new vertex t attaches m edges
    to targets sampled from the degree-proportional pool.
    """
    rng = np.random.default_rng(seed)
    assert n > m >= 1
    # seed clique-ish core of m+1 vertices
    core_src, core_dst = [], []
    for i in range(m + 1):
        for j in range(i + 1, m + 1):
            core_src.append(i)
            core_dst.append(j)
    pool = list(np.repeat(np.arange(m + 1), m))  # degree-proportional pool
    src_list = [np.array(core_src, dtype=np.int64)]
    dst_list = [np.array(core_dst, dtype=np.int64)]
    pool = np.array(pool, dtype=np.int64)
    for t in range(m + 1, n):
        samples = pool[rng.integers(0, pool.shape[0], size=3 * m)]
        # first-occurrence unique (np.unique would sort and bias toward
        # low ids, creating unboundedly rich hubs)
        _, first = np.unique(samples, return_index=True)
        targets = samples[np.sort(first)][:m]
        if targets.shape[0] < m:
            extra = rng.integers(0, t, size=m - targets.shape[0])
            targets = np.unique(np.concatenate([targets, extra]))
        src_list.append(np.full(targets.shape[0], t, dtype=np.int64))
        dst_list.append(targets)
        pool = np.concatenate([pool, targets,
                               np.full(targets.shape[0], t, dtype=np.int64)])
    src = np.concatenate(src_list)
    dst = np.concatenate(dst_list)
    return from_edges(src.astype(np.int32), dst.astype(np.int32), n,
                      directed=False)


def grid_2d(rows: int, cols: int) -> Graph:
    """4-connected grid; the partitioning oracle (good cuts are known)."""
    idx = np.arange(rows * cols, dtype=np.int64).reshape(rows, cols)
    right = np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()])
    down = np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()])
    src = np.concatenate([right[0], down[0]])
    dst = np.concatenate([right[1], down[1]])
    return from_edges(src.astype(np.int32), dst.astype(np.int32),
                      rows * cols, directed=False)


def erdos_renyi(n: int, avg_deg: float, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    m = int(n * avg_deg / 2)
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)
    return from_edges(src.astype(np.int32), dst.astype(np.int32), n,
                      directed=False)


def clustered_graph(num_clusters: int, cluster_size: int, p_in: float,
                    p_out_edges_per_v: float, seed: int = 0) -> Graph:
    """Planted-partition graph: ground-truth communities for quality tests."""
    rng = np.random.default_rng(seed)
    n = num_clusters * cluster_size
    srcs, dsts = [], []
    for c in range(num_clusters):
        base = c * cluster_size
        m_in = int(p_in * cluster_size * cluster_size / 2)
        s = rng.integers(0, cluster_size, size=m_in) + base
        d = rng.integers(0, cluster_size, size=m_in) + base
        srcs.append(s)
        dsts.append(d)
    m_out = int(p_out_edges_per_v * n)
    srcs.append(rng.integers(0, n, size=m_out))
    dsts.append(rng.integers(0, n, size=m_out))
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    return from_edges(src.astype(np.int32), dst.astype(np.int32), n,
                      directed=False)
