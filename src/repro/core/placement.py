"""Spinner-driven placement inside the LM framework (beyond-paper).

Two framework placement problems are graph partitioning in disguise; both
reuse the identical core LPA:

1.  **MoE expert placement** (``place_experts``): experts co-activated by
    the same token (top-k routing) exchange all-to-all traffic when they
    live on different EP shards.  Build the expert co-activation graph
    (edge weight ~ how often two experts fire for the same token), Spinner
    it into n_shards balanced parts -> an expert->shard map that minimizes
    cross-shard co-activation mass while keeping shards load-balanced.
2.  **Pipeline stage assignment** (``place_pipeline_stages``): the layer
    dependency chain weighted by per-layer cost, partitioned into S
    balanced contiguous-ish stages.

Both return the partition plus before/after traffic metrics; see
benchmarks/bench_placement.py for the evaluation on the assigned MoE
architectures.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from . import metrics
from .graph import Graph, _finish, from_edges
from .session import PartitionSession
from .spinner import SpinnerConfig, partition


def coactivation_graph(choices: np.ndarray, n_experts: int,
                       max_edges: int = 2_000_000):
    """choices: (T, top_k) int expert ids per token -> weighted expert graph.

    Edge multiplicity = number of tokens that co-activate the pair; the
    Eq. (3) weighting then reflects reciprocal traffic.
    """
    t, k = choices.shape
    pairs = []
    for i in range(k):
        for j in range(i + 1, k):
            pairs.append(np.stack([choices[:, i], choices[:, j]], axis=1))
    e = np.concatenate(pairs, axis=0)
    e = e[e[:, 0] != e[:, 1]]
    if e.shape[0] > max_edges:
        idx = np.random.default_rng(0).choice(e.shape[0], max_edges,
                                              replace=False)
        e = e[idx]
    # keep multiplicity as edge WEIGHT (co-activation count)
    lo = np.minimum(e[:, 0], e[:, 1]).astype(np.int64)
    hi = np.maximum(e[:, 0], e[:, 1]).astype(np.int64)
    key = lo * n_experts + hi
    uniq, counts = np.unique(key, return_counts=True)
    u = (uniq // n_experts).astype(np.int32)
    v = (uniq % n_experts).astype(np.int32)
    w = counts.astype(np.float32)
    return _finish(np.concatenate([u, v]), np.concatenate([v, u]),
                   np.concatenate([w, w]), n_experts)


def cross_shard_mass(choices: np.ndarray, assignment: np.ndarray) -> float:
    """Fraction of co-activated expert pairs split across shards."""
    t, k = choices.shape
    shards = assignment[choices]              # (T, k)
    total, cross = 0, 0
    for i in range(k):
        for j in range(i + 1, k):
            neq = shards[:, i] != shards[:, j]
            valid = choices[:, i] != choices[:, j]
            total += int(valid.sum())
            cross += int((neq & valid).sum())
    return cross / max(1, total)


# Incremental re-placement sessions, one per (n_experts, n_shards, seed):
# routing drift produces a stream of co-activation graphs of the same
# expert count, so successive place_experts(prev=...) calls land in the
# same shape bucket and reuse one compiled runner (see core.session).
# FIFO-bounded so seed/shard sweeps cannot accumulate graphs forever.
_PLACEMENT_SESSIONS: dict = {}
_PLACEMENT_SESSIONS_MAX = 8


def _placement_session(key, graph, cfg):
    sess = _PLACEMENT_SESSIONS.get(key)
    if sess is None:
        while len(_PLACEMENT_SESSIONS) >= _PLACEMENT_SESSIONS_MAX:
            _PLACEMENT_SESSIONS.pop(
                next(iter(_PLACEMENT_SESSIONS))).close()
        sess = _PLACEMENT_SESSIONS[key] = PartitionSession(graph, cfg)
    return sess


def place_experts(choices: np.ndarray, n_experts: int, n_shards: int,
                  seed: int = 0, prev: Optional[np.ndarray] = None,
                  graph: Optional[Graph] = None
                  ) -> Tuple[np.ndarray, dict]:
    """Spinner-partition experts across EP shards from router statistics.

    ``prev`` enables incremental re-placement as routing drifts
    (Section 3.4 applied to the serving plane); those calls ride a
    reused ``PartitionSession``, so re-placing after a routing shift
    costs an upload, not a compile.  ``graph`` accepts a precomputed
    co-activation graph (``coactivation_graph(choices, n_experts)``) so
    callers that also consume the graph -- e.g. the application bench
    running Pregel over it hash-vs-spinner -- build it once.
    """
    g = coactivation_graph(choices, n_experts) if graph is None else graph
    cfg = SpinnerConfig(k=n_shards, seed=seed, max_iters=150)
    if prev is None:
        res = partition(g, cfg, record_history=False)
    else:
        sess = _placement_session((n_experts, n_shards, seed), g, cfg)
        res = sess.adapt(g, prev=np.asarray(prev, np.int32),
                         record_history=False)
    contiguous = (np.arange(n_experts) * n_shards // n_experts
                  ).astype(np.int32)
    stats = {
        "cross_before": cross_shard_mass(choices, contiguous),
        "cross_after": cross_shard_mass(choices, res.labels),
        "rho": metrics.rho(g, res.labels, n_shards),
        "iterations": res.iterations,
        "moved_from_prev": (None if prev is None else
                            metrics.partitioning_difference(prev, res.labels)),
    }
    stats["traffic_reduction"] = 1.0 - (
        stats["cross_after"] / max(1e-9, stats["cross_before"]))
    return res.labels, stats


def expert_placement_case(n_experts: int = 256, n_tokens: int = 20_000,
                          top_k: int = 2, n_shards: int = 8,
                          seed: int = 0) -> Tuple[Graph, np.ndarray, dict]:
    """(graph, labels, stats): a ready-made MoE expert-placement case.

    Synthesizes clustered router statistics (experts fall into latent
    groups tokens co-activate within), builds the co-activation graph
    ONCE, Spinner-places it -- and returns the pair an application run
    consumes: ``repro.apps.run_app(graph, labels, ...)`` vs the same
    call with hash labels is the expert-graph leg of the
    hash-vs-spinner bench (``benchmarks/bench_apps.py``).
    """
    rng = np.random.default_rng(seed)
    groups = rng.integers(0, n_shards, n_experts)
    tok_grp = rng.integers(0, n_shards, n_tokens)
    choices = np.empty((n_tokens, top_k), np.int64)
    for i in range(top_k):
        # 95% of picks stay inside the token's latent group: routers
        # specialize hard post-training, and the sharper the structure
        # the more vertex-granular halo traffic placement can remove
        in_grp = rng.random(n_tokens) < 0.95
        pick = rng.integers(0, n_experts, n_tokens)
        same = np.where(groups[pick] == tok_grp, True, False)
        retry = pick.copy()
        for _ in range(8):      # rejection-sample toward the group
            bad = in_grp & ~same
            if not bad.any():
                break
            retry[bad] = rng.integers(0, n_experts, int(bad.sum()))
            same = groups[retry] == tok_grp
            pick = retry
        choices[:, i] = pick
    g = coactivation_graph(choices, n_experts)
    labels, stats = place_experts(choices, n_experts, n_shards, seed=seed,
                                  graph=g)
    return g, labels, stats


def place_pipeline_stages(layer_costs: np.ndarray, n_stages: int,
                          seed: int = 0) -> Tuple[np.ndarray, dict]:
    """Balanced chain partitioning of the layer graph into stages.

    The layer chain L0-L1-...-Ln with edge weight ~ activation traffic and
    vertex cost ~ FLOPs; we encode cost on edges (mean of endpoints) and
    let Spinner balance edge mass per stage.
    """
    n = layer_costs.shape[0]
    src = np.arange(n - 1, dtype=np.int32)
    dst = src + 1
    # Weighting the chain by cost through edge multiplicity does not
    # survive from_edges (duplicates collapse per Eq. 3), so we run the
    # plain chain and report the cost balance of the result instead.
    g = from_edges(src, dst, n, directed=False)
    cfg = SpinnerConfig(k=n_stages, seed=seed, max_iters=200, c=1.10)
    res = partition(g, cfg, record_history=False)
    stage_cost = np.zeros(n_stages)
    np.add.at(stage_cost, res.labels, layer_costs)
    contiguous = (np.arange(n) * n_stages // n).astype(np.int32)
    cont_cost = np.zeros(n_stages)
    np.add.at(cont_cost, contiguous, layer_costs)
    cut = int((res.labels[src] != res.labels[dst]).sum())
    stats = {
        "stage_cost_max_over_mean":
            float(stage_cost.max() / max(stage_cost.mean(), 1e-9)),
        "contiguous_max_over_mean":
            float(cont_cost.max() / max(cont_cost.mean(), 1e-9)),
        "cut_edges": cut,
        "min_possible_cuts": n_stages - 1,
    }
    return res.labels, stats
