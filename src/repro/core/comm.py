"""Communication plans for the sharded engines (Section 3.3 / Figure 7).

Spinner's Pregel design wins because per-superstep traffic SHRINKS as
labels converge: a vertex only messages its neighbors when it migrates, so
"messages sent" decays by orders of magnitude over a run (Figure 7).  This
module makes that communication structure an explicit, pluggable layer,
shared by the sharded LPA engine (``repro.core.engine``) and the
distributed Pregel applications (``repro.core.pregel_dist``):

  * ``build_halo_index`` -- the generic halo-plan construction: given which
    device owns each edge and the placed id of the edge's remote endpoint,
    compute (a) the per-pair send lists each owner must push and (b) a
    remapped per-edge index into ``[local values | received halo]``.  This
    is the machinery that used to live privately in ``pregel_dist``; both
    PageRank-over-placement and the LPA engine now share this one copy.
  * ``halo_exchange`` -- the matching traced collective: gather the send
    rows, one ``all_to_all``, concatenate local + halo into the lookup
    array the remapped indices address.
  * ``ExchangePlan`` implementations for the LPA engine's per-iteration
    label exchange, selected by ``SpinnerConfig.label_exchange``:

      - ``allgather`` -- ship the full int32 label vector every iteration
        (the bit-compatible oracle; O(V) bytes per iteration);
      - ``halo``      -- ship only the boundary labels other devices'
        edge shards actually reference (O(cut) bytes, static);
      - ``halo_delta`` -- the halo topology with delta accounting: only
        boundary values that CHANGED since the last exchange are
        counted (O(active cut) bytes -- placement-sensitive AND
        decaying; the transport for ``repro.apps``' shrinking-frontier
        workloads);
      - ``delta``     -- ship only labels that CHANGED last iteration
        (O(migrations) bytes, decaying like Figure 7 as the partitioning
        converges).

    All three plans produce bit-identical label trajectories -- they are
    pure communication strategies; parity is enforced by
    ``tests/test_sharded_engine.py``.

Accounting: every plan reports ``exchanged_bytes`` per iteration -- the
bytes a message-passing runtime would put on the wire under that plan
(changed labels broadcast for delta, true boundary values for halo, the
whole vector for allgather).  The XLA lowering itself moves static-shape
buffers (padded halo rows, a capped delta buffer with an all-gather
fallback); the static buffer sizes are reported separately by
``repro.core.distributed.comm_stats``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec


# ---------------------------------------------------------------------------
# Generic halo-plan construction (shared by pregel_dist and the LPA engine)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HaloIndex:
    """Send lists + remapped per-edge indices for a halo exchange.

    ``ext_idx[e]`` addresses ``concatenate([local_values, halo])`` where
    ``halo`` is the ``(ndev, H)`` result of ``all_to_all`` over the rows of
    ``send_idx[this_device]`` -- i.e. slot ``v_per_dev + p * H + s`` holds
    the ``s``-th value owner ``p`` sent to this device.
    """

    ndev: int
    v_per_dev: int
    halo_size: int             # H: max per-pair halo entries (padding unit)
    true_halo: int             # sum of real (unpadded) halo entries
    send_idx: np.ndarray       # (ndev, ndev, H) int32 local ids owner->needer
    ext_idx: np.ndarray        # (E,) int64 per-edge index into [local | halo]
    send_counts: np.ndarray    # (ndev, ndev) int32 REAL entries per pair
                               # (slots >= count are padding; see halo_delta)


def build_halo_index(edge_owner: np.ndarray, remote_ids: np.ndarray,
                     ndev: int, v_per_dev: int,
                     pad_halo: bool = False) -> HaloIndex:
    """Build the halo plan for edges referencing remote vertex values.

    Args:
      edge_owner: (E,) device owning each edge (where its computation runs).
      remote_ids: (E,) placed id of each edge's remote endpoint -- the
        vertex whose value the edge must read.  Placement is contiguous
        range partitioning: device p owns ``[p*v_per_dev, (p+1)*v_per_dev)``.
      pad_halo: bucket the per-pair halo size H (power-of-two-ish) so the
        all_to_all compile shape survives boundary-set drift when a
        session rebinds a grown graph; pad slots send vertex 0's value
        redundantly and no edge ever reads them.
    """
    edge_owner = np.asarray(edge_owner)
    remote_ids = np.asarray(remote_ids)
    remote_owner = remote_ids // v_per_dev

    need = {}                  # (needer q, owner p) -> sorted unique ids
    H = 1
    true_halo = 0
    for q in range(ndev):
        qe = edge_owner == q
        for p in range(ndev):
            if p == q:
                continue
            ids = np.unique(remote_ids[qe & (remote_owner == p)])
            need[(q, p)] = ids
            true_halo += ids.size
            H = max(H, int(ids.size))
    if pad_halo:
        from .graph import shape_bucket
        H = shape_bucket(H, floor=8)

    send_idx = np.zeros((ndev, ndev, H), np.int32)   # [owner p][needer q]
    send_counts = np.zeros((ndev, ndev), np.int32)
    for (q, p), ids in need.items():
        send_idx[p, q, : ids.size] = (ids - p * v_per_dev).astype(np.int32)
        send_counts[p, q] = ids.size

    ext_idx = np.empty(edge_owner.shape[0], np.int64)
    local = remote_owner == edge_owner
    ext_idx[local] = remote_ids[local] - edge_owner[local] * v_per_dev
    for (q, p), ids in need.items():
        sel = (edge_owner == q) & (remote_owner == p)
        if not sel.any():
            continue
        ext_idx[sel] = v_per_dev + p * H + np.searchsorted(ids,
                                                           remote_ids[sel])
    return HaloIndex(ndev=ndev, v_per_dev=v_per_dev, halo_size=H,
                     true_halo=true_halo, send_idx=send_idx, ext_idx=ext_idx,
                     send_counts=send_counts)


def halo_exchange_start(values_local: jax.Array, send_idx_dev: jax.Array,
                        axis: str) -> Tuple[jax.Array, jax.Array]:
    """Issue the halo collective: ``(values_local, (ndev, H) halo)``.

    The one copy of the halo wire format (gather the send rows, one
    ``all_to_all``); ``halo_exchange_finish`` assembles the lookup.
    Split so the overlap schedule can compute between the halves.
    """
    outbox = values_local[send_idx_dev]                     # (ndev, H)
    halo = jax.lax.all_to_all(outbox, axis, split_axis=0, concat_axis=0)
    return values_local, halo


def halo_exchange_finish(values_local: jax.Array,
                         halo: jax.Array) -> jax.Array:
    """Assemble the ``[local | halo]`` lookup from a started exchange."""
    return jnp.concatenate([values_local, halo.reshape(-1)])


def halo_exchange(values_local: jax.Array, send_idx_dev: jax.Array,
                  axis: str) -> jax.Array:
    """One halo exchange (traced, inside ``shard_map``).

    ``values_local`` is this device's ``(v_per_dev,)`` value shard;
    ``send_idx_dev`` its ``(ndev, H)`` send rows.  Returns the
    ``(v_per_dev + ndev * H,)`` lookup array addressed by
    ``HaloIndex.ext_idx``.
    """
    return halo_exchange_finish(*halo_exchange_start(values_local,
                                                     send_idx_dev, axis))


# ---------------------------------------------------------------------------
# Exchange plans for the sharded LPA engine
# ---------------------------------------------------------------------------

class ExchangePlan:
    """How the sharded LPA step turns local label shards into the lookup
    array its edge shard reads.

    Host-side products (built once per (graph layout, plan)):
      * ``dst_index`` -- the (ndev, E_shard) per-edge index each score
        backend uses against the plan's lookup array (global vertex ids
        for allgather/delta, halo-remapped ids for halo);
      * ``device_args()`` / ``arg_specs(axis)`` -- extra arrays threaded
        through ``shard_map`` (e.g. halo send lists), leading dim = ndev.

    Traced methods (called inside ``shard_map``):
      * ``init_aux(labels_local, axis, *args)`` -- the plan's loop-carried
        auxiliary state (e.g. delta's replicated label mirror);
      * ``start_exchange(labels_local, aux, axis, *args)`` -- issue the
        plan's collectives and return an opaque pending pytree.  Under
        the engine's overlap schedule this is called BEFORE interior
        scoring, so the wire transfer and the interior scatter-add/
        matmul are dataflow-independent and XLA's latency-hiding
        scheduler can run them concurrently;
      * ``finish_exchange(pending)`` -- complete the exchange:
        ``(lookup, new_aux, wire_bytes)`` where ``wire_bytes`` is the
        f32 per-iteration message volume accumulated into
        ``SpinnerState.exchanged_bytes``;
      * ``exchange(labels_local, aux, axis, *args)`` -- the composed
        single-phase form (``finish_exchange(start_exchange(...))``),
        what the non-overlapped schedule calls.

    Static identity (``signature()`` / ``from_signature``): the traced
    methods only read python-int shape parameters off ``self``, so a plan
    is fully described -- for compile purposes -- by its signature tuple.
    The engine's global program cache keys on that signature and traces
    against a ``from_signature`` view, which lets two different graphs
    whose layouts share the same shape bucket share one compiled sharded
    runner (see ``repro.core.session``).
    """

    name: str
    dst_index: np.ndarray

    def signature(self) -> tuple:
        """Static ints the traced methods close over (program cache key)."""
        raise NotImplementedError

    @classmethod
    def from_signature(cls, sig: tuple) -> "ExchangePlan":
        """Array-free trace view reconstructed from ``signature()``."""
        raise NotImplementedError

    def device_args(self) -> Tuple[jax.Array, ...]:
        return ()

    def arg_specs(self, axis: str) -> Tuple[PartitionSpec, ...]:
        return ()

    def wire_bytes_per_iter(self) -> Optional[int]:
        """Static per-iteration message bytes; None = measured on device."""
        raise NotImplementedError

    def init_aux(self, labels_local: jax.Array, axis: str, *args):
        return ()

    def start_exchange(self, labels_local: jax.Array, aux, axis: str,
                       *args):
        """Issue the plan's collectives; returns an opaque pending value."""
        raise NotImplementedError

    def finish_exchange(self, pending):
        """Complete a ``start_exchange``: ``(lookup, aux, wire_bytes)``.

        The default assumes ``start_exchange`` already produced the
        finished triple (plans whose assembly is itself collective-bound,
        like delta's ``lax.cond``, keep everything in the start half).
        """
        return pending

    def exchange(self, labels_local: jax.Array, aux, axis: str, *args):
        """One full exchange -- the non-overlapped schedule."""
        return self.finish_exchange(
            self.start_exchange(labels_local, aux, axis, *args))

    def prime(self, labels_local: jax.Array, axis: str, *args):
        """Bootstrap ``(lookup, aux, wire_bytes)`` before an iteration loop.

        The frontier engine diffs consecutive lookup arrays to expand the
        active set, so it needs a pre-loop lookup of the *initial* labels.
        This is ``init_aux`` plus one regular exchange; plans with a
        cheaper bootstrap can override it.
        """
        aux = self.init_aux(labels_local, axis, *args)
        return self.exchange(labels_local, aux, axis, *args)


class AllGatherPlan(ExchangePlan):
    """Full label vector every iteration -- the bit-compatible oracle."""

    name = "allgather"

    def __init__(self, sg):
        self.ndev = sg.ndev
        self.v_pad = sg.num_vertices
        self.dst_index = sg.dst

    def signature(self) -> tuple:
        return (self.name, self.ndev, self.v_pad)

    @classmethod
    def from_signature(cls, sig):
        plan = cls.__new__(cls)
        _, plan.ndev, plan.v_pad = sig
        plan.dst_index = None
        return plan

    def wire_bytes_per_iter(self) -> int:
        # every device receives the (v_pad - v_per_dev) labels it lacks
        return (self.ndev - 1) * self.v_pad * 4

    def start_exchange(self, labels_local, aux, axis, *args):
        lookup = jax.lax.all_gather(labels_local, axis, tiled=True)
        return lookup, aux, jnp.float32(self.wire_bytes_per_iter())


class HaloPlan(ExchangePlan):
    """Boundary labels only: each device receives exactly the remote
    vertices its edge shard references (O(cut) instead of O(V))."""

    name = "halo"

    def __init__(self, sg, pad: bool = False):
        self.ndev = sg.ndev
        self.v_per_dev = sg.v_per_dev
        real = sg.weight.reshape(-1) > 0                 # drop layout padding
        owner = np.repeat(np.arange(sg.ndev), sg.dst.shape[1])[real]
        remote = sg.dst.reshape(-1)[real]
        hidx = build_halo_index(owner, remote, sg.ndev, sg.v_per_dev,
                                pad_halo=pad)
        self.halo_size = hidx.halo_size
        self.true_halo = hidx.true_halo
        self._send_idx = hidx.send_idx
        self._send_counts = hidx.send_counts
        # regroup the remapped indices into the (ndev, E_shard) edge layout;
        # padding edges (weight 0) read slot 0 and contribute nothing
        dst_index = np.zeros(sg.dst.shape, np.int32)
        dst_index.reshape(-1)[real] = hidx.ext_idx.astype(np.int32)
        self.dst_index = dst_index
        self._send_idx_dev = None

    def signature(self) -> tuple:
        return (self.name, self.ndev, self.v_per_dev, self.halo_size)

    @classmethod
    def from_signature(cls, sig):
        plan = cls.__new__(cls)
        _, plan.ndev, plan.v_per_dev, plan.halo_size = sig
        plan.true_halo = None          # graph-dependent: wire bytes arrive
        plan.dst_index = None          # as a traced device arg instead
        return plan

    def device_args(self):
        # uploaded once per plan (plans are cached per layout); the true
        # (unpadded) wire volume rides along as a replicated scalar so the
        # compiled program stays correct for every graph in the bucket
        if self._send_idx_dev is None:
            self._send_idx_dev = (jnp.asarray(self._send_idx),
                                  jnp.float32(self.true_halo * 4))
        return self._send_idx_dev

    def arg_specs(self, axis):
        return (PartitionSpec(axis), PartitionSpec())

    def wire_bytes_per_iter(self) -> int:
        return self.true_halo * 4

    def padded_wire_bytes_per_iter(self) -> int:
        """What the static-shape all_to_all physically moves."""
        return self.ndev * (self.ndev - 1) * self.halo_size * 4

    def start_exchange(self, labels_local, aux, axis, send_idx_dev,
                       wire_bytes):
        # the all_to_all is issued here; the cheap local assembly that
        # builds the lookup waits in finish_exchange, so interior scoring
        # scheduled between the halves overlaps the wire transfer
        local, halo = halo_exchange_start(labels_local, send_idx_dev, axis)
        return local, halo, aux, wire_bytes

    def finish_exchange(self, pending):
        labels_local, halo, aux, wire_bytes = pending
        return halo_exchange_finish(labels_local, halo), aux, wire_bytes


class HaloDeltaPlan(HaloPlan):
    """Changed BOUNDARY values only: the halo topology with delta
    accounting -- the transport for shrinking-frontier Pregel workloads
    (WCC / BFS in ``repro.apps``) on a placed graph.

    The physical collective is the halo plan's static-shape all_to_all
    (bit-identical lookup), but the wire accounting models what a
    message-passing runtime with per-value dirty tracking sends: 8
    bytes (slot + value) per boundary value that CHANGED since the last
    exchange, counted once per (owner, needer) pair it is pushed to.
    Unlike ``delta``'s full-mirror broadcast (every changed value to
    every device, placement-blind), this volume is BOTH
    placement-sensitive (only cut-referenced vertices count -- a better
    partition moves strictly less) and frontier-decaying (a converged
    region stops paying); the aux carry is the previous send vector the
    deltas are diffed against, bootstrapped uncounted by ``init_aux``
    like the delta mirror.
    """

    name = "halo_delta"

    def __init__(self, sg, pad: bool = False):
        super().__init__(sg, pad=pad)
        self._dev_args = None

    def signature(self) -> tuple:
        return (self.name, self.ndev, self.v_per_dev, self.halo_size)

    def device_args(self):
        if self._dev_args is None:
            valid = (np.arange(self.halo_size)[None, None, :]
                     < self._send_counts[:, :, None])
            self._dev_args = (jnp.asarray(self._send_idx),
                              jnp.asarray(valid.astype(np.float32)))
        return self._dev_args

    def arg_specs(self, axis):
        return (PartitionSpec(axis), PartitionSpec(axis))

    def wire_bytes_per_iter(self) -> Optional[int]:
        return None        # measured: depends on per-iteration changes

    def init_aux(self, labels_local, axis, *args):
        return labels_local        # the previous send vector (the mirror)

    def start_exchange(self, labels_local, aux, axis, send_idx, send_valid):
        changed = (labels_local != aux).astype(jnp.float32)
        wire = jax.lax.psum(jnp.sum(changed[send_idx] * send_valid),
                            axis) * jnp.float32(8.0)
        local, halo = halo_exchange_start(labels_local, send_idx, axis)
        return local, halo, labels_local, wire

    def finish_exchange(self, pending):
        labels_local, halo, aux, wire = pending
        return halo_exchange_finish(labels_local, halo), aux, wire


class DeltaPlan(ExchangePlan):
    """Changed labels only: reproduce the Figure 7 traffic decay.

    Each device mirrors the full label vector (the aux carry) and, per
    iteration, broadcasts only the (index, label) pairs of its vertices
    that migrated since the last exchange.  On device this uses a
    static-shape capped compact buffer (``cap`` entries per device, as an
    all-gather) and falls back to a full label all-gather on iterations
    where any device exceeds the cap -- both branches produce an identical
    mirror, so the trajectory is bit-identical to ``allgather``.

    ``exchanged_bytes`` counts the message-runtime volume: 8 bytes per
    changed label (index + value) to each of the other ``ndev - 1``
    devices.  That is exactly the decaying "messages sent" curve of
    Figure 7, measured on device.
    """

    name = "delta"

    def __init__(self, sg, cap: Optional[int] = None):
        self.ndev = sg.ndev
        self.v_pad = sg.num_vertices
        self.v_per_dev = sg.v_per_dev
        self.dst_index = sg.dst
        if cap is None:
            cap = max(1, sg.v_per_dev // 4)
        elif cap < 1:
            raise ValueError(f"delta_cap must be >= 1, got {cap}")
        self.cap = min(int(cap), sg.v_per_dev)

    def signature(self) -> tuple:
        return (self.name, self.ndev, self.v_per_dev, self.v_pad, self.cap)

    @classmethod
    def from_signature(cls, sig):
        plan = cls.__new__(cls)
        _, plan.ndev, plan.v_per_dev, plan.v_pad, plan.cap = sig
        plan.dst_index = None
        return plan

    def wire_bytes_per_iter(self) -> Optional[int]:
        return None            # measured: depends on per-iteration migrations

    def init_aux(self, labels_local, axis, *args):
        return jax.lax.all_gather(labels_local, axis, tiled=True)

    def start_exchange(self, labels_local, aux, axis, *args):
        # everything stays in the start half: the mirror update is a
        # lax.cond whose BOTH branches are collectives, so there is no
        # communication-free finish to defer -- the engine still issues
        # this before interior scoring, which overlaps the gathers
        vl, v_pad, cap = self.v_per_dev, self.v_pad, self.cap
        off = jax.lax.axis_index(axis) * vl
        prev = jax.lax.dynamic_slice_in_dim(aux, off, vl, 0)
        changed = labels_local != prev
        n_local = jnp.sum(changed.astype(jnp.int32))
        wire = (jax.lax.psum(n_local, axis).astype(jnp.float32)
                * jnp.float32(8 * (self.ndev - 1)))

        def compact(_):
            # changed entries first (stable, so in ascending index order)
            order = jnp.argsort(jnp.where(changed, 0, 1), stable=True)
            idx_l = order[:cap]
            is_ch = changed[idx_l]
            # invalid slots point past the mirror and are dropped
            idx_g = jnp.where(is_ch, idx_l + off, v_pad)
            val = labels_local[idx_l]
            g_idx = jax.lax.all_gather(idx_g, axis, tiled=True)
            g_val = jax.lax.all_gather(val, axis, tiled=True)
            return aux.at[g_idx].set(g_val, mode="drop")

        def full(_):
            return jax.lax.all_gather(labels_local, axis, tiled=True)

        # the predicate is a psum/pmax-style replicated value, so every
        # device takes the same branch and the collectives stay aligned
        lookup = jax.lax.cond(jax.lax.pmax(n_local, axis) <= cap,
                              compact, full, None)
        return lookup, lookup, wire


# The one registry of plan names: EngineOptions.resolved_label_exchange
# validates against its keys, so adding a plan here is the whole job.
EXCHANGE_PLANS = {
    "allgather": AllGatherPlan,
    "halo": HaloPlan,
    "halo_delta": HaloDeltaPlan,
    "delta": DeltaPlan,
}

_PLAN_CACHE: dict = {}   # per ShardedGraph: (name[, delta_cap], pad) -> plan


def make_exchange_plan(name: str, sg, delta_cap: Optional[int] = None,
                       pad: bool = False) -> ExchangePlan:
    """Build (or fetch cached) the named plan for a ``ShardedGraph``.

    Cached per layout via the engine's weakref-guarded memoization: the
    halo construction is an O(ndev^2) pass over the edge set, and both
    the runner build and ``comm_stats`` ask for the same plan.
    ``delta_cap`` only shapes the delta plan, so it stays out of the
    other plans' keys (a cap sweep never rebuilds the halo pass).
    ``pad`` buckets the halo size for session compile reuse.
    """
    from .engine import _graph_cached        # lazy: engine imports us too

    if name not in EXCHANGE_PLANS:
        raise ValueError(f"unknown label exchange {name!r}; "
                         f"available: {', '.join(sorted(EXCHANGE_PLANS))}")
    if name == "delta":
        key, build = ((name, delta_cap, pad),
                      lambda: DeltaPlan(sg, cap=delta_cap))
    elif name in ("halo", "halo_delta"):
        key, build = ((name, None, pad),
                      lambda: EXCHANGE_PLANS[name](sg, pad=pad))
    else:
        key, build = (name, None, pad), lambda: EXCHANGE_PLANS[name](sg)
    return _graph_cached(_PLAN_CACHE, sg, key, build)


def plan_from_signature(sig: tuple) -> ExchangePlan:
    """Array-free plan view for tracing (see ``ExchangePlan.signature``)."""
    return EXCHANGE_PLANS[sig[0]].from_signature(sig)
