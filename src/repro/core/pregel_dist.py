"""Distributed PageRank over a label placement -- now a thin wrapper.

The integration the paper performs on Giraph (Section 5.6), on our
mesh: vertices are physically placed by partition label and each
superstep exchanges only the *boundary* values other devices actually
reference, so a better partitioning (Spinner vs hash) directly shrinks
the bytes on the wire -- the mechanism behind the paper's 2x
application speedup.

This module's hand-rolled halo plan and per-superstep dispatch loop
were replaced by :mod:`repro.apps`: placement goes through
``apps.layout`` (label-sorted equal chop onto ``shard_graph``),
transport through the shared :class:`repro.core.comm.ExchangePlan`
halo machinery, and the whole run is ONE cached
``shard_map(lax.while_loop)`` program with on-device wire accounting.
``pagerank_distributed`` remains as the back-compat entry returning
``(values, stats)`` with the measured (not estimated) wire bytes.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np
from jax.sharding import Mesh


def pagerank_distributed(graph, labels: np.ndarray, mesh: Mesh,
                         iters: int = 20, damping: float = 0.85,
                         axis: str = "data",
                         plan: Optional[str] = None
                         ) -> Tuple[np.ndarray, dict]:
    """PageRank on ``graph`` placed by ``labels`` over ``mesh``.

    Thin wrapper over :func:`repro.apps.run_app`; ``stats`` keeps the
    historical ``halo_true_bytes_per_step`` key, now the on-device
    accumulated per-superstep wire bytes of the shared halo plan
    (0 on a single-device mesh: nothing crosses the wire).
    """
    from repro.apps import build_app_layout, run_app

    res = run_app(graph, labels, "pagerank", mesh=mesh, axis=axis,
                  plan=plan or "halo", iters=iters, damping=damping)
    layout = build_app_layout(graph, np.asarray(labels), res.ndev)
    stats = {
        "halo_true_bytes_per_step": res.wire_bytes_per_step,
        "wire_bytes": res.wire_bytes,
        "supersteps": res.supersteps,
        "straggler_skew": res.straggler_skew,
        "v_per_dev": layout.v_per_dev,
        "iters": iters,
    }
    return res.values, stats
