"""Distributed Pregel with halo exchange (shard_map).

The integration the paper performs on Giraph (Section 5.6), on our mesh:
vertices are physically placed by partition label (one partition per
device), and each superstep exchanges only the *boundary* values other
devices actually reference -- an all_to_all halo exchange with
precomputed index lists.  A better partitioning (Spinner vs hash) directly
shrinks the halo, i.e. the bytes on the wire, which is exactly the
mechanism behind the paper's 2x application speedup.

The halo-plan construction itself (send lists + remapped edge indices)
lives in ``repro.core.comm`` (``build_halo_index`` / ``halo_exchange``),
shared with the sharded LPA engine's ``label_exchange="halo"`` plan; this
module only adds the label-driven placement and the PageRank superstep.

PageRank is implemented end-to-end; halo construction is generic.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from . import comm
from .graph import Graph


@dataclasses.dataclass(frozen=True)
class HaloPlan:
    ndev: int
    v_per_dev: int
    perm: np.ndarray           # (V,) original id -> placed id
    send_idx: np.ndarray       # (ndev, ndev, H) local indices to send
    halo_size: int             # H (padded per pair)
    true_halo: int             # sum of real (unpadded) halo entries
    # per-device edge arrays (edges live at their dst owner)
    src_ext: np.ndarray        # (ndev, E) index into [local values | halo]
    dst_local: np.ndarray      # (ndev, E) local dst index
    edge_valid: np.ndarray     # (ndev, E) bool
    out_deg: np.ndarray        # (ndev, v_per_dev) f32 (global out-degree)


def build_halo_plan(graph: Graph, labels: np.ndarray, ndev: int) -> HaloPlan:
    V = graph.num_vertices
    labels = np.asarray(labels)
    assert labels.max() < ndev
    # place partition p's vertices contiguously
    order = np.argsort(labels, kind="stable")
    counts = np.bincount(labels, minlength=ndev)
    v_per_dev = int(counts.max())
    perm = np.empty(V, np.int64)
    off = 0
    for p in range(ndev):
        mine = order[off: off + counts[p]]
        perm[mine] = p * v_per_dev + np.arange(counts[p])
        off += counts[p]
    src_p = perm[graph.src]
    dst_p = perm[graph.dst]
    owner_dst = dst_p // v_per_dev

    # edges live at their dst owner and read their src's value: the shared
    # halo machinery computes the send lists and the per-edge remap into
    # [local values | halo]
    hidx = comm.build_halo_index(owner_dst, src_p, ndev, v_per_dev)
    H = hidx.halo_size

    # group the remapped edges by owning device, padded square
    e_per = np.bincount(owner_dst, minlength=ndev)
    E = int(e_per.max()) if e_per.size else 1
    src_ext = np.zeros((ndev, E), np.int64)
    dst_local = np.zeros((ndev, E), np.int64)
    valid = np.zeros((ndev, E), bool)
    for q in range(ndev):
        qe = np.where(owner_dst == q)[0]
        src_ext[q, : qe.size] = hidx.ext_idx[qe]
        dst_local[q, : qe.size] = dst_p[qe] - q * v_per_dev
        valid[q, : qe.size] = True

    out_deg = np.zeros(ndev * v_per_dev, np.float32)
    np.add.at(out_deg, src_p, 1.0)
    return HaloPlan(ndev=ndev, v_per_dev=v_per_dev, perm=perm,
                    send_idx=hidx.send_idx, halo_size=H,
                    true_halo=hidx.true_halo, src_ext=src_ext,
                    dst_local=dst_local, edge_valid=valid,
                    out_deg=out_deg.reshape(ndev, v_per_dev))


def pagerank_distributed(graph: Graph, labels: np.ndarray, mesh: Mesh,
                         iters: int = 20, damping: float = 0.85,
                         axis: str = "data") -> Tuple[np.ndarray, dict]:
    ndev = mesh.shape[axis]
    plan = build_halo_plan(graph, labels, ndev)
    V = graph.num_vertices
    vl, H = plan.v_per_dev, plan.halo_size

    send_idx = jnp.asarray(plan.send_idx)       # (ndev, ndev, H)
    src_ext = jnp.asarray(plan.src_ext)
    dst_local = jnp.asarray(plan.dst_local)
    w_valid = jnp.asarray(plan.edge_valid.astype(np.float32))
    out_deg = jnp.asarray(plan.out_deg)

    def superstep(pr_l, send_l, src_l, dst_l, wv_l, deg_l):
        share = (pr_l[0] / jnp.maximum(deg_l[0], 1.0)).astype(jnp.float32)
        # boundary-only exchange, shared with the LPA engine's halo plan
        ext = comm.halo_exchange(share, send_l[0], axis)
        contrib = jnp.zeros((vl,), jnp.float32).at[dst_l[0]].add(
            ext[src_l[0]] * wv_l[0])
        pr_new = (1 - damping) / V + damping * contrib
        return pr_new[None]

    step = jax.jit(shard_map(
        superstep, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(axis), P(axis)),
        out_specs=P(axis), check_rep=False))

    pr = jnp.full((ndev, vl), 1.0 / V, jnp.float32)
    for _ in range(iters):
        pr = step(pr, send_idx, src_ext, dst_local, w_valid, out_deg)
    pr_flat = np.asarray(pr).reshape(-1)
    values = np.empty(V, np.float32)
    values = pr_flat[plan.perm]
    stats = {
        "halo_padded_bytes_per_step": int(ndev * (ndev - 1) * H * 4),
        "halo_true_bytes_per_step": int(plan.true_halo * 4),
        "v_per_dev": vl,
        "iters": iters,
    }
    return values, stats
