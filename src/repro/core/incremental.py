"""Incremental (Section 3.4) and elastic (Section 3.5) repartitioning.

Both reduce to: perturb the previous stable labeling, then restart the core
LPA -- "supporting incremental and elastic repartitioning is as simple as
halting the computation and restarting it" (Section 4.2).

Both entry points ride on ``spinner.partition`` and therefore on the
device-resident engine (``repro.core.engine``): with
``record_history=False`` (or ``engine="fused"``) an adapt/resize restart
executes as a single fused ``lax.while_loop`` device call, which is what
near-real-time reaction to graph changes (xDGP/SDP-style) needs.  The
default keeps per-iteration history via the chunked runner; pass
``engine="host"`` (or "chunked"/"fused"/"sharded") through ``**kw`` to
pick a specific runner -- ``engine="sharded", mesh=...`` restarts the
whole adapted/resized run as one ``while_loop`` dispatch across a device
mesh, so incremental repartitioning scales with the cluster exactly like
a from-scratch run.

For a STREAM of adapts/resizes, hold a ``repro.core.session.
PartitionSession`` instead: its ``adapt()``/``resize()`` methods are
bit-identical to these wrappers (both run the same shape-bucketed
compiled programs) but amortize the O(E) upload and the runner compile
across calls -- a grown graph that stays inside its shape bucket
recompiles nothing.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import Graph
from .spinner import PartitionResult, SpinnerConfig, partition


def extend_labels(prev_labels: np.ndarray, new_num_vertices: int) -> np.ndarray:
    """Carry labels to a grown vertex set; new vertices marked -1.

    ``partition`` assigns -1 entries to the least-loaded partition, matching
    Section 3.4 ("we assign them to the least loaded partition").

    Contract: the vertex set may only GROW.  Section 3.4's incremental
    restart carries the previous label of every surviving vertex, and
    vertex ids are positional -- a smaller ``new_num_vertices`` cannot
    say WHICH vertices were removed, so shrinking is rejected rather
    than silently truncating the tail.  To remove vertices, rebuild the
    graph with ``graph.remove_vertices`` (which returns the surviving-id
    remap) and re-index the previous labels through that remap before
    adapting.
    """
    prev = np.asarray(prev_labels, dtype=np.int32)
    if new_num_vertices < prev.shape[0]:
        raise ValueError(
            f"extend_labels: new vertex count {new_num_vertices} is "
            f"smaller than the previous labeling ({prev.shape[0]} "
            "vertices); the incremental restart only supports a grown "
            "vertex set -- remove vertices via graph.remove_vertices and "
            "remap the previous labels through its survivor index first")
    out = np.full(new_num_vertices, -1, dtype=np.int32)
    out[: prev.shape[0]] = prev
    return out


def adapt(graph: Graph, prev_labels: np.ndarray, cfg: SpinnerConfig,
          **kw) -> PartitionResult:
    """Incremental LPA: restart from the previous stable state (Section 3.4).

    Extra keyword arguments (``engine=``, ``chunk_size=``,
    ``record_history=``, ...) are forwarded to ``partition``; with the
    default ``engine="auto"`` a no-history adapt is one fused device call.

    ``graph`` must contain at least as many vertices as ``prev_labels``
    (see ``extend_labels``); a shrunk vertex set raises ``ValueError``.
    """
    init = extend_labels(prev_labels, graph.num_vertices)
    return partition(graph, cfg, init=init, **kw)


def elastic_relabel(prev_labels: np.ndarray, k_old: int, k_new: int,
                    seed: int = 0) -> np.ndarray:
    """Probabilistic relabeling for a changed partition count (Section 3.5).

    Growth (n = k_new - k_old > 0): every vertex migrates with probability
    p = n / (k_old + n) (Eq. 10) to a uniformly random *new* partition, so
    expected loads stay uniform across all k_new partitions.
    Shrink: vertices on removed partitions move to a uniformly random
    surviving partition; everyone else stays.
    """
    prev = np.asarray(prev_labels, dtype=np.int32)
    rng = np.random.default_rng(seed)
    if k_new == k_old:
        return prev.copy()
    if k_new > k_old:
        n = k_new - k_old
        p = n / (k_old + n)
        move = rng.random(prev.shape[0]) < p
        dest = rng.integers(k_old, k_new, size=prev.shape[0]).astype(np.int32)
        return np.where(move, dest, prev)
    # shrink: partitions [k_new, k_old) are removed
    evicted = prev >= k_new
    dest = rng.integers(0, k_new, size=prev.shape[0]).astype(np.int32)
    return np.where(evicted, dest, prev)


def resize(graph: Graph, prev_labels: np.ndarray, cfg_new: SpinnerConfig,
           k_old: int, seed: Optional[int] = None, **kw) -> Tuple[
               PartitionResult, np.ndarray]:
    """Elastic LPA: relabel per Eq. (10), then restart (Section 3.5).

    Returns (result, relabeled_init) so callers can measure the shuffle the
    relabeling itself caused (Section 5.5 partitioning-difference analysis).
    Like ``adapt``, forwards ``engine=`` and friends to ``partition``.
    """
    init = elastic_relabel(prev_labels, k_old, cfg_new.k,
                           seed=cfg_new.seed if seed is None else seed)
    return partition(graph, cfg_new, init=init, **kw), init
