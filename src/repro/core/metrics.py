"""Partitioning quality metrics (Section 5.1, Eq. 13).

phi  = ratio of local edges (fraction of edges whose endpoints share a label)
rho  = maximum normalized load (max partition load / ideal load)
score(G) = Eq. (9), the aggregate objective the vertices hill-climb.

Conventions: following Eq. (6), the load B(l) sums *weighted degrees* of the
vertices in l, so sum_l B(l) == total_weight == 2 * weighted undirected edges.
The ideal load is total_weight / k.  phi is reported both unweighted (edge
count, as in the paper's tables) and weighted (message volume, what the
objective actually optimizes).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from .graph import Graph


def loads(graph: Graph, labels: np.ndarray, k: int) -> np.ndarray:
    """B(l) per Eq. (6): weighted degree mass per partition."""
    labels = np.asarray(labels)
    out = np.zeros(k, dtype=np.float64)
    np.add.at(out, labels, graph.deg_w.astype(np.float64))
    return out


def phi(graph: Graph, labels: np.ndarray) -> float:
    """Unweighted ratio of local edges (paper's phi)."""
    labels = np.asarray(labels)
    local = labels[graph.src] == labels[graph.dst]
    return float(local.mean()) if local.size else 1.0


def phi_weighted(graph: Graph, labels: np.ndarray) -> float:
    """Weighted locality: fraction of message volume that stays local."""
    labels = np.asarray(labels)
    local = (labels[graph.src] == labels[graph.dst]).astype(np.float64)
    tw = graph.weight.astype(np.float64)
    return float((local * tw).sum() / tw.sum()) if tw.size else 1.0


def rho(graph: Graph, labels: np.ndarray, k: int) -> float:
    """Maximum normalized load (Eq. 13)."""
    b = loads(graph, labels, k)
    ideal = graph.total_weight / k
    return float(b.max() / ideal) if ideal > 0 else 1.0


def score_global(graph: Graph, labels: np.ndarray, k: int, c: float) -> float:
    """Eq. (9): sum over vertices of score''(v, alpha(v))."""
    labels = np.asarray(labels)
    local_w = np.zeros(graph.num_vertices, dtype=np.float64)
    same = labels[graph.src] == labels[graph.dst]
    np.add.at(local_w, graph.src[same], graph.weight[same].astype(np.float64))
    degw = np.maximum(graph.deg_w.astype(np.float64), 1e-12)
    norm = local_w / degw
    C = c * graph.total_weight / k
    pen = loads(graph, labels, k) / C
    return float((norm - pen[labels]).sum())


def partitioning_difference(labels_a: np.ndarray, labels_b: np.ndarray) -> float:
    """Fraction of vertices whose partition differs (Section 5.4)."""
    a = np.asarray(labels_a)
    b = np.asarray(labels_b)
    assert a.shape == b.shape
    return float((a != b).mean()) if a.size else 0.0


def summarize(graph: Graph, labels: np.ndarray, k: int, c: float = 1.05
              ) -> dict:
    return {
        "phi": phi(graph, labels),
        "phi_weighted": phi_weighted(graph, labels),
        "rho": rho(graph, labels, k),
        "score": score_global(graph, labels, k, c),
        "k": k,
    }
