"""Partitioning quality metrics (Section 5.1, Eq. 13).

phi  = ratio of local edges (fraction of edges whose endpoints share a label)
rho  = maximum normalized load (max partition load / ideal load)
score(G) = Eq. (9), the aggregate objective the vertices hill-climb.

Conventions: following Eq. (6), the load B(l) sums *weighted degrees* of the
vertices in l, so sum_l B(l) == total_weight == 2 * weighted undirected edges.
The ideal load is total_weight / k.  phi is reported both unweighted (edge
count, as in the paper's tables) and weighted (message volume, what the
objective actually optimizes).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from .graph import Graph


def loads(graph: Graph, labels: np.ndarray, k: int) -> np.ndarray:
    """B(l) per Eq. (6): weighted degree mass per partition."""
    labels = np.asarray(labels)
    out = np.zeros(k, dtype=np.float64)
    np.add.at(out, labels, graph.deg_w.astype(np.float64))
    return out


def phi(graph: Graph, labels: np.ndarray) -> float:
    """Unweighted ratio of local edges (paper's phi)."""
    labels = np.asarray(labels)
    local = labels[graph.src] == labels[graph.dst]
    return float(local.mean()) if local.size else 1.0


def phi_weighted(graph: Graph, labels: np.ndarray) -> float:
    """Weighted locality: fraction of message volume that stays local."""
    labels = np.asarray(labels)
    local = (labels[graph.src] == labels[graph.dst]).astype(np.float64)
    tw = graph.weight.astype(np.float64)
    return float((local * tw).sum() / tw.sum()) if tw.size else 1.0


def rho(graph: Graph, labels: np.ndarray, k: int) -> float:
    """Maximum normalized load (Eq. 13)."""
    b = loads(graph, labels, k)
    ideal = graph.total_weight / k
    return float(b.max() / ideal) if ideal > 0 else 1.0


def score_global(graph: Graph, labels: np.ndarray, k: int, c: float) -> float:
    """Eq. (9): sum over vertices of score''(v, alpha(v))."""
    labels = np.asarray(labels)
    local_w = np.zeros(graph.num_vertices, dtype=np.float64)
    same = labels[graph.src] == labels[graph.dst]
    np.add.at(local_w, graph.src[same], graph.weight[same].astype(np.float64))
    degw = np.maximum(graph.deg_w.astype(np.float64), 1e-12)
    norm = local_w / degw
    C = c * graph.total_weight / k
    pen = loads(graph, labels, k) / C
    return float((norm - pen[labels]).sum())


def partitioning_difference(labels_a: np.ndarray, labels_b: np.ndarray) -> float:
    """Fraction of vertices whose partition differs (Section 5.4)."""
    a = np.asarray(labels_a)
    b = np.asarray(labels_b)
    assert a.shape == b.shape
    return float((a != b).mean()) if a.size else 0.0


def comm_volume(graph: Graph, labels: np.ndarray, k: int) -> np.ndarray:
    """Per-partition remote-neighbor count -- the paper's communication
    cost proxy (Section 2: messages cross the network iff the endpoints
    live in different partitions).

    Entry ``l`` counts the directed adjacency entries whose source is in
    partition ``l`` and whose destination is not, i.e. the neighbor
    labels partition ``l`` must fetch from other partitions every
    superstep under a message-passing runtime.  The total over all
    partitions is the (unweighted) directed cut size; phi relates as
    ``comm_volume(...).sum() == (1 - phi) * num_directed_entries``.

    ``summarize`` computes this unconditionally, so every benchmark row
    built on it carries ``comm_volume`` -- the static predictor the
    application bench (``benchmarks/bench_apps.py``) correlates with
    the wire bytes the exchange plans actually move per superstep.
    """
    labels = np.asarray(labels)
    cut = labels[graph.src] != labels[graph.dst]
    return np.bincount(labels[graph.src[cut]], minlength=k).astype(np.int64)


def frontier_fraction(sg) -> float:
    """Fraction of a ``ShardedGraph``'s real edges in the frontier
    segment -- the share of each step's scoring that must wait for the
    label exchange under the overlap schedule (``EngineOptions.overlap``;
    the interior remainder computes while the collective is in flight).
    """
    interior = int(np.sum(sg.interior_counts))
    frontier = int(np.sum(sg.frontier_counts))
    total = interior + frontier
    return float(frontier / total) if total else 0.0


def summarize(graph: Graph, labels: np.ndarray, k: int, c: float = 1.05,
              sg=None) -> dict:
    """Quality summary; pass a ``ShardedGraph`` as ``sg`` to include the
    layout's frontier fraction alongside the quality metrics."""
    cv = comm_volume(graph, labels, k)
    out = {
        "phi": phi(graph, labels),
        "phi_weighted": phi_weighted(graph, labels),
        "rho": rho(graph, labels, k),
        "score": score_global(graph, labels, k, c),
        "comm_volume": int(cv.sum()),
        "comm_volume_max": int(cv.max()) if cv.size else 0,
        "k": k,
    }
    if sg is not None:
        out["frontier_fraction"] = frontier_fraction(sg)
    return out
