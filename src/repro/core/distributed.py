"""Sharded Spinner: the edge-shard layout layer + legacy entry points.

The iteration math no longer lives here.  Pre-PR-2 this module was a fork
of the engine: a hand-rolled per-iteration ``shard_map`` step with its own
copy of the two-phase update and a host halting loop that paid a
``float(score_g)`` sync every superstep -- exactly the distributed
overhead xDGP (1309.1049) and SDP (2110.15669) show must be driven to the
floor for adaptive repartitioning to pay off.  The sharded engine in
``repro.core.engine`` now runs the whole LPA as ONE
``shard_map(lax.while_loop)`` dispatch built on the same
``make_vertex_update`` math as every other engine.  What remains here:

  * ``ShardedGraph`` / ``shard_graph`` -- the padding/layout layer:
    vertices range-partitioned across devices (ceil(V/ndev) contiguous
    ids, tail padded with degree-0 vertices), edges living on their source
    vertex's owner (zero-weight rows pad the shards square);
  * ``shard_layout`` / ``device_upload`` -- the cached layout per
    (graph, ndev) and one cached device upload per (layout, array), so
    mesh sweeps over one graph share a single copy of each;
  * ``make_sharded_step`` -- ONE iteration as a jitted ``shard_map``
    dispatch (the engine's step_fn under a per-call ``shard_map``), kept
    for the dispatch-overhead benchmark;
  * ``run_sharded_hostloop`` -- the pre-PR-2 driving mode: one dispatch
    per iteration with a host sync on ``state.halted``.  The halting
    criterion is the on-device ``engine._halting_update`` carried in the
    state, so iteration counts match ``partition(engine="sharded")``
    exactly -- the ONLY difference this driver measures is dispatch/sync
    overhead (see ``benchmarks/bench_engine.py``);
  * ``partition_distributed`` -- back-compat wrapper over
    ``partition(graph, cfg, engine="sharded", mesh=...)`` returning
    (labels, comm stats), the quantities Figure 5 scales.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from . import engine
from .graph import Graph
from .spinner import SpinnerConfig

_SHARD_CACHE: dict = {}   # per graph: (ndev, pad) -> ShardedGraph
_UPLOAD_CACHE: dict = {}  # per ShardedGraph: () -> device edge arrays


@dataclasses.dataclass(frozen=True)
class ShardedGraph:
    """Host-side edge shards, one row per device, interior-first.

    Each device's edge row holds two contiguous segments: columns
    ``[0, e_interior)`` are INTERIOR edges -- their dst vertex is owned
    by the same device, so its label is readable from the local label
    shard without any communication -- and columns ``[e_interior, E)``
    are FRONTIER edges, whose dst label arrives via the exchange plan.
    The split is what lets the sharded step overlap the label collective
    with interior scoring (``EngineOptions.overlap``): only the frontier
    segment depends on the wire.  Within each segment the CSR edge order
    is preserved; ``edge_perm`` records where each slot's edge sat in
    the original ``Graph`` arrays (-1 for padding), so tests can
    reconstruct the permutation exactly.
    """
    num_vertices: int          # padded to ndev multiple
    num_real_vertices: int
    ndev: int
    v_per_dev: int
    src_local: np.ndarray      # (ndev, E_shard) int32, src - owner_offset
    dst: np.ndarray            # (ndev, E_shard) int32 global ids
    weight: np.ndarray         # (ndev, E_shard) f32, 0 = padding
    deg_w: np.ndarray          # (ndev, v_per_dev) f32
    e_interior: int = 0        # static split column (padded segment width)
    interior_counts: Optional[np.ndarray] = None  # (ndev,) real interior
    frontier_counts: Optional[np.ndarray] = None  # (ndev,) real frontier
    edge_perm: Optional[np.ndarray] = None  # (ndev, E_shard) orig idx | -1
    local_only: Optional[int] = None  # set: arrays hold ONE host's row


@dataclasses.dataclass(frozen=True)
class EdgeShardView:
    """One host's edge file as ``shard_graph(local_only=...)`` input.

    The multi-process bootstrap (``repro.cluster.bootstrap``) splits a
    graph's directed-edge list by owning host and writes one file per
    host; a worker process loads ONLY its file, so it never materializes
    the full O(E) edge set.  ``deg_w`` is the full (V,) weighted-degree
    vector -- O(V) vertex state, shipped in the shard manifest alongside
    the globally agreed segment widths so all hosts build
    layout-compatible rows.
    """
    num_vertices: int
    src: np.ndarray
    dst: np.ndarray
    weight: np.ndarray
    deg_w: np.ndarray


def shard_graph(graph, ndev: int, pad: bool = False, *,
                local_only: Optional[int] = None,
                seg_widths: Optional[Tuple[int, int]] = None
                ) -> ShardedGraph:
    """Range-partition vertices and edges into per-device shards.

    Pure layout: contiguous blocks of ceil(V/ndev) vertex ids per
    device, every edge stored with its source's owner and reordered
    ``[interior | frontier]`` (dst owned locally vs. remotely; see
    ``ShardedGraph``).  CSR order is preserved inside each segment, and
    on 1 device every edge is interior, so the shard IS the graph's
    edge list; on any layout the scatter-add totals are bit-identical
    to the unsharded ones because the integer edge weights make f32
    sums exact under reordering.  ``pad`` buckets each segment's width
    so a session rebinding a slightly grown graph keeps the compile
    shape: the interior (bulk) segment gets the usual quarter-step
    ``shape_bucket`` (<= 25% overhead), while the frontier segment is
    rounded to a full power of two -- it is the minority of the shard,
    and its width tracks the boundary SET, which drifts more than the
    edge count under growth, so the coarser steps (<= 2x padding on
    <= ~25% of the edges) halve the bucket boundaries a drifting
    boundary set can cross.  Interior pad slots point at the device's
    own vertex 0 (global id ``p * v_per_dev``) so every dst view --
    global ids, the halo remap, the split local view -- stays in
    bounds; weight 0 makes all pads exact no-ops.

    ``local_only=p`` is the per-host loading path: ``graph`` holds ONLY
    host ``p``'s edges (a :class:`Graph` or an :class:`EdgeShardView`
    from one edge file) and the result carries a single row -- row 0 is
    device ``p``'s shard, byte-identical to row ``p`` of the full-graph
    layout when ``seg_widths`` passes the globally agreed raw
    ``(max interior, max frontier)`` counts (from the shard manifest;
    the bucketing rules above are applied to them identically).  Without
    ``seg_widths`` the widths come from the local counts alone --
    standalone mode, fine when rows are never stacked across hosts.
    """
    from .graph import shape_bucket
    v_per_dev = -(-graph.num_vertices // ndev)
    v_pad = v_per_dev * ndev
    # weight-0 edges (pad_graph's bucket-filler self-loops) are dropped
    # from the layout entirely: they are exact no-ops for every consumer,
    # and excluding them keeps each (device, segment) run's unused slots
    # at the TAIL -- a contiguous per-segment append region the on-device
    # delta merge can scatter new edges into (see repro.core.delta)
    real = graph.weight > 0
    owner_all = graph.src // v_per_dev
    frontier_all = (graph.dst // v_per_dev) != owner_all
    oidx_all = np.arange(graph.src.shape[0], dtype=np.int32)
    owner, frontier = owner_all[real], frontier_all[real]
    if local_only is not None:
        if not 0 <= local_only < ndev:
            raise ValueError(f"local_only={local_only} outside [0, {ndev})")
        if owner.size and not (owner == local_only).all():
            raise ValueError(
                f"local_only={local_only}: edge list contains edges owned "
                f"by hosts {sorted(set(np.unique(owner)) - {local_only})}")
    n_int = np.bincount(owner[~frontier], minlength=ndev).astype(np.int64)
    n_fro = np.bincount(owner[frontier], minlength=ndev).astype(np.int64)
    int_counts, fro_counts = n_int, n_fro
    if local_only is None:
        e_int = int(n_int.max()) if n_int.size else 0
        e_fro = int(n_fro.max()) if n_fro.size else 0
    elif seg_widths is not None:
        e_int, e_fro = int(seg_widths[0]), int(seg_widths[1])
    else:
        e_int, e_fro = int(n_int[local_only]), int(n_fro[local_only])
    if e_int + e_fro == 0:
        e_int = 1                       # keep one (zeroed) slot per shard
    if pad:
        e_int = shape_bucket(e_int, floor=128)
        if e_fro:                       # 1-device shards stay frontier-free
            e_fro = max(128, 1 << (e_fro - 1).bit_length())
    e_shard = e_int + e_fro
    devs = range(ndev) if local_only is None else (local_only,)
    rows = len(devs) if local_only is None else 1
    src_l = np.zeros((rows, e_shard), np.int32)
    w = np.zeros((rows, e_shard), np.float32)
    perm = np.full((rows, e_shard), -1, np.int32)
    # pad slots read the owner's vertex 0 under every dst layout
    dst = np.tile((np.asarray(list(devs), np.int32) * v_per_dev)[:, None],
                  (1, e_shard))
    # stable sort by (owner, frontier flag): per device, the interior run
    # comes first, each run in CSR order
    order = np.argsort(owner.astype(np.int64) * 2 + frontier, kind="stable")
    s = graph.src[real][order]
    d = graph.dst[real][order]
    ww = graph.weight[real][order]
    oidx = oidx_all[real][order]
    starts = np.zeros(2 * ndev + 1, np.int64)
    np.cumsum(np.stack([n_int, n_fro], axis=1).reshape(-1), out=starts[1:])
    for row, p in enumerate(devs):
        for lo, hi, col in ((starts[2 * p], starts[2 * p + 1], 0),
                            (starts[2 * p + 1], starts[2 * p + 2], e_int)):
            n = hi - lo
            src_l[row, col: col + n] = s[lo:hi] - p * v_per_dev
            dst[row, col: col + n] = d[lo:hi]
            w[row, col: col + n] = ww[lo:hi]
            perm[row, col: col + n] = oidx[lo:hi]
    if local_only is None:
        deg = np.zeros(v_pad, np.float32)
        deg[: graph.num_vertices] = graph.deg_w
        deg = deg.reshape(ndev, v_per_dev)
    else:
        # deg_w must be the full (V,) vector; slice this host's range
        p = local_only
        deg = np.zeros((1, v_per_dev), np.float32)
        lo, hi = p * v_per_dev, min((p + 1) * v_per_dev, graph.num_vertices)
        deg[0, : hi - lo] = np.asarray(graph.deg_w)[lo:hi]
        int_counts = n_int[[p]]
        fro_counts = n_fro[[p]]
    return ShardedGraph(num_vertices=v_pad,
                        num_real_vertices=graph.num_vertices, ndev=ndev,
                        v_per_dev=v_per_dev, src_local=src_l, dst=dst,
                        weight=w, deg_w=deg,
                        e_interior=e_int, interior_counts=int_counts,
                        frontier_counts=fro_counts, edge_perm=perm,
                        local_only=local_only)


def shard_layout(graph: Graph, ndev: int, pad: bool = False) -> ShardedGraph:
    """The cached ``ShardedGraph`` layout for a (graph, ndev, pad) tuple."""
    return engine._graph_cached(_SHARD_CACHE, graph, (ndev, pad),
                                lambda: shard_graph(graph, ndev, pad=pad))


def device_upload(sg: ShardedGraph, field: str) -> jax.Array:
    """One uploaded shard array (``src_local``/``dst``/``weight``/``deg_w``),
    cached per (layout, field).

    Keyed on the ShardedGraph identity (itself cached per (graph, ndev))
    and lazy per array, so runner variants -- different cfg / exchange
    plan / score backend sweeping one graph on one mesh size -- share a
    single O(E) device copy of each array they actually use (the Pallas
    backend, for instance, only ever touches ``deg_w`` here).
    """
    return engine._graph_cached(_UPLOAD_CACHE, sg, (field,),
                                lambda: jnp.asarray(getattr(sg, field)))


def comm_stats(sg: ShardedGraph, cfg: SpinnerConfig,
               options: Optional[engine.EngineOptions] = None,
               graph: Optional[Graph] = None) -> dict:
    """Per-iteration communication volume of the sharded engine.

    The label exchange (plan selected by ``options.label_exchange``, see
    ``repro.core.comm``) plus the psum'd (k,) aggregators (M(l), load
    delta, score/migration scalars) -- the quantities Figure 5 scales
    with workers and Figure 7 shows decaying.  ``message_bytes_per_iter``
    is the plan's static message volume; None for the delta plan, whose
    volume is measured on device (``PartitionResult.exchanged_bytes``).

    Passing ``graph`` (the padded view the runner binds) additionally
    resolves the tile autotuner, so the reported ``score_backend`` /
    ``fused_update`` / ``tile_config`` match the compiled program.
    """
    from . import comm, metrics
    opts = options if options is not None else engine.EngineOptions()
    if graph is not None:
        opts = engine._autotuned(graph, cfg, opts, ndev=sg.ndev)
    name = opts.resolved_label_exchange(sg.ndev)
    # same pad flag as the runner's plan (engine._sharded_parts), so this
    # hits the cached plan and halo's padded volume matches what the
    # compiled all_to_all physically moves
    pad = opts.pad == "bucket"
    plan = comm.make_exchange_plan(name, sg, delta_cap=opts.delta_cap,
                                   pad=pad)
    wire = plan.wire_bytes_per_iter()
    stats = {
        "label_exchange": name,
        "overlap": opts.resolved_overlap(sg.ndev),
        "frontier_fraction": metrics.frontier_fraction(sg),
        "message_bytes_per_iter": None if wire is None else int(wire),
        "allgather_bytes_per_iter": int(comm.make_exchange_plan(
            "allgather", sg, pad=pad).wire_bytes_per_iter()),
        "aggregator_bytes_per_iter": int(3 * cfg.k * 4 * sg.ndev),
        "edge_shard_sizes": [int((sg.weight[p] > 0).sum())
                             for p in range(sg.ndev)],
    }
    backend = opts.backend()
    stats["score_backend"] = backend.name
    stats["fused_update"] = opts.resolved_fused_update()
    if backend.name == "pallas":
        from repro.kernels.ops import round_up
        stats["tile_config"] = {"tile_v": backend.tile_v,
                                "tile_e": backend.tile_e,
                                "k_pad": round_up(max(cfg.k, 1), 128)}
    if name == "halo":
        # message_bytes_per_iter above is the TRUE halo volume; this is
        # what the static-shape all_to_all physically moves
        stats["halo_padded_bytes_per_iter"] = \
            plan.padded_wire_bytes_per_iter()
    if name == "delta":
        stats["delta_cap"] = plan.cap
    return stats


def make_sharded_step(graph: Graph, cfg: SpinnerConfig, mesh: Mesh,
                      axis: str = "data",
                      options: Optional[engine.EngineOptions] = None):
    """One LPA iteration as a single jitted ``shard_map`` dispatch.

    ``step(state) -> state`` over the engine's ``SpinnerState`` (padded
    labels).  This is the engine's sharded step_fn without the surrounding
    ``while_loop`` -- the building block of ``run_sharded_hostloop``.
    The step is assembled by the one shared ``engine._sharded_parts``
    code path (``single_step=True`` pins the aux-free allgather oracle
    and the non-overlapped schedule there), and the compiled program is
    cached globally like the engine's runners, so the hostloop driver's
    repeat calls pay dispatch, not retrace/recompile.
    """
    opts = options if options is not None else engine.EngineOptions()
    _, _, prog, args = engine._sharded_parts(graph, cfg, opts, mesh, axis,
                                             single_step=True)

    def run_step(state: engine.SpinnerState) -> engine.SpinnerState:
        return prog.run(state, *args)

    run_step.program = prog
    return run_step


def run_sharded_hostloop(graph: Graph, cfg: SpinnerConfig, mesh: Mesh,
                         axis: str = "data",
                         init: Optional[np.ndarray] = None,
                         options: Optional[engine.EngineOptions] = None
                         ) -> engine.SpinnerState:
    """Drive the sharded step from the host, one dispatch per iteration.

    The pre-PR-2 driving mode, preserved as the dispatch-overhead baseline:
    identical math and identical on-device ``_halting_update`` as
    ``partition(engine="sharded")`` (so labels and iteration counts match
    bit for bit -- both run the same shape-bucketed padded layout), but
    the loop pays a host sync on ``state.halted`` every iteration instead
    of running as one fused ``while_loop``.
    """
    from .spinner import prepare_init, resolve_options
    cfg, opts = resolve_options(cfg, options)
    labels, loads, key = prepare_init(graph, cfg, init)
    v_pad = engine.sharded_v_pad(graph, opts, mesh, axis)
    step = make_sharded_step(graph, cfg, mesh, axis, opts)
    state = engine.init_state(engine.pad_labels(labels, v_pad), loads, key)
    for _ in range(cfg.max_iters):
        state = step(state)
        if bool(state.halted):      # the per-iteration host round-trip
            break
    return state


def partition_distributed(graph: Graph, cfg: SpinnerConfig, mesh: Mesh,
                          axis: str = "data",
                          init: Optional[np.ndarray] = None,
                          options: Optional[engine.EngineOptions] = None,
                          ) -> Tuple[np.ndarray, dict]:
    """Run sharded Spinner to the halting criterion; returns (labels, stats).

    Back-compat wrapper: the run itself is
    ``partition(graph, cfg, engine="sharded", mesh=mesh)`` -- one
    ``while_loop`` dispatch across the mesh, halting unified on
    ``engine._halting_update`` with every other engine.  Stats carry the
    per-iteration communication volume (see ``comm_stats``).
    """
    from .spinner import partition, resolve_options
    cfg, opts = resolve_options(cfg, options)
    res = partition(graph, cfg, init=init, record_history=False,
                    engine="sharded", mesh=mesh, axis=axis, options=opts)
    padded, _ = engine.padded_view(graph, opts)
    sg = shard_layout(padded, mesh.shape[axis], pad=opts.pad == "bucket")
    stats = dict(comm_stats(sg, cfg, opts, graph=padded),
                 iterations=res.iterations,
                 halted=res.halted,
                 exchanged_bytes=res.exchanged_bytes)
    return res.labels, stats
