"""Sharded Spinner: the edge-shard layout layer + legacy entry points.

The iteration math no longer lives here.  Pre-PR-2 this module was a fork
of the engine: a hand-rolled per-iteration ``shard_map`` step with its own
copy of the two-phase update and a host halting loop that paid a
``float(score_g)`` sync every superstep -- exactly the distributed
overhead xDGP (1309.1049) and SDP (2110.15669) show must be driven to the
floor for adaptive repartitioning to pay off.  The sharded engine in
``repro.core.engine`` now runs the whole LPA as ONE
``shard_map(lax.while_loop)`` dispatch built on the same
``make_vertex_update`` math as every other engine.  What remains here:

  * ``ShardedGraph`` / ``shard_graph`` -- the padding/layout layer:
    vertices range-partitioned across devices (ceil(V/ndev) contiguous
    ids, tail padded with degree-0 vertices), edges living on their source
    vertex's owner (zero-weight rows pad the shards square);
  * ``shard_layout`` / ``device_upload`` -- the cached layout per
    (graph, ndev) and one cached device upload per (layout, array), so
    mesh sweeps over one graph share a single copy of each;
  * ``make_sharded_step`` -- ONE iteration as a jitted ``shard_map``
    dispatch (the engine's step_fn under a per-call ``shard_map``), kept
    for the dispatch-overhead benchmark;
  * ``run_sharded_hostloop`` -- the pre-PR-2 driving mode: one dispatch
    per iteration with a host sync on ``state.halted``.  The halting
    criterion is the on-device ``engine._halting_update`` carried in the
    state, so iteration counts match ``partition(engine="sharded")``
    exactly -- the ONLY difference this driver measures is dispatch/sync
    overhead (see ``benchmarks/bench_engine.py``);
  * ``partition_distributed`` -- back-compat wrapper over
    ``partition(graph, cfg, engine="sharded", mesh=...)`` returning
    (labels, comm stats), the quantities Figure 5 scales.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from . import engine
from .graph import Graph
from .spinner import SpinnerConfig

_SHARD_CACHE: dict = {}   # per graph: (ndev, pad) -> ShardedGraph
_UPLOAD_CACHE: dict = {}  # per ShardedGraph: () -> device edge arrays


@dataclasses.dataclass(frozen=True)
class ShardedGraph:
    """Host-side edge shards, one row per device."""
    num_vertices: int          # padded to ndev multiple
    num_real_vertices: int
    ndev: int
    v_per_dev: int
    src_local: np.ndarray      # (ndev, E_shard) int32, src - owner_offset
    dst: np.ndarray            # (ndev, E_shard) int32 global ids
    weight: np.ndarray         # (ndev, E_shard) f32, 0 = padding
    deg_w: np.ndarray          # (ndev, v_per_dev) f32


def shard_graph(graph: Graph, ndev: int, pad: bool = False) -> ShardedGraph:
    """Range-partition vertices and edges into per-device shards.

    Pure layout: contiguous blocks of ceil(V/ndev) vertex ids per device,
    every edge stored with its source's owner (the CSR order inside a
    shard is preserved, so on 1 device the shard IS the graph's edge list
    and the sharded scatter-add is bit-identical to the unsharded one).
    ``pad`` buckets the per-device edge width (power-of-two-ish) so a
    session rebinding a slightly grown graph keeps the compile shape.
    """
    from .graph import shape_bucket
    v_per_dev = -(-graph.num_vertices // ndev)
    v_pad = v_per_dev * ndev
    owner = graph.src // v_per_dev
    counts = np.bincount(owner, minlength=ndev)
    e_shard = int(counts.max()) if counts.size else 1
    if pad:
        e_shard = shape_bucket(e_shard, floor=128)
    src_l = np.zeros((ndev, e_shard), np.int32)
    dst = np.zeros((ndev, e_shard), np.int32)
    w = np.zeros((ndev, e_shard), np.float32)
    order = np.argsort(owner, kind="stable")
    s, d, ww = graph.src[order], graph.dst[order], graph.weight[order]
    starts = np.zeros(ndev + 1, np.int64)
    np.cumsum(counts, out=starts[1:])
    for p in range(ndev):
        lo, hi = starts[p], starts[p + 1]
        n = hi - lo
        src_l[p, :n] = s[lo:hi] - p * v_per_dev
        dst[p, :n] = d[lo:hi]
        w[p, :n] = ww[lo:hi]
    deg = np.zeros(v_pad, np.float32)
    deg[: graph.num_vertices] = graph.deg_w
    return ShardedGraph(num_vertices=v_pad,
                        num_real_vertices=graph.num_vertices, ndev=ndev,
                        v_per_dev=v_per_dev, src_local=src_l, dst=dst,
                        weight=w, deg_w=deg.reshape(ndev, v_per_dev))


def shard_layout(graph: Graph, ndev: int, pad: bool = False) -> ShardedGraph:
    """The cached ``ShardedGraph`` layout for a (graph, ndev, pad) tuple."""
    return engine._graph_cached(_SHARD_CACHE, graph, (ndev, pad),
                                lambda: shard_graph(graph, ndev, pad=pad))


def device_upload(sg: ShardedGraph, field: str) -> jax.Array:
    """One uploaded shard array (``src_local``/``dst``/``weight``/``deg_w``),
    cached per (layout, field).

    Keyed on the ShardedGraph identity (itself cached per (graph, ndev))
    and lazy per array, so runner variants -- different cfg / exchange
    plan / score backend sweeping one graph on one mesh size -- share a
    single O(E) device copy of each array they actually use (the Pallas
    backend, for instance, only ever touches ``deg_w`` here).
    """
    return engine._graph_cached(_UPLOAD_CACHE, sg, (field,),
                                lambda: jnp.asarray(getattr(sg, field)))


def comm_stats(sg: ShardedGraph, cfg: SpinnerConfig,
               options: Optional[engine.EngineOptions] = None) -> dict:
    """Per-iteration communication volume of the sharded engine.

    The label exchange (plan selected by ``options.label_exchange``, see
    ``repro.core.comm``) plus the psum'd (k,) aggregators (M(l), load
    delta, score/migration scalars) -- the quantities Figure 5 scales
    with workers and Figure 7 shows decaying.  ``message_bytes_per_iter``
    is the plan's static message volume; None for the delta plan, whose
    volume is measured on device (``PartitionResult.exchanged_bytes``).
    """
    from . import comm
    opts = options if options is not None else engine.EngineOptions()
    name = opts.resolved_label_exchange(sg.ndev)
    # same pad flag as the runner's plan (engine._sharded_parts), so this
    # hits the cached plan and halo's padded volume matches what the
    # compiled all_to_all physically moves
    pad = opts.pad == "bucket"
    plan = comm.make_exchange_plan(name, sg, delta_cap=opts.delta_cap,
                                   pad=pad)
    wire = plan.wire_bytes_per_iter()
    stats = {
        "label_exchange": name,
        "message_bytes_per_iter": None if wire is None else int(wire),
        "allgather_bytes_per_iter": int(comm.make_exchange_plan(
            "allgather", sg, pad=pad).wire_bytes_per_iter()),
        "aggregator_bytes_per_iter": int(3 * cfg.k * 4 * sg.ndev),
        "edge_shard_sizes": [int((sg.weight[p] > 0).sum())
                             for p in range(sg.ndev)],
    }
    if name == "halo":
        # message_bytes_per_iter above is the TRUE halo volume; this is
        # what the static-shape all_to_all physically moves
        stats["halo_padded_bytes_per_iter"] = \
            plan.padded_wire_bytes_per_iter()
    if name == "delta":
        stats["delta_cap"] = plan.cap
    return stats


def make_sharded_step(graph: Graph, cfg: SpinnerConfig, mesh: Mesh,
                      axis: str = "data",
                      options: Optional[engine.EngineOptions] = None):
    """One LPA iteration as a single jitted ``shard_map`` dispatch.

    ``step(state) -> state`` over the engine's ``SpinnerState`` (padded
    labels).  This is the engine's sharded step_fn without the surrounding
    ``while_loop`` -- the building block of ``run_sharded_hostloop``.
    The compiled program is cached globally like the engine's runners, so
    the hostloop driver's repeat calls pay dispatch, not retrace/recompile.
    """
    # Forced onto the all-gather oracle plan: it carries no loop state
    # (delta's label mirror would have to round-trip between dispatches),
    # so each dispatch is self-contained -- and every plan walks the same
    # trajectory anyway, so parity with engine="sharded" is unaffected.
    opts = options if options is not None else engine.EngineOptions()
    opts = dataclasses.replace(opts, label_exchange="allgather")
    _, _, prog, args = engine._sharded_parts(graph, cfg, opts, mesh, axis,
                                             single_step=True)

    def run_step(state: engine.SpinnerState) -> engine.SpinnerState:
        return prog.run(state, *args)

    run_step.program = prog
    return run_step


def run_sharded_hostloop(graph: Graph, cfg: SpinnerConfig, mesh: Mesh,
                         axis: str = "data",
                         init: Optional[np.ndarray] = None,
                         options: Optional[engine.EngineOptions] = None
                         ) -> engine.SpinnerState:
    """Drive the sharded step from the host, one dispatch per iteration.

    The pre-PR-2 driving mode, preserved as the dispatch-overhead baseline:
    identical math and identical on-device ``_halting_update`` as
    ``partition(engine="sharded")`` (so labels and iteration counts match
    bit for bit -- both run the same shape-bucketed padded layout), but
    the loop pays a host sync on ``state.halted`` every iteration instead
    of running as one fused ``while_loop``.
    """
    from .spinner import prepare_init, resolve_options
    cfg, opts = resolve_options(cfg, options)
    labels, loads, key = prepare_init(graph, cfg, init)
    v_pad = engine.sharded_v_pad(graph, opts, mesh, axis)
    step = make_sharded_step(graph, cfg, mesh, axis, opts)
    state = engine.init_state(engine.pad_labels(labels, v_pad), loads, key)
    for _ in range(cfg.max_iters):
        state = step(state)
        if bool(state.halted):      # the per-iteration host round-trip
            break
    return state


def partition_distributed(graph: Graph, cfg: SpinnerConfig, mesh: Mesh,
                          axis: str = "data",
                          init: Optional[np.ndarray] = None,
                          options: Optional[engine.EngineOptions] = None,
                          ) -> Tuple[np.ndarray, dict]:
    """Run sharded Spinner to the halting criterion; returns (labels, stats).

    Back-compat wrapper: the run itself is
    ``partition(graph, cfg, engine="sharded", mesh=mesh)`` -- one
    ``while_loop`` dispatch across the mesh, halting unified on
    ``engine._halting_update`` with every other engine.  Stats carry the
    per-iteration communication volume (see ``comm_stats``).
    """
    from .spinner import partition, resolve_options
    cfg, opts = resolve_options(cfg, options)
    res = partition(graph, cfg, init=init, record_history=False,
                    engine="sharded", mesh=mesh, axis=axis, options=opts)
    padded, _ = engine.padded_view(graph, opts)
    sg = shard_layout(padded, mesh.shape[axis], pad=opts.pad == "bucket")
    stats = dict(comm_stats(sg, cfg, opts), iterations=res.iterations,
                 halted=res.halted,
                 exchanged_bytes=res.exchanged_bytes)
    return res.labels, stats
