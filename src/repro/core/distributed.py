"""Distributed Spinner: edge-sharded LPA over a device mesh (shard_map).

The Pregel implementation maps onto the mesh as follows (DESIGN.md Sec. 3):

  * vertices are range-partitioned across devices (V/ndev contiguous ids);
  * edges live on their source vertex's owner (CSR shards never move);
  * the per-iteration "messages" are ONE tiled all-gather of the int32
    label vector (V * 4 bytes), the aggregate of Pregel's label-change
    messages;
  * the B(l), M(l), score(G) aggregators are psums of (k,) partials --
    exactly Giraph's sharded aggregators, fused into one collective each.

Per-device work is the same vectorized two-phase update as the
single-device engine, so the distributed run is bit-compatible with the
sequential one given the same per-vertex keys (validated in tests).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .graph import Graph
from .spinner import SpinnerConfig


@dataclasses.dataclass(frozen=True)
class ShardedGraph:
    """Host-side edge shards, one row per device."""
    num_vertices: int          # padded to ndev multiple
    num_real_vertices: int
    ndev: int
    v_per_dev: int
    src_local: np.ndarray      # (ndev, E_shard) int32, src - owner_offset
    dst: np.ndarray            # (ndev, E_shard) int32 global ids
    weight: np.ndarray         # (ndev, E_shard) f32, 0 = padding
    deg_w: np.ndarray          # (ndev, v_per_dev) f32


def shard_graph(graph: Graph, ndev: int) -> ShardedGraph:
    v_per_dev = -(-graph.num_vertices // ndev)
    v_pad = v_per_dev * ndev
    owner = graph.src // v_per_dev
    counts = np.bincount(owner, minlength=ndev)
    e_shard = int(counts.max()) if counts.size else 1
    src_l = np.zeros((ndev, e_shard), np.int32)
    dst = np.zeros((ndev, e_shard), np.int32)
    w = np.zeros((ndev, e_shard), np.float32)
    order = np.argsort(owner, kind="stable")
    s, d, ww = graph.src[order], graph.dst[order], graph.weight[order]
    starts = np.zeros(ndev + 1, np.int64)
    np.cumsum(counts, out=starts[1:])
    for p in range(ndev):
        lo, hi = starts[p], starts[p + 1]
        n = hi - lo
        src_l[p, :n] = s[lo:hi] - p * v_per_dev
        dst[p, :n] = d[lo:hi]
        w[p, :n] = ww[lo:hi]
    deg = np.zeros(v_pad, np.float32)
    deg[: graph.num_vertices] = graph.deg_w
    return ShardedGraph(num_vertices=v_pad,
                        num_real_vertices=graph.num_vertices, ndev=ndev,
                        v_per_dev=v_per_dev, src_local=src_l, dst=dst,
                        weight=w, deg_w=deg.reshape(ndev, v_per_dev))


def make_distributed_step(sg: ShardedGraph, cfg: SpinnerConfig, mesh: Mesh,
                          axis: str = "data"):
    """Jitted shard_map iteration: (labels, loads, key) -> updated."""
    k = cfg.k
    C = jnp.float32(cfg.c * float(sg.deg_w.sum()) / k)
    vl = sg.v_per_dev
    degree_weighted = cfg.migration_weighting == "edges"

    def step_local(labels_l, src_l, dst, w, deg_l, loads, key):
        # labels_l: (1, vl) this device's block; gather the full vector
        labels_full = jax.lax.all_gather(labels_l[0], axis).reshape(-1)
        me = jax.lax.axis_index(axis)
        nbr = labels_full[dst[0]]
        scores = jnp.zeros((vl, k), jnp.float32).at[src_l[0], nbr].add(w[0])
        norm = scores / jnp.maximum(deg_l[0], 1.0)[:, None]
        total = norm - (loads / C)[None, :]

        key = jax.random.fold_in(key, me)
        k_noise, k_mig = jax.random.split(key)
        noise = jax.random.uniform(k_noise, (vl, k), jnp.float32, 0.0,
                                   cfg.tie_noise)
        labels_mine = labels_l[0]
        bonus = cfg.current_bonus * jax.nn.one_hot(labels_mine, k,
                                                   dtype=jnp.float32)
        best = jnp.argmax(total + noise + bonus, axis=1).astype(jnp.int32)
        want = best != labels_mine

        measure = deg_l[0] if degree_weighted else jnp.ones_like(deg_l[0])
        M_part = jnp.zeros((k,), jnp.float32).at[best].add(
            jnp.where(want, measure, 0.0))
        M = jax.lax.psum(M_part, axis)                    # aggregator
        R = jnp.maximum(C - loads, 0.0)
        p = jnp.clip(R / jnp.maximum(M, 1e-9), 0.0, 1.0)
        u = jax.random.uniform(k_mig, (vl,), jnp.float32)
        migrate = want & (u < p[best])

        new_labels = jnp.where(migrate, best, labels_mine)
        mig_deg = jnp.where(migrate, deg_l[0], 0.0)
        delta = (jnp.zeros((k,), jnp.float32).at[best].add(mig_deg)
                 .at[labels_mine].add(-mig_deg))
        new_loads = loads + jax.lax.psum(delta, axis)     # aggregator
        sel = jnp.take_along_axis(total, new_labels[:, None], axis=1)[:, 0]
        score_part = jnp.sum(sel)
        score_g = jax.lax.psum(score_part, axis)          # aggregator
        n_mig = jax.lax.psum(jnp.sum(migrate), axis)
        return (new_labels[None], new_loads, score_g, n_mig)

    sharded = shard_map(
        step_local, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(axis), P(), P()),
        out_specs=(P(axis), P(), P(), P()),
        check_rep=False)
    return jax.jit(sharded)


def partition_distributed(graph: Graph, cfg: SpinnerConfig, mesh: Mesh,
                          axis: str = "data",
                          init: Optional[np.ndarray] = None,
                          ) -> Tuple[np.ndarray, dict]:
    """Run distributed Spinner to the halting criterion; returns labels.

    Also returns comm stats: per-iteration message volume (the label
    all-gather) and aggregator volume, the quantities Figure 5 scales.
    """
    ndev = mesh.shape[axis]
    sg = shard_graph(graph, ndev)
    key = jax.random.PRNGKey(cfg.seed)
    key, k0 = jax.random.split(key)
    if init is None:
        labels = jax.random.randint(k0, (sg.num_vertices,), 0, cfg.k,
                                    dtype=jnp.int32)
    else:
        pad = sg.num_vertices - init.shape[0]
        labels = jnp.asarray(np.pad(np.asarray(init, np.int32), (0, pad)))
    deg_flat = jnp.asarray(sg.deg_w.reshape(-1))
    loads = jnp.zeros((cfg.k,), jnp.float32).at[labels].add(deg_flat)

    step = make_distributed_step(sg, cfg, mesh, axis)
    labels = labels.reshape(ndev, sg.v_per_dev)
    args = tuple(map(jnp.asarray, (sg.src_local, sg.dst, sg.weight,
                                   sg.deg_w)))
    best, stall, it, halted = -np.inf, 0, 0, False
    for it in range(1, cfg.max_iters + 1):
        key, k_it = jax.random.split(key)
        labels, loads, score_g, n_mig = step(labels, *args, loads, k_it)
        score_g = float(score_g)
        tol = cfg.eps * max(1.0, abs(best))
        if score_g > best + tol:
            best, stall = max(best, score_g), 0
        else:
            best = max(best, score_g)
            stall += 1
            if stall >= cfg.halt_window:
                halted = True
                break
    out = np.asarray(labels).reshape(-1)[: sg.num_real_vertices]
    stats = {
        "iterations": it,
        "halted": halted,
        "message_bytes_per_iter": int(sg.num_vertices * 4 * ndev),
        "aggregator_bytes_per_iter": int(3 * cfg.k * 4 * ndev),
        "edge_shard_sizes": [int((sg.weight[p] > 0).sum())
                             for p in range(ndev)],
    }
    return out, stats


def _selftest() -> None:
    """Run under XLA_FLAGS=--xla_force_host_platform_device_count=8."""
    from . import generators, metrics
    g = generators.watts_strogatz(4000, 12, 0.2, seed=3)
    cfg = SpinnerConfig(k=8, seed=1, max_iters=120)
    ndev = len(jax.devices())
    mesh = jax.sharding.Mesh(np.array(jax.devices()), ("data",))
    labels, stats = partition_distributed(g, cfg, mesh)
    phi = metrics.phi(g, labels)
    rho = metrics.rho(g, labels, cfg.k)
    print(f"devices={ndev} iters={stats['iterations']} "
          f"phi={phi:.3f} rho={rho:.3f} "
          f"shards={stats['edge_shard_sizes']}")
    assert phi > 0.3, "distributed LPA failed to find locality"
    assert rho < cfg.c + 0.05, "distributed LPA failed balance"
    print("DISTRIBUTED SELFTEST OK")


if __name__ == "__main__":
    _selftest()
