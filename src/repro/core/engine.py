"""Device-resident Spinner LPA engine (state / step / runner layering).

The legacy driver in ``spinner.py`` round-trips to the host every iteration
(``float(score_g)`` sync, host PRNG splitting, per-iteration numpy history),
so on small graphs wall-clock is dominated by dispatch latency rather than
the ComputeScores kernel.  This module keeps the whole run on device:

  * ``SpinnerState`` -- a pure functional pytree carrying everything one LPA
    iteration reads or writes: labels, loads, the PRNG key, the Eq. 9
    halting aggregates (best_score / stall), iteration counter, and the
    migration statistics of the last step.
  * ``make_iteration`` -- the two-phase ComputeScores / ComputeMigrations
    math (Eqs. 8, 11, 12) as a pure function, shared verbatim with the
    legacy host loop so the two engines are bit-compatible oracles of each
    other.  The Eq. 8 numerator is delegated to a pluggable score backend
    (``repro.kernels.ops.get_score_backend``): the XLA scatter-add path and
    the Pallas ``spinner_scores_tiled`` kernel are interchangeable and
    selected once at trace time.
  * ``make_step_fn`` -- one fully-jittable state -> state transition:
    PRNG split, iteration, and the Section 3.3 eps/halt_window stall logic
    evaluated on device.
  * ``run_fused`` -- the entire run as a single ``jax.lax.while_loop``
    dispatch; nothing touches the host until the final state is read back.
  * ``run_chunked`` -- a ``jax.lax.scan`` that executes ``chunk_size``
    iterations per dispatch and records a fixed-size on-device history
    (score / migrations / message mass / phi / rho per iteration) for
    callers that need per-iteration traces; the host only syncs once per
    chunk to check the halting flag.
  * ``run_sharded`` -- the fused loop over a DEVICE MESH: labels and every
    other per-vertex array are sharded over the vertex axis via
    ``shard_map``, the (k,) load / migration aggregates and the Eq. 9
    halting scalars are ``psum``-reduced inside the step so every device
    sees the same halting decision, and the whole run is ONE
    ``lax.while_loop`` dispatch across all devices -- the Giraph-cluster
    analogue of Section 4 with zero per-iteration host round-trips.  The
    per-vertex math is ``make_vertex_update``, shared verbatim with the
    single-device iteration, which is what makes a 1-device mesh a
    bit-compatible oracle of ``run_fused`` (same labels, same iteration
    counts for the same seed).  Edge layout/padding lives in
    ``repro.core.distributed`` (``shard_graph``); the per-iteration label
    exchange is a pluggable plan from ``repro.core.comm``
    (``cfg.label_exchange``: the full all-gather oracle, a boundary-only
    halo exchange, or a changed-labels-only delta exchange that
    reproduces the Figure 7 traffic decay), with wire bytes accumulated
    on device in ``SpinnerState.exchanged_bytes``.

``spinner.partition`` selects between these runners and the legacy host
loop via its ``engine`` argument; ``incremental.adapt`` / ``resize`` ride on
the same entry point, so incremental and elastic restarts are a single
device call as well -- on whichever mesh the caller passes.
"""
from __future__ import annotations

import weakref
import dataclasses
from typing import Callable, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec

from .graph import Graph

DEFAULT_CHUNK = 32

# Per-Graph memoization.  partition()/adapt()/resize() are typically called
# many times against the same Graph (benchmark sweeps, incremental
# restarts); rebuilding closures per call would re-upload edge arrays and
# re-trace/re-compile the jitted step or whole while_loop/scan each time,
# wiping out the dispatch win.  Every cache below is keyed on id(graph) + a
# per-use suffix, with a weakref guard so entries die with their graph and
# a recycled id() can never alias.
_RUNNER_CACHE: dict = {}      # (kind, cfg, chunk_size, record) -> runner
                              # sharded kind keys on (cfg, mesh, axis)
_STEP_CACHE: dict = {}        # (cfg,) -> jitted iterate (host loop's step)
_SCORE_FN_CACHE: dict = {}    # (backend, k) -> score closure
_EDGE_UPLOAD_CACHE: dict = {} # () -> (src, dst, weight, deg_w) on device


def _graph_cached(cache: dict, graph: Graph, suffix: tuple,
                  build: Callable[[], object]):
    """Memoize ``build()`` per (graph, suffix); evicted when graph dies."""
    key = (id(graph),) + suffix
    entry = cache.get(key)
    if entry is not None and entry[0]() is graph:
        return entry[1]
    value = build()
    cache[key] = (weakref.ref(graph, lambda _: cache.pop(key, None)), value)
    return value


def _cache_cfg(cfg):
    """Cache-key view of the config: the seed never enters the traced
    computation (it only feeds host-side PRNGKey creation in
    ``prepare_init``), so seed sweeps must share one compiled runner."""
    return dataclasses.replace(cfg, seed=0)


def _get_runner(kind: str, graph: Graph, cfg, chunk_size: Optional[int],
                score_fn: Optional[Callable], record: bool = True) -> Callable:
    if score_fn is not None:
        # custom backend closure: not keyable, build fresh
        if kind == "fused":
            return make_fused_runner(graph, cfg, score_fn)
        return make_chunked_runner(graph, cfg, chunk_size, score_fn,
                                   record=record)
    if kind == "fused":
        build = lambda: make_fused_runner(graph, cfg)
    else:
        build = lambda: make_chunked_runner(graph, cfg, chunk_size,
                                            record=record)
    return _graph_cached(_RUNNER_CACHE, graph,
                         (kind, _cache_cfg(cfg), chunk_size, record), build)


def cached_jit_step(graph: Graph, cfg) -> Callable:
    """Jitted ``iterate(labels, loads, key)``, cached per (graph, cfg).

    This is the host loop's step; caching it keeps ``engine="host"`` from
    re-tracing on every partition() call, same as the fused runners.
    """
    return _graph_cached(_STEP_CACHE, graph, (_cache_cfg(cfg),),
                         lambda: jax.jit(make_iteration(graph, cfg)))


class SpinnerState(NamedTuple):
    """Carry of the fused LPA loop -- one pytree, fully device-resident."""

    labels: jax.Array          # (V,) int32 current assignment
    loads: jax.Array           # (k,) float32 B(l) (Eq. 6), running update
    key: jax.Array             # PRNG key consumed by splitting each iter
    best_score: jax.Array      # f32 scalar, best score(G) so far (Eq. 9)
    stall: jax.Array           # int32, consecutive non-improving iterations
    iteration: jax.Array       # int32, iterations completed
    halted: jax.Array          # bool, eps/halt_window criterion fired
    total_messages: jax.Array  # f32, cumulative migrant degree mass
    score: jax.Array           # f32, score(G) after the last iteration
    migrations: jax.Array      # int32, migrating vertices last iteration
    message_mass: jax.Array    # f32, migrant degree mass last iteration
    exchanged_bytes: jax.Array # f32, cumulative label-exchange wire bytes
                               # (0 off the sharded engine; see core.comm)


def init_state(labels: jax.Array, loads: jax.Array,
               key: jax.Array) -> SpinnerState:
    return SpinnerState(
        labels=jnp.asarray(labels, jnp.int32),
        loads=jnp.asarray(loads, jnp.float32),
        key=key,
        best_score=jnp.float32(-jnp.inf),
        stall=jnp.int32(0),
        iteration=jnp.int32(0),
        halted=jnp.asarray(False),
        total_messages=jnp.float32(0.0),
        score=jnp.float32(0.0),
        migrations=jnp.int32(0),
        message_mass=jnp.float32(0.0),
        exchanged_bytes=jnp.float32(0.0),
    )


def device_edges(graph: Graph):
    """(src, dst, weight, deg_w) as device arrays, uploaded once per Graph.

    Shared by every runner variant and the XLA score backend: a config
    sweep over one graph would otherwise hold one 2*E copy of
    src/dst/weight per variant.
    """
    return _graph_cached(
        _EDGE_UPLOAD_CACHE, graph, (),
        lambda: (jnp.asarray(graph.src), jnp.asarray(graph.dst),
                 jnp.asarray(graph.weight), jnp.asarray(graph.deg_w)))


def make_score_fn(graph: Graph, cfg) -> Callable[[jax.Array], jax.Array]:
    """Build (or fetch cached) the Eq. 8 numerator fn for the backend.

    Cached per (graph, backend, k): the backend build uploads the O(E)
    edge arrays (and, for pallas, retiles the CSR on the host), none of
    which depends on the rest of the config -- so runner variants
    (different eps/seed/max_iters sweeping the same graph) share one
    built backend.
    """
    from repro.kernels import ops as kernel_ops   # lazy: no import cycle
    name = cfg.resolved_score_backend()

    def build():
        return kernel_ops.get_score_backend(name).build(graph, cfg.k)

    return _graph_cached(_SCORE_FN_CACHE, graph, (name, cfg.k), build)


def make_vertex_update(cfg, C: jnp.float32) -> Callable:
    """The per-vertex two-phase update (Eqs. 7-8, 11-12) as a pure function.

    Shared verbatim by the single-device iteration (``make_iteration``) and
    the per-shard sharded iteration (``make_sharded_step_fn``), which is
    what makes every engine an oracle of the others.  The caller supplies
    whatever slice of the vertex set it owns plus the matching noise/u
    draws; every (k,) or scalar aggregate (M(l), the load delta, score(G),
    migration counts) goes through ``reduce_`` -- identity on a single
    device, ``lax.psum`` over the vertex axis under ``shard_map``, i.e. the
    Giraph sharded aggregators as one collective each.

    ``valid`` masks padding vertices introduced by the sharded layout
    (``None`` statically skips the masking ops so the unpadded path is
    bit-identical to the pre-sharding engine).
    """
    k = cfg.k
    degree_weighted = cfg.migration_weighting == "edges"

    def update(scores, labels, deg_w, loads, noise, u, valid, reduce_):
        # ---- ComputeScores (Eq. 8) -------------------------------------
        norm = scores / jnp.maximum(deg_w, 1.0)[:, None]
        penalty = loads / C                                # pi(l) (Eq. 7)
        total = norm - penalty[None, :]
        bonus = cfg.current_bonus * jax.nn.one_hot(labels, k,
                                                   dtype=jnp.float32)
        best = jnp.argmax(total + noise + bonus, axis=1).astype(jnp.int32)
        want = best != labels
        if valid is not None:
            want = want & valid

        # ---- ComputeMigrations (Eq. 11-12) -----------------------------
        measure = deg_w if degree_weighted else jnp.ones_like(deg_w)
        M = reduce_(jnp.zeros((k,), jnp.float32).at[best].add(
            jnp.where(want, measure, 0.0)))                # aggregator
        R = jnp.maximum(C - loads, 0.0)                    # Eq. 11
        p = jnp.clip(R / jnp.maximum(M, 1e-9), 0.0, 1.0)   # Eq. 12
        migrate = want & (u < p[best])

        new_labels = jnp.where(migrate, best, labels)
        mig_deg = jnp.where(migrate, deg_w, 0.0)
        delta = (jnp.zeros((k,), jnp.float32)
                 .at[best].add(mig_deg)
                 .at[labels].add(-mig_deg))
        new_loads = loads + reduce_(delta)                 # aggregator

        # ---- halting aggregate: score(G) at the new assignment (Eq. 9) --
        sel = jnp.take_along_axis(total, new_labels[:, None], axis=1)[:, 0]
        if valid is not None:
            sel = jnp.where(valid, sel, 0.0)
        score_g = reduce_(jnp.sum(sel))                    # aggregator
        # migration mass = sum of migrant degrees = Pregel messages sent
        # (each migrating vertex notifies all neighbors, Section 4.1.3)
        n_mig = reduce_(jnp.sum(migrate).astype(jnp.int32))
        mig_mass = reduce_(jnp.sum(mig_deg))
        return new_labels, new_loads, score_g, n_mig, mig_mass

    return update


def make_iteration(graph: Graph, cfg,
                   score_fn: Optional[Callable] = None) -> Callable:
    """One LPA iteration (ComputeScores + ComputeMigrations) as a pure fn.

    Returns ``iterate(labels, loads, key) -> (labels, loads, score_g,
    n_migrations, migration_mass)``.  Both the legacy host loop and the
    fused runners call exactly this function, which is what makes them
    oracles of each other; the math itself lives in ``make_vertex_update``
    and is also what the sharded engine executes per shard.
    """
    if score_fn is None:
        score_fn = make_score_fn(graph, cfg)
    deg_w = device_edges(graph)[3]
    V, k = graph.num_vertices, cfg.k
    update = make_vertex_update(cfg, jnp.float32(cfg.capacity(graph)))

    def iterate(labels: jax.Array, loads: jax.Array, key: jax.Array):
        scores = score_fn(labels)                          # (V, k) f32
        k_noise, k_mig = jax.random.split(key)
        noise = jax.random.uniform(k_noise, (V, k), jnp.float32,
                                   0.0, cfg.tie_noise)
        u = jax.random.uniform(k_mig, (V,), jnp.float32)
        return update(scores, labels, deg_w, loads, noise, u,
                      None, lambda x: x)

    return iterate


def _halting_update(best_score, stall, score_g, eps, halt_window):
    """Section 3.3 stall logic on device, mirroring the host loop exactly.

    On the first iteration best_score is -inf, so tol is inf and
    ``best + tol`` is NaN: the comparison is False and the iteration counts
    toward the stall window -- the same (intentional) behaviour as the
    legacy host loop's float arithmetic.
    """
    tol = eps * jnp.maximum(jnp.float32(1.0), jnp.abs(best_score))
    improved = score_g > best_score + tol
    new_best = jnp.maximum(best_score, score_g)
    new_stall = jnp.where(improved, jnp.int32(0), stall + 1)
    return new_best, new_stall, new_stall >= halt_window


def make_step_fn(graph: Graph, cfg,
                 score_fn: Optional[Callable] = None) -> Callable:
    """Jittable ``SpinnerState -> SpinnerState`` transition."""
    iterate = make_iteration(graph, cfg, score_fn)
    eps = jnp.float32(cfg.eps)
    halt_window = cfg.halt_window

    def step_fn(state: SpinnerState) -> SpinnerState:
        key, k_it = jax.random.split(state.key)
        labels, loads, score_g, n_mig, mig_mass = iterate(
            state.labels, state.loads, k_it)
        best, stall, halted = _halting_update(
            state.best_score, state.stall, score_g, eps, halt_window)
        return SpinnerState(
            labels=labels, loads=loads, key=key,
            best_score=best, stall=stall,
            iteration=state.iteration + 1, halted=halted,
            total_messages=state.total_messages + mig_mass,
            score=score_g, migrations=n_mig, message_mass=mig_mass,
            exchanged_bytes=state.exchanged_bytes)

    return step_fn


# ---------------------------------------------------------------------------
# Fused runner: the whole run is one lax.while_loop dispatch
# ---------------------------------------------------------------------------

def make_fused_runner(graph: Graph, cfg,
                      score_fn: Optional[Callable] = None) -> Callable:
    """Compile the full Spinner run into a single device call."""
    step_fn = make_step_fn(graph, cfg, score_fn)
    max_iters = cfg.max_iters

    def cond_fn(s: SpinnerState):
        return jnp.logical_and(jnp.logical_not(s.halted),
                               s.iteration < max_iters)

    @jax.jit
    def run(state: SpinnerState) -> SpinnerState:
        return jax.lax.while_loop(cond_fn, step_fn, state)

    return run


def run_fused(graph: Graph, cfg, labels, loads, key,
              score_fn: Optional[Callable] = None) -> SpinnerState:
    """Run to the stable state in one ``lax.while_loop`` dispatch.

    The compiled runner is cached per (graph, cfg), so repeated runs --
    determinism checks, incremental adapt/resize restarts -- skip
    re-tracing entirely.
    """
    runner = _get_runner("fused", graph, cfg, None, score_fn)
    return runner(init_state(labels, loads, key))


# ---------------------------------------------------------------------------
# Chunked runner: chunk_size iterations per dispatch, on-device history
# ---------------------------------------------------------------------------

def make_chunked_runner(graph: Graph, cfg, chunk_size: int = DEFAULT_CHUNK,
                        score_fn: Optional[Callable] = None,
                        record: bool = True) -> Callable:
    """Compile ``chunk_size`` iterations + history recording into one scan.

    Each scan step is guarded: once the halting criterion fires (or
    ``max_iters`` is reached) the state passes through unchanged and the
    record is marked invalid, so a trailing partial chunk costs nothing but
    pass-through work.  With ``record=False`` the per-iteration phi trace
    (an O(E) gather) is skipped and only the validity flags come back.
    """
    step_fn = make_step_fn(graph, cfg, score_fn)
    src, dst, _, _ = device_edges(graph)
    has_edges = graph.src.size > 0
    # edgeless graph: mirror metrics.rho's ideal<=0 convention (rho = 1)
    ideal = jnp.float32(graph.total_weight / cfg.k) if has_edges else None
    max_iters = cfg.max_iters

    def body(state: SpinnerState, _):
        active = jnp.logical_and(jnp.logical_not(state.halted),
                                 state.iteration < max_iters)
        new_state = jax.lax.cond(active, step_fn, lambda s: s, state)
        if not record:
            return new_state, {"valid": active}
        if has_edges:
            local = new_state.labels[src] == new_state.labels[dst]
            phi = jnp.mean(local.astype(jnp.float32))
            rho = jnp.max(new_state.loads) / ideal
        else:
            phi = jnp.float32(1.0)
            rho = jnp.float32(1.0)
        rec = {
            "iteration": new_state.iteration,
            "score": new_state.score,
            "migrations": new_state.migrations,
            "message_mass": new_state.message_mass,
            "phi": phi,
            "rho": rho,
            "valid": active,
        }
        return new_state, rec

    @jax.jit
    def run_chunk(state: SpinnerState):
        return jax.lax.scan(body, state, None, length=chunk_size)

    return run_chunk


def run_chunked(graph: Graph, cfg, labels, loads, key,
                chunk_size: int = DEFAULT_CHUNK,
                score_fn: Optional[Callable] = None,
                callback: Optional[Callable[[int, dict], None]] = None,
                record: bool = True,
                ) -> Tuple[SpinnerState, List[dict]]:
    """Run with at most ``ceil(max_iters / chunk_size)`` device dispatches.

    Returns the final state plus the per-iteration history (same dict
    schema as the legacy host loop: iteration / score / migrations /
    message_mass / phi / rho), recorded on device and synced once per
    chunk.  ``record=False`` skips history recording entirely (the
    returned list is empty); a ``callback`` forces recording on.
    """
    record = record or callback is not None
    run_chunk = _get_runner("chunked", graph, cfg, chunk_size, score_fn,
                            record=record)
    state = init_state(labels, loads, key)
    history: List[dict] = []
    num_chunks = -(-cfg.max_iters // chunk_size)
    for _ in range(num_chunks):
        state, recs = run_chunk(state)
        recs = jax.device_get(recs)
        if record:
            for i in range(chunk_size):
                if not bool(recs["valid"][i]):
                    break
                entry = {
                    "iteration": int(recs["iteration"][i]),
                    "score": float(recs["score"][i]),
                    "migrations": int(recs["migrations"][i]),
                    "message_mass": float(recs["message_mass"][i]),
                    "phi": float(recs["phi"][i]),
                    "rho": float(recs["rho"][i]),
                }
                history.append(entry)
                if callback is not None:
                    callback(entry["iteration"], entry)
        # One scalar sync per chunk: stop dispatching once the run is over.
        if not bool(recs["valid"][chunk_size - 1]) or bool(
                jax.device_get(state.halted)):
            break
    return state, history


# ---------------------------------------------------------------------------
# Sharded runner: one lax.while_loop dispatch across the whole device mesh
# ---------------------------------------------------------------------------

def state_partition_spec(axis: str) -> SpinnerState:
    """``shard_map`` specs for a ``SpinnerState``: labels sharded over the
    vertex ``axis``, every aggregate (loads, key, halting scalars, the
    exchange-byte counter) replicated -- they are psum-consistent across
    devices by construction, whichever exchange plan is active."""
    rep = PartitionSpec()
    return SpinnerState(
        labels=PartitionSpec(axis), loads=rep, key=rep, best_score=rep,
        stall=rep, iteration=rep, halted=rep, total_messages=rep,
        score=rep, migrations=rep, message_mass=rep, exchanged_bytes=rep)


def _default_partition_mesh() -> Mesh:
    """1-D mesh over all local devices (cached so cache keys stay stable)."""
    global _DEFAULT_MESH
    if _DEFAULT_MESH is None:
        from repro.launch.mesh import make_partition_mesh
        _DEFAULT_MESH = make_partition_mesh()
    return _DEFAULT_MESH


_DEFAULT_MESH: Optional[Mesh] = None


def make_sharded_step_fn(graph: Graph, sg, cfg, axis: str, plan,
                         scores: Callable) -> Callable:
    """Per-device jittable sharded transition, parameterized by the plan.

    Runs INSIDE ``shard_map`` over ``axis``: ``state.labels`` arrives as
    this device's ``(v_per_dev,)`` shard, the edge blocks as this device's
    rows of the score backend's layout, scalars replicated.  The label
    exchange is delegated to ``plan`` (``repro.core.comm.ExchangePlan``):
    the all-gather oracle, the boundary-only halo exchange, or the
    changed-labels-only delta exchange -- all bit-compatible, differing
    only in bytes on the wire (accumulated into
    ``state.exchanged_bytes``).  The (k,) and scalar aggregates inside
    ``make_vertex_update`` are psum-reduced, so every device computes the
    same ``_halting_update`` decision and a surrounding ``while_loop``
    stays in lockstep with no host involvement.

    Returns ``step(state, aux, deg_l, score_blocks, plan_blocks) ->
    (state, aux)`` where ``aux`` is the plan's loop-carried state (e.g.
    delta's replicated label mirror; ``()`` for stateless plans).

    PRNG (``cfg.sharded_noise``): with ``"replicated"`` (default) noise/u
    are drawn over the full padded vertex set from the replicated key and
    sliced to the local shard -- on a 1-device mesh the padded set IS the
    vertex set, so draws (and therefore labels and iteration counts) are
    bit-identical to the single-device engine.  With ``"folded"`` each
    device folds its axis index into the key and draws only its local
    (v_per_dev, k) block -- O(V/ndev) instead of O(V) noise memory for
    very large V, at the cost of a different (still deterministic) stream.
    """
    k = cfg.k
    v_pad, vl = sg.num_vertices, sg.v_per_dev
    num_real = sg.num_real_vertices
    update = make_vertex_update(cfg, jnp.float32(cfg.capacity(graph)))
    eps = jnp.float32(cfg.eps)
    halt_window = cfg.halt_window
    noise_mode = cfg.resolved_sharded_noise()

    def psum(x):
        return jax.lax.psum(x, axis)

    def step_fn(state: SpinnerState, aux, deg_l, score_blocks, plan_blocks):
        key, k_it = jax.random.split(state.key)
        # Pregel messages: one plan-defined label exchange.
        lookup, aux, xbytes = plan.exchange(state.labels, aux, axis,
                                            *plan_blocks)
        scores_v = scores(lookup, *score_blocks)           # (vl, k) local
        off = jax.lax.axis_index(axis) * vl
        if noise_mode == "folded":
            k_dev = jax.random.fold_in(k_it, jax.lax.axis_index(axis))
            k_noise, k_mig = jax.random.split(k_dev)
            noise = jax.random.uniform(k_noise, (vl, k), jnp.float32,
                                       0.0, cfg.tie_noise)
            u = jax.random.uniform(k_mig, (vl,), jnp.float32)
        else:
            k_noise, k_mig = jax.random.split(k_it)
            noise_full = jax.random.uniform(k_noise, (v_pad, k), jnp.float32,
                                            0.0, cfg.tie_noise)
            u_full = jax.random.uniform(k_mig, (v_pad,), jnp.float32)
            noise = jax.lax.dynamic_slice_in_dim(noise_full, off, vl, 0)
            u = jax.lax.dynamic_slice_in_dim(u_full, off, vl, 0)
        if num_real == v_pad:
            valid = None         # no padding: bit-identical unpadded math
        else:
            valid = off + jnp.arange(vl, dtype=jnp.int32) < num_real
        labels, loads, score_g, n_mig, mig_mass = update(
            scores_v, state.labels, deg_l, state.loads, noise, u, valid,
            psum)
        best, stall, halted = _halting_update(
            state.best_score, state.stall, score_g, eps, halt_window)
        return SpinnerState(
            labels=labels, loads=loads, key=key,
            best_score=best, stall=stall,
            iteration=state.iteration + 1, halted=halted,
            total_messages=state.total_messages + mig_mass,
            score=score_g, migrations=n_mig, message_mass=mig_mass,
            exchanged_bytes=state.exchanged_bytes + xbytes), aux

    return step_fn


def _sharded_parts(graph: Graph, cfg, mesh: Mesh, axis: str,
                   score_fn: Optional[Callable] = None):
    """Everything the sharded runner and one-step dispatcher share.

    Resolves the exchange plan from ``cfg.label_exchange``, builds the
    score backend's sharded layout against the plan's ``dst_index``, and
    assembles the per-device step plus the full ``shard_map`` argument
    list.  Returns ``(sg, plan, step_fn, args, arg_specs, n_score_args)``
    where ``args``/``arg_specs`` cover ``(deg_w, *score_args,
    *plan_args)`` -- every array with leading dimension ndev, sharded
    over ``axis``.

    A custom ``score_fn`` closure gets the XLA-layout edge blocks
    ``(src_local, dst_index, weight)``, matching the signature the XLA
    backend's sharded scorer uses.
    """
    from . import comm                                    # sibling, no cycle
    from .distributed import device_upload, shard_layout  # layout layer
    ndev = mesh.shape[axis]
    sg = shard_layout(graph, ndev)
    plan = comm.make_exchange_plan(cfg.resolved_label_exchange(ndev), sg,
                                   delta_cap=cfg.delta_cap)
    if score_fn is None:
        from repro.kernels import ops as kernel_ops   # lazy: no import cycle
        backend = kernel_ops.get_score_backend(cfg.resolved_score_backend())
        build_sharded = getattr(backend, "build_sharded", None)
        if build_sharded is None:
            raise NotImplementedError(
                f"score backend {backend.name!r} has no sharded "
                "implementation (build_sharded)")
        # cached like make_score_fn: the build retiles/uploads O(E) arrays
        # (for pallas, a host retile per shard) and depends only on the
        # layout, the backend, k, and the plan's dst_index -- so a cfg
        # sweep (eps/seed/max_iters/...) over one graph shares one build,
        # and so do the allgather/delta plans (both index with sg.dst)
        dst_layout = "halo" if plan.dst_index is not sg.dst else "global"
        score_args, scores = _graph_cached(
            _SCORE_FN_CACHE, graph,
            ("sharded", backend.name, cfg.k, ndev, dst_layout),
            lambda: build_sharded(sg, cfg.k, plan.dst_index))
    else:
        # custom closures get the XLA backend's edge layout (same arrays,
        # same normalization), just a different scores fn
        from repro.kernels import ops as kernel_ops
        score_args, _ = kernel_ops.get_score_backend("xla").build_sharded(
            sg, cfg.k, plan.dst_index)
        scores = score_fn
    step_fn = make_sharded_step_fn(graph, sg, cfg, axis, plan, scores)
    args = (device_upload(sg, "deg_w"),) + tuple(score_args) \
        + tuple(plan.device_args())
    arg_specs = (PartitionSpec(axis),) * (1 + len(score_args)) \
        + tuple(plan.arg_specs(axis))
    return sg, plan, step_fn, args, arg_specs, len(score_args)


def make_sharded_runner(graph: Graph, cfg, mesh: Mesh, axis: str = "data",
                        score_fn: Optional[Callable] = None) -> Callable:
    """Compile the full sharded run into ONE device dispatch.

    Returns ``runner(state) -> state`` where ``state.labels`` is the padded
    (ndev * v_per_dev,) vector; the ``lax.while_loop`` lives INSIDE the
    ``shard_map``, so all devices iterate in lockstep driven purely by the
    psum-reduced halting scalars -- no per-iteration host sync exists even
    in principle.  The while_loop carry is ``(state, plan aux)``: the
    exchange plan's auxiliary state (e.g. delta's label mirror) never
    leaves the device either.
    """
    sg, plan, step_fn, args, arg_specs, n_score = _sharded_parts(
        graph, cfg, mesh, axis, score_fn)
    max_iters = cfg.max_iters

    def cond_fn(carry):
        s = carry[0]
        return jnp.logical_and(jnp.logical_not(s.halted),
                               s.iteration < max_iters)

    def run_local(state, deg_l, *rest):
        # per-device blocks arrive with a leading length-1 shard dim
        blocks = tuple(r[0] for r in rest)
        score_blocks, plan_blocks = blocks[:n_score], blocks[n_score:]
        dl = deg_l[0]
        aux0 = plan.init_aux(state.labels, axis, *plan_blocks)

        def body(carry):
            s, aux = carry
            return step_fn(s, aux, dl, score_blocks, plan_blocks)

        state, _ = jax.lax.while_loop(cond_fn, body, (state, aux0))
        return state

    spec = state_partition_spec(axis)
    run = jax.jit(shard_map(
        run_local, mesh=mesh, in_specs=(spec,) + arg_specs,
        out_specs=spec, check_rep=False))

    def runner(state: SpinnerState) -> SpinnerState:
        return run(state, *args)

    return runner


def pad_labels(labels: jax.Array, v_pad: int) -> jax.Array:
    """Extend labels to the sharded layout's padded vertex count."""
    labels = jnp.asarray(labels, jnp.int32)
    pad = v_pad - labels.shape[0]
    if pad:
        labels = jnp.concatenate([labels, jnp.zeros((pad,), jnp.int32)])
    return labels


def run_sharded(graph: Graph, cfg, labels, loads, key,
                mesh: Optional[Mesh] = None, axis: str = "data",
                score_fn: Optional[Callable] = None) -> SpinnerState:
    """Run to the stable state in one ``while_loop`` dispatch over ``mesh``.

    ``mesh=None`` uses a 1-D mesh over all local devices
    (``repro.launch.mesh.make_partition_mesh``).  The returned state
    carries PADDED labels (length ndev * ceil(V / ndev)); callers slice
    ``[:graph.num_vertices]``.  Compiled runners are cached per
    (graph, cfg, mesh, axis) -- meshes compare by value, so rebuilding an
    identical mesh reuses the compilation.
    """
    if mesh is None:
        mesh = _default_partition_mesh()
    ndev = mesh.shape[axis]
    if score_fn is not None:
        runner = make_sharded_runner(graph, cfg, mesh, axis, score_fn)
    else:
        runner = _graph_cached(
            _RUNNER_CACHE, graph, ("sharded", _cache_cfg(cfg), mesh, axis),
            lambda: make_sharded_runner(graph, cfg, mesh, axis))
    v_pad = -(-graph.num_vertices // ndev) * ndev
    return runner(init_state(pad_labels(labels, v_pad), loads, key))
