"""Device-resident Spinner LPA engine (program / bind / runner layering).

The legacy driver in ``spinner.py`` round-trips to the host every iteration
(``float(score_g)`` sync, host PRNG splitting, per-iteration numpy history),
so on small graphs wall-clock is dominated by dispatch latency rather than
the ComputeScores kernel.  This module keeps the whole run on device, and --
since PR 4 -- separates WHAT is compiled from WHICH graph it runs on:

  * ``SpinnerState`` -- a pure functional pytree carrying everything one LPA
    iteration reads or writes: labels, loads, the PRNG key, the Eq. 9
    halting aggregates (best_score / stall), iteration counter, and the
    migration statistics of the last step.
  * **Programs** -- jitted executables cached GLOBALLY per static
    configuration (``_PROGRAM_CACHE``): the paper parameters that enter
    the trace (k, eps, halt_window, max_iters, weighting, noise
    amplitudes), the score-backend signature, and -- for the sharded
    runner -- the mesh, axis and exchange-plan signature.  A program
    closes over NO graph data; every per-graph array arrives as a traced
    argument, so two graphs with the same compile shapes share one
    executable and a run on a new graph costs an upload, not a compile.
  * **Binds** (``GraphBind``) -- the per-graph argument pytree: weighted
    degrees, the Eq. 5 capacity C and the real vertex count as traced
    scalars, the score backend's edge arrays, and (for the chunked
    history) the raw edge list.  Padding vertices/edges introduced by the
    shape-bucket layer (``graph.pad_graph``; see ``repro.core.session``)
    are masked out of every migration/halting aggregate by a ``valid``
    mask derived from the traced real-vertex count.
  * ``run_fused`` -- the entire run as a single ``jax.lax.while_loop``
    dispatch; ``run_chunked`` -- ``chunk_size`` iterations per dispatch
    with fixed-size on-device history; ``run_sharded`` -- the fused loop
    over a DEVICE MESH in ONE ``shard_map(lax.while_loop)`` dispatch,
    with (k,) aggregates psum-reduced in the step, the halting decision
    on device, and a pluggable per-iteration label exchange
    (``repro.core.comm``: all-gather oracle / boundary halo / Figure 7
    delta), wire bytes accumulated in ``SpinnerState.exchanged_bytes``.
    Under ``EngineOptions.overlap`` the sharded step splits each edge
    shard at ``ShardedGraph.e_interior`` and reschedules to
    start_exchange -> score_interior -> finish_exchange ->
    score_frontier, overlapping the collective with the
    exchange-independent majority of ComputeScores -- bit-identical
    to the sequential schedule.
    All runners share ``make_vertex_update`` (Eqs. 7-8, 11-12) and
    ``_halting_update``, so for one padded layout every engine walks the
    same trajectory bit for bit.

``EngineOptions`` is the runtime half of the old ``SpinnerConfig``: engine
choice, mesh/axis, score backend, exchange plan, chunking and the shape-pad
policy.  ``repro.core.session.PartitionSession`` owns a (graph, cfg,
options) triple and drives these programs across a stream of
partition/adapt/resize calls; ``spinner.partition`` opens a throwaway
session, so one-shot calls and long-lived sessions execute the exact same
compiled programs.
"""
from __future__ import annotations

import weakref
import dataclasses
from typing import (Callable, List, NamedTuple, Optional, Sequence, Tuple,
                    Union)

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec

from .graph import Graph, pad_graph, shape_bucket

DEFAULT_CHUNK = 32

# Shape-bucket floors: graphs below these sizes all share one bucket.
V_FLOOR = 64
E_FLOOR = 128

# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------
# Programs are cached globally by STATIC configuration -- they hold no graph
# data, so entries are small (a jitted callable) and survive their graphs.
# Everything graph-shaped lives in weakref-guarded per-graph caches keyed on
# id(graph) + a suffix, evicted when the graph dies so a recycled id() can
# never alias.

_PROGRAM_CACHE: dict = {}     # static key -> Program
_SCORE_ARG_CACHE: dict = {}   # per graph/layout: backend edge-array uploads
_EDGE_UPLOAD_CACHE: dict = {} # per graph: (src, dst, weight, deg_w) on device
_PAD_CACHE: dict = {}         # per graph: (v_bucket, e_bucket) -> padded view


def _graph_cached(cache: dict, graph, suffix: tuple,
                  build: Callable[[], object]):
    """Memoize ``build()`` per (graph, suffix); evicted when graph dies."""
    key = (id(graph),) + suffix
    entry = cache.get(key)
    if entry is not None and entry[0]() is graph:
        return entry[1]
    value = build()
    cache[key] = (weakref.ref(graph, lambda _: cache.pop(key, None)), value)
    return value


@dataclasses.dataclass
class Program:
    """A compiled (shape-polymorphic) runner plus its cache identity."""

    run: Callable
    key: Optional[tuple] = None

    def compiles(self) -> int:
        """Number of traced/compiled entries behind this program."""
        size = getattr(self.run, "_cache_size", None)
        return int(size()) if size is not None else 0


# Each cached program retains its jit-compiled executables, so a config
# sweep must not grow the cache forever: FIFO-evict past the cap (live
# runners/sessions keep their own references; a re-request just
# rebuilds and recompiles).
_PROGRAM_CACHE_MAX = 128


def _program(key: tuple, build: Callable[[], Callable]) -> Program:
    prog = _PROGRAM_CACHE.get(key)
    if prog is None:
        while len(_PROGRAM_CACHE) >= _PROGRAM_CACHE_MAX:
            _PROGRAM_CACHE.pop(next(iter(_PROGRAM_CACHE)))
        prog = _PROGRAM_CACHE[key] = Program(run=build(), key=key)
    return prog


def _static_cfg(cfg) -> tuple:
    """The paper parameters that enter a program's trace.

    ``seed`` feeds host-side PRNGKey creation only and ``c`` only enters
    via the traced capacity scalar, so seed/slack sweeps share programs.
    """
    return (cfg.k, float(cfg.eps), cfg.halt_window, cfg.max_iters,
            cfg.migration_weighting, float(cfg.tie_noise),
            float(cfg.current_bonus))


# ---------------------------------------------------------------------------
# Engine options (the runtime half of the old SpinnerConfig)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EngineOptions:
    """How a Spinner run executes -- everything that is NOT a paper
    parameter: runner choice, device layout, score backend, exchange
    plan, chunking and the compile-shape policy.  ``SpinnerConfig`` keeps
    only the algorithm (Sections 3.1-3.5); the old config fields for
    these knobs survive as a deprecation shim (see ``repro.core.spinner``).

    ``pad="bucket"`` (default) runs every engine on a power-of-two-ish
    padded (V, E) layout (``graph.shape_bucket``), which is what lets a
    ``PartitionSession`` -- and the one-shot wrappers, which open
    throwaway sessions -- reuse one compiled program across all graphs
    in a bucket.  ``pad="none"`` keeps exact shapes (one compile per
    graph size, marginally less memory/compute per step).
    """

    engine: str = "auto"             # auto | fused | chunked | sharded | host
    chunk_size: Optional[int] = None
    mesh: Optional[Mesh] = None
    axis: str = "data"
    # ComputeScores backend: "xla" | "pallas" or a ScoreBackend instance.
    score_backend: Union[str, object] = "xla"
    # Sharded label exchange (repro.core.comm): "allgather" ships the full
    # label vector per iteration (the bit-compatible oracle), "halo" only
    # boundary labels, "delta" only changed labels (the Figure 7 decay).
    # All walk identical trajectories; "auto" picks allgather on 1 device
    # and delta on a real mesh.
    label_exchange: str = "auto"
    # Per-device compact-buffer capacity of the delta exchange (entries);
    # None = v_per_dev // 4.
    delta_cap: Optional[int] = None
    # "replicated" draws tie-break noise over the full padded vertex set
    # (bit parity with the single-device engines); "folded" draws only
    # the local shard from a device-folded key (O(V/ndev) memory).
    sharded_noise: str = "replicated"
    # Sharded step schedule.  "on" splits each device's edge shard at
    # ShardedGraph.e_interior and reschedules the step as start_exchange
    # -> score_interior -> finish_exchange -> score_frontier: only the
    # frontier segment depends on remote labels, so the label collective
    # and the interior scatter-add/matmul are dataflow-independent and
    # can run concurrently.  Bit-identical to "off" for every exchange
    # plan and score backend (integer edge weights make the f32 partial
    # sums exact under the segment split).  "auto" = on over a real
    # mesh, off on a single device (nothing to overlap).
    overlap: str = "auto"            # auto | on | off
    # Fused vertex update.  "on" asks the score backend for its
    # make_fused_update entry: the edge reduction, Eq. 7-8 normalization,
    # tie-noise argmax and migration bookkeeping run inside ONE kernel and
    # the (V_pad, k) score matrix never touches HBM (see
    # kernels/spinner_scores._fused_kernel); only the O(V + k) epilogue
    # (make_update_parts's ``finish``) runs as XLA ops.  Bit-identical to
    # "off" for every engine, exchange plan and overlap schedule (integer
    # Eq. 3 weights; same op order; same noise/u streams).  "auto" = on
    # iff the backend advertises ``fused_auto`` (the Pallas backend does;
    # XLA's scatter path gains nothing from fusing by hand).
    fused_update: str = "auto"       # auto | on | off
    # Tile autotuning for the Pallas backend: sweep the
    # kernels.autotune.CANDIDATES (tile_v, tile_e) configs against a
    # static roofline cost model of the actual degree distribution and
    # bind the winner (a dataclasses.replace of the backend, so it flows
    # into every program/arg cache key like any other backend).  The
    # choice is memoized per padded (V, E, k_pad, ndev) bucket -- the
    # first graph in a bucket decides -- so a session's warm same-bucket
    # adapt() never flips config and costs zero new compiles.  "auto"
    # tunes the registry default ("pallas" by name); explicit
    # PallasTiledBackend instances pin their tile config unless "on".
    autotune: str = "auto"           # auto | on | off
    pad: str = "bucket"              # bucket | none

    def resolved_label_exchange(self, ndev: int) -> str:
        from .comm import EXCHANGE_PLANS     # the one plan registry
        if self.label_exchange == "auto":
            return "allgather" if ndev == 1 else "delta"
        if self.label_exchange not in EXCHANGE_PLANS:
            raise ValueError(
                f"unknown label_exchange {self.label_exchange!r}; "
                f"available: auto, {', '.join(sorted(EXCHANGE_PLANS))}")
        return self.label_exchange

    def resolved_sharded_noise(self) -> str:
        if self.sharded_noise not in ("replicated", "folded"):
            raise ValueError(
                f"unknown sharded_noise {self.sharded_noise!r}; "
                "available: replicated, folded")
        return self.sharded_noise

    def resolved_overlap(self, ndev: int) -> str:
        if self.overlap == "auto":
            return "on" if ndev > 1 else "off"
        if self.overlap not in ("on", "off"):
            raise ValueError(f"unknown overlap {self.overlap!r}; "
                             "available: auto, on, off")
        return self.overlap

    def resolved_fused_update(self) -> str:
        if self.fused_update not in ("auto", "on", "off"):
            raise ValueError(f"unknown fused_update {self.fused_update!r}; "
                             "available: auto, on, off")
        if self.fused_update == "off":
            return "off"
        backend = self.backend()
        has = callable(getattr(backend, "make_fused_update", None))
        if self.fused_update == "auto":
            return "on" if (has and getattr(backend, "fused_auto", False)) \
                else "off"
        if not has:
            raise ValueError(
                f"score backend {getattr(backend, 'name', backend)!r} has "
                "no fused vertex-update entry (make_fused_update); use "
                "fused_update='auto'/'off' or a backend implementing the "
                "fused protocol")
        return "on"

    def resolved_autotune(self) -> str:
        if self.autotune not in ("auto", "on", "off"):
            raise ValueError(f"unknown autotune {self.autotune!r}; "
                             "available: auto, on, off")
        return self.autotune

    def backend(self):
        from repro.kernels import ops as kernel_ops   # lazy: no import cycle
        return kernel_ops.get_score_backend(self.score_backend)


_DEFAULT_OPTS = EngineOptions()
_UNPADDED_OPTS = EngineOptions(pad="none")


# ---------------------------------------------------------------------------
# State
# ---------------------------------------------------------------------------

class SpinnerState(NamedTuple):
    """Carry of the fused LPA loop -- one pytree, fully device-resident."""

    labels: jax.Array          # (V,) int32 current assignment
    loads: jax.Array           # (k,) float32 B(l) (Eq. 6), running update
    key: jax.Array             # PRNG key consumed by splitting each iter
    best_score: jax.Array      # f32 scalar, best score(G) so far (Eq. 9)
    stall: jax.Array           # int32, consecutive non-improving iterations
    iteration: jax.Array       # int32, iterations completed
    halted: jax.Array          # bool, eps/halt_window criterion fired
    total_messages: jax.Array  # f32, cumulative migrant degree mass
    score: jax.Array           # f32, score(G) after the last iteration
    migrations: jax.Array      # int32, migrating vertices last iteration
    message_mass: jax.Array    # f32, migrant degree mass last iteration
    exchanged_bytes: jax.Array # f32, cumulative label-exchange wire bytes
                               # (0 off the sharded engine; see core.comm)


def init_state(labels: jax.Array, loads: jax.Array,
               key: jax.Array) -> SpinnerState:
    return SpinnerState(
        labels=jnp.asarray(labels, jnp.int32),
        loads=jnp.asarray(loads, jnp.float32),
        key=key,
        best_score=jnp.float32(-jnp.inf),
        stall=jnp.int32(0),
        iteration=jnp.int32(0),
        halted=jnp.asarray(False),
        total_messages=jnp.float32(0.0),
        score=jnp.float32(0.0),
        migrations=jnp.int32(0),
        message_mass=jnp.float32(0.0),
        exchanged_bytes=jnp.float32(0.0),
    )


class GraphBind(NamedTuple):
    """Per-graph traced arguments of the single-device programs.

    Uploaded/derived once per (graph, backend, pad policy) and passed to
    the program on every call -- the program itself never closes over
    them, which is what makes compile reuse across graphs possible.
    """

    deg_w: jax.Array           # (V_pad,) f32 weighted degrees (0 on pads)
    capacity: jax.Array        # f32 scalar C (Eq. 5) of the REAL graph
    num_real: jax.Array        # int32 scalar: vertices < num_real are real
    score: tuple               # score backend's edge arrays
    hist: tuple = ()           # (src, dst, w, ideal, real_e) for history
    frontier: tuple = ()       # (src, dst) COO expansion index, frontier mode


# ---------------------------------------------------------------------------
# Shape-bucketed padded views
# ---------------------------------------------------------------------------

def graph_buckets(graph: Graph) -> Tuple[int, int]:
    """(vertex bucket, edge bucket) the graph's compile shapes land in."""
    return (shape_bucket(graph.num_vertices, V_FLOOR),
            shape_bucket(graph.num_directed_entries, E_FLOOR))


def padded_view(graph: Graph, opts: EngineOptions) -> Tuple[Graph, int]:
    """(padded graph, real vertex count) under the options' pad policy.

    The padded view is cached per (graph, buckets) and dies with the
    graph; with ``pad="none"`` the graph itself is returned.
    """
    if opts.pad == "none":
        return graph, graph.num_vertices
    if opts.pad != "bucket":
        raise ValueError(f"unknown pad policy {opts.pad!r}; "
                         "available: bucket, none")
    vb, eb = graph_buckets(graph)
    padded = _graph_cached(_PAD_CACHE, graph, (vb, eb),
                           lambda: pad_graph(graph, vb, eb))
    return padded, graph.num_vertices


def device_edges(graph: Graph):
    """(src, dst, weight, deg_w) as device arrays, uploaded once per Graph.

    Shared by every runner variant and the XLA score backend: a config
    sweep over one graph would otherwise hold one 2*E copy of
    src/dst/weight per variant.
    """
    return _graph_cached(
        _EDGE_UPLOAD_CACHE, graph, (),
        lambda: (jnp.asarray(graph.src), jnp.asarray(graph.dst),
                 jnp.asarray(graph.weight), jnp.asarray(graph.deg_w)))


def pad_labels(labels: jax.Array, v_pad: int) -> jax.Array:
    """Extend labels to a padded vertex count (pads land on partition 0;
    they are masked out of every aggregate and never migrate)."""
    labels = jnp.asarray(labels, jnp.int32)
    pad = v_pad - labels.shape[0]
    if pad:
        labels = jnp.concatenate([labels, jnp.zeros((pad,), jnp.int32)])
    return labels


def _single_bind(graph: Graph, cfg, opts: EngineOptions,
                 hist: bool = False,
                 score_fn: Optional[Callable] = None,
                 frontier: bool = False
                 ) -> Tuple[GraphBind, Graph]:
    """Build (or fetch cached pieces of) the bind for a one-device run."""
    padded, num_real = padded_view(graph, opts)
    deg_w = device_edges(padded)[3]
    if score_fn is not None:
        score_args = ()
    else:
        backend = opts.backend()
        pad = opts.pad == "bucket"
        fused = opts.resolved_fused_update() == "on"
        args_of = backend.fused_graph_args if fused else backend.graph_args
        score_args = _graph_cached(
            _SCORE_ARG_CACHE, padded,
            ("single", backend.signature(), pad, fused),
            lambda: tuple(args_of(padded, cfg.k, pad=pad)))
    if hist and graph.src.size:
        src, dst, w, _ = device_edges(padded)
        hist_args = (src, dst, w,
                     jnp.float32(graph.total_weight / cfg.k),
                     jnp.float32(graph.num_directed_entries))
    else:
        hist_args = ()
    # The padded COO (cached upload) doubles as the frontier expansion
    # index: pad entries are weight-0 self-loops on pad vertices, which
    # never change label, so they can never activate anything.
    frontier_args = device_edges(padded)[:2] if frontier else ()
    return GraphBind(deg_w=deg_w,
                     capacity=jnp.float32(cfg.capacity(graph)),
                     num_real=jnp.int32(num_real),
                     score=score_args, hist=hist_args,
                     frontier=frontier_args), padded


def _autotuned(graph: Graph, cfg, opts: EngineOptions,
               ndev: int = 1) -> EngineOptions:
    """Options with the tile autotuner's (tile_v, tile_e) choice applied.

    Only the Pallas backend is tunable; the winner is bound by
    ``dataclasses.replace`` on the backend instance, so it flows into
    ``signature()`` and thence every program / score-arg cache key -- an
    autotuned config is cached exactly like a hand-picked one.  The
    choice is memoized per padded (V, E, k_pad, ndev) shape
    (``kernels.autotune``), so every graph in a shape bucket resolves to
    ONE config and warm session rebinds stay compile-free.  Under
    ``autotune="auto"`` explicit backend INSTANCES are left alone (they
    pin their tile config); ``"on"`` tunes those too.
    """
    mode = opts.resolved_autotune()
    if mode == "off":
        return opts
    if mode == "auto" and not isinstance(opts.score_backend, str):
        return opts
    backend = opts.backend()
    if getattr(backend, "name", None) != "pallas":
        return opts
    from repro.kernels import autotune as _tune   # lazy: no import cycle
    padded, _ = padded_view(graph, opts)
    tile_v, tile_e, _kp = _tune.choose_tile_config(padded, cfg.k, ndev=ndev)
    if (tile_v, tile_e) == (backend.tile_v, backend.tile_e):
        return opts
    return dataclasses.replace(opts, score_backend=dataclasses.replace(
        backend, tile_v=tile_v, tile_e=tile_e))


# ---------------------------------------------------------------------------
# The iteration math (shared verbatim by every engine)
# ---------------------------------------------------------------------------

def make_update_parts(k: int, *, degree_weighted: bool,
                      current_bonus: float) -> Tuple[Callable, Callable]:
    """The vertex update split at its one global synchronization point.

    ``propose(scores, labels, deg_w, loads, noise, valid, C)`` is the
    per-vertex half -- Eq. 7-8 normalization, penalty, current-label
    bonus and tie-noise argmax plus the local migration-candidate mass
    partial -- returning ``(best, tot_best, tot_cur, m_partial)``:
    the proposed label, the Eq. 8 total at the proposal and at the
    current label, and the un-reduced (k,) M(l) contribution.  A fused
    score backend computes these INSIDE its kernel (the (V, k) score
    matrix never materializes); this reference form shares its exact op
    sequence so the two are bit-identical.

    ``finish(best, tot_best, tot_cur, m_partial, labels, deg_w, loads,
    u, valid, reduce_, C)`` is the epilogue that needs the globally
    reduced M(l): the Eq. 11-12 probability test, the load delta, and
    the score(G)/migration aggregates.  O(V + k) -- no (V, k) operand.

    ``reduce_`` is identity on a single device and ``lax.psum`` under
    ``shard_map`` (the Giraph sharded aggregators as one collective
    each); ``valid`` masks padding vertices (``None`` statically skips
    the masking ops).
    """

    def propose(scores, labels, deg_w, loads, noise, valid, C):
        # ---- ComputeScores (Eq. 8) -------------------------------------
        norm = scores / jnp.maximum(deg_w, 1.0)[:, None]
        penalty = loads / C                                # pi(l) (Eq. 7)
        total = norm - penalty[None, :]
        bonus = current_bonus * jax.nn.one_hot(labels, k,
                                               dtype=jnp.float32)
        best = jnp.argmax(total + noise + bonus, axis=1).astype(jnp.int32)
        want = best != labels
        if valid is not None:
            want = want & valid
        measure = deg_w if degree_weighted else jnp.ones_like(deg_w)
        m_partial = jnp.zeros((k,), jnp.float32).at[best].add(
            jnp.where(want, measure, 0.0))
        tot_best = jnp.take_along_axis(total, best[:, None], axis=1)[:, 0]
        tot_cur = jnp.take_along_axis(total, labels[:, None],
                                      axis=1)[:, 0]
        return best, tot_best, tot_cur, m_partial

    def finish(best, tot_best, tot_cur, m_partial, labels, deg_w, loads,
               u, valid, reduce_, C):
        want = best != labels
        if valid is not None:
            want = want & valid

        # ---- ComputeMigrations (Eq. 11-12) -----------------------------
        M = reduce_(m_partial)                             # aggregator
        R = jnp.maximum(C - loads, 0.0)                    # Eq. 11
        p = jnp.clip(R / jnp.maximum(M, 1e-9), 0.0, 1.0)   # Eq. 12
        migrate = want & (u < p[best])

        new_labels = jnp.where(migrate, best, labels)
        mig_deg = jnp.where(migrate, deg_w, 0.0)
        delta = (jnp.zeros((k,), jnp.float32)
                 .at[best].add(mig_deg)
                 .at[labels].add(-mig_deg))
        new_loads = loads + reduce_(delta)                 # aggregator

        # ---- halting aggregate: score(G) at the new assignment (Eq. 9) --
        # total[v, new_labels[v]] == tot_best where migrating else tot_cur
        sel = jnp.where(migrate, tot_best, tot_cur)
        if valid is not None:
            sel = jnp.where(valid, sel, 0.0)
        score_g = reduce_(jnp.sum(sel))                    # aggregator
        # migration mass = sum of migrant degrees = Pregel messages sent
        # (each migrating vertex notifies all neighbors, Section 4.1.3)
        n_mig = reduce_(jnp.sum(migrate).astype(jnp.int32))
        mig_mass = reduce_(jnp.sum(mig_deg))
        return new_labels, new_loads, score_g, n_mig, mig_mass

    return propose, finish


def make_vertex_update(cfg) -> Callable:
    """The per-vertex two-phase update (Eqs. 7-8, 11-12) as a pure function.

    Shared verbatim by the single-device iteration and the per-shard
    sharded iteration, which is what makes every engine an oracle of the
    others.  The caller supplies whatever slice of the vertex set it owns
    plus the matching noise/u draws and the Eq. 5 capacity ``C`` (a
    traced scalar, so graph growth never forces a recompile).  Composed
    from ``make_update_parts`` -- the same two halves a fused score
    backend splits across its kernel and the XLA epilogue -- so the
    dense-scores and fused paths walk identical trajectories.

    ``valid`` masks padding vertices introduced by the shape-bucket /
    sharded layouts; pads never migrate and contribute nothing to any
    aggregate.  (``None`` statically skips the masking ops.  Tie-break
    noise is drawn over the padded set, so trajectories are
    deterministic PER padded layout -- see ``graph.pad_graph``.)
    """
    propose, finish = make_update_parts(
        cfg.k, degree_weighted=cfg.migration_weighting == "edges",
        current_bonus=cfg.current_bonus)

    def update(scores, labels, deg_w, loads, noise, u, valid, reduce_, C):
        best, tot_best, tot_cur, m_partial = propose(
            scores, labels, deg_w, loads, noise, valid, C)
        return finish(best, tot_best, tot_cur, m_partial, labels, deg_w,
                      loads, u, valid, reduce_, C)

    return update


def _halting_update(best_score, stall, score_g, eps, halt_window):
    """Section 3.3 stall logic on device, mirroring the host loop exactly.

    On the first iteration best_score is -inf, so tol is inf and
    ``best + tol`` is NaN: the comparison is False and the iteration counts
    toward the stall window -- the same (intentional) behaviour as the
    legacy host loop's float arithmetic.
    """
    tol = eps * jnp.maximum(jnp.float32(1.0), jnp.abs(best_score))
    improved = score_g > best_score + tol
    new_best = jnp.maximum(best_score, score_g)
    new_stall = jnp.where(improved, jnp.int32(0), stall + 1)
    return new_best, new_stall, new_stall >= halt_window


def _bind_iterate(cfg, scores_fn: Callable, fused: bool = False) -> Callable:
    """One LPA iteration in bind-argument form (graph data as arguments).

    ``iterate(labels, loads, key, bind) -> (labels, loads, score_g,
    n_migrations, migration_mass)``.  Noise/u are drawn over the padded
    vertex set, so for a fixed padded layout the host loop, the fused
    runner and a 1-device sharded mesh consume identical streams.

    With ``fused=True``, ``scores_fn`` is the backend's whole-update
    closure (``make_fused_update``): it consumes the same noise/u/valid
    arrays and returns the iteration outputs directly -- the (V_pad, k)
    score matrix never materializes.
    """
    k, tie = cfg.k, cfg.tie_noise
    update = None if fused else make_vertex_update(cfg)

    def iterate(labels, loads, key, bind: GraphBind):
        v_pad = labels.shape[0]
        k_noise, k_mig = jax.random.split(key)
        noise = jax.random.uniform(k_noise, (v_pad, k), jnp.float32,
                                   0.0, tie)
        u = jax.random.uniform(k_mig, (v_pad,), jnp.float32)
        valid = jnp.arange(v_pad, dtype=jnp.int32) < bind.num_real
        if fused:
            return scores_fn(labels, labels, bind.deg_w, loads, noise, u,
                             valid, lambda x: x, bind.capacity,
                             *bind.score)
        scores = scores_fn(labels, *bind.score)            # (V_pad, k) f32
        return update(scores, labels, bind.deg_w, loads, noise, u, valid,
                      lambda x: x, bind.capacity)

    return iterate


def _bind_step(cfg, scores_fn: Callable, fused: bool = False) -> Callable:
    """Jittable ``(SpinnerState, GraphBind) -> SpinnerState`` transition."""
    iterate = _bind_iterate(cfg, scores_fn, fused)
    eps = jnp.float32(cfg.eps)
    halt_window = cfg.halt_window

    def step_fn(state: SpinnerState, bind: GraphBind) -> SpinnerState:
        key, k_it = jax.random.split(state.key)
        labels, loads, score_g, n_mig, mig_mass = iterate(
            state.labels, state.loads, k_it, bind)
        best, stall, halted = _halting_update(
            state.best_score, state.stall, score_g, eps, halt_window)
        return SpinnerState(
            labels=labels, loads=loads, key=key,
            best_score=best, stall=stall,
            iteration=state.iteration + 1, halted=halted,
            total_messages=state.total_messages + mig_mass,
            score=score_g, migrations=n_mig, message_mass=mig_mass,
            exchanged_bytes=state.exchanged_bytes)

    return step_fn


def _update_for(cfg, opts: EngineOptions, score_fn: Optional[Callable]
                ) -> Tuple[Callable, tuple, bool]:
    """(traced closure, static signature, fused?) for single-device runs.

    Non-fused: the backend's ``make_scores`` closure (or a custom
    ``score_fn``, which is single-phase dense by contract and therefore
    pins fused off).  Fused: the backend's ``make_fused_update`` whole-
    iteration closure.  The fused flag is part of every program cache
    key, so the two paths never share an executable.
    """
    if score_fn is not None:
        return (lambda labels, *unused: score_fn(labels)), ("custom",), False
    backend = opts.backend()
    if opts.resolved_fused_update() == "on":
        fn = backend.make_fused_update(
            cfg.k, degree_weighted=cfg.migration_weighting == "edges",
            current_bonus=float(cfg.current_bonus))
        return fn, backend.signature(), True
    return backend.make_scores(cfg.k), backend.signature(), False


# ---------------------------------------------------------------------------
# Single-device programs
# ---------------------------------------------------------------------------

def _iterate_program(cfg, opts, score_fn=None) -> Program:
    """``run(labels, loads, key, bind)`` -- the host loop's jitted step."""
    scores_fn, sig, fused = _update_for(cfg, opts, score_fn)

    def build():
        return jax.jit(_bind_iterate(cfg, scores_fn, fused))

    if score_fn is not None:
        return Program(run=build())
    return _program(("iterate", _static_cfg(cfg), sig, fused), build)


def _state_step_program(cfg, opts, score_fn=None) -> Program:
    """``run(state, bind)`` -- one state transition (make_step_fn)."""
    scores_fn, sig, fused = _update_for(cfg, opts, score_fn)

    def build():
        return jax.jit(_bind_step(cfg, scores_fn, fused))

    if score_fn is not None:
        return Program(run=build())
    return _program(("state_step", _static_cfg(cfg), sig, fused), build)


def _fused_program(cfg, opts, score_fn=None) -> Program:
    """``run(state, bind)`` -- the whole run as one while_loop dispatch."""
    scores_fn, sig, fused = _update_for(cfg, opts, score_fn)
    max_iters = cfg.max_iters

    def build():
        step_fn = _bind_step(cfg, scores_fn, fused)

        def cond_fn(s: SpinnerState):
            return jnp.logical_and(jnp.logical_not(s.halted),
                                   s.iteration < max_iters)

        @jax.jit
        def run(state: SpinnerState, bind: GraphBind) -> SpinnerState:
            return jax.lax.while_loop(cond_fn, lambda s: step_fn(s, bind),
                                      state)

        return run

    if score_fn is not None:
        return Program(run=build())
    return _program(("fused", _static_cfg(cfg), sig, fused), build)


def _chunked_program(cfg, opts, chunk_size: int, record: bool,
                     has_edges: bool, score_fn=None) -> Program:
    """``run(state, bind) -> (state, records)`` -- one guarded scan chunk."""
    scores_fn, sig, fused = _update_for(cfg, opts, score_fn)
    max_iters = cfg.max_iters

    def build():
        step_fn = _bind_step(cfg, scores_fn, fused)

        @jax.jit
        def run(state: SpinnerState, bind: GraphBind):
            def body(state, _):
                active = jnp.logical_and(jnp.logical_not(state.halted),
                                         state.iteration < max_iters)
                new_state = jax.lax.cond(active,
                                         lambda s: step_fn(s, bind),
                                         lambda s: s, state)
                if not record:
                    return new_state, {"valid": active}
                if has_edges:
                    src, dst, w, ideal, real_e = bind.hist
                    # count only real edges: pads are weight-0 self-loops
                    local = (new_state.labels[src] == new_state.labels[dst]
                             ) & (w > 0)
                    phi = jnp.sum(local.astype(jnp.float32)) / real_e
                    rho = jnp.max(new_state.loads) / ideal
                else:
                    # edgeless graph: mirror metrics.rho's ideal<=0
                    # convention (rho = 1)
                    phi = jnp.float32(1.0)
                    rho = jnp.float32(1.0)
                rec = {
                    "iteration": new_state.iteration,
                    "score": new_state.score,
                    "migrations": new_state.migrations,
                    "message_mass": new_state.message_mass,
                    "phi": phi,
                    "rho": rho,
                    "valid": active,
                }
                return new_state, rec

            return jax.lax.scan(body, state, None, length=chunk_size)

        return run

    if score_fn is not None:
        return Program(run=build())
    return _program(("chunked", _static_cfg(cfg), sig, fused, chunk_size,
                     record, has_edges), build)


# ---------------------------------------------------------------------------
# Frontier mode: dirty-set LPA reconvergence (delta-proportional compute)
# ---------------------------------------------------------------------------
# After a small edge delta on a converged partition, only the endpoints of
# changed edges can want to move -- and migrations propagate label changes
# one hop per iteration.  Frontier mode exploits that: the step scores only
# the ACTIVE vertex set (valid &= active), expands it along edges out of
# vertices that changed label, and halts when no active vertex wants to
# move.  Inactive vertices keep their labels and contribute nothing to any
# aggregate, so under the fused Pallas backend whole tiles without active
# vertices skip their edge reduction entirely (the tile-activity bitmap in
# kernels/spinner_scores); the XLA backend keeps dense compute but the same
# masked semantics.  On a base labeling that is a fixed point robust to the
# delta's load perturbation the final labels are bit-identical to a full
# re-adapt (the oracle); the per-iteration scored-vertex counts come back
# as a (max_iters,) history for sub-linearity reporting.


def _frontier_update_for(cfg, opts: EngineOptions
                         ) -> Tuple[Callable, tuple, bool]:
    """(traced closure, signature, fused?) for frontier-mode runs.

    The fused form asks the backend for its ``frontier=True`` variant,
    which additionally returns the post-proposal ``want`` mask (the
    drain-halting signal) and -- for the Pallas backend -- skips tiles
    with no active vertex.
    """
    backend = opts.backend()
    if opts.resolved_fused_update() == "on":
        fn = backend.make_fused_update(
            cfg.k, degree_weighted=cfg.migration_weighting == "edges",
            current_bonus=float(cfg.current_bonus), frontier=True)
        return fn, backend.signature(), True
    return backend.make_scores(cfg.k), backend.signature(), False


def _bind_frontier_step(cfg, scores_fn: Callable, fused: bool) -> Callable:
    """One frontier-mode LPA iteration over ``(state, active, hist)``.

    Identical update math to ``_bind_step`` except ``valid`` is
    additionally masked by the active set, halting is drain-based
    (no active vertex wants to move) rather than score-stall, and the
    active set for the next iteration is ``want | touched`` where
    ``touched`` marks endpoints of edges whose other endpoint changed
    label this iteration.  Noise/u are still drawn over the FULL padded
    vertex set, so on a converged base the frontier trajectory replays
    the oracle's migration decisions bit for bit.
    """
    k, tie = cfg.k, cfg.tie_noise
    eps = jnp.float32(cfg.eps)
    halt_window = cfg.halt_window
    propose, finish = make_update_parts(
        k, degree_weighted=cfg.migration_weighting == "edges",
        current_bonus=cfg.current_bonus)

    def step_fn(carry, bind: GraphBind):
        state, active, hist = carry
        key, k_it = jax.random.split(state.key)
        v_pad = state.labels.shape[0]
        k_noise, k_mig = jax.random.split(k_it)
        noise = jax.random.uniform(k_noise, (v_pad, k), jnp.float32,
                                   0.0, tie)
        u = jax.random.uniform(k_mig, (v_pad,), jnp.float32)
        valid = (jnp.arange(v_pad, dtype=jnp.int32) < bind.num_real) \
            & active
        if fused:
            labels, loads, score_g, n_mig, mig_mass, want = scores_fn(
                state.labels, state.labels, bind.deg_w, state.loads,
                noise, u, valid, lambda x: x, bind.capacity, *bind.score)
        else:
            scores = scores_fn(state.labels, *bind.score)
            best, tot_best, tot_cur, m_partial = propose(
                scores, state.labels, bind.deg_w, state.loads, noise,
                valid, bind.capacity)
            want = (best != state.labels) & valid
            labels, loads, score_g, n_mig, mig_mass = finish(
                best, tot_best, tot_cur, m_partial, state.labels,
                bind.deg_w, state.loads, u, valid, lambda x: x,
                bind.capacity)
        src, dst = bind.frontier
        changed = (labels != state.labels).astype(jnp.int32)
        touched = jnp.zeros((v_pad,), jnp.int32).at[src].max(
            changed[dst]) > 0
        hist = hist.at[state.iteration].set(
            jnp.sum(valid.astype(jnp.float32)))
        best_s, stall, _ = _halting_update(
            state.best_score, state.stall, score_g, eps, halt_window)
        new_state = SpinnerState(
            labels=labels, loads=loads, key=key,
            best_score=best_s, stall=stall,
            iteration=state.iteration + 1,
            halted=jnp.sum(want.astype(jnp.int32)) == 0,
            total_messages=state.total_messages + mig_mass,
            score=score_g, migrations=n_mig, message_mass=mig_mass,
            exchanged_bytes=state.exchanged_bytes)
        return new_state, want | touched, hist

    return step_fn


def _frontier_program(cfg, opts: EngineOptions) -> Program:
    """``run(state, active, bind) -> (state, scored_hist)``: the frontier
    loop as one while_loop dispatch.  ``scored_hist`` is the (max_iters,)
    per-iteration count of scored (valid & active) vertices, 0 past the
    final iteration."""
    scores_fn, sig, fused = _frontier_update_for(cfg, opts)
    max_iters = cfg.max_iters

    def build():
        step_fn = _bind_frontier_step(cfg, scores_fn, fused)

        def cond_fn(carry):
            s = carry[0]
            return jnp.logical_and(jnp.logical_not(s.halted),
                                   s.iteration < max_iters)

        @jax.jit
        def run(state: SpinnerState, active, bind: GraphBind):
            hist0 = jnp.zeros((max_iters,), jnp.float32)
            state, _, hist = jax.lax.while_loop(
                cond_fn, lambda c: step_fn(c, bind),
                (state, active, hist0))
            return state, hist

        return run

    return _program(("frontier", _static_cfg(cfg), sig, fused), build)


def make_frontier_runner(graph: Graph, cfg,
                         opts: EngineOptions = _DEFAULT_OPTS) -> Callable:
    """``runner(state, active) -> (state, scored_hist)`` over the padded
    layout; accepts state/active over the REAL vertex set."""
    opts = _autotuned(graph, cfg, opts)
    bind, padded = _single_bind(graph, cfg, opts, frontier=True)
    prog = _frontier_program(cfg, opts)
    v_pad, num_real = padded.num_vertices, graph.num_vertices

    def runner(state: SpinnerState, active):
        state = state._replace(labels=pad_labels(state.labels, v_pad))
        active = jnp.asarray(active, jnp.bool_)
        pad = v_pad - active.shape[0]
        if pad:
            active = jnp.concatenate(
                [active, jnp.zeros((pad,), jnp.bool_)])
        out, hist = prog.run(state, active, bind)
        return out._replace(labels=out.labels[:num_real]), hist

    runner.program = prog
    runner.v_pad = v_pad
    return runner


def run_frontier(graph: Graph, cfg, labels, loads, key, active,
                 opts: EngineOptions = _DEFAULT_OPTS,
                 on_program: Optional[Callable] = None):
    """Frontier-mode run to drain: ``(state, scored_hist)``."""
    runner = make_frontier_runner(graph, cfg, opts)
    if on_program is not None:
        on_program(runner.program)
    return runner(init_state(labels, loads, key), active)


# ---------------------------------------------------------------------------
# On-device delta merge programs (the adapt(edge_updates=...) fast path)
# ---------------------------------------------------------------------------

def _merge_program() -> Program:
    """``run(set_groups, add_groups)``: scatter a delta batch into resident
    device arrays.

    ``set_groups`` is a tuple of ``(arrays, idx, vals)`` where every array
    in ``arrays`` receives ``vals[i]`` at the shared flat slots ``idx``
    (the slack/filler slots of a padded edge layout); ``add_groups`` is a
    tuple of ``(array, idx, inc)`` flat scatter-adds (per-vertex degree
    updates).  Batches are shape-bucketed by the caller with
    out-of-range sentinel indices, which ``mode="drop"`` discards -- so
    one compiled entry serves every batch in a size bucket.
    """

    def build():
        @jax.jit
        def run(set_groups, add_groups):
            merged = tuple(
                tuple(a.reshape(-1).at[idx].set(v, mode="drop")
                      .reshape(a.shape) for a, v in zip(arrays, vals))
                for arrays, idx, vals in set_groups)
            bumped = tuple(
                a.reshape(-1).at[idx].add(inc, mode="drop").reshape(a.shape)
                for a, idx, inc in add_groups)
            return merged, bumped

        return run

    return _program(("delta_merge",), build)


def _loads_program(k: int) -> Program:
    """``run(labels, deg_w) -> (k,) loads``: compute_loads on device.

    Bit-identical to ``spinner.compute_loads`` over the real graph: pads
    carry zero degree, and the integer-valued f32 degrees make the
    scatter-add exact under any ordering.
    """

    def build():
        @jax.jit
        def run(labels, deg_w):
            return jnp.zeros((k,), jnp.float32).at[labels.reshape(-1)].add(
                deg_w.reshape(-1))

        return run

    return _program(("delta_loads", k), build)


# ---------------------------------------------------------------------------
# Batched multi-graph programs (the serving tier's same-bucket executor)
# ---------------------------------------------------------------------------

def batch_bucket(n: int) -> int:
    """Power-of-two batch-size bucket (1, 2, 4, 8, ...): a fleet whose
    size wobbles between dispatch rounds keeps hitting the same compiled
    batched program instead of tracing one per batch size."""
    n = max(int(n), 1)
    return 1 << (n - 1).bit_length()


def stack_states(states: Sequence[SpinnerState]) -> SpinnerState:
    """Stack per-tenant states along a new leading batch dimension."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)


def stack_binds(binds: Sequence[GraphBind]) -> GraphBind:
    """Stack same-shaped GraphBinds along a new leading batch dimension.

    Requires identical tree structure and leaf shapes -- i.e. the graphs
    share a padded (V, E) shape bucket and score-backend signature (see
    ``batch_signature``).
    """
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *binds)


def index_state(states: SpinnerState, i: int) -> SpinnerState:
    """Slice element ``i`` back out of a stacked batch of states."""
    return jax.tree_util.tree_map(lambda x: x[i], states)


def batch_signature(cfg, opts: EngineOptions, bind: GraphBind) -> tuple:
    """Stackability key: two (cfg, opts, bind) triples with equal keys
    resolve to the same batched program and stack leaf-for-leaf."""
    shapes = tuple((tuple(x.shape), str(x.dtype))
                   for x in jax.tree_util.tree_leaves(bind))
    return (_static_cfg(cfg), opts.backend().signature(),
            opts.resolved_fused_update() == "on", shapes)


def _batched_program(cfg, opts: EngineOptions, nb: int) -> Program:
    """``run(states, binds) -> states``: ``nb`` independent fused runs as
    ONE while_loop dispatch over a leading batch dimension.

    Per-element semantics are exactly the unbatched fused program's: the
    loop continues while ANY element is still active, the shared step is
    ``vmap`` of the same ``_bind_step`` transition, and an element that
    has halted (or exhausted ``max_iters``) is frozen by a post-step
    select -- its state stops changing at precisely the iteration where
    its own ``while_loop`` would have exited, so every element's final
    state is bit-identical to running it alone (a batch of 1 is
    bit-identical to ``_fused_program``).
    """
    scores_fn, sig, fused = _update_for(cfg, opts, None)
    max_iters = cfg.max_iters

    def build():
        step_fn = _bind_step(cfg, scores_fn, fused)

        def active(s: SpinnerState):
            return jnp.logical_and(jnp.logical_not(s.halted),
                                   s.iteration < max_iters)

        v_active = jax.vmap(active)
        v_step = jax.vmap(step_fn)

        def body(states: SpinnerState, binds: GraphBind) -> SpinnerState:
            act = v_active(states)
            new = v_step(states, binds)

            def freeze(n, o):
                return jnp.where(act.reshape((nb,) + (1,) * (n.ndim - 1)),
                                 n, o)

            return jax.tree_util.tree_map(freeze, new, states)

        @jax.jit
        def run(states: SpinnerState, binds: GraphBind) -> SpinnerState:
            return jax.lax.while_loop(lambda s: jnp.any(v_active(s)),
                                      lambda s: body(s, binds), states)

        return run

    return _program(("batched", _static_cfg(cfg), sig, fused, nb), build)


def run_batched(items: Sequence[Tuple[SpinnerState, GraphBind]], cfg,
                opts: EngineOptions = _DEFAULT_OPTS,
                on_program: Optional[Callable] = None
                ) -> List[SpinnerState]:
    """Run independent same-shape ``(state, bind)`` fused work items as
    ONE batched device dispatch; returns each item's final state.

    All items must share one ``batch_signature`` (the serving scheduler
    groups tenants by it).  The batch size is rounded up to a power-of-
    two bucket; pad slots replicate item 0 pre-halted, so they are
    frozen from the very first cond evaluation and cost a vector lane,
    not a run.  States arrive and leave PADDED to the layout's vertex
    bucket (``adapt_parts``/``commit_adapt`` on the session handle the
    pad/slice).
    """
    nb_real = len(items)
    if nb_real == 0:
        return []
    nb = batch_bucket(nb_real)
    states = [s for s, _ in items]
    binds = [b for _, b in items]
    if nb > nb_real:
        pad_state = states[0]._replace(halted=jnp.asarray(True))
        states = states + [pad_state] * (nb - nb_real)
        binds = binds + [binds[0]] * (nb - nb_real)
    prog = _batched_program(cfg, opts, nb)
    if on_program is not None:
        on_program(prog)
    out = prog.run(stack_states(states), stack_binds(binds))
    return [index_state(out, i) for i in range(nb_real)]


# ---------------------------------------------------------------------------
# Single-device runners (legacy-compatible wrappers over programs)
# ---------------------------------------------------------------------------

def _pad_slice_runner(prog: Program, bind: GraphBind, padded: Graph,
                      num_real: int) -> Callable:
    """Wrap a (state, bind) program: pad labels in, slice real labels out."""
    v_pad = padded.num_vertices

    def runner(state: SpinnerState) -> SpinnerState:
        state = state._replace(labels=pad_labels(state.labels, v_pad))
        out = prog.run(state, bind)
        return out._replace(labels=out.labels[:num_real])

    runner.program = prog
    return runner


def make_host_step(graph: Graph, cfg, opts: EngineOptions = _UNPADDED_OPTS,
                   score_fn: Optional[Callable] = None) -> Callable:
    """``step(labels, loads, key)`` on the options' padded layout.

    Labels are carried PADDED between calls (the session's host driver
    slices for metrics only); ``step.v_pad`` / ``step.num_real`` describe
    the layout and ``step.program`` exposes the compiled program.  A
    custom ``score_fn`` closure is shaped to the real graph, so it
    forces ``pad="none"``.
    """
    if score_fn is not None:
        opts = dataclasses.replace(opts, pad="none")
    else:
        opts = _autotuned(graph, cfg, opts)
    bind, padded = _single_bind(graph, cfg, opts, score_fn=score_fn)
    prog = _iterate_program(cfg, opts, score_fn)

    def step(labels, loads, key):
        return prog.run(labels, loads, key, bind)

    step.program = prog
    step.v_pad = padded.num_vertices
    step.num_real = graph.num_vertices
    return step


def cached_jit_step(graph: Graph, cfg) -> Callable:
    """Jitted ``iterate(labels, loads, key)`` on the graph's exact shapes.

    The compiled program is shared globally per (cfg statics, backend),
    so repeated host-engine runs -- and config sweeps -- never re-trace.
    """
    return make_host_step(graph, cfg, _UNPADDED_OPTS)


def make_iteration(graph: Graph, cfg,
                   score_fn: Optional[Callable] = None) -> Callable:
    """One LPA iteration bound to ``graph`` (exact shapes, jitted)."""
    return make_host_step(graph, cfg, _UNPADDED_OPTS, score_fn)


def make_step_fn(graph: Graph, cfg,
                 score_fn: Optional[Callable] = None) -> Callable:
    """``SpinnerState -> SpinnerState`` bound to ``graph`` (exact shapes)."""
    bind, _ = _single_bind(graph, cfg, _UNPADDED_OPTS, score_fn=score_fn)
    prog = _state_step_program(cfg, _UNPADDED_OPTS, score_fn)

    def step_fn(state: SpinnerState) -> SpinnerState:
        return prog.run(state, bind)

    step_fn.program = prog
    return step_fn


def make_fused_runner(graph: Graph, cfg,
                      score_fn: Optional[Callable] = None,
                      opts: EngineOptions = _DEFAULT_OPTS) -> Callable:
    """``runner(state) -> state``: the full run as a single device call.

    Accepts a state over the REAL vertex set; padding to the options'
    shape bucket (and slicing back) happens inside, so callers never see
    the padded layout.  A custom ``score_fn`` closure is shaped to the
    real graph, so it forces ``pad="none"``.
    """
    if score_fn is not None:
        opts = dataclasses.replace(opts, pad="none")
    else:
        opts = _autotuned(graph, cfg, opts)
    bind, padded = _single_bind(graph, cfg, opts, score_fn=score_fn)
    prog = _fused_program(cfg, opts, score_fn)
    return _pad_slice_runner(prog, bind, padded, graph.num_vertices)


def run_fused(graph: Graph, cfg, labels, loads, key,
              score_fn: Optional[Callable] = None,
              opts: EngineOptions = _DEFAULT_OPTS,
              on_program: Optional[Callable] = None) -> SpinnerState:
    """Run to the stable state in one ``lax.while_loop`` dispatch.

    Compiled programs are cached globally per (cfg statics, backend) and
    reused across graphs sharing a shape bucket, so repeated runs --
    determinism checks, incremental adapt/resize restarts, session
    streams -- skip re-tracing entirely.
    """
    runner = make_fused_runner(graph, cfg, score_fn, opts)
    if on_program is not None:
        on_program(getattr(runner, "program", None))
    return runner(init_state(labels, loads, key))


def make_chunked_runner(graph: Graph, cfg, chunk_size: int = DEFAULT_CHUNK,
                        score_fn: Optional[Callable] = None,
                        record: bool = True,
                        opts: EngineOptions = _DEFAULT_OPTS) -> Callable:
    """Compile ``chunk_size`` iterations + history recording into one scan.

    Each scan step is guarded: once the halting criterion fires (or
    ``max_iters`` is reached) the state passes through unchanged and the
    record is marked invalid, so a trailing partial chunk costs nothing but
    pass-through work.  With ``record=False`` the per-iteration phi trace
    (an O(E) gather) is skipped and only the validity flags come back.
    A custom ``score_fn`` closure is shaped to the real graph, so it
    forces ``pad="none"``.
    """
    if score_fn is not None:
        opts = dataclasses.replace(opts, pad="none")
    else:
        opts = _autotuned(graph, cfg, opts)
    has_edges = graph.src.size > 0
    bind, padded = _single_bind(graph, cfg, opts,
                                hist=record and has_edges,
                                score_fn=score_fn)
    prog = _chunked_program(cfg, opts, chunk_size, record, has_edges,
                            score_fn)
    v_pad, num_real = padded.num_vertices, graph.num_vertices

    def run_chunk(state: SpinnerState):
        state = state._replace(labels=pad_labels(state.labels, v_pad))
        out, recs = prog.run(state, bind)
        return out._replace(labels=out.labels[:num_real]), recs

    run_chunk.program = prog
    return run_chunk


def run_chunked(graph: Graph, cfg, labels, loads, key,
                chunk_size: int = DEFAULT_CHUNK,
                score_fn: Optional[Callable] = None,
                callback: Optional[Callable[[int, dict], None]] = None,
                record: bool = True,
                opts: EngineOptions = _DEFAULT_OPTS,
                on_program: Optional[Callable] = None,
                ) -> Tuple[SpinnerState, List[dict]]:
    """Run with at most ``ceil(max_iters / chunk_size)`` device dispatches.

    Returns the final state plus the per-iteration history (same dict
    schema as the legacy host loop: iteration / score / migrations /
    message_mass / phi / rho), recorded on device and synced once per
    chunk.  ``record=False`` skips history recording entirely (the
    returned list is empty); a ``callback`` forces recording on.
    """
    record = record or callback is not None
    run_chunk = make_chunked_runner(graph, cfg, chunk_size, score_fn,
                                    record=record, opts=opts)
    if on_program is not None:
        on_program(getattr(run_chunk, "program", None))
    state = init_state(labels, loads, key)
    history: List[dict] = []
    num_chunks = -(-cfg.max_iters // chunk_size)
    for _ in range(num_chunks):
        state, recs = run_chunk(state)
        recs = jax.device_get(recs)
        if record:
            for i in range(chunk_size):
                if not bool(recs["valid"][i]):
                    break
                entry = {
                    "iteration": int(recs["iteration"][i]),
                    "score": float(recs["score"][i]),
                    "migrations": int(recs["migrations"][i]),
                    "message_mass": float(recs["message_mass"][i]),
                    "phi": float(recs["phi"][i]),
                    "rho": float(recs["rho"][i]),
                }
                history.append(entry)
                if callback is not None:
                    callback(entry["iteration"], entry)
        # One scalar sync per chunk: stop dispatching once the run is over.
        if not bool(recs["valid"][chunk_size - 1]) or bool(
                jax.device_get(state.halted)):
            break
    return state, history


# ---------------------------------------------------------------------------
# Sharded runner: one lax.while_loop dispatch across the whole device mesh
# ---------------------------------------------------------------------------

def state_partition_spec(axis: str) -> SpinnerState:
    """``shard_map`` specs for a ``SpinnerState``: labels sharded over the
    vertex ``axis``, every aggregate (loads, key, halting scalars, the
    exchange-byte counter) replicated -- they are psum-consistent across
    devices by construction, whichever exchange plan is active."""
    rep = PartitionSpec()
    return SpinnerState(
        labels=PartitionSpec(axis), loads=rep, key=rep, best_score=rep,
        stall=rep, iteration=rep, halted=rep, total_messages=rep,
        score=rep, migrations=rep, message_mass=rep, exchanged_bytes=rep)


def _default_partition_mesh() -> Mesh:
    """1-D mesh over all local devices (cached so cache keys stay stable)."""
    global _DEFAULT_MESH
    if _DEFAULT_MESH is None:
        from repro.launch.mesh import make_partition_mesh
        _DEFAULT_MESH = make_partition_mesh()
    return _DEFAULT_MESH


_DEFAULT_MESH: Optional[Mesh] = None


def make_sharded_step_fn(cfg, axis: str, ndev: int, v_local: int, plan,
                         scores, noise_mode: str,
                         overlap: bool = False,
                         fused: bool = False) -> Callable:
    """Per-device jittable sharded transition, parameterized by the plan.

    Runs INSIDE ``shard_map`` over ``axis``: ``state.labels`` arrives as
    this device's ``(v_local,)`` shard, the edge blocks as this device's
    rows of the score backend's layout, scalars replicated.  The label
    exchange is delegated to ``plan`` (``repro.core.comm.ExchangePlan``):
    the all-gather oracle, the boundary-only halo exchange, or the
    changed-labels-only delta exchange -- all bit-compatible, differing
    only in bytes on the wire (accumulated into
    ``state.exchanged_bytes``).  The (k,) and scalar aggregates inside
    ``make_vertex_update`` are psum-reduced, so every device computes the
    same ``_halting_update`` decision and a surrounding ``while_loop``
    stays in lockstep with no host involvement.

    Schedule (``overlap``): with ``overlap=False``, ``scores`` is the
    backend's single-phase closure and the step is exchange -> score.
    With ``overlap=True``, ``scores`` is the backend's ``(interior_fn,
    frontier_fn)`` pair over the [interior | frontier] edge split (see
    ``distributed.ShardedGraph``) and the step is rescheduled to
    ``start_exchange -> score_interior -> finish_exchange ->
    score_frontier``: the collective is issued before any edge is
    scored and only the frontier phase consumes it, so the two are
    dataflow-independent and XLA's latency-hiding scheduler can overlap
    wire and compute.  Both schedules are bit-identical (the integer
    edge weights make the f32 partial sums exact).

    Fused (``fused=True``): ``scores`` is the backend's whole-iteration
    closure (``make_sharded_fused_update``; under overlap the
    ``(interior_fn, frontier_fn)`` split form, where the interior phase
    returns a RAW tiled score partial and the frontier megakernel seeds
    its accumulator with it).  The closure consumes the exact same
    noise/u/valid slices and the psum reducer the dense path hands to
    ``make_vertex_update``, so the trajectory is bit-identical.

    Closes over static shape ints only (``ndev``, ``v_local``, the plan's
    signature) -- capacity, the real vertex count and every edge array
    are traced arguments, so one compiled program serves every graph in a
    shape bucket.  Returns ``step(state, aux, capacity, num_real, deg_l,
    score_blocks, plan_blocks) -> (state, aux)`` where ``aux`` is the
    plan's loop-carried state (e.g. delta's replicated label mirror;
    ``()`` for stateless plans).

    PRNG (``EngineOptions.sharded_noise``): with ``"replicated"``
    (default) noise/u are drawn over the full padded vertex set from the
    replicated key and sliced to the local shard -- on a 1-device mesh
    the padded set IS the engine's padded vertex set, so draws (and
    therefore labels and iteration counts) are bit-identical to the
    single-device engines.  With ``"folded"`` each device folds its axis
    index into the key and draws only its local (v_local, k) block --
    O(V/ndev) instead of O(V) noise memory for very large V, at the cost
    of a different (still deterministic) stream.
    """
    k = cfg.k
    v_pad = ndev * v_local
    update = make_vertex_update(cfg)
    eps = jnp.float32(cfg.eps)
    halt_window = cfg.halt_window

    def psum(x):
        return jax.lax.psum(x, axis)

    def step_fn(state: SpinnerState, aux, capacity, num_real, deg_l,
                score_blocks, plan_blocks):
        key, k_it = jax.random.split(state.key)
        # Pregel messages: one plan-defined label exchange.
        if overlap:
            interior_fn, frontier_fn = scores
            pending = plan.start_exchange(state.labels, aux, axis,
                                          *plan_blocks)
            partial = interior_fn(state.labels, *score_blocks)
            lookup, aux, xbytes = plan.finish_exchange(pending)
        else:
            lookup, aux, xbytes = plan.exchange(state.labels, aux, axis,
                                                *plan_blocks)
        off = jax.lax.axis_index(axis) * v_local
        if noise_mode == "folded":
            k_dev = jax.random.fold_in(k_it, jax.lax.axis_index(axis))
            k_noise, k_mig = jax.random.split(k_dev)
            noise = jax.random.uniform(k_noise, (v_local, k), jnp.float32,
                                       0.0, cfg.tie_noise)
            u = jax.random.uniform(k_mig, (v_local,), jnp.float32)
        else:
            k_noise, k_mig = jax.random.split(k_it)
            noise_full = jax.random.uniform(k_noise, (v_pad, k), jnp.float32,
                                            0.0, cfg.tie_noise)
            u_full = jax.random.uniform(k_mig, (v_pad,), jnp.float32)
            noise = jax.lax.dynamic_slice_in_dim(noise_full, off, v_local, 0)
            u = jax.lax.dynamic_slice_in_dim(u_full, off, v_local, 0)
        valid = off + jnp.arange(v_local, dtype=jnp.int32) < num_real
        if fused:
            fused_fn = frontier_fn if overlap else scores
            head = (partial, lookup) if overlap else (lookup,)
            labels, loads, score_g, n_mig, mig_mass = fused_fn(
                *head, state.labels, deg_l, state.loads, noise, u, valid,
                psum, capacity, *score_blocks)
        else:
            scores_v = (frontier_fn(partial, lookup, *score_blocks)
                        if overlap else
                        scores(lookup, *score_blocks))     # (v_local, k)
            labels, loads, score_g, n_mig, mig_mass = update(
                scores_v, state.labels, deg_l, state.loads, noise, u,
                valid, psum, capacity)
        best, stall, halted = _halting_update(
            state.best_score, state.stall, score_g, eps, halt_window)
        return SpinnerState(
            labels=labels, loads=loads, key=key,
            best_score=best, stall=stall,
            iteration=state.iteration + 1, halted=halted,
            total_messages=state.total_messages + mig_mass,
            score=score_g, migrations=n_mig, message_mass=mig_mass,
            exchanged_bytes=state.exchanged_bytes + xbytes), aux

    return step_fn


def _sharded_program(cfg, opts: EngineOptions, mesh: Mesh, axis: str,
                     plan_sig: tuple, n_score: int,
                     score_fn: Optional[Callable] = None,
                     single_step: bool = False,
                     overlap: bool = False,
                     fused: bool = False) -> Program:
    """The compiled sharded runner (or one-iteration step) for a static
    (cfg, backend, mesh, axis, plan signature, noise mode, overlap
    schedule, fused-update) tuple.

    Traces against an array-free ``plan_from_signature`` view, so the
    program closes over shape ints only and is shared by every graph
    whose sharded layout lands in the same bucket.
    """
    from . import comm                                    # sibling, no cycle
    noise_mode = opts.resolved_sharded_noise()
    ndev = mesh.shape[axis]
    if score_fn is not None:
        scores_sig = ("custom",)
    else:
        backend = opts.backend()
        scores_sig = backend.signature()
    kind = "sharded_step" if single_step else "sharded"
    key = (kind, _static_cfg(cfg), scores_sig, mesh, axis, plan_sig,
           noise_mode, overlap, fused)
    max_iters = cfg.max_iters

    def build():
        plan = comm.plan_from_signature(plan_sig)
        v_local = plan_sig[2] if plan_sig[0] != "allgather" \
            else plan_sig[2] // ndev
        deg_weighted = cfg.migration_weighting == "edges"
        if score_fn is not None:
            scores = lambda lookup, *blocks: score_fn(lookup, *blocks)
        elif fused and overlap:
            scores = opts.backend().make_sharded_fused_update_split(
                cfg.k, v_local, degree_weighted=deg_weighted,
                current_bonus=float(cfg.current_bonus))
        elif fused:
            scores = opts.backend().make_sharded_fused_update(
                cfg.k, v_local, degree_weighted=deg_weighted,
                current_bonus=float(cfg.current_bonus))
        elif overlap:
            scores = opts.backend().make_sharded_scores_split(cfg.k,
                                                              v_local)
        else:
            scores = opts.backend().make_sharded_scores(cfg.k, v_local)
        step_fn = make_sharded_step_fn(cfg, axis, ndev, v_local, plan,
                                       scores, noise_mode,
                                       overlap=overlap, fused=fused)

        def cond_fn(carry):
            s = carry[0]
            return jnp.logical_and(jnp.logical_not(s.halted),
                                   s.iteration < max_iters)

        plan_specs = tuple(plan.arg_specs(axis))
        # sharded args arrive with a leading length-1 shard dim to strip;
        # replicated plan args (e.g. halo's wire-bytes scalar) do not
        strip = (True,) * n_score + tuple(s == PartitionSpec(axis)
                                          for s in plan_specs)

        def run_local(state, capacity, num_real, deg_l, *rest):
            blocks = tuple(r[0] if s else r for r, s in zip(rest, strip))
            score_blocks, plan_blocks = blocks[:n_score], blocks[n_score:]
            dl = deg_l[0]
            aux0 = plan.init_aux(state.labels, axis, *plan_blocks)
            if single_step:
                new_state, _ = step_fn(state, aux0, capacity, num_real, dl,
                                       score_blocks, plan_blocks)
                return new_state

            def body(carry):
                s, aux = carry
                return step_fn(s, aux, capacity, num_real, dl,
                               score_blocks, plan_blocks)

            state, _ = jax.lax.while_loop(cond_fn, body, (state, aux0))
            return state

        spec = state_partition_spec(axis)
        rep = PartitionSpec()
        arg_specs = (rep, rep, PartitionSpec(axis)) \
            + (PartitionSpec(axis),) * n_score + tuple(plan.arg_specs(axis))
        return jax.jit(shard_map(
            run_local, mesh=mesh, in_specs=(spec,) + arg_specs,
            out_specs=spec, check_rep=False))

    if score_fn is not None:
        return Program(run=build())
    return _program(key, build)


def _sharded_parts(graph: Graph, cfg, opts: EngineOptions, mesh: Mesh,
                   axis: str, score_fn: Optional[Callable] = None,
                   single_step: bool = False):
    """Everything the sharded runner and one-step dispatcher share.

    Resolves the exchange plan and the overlap schedule, builds (or
    fetches cached) the score backend's sharded edge arrays against the
    plan's ``dst_index`` (the two-phase split arrays under overlap), and
    returns ``(sg, plan, program, args)`` where ``args`` is the full
    argument tuple after the state: ``(capacity, num_real, deg_w,
    *score_args, *plan_args)``.

    ``single_step=True`` (the hostloop baseline's one-iteration
    dispatcher) pins the aux-free allgather oracle -- delta's label
    mirror would have to round-trip between dispatches -- and the
    non-overlapped schedule, so there is exactly ONE step-construction
    code path for every driver.  Every plan/schedule combination walks
    the same trajectory, so parity with ``engine="sharded"`` is
    unaffected.
    """
    from . import comm                                    # sibling, no cycle
    from .distributed import device_upload, shard_layout  # layout layer
    if single_step:
        opts = dataclasses.replace(opts, label_exchange="allgather",
                                   overlap="off")
    ndev = mesh.shape[axis]
    if score_fn is None:
        opts = _autotuned(graph, cfg, opts, ndev=ndev)
    padded, num_real = padded_view(graph, opts)
    pad = opts.pad == "bucket"
    # custom score closures are single-phase by contract
    overlap = (opts.resolved_overlap(ndev) == "on" and score_fn is None)
    fused = score_fn is None and opts.resolved_fused_update() == "on"
    sg = shard_layout(padded, ndev, pad=pad)
    plan = comm.make_exchange_plan(opts.resolved_label_exchange(ndev), sg,
                                   delta_cap=opts.delta_cap, pad=pad)
    if score_fn is None:
        backend = opts.backend()
        # cached per layout: the build retiles/uploads O(E) arrays (for
        # pallas, a host retile per shard) and depends only on the layout,
        # the backend, the plan's dst layout and the schedule -- so a cfg
        # sweep (eps/seed/max_iters/...) over one graph shares one build,
        # and so do the allgather/delta plans (both index with sg.dst)
        dst_layout = "halo" if plan.dst_index is not sg.dst else "global"
        if fused:
            args_of = (backend.sharded_fused_graph_args_split if overlap
                       else backend.sharded_fused_graph_args)
        else:
            args_of = (backend.sharded_graph_args_split if overlap
                       else backend.sharded_graph_args)
        score_args = _graph_cached(
            _SCORE_ARG_CACHE, sg,
            ("sharded", backend.signature(), dst_layout, pad, overlap,
             fused),
            lambda: tuple(args_of(sg, cfg.k, plan.dst_index, pad=pad)))
    else:
        # custom closures get the XLA backend's edge layout (same arrays,
        # same normalization), just a different scores fn
        from repro.kernels import ops as kernel_ops
        score_args = kernel_ops.get_score_backend("xla").sharded_graph_args(
            sg, cfg.k, plan.dst_index)
    prog = _sharded_program(cfg, opts, mesh, axis, plan.signature(),
                            len(score_args), score_fn,
                            single_step=single_step, overlap=overlap,
                            fused=fused)
    args = (jnp.float32(cfg.capacity(graph)), jnp.int32(num_real),
            device_upload(sg, "deg_w")) + tuple(score_args) \
        + tuple(plan.device_args())
    return sg, plan, prog, args


def make_sharded_frontier_step_fn(cfg, axis: str, ndev: int, v_local: int,
                                  plan, scores, noise_mode: str,
                                  fused: bool = False) -> Callable:
    """Frontier-mode per-device sharded transition.

    Same exchange/noise/update structure as ``make_sharded_step_fn``
    (non-overlapped schedule) with the frontier additions: ``valid`` is
    masked by the local active set, the next active set is the
    post-proposal ``want`` mask, expansion rides the LOOKUP DIFF -- the
    carry keeps the previous iteration's lookup array and any local
    vertex with an edge whose remote endpoint's looked-up label changed
    is re-activated (the plan-agnostic analogue of the single-device
    ``changed[dst]`` gather; works for allgather/delta's global mirror
    and halo's fixed boundary-slot layout alike).  Halting is
    psum-reduced drain: no device has an active vertex that wants to
    move.  The carry is ``(state, aux, active, prev_lookup, hist)``.

    The score backend's first two edge blocks must be the XLA layout's
    ``(src_local, dst_index)`` pair -- they double as the expansion
    index, which is why sharded frontier mode is XLA-backend-only.
    """
    k = cfg.k
    v_pad = ndev * v_local
    eps = jnp.float32(cfg.eps)
    halt_window = cfg.halt_window
    propose, finish = make_update_parts(
        k, degree_weighted=cfg.migration_weighting == "edges",
        current_bonus=cfg.current_bonus)

    def psum(x):
        return jax.lax.psum(x, axis)

    def step_fn(carry, capacity, num_real, deg_l, score_blocks,
                plan_blocks):
        state, aux, active, prev_lookup, hist = carry
        key, k_it = jax.random.split(state.key)
        lookup, aux, xbytes = plan.exchange(state.labels, aux, axis,
                                            *plan_blocks)
        # Expand: re-activate local endpoints of edges whose remote
        # endpoint changed label last iteration (pad edges point at a
        # fixed in-range slot, so a spurious hit only re-activates an
        # already-active migrant -- conservative, never unsound).
        src_local, dst_idx = score_blocks[0], score_blocks[1]
        changed_dst = (lookup[dst_idx] != prev_lookup[dst_idx]
                       ).astype(jnp.int32)
        touched = jnp.zeros((v_local,), jnp.int32).at[src_local].max(
            changed_dst) > 0
        active = active | touched
        off = jax.lax.axis_index(axis) * v_local
        if noise_mode == "folded":
            k_dev = jax.random.fold_in(k_it, jax.lax.axis_index(axis))
            k_noise, k_mig = jax.random.split(k_dev)
            noise = jax.random.uniform(k_noise, (v_local, k), jnp.float32,
                                       0.0, cfg.tie_noise)
            u = jax.random.uniform(k_mig, (v_local,), jnp.float32)
        else:
            k_noise, k_mig = jax.random.split(k_it)
            noise_full = jax.random.uniform(k_noise, (v_pad, k),
                                            jnp.float32, 0.0,
                                            cfg.tie_noise)
            u_full = jax.random.uniform(k_mig, (v_pad,), jnp.float32)
            noise = jax.lax.dynamic_slice_in_dim(noise_full, off, v_local,
                                                 0)
            u = jax.lax.dynamic_slice_in_dim(u_full, off, v_local, 0)
        valid = (off + jnp.arange(v_local, dtype=jnp.int32) < num_real) \
            & active
        if fused:
            labels, loads, score_g, n_mig, mig_mass, want = scores(
                lookup, state.labels, deg_l, state.loads, noise, u, valid,
                psum, capacity, *score_blocks)
        else:
            scores_v = scores(lookup, *score_blocks)
            best, tot_best, tot_cur, m_partial = propose(
                scores_v, state.labels, deg_l, state.loads, noise, valid,
                capacity)
            want = (best != state.labels) & valid
            labels, loads, score_g, n_mig, mig_mass = finish(
                best, tot_best, tot_cur, m_partial, state.labels, deg_l,
                state.loads, u, valid, psum, capacity)
        hist = hist.at[state.iteration].set(
            psum(jnp.sum(valid.astype(jnp.float32))))
        n_want = psum(jnp.sum(want.astype(jnp.int32)))
        best_s, stall, _ = _halting_update(
            state.best_score, state.stall, score_g, eps, halt_window)
        new_state = SpinnerState(
            labels=labels, loads=loads, key=key,
            best_score=best_s, stall=stall,
            iteration=state.iteration + 1, halted=n_want == 0,
            total_messages=state.total_messages + mig_mass,
            score=score_g, migrations=n_mig, message_mass=mig_mass,
            exchanged_bytes=state.exchanged_bytes + xbytes)
        return new_state, aux, want, lookup, hist

    return step_fn


def _sharded_frontier_program(cfg, opts: EngineOptions, mesh: Mesh,
                              axis: str, plan_sig: tuple, n_score: int,
                              fused: bool = False) -> Program:
    """``run(state, active, capacity, num_real, deg_w, *score, *plan)
    -> (state, scored_hist)``: the sharded frontier loop in one
    shard_map(while_loop) dispatch, primed with a pre-loop exchange of
    the initial labels (``ExchangePlan.prime``)."""
    from . import comm                                    # sibling, no cycle
    noise_mode = opts.resolved_sharded_noise()
    ndev = mesh.shape[axis]
    backend = opts.backend()
    key = ("sharded_frontier", _static_cfg(cfg), backend.signature(), mesh,
           axis, plan_sig, noise_mode, fused)
    max_iters = cfg.max_iters

    def build():
        plan = comm.plan_from_signature(plan_sig)
        v_local = plan_sig[2] if plan_sig[0] != "allgather" \
            else plan_sig[2] // ndev
        deg_weighted = cfg.migration_weighting == "edges"
        if fused:
            scores = backend.make_sharded_fused_update(
                cfg.k, v_local, degree_weighted=deg_weighted,
                current_bonus=float(cfg.current_bonus), frontier=True)
        else:
            scores = backend.make_sharded_scores(cfg.k, v_local)
        step_fn = make_sharded_frontier_step_fn(
            cfg, axis, ndev, v_local, plan, scores, noise_mode,
            fused=fused)

        def cond_fn(carry):
            s = carry[0]
            return jnp.logical_and(jnp.logical_not(s.halted),
                                   s.iteration < max_iters)

        plan_specs = tuple(plan.arg_specs(axis))
        strip = (True,) * n_score + tuple(s == PartitionSpec(axis)
                                          for s in plan_specs)

        def run_local(state, active, capacity, num_real, deg_l, *rest):
            blocks = tuple(r[0] if s else r for r, s in zip(rest, strip))
            score_blocks, plan_blocks = blocks[:n_score], blocks[n_score:]
            dl = deg_l[0]
            prev_lookup, aux0, b0 = plan.prime(state.labels, axis,
                                               *plan_blocks)
            state = state._replace(
                exchanged_bytes=state.exchanged_bytes + b0)

            def body(carry):
                return step_fn(carry, capacity, num_real, dl,
                               score_blocks, plan_blocks)

            carry = (state, aux0, active, prev_lookup,
                     jnp.zeros((max_iters,), jnp.float32))
            carry = jax.lax.while_loop(cond_fn, body, carry)
            return carry[0], carry[4]

        spec = state_partition_spec(axis)
        rep = PartitionSpec()
        arg_specs = (PartitionSpec(axis), rep, rep, PartitionSpec(axis)) \
            + (PartitionSpec(axis),) * n_score + plan_specs
        return jax.jit(shard_map(
            run_local, mesh=mesh, in_specs=(spec,) + arg_specs,
            out_specs=(spec, rep), check_rep=False))

    return _program(key, build)


def _sharded_frontier_parts(graph: Graph, cfg, opts: EngineOptions,
                            mesh: Mesh, axis: str):
    """Layout/plan/program/args for a sharded frontier run.

    Frontier mode pins the non-overlapped schedule (the expansion diff
    needs the whole lookup before scoring) and the XLA score backend
    (its COO edge blocks double as the expansion index).
    """
    from . import comm                                    # sibling, no cycle
    from .distributed import device_upload, shard_layout  # layout layer
    opts = dataclasses.replace(opts, overlap="off")
    ndev = mesh.shape[axis]
    opts = _autotuned(graph, cfg, opts, ndev=ndev)
    backend = opts.backend()
    if getattr(backend, "name", None) != "xla":
        raise ValueError(
            "frontier mode on the sharded engine requires the XLA score "
            "backend (its (src_local, dst_index) edge blocks double as "
            "the frontier expansion index); got "
            f"{getattr(backend, 'name', backend)!r}")
    padded, num_real = padded_view(graph, opts)
    pad = opts.pad == "bucket"
    fused = opts.resolved_fused_update() == "on"
    sg = shard_layout(padded, ndev, pad=pad)
    plan = comm.make_exchange_plan(opts.resolved_label_exchange(ndev), sg,
                                   delta_cap=opts.delta_cap, pad=pad)
    dst_layout = "halo" if plan.dst_index is not sg.dst else "global"
    args_of = (backend.sharded_fused_graph_args if fused
               else backend.sharded_graph_args)
    score_args = _graph_cached(
        _SCORE_ARG_CACHE, sg,
        ("sharded", backend.signature(), dst_layout, pad, False, fused),
        lambda: tuple(args_of(sg, cfg.k, plan.dst_index, pad=pad)))
    prog = _sharded_frontier_program(cfg, opts, mesh, axis,
                                     plan.signature(), len(score_args),
                                     fused=fused)
    args = (jnp.float32(cfg.capacity(graph)), jnp.int32(num_real),
            device_upload(sg, "deg_w")) + tuple(score_args) \
        + tuple(plan.device_args())
    return sg, plan, prog, args


def run_sharded_frontier(graph: Graph, cfg, labels, loads, key, active,
                         mesh: Optional[Mesh] = None, axis: str = "data",
                         opts: EngineOptions = _DEFAULT_OPTS,
                         on_program: Optional[Callable] = None):
    """Sharded frontier-mode run to drain: ``(state, scored_hist)``.

    ``state.labels`` comes back PADDED (slice ``[:graph.num_vertices]``);
    ``active`` is a bool mask over the real vertex set.
    """
    if mesh is None:
        mesh = _default_partition_mesh()
    sg, plan, prog, args = _sharded_frontier_parts(graph, cfg, opts, mesh,
                                                   axis)
    if on_program is not None:
        on_program(prog)
    v_pad = sg.num_vertices
    active = jnp.asarray(active, jnp.bool_)
    pad = v_pad - active.shape[0]
    if pad:
        active = jnp.concatenate([active, jnp.zeros((pad,), jnp.bool_)])
    state = init_state(pad_labels(labels, v_pad), loads, key)
    return prog.run(state, active, *args)


def make_sharded_runner(graph: Graph, cfg, mesh: Mesh, axis: str = "data",
                        score_fn: Optional[Callable] = None,
                        opts: EngineOptions = _DEFAULT_OPTS) -> Callable:
    """Compile the full sharded run into ONE device dispatch.

    Returns ``runner(state) -> state`` where ``state.labels`` is the
    padded (ndev * v_per_dev,) vector over the shape-bucketed layout; the
    ``lax.while_loop`` lives INSIDE the ``shard_map``, so all devices
    iterate in lockstep driven purely by the psum-reduced halting scalars
    -- no per-iteration host sync exists even in principle.  The
    while_loop carry is ``(state, plan aux)``: the exchange plan's
    auxiliary state (e.g. delta's label mirror) never leaves the device
    either.  A custom ``score_fn`` closure is shaped to the real graph's
    layout, so it forces ``pad="none"``.
    """
    if score_fn is not None:
        opts = dataclasses.replace(opts, pad="none")
    sg, plan, prog, args = _sharded_parts(graph, cfg, opts, mesh, axis,
                                          score_fn)

    def runner(state: SpinnerState) -> SpinnerState:
        return prog.run(state, *args)

    runner.program = prog
    runner.v_pad = sg.num_vertices
    return runner


def sharded_v_pad(graph: Graph, opts: EngineOptions, mesh: Mesh,
                  axis: str = "data") -> int:
    """Padded vertex count of the sharded layout (bucket + mesh rounding)."""
    padded, _ = padded_view(graph, opts)
    ndev = mesh.shape[axis]
    return -(-padded.num_vertices // ndev) * ndev


def run_sharded(graph: Graph, cfg, labels, loads, key,
                mesh: Optional[Mesh] = None, axis: str = "data",
                score_fn: Optional[Callable] = None,
                opts: EngineOptions = _DEFAULT_OPTS,
                on_program: Optional[Callable] = None) -> SpinnerState:
    """Run to the stable state in one ``while_loop`` dispatch over ``mesh``.

    ``mesh=None`` uses a 1-D mesh over all local devices
    (``repro.launch.mesh.make_partition_mesh``).  The returned state
    carries PADDED labels (the bucketed layout rounded up to a mesh
    multiple); callers slice ``[:graph.num_vertices]``.  Compiled
    programs are cached globally per (cfg statics, backend, mesh, axis,
    plan signature) -- meshes compare by value, so rebuilding an
    identical mesh reuses the compilation, and so do all graphs sharing
    a shape bucket.
    """
    if mesh is None:
        mesh = _default_partition_mesh()
    if score_fn is not None:             # custom closures run unpadded
        opts = dataclasses.replace(opts, pad="none")
    runner = make_sharded_runner(graph, cfg, mesh, axis, score_fn, opts=opts)
    if on_program is not None:
        on_program(getattr(runner, "program", None))
    v_pad = sharded_v_pad(graph, opts, mesh, axis)
    return runner(init_state(pad_labels(labels, v_pad), loads, key))
