"""Device-resident Spinner LPA engine (state / step / runner layering).

The legacy driver in ``spinner.py`` round-trips to the host every iteration
(``float(score_g)`` sync, host PRNG splitting, per-iteration numpy history),
so on small graphs wall-clock is dominated by dispatch latency rather than
the ComputeScores kernel.  This module keeps the whole run on device:

  * ``SpinnerState`` -- a pure functional pytree carrying everything one LPA
    iteration reads or writes: labels, loads, the PRNG key, the Eq. 9
    halting aggregates (best_score / stall), iteration counter, and the
    migration statistics of the last step.
  * ``make_iteration`` -- the two-phase ComputeScores / ComputeMigrations
    math (Eqs. 8, 11, 12) as a pure function, shared verbatim with the
    legacy host loop so the two engines are bit-compatible oracles of each
    other.  The Eq. 8 numerator is delegated to a pluggable score backend
    (``repro.kernels.ops.get_score_backend``): the XLA scatter-add path and
    the Pallas ``spinner_scores_tiled`` kernel are interchangeable and
    selected once at trace time.
  * ``make_step_fn`` -- one fully-jittable state -> state transition:
    PRNG split, iteration, and the Section 3.3 eps/halt_window stall logic
    evaluated on device.
  * ``run_fused`` -- the entire run as a single ``jax.lax.while_loop``
    dispatch; nothing touches the host until the final state is read back.
  * ``run_chunked`` -- a ``jax.lax.scan`` that executes ``chunk_size``
    iterations per dispatch and records a fixed-size on-device history
    (score / migrations / message mass / phi / rho per iteration) for
    callers that need per-iteration traces; the host only syncs once per
    chunk to check the halting flag.

``spinner.partition`` selects between these runners and the legacy host
loop via its ``engine`` argument; ``incremental.adapt`` / ``resize`` ride on
the same entry point, so incremental and elastic restarts are a single
device call as well.
"""
from __future__ import annotations

import weakref
import dataclasses
from typing import Callable, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .graph import Graph

DEFAULT_CHUNK = 32

# Per-Graph memoization.  partition()/adapt()/resize() are typically called
# many times against the same Graph (benchmark sweeps, incremental
# restarts); rebuilding closures per call would re-upload edge arrays and
# re-trace/re-compile the jitted step or whole while_loop/scan each time,
# wiping out the dispatch win.  Every cache below is keyed on id(graph) + a
# per-use suffix, with a weakref guard so entries die with their graph and
# a recycled id() can never alias.
_RUNNER_CACHE: dict = {}      # (kind, cfg, chunk_size, record) -> runner
_STEP_CACHE: dict = {}        # (cfg,) -> jitted iterate (host loop's step)
_SCORE_FN_CACHE: dict = {}    # (backend, k) -> score closure
_EDGE_UPLOAD_CACHE: dict = {} # () -> (src, dst, weight, deg_w) on device


def _graph_cached(cache: dict, graph: Graph, suffix: tuple,
                  build: Callable[[], object]):
    """Memoize ``build()`` per (graph, suffix); evicted when graph dies."""
    key = (id(graph),) + suffix
    entry = cache.get(key)
    if entry is not None and entry[0]() is graph:
        return entry[1]
    value = build()
    cache[key] = (weakref.ref(graph, lambda _: cache.pop(key, None)), value)
    return value


def _cache_cfg(cfg):
    """Cache-key view of the config: the seed never enters the traced
    computation (it only feeds host-side PRNGKey creation in
    ``prepare_init``), so seed sweeps must share one compiled runner."""
    return dataclasses.replace(cfg, seed=0)


def _get_runner(kind: str, graph: Graph, cfg, chunk_size: Optional[int],
                score_fn: Optional[Callable], record: bool = True) -> Callable:
    if score_fn is not None:
        # custom backend closure: not keyable, build fresh
        if kind == "fused":
            return make_fused_runner(graph, cfg, score_fn)
        return make_chunked_runner(graph, cfg, chunk_size, score_fn,
                                   record=record)
    if kind == "fused":
        build = lambda: make_fused_runner(graph, cfg)
    else:
        build = lambda: make_chunked_runner(graph, cfg, chunk_size,
                                            record=record)
    return _graph_cached(_RUNNER_CACHE, graph,
                         (kind, _cache_cfg(cfg), chunk_size, record), build)


def cached_jit_step(graph: Graph, cfg) -> Callable:
    """Jitted ``iterate(labels, loads, key)``, cached per (graph, cfg).

    This is the host loop's step; caching it keeps ``engine="host"`` from
    re-tracing on every partition() call, same as the fused runners.
    """
    return _graph_cached(_STEP_CACHE, graph, (_cache_cfg(cfg),),
                         lambda: jax.jit(make_iteration(graph, cfg)))


class SpinnerState(NamedTuple):
    """Carry of the fused LPA loop -- one pytree, fully device-resident."""

    labels: jax.Array          # (V,) int32 current assignment
    loads: jax.Array           # (k,) float32 B(l) (Eq. 6), running update
    key: jax.Array             # PRNG key consumed by splitting each iter
    best_score: jax.Array      # f32 scalar, best score(G) so far (Eq. 9)
    stall: jax.Array           # int32, consecutive non-improving iterations
    iteration: jax.Array       # int32, iterations completed
    halted: jax.Array          # bool, eps/halt_window criterion fired
    total_messages: jax.Array  # f32, cumulative migrant degree mass
    score: jax.Array           # f32, score(G) after the last iteration
    migrations: jax.Array      # int32, migrating vertices last iteration
    message_mass: jax.Array    # f32, migrant degree mass last iteration


def init_state(labels: jax.Array, loads: jax.Array,
               key: jax.Array) -> SpinnerState:
    return SpinnerState(
        labels=jnp.asarray(labels, jnp.int32),
        loads=jnp.asarray(loads, jnp.float32),
        key=key,
        best_score=jnp.float32(-jnp.inf),
        stall=jnp.int32(0),
        iteration=jnp.int32(0),
        halted=jnp.asarray(False),
        total_messages=jnp.float32(0.0),
        score=jnp.float32(0.0),
        migrations=jnp.int32(0),
        message_mass=jnp.float32(0.0),
    )


def device_edges(graph: Graph):
    """(src, dst, weight, deg_w) as device arrays, uploaded once per Graph.

    Shared by every runner variant and the XLA score backend: a config
    sweep over one graph would otherwise hold one 2*E copy of
    src/dst/weight per variant.
    """
    return _graph_cached(
        _EDGE_UPLOAD_CACHE, graph, (),
        lambda: (jnp.asarray(graph.src), jnp.asarray(graph.dst),
                 jnp.asarray(graph.weight), jnp.asarray(graph.deg_w)))


def make_score_fn(graph: Graph, cfg) -> Callable[[jax.Array], jax.Array]:
    """Build (or fetch cached) the Eq. 8 numerator fn for the backend.

    Cached per (graph, backend, k): the backend build uploads the O(E)
    edge arrays (and, for pallas, retiles the CSR on the host), none of
    which depends on the rest of the config -- so runner variants
    (different eps/seed/max_iters sweeping the same graph) share one
    built backend.
    """
    from repro.kernels import ops as kernel_ops   # lazy: no import cycle
    name = cfg.resolved_score_backend()

    def build():
        return kernel_ops.get_score_backend(name).build(graph, cfg.k)

    return _graph_cached(_SCORE_FN_CACHE, graph, (name, cfg.k), build)


def make_iteration(graph: Graph, cfg,
                   score_fn: Optional[Callable] = None) -> Callable:
    """One LPA iteration (ComputeScores + ComputeMigrations) as a pure fn.

    Returns ``iterate(labels, loads, key) -> (labels, loads, score_g,
    n_migrations, migration_mass)``.  Both the legacy host loop and the
    fused runners call exactly this function, which is what makes them
    oracles of each other.
    """
    if score_fn is None:
        score_fn = make_score_fn(graph, cfg)
    deg_w = device_edges(graph)[3]
    V, k = graph.num_vertices, cfg.k
    C = jnp.float32(cfg.capacity(graph))
    degree_weighted = cfg.migration_weighting == "edges"

    def iterate(labels: jax.Array, loads: jax.Array, key: jax.Array):
        # ---- ComputeScores (Eq. 8) -------------------------------------
        scores = score_fn(labels)                          # (V, k) f32
        norm = scores / jnp.maximum(deg_w, 1.0)[:, None]
        penalty = loads / C                                # pi(l) (Eq. 7)
        total = norm - penalty[None, :]

        k_noise, k_mig = jax.random.split(key)
        noise = jax.random.uniform(k_noise, (V, k), jnp.float32,
                                   0.0, cfg.tie_noise)
        bonus = cfg.current_bonus * jax.nn.one_hot(labels, k,
                                                   dtype=jnp.float32)
        best = jnp.argmax(total + noise + bonus, axis=1).astype(jnp.int32)
        want = best != labels

        # ---- ComputeMigrations (Eq. 11-12) -----------------------------
        measure = deg_w if degree_weighted else jnp.ones_like(deg_w)
        M = jnp.zeros((k,), jnp.float32).at[best].add(
            jnp.where(want, measure, 0.0))
        R = jnp.maximum(C - loads, 0.0)                    # Eq. 11
        p = jnp.clip(R / jnp.maximum(M, 1e-9), 0.0, 1.0)   # Eq. 12
        u = jax.random.uniform(k_mig, (V,), jnp.float32)
        migrate = want & (u < p[best])

        new_labels = jnp.where(migrate, best, labels)
        mig_deg = jnp.where(migrate, deg_w, 0.0)
        new_loads = (loads
                     .at[best].add(mig_deg)
                     .at[labels].add(-mig_deg))

        # ---- halting aggregate: score(G) at the new assignment (Eq. 9) --
        sel = jnp.take_along_axis(total, new_labels[:, None], axis=1)[:, 0]
        score_g = jnp.sum(sel)
        # migration mass = sum of migrant degrees = Pregel messages sent
        # (each migrating vertex notifies all neighbors, Section 4.1.3)
        return (new_labels, new_loads, score_g,
                jnp.sum(migrate).astype(jnp.int32), jnp.sum(mig_deg))

    return iterate


def _halting_update(best_score, stall, score_g, eps, halt_window):
    """Section 3.3 stall logic on device, mirroring the host loop exactly.

    On the first iteration best_score is -inf, so tol is inf and
    ``best + tol`` is NaN: the comparison is False and the iteration counts
    toward the stall window -- the same (intentional) behaviour as the
    legacy host loop's float arithmetic.
    """
    tol = eps * jnp.maximum(jnp.float32(1.0), jnp.abs(best_score))
    improved = score_g > best_score + tol
    new_best = jnp.maximum(best_score, score_g)
    new_stall = jnp.where(improved, jnp.int32(0), stall + 1)
    return new_best, new_stall, new_stall >= halt_window


def make_step_fn(graph: Graph, cfg,
                 score_fn: Optional[Callable] = None) -> Callable:
    """Jittable ``SpinnerState -> SpinnerState`` transition."""
    iterate = make_iteration(graph, cfg, score_fn)
    eps = jnp.float32(cfg.eps)
    halt_window = cfg.halt_window

    def step_fn(state: SpinnerState) -> SpinnerState:
        key, k_it = jax.random.split(state.key)
        labels, loads, score_g, n_mig, mig_mass = iterate(
            state.labels, state.loads, k_it)
        best, stall, halted = _halting_update(
            state.best_score, state.stall, score_g, eps, halt_window)
        return SpinnerState(
            labels=labels, loads=loads, key=key,
            best_score=best, stall=stall,
            iteration=state.iteration + 1, halted=halted,
            total_messages=state.total_messages + mig_mass,
            score=score_g, migrations=n_mig, message_mass=mig_mass)

    return step_fn


# ---------------------------------------------------------------------------
# Fused runner: the whole run is one lax.while_loop dispatch
# ---------------------------------------------------------------------------

def make_fused_runner(graph: Graph, cfg,
                      score_fn: Optional[Callable] = None) -> Callable:
    """Compile the full Spinner run into a single device call."""
    step_fn = make_step_fn(graph, cfg, score_fn)
    max_iters = cfg.max_iters

    def cond_fn(s: SpinnerState):
        return jnp.logical_and(jnp.logical_not(s.halted),
                               s.iteration < max_iters)

    @jax.jit
    def run(state: SpinnerState) -> SpinnerState:
        return jax.lax.while_loop(cond_fn, step_fn, state)

    return run


def run_fused(graph: Graph, cfg, labels, loads, key,
              score_fn: Optional[Callable] = None) -> SpinnerState:
    """Run to the stable state in one ``lax.while_loop`` dispatch.

    The compiled runner is cached per (graph, cfg), so repeated runs --
    determinism checks, incremental adapt/resize restarts -- skip
    re-tracing entirely.
    """
    runner = _get_runner("fused", graph, cfg, None, score_fn)
    return runner(init_state(labels, loads, key))


# ---------------------------------------------------------------------------
# Chunked runner: chunk_size iterations per dispatch, on-device history
# ---------------------------------------------------------------------------

def make_chunked_runner(graph: Graph, cfg, chunk_size: int = DEFAULT_CHUNK,
                        score_fn: Optional[Callable] = None,
                        record: bool = True) -> Callable:
    """Compile ``chunk_size`` iterations + history recording into one scan.

    Each scan step is guarded: once the halting criterion fires (or
    ``max_iters`` is reached) the state passes through unchanged and the
    record is marked invalid, so a trailing partial chunk costs nothing but
    pass-through work.  With ``record=False`` the per-iteration phi trace
    (an O(E) gather) is skipped and only the validity flags come back.
    """
    step_fn = make_step_fn(graph, cfg, score_fn)
    src, dst, _, _ = device_edges(graph)
    has_edges = graph.src.size > 0
    # edgeless graph: mirror metrics.rho's ideal<=0 convention (rho = 1)
    ideal = jnp.float32(graph.total_weight / cfg.k) if has_edges else None
    max_iters = cfg.max_iters

    def body(state: SpinnerState, _):
        active = jnp.logical_and(jnp.logical_not(state.halted),
                                 state.iteration < max_iters)
        new_state = jax.lax.cond(active, step_fn, lambda s: s, state)
        if not record:
            return new_state, {"valid": active}
        if has_edges:
            local = new_state.labels[src] == new_state.labels[dst]
            phi = jnp.mean(local.astype(jnp.float32))
            rho = jnp.max(new_state.loads) / ideal
        else:
            phi = jnp.float32(1.0)
            rho = jnp.float32(1.0)
        rec = {
            "iteration": new_state.iteration,
            "score": new_state.score,
            "migrations": new_state.migrations,
            "message_mass": new_state.message_mass,
            "phi": phi,
            "rho": rho,
            "valid": active,
        }
        return new_state, rec

    @jax.jit
    def run_chunk(state: SpinnerState):
        return jax.lax.scan(body, state, None, length=chunk_size)

    return run_chunk


def run_chunked(graph: Graph, cfg, labels, loads, key,
                chunk_size: int = DEFAULT_CHUNK,
                score_fn: Optional[Callable] = None,
                callback: Optional[Callable[[int, dict], None]] = None,
                record: bool = True,
                ) -> Tuple[SpinnerState, List[dict]]:
    """Run with at most ``ceil(max_iters / chunk_size)`` device dispatches.

    Returns the final state plus the per-iteration history (same dict
    schema as the legacy host loop: iteration / score / migrations /
    message_mass / phi / rho), recorded on device and synced once per
    chunk.  ``record=False`` skips history recording entirely (the
    returned list is empty); a ``callback`` forces recording on.
    """
    record = record or callback is not None
    run_chunk = _get_runner("chunked", graph, cfg, chunk_size, score_fn,
                            record=record)
    state = init_state(labels, loads, key)
    history: List[dict] = []
    num_chunks = -(-cfg.max_iters // chunk_size)
    for _ in range(num_chunks):
        state, recs = run_chunk(state)
        recs = jax.device_get(recs)
        if record:
            for i in range(chunk_size):
                if not bool(recs["valid"][i]):
                    break
                entry = {
                    "iteration": int(recs["iteration"][i]),
                    "score": float(recs["score"][i]),
                    "migrations": int(recs["migrations"][i]),
                    "message_mass": float(recs["message_mass"][i]),
                    "phi": float(recs["phi"][i]),
                    "rho": float(recs["rho"][i]),
                }
                history.append(entry)
                if callback is not None:
                    callback(entry["iteration"], entry)
        # One scalar sync per chunk: stop dispatching once the run is over.
        if not bool(recs["valid"][chunk_size - 1]) or bool(
                jax.device_get(state.halted)):
            break
    return state, history
