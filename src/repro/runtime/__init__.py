from . import failures
from .failures import SupervisorConfig, TrainSupervisor
