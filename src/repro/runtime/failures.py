"""Fault tolerance: checkpoint/restart orchestration + failure policy.

On a real multi-pod deployment the coordinator (jax.distributed) detects a
dead host via heartbeat timeout; the policy implemented here is the
standard synchronous-SPMD one:

  1. every worker checkpoints atomically every N steps (repro.ckpt);
  2. on any failure the job restarts from the newest complete checkpoint;
     the data pipeline is a pure function of (seed, step, shard), so NO
     data-state needs recovery and the restart is bit-exact (tested);
  3. if the replacement capacity differs (k -> k'), the elastic path
     (repro.core.incremental.resize for graph state, fresh mesh +
     checkpoint restore with new shardings for tensors) resumes on the
     new mesh -- restore() device_puts against caller shardings.
  4. stragglers: synchronous steps bound progress by the slowest worker;
     the Spinner-balanced placement minimizes the skew at its source
     (Table 4 experiment), and the launcher exposes a per-step walltime
     watchdog that flags >p99 outliers for replacement.

``TrainSupervisor`` packages (1)-(2) for the drivers; the simulated-crash
test lives in tests/test_runtime.py.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

from repro.ckpt import checkpoint


@dataclasses.dataclass
class SupervisorConfig:
    ckpt_dir: str
    ckpt_every: int = 100
    keep: int = 3
    straggler_factor: float = 3.0      # flag steps slower than 3x median


class TrainSupervisor:
    """Wraps a train loop with checkpointing + straggler detection."""

    def __init__(self, cfg: SupervisorConfig, state):
        self.cfg = cfg
        self.state = state
        self.step_times = []
        self.flagged_steps = []
        start = checkpoint.latest_step(cfg.ckpt_dir)
        self.start_step = 0
        if start is not None:
            self.state = checkpoint.restore(cfg.ckpt_dir, state)
            self.start_step = start

    def run(self, train_step: Callable, batch_fn: Callable, num_steps: int,
            crash_at: Optional[int] = None):
        """Run to num_steps; ``crash_at`` simulates a mid-run failure."""
        step = self.start_step
        while step < num_steps:
            if crash_at is not None and step == crash_at:
                raise RuntimeError(f"simulated worker failure at {step}")
            t0 = time.time()
            self.state, stats = train_step(self.state, batch_fn(step))
            dt = time.time() - t0
            self.step_times.append(dt)
            med = sorted(self.step_times)[len(self.step_times) // 2]
            if dt > self.cfg.straggler_factor * med and len(
                    self.step_times) > 5:
                self.flagged_steps.append((step, dt, med))
            step += 1
            if step % self.cfg.ckpt_every == 0:
                checkpoint.save(self.cfg.ckpt_dir, step, self.state)
                checkpoint.gc_old(self.cfg.ckpt_dir, keep=self.cfg.keep)
        checkpoint.save(self.cfg.ckpt_dir, step, self.state)
        return self.state

    def stats(self) -> dict:
        """Straggler-watchdog report (consumed by the cluster supervisor).

        ``flagged_steps`` is the list of ``(step, dt, median)`` walltime
        outliers (> ``straggler_factor`` x running median); previously
        accumulated but never surfaced.
        """
        times = sorted(self.step_times)
        return {
            "steps": len(self.step_times),
            "start_step": self.start_step,
            "median_step_time": times[len(times) // 2] if times else None,
            "straggler_factor": self.cfg.straggler_factor,
            "flagged_steps": list(self.flagged_steps),
        }
