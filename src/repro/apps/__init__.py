"""repro.apps -- the partition-consuming application layer.

The paper's headline claim (Section 7) is that consuming Spinner
partitions instead of hash partitioning speeds Pregel applications up
~2x by cutting cross-worker message traffic.  This package is the
consumer side that makes the measurement real:

  * :mod:`repro.apps.layout` places vertices on devices by ANY label
    vector (Spinner's, or the hash baseline) and reuses the engine's
    sharded bucketed CSR layouts;
  * :mod:`repro.apps.workloads` defines the suite -- PageRank,
    connected components (WCC), BFS/SSSP -- with semantics matching
    ``core.pregel``'s numpy oracles;
  * :mod:`repro.apps.engine` runs each as ONE
    ``shard_map(lax.while_loop)`` dispatch through the shared
    ``core.comm`` exchange plans, the overlap schedule, and the fused
    Pallas combiner (``kernels.pregel_combine``).

Entry points: :func:`run_app` here, or
``PartitionSession.run_app(workload)`` to consume the labels a session
just computed.  ``benchmarks/bench_apps.py`` drives the hash-vs-spinner
matrix into ``BENCH_apps.json``.
"""
from .engine import AppResult, AppState, run_app
from .layout import AppLayout, build_app_layout, placement_from_labels
from .workloads import APPS, AppSpec, finalize_values, init_active, init_values

__all__ = [
    "APPS", "AppLayout", "AppResult", "AppSpec", "AppState",
    "build_app_layout", "finalize_values", "init_active", "init_values",
    "placement_from_labels", "run_app",
]
