"""The partition-consuming workload suite (paper Section 7 / Figure 8).

Each workload is a Pregel vertex program expressed as the engine's
three hooks over a combine MONOID:

  * ``to_message`` -- the value a vertex sends along its out-edges
    (PageRank: ``pr / out_degree``; min-propagation: the value itself);
  * ``combine``    -- how incoming messages fold (``sum`` / ``min``);
  * update         -- the new vertex value from the combined inbox
    (PageRank's damped affine map; the monotone ``min(old, acc)``).

Semantics mirror ``core.pregel``'s numpy oracles exactly: messages are
UNWEIGHTED (the Eq. 3 edge weights only shape the partitioner), the
PageRank share divisor is the directed-entry out-degree, WCC components
converge to the minimum ORIGINAL vertex id (so results are
placement-invariant by construction), and BFS/SSSP counts unit hops.

``init_values`` / ``init_active`` produce the PERMUTED padded initial
state for an :class:`repro.apps.layout.AppLayout`; pad vertices carry
the monoid-neutral value and ``active = False`` forever.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.kernels.pregel_combine import INF_I32


@dataclasses.dataclass(frozen=True)
class AppSpec:
    """Static description of one vertex program (program-cache key part)."""
    name: str
    combine: str            # "sum" | "min"
    dtype: str              # "float32" | "int32"
    bias: int               # added to each message (BFS hop count)
    halts: bool             # drain-halt on zero changed vs. fixed iters
    default_iters: int      # pagerank sweep length / halt-cap for others
    default_plan: str       # exchange plan on a multi-device mesh


APPS = {
    "pagerank": AppSpec("pagerank", "sum", "float32", 0, False, 20, "halo"),
    "wcc": AppSpec("wcc", "min", "int32", 0, True, 4096, "halo_delta"),
    "bfs": AppSpec("bfs", "min", "int32", 1, True, 4096, "halo_delta"),
}
APPS["sssp"] = dataclasses.replace(APPS["bfs"], name="sssp")


def init_values(spec: AppSpec, layout, source: int = 0) -> np.ndarray:
    """(v_pad,) initial values in PERMUTED vertex order."""
    v_pad, n = layout.v_pad, layout.num_real
    if spec.combine == "sum":                      # pagerank
        vals = np.zeros(v_pad, np.float32)
        vals[layout.perm] = np.float32(1.0 / n)
        return vals
    vals = np.full(v_pad, INF_I32, np.int32)
    if spec.name == "wcc":
        # original ids as component seeds: the converged minimum is the
        # same vertex id under every placement (bit-identical results)
        vals[layout.perm] = np.arange(n, dtype=np.int32)
    else:                                          # bfs / sssp
        vals[layout.perm[source]] = 0
    return vals


def init_active(spec: AppSpec, layout, source: int = 0) -> np.ndarray:
    """(v_pad,) bool: who sends in superstep 1 (permuted order)."""
    act = np.zeros(layout.v_pad, bool)
    if spec.name in ("bfs", "sssp"):
        act[layout.perm[source]] = True
    else:
        act[layout.perm] = True
    return act


def finalize_values(spec: AppSpec, values: np.ndarray) -> np.ndarray:
    """Oracle-comparable view: BFS/SSSP unreached -> inf (float), the
    rest pass through."""
    if spec.name in ("bfs", "sssp"):
        out = values.astype(np.float64)
        return np.where(values >= INF_I32, np.inf, out)
    return values
