"""Label-driven vertex->device relayout for the application engine.

Spinner's output is a label per vertex; a Pregel runtime consumes it by
PLACING each partition's vertices on one worker so most edges become
worker-local.  This module turns any label vector (a Spinner
assignment, or the hash baseline) into the engine's existing sharded
layout machinery:

  1. sort vertices by label (stable) and chop the order into ``ndev``
     equal ranges -- device p owns new ids ``[p*v_per_dev + i)``.  With
     ``k == ndev`` and Spinner's balance guarantee this is the
     label->worker mapping of the paper's Giraph deployment; chopping
     EQUAL ranges (rather than one range per label) keeps both
     placements perfectly vertex-balanced, so the hash-vs-spinner
     comparison isolates communication, not load.
  2. permute the graph through that placement and pad to the shared
     power-of-two-ish vertex bucket (``shape_bucket``; pads are
     degree-0 tail vertices on the last devices).
  3. ``shard_graph(..., pad=True)`` -- the SAME range-partitioned
     [interior | frontier] bucketed edge layout, exchange plans, and
     overlap split the LPA engine runs on.

The layout is cached per (graph, ndev, labels digest) through the
engine's weakref cache, so repeated ``run_app`` calls (and the plan /
score-arg / program caches keyed on the inner ``ShardedGraph``) all
reuse one relayout.
"""
from __future__ import annotations

import hashlib

import numpy as np

from repro.core import engine as _engine
from repro.core.distributed import shard_graph
from repro.core.graph import Graph, _finish, shape_bucket

_LAYOUT_CACHE: dict = {}


def placement_from_labels(labels: np.ndarray, ndev: int,
                          v_per_dev: int) -> tuple:
    """(perm, counts): new vertex ids under label-sorted equal chop.

    ``perm[v]`` is vertex v's new id; device p owns new ids
    ``[p * v_per_dev, p * v_per_dev + counts[p])`` with
    ``counts`` the near-equal real-vertex split (pads fill the tail of
    each device's range).  The hash baseline rides the same path with
    hash labels, so both placements share every downstream cache.
    """
    n = len(labels)
    counts = np.full(ndev, n // ndev, np.int64)
    counts[: n % ndev] += 1
    if counts.max() > v_per_dev:
        raise ValueError(f"{n} vertices do not fit {ndev} x {v_per_dev}")
    order = np.argsort(labels, kind="stable")
    perm = np.empty(n, np.int64)
    start = 0
    for p in range(ndev):
        sel = order[start:start + counts[p]]
        perm[sel] = p * v_per_dev + np.arange(counts[p])
        start += counts[p]
    return perm.astype(np.int32), counts.astype(np.int32)


class AppLayout:
    """A placed, padded, sharded view of one (graph, labels, ndev).

    Fields:
      perm: (V,) int32 old->new vertex ids (``placement_from_labels``).
      pgraph: the permuted padded :class:`Graph` (v_pad vertices).
      sg: ``shard_graph(pgraph, ndev, pad=True)`` -- what the exchange
        plans, score-arg caches and the app program bind against.
      counts: (ndev,) real vertices per device (valid mask bound).
      deg_cnt: (ndev, v_per_dev) f32 UNWEIGHTED out-degree (directed
        CSR entries per source) -- PageRank's share divisor, matching
        ``core.pregel``'s oracle which ignores Eq. 3 weights.
      edge_counts: (ndev,) real directed edges stored per device (the
        straggler-skew load proxy).
    """

    def __init__(self, graph: Graph, labels: np.ndarray, ndev: int):
        labels = np.asarray(labels)
        if len(labels) != graph.num_vertices:
            raise ValueError(
                f"labels cover {len(labels)} vertices, graph has "
                f"{graph.num_vertices}")
        v = graph.num_vertices
        v_pad = shape_bucket(v, floor=max(_engine.V_FLOOR, ndev))
        self.ndev = ndev
        self.v_pad = v_pad
        self.v_per_dev = v_pad // ndev
        self.num_real = v
        self.perm, self.counts = placement_from_labels(
            labels, ndev, self.v_per_dev)
        self.pgraph = _finish(self.perm[graph.src], self.perm[graph.dst],
                              graph.weight.astype(np.float32), v_pad)
        self.sg = shard_graph(self.pgraph, ndev, pad=True)
        deg_cnt = np.diff(self.pgraph.row_ptr).astype(np.float32)
        self.deg_cnt = deg_cnt.reshape(ndev, self.v_per_dev)
        self.edge_counts = (np.asarray(self.sg.weight) > 0).sum(axis=1)

    def unpermute(self, values_pad: np.ndarray) -> np.ndarray:
        """Map a (v_pad,) result back to original vertex order, (V,)."""
        return np.asarray(values_pad).reshape(-1)[self.perm]


def _digest(labels: np.ndarray) -> str:
    return hashlib.blake2b(np.ascontiguousarray(labels, np.int64).tobytes(),
                           digest_size=8).hexdigest()


def build_app_layout(graph: Graph, labels: np.ndarray,
                     ndev: int) -> AppLayout:
    """The cached relayout (one per graph x ndev x labels digest)."""
    return _engine._graph_cached(
        _LAYOUT_CACHE, graph, ("app-layout", ndev, _digest(labels)),
        lambda: AppLayout(graph, labels, ndev))
