"""Partition-aware Pregel execution engine: ONE shard_map per run.

The consumer side of Spinner: given any vertex->device placement
(``apps.layout``), run a vertex program to convergence as a single
``jax.jit(shard_map(lax.while_loop))`` dispatch over the device mesh --
the exact architecture of the LPA partitioner engine, re-instantiated
for application state:

  * per superstep every vertex's message value is exchanged through a
    pluggable :class:`repro.core.comm.ExchangePlan` -- the allgather
    oracle, the boundary-only HALO plan (O(cut) values), or the DELTA
    changed-values plan (shrinking-frontier workloads: WCC/BFS send
    only vertices that improved last superstep) -- with per-iteration
    wire bytes accumulated ON DEVICE into the state, exactly as the
    LPA engine's ``exchanged_bytes``;
  * the message combine runs over the layout's [interior | frontier]
    edge split, so the overlap schedule (``start_exchange -> combine
    interior -> finish_exchange -> combine frontier``) is
    dataflow-identical to the sequential one -- bit-identical results,
    collective hidden behind the interior reduction;
  * the combine itself is either XLA scatter ops or the fused Pallas
    combiner (``kernels.pregel_combine``: segmented reduce + vertex
    update per VMEM tile, seeded from the interior partial);
  * programs join the engine's global ``_PROGRAM_CACHE`` keyed on
    static shape/plan/mesh signatures only, so warm re-runs (and the
    hash-vs-spinner A/B on one graph) compile NOTHING new.

Per-device straggler accounting rides in the state: ``msgs[p]`` counts
the messages device p combined (sum of senders' out-degrees), whose
max/mean is the barrier-skew proxy of ``core.pregel``'s simulated-time
model, now measured on device.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec

from repro.core import comm
from repro.core import engine as _engine
from repro.core.graph import Graph, build_sharded_tiled_csr, round_robin_perm
from repro.kernels import ops as kernel_ops
from repro.kernels.pregel_combine import (INF_I32, combine_tiles_finish,
                                          combine_tiles_interior)

from .layout import AppLayout, build_app_layout
from .workloads import APPS, AppSpec, finalize_values, init_active, init_values


class AppState(NamedTuple):
    """The while_loop carry (global view; sharded inside shard_map)."""
    values: jax.Array     # (v_pad,) vertex values, placed+padded order
    changed: jax.Array    # (v_pad,) bool: improved last superstep (senders)
    step: jax.Array       # i32 replicated: supersteps completed
    active: jax.Array     # i32 replicated: global changed count (halting)
    wire: jax.Array       # f32 replicated: cumulative exchanged bytes
    msgs: jax.Array       # (ndev,) f32: messages combined per device


def _app_state_spec(axis: str) -> AppState:
    rep = PartitionSpec()
    return AppState(values=PartitionSpec(axis), changed=PartitionSpec(axis),
                    step=rep, active=rep, wire=rep,
                    msgs=PartitionSpec(axis))


# ---------------------------------------------------------------------------
# Combine closures (per backend x monoid), over the interior/frontier split
# ---------------------------------------------------------------------------

_APP_ARG_CACHE: dict = {}


def _xla_app_args(sg, plan) -> tuple:
    """(si, di, wmi, sf, df, wmf): the split edge blocks with weight MASKS
    (messages combine unweighted; 0 disables layout-padding slots)."""
    e = sg.e_interior
    d_int, d_fro = kernel_ops._split_dst_views(sg, plan.dst_index)
    wm = (np.asarray(sg.weight) > 0).astype(np.float32)
    return tuple(map(jnp.asarray, (sg.src_local[:, :e], d_int, wm[:, :e],
                                   sg.src_local[:, e:], d_fro, wm[:, e:])))


def _pallas_app_args(sg, plan, tile_v: int, tile_e: int) -> tuple:
    """Two segment tilings sharing ONE ``ext_perm`` row layout (the
    `ops.PallasBackend` split idiom), so the interior partial seeds the
    frontier kernel's accumulator row-for-row."""
    e = sg.e_interior
    d_int, d_fro = kernel_ops._split_dst_views(sg, plan.dst_index)
    ext = np.stack([round_robin_perm(sg.deg_w[p], tile_v)
                    for p in range(sg.ndev)])
    seg_i = dataclasses.replace(sg, src_local=sg.src_local[:, :e],
                                dst=sg.dst[:, :e],
                                weight=sg.weight[:, :e], edge_perm=None)
    seg_f = dataclasses.replace(sg, src_local=sg.src_local[:, e:],
                                dst=sg.dst[:, e:],
                                weight=sg.weight[:, e:], edge_perm=None)
    st_i = build_sharded_tiled_csr(seg_i, d_int, tile_v=tile_v,
                                   tile_e=tile_e, ext_perm=ext)
    st_f = build_sharded_tiled_csr(seg_f, d_fro, tile_v=tile_v,
                                   tile_e=tile_e, ext_perm=ext)
    wm_i = (st_i.weight > 0).astype(np.float32)
    wm_f = (st_f.weight > 0).astype(np.float32)
    return tuple(map(jnp.asarray, (st_i.src_local, st_i.dst, wm_i,
                                   st_f.src_local, st_f.dst, wm_f,
                                   st_f.perm, st_f.inv_perm)))


def _make_combine(spec: AppSpec, backend: str, v_local: int,
                  damping: float, tile_v: int, interpret: bool) -> tuple:
    """(interior, finish): interior reduces the local-dst segment from
    the SEND vector (no exchange data -- runs while the collective is in
    flight); finish folds the frontier segment through the plan's lookup
    and applies the vertex update, returning ``(new_values, changed)``.
    Both schedules call the same pair, so overlap on/off is
    bit-identical."""
    bias = spec.bias
    if backend == "xla":
        if spec.combine == "sum":
            def interior(send, si, di, wi, sf, df, wf):
                return jnp.zeros((v_local,), jnp.float32) \
                          .at[si].add(send[di] * wi)

            def finish(partial, lookup, values, valid, base,
                       si, di, wi, sf, df, wf):
                acc = partial.at[sf].add(lookup[df] * wf)
                new = jnp.where(valid, base + damping * acc, 0.0)
                return new, valid
        else:
            inf = jnp.int32(INF_I32)

            def interior(send, si, di, wi, sf, df, wf):
                cand = jnp.where(wi > 0, send[di] + bias, inf)
                return jnp.full((v_local,), inf, jnp.int32) \
                          .at[si].min(cand)

            def finish(partial, lookup, values, valid, base,
                       si, di, wi, sf, df, wf):
                acc = partial.at[sf].min(
                    jnp.where(wf > 0, lookup[df] + bias, inf))
                new = jnp.where(valid, jnp.minimum(values, acc), values)
                return new, jnp.logical_and(new != values, valid)
        return interior, finish

    update = "pagerank" if spec.combine == "sum" else "min"

    def interior(send, si, ii, wmi, sf, fi, wmf, perm, inv_perm):
        return combine_tiles_interior(send, si, ii, wmi, tile_v=tile_v,
                                      combine=spec.combine, bias=bias,
                                      interpret=interpret)

    def finish(partial, lookup, values, valid, base,
               si, ii, wmi, sf, fi, wmf, perm, inv_perm):
        return combine_tiles_finish(partial, lookup, values, valid, base,
                                    sf, fi, wmf, perm, inv_perm,
                                    tile_v=tile_v, combine=spec.combine,
                                    update=update, damping=damping,
                                    bias=bias, interpret=interpret)

    return interior, finish


# ---------------------------------------------------------------------------
# The compiled app program (one per static signature, globally cached)
# ---------------------------------------------------------------------------

def _app_program(spec: AppSpec, mesh: Mesh, axis: str, plan_sig: tuple,
                 combine_sig: tuple, overlap: bool, n_steps: int,
                 damping: float, n_score: int) -> "_engine.Program":
    """The jitted ``shard_map(while_loop)`` runner for one static
    (workload, mesh, plan signature, combine backend, schedule) tuple.
    Traces against an array-free ``plan_from_signature`` view and joins
    the engine's global ``_PROGRAM_CACHE``, so every graph whose layout
    lands in the same shape bucket -- and both placements of ONE graph
    -- share a single compiled executable."""
    key = ("app", spec.name, spec.combine, spec.bias, spec.halts, mesh,
           axis, plan_sig, combine_sig, overlap, n_steps, float(damping),
           n_score)
    ndev = mesh.shape[axis]

    def build():
        plan = comm.plan_from_signature(plan_sig)
        v_local = plan_sig[2] if plan_sig[0] != "allgather" \
            else plan_sig[2] // ndev
        backend, tile_v, _tile_e, interpret = combine_sig
        interior_fn, finish_fn = _make_combine(
            spec, backend, v_local, damping, tile_v, interpret)
        pagerank = spec.combine == "sum"
        halts = spec.halts
        plan_specs = tuple(plan.arg_specs(axis))
        # sharded args arrive with a leading length-1 shard dim to strip
        strip = (False, True, True) + (True,) * n_score \
            + tuple(s == PartitionSpec(axis) for s in plan_specs)

        def run_local(state, base, counts, deg, *rest):
            blocks = tuple(r[0] if s else r
                           for r, s in zip((base, counts, deg) + rest,
                                           strip))
            base_l, count_l, deg_l = blocks[:3]
            score_blocks = blocks[3:3 + n_score]
            plan_blocks = blocks[3 + n_score:]
            valid = jax.lax.broadcasted_iota(
                jnp.int32, (v_local,), 0) < count_l

            def to_msg(vals):
                return vals / jnp.maximum(deg_l, 1.0) if pagerank else vals

            def body(carry):
                s, aux = carry
                send = to_msg(s.values)
                if overlap:
                    pending = plan.start_exchange(send, aux, axis,
                                                  *plan_blocks)
                    partial = interior_fn(send, *score_blocks)
                    lookup, aux, xb = plan.finish_exchange(pending)
                else:
                    lookup, aux, xb = plan.exchange(send, aux, axis,
                                                    *plan_blocks)
                    partial = interior_fn(send, *score_blocks)
                new, chg = finish_fn(partial, lookup, s.values, valid,
                                     base_l, *score_blocks)
                # messages combined here = senders' out-degrees (each
                # sender's out-edges terminate at exactly one combiner)
                msgs = s.msgs + jnp.sum(
                    deg_l * s.changed.astype(jnp.float32))[None]
                n_act = jax.lax.psum(jnp.sum(chg.astype(jnp.int32)), axis)
                return AppState(values=new, changed=chg, step=s.step + 1,
                                active=n_act, wire=s.wire + xb,
                                msgs=msgs), aux

            def cond(carry):
                s = carry[0]
                go = s.step < jnp.int32(n_steps)
                if halts:
                    go = jnp.logical_and(go, s.active > 0)
                return go

            aux0 = plan.init_aux(to_msg(state.values), axis, *plan_blocks)
            final, _ = jax.lax.while_loop(cond, body, (state, aux0))
            return final

        spec_s = _app_state_spec(axis)
        rep = PartitionSpec()
        arg_specs = (rep, PartitionSpec(axis), PartitionSpec(axis)) \
            + (PartitionSpec(axis),) * n_score + plan_specs
        return jax.jit(shard_map(
            run_local, mesh=mesh, in_specs=(spec_s,) + arg_specs,
            out_specs=spec_s, check_rep=False))

    return _engine._program(key, build)


# ---------------------------------------------------------------------------
# Public entry
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AppResult:
    """One application run on one placement.

    ``values`` is in ORIGINAL vertex order, oracle-comparable
    (BFS/SSSP: float with inf for unreached).  ``wire_bytes`` is the
    on-device-accumulated total the exchange plan moved;
    ``device_messages`` the per-device combined-message counts whose
    ``straggler_skew`` (max/mean) is the barrier-idle proxy of the
    paper's Table 4 model; ``edge_counts`` the per-device stored-edge
    load.  ``program`` is the cached compiled runner (session compile
    accounting)."""
    workload: str
    plan: str
    ndev: int
    values: np.ndarray
    supersteps: int
    converged: bool
    wire_bytes: float
    wire_bytes_per_step: float
    device_messages: np.ndarray
    straggler_skew: float
    edge_counts: np.ndarray
    program: object = dataclasses.field(repr=False, default=None)


def run_app(graph: Graph, labels: np.ndarray, workload: str, *,
            mesh: Optional[Mesh] = None, axis: str = "data",
            plan: Optional[str] = None, combine: str = "xla",
            overlap: bool = True, iters: Optional[int] = None,
            max_steps: Optional[int] = None, source: int = 0,
            damping: float = 0.85, delta_cap: Optional[int] = None,
            tile_v: int = 128, tile_e: int = 128,
            interpret: Optional[bool] = None) -> AppResult:
    """Run ``workload`` on ``graph`` placed by ``labels`` -- one dispatch.

    ``labels`` is ANY per-vertex assignment: a Spinner partition, or the
    hash baseline (``benchmarks.common.hash_labels``); the layout,
    exchange plan, edge blocks and compiled program are all cached, so
    an A/B between placements costs two dispatches and zero recompiles.

    ``plan`` defaults per workload (halo for PageRank's dense frontier,
    delta for WCC/BFS's shrinking one); ``combine`` picks the XLA
    scatter path or the fused Pallas combiner (``"pallas"``, interpret
    mode off-TPU).  ``overlap`` toggles the in-flight-collective
    schedule (bit-identical either way).
    """
    spec = APPS.get(workload)
    if spec is None:
        raise ValueError(f"unknown workload {workload!r}; "
                         f"available: {', '.join(sorted(APPS))}")
    if mesh is None:
        mesh = _engine._default_partition_mesh()
    ndev = mesh.shape[axis]
    layout = build_app_layout(graph, labels, ndev)
    plan_name = plan or spec.default_plan
    plan_obj = comm.make_exchange_plan(plan_name, layout.sg,
                                       delta_cap=delta_cap, pad=True)
    if spec.halts:
        n_steps = max_steps or spec.default_iters
    else:
        n_steps = iters or spec.default_iters
    if combine == "pallas":
        if interpret is None:
            interpret = kernel_ops._default_interpret()
        combine_sig = ("pallas", tile_v, tile_e, bool(interpret))
        args_of = lambda: _pallas_app_args(layout.sg, plan_obj,
                                           tile_v, tile_e)
    elif combine == "xla":
        combine_sig = ("xla", 0, 0, False)
        args_of = lambda: _xla_app_args(layout.sg, plan_obj)
    else:
        raise ValueError(f"combine must be 'xla' or 'pallas', "
                         f"got {combine!r}")
    dst_layout = "halo" if plan_obj.dst_index is not layout.sg.dst \
        else "global"
    score_args = _engine._graph_cached(
        _APP_ARG_CACHE, layout.sg, ("app", combine_sig, dst_layout),
        args_of)
    prog = _app_program(spec, mesh, axis, plan_obj.signature(),
                        combine_sig, overlap, n_steps, damping,
                        len(score_args))
    vals0 = init_values(spec, layout, source)
    act0 = init_active(spec, layout, source)
    state0 = AppState(
        values=jnp.asarray(vals0), changed=jnp.asarray(act0),
        step=jnp.int32(0), active=jnp.int32(int(act0.sum())),
        wire=jnp.float32(0),
        msgs=jnp.zeros((ndev,), jnp.float32))
    final = prog.run(state0, jnp.float32((1.0 - damping) / layout.num_real),
                     jnp.asarray(layout.counts), jnp.asarray(layout.deg_cnt),
                     *score_args, *plan_obj.device_args())
    supersteps = int(final.step)
    msgs = np.asarray(final.msgs, np.float64)
    skew = float(msgs.max() / msgs.mean()) if msgs.sum() > 0 else 1.0
    values = finalize_values(spec, layout.unpermute(np.asarray(final.values)))
    return AppResult(
        workload=spec.name, plan=plan_name, ndev=ndev, values=values,
        supersteps=supersteps,
        converged=(not spec.halts) or int(final.active) == 0,
        wire_bytes=float(final.wire),
        wire_bytes_per_step=float(final.wire) / max(supersteps, 1),
        device_messages=msgs, straggler_skew=skew,
        edge_counts=np.asarray(layout.edge_counts, np.int64),
        program=prog)
