from . import pipeline
