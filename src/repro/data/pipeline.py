"""Deterministic synthetic data pipeline.

Tokens are drawn from a learnable synthetic language: each sequence repeats
a document "motif" (one of a small pool of random n-grams) with occasional
uniform noise, so cross-entropy drops measurably within a few hundred steps
-- enough signal for the end-to-end training example and the fault-tolerance
(restart-bitexactness) tests.  Batches are a pure function of
(seed, step, shard), so any worker can regenerate any shard of any step:
this is the elastic/fault-tolerant contract (no data-state checkpointing
needed beyond the step counter).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_motifs: int = 64
    motif_len: int = 16
    noise: float = 0.05


def _motifs(cfg: DataConfig) -> np.ndarray:
    rng = np.random.default_rng(cfg.seed)
    return rng.integers(1, cfg.vocab, size=(cfg.n_motifs, cfg.motif_len),
                        dtype=np.int32)


def batch_at(cfg: DataConfig, step: int, shard: int = 0,
             num_shards: int = 1) -> dict:
    """The (step, shard) batch as numpy int32 arrays {tokens, labels}."""
    assert cfg.global_batch % num_shards == 0
    bsz = cfg.global_batch // num_shards
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, shard]))
    motifs = _motifs(cfg)
    ids = rng.integers(0, cfg.n_motifs, size=bsz)
    reps = -(-(cfg.seq_len + 1) // cfg.motif_len)
    seq = np.tile(motifs[ids], (1, reps))[:, : cfg.seq_len + 1]
    noise_mask = rng.random(seq.shape) < cfg.noise
    seq = np.where(noise_mask,
                   rng.integers(1, cfg.vocab, size=seq.shape), seq)
    return {"tokens": seq[:, :-1].astype(np.int32),
            "labels": seq[:, 1:].astype(np.int32)}


def stream(cfg: DataConfig, start_step: int = 0, shard: int = 0,
           num_shards: int = 1) -> Iterator[dict]:
    step = start_step
    while True:
        yield batch_at(cfg, step, shard, num_shards)
        step += 1


def for_model(model: ModelConfig, shape: ShapeConfig, seed: int = 0
              ) -> DataConfig:
    return DataConfig(vocab=model.vocab, seq_len=shape.seq_len,
                      global_batch=shape.global_batch, seed=seed)


def frontend_stub(model: ModelConfig, shape: ShapeConfig, step: int,
                  seed: int = 0) -> Optional[np.ndarray]:
    """Precomputed modality embeddings for [audio]/[vlm] backbones."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, 777]))
    if model.family == "encdec":
        shp = (shape.global_batch, shape.seq_len, model.d_model)
    elif model.family == "vlm":
        shp = (shape.global_batch, model.n_img_tokens, model.d_model)
    else:
        return None
    return (rng.standard_normal(shp) * 0.02).astype(np.float32)
