"""Mamba2 (SSD) blocks and the Zamba2 hybrid stack.

Mamba2's scalar-per-head decay makes the chunked scan fully MXU-friendly:
the intra-chunk kernel is (C @ B^T) elementwise-scaled by a (chunk, chunk)
decay matrix per head, and the carried state is (H, N, hd) per sequence.

Zamba2 (arXiv:2411.15242): 81 Mamba2 blocks with ONE weight-shared
attention(+MLP) block applied after every 6th Mamba2 block (13
applications) plus a 3-block tail.  Simplifications vs the checkpoint
(DESIGN.md): the shared block consumes the current hidden state (no
concat-with-embedding projection), conv is applied to x only (not B/C).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .attention import KVCache, attention, attn_param_specs
from .common import (COMPUTE_DTYPE, cast, dense, rms_norm,
                     softmax_cross_entropy, spec, swiglu)


class MambaState(NamedTuple):
    conv: jax.Array   # (..., B, W-1, d_in)   conv tail carry
    s: jax.Array      # (..., B, H, N, hd)    SSD state


class ZambaState(NamedTuple):
    mamba: MambaState          # leading dims (n_groups, period) / tail (tail,)
    tail: MambaState
    attn: KVCache              # (n_groups, B, S_max, KV, hd)
    pos: jax.Array             # scalar int32 (tokens written)


def _dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    h = d_in // cfg.ssm_head_dim
    return d_in, h, cfg.ssm_state


def mamba_param_specs(cfg: ModelConfig, prefix_shape: Tuple[int, ...]) -> dict:
    d = cfg.d_model
    d_in, h, n = _dims(cfg)
    ps = prefix_shape
    return {
        "norm": spec(*ps, d),
        "wz": spec(*ps, d, d_in),
        "wx": spec(*ps, d, d_in),
        "wB": spec(*ps, d, n),
        "wC": spec(*ps, d, n),
        "wdt": spec(*ps, d, h),
        "conv_w": spec(*ps, cfg.conv_width, d_in),
        "conv_bias": spec(*ps, d_in),
        "A_log": spec(*ps, h),
        "skip_D": spec(*ps, h),
        "dt_bias": spec(*ps, h),
        "gn_scale": spec(*ps, d_in),
        "out_proj": spec(*ps, d_in, d),
    }


def _causal_conv(x: jax.Array, w: jax.Array, bias: jax.Array,
                 carry: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv, width W; x: (B, S, C), w: (W, C).

    ``carry`` is the previous W-1 inputs (B, W-1, C); returns new carry.
    """
    bsz, s, c = x.shape
    wdt = w.shape[0]
    if carry is None:
        carry = jnp.zeros((bsz, wdt - 1, c), x.dtype)
    ext = jnp.concatenate([carry, x], axis=1)          # (B, S+W-1, C)
    out = jnp.zeros((bsz, s, c), jnp.float32)
    for j in range(wdt):
        out = out + ext[:, j:j + s, :].astype(jnp.float32) \
            * w[j].astype(jnp.float32)
    out = out + bias.astype(jnp.float32)
    new_carry = ext[:, -(wdt - 1):, :] if wdt > 1 else carry
    return jax.nn.silu(out).astype(COMPUTE_DTYPE), new_carry


def ssd_chunked(xh, Bc, Cc, dt, a_log, s0, chunk: int):
    """Chunked SSD scan.

    xh: (B, S, H, hd); Bc/Cc: (B, S, N); dt: (B, S, H) (post-softplus);
    a_log: (H,) (negative); s0: (B, H, N, hd).
    Recurrence: S_t = exp(dt_t a_log) S_{t-1} + dt_t B_t (x) xh_t;
                y_t = C_t . S_t.
    """
    b, s, h, hd = xh.shape
    n = Bc.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    la_step = dt * a_log[None, None, :]                # (B,S,H) <= 0

    def resh(x):
        return x.reshape(b, nc, chunk, *x.shape[2:]).transpose(
            1, 0, 2, *range(3, x.ndim + 1))

    xc, bc, cc, dc, lc = map(resh, (xh, Bc, Cc, dt, la_step))

    def step(S, xs):
        xb, bb, cb, db, lb = (x.astype(jnp.float32) for x in xs)
        lai = jnp.cumsum(lb, axis=1)                   # (B,C,H) inclusive
        # intra: P[t,s,h] = (C_t . B_s) exp(lai_t - lai_s) dt_s, s <= t
        cb_ = jnp.einsum("btn,bsn->bts", cb, bb)       # (B,C,C)
        dm = lai[:, :, None, :] - lai[:, None, :, :]   # (B,C,C,H)
        tri = jnp.tril(jnp.ones((xb.shape[1], xb.shape[1]), bool))
        dm = jnp.where(tri[None, :, :, None], dm, -jnp.inf)
        P = cb_[..., None] * jnp.exp(dm) * db[:, None, :, :]
        intra = jnp.einsum("btsh,bshd->bthd", P, xb)
        inter = jnp.einsum("btn,bth,bhnd->bthd", cb, jnp.exp(lai), S)
        out = intra + inter
        tail = lai[:, -1:, :]                          # (B,1,H)
        S_new = (jnp.exp(tail[:, 0])[:, :, None, None] * S
                 + jnp.einsum("bsn,bsh,bshd->bhnd",
                              bb, db * jnp.exp(tail - lai), xb))
        return S_new, out.astype(COMPUTE_DTYPE)

    s_fin, outs = jax.lax.scan(jax.checkpoint(step), s0.astype(jnp.float32),
                               (xc, bc, cc, dc, lc))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)
    return out, s_fin


def ssd_ref(xh, Bc, Cc, dt, a_log, s0):
    """Step-by-step oracle."""
    def step(S, xs):
        xt, bt, ct, dtt = (x.astype(jnp.float32) for x in xs)
        decay = jnp.exp(dtt * a_log.astype(jnp.float32))   # (B,H)
        S = decay[:, :, None, None] * S + jnp.einsum(
            "bn,bh,bhd->bhnd", bt, dtt, xt)
        y = jnp.einsum("bn,bhnd->bhd", ct, S)
        return S, y

    xs = (xh.transpose(1, 0, 2, 3), Bc.transpose(1, 0, 2),
          Cc.transpose(1, 0, 2), dt.transpose(1, 0, 2))
    s_fin, outs = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return outs.transpose(1, 0, 2, 3).astype(COMPUTE_DTYPE), s_fin


def mamba_block(x, lp, cfg: ModelConfig, state: MambaState
                ) -> Tuple[jax.Array, MambaState]:
    """x: (B, S, d) -> (out, new_state)."""
    b, s, d = x.shape
    d_in, h, n = _dims(cfg)
    hd = cfg.ssm_head_dim
    hx = rms_norm(x, lp["norm"], cfg.norm_eps)

    z = dense(hx, lp["wz"])
    xin = dense(hx, lp["wx"])
    Bc = dense(hx, lp["wB"]).astype(jnp.float32)
    Cc = dense(hx, lp["wC"]).astype(jnp.float32)
    dt = jax.nn.softplus(dense(hx, lp["wdt"]).astype(jnp.float32)
                         + lp["dt_bias"].astype(jnp.float32))

    xin, conv_new = _causal_conv(xin, lp["conv_w"], lp["conv_bias"],
                                 state.conv)
    xh = xin.reshape(b, s, h, hd)
    a_log = -jnp.exp(jnp.clip(lp["A_log"].astype(jnp.float32), -8.0, 6.0))
    y, s_new = ssd_chunked(xh, Bc, Cc, dt, a_log, state.s, cfg.seq_chunk)
    y = y + lp["skip_D"].astype(jnp.float32)[None, None, :, None] \
        * xh.astype(jnp.float32)
    y = y.reshape(b, s, d_in)
    y = rms_norm((y.astype(jnp.float32)
                  * jax.nn.silu(z.astype(jnp.float32))).astype(COMPUTE_DTYPE),
                 lp["gn_scale"], cfg.norm_eps)
    out = dense(y, lp["out_proj"])
    return x + out, MambaState(conv_new, s_new)


def mamba_state_specs(cfg: ModelConfig, batch: int,
                      prefix_shape: Tuple[int, ...]) -> MambaState:
    d_in, h, n = _dims(cfg)
    return MambaState(
        spec(*prefix_shape, batch, cfg.conv_width - 1, d_in,
             dtype=COMPUTE_DTYPE),
        spec(*prefix_shape, batch, h, n, cfg.ssm_head_dim,
             dtype=jnp.float32))


# ---------------------------------------------------------------------------
# Zamba2 hybrid stack
# ---------------------------------------------------------------------------

def _zamba_shape(cfg: ModelConfig) -> Tuple[int, int]:
    groups = cfg.n_layers // cfg.attn_period
    tail = cfg.n_layers - groups * cfg.attn_period
    return groups, tail


def shared_attn_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    return {
        "attn_norm": spec(d),
        "attn": attn_param_specs(d, cfg.n_heads, cfg.n_kv_heads, cfg.hd),
        "mlp_norm": spec(d),
        "w1": spec(d, cfg.d_ff), "w3": spec(d, cfg.d_ff),
        "w2": spec(cfg.d_ff, d),
    }


def param_specs(cfg: ModelConfig) -> dict:
    groups, tail = _zamba_shape(cfg)
    p = {
        "embed": spec(cfg.vocab_padded, cfg.d_model),
        "mamba": mamba_param_specs(cfg, (groups, cfg.attn_period)),
        "shared_attn": shared_attn_specs(cfg),
        "final_norm": spec(cfg.d_model),
        "lm_head": spec(cfg.d_model, cfg.vocab_padded),
    }
    if tail:
        p["mamba_tail"] = mamba_param_specs(cfg, (tail,))
    return p


def _shared_block(x, sp, cfg: ModelConfig, cache: Optional[KVCache],
                  pos, return_cache: bool):
    h = rms_norm(x, sp["attn_norm"], cfg.norm_eps)
    a, new_cache = attention(
        h, sp["attn"], n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.hd, rope_theta=cfg.rope_theta, causal=True,
        chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv,
        cache=cache, pos=pos, return_cache=return_cache)
    x = x + a
    h = rms_norm(x, sp["mlp_norm"], cfg.norm_eps)
    return x + swiglu(h, sp["w1"], sp["w3"], sp["w2"]), new_cache


def state_specs(cfg: ModelConfig, batch: int, cache_len: int) -> ZambaState:
    groups, tail = _zamba_shape(cfg)
    return ZambaState(
        mamba=mamba_state_specs(cfg, batch, (groups, cfg.attn_period)),
        tail=mamba_state_specs(cfg, batch, (max(tail, 1),)),
        attn=KVCache(
            spec(groups, batch, cache_len, cfg.n_kv_heads, cfg.hd,
                 dtype=COMPUTE_DTYPE),
            spec(groups, batch, cache_len, cfg.n_kv_heads, cfg.hd,
                 dtype=COMPUTE_DTYPE)),
        pos=spec(dtype=jnp.int32))


def init_state(cfg: ModelConfig, batch: int, cache_len: int) -> ZambaState:
    s = state_specs(cfg, batch, cache_len)
    return jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), s,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _run_stack(params, x, cfg: ModelConfig, state: ZambaState, *,
               mode: str, pos=None):
    """mode: 'train' (no caches), 'prefill' (fill caches), 'decode'."""
    groups, tail = _zamba_shape(cfg)
    decode = mode == "decode"

    def group_body(carry, xs):
        h = carry
        mp, mstate, k_g, v_g = xs

        def mamba_scan(hh, layer):
            lp, st = layer
            hh, st2 = mamba_block(hh, lp, cfg, MambaState(*st))
            return hh, st2

        h, mstates = jax.lax.scan(mamba_scan, h,
                                  (mp, (mstate.conv, mstate.s)))
        cache = KVCache(k_g, v_g) if decode else None
        h, new_cache = _shared_block(h, params["shared_attn"], cfg, cache,
                                     pos, return_cache=mode == "prefill")
        kv = new_cache if new_cache is not None else KVCache(k_g, v_g)
        return h, (mstates, kv)

    body = group_body
    if cfg.remat and mode == "train":
        body = jax.checkpoint(group_body)
    x, (mstates, kvs) = jax.lax.scan(
        body, x, (params["mamba"], state.mamba, state.attn.k, state.attn.v))

    new_tail = state.tail
    if tail:
        def tail_scan(hh, layer):
            lp, st = layer
            hh, st2 = mamba_block(hh, lp, cfg, MambaState(*st))
            return hh, st2

        x, new_tail = jax.lax.scan(
            tail_scan, x, (params["mamba_tail"],
                           (state.tail.conv, state.tail.s)))
        new_tail = MambaState(*new_tail)

    new_state = ZambaState(mamba=MambaState(*mstates), tail=new_tail,
                           attn=KVCache(kvs.k, kvs.v),
                           pos=(pos + 1 if pos is not None else state.pos))
    return x, new_state


def forward(params, tokens, cfg: ModelConfig):
    from .dense import embed, lm_logits
    x = embed(params, tokens)
    state = init_state(cfg, tokens.shape[0], 8)
    x, _ = _run_stack(params, x, cfg, state, mode="train")
    return lm_logits(params, x, cfg)


def loss_fn(params, batch, cfg: ModelConfig):
    logits = forward(params, batch["tokens"], cfg)
    return softmax_cross_entropy(logits, batch["labels"])


def prefill(params, tokens, cfg: ModelConfig, cache_len: Optional[int] = None):
    from .dense import embed, lm_logits
    b, s = tokens.shape
    cache_len = cache_len or s
    x = embed(params, tokens)
    state = init_state(cfg, b, cache_len)
    x, state = _run_stack(params, x, cfg, state, mode="prefill")
    # pad prefill caches to cache_len
    def pad(c):
        return jnp.pad(c, ((0, 0), (0, 0), (0, cache_len - s), (0, 0),
                           (0, 0))) if c.shape[2] < cache_len else c
    state = state._replace(attn=KVCache(pad(state.attn.k), pad(state.attn.v)),
                           pos=jnp.int32(s))
    return lm_logits(params, x[:, -1:, :], cfg), state


def decode_step(params, token, pos, state: ZambaState, cfg: ModelConfig):
    from .dense import embed, lm_logits
    x = embed(params, token[:, None])
    x, state = _run_stack(params, x, cfg, state, mode="decode", pos=pos)
    return lm_logits(params, x, cfg), state
