"""VLM backbone (llama-3.2-vision-11b): decoder with gated cross-attention.

Backbone only: the vision tower is a stub; ``input_specs`` provides
precomputed patch embeddings (B, n_img_tokens, d_model).  Layout follows
Llama-3.2-Vision: every ``cross_attn_period``-th layer is a gated
cross-attention(+MLP) layer -- with period 5 over 40 layers the stack is 8
groups of (4 self layers + 1 cross layer), scanned at both levels.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .attention import (KVCache, attention, attn_param_specs,
                        decode_attention)
from .common import (COMPUTE_DTYPE, cast, dense, rms_norm,
                     softmax_cross_entropy, spec, swiglu)
from .dense import embed, layer_param_specs, lm_logits
from .dense import _layer as self_layer


class VLMCache(NamedTuple):
    self_kv: KVCache     # (G, P-1, B, S_max, KV, hd)
    cross_kv: KVCache    # (G, B, n_img, KV, hd)


def _shape(cfg: ModelConfig) -> Tuple[int, int]:
    period = cfg.cross_attn_period
    assert cfg.n_layers % period == 0, "layers must tile into groups"
    return cfg.n_layers // period, period


def param_specs(cfg: ModelConfig) -> dict:
    groups, period = _shape(cfg)
    d = cfg.d_model
    cross = {
        "norm": spec(groups, d),
        "attn": attn_param_specs(d, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                                 prefix_shape=(groups,)),
        "gate_attn": spec(groups),
        "mlp_norm": spec(groups, d),
        "w1": spec(groups, d, cfg.d_ff),
        "w3": spec(groups, d, cfg.d_ff),
        "w2": spec(groups, cfg.d_ff, d),
        "gate_mlp": spec(groups),
    }
    # self layers: (groups, period-1, ...)
    import dataclasses
    sub = dataclasses.replace(cfg)  # same dims
    self_specs = layer_param_specs(sub, period - 1)
    self_specs = jax.tree.map(
        lambda s: spec(groups, *s.shape, dtype=s.dtype), self_specs)
    return {
        "embed": spec(cfg.vocab, d),
        "self_layers": self_specs,
        "cross_layers": cross,
        "img_norm": spec(d),
        "final_norm": spec(d),
        "lm_head": spec(d, cfg.vocab),
    }


def _cross_layer(x, cp, cfg: ModelConfig, img=None, cross_cache=None,
                 return_cache=False):
    h = rms_norm(x, cp["norm"], cfg.norm_eps)
    if cross_cache is not None:
        b = h.shape[0]
        q = dense(h, cp["attn"]["wq"]).reshape(b, 1, cfg.n_heads, cfg.hd)
        o = decode_attention(q, cross_cache,
                             jnp.int32(cross_cache.k.shape[1] - 1))
        a = dense(o.reshape(b, 1, -1), cp["attn"]["wo"])
        new_cache = cross_cache
    else:
        a, new_cache = attention(
            h, cp["attn"], n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.hd, rope_theta=None, causal=False,
            chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv,
            memory=img, return_cache=return_cache)
    x = x + jnp.tanh(cp["gate_attn"]).astype(COMPUTE_DTYPE) * a
    m = swiglu(rms_norm(x, cp["mlp_norm"], cfg.norm_eps),
               cp["w1"], cp["w3"], cp["w2"])
    x = x + jnp.tanh(cp["gate_mlp"]).astype(COMPUTE_DTYPE) * m
    return x, new_cache


def forward(params, tokens, img_embed, cfg: ModelConfig) -> jax.Array:
    x = embed(params, tokens)
    img = rms_norm(cast(img_embed), params["img_norm"], cfg.norm_eps)

    def group(h, gp):
        sp, cp = gp

        def body(hh, lp):
            hh, _ = self_layer(hh, lp, cfg)
            return hh, None

        h, _ = jax.lax.scan(body, h, sp)
        h, _ = _cross_layer(h, cp, cfg, img=img)
        return h, None

    if cfg.remat:
        group = jax.checkpoint(group)
    x, _ = jax.lax.scan(group, x,
                        (params["self_layers"], params["cross_layers"]))
    return lm_logits(params, x, cfg)


def loss_fn(params, batch, cfg: ModelConfig) -> jax.Array:
    logits = forward(params, batch["tokens"], batch["img_embed"], cfg)
    return softmax_cross_entropy(logits, batch["labels"])


def prefill(params, tokens, img_embed, cfg: ModelConfig
            ) -> Tuple[jax.Array, VLMCache]:
    x = embed(params, tokens)
    img = rms_norm(cast(img_embed), params["img_norm"], cfg.norm_eps)

    def group(h, gp):
        sp, cp = gp

        def body(hh, lp):
            hh, kv = self_layer(hh, lp, cfg, return_cache=True)
            return hh, kv

        h, self_kv = jax.lax.scan(body, h, sp)
        h, cross_kv = _cross_layer(h, cp, cfg, img=img, return_cache=True)
        return h, (self_kv, cross_kv)

    if cfg.remat:
        group = jax.checkpoint(group)
    x, (skv, ckv) = jax.lax.scan(
        group, x, (params["self_layers"], params["cross_layers"]))
    return (lm_logits(params, x[:, -1:, :], cfg),
            VLMCache(KVCache(*skv), KVCache(*ckv)))


def decode_step(params, token, pos, cache: VLMCache, cfg: ModelConfig):
    x = embed(params, token[:, None])

    def group(h, xs):
        sp, cp, sk, sv, ck, cv = xs

        def body(hh, lp_kv):
            lp, k_l, v_l = lp_kv
            hh, kv = self_layer(hh, lp, cfg, cache=KVCache(k_l, v_l),
                                pos=pos)
            return hh, kv

        h, self_kv = jax.lax.scan(body, h, (sp, sk, sv))
        h, _ = _cross_layer(h, cp, cfg, cross_cache=KVCache(ck, cv))
        return h, self_kv

    x, skv = jax.lax.scan(
        group, x, (params["self_layers"], params["cross_layers"],
                   cache.self_kv.k, cache.self_kv.v,
                   cache.cross_kv.k, cache.cross_kv.v))
    return lm_logits(params, x, cfg), VLMCache(KVCache(*skv), cache.cross_kv)


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int) -> VLMCache:
    groups, period = _shape(cfg)
    kv, hd = cfg.n_kv_heads, cfg.hd
    return VLMCache(
        KVCache(spec(groups, period - 1, batch, seq_len, kv, hd,
                     dtype=COMPUTE_DTYPE),
                spec(groups, period - 1, batch, seq_len, kv, hd,
                     dtype=COMPUTE_DTYPE)),
        KVCache(spec(groups, batch, cfg.n_img_tokens, kv, hd,
                     dtype=COMPUTE_DTYPE),
                spec(groups, batch, cfg.n_img_tokens, kv, hd,
                     dtype=COMPUTE_DTYPE)))
