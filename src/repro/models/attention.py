"""GQA attention: chunked (flash-style in XLA) prefill/train + cached decode.

Long sequences never materialize the (S, S) score matrix: queries and keys
are processed in (chunk_q, chunk_kv) blocks with an online-softmax
accumulator carried through ``lax.scan`` -- the XLA analogue of flash
attention, and the natural lowering target for a future Pallas port.
Head dims stay intact through every einsum so a 'model'-sharded head axis
induces no collectives.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import COMPUTE_DTYPE, apply_rope, cast, dense, rope_angles

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jax.Array          # (B, S_max, KV, hd)
    v: jax.Array          # (B, S_max, KV, hd)


def _split_heads(x: jax.Array, n: int, hd: int) -> jax.Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n, hd)


def _chunk_qkv(q, k, v, chunk_q, chunk_kv):
    b, sq, h, hd = q.shape
    _, skv, kvh, _ = k.shape
    g = h // kvh
    nq, nk = sq // chunk_q, skv // chunk_kv
    # blocks in (B, KV, G, C, hd) layout, chunk index leading for scan
    qc = q.reshape(b, nq, chunk_q, kvh, g, hd).transpose(1, 0, 3, 4, 2, 5)
    kc = k.reshape(b, nk, chunk_kv, kvh, hd).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, nk, chunk_kv, kvh, hd).transpose(1, 0, 3, 2, 4)
    return qc, kc, vc, (b, kvh, g, nq, nk)


def _scores(qblk, kblk, scale, causal, qpos, kpos):
    """(B, KV, G, Cq, Ckv) masked logits block, fp32."""
    s = jax.lax.dot_general(
        cast(qblk), cast(kblk), (((4,), (3,)), ((0, 1), (0, 1))),
        preferred_element_type=jnp.float32) * scale
    if causal:
        mask = kpos[None, :] <= qpos[:, None]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    return s


def _flash_fwd(q, k, v, causal, chunk_q, chunk_kv, q_offset):
    """Returns (out (B,Sq,H,hd), lse (B,KV,G,Sq))."""
    b, sq, h, hd = q.shape
    scale = hd ** -0.5
    qc, kc, vc, (_, kvh, g, nq, nk) = _chunk_qkv(q, k, v, chunk_q, chunk_kv)

    def q_step(_, qi):
        qblk, iq = qi                       # (B, KV, G, Cq, hd)
        qpos = q_offset + iq * chunk_q + jnp.arange(chunk_q)

        def kv_step(carry, kj):
            m, l, acc = carry
            kblk, vblk, jk = kj
            kpos = jk * chunk_kv + jnp.arange(chunk_kv)
            s = _scores(qblk, kblk, scale, causal, qpos, kpos)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jax.lax.dot_general(      # (B, KV, G, Cq, hd)
                p.astype(COMPUTE_DTYPE), cast(vblk),
                (((4,), (2,)), ((0, 1), (0, 1))),
                preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kvh, g, chunk_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, chunk_q), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, chunk_q, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (kc, vc, jnp.arange(nk)))
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(COMPUTE_DTYPE)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return None, (out, lse)

    _, (out, lse) = jax.lax.scan(q_step, None, (qc, jnp.arange(nq)))
    # out: (nq, B, KV, G, Cq, hd) -> (B, Sq, H, hd)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, h, hd)
    lse = lse.transpose(1, 2, 3, 0, 4).reshape(b, kvh, g, sq)
    return out, lse


def _flash_bwd_impl(q, k, v, out, lse, dout, causal, chunk_q, chunk_kv,
                    q_offset):
    """Standard flash backward: recompute p blockwise.

    dq accumulates along the q-chunk scan (emitted as ys); dk/dv are
    full-size fp32 carries updated chunk-in-place.
    """
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    scale = hd ** -0.5
    qc, kc, vc, (_, _, g, nq, nk) = _chunk_qkv(q, k, v, chunk_q, chunk_kv)
    doc = dout.reshape(b, nq, chunk_q, kvh, g, hd).transpose(1, 0, 3, 4, 2, 5)
    lsec = lse.reshape(b, kvh, g, nq, chunk_q).transpose(3, 0, 1, 2, 4)
    outc = out.reshape(b, nq, chunk_q, kvh, g, hd).transpose(1, 0, 3, 4, 2, 5)

    def q_step(carry, xs):
        dk_all, dv_all = carry               # (nk, B, KV, Ckv, hd) fp32
        qblk, dblk, oblk, lseb, iq = xs
        qpos = q_offset + iq * chunk_q + jnp.arange(chunk_q)
        delta = jnp.sum(dblk.astype(jnp.float32)
                        * oblk.astype(jnp.float32), axis=-1)  # (B,KV,G,Cq)

        def kv_step(inner, kj):
            dq_acc, dk_all, dv_all = inner
            kblk, vblk, jk = kj
            kpos = jk * chunk_kv + jnp.arange(chunk_kv)
            s = _scores(qblk, kblk, scale, causal, qpos, kpos)
            p = jnp.exp(s - lseb[..., None])                  # (B,KV,G,Cq,Ckv)
            dp = jax.lax.dot_general(                         # dout @ v^T
                dblk.astype(COMPUTE_DTYPE), cast(vblk),
                (((4,), (3,)), ((0, 1), (0, 1))),
                preferred_element_type=jnp.float32)
            ds = p * (dp - delta[..., None])                  # fp32
            dsc = ds.astype(COMPUTE_DTYPE)
            dq_acc = dq_acc + jax.lax.dot_general(            # ds @ k
                dsc, cast(kblk), (((4,), (2,)), ((0, 1), (0, 1))),
                preferred_element_type=jnp.float32) * scale
            dk_blk = jax.lax.dot_general(                     # ds^T @ q
                dsc, cast(qblk),
                (((3,), (3,)), ((0, 1, 2), (0, 1, 2))),
                preferred_element_type=jnp.float32) * scale   # (B,KV,G,Ckv,hd)
            dv_blk = jax.lax.dot_general(                     # p^T @ dout
                p.astype(COMPUTE_DTYPE), dblk.astype(COMPUTE_DTYPE),
                (((3,), (3,)), ((0, 1, 2), (0, 1, 2))),
                preferred_element_type=jnp.float32)
            dk_all = dk_all.at[jk].add(dk_blk.sum(axis=2))    # sum G
            dv_all = dv_all.at[jk].add(dv_blk.sum(axis=2))
            return (dq_acc, dk_all, dv_all), None

        dq0 = jnp.zeros((b, kvh, g, chunk_q, hd), jnp.float32)
        (dq_acc, dk_all, dv_all), _ = jax.lax.scan(
            kv_step, (dq0, dk_all, dv_all), (kc, vc, jnp.arange(nk)))
        return (dk_all, dv_all), dq_acc

    dk0 = jnp.zeros((nk, b, kvh, chunk_kv, hd), jnp.float32)
    dv0 = jnp.zeros((nk, b, kvh, chunk_kv, hd), jnp.float32)
    (dk_all, dv_all), dq = jax.lax.scan(
        q_step, (dk0, dv0), (qc, doc, outc, lsec, jnp.arange(nq)))
    # dq: (nq, B, KV, G, Cq, hd) -> (B, Sq, H, hd)
    dq = dq.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, h, hd)
    dk = dk_all.transpose(1, 0, 3, 2, 4).reshape(b, skv, kvh, hd)
    dv = dv_all.transpose(1, 0, 3, 2, 4).reshape(b, skv, kvh, hd)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, chunk_q, chunk_kv, q_offset):
    return _flash_fwd(q, k, v, causal, chunk_q, chunk_kv, q_offset)[0]


def _flash_vjp_fwd(q, k, v, causal, chunk_q, chunk_kv, q_offset):
    out, lse = _flash_fwd(q, k, v, causal, chunk_q, chunk_kv, q_offset)
    return out, (q, k, v, out, lse)


def _flash_vjp_bwd(causal, chunk_q, chunk_kv, q_offset, res, dout):
    q, k, v, out, lse = res
    return _flash_bwd_impl(q, k, v, out, lse, dout, causal, chunk_q,
                           chunk_kv, q_offset)


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool, chunk_q: int, chunk_kv: int,
                      q_offset: int = 0) -> jax.Array:
    """Flash attention in XLA: q (B, Sq, H, hd); k, v (B, Skv, KV, hd).

    Never materializes (Sq, Skv); backward recomputes probability blocks
    (custom VJP), so autodiff stores only (q, k, v, out, lse).
    """
    import math
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    chunk_q = math.gcd(min(chunk_q, sq), sq)
    chunk_kv = math.gcd(min(chunk_kv, skv), skv)
    return _flash(q, k, v, causal, chunk_q, chunk_kv, q_offset)


def decode_attention(q: jax.Array, cache: KVCache, pos: jax.Array
                     ) -> jax.Array:
    """One-token attention against a cache: q (B, 1, H, hd), pos scalar.

    Positions > pos are masked; the current token must already be written.
    """
    b, _, h, hd = q.shape
    _, smax, kvh, _ = cache.k.shape
    g = h // kvh
    qh = cast(q).reshape(b, kvh, g, hd)
    s = jax.lax.dot_general(               # (B, KV, G, Smax)
        qh, cast(cache.k).transpose(0, 2, 1, 3),
        (((3,), (3,)), ((0, 1), (0, 1))),
        preferred_element_type=jnp.float32) * hd ** -0.5
    mask = jnp.arange(smax) <= pos
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(COMPUTE_DTYPE)
    out = jax.lax.dot_general(             # (B, KV, G, hd)
        p, cast(cache.v).transpose(0, 2, 1, 3),
        (((3,), (2,)), ((0, 1), (0, 1))),
        preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, hd).astype(COMPUTE_DTYPE)


def attention(x: jax.Array, p: dict, *, n_heads: int, n_kv_heads: int,
              head_dim: int, rope_theta: Optional[float], causal: bool,
              chunk_q: int, chunk_kv: int,
              memory: Optional[jax.Array] = None,
              cache: Optional[KVCache] = None,
              pos: Optional[jax.Array] = None,
              return_cache: bool = False,
              bf16_wire: bool = False,
              replicate_heads: bool = False,
              ) -> Tuple[jax.Array, Optional[KVCache]]:
    """Unified attention block over params {wq, wk, wv, wo [, bq, bk, bv]}.

    - self-attn train/prefill: memory=None, cache=None
    - cross-attn: memory = encoder/image states (keys/values source)
    - decode: cache + pos given; x is the (B, 1, d) current token
    """
    b, sq, _ = x.shape
    kv_src = x if memory is None else memory
    q = _split_heads(dense(x, p["wq"], p.get("bq")), n_heads, head_dim)

    if cache is not None and memory is not None:
        # cross-attn during decode: cache holds the projected memory
        k_all, v_all = cache.k, cache.v
        out = decode_attention(q, KVCache(k_all, v_all),
                               jnp.asarray(k_all.shape[1] - 1))
        return dense(out.reshape(b, sq, -1), p["wo"],
                     bf16_wire=bf16_wire), cache

    k = _split_heads(dense(kv_src, p["wk"], p.get("bk")), n_kv_heads, head_dim)
    v = _split_heads(dense(kv_src, p["wv"], p.get("bv")), n_kv_heads, head_dim)

    if cache is not None:                          # self-attn decode
        assert pos is not None
        angles = rope_angles(pos[None], head_dim, rope_theta) \
            if rope_theta else None
        if angles is not None:
            q = apply_rope(q, angles)
            k = apply_rope(k, angles)
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache.k, cast(k), pos,
                                                      axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache.v, cast(v), pos,
                                                      axis=1)
        new_cache = KVCache(k_cache, v_cache)
        out = decode_attention(q, new_cache, pos)
        return dense(out.reshape(b, sq, -1), p["wo"],
                     bf16_wire=bf16_wire), new_cache

    if rope_theta and memory is None:
        angles = rope_angles(jnp.arange(sq), head_dim, rope_theta)
        q = apply_rope(q, angles)
        k = apply_rope(k, angles)

    if replicate_heads:
        from repro.parallel.constraints import BATCH, constrain
        q = constrain(q, BATCH, None, None, None)
        k = constrain(k, BATCH, None, None, None)
        v = constrain(v, BATCH, None, None, None)
    out = chunked_attention(q, k, v, causal=causal, chunk_q=chunk_q,
                            chunk_kv=chunk_kv)
    out = dense(out.reshape(b, sq, -1), p["wo"], bf16_wire=bf16_wire)
    if return_cache:
        return out, KVCache(cast(k), cast(v))
    return out, None


def attn_param_specs(d_model: int, n_heads: int, n_kv_heads: int,
                     head_dim: int, qkv_bias: bool = False,
                     prefix_shape: Tuple[int, ...] = ()) -> dict:
    from .common import spec
    ps = prefix_shape
    p = {
        "wq": spec(*ps, d_model, n_heads * head_dim),
        "wk": spec(*ps, d_model, n_kv_heads * head_dim),
        "wv": spec(*ps, d_model, n_kv_heads * head_dim),
        "wo": spec(*ps, n_heads * head_dim, d_model),
    }
    if qkv_bias:
        p["bq"] = spec(*ps, n_heads * head_dim)
        p["bk"] = spec(*ps, n_kv_heads * head_dim)
        p["bv"] = spec(*ps, n_kv_heads * head_dim)
    return p
