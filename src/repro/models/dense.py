"""Dense decoder-only LM (llama lineage: granite, stablelm, qwen2.5).

Layers are stacked along a leading L axis and driven by ``lax.scan`` so the
HLO is O(1) in depth (essential to compile 94-layer configs quickly), with
optional rematerialization of the scan body.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .attention import KVCache, attention, attn_param_specs
from .common import (COMPUTE_DTYPE, cast, dense, rms_norm,
                     softmax_cross_entropy, spec, swiglu)
from repro.parallel.constraints import BATCH, MODEL, constrain


def layer_param_specs(cfg: ModelConfig, n_layers: int) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "attn_norm": spec(n_layers, d),
        "attn": attn_param_specs(d, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                                 cfg.qkv_bias, prefix_shape=(n_layers,)),
        "mlp_norm": spec(n_layers, d),
        "w1": spec(n_layers, d, f),
        "w3": spec(n_layers, d, f),
        "w2": spec(n_layers, f, d),
    }


def param_specs(cfg: ModelConfig) -> dict:
    return {
        "embed": spec(cfg.vocab_padded, cfg.d_model),
        "layers": layer_param_specs(cfg, cfg.n_layers),
        "final_norm": spec(cfg.d_model),
        "lm_head": spec(cfg.d_model, cfg.vocab_padded),
    }


def constrain_residual(x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Pin the residual stream's sharding at block boundaries.

    'replicated': (batch, None, None) -- the canonical Megatron layout;
    kills GSPMD's drift into feature-sharded residuals (which forces an
    fp32 activation all-reduce after EVERY projection, see EXPERIMENTS.md
    Perf).  'seq': (batch, model, None) -- Megatron sequence parallelism;
    the pair AR(fp32) collapses into RS + bf16 AG at block edges.
    """
    if cfg.residual_sharding == "replicated" and x.ndim == 3:
        return constrain(x, BATCH, None, None)
    if cfg.residual_sharding == "seq" and x.ndim == 3:
        return constrain(x, BATCH, MODEL, None)
    return x


def _layer(x: jax.Array, lp: dict, cfg: ModelConfig, *, causal: bool = True,
           cache: Optional[KVCache] = None, pos=None,
           return_cache: bool = False) -> Tuple[jax.Array, Optional[KVCache]]:
    if cfg.gather_weights:
        from repro.parallel.rules import constrain_compute
        lp = constrain_compute(lp)
    x = constrain_residual(x, cfg)
    h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    if cfg.residual_sharding == "seq":
        h = constrain(h, BATCH, None, None)   # gather S for attention
    a, new_cache = attention(
        h, lp["attn"], n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.hd, rope_theta=cfg.rope_theta, causal=causal,
        chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv,
        cache=cache, pos=pos, return_cache=return_cache,
        bf16_wire=cfg.bf16_reduce, replicate_heads=cfg.attn_replicate)
    x = x + a
    x = constrain_residual(x, cfg)
    h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    x = x + swiglu(h, lp["w1"], lp["w3"], lp["w2"],
                   bf16_wire=cfg.bf16_reduce)
    return x, new_cache


def embed(params: dict, tokens: jax.Array) -> jax.Array:
    return constrain(cast(params["embed"][tokens]), BATCH, None, None)


def lm_logits(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = constrain(x, BATCH, None, None)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return constrain(dense(x, params["lm_head"]), BATCH, None, MODEL)


def lm_loss(params: dict, x: jax.Array, labels: jax.Array,
            cfg: ModelConfig) -> jax.Array:
    """Final-norm + head + CE.

    With ``cfg.ce_chunked`` > 0 the (B, S, V) logits tensor is never
    materialized: sequence chunks are projected, reduced to (lse,
    label-logit) pairs, and rematerialized in the backward pass -- the
    memory-term optimization logged in EXPERIMENTS.md Perf.
    """
    if not cfg.ce_chunked:
        return softmax_cross_entropy(lm_logits(params, x, cfg), labels)
    x = constrain(x, BATCH, None, None)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    b, s, d = x.shape
    import math
    chunk = math.gcd(cfg.ce_chunked, s)
    nc = s // chunk
    xc = x.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, chunk).transpose(1, 0, 2)
    head = cast(params["lm_head"])

    @jax.checkpoint
    def body(acc, xs):
        xb, lb = xs
        logits = jax.lax.dot_general(
            cast(xb), head, (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        logits = constrain(logits, BATCH, None, MODEL)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - ll), None

    total, _ = jax.lax.scan(body, jnp.float32(0.0), (xc, lc))
    return total / (b * s)


def maybe_cast_stack(tree, cfg: ModelConfig):
    """bf16-cast stacked layer params before the scan so FSDP
    all-gathers move bf16, not fp32 (collective-term optimization)."""
    if not cfg.cast_params_before_scan:
        return tree
    return jax.tree.map(
        lambda p: cast(p) if p.dtype == jnp.float32 and p.ndim >= 2 else p,
        tree)


def forward(params: dict, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Full-sequence causal forward -> (B, S, V) logits (train path)."""
    x = embed(params, tokens)

    def body(h, lp):
        h, _ = _layer(h, lp, cfg)
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["layers"])
    return lm_logits(params, x, cfg)


def loss_fn(params: dict, batch: dict, cfg: ModelConfig) -> jax.Array:
    x = embed(params, batch["tokens"])

    def body(h, lp):
        h, _ = _layer(h, lp, cfg)
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, maybe_cast_stack(params["layers"], cfg))
    return lm_loss(params, x, batch["labels"], cfg)


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int) -> KVCache:
    shape = (cfg.n_layers, batch, seq_len, cfg.n_kv_heads, cfg.hd)
    return KVCache(spec(*shape, dtype=COMPUTE_DTYPE),
                   spec(*shape, dtype=COMPUTE_DTYPE))


def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> KVCache:
    s = cache_specs(cfg, batch, seq_len)
    return KVCache(jnp.zeros(s.k.shape, s.k.dtype),
                   jnp.zeros(s.v.shape, s.v.dtype))


def prefill(params: dict, tokens: jax.Array, cfg: ModelConfig
            ) -> Tuple[jax.Array, KVCache]:
    """Run the prompt; returns last-position logits + stacked KV caches."""
    x = embed(params, tokens)

    def body(h, lp):
        h, kv = _layer(h, lp, cfg, return_cache=True)
        return h, kv

    if cfg.remat:
        body = jax.checkpoint(body)
    x, caches = jax.lax.scan(body, x, params["layers"])
    logits = lm_logits(params, x[:, -1:, :], cfg)
    return logits, caches


def decode_step(params: dict, token: jax.Array, pos: jax.Array,
                cache: KVCache, cfg: ModelConfig
                ) -> Tuple[jax.Array, KVCache]:
    """One decode step. token: (B,) int32; pos: scalar int32;
    cache: stacked (L, B, S_max, KV, hd)."""
    x = embed(params, token[:, None])

    def body(h, lp_kv):
        lp, k_l, v_l = lp_kv
        h, new_kv = _layer(h, lp, cfg, cache=KVCache(k_l, v_l), pos=pos)
        return h, new_kv

    x, new_caches = jax.lax.scan(body, x, (params["layers"], cache.k, cache.v))
    logits = lm_logits(params, x, cfg)
    return logits, KVCache(new_caches.k, new_caches.v)
