"""RWKV6 "Finch": attention-free LM with data-dependent per-channel decay.

Training/prefill uses a chunked linear-attention formulation (GLA-style):
within a chunk the pairwise decay tensor D[t,s,c] = exp(la_ex[t,c] -
la_in[s,c]) is formed explicitly (exponents are <= 0, so it never
overflows), the inter-chunk contribution flows through a carried per-head
state S (hd_k x hd_v), and chunks are scanned sequentially.  Decode is the
plain O(1) recurrence.  A step-by-step ``lax.scan`` oracle lives in
``wkv_ref`` for tests.

Simplifications vs the released checkpoint (documented in DESIGN.md):
static token-shift lerp coefficients (the ddlerp LoRA is kept only for the
decay, which is the paper-defining "data-dependent decay"), RMSNorm instead
of LayerNorm.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .common import (COMPUTE_DTYPE, cast, dense, rms_norm,
                     softmax_cross_entropy, spec)


class RWKVState(NamedTuple):
    tm_last: jax.Array    # (L, B, d)   token-shift carry, time-mix
    cm_last: jax.Array    # (L, B, d)   token-shift carry, channel-mix
    s: jax.Array          # (L, B, H, hd, hd) wkv state


def layer_param_specs(cfg: ModelConfig, n_layers: int) -> dict:
    d, f, hd = cfg.d_model, cfg.d_ff, cfg.ssm_head_dim
    h = d // hd
    lora = 64
    return {
        "ln1": spec(n_layers, d),
        "ln2": spec(n_layers, d),
        "mix_r": spec(n_layers, d), "mix_k": spec(n_layers, d),
        "mix_v": spec(n_layers, d), "mix_w": spec(n_layers, d),
        "mix_g": spec(n_layers, d),
        "wr": spec(n_layers, d, d), "wk": spec(n_layers, d, d),
        "wv": spec(n_layers, d, d), "wg": spec(n_layers, d, d),
        "wo": spec(n_layers, d, d),
        "decay0": spec(n_layers, d),
        "decay_a": spec(n_layers, d, lora),
        "decay_b": spec(n_layers, lora, d),
        "bonus_u": spec(n_layers, h, hd),
        "gn_scale": spec(n_layers, d),
        "mix_cr": spec(n_layers, d), "mix_ck": spec(n_layers, d),
        "cwk": spec(n_layers, d, f), "cwv": spec(n_layers, f, d),
        "cwr": spec(n_layers, d, d),
    }


def param_specs(cfg: ModelConfig) -> dict:
    return {
        "embed": spec(cfg.vocab_padded, cfg.d_model),
        "layers": layer_param_specs(cfg, cfg.n_layers),
        "final_norm": spec(cfg.d_model),
        "lm_head": spec(cfg.d_model, cfg.vocab_padded),
    }


def _shift(x: jax.Array, last: jax.Array) -> jax.Array:
    """x_{t-1} along seq; position 0 uses the carried ``last`` token."""
    return jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)


def _log_decay(xw: jax.Array, lp: dict) -> jax.Array:
    """Data-dependent log decay, guaranteed < 0 (decay in (0, 1))."""
    lora = dense(jnp.tanh(dense(xw, lp["decay_a"]).astype(jnp.float32)
                          ).astype(COMPUTE_DTYPE), lp["decay_b"])
    return -jnp.exp(jnp.clip(lp["decay0"].astype(jnp.float32)
                             + lora.astype(jnp.float32), -8.0, 6.0))


def wkv_chunked(r, k, v, lw, u, s0, chunk: int):
    """Chunked WKV. r/k/v/lw: (B, S, H, hd); u: (H, hd); s0: (B, H, hd, hd).

    Recurrence: out_t = r_t . (S_{t-1} + diag(u) k_t v_t^T);
                S_t = diag(exp(lw_t)) S_{t-1} + k_t v_t^T.
    Returns (out (B, S, H, hd), s_final).
    """
    b, s, h, hd = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    n = s // chunk
    rc = r.reshape(b, n, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    kc = k.reshape(b, n, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    lwc = lw.reshape(b, n, chunk, h, hd).transpose(1, 0, 2, 3, 4)

    def step(S, xs):
        rb, kb, vb, lwb = (x.astype(jnp.float32) for x in xs)
        la_in = jnp.cumsum(lwb, axis=1)               # inclusive (B,C,H,hd)
        la_ex = la_in - lwb                           # exclusive
        # inter-chunk: r_t decayed against carried state
        r_dec = rb * jnp.exp(la_ex)
        inter = jnp.einsum("bthc,bhcv->bthv", r_dec, S)
        # intra-chunk, strictly lower-triangular via pairwise decays
        dmat = la_ex[:, :, None] - la_in[:, None, :]  # (B,C,C,H,hd) t,s
        tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        dmat = jnp.where(tri[None, :, :, None, None], dmat, -jnp.inf)
        D = jnp.exp(dmat)
        P = jnp.einsum("bthc,bshc,btshc->btsh", rb, kb, D)
        intra = jnp.einsum("btsh,bshv->bthv", P, vb)
        # diagonal bonus term
        sig = jnp.einsum("bthc,hc,bthc->bth", rb, u.astype(jnp.float32), kb)
        diag = sig[..., None] * vb
        out = inter + intra + diag
        # carry state across the chunk
        tail = la_in[:, -1:, :, :]                    # (B,1,H,hd)
        S_new = (jnp.exp(tail[:, 0])[..., None] * S
                 + jnp.einsum("bshc,bshv->bhcv",
                              kb * jnp.exp(tail - la_in), vb))
        return S_new, out.astype(COMPUTE_DTYPE)

    s_fin, outs = jax.lax.scan(jax.checkpoint(step), s0.astype(jnp.float32),
                               (rc, kc, vc, lwc))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)
    return out, s_fin


def wkv_ref(r, k, v, lw, u, s0):
    """Step-by-step oracle for tests."""
    b, s, h, hd = r.shape

    def step(S, xs):
        rt, kt, vt, lwt = (x.astype(jnp.float32) for x in xs)
        kv = jnp.einsum("bhc,bhv->bhcv", kt, vt)
        out = jnp.einsum("bhc,bhcv->bhv",
                         rt, S + u.astype(jnp.float32)[None, :, :, None] * kv)
        S = jnp.exp(lwt)[..., None] * S + kv
        return S, out

    xs = tuple(x.transpose(1, 0, 2, 3) for x in (r, k, v, lw))
    s_fin, outs = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return outs.transpose(1, 0, 2, 3).astype(COMPUTE_DTYPE), s_fin


def _head_groupnorm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    """Per-head RMS normalization; x: (B, S, H, hd), scale: (d,)."""
    b, s, h, hd = x.shape
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = (xf * jax.lax.rsqrt(var + eps)).reshape(b, s, h * hd)
    return (out * scale.astype(jnp.float32)).astype(COMPUTE_DTYPE)


def time_mix(x, last, lp, cfg: ModelConfig, s0):
    """Returns (out, new_last, s_final). x: (B, S, d)."""
    b, s, d = x.shape
    hd = cfg.ssm_head_dim
    h = d // hd
    xx = _shift(x, last)

    def lerp(mix):
        return x + (xx - x) * mix.astype(x.dtype)

    r = dense(lerp(lp["mix_r"]), lp["wr"]).reshape(b, s, h, hd)
    k = dense(lerp(lp["mix_k"]), lp["wk"]).reshape(b, s, h, hd)
    v = dense(lerp(lp["mix_v"]), lp["wv"]).reshape(b, s, h, hd)
    g = dense(lerp(lp["mix_g"]), lp["wg"])
    lw = _log_decay(lerp(lp["mix_w"]), lp).reshape(b, s, h, hd)

    out, s_fin = wkv_chunked(r, k, v, lw, lp["bonus_u"], s0, cfg.seq_chunk)
    out = _head_groupnorm(out, lp["gn_scale"], cfg.norm_eps)
    out = out * jax.nn.silu(g.astype(jnp.float32)).astype(COMPUTE_DTYPE)
    return dense(out, lp["wo"]), x[:, -1, :], s_fin


def channel_mix(x, last, lp):
    xx = _shift(x, last)

    def lerp(mix):
        return x + (xx - x) * mix.astype(x.dtype)

    k = dense(lerp(lp["mix_ck"]), lp["cwk"]).astype(jnp.float32)
    k = jnp.square(jax.nn.relu(k)).astype(COMPUTE_DTYPE)
    rgate = jax.nn.sigmoid(dense(lerp(lp["mix_cr"]), lp["cwr"])
                           .astype(jnp.float32)).astype(COMPUTE_DTYPE)
    return rgate * dense(k, lp["cwv"]), x[:, -1, :]


def _layer(x, lp, cfg: ModelConfig, state):
    tm_last, cm_last, s0 = state
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    a, tm_new, s_new = time_mix(h, tm_last, lp, cfg, s0)
    x = x + a
    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    m, cm_new = channel_mix(h2, cm_last, lp)
    return x + m, (tm_new, cm_new, s_new)


def state_specs(cfg: ModelConfig, batch: int) -> RWKVState:
    d, hd = cfg.d_model, cfg.ssm_head_dim
    h = d // hd
    return RWKVState(
        spec(cfg.n_layers, batch, d, dtype=COMPUTE_DTYPE),
        spec(cfg.n_layers, batch, d, dtype=COMPUTE_DTYPE),
        spec(cfg.n_layers, batch, h, hd, hd, dtype=jnp.float32))


def init_state(cfg: ModelConfig, batch: int) -> RWKVState:
    s = state_specs(cfg, batch)
    return RWKVState(*(jnp.zeros(x.shape, x.dtype) for x in s))


def _run_stack(params, x, cfg: ModelConfig, state: RWKVState):
    def body(h, lp_state):
        lp, tm, cm, s0 = lp_state
        h, (tm2, cm2, s2) = _layer(h, lp, cfg, (tm, cm, s0))
        return h, (tm2, cm2, s2)

    if cfg.remat:
        body = jax.checkpoint(body)
    x, news = jax.lax.scan(body, x,
                           (params["layers"], state.tm_last, state.cm_last,
                            state.s))
    return x, RWKVState(*news)


def forward(params, tokens, cfg: ModelConfig):
    from .dense import embed, lm_logits
    x = embed(params, tokens)
    state = init_state(cfg, tokens.shape[0])
    x, _ = _run_stack(params, x, cfg, state)
    return lm_logits(params, x, cfg)


def loss_fn(params, batch, cfg: ModelConfig):
    logits = forward(params, batch["tokens"], cfg)
    return softmax_cross_entropy(logits, batch["labels"])


def prefill(params, tokens, cfg: ModelConfig):
    from .dense import embed, lm_logits
    x = embed(params, tokens)
    state = init_state(cfg, tokens.shape[0])
    x, state = _run_stack(params, x, cfg, state)
    return lm_logits(params, x[:, -1:, :], cfg), state


def decode_step(params, token, pos, state: RWKVState, cfg: ModelConfig):
    """O(1) recurrent decode; ``pos`` unused (state is position-free)."""
    del pos
    from .dense import embed, lm_logits
    x = embed(params, token[:, None])
    x, state = _run_stack(params, x, cfg, state)
    return lm_logits(params, x, cfg), state
