"""Mixture-of-Experts decoder (kimi-k2, qwen3-moe).

Token-choice top-k routing with capacity-bounded expert buffers.  Dispatch
is scatter-based: each (token, choice) gets a position inside its expert's
buffer via a cumulative-sum over the (tokens, experts) one-hot matrix;
overflow beyond capacity is dropped (weight 0), matching Switch/GShard
semantics.  Experts are stacked (L, E, ...) and sharded over the 'model'
mesh axis (expert parallelism); the scatter/gather pair between
token-sharded and expert-sharded layouts is where GSPMD inserts the
all-to-all-class collectives this family is known for.

Beyond-paper tie-in: `repro.core.placement` partitions the expert
co-activation graph with Spinner to reorder experts across EP shards,
reducing cross-shard routing volume (see EXPERIMENTS.md).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .attention import KVCache
from .common import (COMPUTE_DTYPE, cast, dense, rms_norm,
                     softmax_cross_entropy, spec, swiglu)
from .dense import _layer as dense_layer  # attention part is shared
from .dense import embed, lm_logits, lm_loss, maybe_cast_stack
from .attention import attn_param_specs


def layer_param_specs(cfg: ModelConfig, n_layers: int) -> dict:
    d, e, fe = cfg.d_model, cfg.n_experts, cfg.d_expert
    p = {
        "attn_norm": spec(n_layers, d),
        "attn": attn_param_specs(d, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                                 cfg.qkv_bias, prefix_shape=(n_layers,)),
        "mlp_norm": spec(n_layers, d),
        "router": spec(n_layers, d, e),
        "exp_w1": spec(n_layers, e, d, fe),
        "exp_w3": spec(n_layers, e, d, fe),
        "exp_w2": spec(n_layers, e, fe, d),
    }
    if cfg.shared_expert_ff:
        fs = cfg.shared_expert_ff
        p["shared_w1"] = spec(n_layers, d, fs)
        p["shared_w3"] = spec(n_layers, d, fs)
        p["shared_w2"] = spec(n_layers, fs, d)
    return p


def param_specs(cfg: ModelConfig) -> dict:
    return {
        "embed": spec(cfg.vocab_padded, cfg.d_model),
        "layers": layer_param_specs(cfg, cfg.n_layers),
        "final_norm": spec(cfg.d_model),
        "lm_head": spec(cfg.d_model, cfg.vocab_padded),
    }


def _capacity(num_tokens: int, cfg: ModelConfig) -> int:
    cap = int(num_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-cap // 8) * 8)           # multiple of 8, at least 8


def moe_ffn(x: jax.Array, lp: dict, cfg: ModelConfig
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss). Capacity-bounded top-k dispatch."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(t, cfg)
    xt = x.reshape(t, d)

    logits = dense(xt, lp["router"]).astype(jnp.float32)    # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, choice = jax.lax.top_k(probs, k)              # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # Position of each (token, choice) inside its expert buffer.
    if cfg.moe_dispatch == "sort":
        # O(Tk log Tk) rank-by-sort: peak memory O(Tk), vs the one-hot
        # cumsum's O(Tk * E) buffers (EXPERIMENTS.md Perf, kimi cell).
        flat_choice = choice.reshape(-1)                     # (T*k,)
        order = jnp.argsort(flat_choice)
        sorted_c = flat_choice[order]
        # rank within equal-expert run
        start = jnp.searchsorted(sorted_c, sorted_c, side="left")
        rank_sorted = jnp.arange(t * k, dtype=jnp.int32) - start
        pos = jnp.zeros((t * k,), jnp.int32).at[order].set(
            rank_sorted).reshape(t, k)
    else:
        onehot = jax.nn.one_hot(choice, e, dtype=jnp.int32)  # (T, k, E)
        flat = onehot.reshape(t * k, e)
        pos_flat = jnp.cumsum(flat, axis=0) * flat           # 1-based ranks
        pos = pos_flat.reshape(t, k, e).sum(-1) - 1          # (T, k)
    keep = (pos >= 0) & (pos < cap)
    pos_c = jnp.clip(pos, 0, cap - 1)

    # Scatter tokens into (E, cap, d) buffers.
    buf = jnp.zeros((e, cap, d), COMPUTE_DTYPE)
    tok_flat = jnp.broadcast_to(jnp.arange(t)[:, None], (t, k)).reshape(-1)
    src = jnp.where(keep.reshape(-1)[:, None], cast(xt)[tok_flat], 0)
    buf = buf.at[choice.reshape(-1), pos_c.reshape(-1)].add(src)

    h = jax.lax.dot_general(buf, cast(lp["exp_w1"]),
                            (((2,), (1,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)
    h3 = jax.lax.dot_general(buf, cast(lp["exp_w3"]),
                             (((2,), (1,)), ((0,), (0,))),
                             preferred_element_type=jnp.float32)
    h = (jax.nn.silu(h) * h3).astype(COMPUTE_DTYPE)
    out_buf = jax.lax.dot_general(
        h, cast(lp["exp_w2"]), (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=(COMPUTE_DTYPE if cfg.bf16_reduce
                                else jnp.float32)
        ).astype(COMPUTE_DTYPE)                              # (E, cap, d)

    # Gather back and combine with gate weights.
    gathered = out_buf[choice.reshape(-1), pos_c.reshape(-1)]  # (T*k, d)
    gathered = gathered.reshape(t, k, d)
    w = jnp.where(keep, gate_vals, 0.0).astype(jnp.float32)
    out = (gathered.astype(jnp.float32) * w[..., None]).sum(1)

    # Switch-style load-balance aux loss over all k choices.
    me = probs.mean(0)                                        # (E,)
    ce = jax.nn.one_hot(choice, e, dtype=jnp.float32).mean((0, 1))
    aux = e * jnp.sum(me * ce)

    if cfg.shared_expert_ff:
        out = out + swiglu(xt, lp["shared_w1"], lp["shared_w3"],
                           lp["shared_w2"]).astype(jnp.float32)
    return out.reshape(b, s, d).astype(COMPUTE_DTYPE), aux


def _layer(x, lp, cfg: ModelConfig, *, cache=None, pos=None,
           return_cache=False):
    if cfg.gather_weights:
        from repro.parallel.rules import constrain_compute
        lp = constrain_compute(lp)
    from .dense import constrain_residual
    x = constrain_residual(x, cfg)
    h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    from .attention import attention
    a, new_cache = attention(
        h, lp["attn"], n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.hd, rope_theta=cfg.rope_theta, causal=True,
        chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv,
        cache=cache, pos=pos, return_cache=return_cache,
        bf16_wire=cfg.bf16_reduce, replicate_heads=cfg.attn_replicate)
    x = x + a
    h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    m, aux = moe_ffn(h, lp, cfg)
    return x + m, new_cache, aux


def forward(params: dict, tokens: jax.Array, cfg: ModelConfig
            ) -> Tuple[jax.Array, jax.Array]:
    x = embed(params, tokens)

    def body(h, lp):
        h, _, aux = _layer(h, lp, cfg)
        return h, aux

    if cfg.remat:
        body = jax.checkpoint(body)
    x, auxs = jax.lax.scan(body, x, params["layers"])
    return lm_logits(params, x, cfg), jnp.mean(auxs)


def loss_fn(params: dict, batch: dict, cfg: ModelConfig) -> jax.Array:
    x = embed(params, batch["tokens"])

    def body(h, lp):
        h, _, aux = _layer(h, lp, cfg)
        return h, aux

    if cfg.remat:
        body = jax.checkpoint(body)
    x, auxs = jax.lax.scan(body, x, maybe_cast_stack(params["layers"], cfg))
    return (lm_loss(params, x, batch["labels"], cfg)
            + cfg.router_aux_weight * jnp.mean(auxs))


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int) -> KVCache:
    shape = (cfg.n_layers, batch, seq_len, cfg.n_kv_heads, cfg.hd)
    return KVCache(spec(*shape, dtype=COMPUTE_DTYPE),
                   spec(*shape, dtype=COMPUTE_DTYPE))


def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> KVCache:
    s = cache_specs(cfg, batch, seq_len)
    return KVCache(jnp.zeros(s.k.shape, s.k.dtype),
                   jnp.zeros(s.v.shape, s.v.dtype))


def prefill(params: dict, tokens: jax.Array, cfg: ModelConfig):
    x = embed(params, tokens)

    def body(h, lp):
        h, kv, _ = _layer(h, lp, cfg, return_cache=True)
        return h, kv

    if cfg.remat:
        body = jax.checkpoint(body)
    x, caches = jax.lax.scan(body, x, params["layers"])
    return lm_logits(params, x[:, -1:, :], cfg), caches


def decode_step(params: dict, token: jax.Array, pos: jax.Array,
                cache: KVCache, cfg: ModelConfig):
    x = embed(params, token[:, None])

    def body(h, lp_kv):
        lp, k_l, v_l = lp_kv
        h, new_kv, _ = _layer(h, lp, cfg, cache=KVCache(k_l, v_l), pos=pos)
        return h, new_kv

    x, new_caches = jax.lax.scan(body, x, (params["layers"], cache.k, cache.v))
    return lm_logits(params, x, cfg), KVCache(new_caches.k, new_caches.v)
