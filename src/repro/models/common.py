"""Shared model building blocks (pure JAX, no framework dependency).

Parameters are nested dicts of arrays.  Each model module defines
``param_specs(cfg)`` returning the same pytree with ShapeDtypeStructs, which
drives (a) real initialization for smoke tests / training, and (b)
allocation-free lowering for the multi-pod dry-run.

Compute policy: parameters are stored fp32 (canonical/master), cast to bf16
at use; matmuls accumulate fp32 via ``preferred_element_type``.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

PyTree = Any
COMPUTE_DTYPE = jnp.bfloat16
PARAM_DTYPE = jnp.float32


def spec(*shape, dtype=PARAM_DTYPE) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def init_from_specs(specs: PyTree, key: jax.Array) -> PyTree:
    """Initialize a parameter pytree from its spec pytree.

    Leaf-name heuristics: '*norm*'/'*scale*' -> ones; '*bias*' -> zeros;
    everything else truncated-normal with fan-in scaling.
    """
    # jax.tree.flatten_with_path only exists on newer jax; the tree_util
    # spelling is available across the versions we support.
    leaves, treedef = jax.tree_util.tree_flatten_with_path(specs)
    keys = jax.random.split(key, len(leaves))

    def init_leaf(path, s, k):
        name = "/".join(str(getattr(p, "key", p)) for p in path).lower()
        if "norm" in name or name.endswith("scale") or "/g_" in name:
            return jnp.ones(s.shape, s.dtype)
        if "bias" in name or name.endswith("_b") or "decay0" in name:
            return jnp.zeros(s.shape, s.dtype)
        fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
        std = min(0.02, fan_in ** -0.5)
        return (jax.random.truncated_normal(k, -3, 3, s.shape, jnp.float32)
                * std).astype(s.dtype)

    inited = [init_leaf(p, s, k) for (p, s), k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, inited)


def cast(x: jax.Array, dtype=COMPUTE_DTYPE) -> jax.Array:
    return x.astype(dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def dense(x: jax.Array, w: jax.Array, b=None,
          bf16_wire: bool = False) -> jax.Array:
    """x @ w in bf16 with fp32 accumulation; x: (..., d_in), w: (d_in, d_out).

    ``bf16_wire``: emit bf16 from the dot itself so a GSPMD partial-sum
    all-reduce (row-parallel weights) moves bf16, not fp32.  MXU hardware
    accumulation is fp32 either way; only the wire/HBM format changes.
    """
    pet = COMPUTE_DTYPE if bf16_wire else jnp.float32
    y = jax.lax.dot_general(
        cast(x), cast(w), (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=pet)
    if b is not None:
        y = (y.astype(jnp.float32) + b.astype(jnp.float32))
    return y.astype(COMPUTE_DTYPE)


def rope_angles(positions: jax.Array, head_dim: int, theta: float
                ) -> jax.Array:
    """(..., head_dim//2) rotation angles for given integer positions."""
    freqs = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                             / head_dim))
    return positions.astype(jnp.float32)[..., None] * freqs


def apply_rope(x: jax.Array, angles: jax.Array) -> jax.Array:
    """x: (B, S, H, hd); angles: (B, S, hd//2) or (S, hd//2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    if angles.ndim == 2:
        angles = angles[None]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w1, w3, w2, bf16_wire: bool = False) -> jax.Array:
    """LLaMA-style gated MLP: (silu(x@w1) * (x@w3)) @ w2."""
    return dense(jax.nn.silu(dense(x, w1).astype(jnp.float32)).astype(
        COMPUTE_DTYPE) * dense(x, w3), w2, bf16_wire=bf16_wire)


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token CE; logits (..., V) fp32-safe, labels (...) int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)


def count_params(specs: PyTree) -> int:
    import math
    return sum(math.prod(l.shape) for l in jax.tree.leaves(specs))
