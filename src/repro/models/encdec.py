"""Encoder-decoder backbone (seamless-m4t-large-v2).

Assigned as the transformer backbone only: the speech frontend is a stub,
so the encoder consumes precomputed frame embeddings (B, S_src, d) from
``input_specs``.  Decoder layers carry self-attention (causal, cached at
decode) and cross-attention (keys/values from the encoder output,
precomputed into a cache at prefill).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .attention import KVCache, attention, attn_param_specs, decode_attention
from .common import (COMPUTE_DTYPE, cast, dense, rms_norm,
                     softmax_cross_entropy, spec, swiglu)
from .dense import lm_logits
from repro.parallel.constraints import BATCH, constrain


class EncDecCache(NamedTuple):
    self_kv: KVCache     # (L, B, S_max, KV, hd)
    cross_kv: KVCache    # (L, B, S_src, KV, hd)


def _mlp_specs(cfg: ModelConfig, n: int) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {"mlp_norm": spec(n, d), "w1": spec(n, d, f),
            "w3": spec(n, d, f), "w2": spec(n, f, d)}


def param_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    ne, nd = cfg.n_enc_layers, cfg.n_layers
    enc = {
        "attn_norm": spec(ne, d),
        "attn": attn_param_specs(d, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                                 prefix_shape=(ne,)),
        **_mlp_specs(cfg, ne),
    }
    dec = {
        "attn_norm": spec(nd, d),
        "attn": attn_param_specs(d, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                                 prefix_shape=(nd,)),
        "cross_norm": spec(nd, d),
        "cross": attn_param_specs(d, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                                  prefix_shape=(nd,)),
        **_mlp_specs(cfg, nd),
    }
    return {
        "enc_in_norm": spec(d),
        "enc_layers": enc,
        "enc_out_norm": spec(d),
        "embed": spec(cfg.vocab_padded, d),
        "dec_layers": dec,
        "final_norm": spec(d),
        "lm_head": spec(d, cfg.vocab_padded),
    }


def encode(params, src_embed: jax.Array, cfg: ModelConfig) -> jax.Array:
    """src_embed: (B, S_src, d) stub frontend output -> encoder states."""
    x = constrain(cast(src_embed), BATCH, None, None)
    x = rms_norm(x, params["enc_in_norm"], cfg.norm_eps)

    def body(h, lp):
        a, _ = attention(
            rms_norm(h, lp["attn_norm"], cfg.norm_eps), lp["attn"],
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
            rope_theta=cfg.rope_theta, causal=False,
            chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv)
        h = h + a
        h = h + swiglu(rms_norm(h, lp["mlp_norm"], cfg.norm_eps),
                       lp["w1"], lp["w3"], lp["w2"])
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rms_norm(x, params["enc_out_norm"], cfg.norm_eps)


def _dec_layer(x, lp, cfg: ModelConfig, memory=None, self_cache=None,
               cross_cache=None, pos=None, return_cache=False):
    a, new_self = attention(
        rms_norm(x, lp["attn_norm"], cfg.norm_eps), lp["attn"],
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
        rope_theta=cfg.rope_theta, causal=True, chunk_q=cfg.attn_chunk_q,
        chunk_kv=cfg.attn_chunk_kv, cache=self_cache, pos=pos,
        return_cache=return_cache)
    x = x + a
    h = rms_norm(x, lp["cross_norm"], cfg.norm_eps)
    if cross_cache is not None:          # decode: precomputed memory K/V
        b = h.shape[0]
        q = dense(h, lp["cross"]["wq"]).reshape(b, 1, cfg.n_heads, cfg.hd)
        o = decode_attention(q, cross_cache,
                             jnp.int32(cross_cache.k.shape[1] - 1))
        x = x + dense(o.reshape(b, 1, -1), lp["cross"]["wo"])
        new_cross = cross_cache
    else:                                # train/prefill: full cross-attn
        o, new_cross = attention(
            h, lp["cross"], n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.hd, rope_theta=None, causal=False,
            chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv,
            memory=memory, return_cache=return_cache)
        x = x + o
    x = x + swiglu(rms_norm(x, lp["mlp_norm"], cfg.norm_eps),
                   lp["w1"], lp["w3"], lp["w2"])
    return x, new_self, new_cross


def forward(params, src_embed, tokens, cfg: ModelConfig) -> jax.Array:
    memory = encode(params, src_embed, cfg)
    x = cast(params["embed"][tokens])

    def body(h, lp):
        h, _, _ = _dec_layer(h, lp, cfg, memory=memory)
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    return lm_logits(params, x, cfg)


def loss_fn(params, batch, cfg: ModelConfig) -> jax.Array:
    logits = forward(params, batch["src_embed"], batch["tokens"], cfg)
    return softmax_cross_entropy(logits, batch["labels"])


def prefill(params, src_embed, tokens, cfg: ModelConfig
            ) -> Tuple[jax.Array, EncDecCache]:
    memory = encode(params, src_embed, cfg)
    x = cast(params["embed"][tokens])

    def body(h, lp):
        h, skv, ckv = _dec_layer(h, lp, cfg, memory=memory,
                                 return_cache=True)
        return h, (skv, ckv)

    if cfg.remat:
        body = jax.checkpoint(body)
    x, (skv, ckv) = jax.lax.scan(body, x, params["dec_layers"])
    return (lm_logits(params, x[:, -1:, :], cfg),
            EncDecCache(KVCache(*skv), KVCache(*ckv)))


def decode_step(params, token, pos, cache: EncDecCache, cfg: ModelConfig):
    x = cast(params["embed"][token[:, None]])

    def body(h, xs):
        lp, sk, sv, ck, cv = xs
        h, new_self, _ = _dec_layer(h, lp, cfg, self_cache=KVCache(sk, sv),
                                    cross_cache=KVCache(ck, cv), pos=pos)
        return h, new_self

    x, new_self = jax.lax.scan(
        body, x, (params["dec_layers"], cache.self_kv.k, cache.self_kv.v,
                  cache.cross_kv.k, cache.cross_kv.v))
    return (lm_logits(params, x, cfg),
            EncDecCache(KVCache(*new_self), cache.cross_kv))


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int, src_len: int
                ) -> EncDecCache:
    L = cfg.n_layers
    kv, hd = cfg.n_kv_heads, cfg.hd
    return EncDecCache(
        KVCache(spec(L, batch, seq_len, kv, hd, dtype=COMPUTE_DTYPE),
                spec(L, batch, seq_len, kv, hd, dtype=COMPUTE_DTYPE)),
        KVCache(spec(L, batch, src_len, kv, hd, dtype=COMPUTE_DTYPE),
                spec(L, batch, src_len, kv, hd, dtype=COMPUTE_DTYPE)))
