"""LM substrate: six model families behind one ModelAPI."""
from . import attention, common, dense, encdec, model_zoo, moe, rwkv, ssm, vlm
from .model_zoo import ModelAPI, build, init_params, input_specs

__all__ = ["ModelAPI", "build", "init_params", "input_specs", "attention",
           "common", "dense", "encdec", "model_zoo", "moe", "rwkv", "ssm",
           "vlm"]
