"""Unified model API across the six families.

``build(cfg)`` returns a ``ModelAPI`` whose three entry points take a
``batch`` dict (and a cache/state pytree for decode), hiding family
differences from the training loop, the serving loop, and the dry-run:

  train:   batch = {tokens, labels [, src_embed | img_embed]}
  prefill: batch = {tokens [, src_embed | img_embed]}
  decode:  batch = {token (B,), pos ()} + cache pytree

``input_specs`` produces ShapeDtypeStructs for every input of an assigned
(arch x shape) cell, allocation-free, for ``.lower().compile()``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig

from . import dense, encdec, moe, rwkv, ssm, vlm
from .common import COMPUTE_DTYPE, count_params, init_from_specs, spec

# Fixed stub lengths for modality frontends at decode time (DESIGN.md).
ENCDEC_DECODE_SRC_LEN = 4096


class ModelAPI(NamedTuple):
    cfg: ModelConfig
    param_specs: Any
    loss: Callable          # (params, batch) -> scalar
    prefill: Callable       # (params, batch) -> (logits, cache)
    decode: Callable        # (params, batch, cache) -> (logits, cache)
    cache_specs: Callable   # (batch_size, seq_len) -> pytree | None
    num_params: int
    num_active_params: int  # = num_params for non-MoE


def _moe_active_params(cfg: ModelConfig, total: int) -> int:
    """Parameters touched per token: experts count only top_k of n_experts."""
    per_expert = 3 * cfg.d_model * cfg.d_expert
    all_experts = cfg.n_layers * cfg.n_experts * per_expert
    active_experts = cfg.n_layers * cfg.top_k * per_expert
    return total - all_experts + active_experts


def build(cfg: ModelConfig) -> ModelAPI:
    fam = cfg.family
    if fam == "dense":
        specs = dense.param_specs(cfg)
        api = ModelAPI(
            cfg, specs,
            loss=lambda p, b: dense.loss_fn(p, b, cfg),
            prefill=lambda p, b: dense.prefill(p, b["tokens"], cfg),
            decode=lambda p, b, c: dense.decode_step(
                p, b["token"], b["pos"], c, cfg),
            cache_specs=lambda bs, sl: dense.cache_specs(cfg, bs, sl),
            num_params=count_params(specs), num_active_params=0)
    elif fam == "moe":
        specs = moe.param_specs(cfg)
        api = ModelAPI(
            cfg, specs,
            loss=lambda p, b: moe.loss_fn(p, b, cfg),
            prefill=lambda p, b: moe.prefill(p, b["tokens"], cfg),
            decode=lambda p, b, c: moe.decode_step(
                p, b["token"], b["pos"], c, cfg),
            cache_specs=lambda bs, sl: moe.cache_specs(cfg, bs, sl),
            num_params=count_params(specs), num_active_params=0)
    elif fam == "encdec":
        specs = encdec.param_specs(cfg)
        api = ModelAPI(
            cfg, specs,
            loss=lambda p, b: encdec.loss_fn(p, b, cfg),
            prefill=lambda p, b: encdec.prefill(
                p, b["src_embed"], b["tokens"], cfg),
            decode=lambda p, b, c: encdec.decode_step(
                p, b["token"], b["pos"], c, cfg),
            cache_specs=lambda bs, sl: encdec.cache_specs(
                cfg, bs, sl, ENCDEC_DECODE_SRC_LEN),
            num_params=count_params(specs), num_active_params=0)
    elif fam == "vlm":
        specs = vlm.param_specs(cfg)
        api = ModelAPI(
            cfg, specs,
            loss=lambda p, b: vlm.loss_fn(p, b, cfg),
            prefill=lambda p, b: vlm.prefill(
                p, b["tokens"], b["img_embed"], cfg),
            decode=lambda p, b, c: vlm.decode_step(
                p, b["token"], b["pos"], c, cfg),
            cache_specs=lambda bs, sl: vlm.cache_specs(cfg, bs, sl),
            num_params=count_params(specs), num_active_params=0)
    elif fam == "rwkv":
        specs = rwkv.param_specs(cfg)
        api = ModelAPI(
            cfg, specs,
            loss=lambda p, b: rwkv.loss_fn(p, b, cfg),
            prefill=lambda p, b: rwkv.prefill(p, b["tokens"], cfg),
            decode=lambda p, b, c: rwkv.decode_step(
                p, b["token"], b["pos"], c, cfg),
            cache_specs=lambda bs, sl: rwkv.state_specs(cfg, bs),
            num_params=count_params(specs), num_active_params=0)
    elif fam == "hybrid":
        specs = ssm.param_specs(cfg)
        api = ModelAPI(
            cfg, specs,
            loss=lambda p, b: ssm.loss_fn(p, b, cfg),
            prefill=lambda p, b: ssm.prefill(p, b["tokens"], cfg),
            decode=lambda p, b, c: ssm.decode_step(
                p, b["token"], b["pos"], c, cfg),
            cache_specs=lambda bs, sl: ssm.state_specs(cfg, bs, sl),
            num_params=count_params(specs), num_active_params=0)
    else:
        raise ValueError(f"unknown family {fam}")

    active = (_moe_active_params(cfg, api.num_params)
              if fam == "moe" else api.num_params)
    return api._replace(num_active_params=active)


def init_params(api: ModelAPI, key: jax.Array):
    return init_from_specs(api.param_specs, key)


def input_specs(cfg: ModelConfig, shape: ShapeConfig
                ) -> Tuple[dict, Optional[Any]]:
    """(batch specs, cache specs or None) for one (arch x shape) cell."""
    b, s = shape.global_batch, shape.seq_len
    tok = spec(b, s, dtype=jnp.int32)
    if shape.kind == "train":
        batch = {"tokens": tok, "labels": tok}
        if cfg.family == "encdec":
            batch["src_embed"] = spec(b, s, cfg.d_model, dtype=COMPUTE_DTYPE)
        if cfg.family == "vlm":
            batch["img_embed"] = spec(b, cfg.n_img_tokens, cfg.d_model,
                                      dtype=COMPUTE_DTYPE)
        return batch, None
    if shape.kind == "prefill":
        batch = {"tokens": tok}
        if cfg.family == "encdec":
            batch["src_embed"] = spec(b, s, cfg.d_model, dtype=COMPUTE_DTYPE)
        if cfg.family == "vlm":
            batch["img_embed"] = spec(b, cfg.n_img_tokens, cfg.d_model,
                                      dtype=COMPUTE_DTYPE)
        return batch, None
    # decode: one new token against a seq_len-deep cache/state
    batch = {"token": spec(b, dtype=jnp.int32), "pos": spec(dtype=jnp.int32)}
    api_cache = build(cfg).cache_specs(b, s)
    return batch, api_cache
