"""Pallas TPU kernels for Spinner's compute hot-spots (validated interpret=True)."""
from . import ops, ref
from .ops import spinner_scores, spinner_scores_tiled
from .spinner_scores import spinner_scores_pallas

__all__ = ["ops", "ref", "spinner_scores", "spinner_scores_tiled",
           "spinner_scores_pallas"]
