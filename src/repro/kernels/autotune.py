"""Deterministic tile-config autotuner for the Pallas vertex-update kernels.

The engine binds a (tile_v, tile_e) choice into the Pallas backend at
trace time (``engine._autotuned``), so the choice MUST be a pure function
of the graph's shape statistics -- no wall-clock probing, no device
state.  A cost model is enough here because the kernel's behaviour is
simple and fully determined by the tiling:

  * compute: each (tile, chunk) grid step does two one-hot matmuls,
    ``2 * tile_e * (tile_v + k_pad)`` MACs, over ``T * C`` steps with
    ``e_pad = T * C * tile_e`` padded edge slots -- so larger tiles waste
    flops on padding, smaller tiles waste them on ragged chunks;
  * memory: the edge stream (src_local, dst_label, w = 12 B/edge slot)
    plus the (padded_v, k_pad) tie-noise block; the fused megakernel
    never writes the score matrix, so there is no V*k term beyond noise;
  * dispatch: a fixed per-grid-step overhead, which is what actually
    penalizes tiny tiles on ragged degree distributions.

Chunk counts come from the same round-robin degree balancing the real
tiling uses (``graph.round_robin_perm`` semantics), so ``e_pad`` here
matches ``build_tiled_csr`` exactly for the single-tiling path.

Choices are memoized on ``(V, E, k_pad, ndev)``: the first graph of a
session shape bucket decides, and every same-bucket rebind reuses the
choice -- a warm ``PartitionSession.adapt()`` can never flip tile config
mid-session (the autotune-determinism CI check relies on this).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

# (tile_v, tile_e) sweep; tile_v multiples of 8 (f32 sublane), tile_e is
# the chunk edge count. 128 lanes keeps every operand MXU/VPU aligned.
CANDIDATES = ((128, 128), (128, 256), (128, 512),
              (256, 128), (256, 256))

# single source of truth with benchmarks/roofline.py (TPU v5e)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
GRID_STEP_OVERHEAD_S = 5e-7   # per-step dispatch/pipeline bubble (model)

_CHOICE_CACHE: dict = {}


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _padded_edges(deg: np.ndarray, tile_v: int, tile_e: int,
                  min_total: int = 0) -> Tuple[int, int, int]:
    """(T, C, e_pad) after round-robin degree balancing, matching
    ``build_tiled_csr``'s chunk geometry for this degree sequence.

    ``min_total`` mirrors the build's ``min_total_slots`` floor: under
    the bucketed session layout every tiling reserves at least the edge
    bucket's worth of slots so the delta-merge append region survives
    retiling, and the model must charge for those slots too.
    """
    V = int(deg.shape[0])
    T = max(1, -(-V // tile_v))
    if V <= tile_v:
        counts = np.array([deg.sum()], dtype=np.int64)
    else:
        d = np.sort(deg.astype(np.int64))[::-1]
        counts = np.zeros(T, dtype=np.int64)
        np.add.at(counts, np.arange(V, dtype=np.int64) % T, d)
    C = max(1, -(-int(counts.max()) // tile_e))
    if min_total:
        C = max(C, -(-int(min_total) // (T * tile_e)))
    return T, C, T * C * tile_e


def _shard_cost(deg: np.ndarray, tile_v: int, tile_e: int,
                k_pad: int, min_total: int = 0) -> float:
    T, C, e_pad = _padded_edges(deg, tile_v, tile_e, min_total)
    padded_v = T * tile_v
    flops = 2.0 * e_pad * (tile_v + k_pad)      # two one-hot matmuls
    hbm = e_pad * 12.0 + padded_v * k_pad * 4.0  # edge stream + noise
    return (flops / PEAK_FLOPS + hbm / HBM_BW
            + T * C * GRID_STEP_OVERHEAD_S)


def _shard_degrees(graph, ndev: int):
    """Per-shard REAL entry counts (weight-0 filler never gets tiled)."""
    src = np.asarray(graph.src)
    w = np.asarray(graph.weight)
    deg = np.bincount(src[w > 0], minlength=graph.num_vertices
                      ).astype(np.int64)
    if ndev <= 1:
        return [deg]
    v_local = -(-deg.shape[0] // ndev)
    return [deg[p * v_local:(p + 1) * v_local] for p in range(ndev)]


def _min_total(graph, ndev: int) -> int:
    # the single-tiling build floors its slot count to the padded entry
    # count (the delta append region); the per-shard build does not
    return int(np.asarray(graph.src).shape[0]) if ndev <= 1 else 0


def sweep(graph, k: int, ndev: int = 1) -> list:
    """All candidate costs (modeled seconds/iteration, max over shards)."""
    k_pad = round_up(max(k, 1), 128)
    shards = _shard_degrees(graph, ndev)
    min_total = _min_total(graph, ndev)
    rows = []
    for tile_v, tile_e in CANDIDATES:
        cost = max(_shard_cost(d, tile_v, tile_e, k_pad, min_total)
                   for d in shards)
        T, C, e_pad = _padded_edges(shards[0], tile_v, tile_e, min_total)
        rows.append({"tile_v": tile_v, "tile_e": tile_e, "k_pad": k_pad,
                     "cost_s": cost, "grid": T * C, "e_pad": e_pad})
    return rows


def choose_tile_config(graph, k: int, ndev: int = 1
                       ) -> Tuple[int, int, int]:
    """(tile_v, tile_e, k_pad) minimizing the modeled per-iteration cost.

    Deterministic: strict ``<`` comparison with ties broken by CANDIDATES
    order, and the result memoized on the graph's (V, E, k_pad, ndev).
    """
    k_pad = round_up(max(k, 1), 128)
    key = (int(graph.num_vertices), int(np.asarray(graph.src).shape[0]),
           k_pad, int(ndev))
    hit = _CHOICE_CACHE.get(key)
    if hit is not None:
        return hit
    best, best_cost = CANDIDATES[0], float("inf")
    shards = _shard_degrees(graph, ndev)
    min_total = _min_total(graph, ndev)
    for tile_v, tile_e in CANDIDATES:
        cost = max(_shard_cost(d, tile_v, tile_e, k_pad, min_total)
                   for d in shards)
        if cost < best_cost:
            best, best_cost = (tile_v, tile_e), cost
    choice = (best[0], best[1], k_pad)
    _CHOICE_CACHE[key] = choice
    return choice


def modeled_traffic(padded_v: int, e_pad: int, k_pad: int
                    ) -> Tuple[dict, dict]:
    """(split, fused) per-iteration HBM byte models for the update.

    The split path materializes the (padded_v, k_pad) score matrix in HBM
    (kernel write) and immediately re-reads it for the XLA
    normalize/argmax chain; the fused megakernel keeps that block in VMEM,
    so exactly those two V*k terms disappear.  The tie-noise block is
    charged identically to both (write at draw + read at use) -- the fused
    row permute fuses into the consuming kernel's gather.
    """
    vk = padded_v * k_pad * 4.0
    edge = e_pad * 12.0                 # src_local + dst_label + w
    split = {"edge_stream": edge, "noise": 2.0 * vk,
             "score_write": vk, "score_read": vk}
    fused = {"edge_stream": edge, "noise": 2.0 * vk}
    return split, fused
