"""Pallas TPU kernels for the Pregel message-combine hot loop.

A Pregel superstep's inner reduction is ``acc[dst-owner-local(src)] =
combine(acc[...], message(dst))`` over every edge of the shard -- the
same sparse pattern as Spinner's ComputeScores, but reducing a SCALAR
per vertex instead of a (k,) score row.  The kernels reuse the
``spinner_scores`` tiling verbatim: edges arrive pre-sorted into
``(T, C, TILE_E)`` chunks whose chunk rows all map into one
``tile_v``-row vertex tile (``core.graph.build_sharded_tiled_csr``),
message values are gathered OUTSIDE the kernel (``lookup[dst]``, the
exchange plan's ``[local | halo]`` layout), and a VMEM scratch
accumulator is revisited across the chunk grid dimension.

Two combine monoids cover the workload suite (``repro.apps``):

  * ``sum``  -- PageRank: a one-hot matmul per chunk, exactly the
    ``spinner_scores`` reduction with k = 1.  f32, tolerance-exact
    vs. the XLA scatter-add (different association order).
  * ``min``  -- WCC / BFS / SSSP: a masked minimum per chunk.  int32,
    BIT-exact vs. the XLA ``.at[].min`` path (min is order-free).

and two kernels share them:

  * ``pregel_reduce_pallas`` -- reduce only, emitting the raw
    ``(T, tile_v)`` partial in tiled row order.  The overlap schedule
    runs it on the interior segment while the halo exchange is in
    flight.
  * ``pregel_combine_pallas`` -- the FUSED form: on each tile's last
    chunk the VMEM accumulator flows straight into the vertex update
    (PageRank's damped affine map, or the monotone ``min(old, acc)``
    with a changed flag), optionally seeded from the interior partial
    (``acc_init``), row-compatible because both segment tilings share
    one ``ext_perm`` row layout (the `ops.PallasBackend` split idiom).

Pad edge slots carry weight-mask 0 and contribute the monoid identity;
pad ROWS (``inv_perm < 0``) carry valid=0 and emit changed=0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

INF_I32 = 2 ** 30        # "unreached" sentinel for min-combine workloads


def _accumulate(acc_ref, sl, msg, wm, *, tile_v: int, combine: str):
    """Fold one edge chunk into the (1, tile_v) scratch accumulator."""
    rows = jax.lax.broadcasted_iota(jnp.int32, (sl.shape[0], tile_v), 1)
    hit = sl[:, None] == rows                       # (TILE_E, TILE_V)
    if combine == "sum":
        onehot_v = hit.astype(jnp.float32)
        part = jax.lax.dot_general(                 # (TILE_V, 1) on the MXU
            onehot_v, (msg * wm)[:, None], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] += part[:, 0][None, :]
    else:                                           # min
        cand = jnp.where(hit & (wm[:, None] > 0), msg[:, None], INF_I32)
        acc_ref[...] = jnp.minimum(acc_ref[...], cand.min(axis=0)[None, :])


def _neutral(acc_ref, combine: str):
    if combine == "sum":
        acc_ref[...] = jnp.zeros_like(acc_ref)
    else:
        acc_ref[...] = jnp.full_like(acc_ref, INF_I32)


def _reduce_kernel(*refs, tile_v: int, nc: int, combine: str,
                   has_init: bool):
    if has_init:
        src_ref, msg_ref, wm_ref, init_ref, out_ref, acc_ref = refs
    else:
        src_ref, msg_ref, wm_ref, out_ref, acc_ref = refs
        init_ref = None
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        if init_ref is None:
            _neutral(acc_ref, combine)
        else:
            acc_ref[...] = init_ref[...]

    _accumulate(acc_ref, src_ref[0, 0, :], msg_ref[0, 0, :],
                wm_ref[0, 0, :], tile_v=tile_v, combine=combine)

    @pl.when(j == nc - 1)
    def _emit():
        out_ref[...] = acc_ref[...]


def _fused_kernel(*refs, tile_v: int, nc: int, combine: str, update: str,
                  damping: float, has_init: bool):
    if has_init:
        (src_ref, msg_ref, wm_ref, vals_ref, valid_ref, base_ref,
         init_ref, out_ref, chg_ref, acc_ref) = refs
    else:
        (src_ref, msg_ref, wm_ref, vals_ref, valid_ref, base_ref,
         out_ref, chg_ref, acc_ref) = refs
        init_ref = None
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        if init_ref is None:
            _neutral(acc_ref, combine)
        else:
            acc_ref[...] = init_ref[...]

    _accumulate(acc_ref, src_ref[0, 0, :], msg_ref[0, 0, :],
                wm_ref[0, 0, :], tile_v=tile_v, combine=combine)

    @pl.when(j == nc - 1)
    def _vertex_update():
        acc = acc_ref[0, :]
        valid = valid_ref[0, :] != 0
        if update == "pagerank":
            new = jnp.where(valid, base_ref[0, :] + damping * acc, 0.0)
            chg = valid
        else:                                        # monotone min update
            vals = vals_ref[0, :]
            new = jnp.where(valid, jnp.minimum(vals, acc), vals)
            chg = (new != vals) & valid
        out_ref[...] = new[None, :]
        chg_ref[...] = chg.astype(jnp.int32)[None, :]


def pregel_reduce_pallas(src_local: jax.Array, msg: jax.Array,
                         wm: jax.Array, *, tile_v: int, combine: str,
                         interpret: bool = False,
                         acc_init=None) -> jax.Array:
    """Segmented combine of pre-gathered messages; (T, tile_v) partial.

    Args:
      src_local: (T, C, TILE_E) int32 row of each edge within its tile.
      msg: (T, C, TILE_E) message value at each edge's destination
        (f32 for ``sum``, int32 for ``min``).
      wm: (T, C, TILE_E) f32 weight MASK (0 pads edges out; the Eq. 3
        weight magnitude is deliberately ignored -- Pregel messages are
        combined unweighted, matching ``core.pregel``'s oracles).
      acc_init: optional (T, tile_v) accumulator seed (the interior
        partial, in the SAME shared row layout).
    """
    t, c, tile_e = src_local.shape
    assert msg.shape == wm.shape == (t, c, tile_e)
    dtype = jnp.float32 if combine == "sum" else jnp.int32
    kernel = functools.partial(_reduce_kernel, tile_v=tile_v, nc=c,
                               combine=combine,
                               has_init=acc_init is not None)
    edge_spec = pl.BlockSpec((1, 1, tile_e), lambda i, j: (i, j, 0))
    row_spec = pl.BlockSpec((1, tile_v), lambda i, j: (i, 0))
    in_specs = [edge_spec, edge_spec, edge_spec]
    args = [src_local, msg.astype(dtype), wm]
    if acc_init is not None:
        in_specs.append(row_spec)
        args.append(acc_init.astype(dtype))
    return pl.pallas_call(
        kernel,
        grid=(t, c),
        in_specs=in_specs,
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct((t, tile_v), dtype),
        scratch_shapes=[pltpu.VMEM((1, tile_v), dtype)],
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("arbitrary", "arbitrary"))
        ) if not interpret else None,
    )(*args)


def pregel_combine_pallas(src_local: jax.Array, msg: jax.Array,
                          wm: jax.Array, vals: jax.Array,
                          valid: jax.Array, base: jax.Array, *,
                          tile_v: int, combine: str, update: str,
                          damping: float = 0.85, interpret: bool = False,
                          acc_init=None) -> tuple:
    """Fused combine + vertex update; ((T, tile_v) new, (T, tile_v) chg).

    ``vals``/``valid``/``base`` are (T, tile_v) rows in tiled order
    (current values, real-vertex mask, and PageRank's ``(1-d)/N``
    teleport row -- zeros for min workloads).  With ``acc_init`` the
    VMEM accumulator is seeded from the interior partial instead of the
    monoid identity, fusing the overlap schedule's second phase.
    """
    t, c, tile_e = src_local.shape
    assert msg.shape == wm.shape == (t, c, tile_e)
    dtype = jnp.float32 if combine == "sum" else jnp.int32
    kernel = functools.partial(_fused_kernel, tile_v=tile_v, nc=c,
                               combine=combine, update=update,
                               damping=float(damping),
                               has_init=acc_init is not None)
    edge_spec = pl.BlockSpec((1, 1, tile_e), lambda i, j: (i, j, 0))
    row_spec = pl.BlockSpec((1, tile_v), lambda i, j: (i, 0))
    in_specs = [edge_spec, edge_spec, edge_spec,
                row_spec, row_spec, row_spec]
    args = [src_local, msg.astype(dtype), wm, vals.astype(dtype),
            valid.astype(jnp.int32), base.astype(jnp.float32)]
    if acc_init is not None:
        in_specs.append(row_spec)
        args.append(acc_init.astype(dtype))
    out, chg = pl.pallas_call(
        kernel,
        grid=(t, c),
        in_specs=in_specs,
        out_specs=[row_spec, row_spec],
        out_shape=[jax.ShapeDtypeStruct((t, tile_v), dtype),
                   jax.ShapeDtypeStruct((t, tile_v), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((1, tile_v), dtype)],
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("arbitrary", "arbitrary"))
        ) if not interpret else None,
    )(*args)
    return out, chg


# ---------------------------------------------------------------------------
# Vertex-order wrappers (gather outside, permute in/out; trace-friendly)
# ---------------------------------------------------------------------------

def combine_tiles_interior(send: jax.Array, src_t: jax.Array,
                           idx_t: jax.Array, wm_t: jax.Array, *,
                           tile_v: int, combine: str, bias: int = 0,
                           interpret: bool = False) -> jax.Array:
    """Interior-segment reduce over the local send vector -> raw partial.

    ``idx_t`` holds LOCAL destination ids (interior edges' dst live on
    their own device by construction), so this phase needs no exchange
    data and runs while the halo collective is in flight.
    """
    msg = send[idx_t]
    if bias:
        msg = msg + bias
    return pregel_reduce_pallas(src_t, msg, wm_t, tile_v=tile_v,
                                combine=combine, interpret=interpret)


def combine_tiles_finish(partial, lookup: jax.Array, values: jax.Array,
                         valid: jax.Array, base, src_t: jax.Array,
                         idx_t: jax.Array, wm_t: jax.Array,
                         perm: jax.Array, inv_perm: jax.Array, *,
                         tile_v: int, combine: str, update: str,
                         damping: float = 0.85, bias: int = 0,
                         interpret: bool = False) -> tuple:
    """Frontier reduce seeded with the interior partial + fused update.

    ``lookup`` is the exchange plan's value table; ``values``/``valid``
    arrive in vertex order and are permuted into the shared tiled row
    layout (``inv_perm``; pad rows -> valid 0).  Returns
    ``(new_values, changed)`` back in vertex order, (v_local,) each.
    """
    t = src_t.shape[0]
    msg = lookup[idx_t]
    if bias:
        msg = msg + bias
    inv_safe = jnp.maximum(inv_perm, 0)
    vals_t = values[inv_safe].reshape(t, tile_v)
    valid_t = jnp.where(inv_perm >= 0, valid[inv_safe],
                        False).reshape(t, tile_v)
    base_t = jnp.full((t, tile_v), base, jnp.float32)
    out_t, chg_t = pregel_combine_pallas(
        src_t, msg, wm_t, vals_t, valid_t, base_t, tile_v=tile_v,
        combine=combine, update=update, damping=damping,
        interpret=interpret, acc_init=partial)
    new = out_t.reshape(-1)[perm]
    chg = chg_t.reshape(-1)[perm].astype(bool)
    return new, chg
