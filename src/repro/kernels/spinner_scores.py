"""Pallas TPU kernel for Spinner's ComputeScores hot loop.

The per-iteration work of LPA is ``scores[u, label(v)] += w(u, v)`` over all
edges -- a sparse-dense matmul A @ onehot(labels).  A GPU implementation
would use atomics; the TPU has none, and scatter lowers to serialized
dynamic-update-slices.  The TPU-native re-cast: process edges in chunks that
all share one source-vertex tile and turn the scatter into a dense MXU
matmul

    out[TILE_V, K] += onehot(src_local)[TILE_E, TILE_V]^T
                      @ (onehot(dst_label) * w)[TILE_E, K]

accumulated in a VMEM-resident (TILE_V, K) block across the chunk grid
dimension (flash-attention-style revisiting).  Preprocessing
(``core.graph.build_tiled_csr``) sorts edges by source tile, pads each tile's
chunk list, and interleaves vertices by degree so hub-heavy tiles do not
dominate the chunk count.

Pad entries carry weight 0 and therefore contribute nothing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(src_local_ref, dst_label_ref, w_ref, out_ref, *, tile_v: int,
            k_pad: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    sl = src_local_ref[0, 0, :]                       # (TILE_E,) int32
    lbl = dst_label_ref[0, 0, :]                      # (TILE_E,) int32
    w = w_ref[0, 0, :]                                # (TILE_E,) f32

    rows = jax.lax.broadcasted_iota(jnp.int32, (sl.shape[0], tile_v), 1)
    onehot_v = (sl[:, None] == rows).astype(jnp.float32)
    cols = jax.lax.broadcasted_iota(jnp.int32, (lbl.shape[0], k_pad), 1)
    onehot_l = (lbl[:, None] == cols).astype(jnp.float32) * w[:, None]

    out_ref[...] += jax.lax.dot_general(
        onehot_v, onehot_l, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def scores_from_tiles(labels_lookup: jax.Array, src_local: jax.Array,
                      dst: jax.Array, w: jax.Array, perm: jax.Array, *,
                      tile_v: int, k_pad: int, k: int,
                      interpret: bool = False) -> jax.Array:
    """Gather destination labels, run the kernel, un-permute the rows.

    The full ComputeScores pipeline for one tiling: ``dst`` indexes
    ``labels_lookup`` (the whole label vector on a single device; an
    exchange plan's ``[local | halo]`` lookup inside ``shard_map``), the
    kernel accumulates the (padded_v, k_pad) block, and ``perm`` maps the
    tiled rows back to vertex order.  Pure and trace-friendly, so it
    inlines into ``lax.while_loop`` bodies on either path.
    """
    dst_label = labels_lookup[dst]               # gather (T, C, TILE_E)
    scores_pad = spinner_scores_pallas(src_local, dst_label, w,
                                       tile_v=tile_v, k_pad=k_pad,
                                       interpret=interpret)
    return scores_pad[perm, :k]


def spinner_scores_pallas(src_local: jax.Array, dst_label: jax.Array,
                          w: jax.Array, *, tile_v: int, k_pad: int,
                          interpret: bool = False) -> jax.Array:
    """Run the tiled ComputeScores kernel.

    Args:
      src_local: (T, C, TILE_E) int32, row of each edge within its tile.
      dst_label: (T, C, TILE_E) int32, label of each edge's destination.
      w: (T, C, TILE_E) float32, Eq. (3) edge weights (0 for padding).
      tile_v: rows per source-vertex tile (multiple of 8; 128 for MXU).
      k_pad: padded label count (multiple of 128 for lane alignment).
    Returns:
      (T * tile_v, k_pad) float32 score matrix in tiled row order.
    """
    t, c, tile_e = src_local.shape
    assert dst_label.shape == w.shape == (t, c, tile_e)
    kernel = functools.partial(_kernel, tile_v=tile_v, k_pad=k_pad)
    edge_spec = pl.BlockSpec((1, 1, tile_e), lambda i, j: (i, j, 0))
    return pl.pallas_call(
        kernel,
        grid=(t, c),
        in_specs=[edge_spec, edge_spec, edge_spec],
        out_specs=pl.BlockSpec((tile_v, k_pad), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t * tile_v, k_pad), jnp.float32),
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("arbitrary", "arbitrary"))
        ) if not interpret else None,
    )(src_local, dst_label, w)
