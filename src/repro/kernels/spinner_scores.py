"""Pallas TPU kernels for Spinner's vertex-update hot loop.

The per-iteration work of LPA starts with ``scores[u, label(v)] += w(u, v)``
over all edges -- a sparse-dense matmul A @ onehot(labels).  A GPU
implementation would use atomics; the TPU has none, and scatter lowers to
serialized dynamic-update-slices.  The TPU-native re-cast: process edges in
chunks that all share one source-vertex tile and turn the scatter into a
dense MXU matmul

    out[TILE_V, K] += onehot(src_local)[TILE_E, TILE_V]^T
                      @ (onehot(dst_label) * w)[TILE_E, K]

accumulated in a VMEM-resident (TILE_V, K) block across the chunk grid
dimension (flash-attention-style revisiting).  Preprocessing
(``core.graph.build_tiled_csr``) sorts edges by source tile, pads each tile's
chunk list, and interleaves vertices by degree so hub-heavy tiles do not
dominate the chunk count.

Two kernels share that reduction:

  * ``_kernel`` / ``spinner_scores_pallas`` -- the SPLIT pipeline: emit the
    full (V_pad, k_pad) score matrix to HBM and let XLA ops do the Eq. 7-8
    normalization, tie-noise argmax and migration bookkeeping.
  * ``_fused_kernel`` / ``fused_update_pallas`` -- the FUSED vertex-update
    megakernel: on each tile's LAST chunk the VMEM accumulator flows
    directly into ``scores / max(deg_w, 1)``, the load penalty and
    current-label bonus, the -inf-masked tie-noise argmax, and the
    ComputeMigrations candidate bookkeeping -- emitting only per-tile
    ``(tile_v,)`` best-label / total-score vectors plus a revisited
    ``(1, k_pad)`` partial of the migration-candidate mass M(l).  The
    (V_pad, k_pad) matrix never touches HBM.  The epilogue that needs the
    globally psum-reduced M(l) -- the Eq. 11-12 probability test, the load
    delta and score(G) -- runs as cheap O(V + k) XLA ops on the kernel's
    vectors (``engine.make_update_parts``'s ``finish`` half), shared
    bit-for-bit with the split path.

Bit parity with the split path holds because the Eq. 3 edge weights are
small integers (f32 sums are exact under any tiling/order), the
normalization/penalty/bonus/argmax ops are the same primitives in the same
association order, and the tie-noise / migration draws are handed in over
the padded vertex set in ORIGINAL vertex order (the wrapper permutes noise
into tiled rows; the first-match argmax over the -inf-masked k_pad columns
equals ``jnp.argmax`` over k columns).

Pad entries carry weight 0 and therefore contribute nothing; pad ROWS
(``inv_perm < 0``) carry valid=0 and are masked out of the migration mass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(src_local_ref, dst_label_ref, w_ref, out_ref, *, tile_v: int,
            k_pad: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    sl = src_local_ref[0, 0, :]                       # (TILE_E,) int32
    lbl = dst_label_ref[0, 0, :]                      # (TILE_E,) int32
    w = w_ref[0, 0, :]                                # (TILE_E,) f32

    rows = jax.lax.broadcasted_iota(jnp.int32, (sl.shape[0], tile_v), 1)
    onehot_v = (sl[:, None] == rows).astype(jnp.float32)
    cols = jax.lax.broadcasted_iota(jnp.int32, (lbl.shape[0], k_pad), 1)
    onehot_l = (lbl[:, None] == cols).astype(jnp.float32) * w[:, None]

    out_ref[...] += jax.lax.dot_general(
        onehot_v, onehot_l, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def scores_from_tiles(labels_lookup: jax.Array, src_local: jax.Array,
                      dst: jax.Array, w: jax.Array, perm: jax.Array, *,
                      tile_v: int, k_pad: int, k: int,
                      interpret: bool = False) -> jax.Array:
    """Gather destination labels, run the kernel, un-permute the rows.

    The full ComputeScores pipeline for one tiling: ``dst`` indexes
    ``labels_lookup`` (the whole label vector on a single device; an
    exchange plan's ``[local | halo]`` lookup inside ``shard_map``), the
    kernel accumulates the (padded_v, k_pad) block, and ``perm`` maps the
    tiled rows back to vertex order.  Pure and trace-friendly, so it
    inlines into ``lax.while_loop`` bodies on either path.
    """
    dst_label = labels_lookup[dst]               # gather (T, C, TILE_E)
    scores_pad = spinner_scores_pallas(src_local, dst_label, w,
                                       tile_v=tile_v, k_pad=k_pad,
                                       interpret=interpret)
    return scores_pad[perm, :k]


def spinner_scores_pallas(src_local: jax.Array, dst_label: jax.Array,
                          w: jax.Array, *, tile_v: int, k_pad: int,
                          interpret: bool = False) -> jax.Array:
    """Run the tiled ComputeScores kernel.

    Args:
      src_local: (T, C, TILE_E) int32, row of each edge within its tile.
      dst_label: (T, C, TILE_E) int32, label of each edge's destination.
      w: (T, C, TILE_E) float32, Eq. (3) edge weights (0 for padding).
      tile_v: rows per source-vertex tile (multiple of 8; 128 for MXU).
      k_pad: padded label count (multiple of 128 for lane alignment).
    Returns:
      (T * tile_v, k_pad) float32 score matrix in tiled row order.
    """
    t, c, tile_e = src_local.shape
    assert dst_label.shape == w.shape == (t, c, tile_e)
    kernel = functools.partial(_kernel, tile_v=tile_v, k_pad=k_pad)
    edge_spec = pl.BlockSpec((1, 1, tile_e), lambda i, j: (i, j, 0))
    return pl.pallas_call(
        kernel,
        grid=(t, c),
        in_specs=[edge_spec, edge_spec, edge_spec],
        out_specs=pl.BlockSpec((tile_v, k_pad), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t * tile_v, k_pad), jnp.float32),
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("arbitrary", "arbitrary"))
        ) if not interpret else None,
    )(src_local, dst_label, w)


# ---------------------------------------------------------------------------
# Fused vertex-update megakernel
# ---------------------------------------------------------------------------

def _fused_kernel(*refs, tile_v: int, k_pad: int, k: int, nc: int,
                  current_bonus: float, degree_weighted: bool,
                  has_init: bool, has_act: bool = False):
    """Edge reduction + per-tile vertex update in one VMEM residency.

    Grid (T, C): chunk j accumulates its one-hot matmul into the scratch
    accumulator; the LAST chunk of each tile (j == nc - 1) finalizes the
    Eq. 7-8 per-vertex totals and the argmax proposal without the
    (tile_v, k_pad) block ever leaving VMEM.  ``m_ref`` is a revisited
    (1, k_pad) output accumulating the migration-candidate mass M(l)
    across all tiles (zeroed on the very first grid step).

    ``has_act`` threads the frontier mode's (T, 1) tile-activity bitmap:
    a tile with no active vertex skips its matmul chain and final update
    entirely and writes the safe proposal ``best = labels`` (a no-op for
    the epilogue: ``want`` is already false for every inactive vertex),
    ``tb = tc = 0``.  Inactive tiles therefore cost O(1) per chunk
    instead of O(tile_e * (tile_v + k_pad)) -- the compute analogue of
    the delta exchange plan.
    """
    n_in = 8 + int(has_init) + int(has_act)
    in_refs = refs[:n_in]
    best_ref, tb_ref, tc_ref, m_ref, acc_ref = refs[n_in:]
    (src_ref, lbl_ref, w_ref, labels_ref, deg_ref, valid_ref,
     pen_ref, noise_ref) = in_refs[:8]
    pos = 8
    init_ref = in_refs[pos] if has_init else None
    pos += int(has_init)
    act_ref = in_refs[pos] if has_act else None
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _zero_m():
        m_ref[...] = jnp.zeros_like(m_ref)

    @pl.when(j == 0)
    def _init_acc():
        # overlap schedule: seed with the interior partial (same tiling)
        acc_ref[...] = (init_ref[...] if init_ref is not None
                        else jnp.zeros_like(acc_ref))

    def _accumulate():
        sl = src_ref[0, 0, :]                         # (TILE_E,) int32
        lbl = lbl_ref[0, 0, :]                        # (TILE_E,) int32
        w = w_ref[0, 0, :]                            # (TILE_E,) f32
        rows = jax.lax.broadcasted_iota(jnp.int32, (sl.shape[0], tile_v), 1)
        onehot_v = (sl[:, None] == rows).astype(jnp.float32)
        ecols = jax.lax.broadcasted_iota(jnp.int32, (lbl.shape[0], k_pad), 1)
        onehot_l = (lbl[:, None] == ecols).astype(jnp.float32) * w[:, None]
        acc_ref[...] += jax.lax.dot_general(
            onehot_v, onehot_l, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    def _vertex_update():
        scores = acc_ref[...]                         # (tile_v, k_pad)
        deg = deg_ref[0, :]                           # (tile_v,) f32
        labels = labels_ref[0, :]                     # (tile_v,) int32
        valid = valid_ref[0, :] != 0
        # ---- Eq. 7-8: normalize, penalize, bonus, tie-noise argmax -----
        norm = scores / jnp.maximum(deg, 1.0)[:, None]
        total = norm - pen_ref[0, :][None, :]
        cols = jax.lax.broadcasted_iota(jnp.int32, (tile_v, k_pad), 1)
        cur = cols == labels[:, None]
        x = (total + noise_ref[...]) + jnp.where(
            cur, jnp.float32(current_bonus), jnp.float32(0.0))
        x = jnp.where(cols < k, x, -jnp.inf)
        # first-match argmax == jnp.argmax over the unpadded k columns
        vmax = jnp.max(x, axis=1)
        best = jnp.min(jnp.where(x == vmax[:, None], cols, k_pad),
                       axis=1).astype(jnp.int32)
        hit = cols == best[:, None]
        best_ref[0, :] = best
        tb_ref[0, :] = jnp.sum(jnp.where(hit, total, 0.0), axis=1)
        tc_ref[0, :] = jnp.sum(jnp.where(cur, total, 0.0), axis=1)
        # ---- migration-candidate mass M(l) partial (Eq. 11 numerator) --
        want = (best != labels) & valid
        measure = deg if degree_weighted else jnp.ones_like(deg)
        m_ref[0, :] += jnp.sum(
            jnp.where(hit & want[:, None], measure[:, None], 0.0), axis=0)

    if has_act:
        act = act_ref[0, 0] != 0

        @pl.when(act)
        def _accum_active():
            _accumulate()

        @pl.when((j == nc - 1) & act)
        def _update_active():
            _vertex_update()

        @pl.when((j == nc - 1) & jnp.logical_not(act))
        def _update_skipped():
            # safe no-op proposal: epilogue sees want == False everywhere
            best_ref[0, :] = labels_ref[0, :]
            tb_ref[0, :] = jnp.zeros((tile_v,), jnp.float32)
            tc_ref[0, :] = jnp.zeros((tile_v,), jnp.float32)
    else:
        _accumulate()

        @pl.when(j == nc - 1)
        def _update():
            _vertex_update()


def fused_update_pallas(src_local: jax.Array, dst_label: jax.Array,
                        w: jax.Array, labels_t: jax.Array,
                        deg_t: jax.Array, valid_t: jax.Array,
                        penalty_row: jax.Array, noise_t: jax.Array, *,
                        tile_v: int, k_pad: int, k: int,
                        current_bonus: float, degree_weighted: bool,
                        interpret: bool = False,
                        acc_init: jax.Array = None,
                        tile_act: jax.Array = None) -> tuple:
    """Launch the fused megakernel over one tiling (tiled row order).

    Args:
      src_local/dst_label/w: (T, C, TILE_E) edge chunks as in
        ``spinner_scores_pallas``.
      labels_t: (T, tile_v) int32 current labels, tiled row order.
      deg_t: (T, tile_v) f32 weighted degrees (0 on pad rows).
      valid_t: (T, tile_v) int32 1 on real vertices, 0 on pads.
      penalty_row: (1, k_pad) f32 ``loads / C`` (0 beyond k).
      noise_t: (T * tile_v, k_pad) f32 tie noise, tiled row order.
      acc_init: optional (T * tile_v, k_pad) f32 interior score partial
        (overlap schedule); the kernel seeds its accumulator with it.
      tile_act: optional (T, 1) int32 frontier-mode activity bitmap;
        tiles with 0 skip their matmuls and write no-op proposals.
    Returns:
      (best, tot_best, tot_cur, m_partial): (T, tile_v) int32 proposals,
      (T, tile_v) f32 totals at the proposal / the current label, and the
      (1, k_pad) migration-candidate mass partial.
    """
    t, c, tile_e = src_local.shape
    assert dst_label.shape == w.shape == (t, c, tile_e)
    kernel = functools.partial(
        _fused_kernel, tile_v=tile_v, k_pad=k_pad, k=k, nc=c,
        current_bonus=float(current_bonus),
        degree_weighted=degree_weighted, has_init=acc_init is not None,
        has_act=tile_act is not None)
    edge_spec = pl.BlockSpec((1, 1, tile_e), lambda i, j: (i, j, 0))
    row_spec = pl.BlockSpec((1, tile_v), lambda i, j: (i, 0))
    mat_spec = pl.BlockSpec((tile_v, k_pad), lambda i, j: (i, 0))
    k_spec = pl.BlockSpec((1, k_pad), lambda i, j: (0, 0))
    act_spec = pl.BlockSpec((1, 1), lambda i, j: (i, 0))
    in_specs = [edge_spec, edge_spec, edge_spec, row_spec, row_spec,
                row_spec, k_spec, mat_spec]
    inputs = [src_local, dst_label, w, labels_t, deg_t, valid_t,
              penalty_row, noise_t]
    if acc_init is not None:
        in_specs.append(mat_spec)
        inputs.append(acc_init)
    if tile_act is not None:
        in_specs.append(act_spec)
        inputs.append(tile_act)
    return pl.pallas_call(
        kernel,
        grid=(t, c),
        in_specs=in_specs,
        out_specs=[row_spec, row_spec, row_spec, k_spec],
        out_shape=[jax.ShapeDtypeStruct((t, tile_v), jnp.int32),
                   jax.ShapeDtypeStruct((t, tile_v), jnp.float32),
                   jax.ShapeDtypeStruct((t, tile_v), jnp.float32),
                   jax.ShapeDtypeStruct((1, k_pad), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((tile_v, k_pad), jnp.float32)],
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("arbitrary", "arbitrary"))
        ) if not interpret else None,
    )(*inputs)


def fused_update_from_tiles(labels_lookup: jax.Array, labels: jax.Array,
                            deg_t: jax.Array, noise: jax.Array,
                            valid: jax.Array, penalty: jax.Array,
                            src_local: jax.Array, dst: jax.Array,
                            w: jax.Array, perm: jax.Array,
                            inv_perm: jax.Array, *, tile_v: int,
                            k_pad: int, k: int, current_bonus: float,
                            degree_weighted: bool, interpret: bool = False,
                            acc_init: jax.Array = None,
                            frontier: bool = False) -> tuple:
    """The fused vertex-update proposal over one tiling, in VERTEX order.

    Gathers destination labels via ``dst``, permutes labels/valid/noise
    into tiled rows (``inv_perm``; pad rows get valid=0), launches the
    megakernel, and un-permutes the per-vertex outputs via ``perm``.
    ``labels``/``noise``/``valid`` are over the caller's vertex range in
    ORIGINAL order -- the same arrays the split path consumes -- which is
    what keeps the fused trajectory bit-identical.

    With ``frontier=True`` the caller's ``valid`` is the frontier mode's
    ``valid & active`` mask; a (T, 1) tile-activity bitmap is derived
    from its tiled view and handed to the kernel so all-inactive tiles
    skip their matmul chain (see ``_fused_kernel``).  Bit parity with
    the dense masked path holds because inactive vertices can never
    migrate (``want`` is false) and their score contribution is zeroed
    by the same ``valid`` mask in the epilogue.

    Returns ``(best, tot_best, tot_cur, m_partial)``: (V,) int32 / f32 /
    f32 vectors in vertex order plus the (k,) local M(l) partial, i.e.
    exactly the contract of ``engine.make_update_parts``'s ``propose``.
    """
    dst_label = labels_lookup[dst]               # gather (T, C, TILE_E)
    t = src_local.shape[0]
    inv_safe = jnp.maximum(inv_perm, 0)
    labels_t = labels[inv_safe].reshape(t, tile_v)
    valid_t = ((inv_perm >= 0) & valid[inv_safe]).astype(
        jnp.int32).reshape(t, tile_v)
    if k_pad != k:
        noise = jnp.pad(noise, ((0, 0), (0, k_pad - k)))
        penalty = jnp.pad(penalty, (0, k_pad - k))
    noise_t = noise[inv_safe]
    tile_act = jnp.max(valid_t, axis=1, keepdims=True) if frontier else None
    best_t, tb_t, tc_t, m = fused_update_pallas(
        src_local, dst_label, w, labels_t, jnp.asarray(deg_t), valid_t,
        penalty[None, :], noise_t, tile_v=tile_v, k_pad=k_pad, k=k,
        current_bonus=current_bonus, degree_weighted=degree_weighted,
        interpret=interpret, acc_init=acc_init, tile_act=tile_act)
    return (best_t.reshape(-1)[perm], tb_t.reshape(-1)[perm],
            tc_t.reshape(-1)[perm], m[0, :k])
