"""Jit'd public wrappers for the Pallas kernels + the score-backend registry.

On a TPU backend the kernel runs compiled; everywhere else it runs in
``interpret=True`` mode (the kernel body executed op-by-op on the host),
which is how correctness is validated in this repository.

The score-backend protocol at the bottom is how the device-resident engine
(``repro.core.engine``) picks its ComputeScores implementation: a backend is
built once per (graph, k) at trace time and the returned closure is inlined
into the fused ``lax.while_loop`` / ``lax.scan`` body, so the XLA
scatter-add path and the Pallas tiled kernel are interchangeable without
any per-call dispatch.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Protocol, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph, TiledCSR, build_tiled_csr

from . import ref
from .spinner_scores import spinner_scores_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnames=("tile_v", "k_pad", "k",
                                             "num_vertices", "interpret"))
def _scores_from_tiles(labels, src_local, dst, w, perm, *, tile_v: int,
                       k_pad: int, k: int, num_vertices: int,
                       interpret: bool):
    dst_label = labels[dst]                      # gather (T, C, TILE_E)
    scores_pad = spinner_scores_pallas(src_local, dst_label, w,
                                       tile_v=tile_v, k_pad=k_pad,
                                       interpret=interpret)
    return scores_pad[perm, :k]                  # back to original vertex order


def spinner_scores_tiled(labels: jax.Array, *, tiled: TiledCSR, k: int,
                         interpret: Optional[bool] = None) -> jax.Array:
    """(V, k) ComputeScores matrix via the Pallas kernel."""
    if interpret is None:
        interpret = _default_interpret()
    k_pad = round_up(max(k, 1), 128)
    return _scores_from_tiles(
        labels, jnp.asarray(tiled.src_local), jnp.asarray(tiled.dst),
        jnp.asarray(tiled.weight), jnp.asarray(tiled.perm),
        tile_v=tiled.tile_v, k_pad=k_pad, k=k,
        num_vertices=int(tiled.perm.shape[0]), interpret=interpret)


def spinner_scores(labels: jax.Array, graph: Graph, k: int,
                   tile_v: int = 128, tile_e: int = 128,
                   interpret: Optional[bool] = None) -> jax.Array:
    """Convenience: tile a Graph and compute its score matrix."""
    tiled = build_tiled_csr(graph, tile_v=tile_v, tile_e=tile_e)
    return spinner_scores_tiled(labels, tiled=tiled, k=k, interpret=interpret)


# ---------------------------------------------------------------------------
# Score-backend protocol: pluggable ComputeScores (Eq. 8 numerator)
# ---------------------------------------------------------------------------

class ScoreBackend(Protocol):
    """Builds the Eq. 8 numerator ``labels -> (V, k) scores`` closure.

    ``build`` runs once per (graph, k) at trace time -- any preprocessing
    (tiling, padding, device upload) happens there, and the returned
    closure must be pure and jit-traceable so runners can inline it into
    ``lax.while_loop`` / ``lax.scan`` bodies.

    ``build_sharded`` is the mesh-parallel counterpart: given the
    ``ShardedGraph`` layout (see ``repro.core.distributed``) it returns
    ``scores(labels_full, src_local, dst, weight) -> (v_per_dev, k)``
    computing the numerator for THIS device's vertex range from this
    device's edge shard, for use inside ``shard_map``.  ``labels_full``
    is the all-gathered label vector; the edge arrays are the local
    shard rows.  Backends without a sharded path raise
    ``NotImplementedError`` at build time (a clear trace-time failure,
    not a silent fallback).
    """

    name: str

    def build(self, graph: Graph, k: int
              ) -> Callable[[jax.Array], jax.Array]: ...

    def build_sharded(self, sg, k: int) -> Callable[..., jax.Array]: ...


@dataclasses.dataclass(frozen=True)
class XlaScatterBackend:
    """ComputeScores via XLA scatter-add -- the Pallas kernel's oracle."""

    name: str = "xla"

    def build(self, graph: Graph, k: int) -> Callable[[jax.Array], jax.Array]:
        from repro.core.engine import device_edges   # shared upload cache
        src, dst, w, _ = device_edges(graph)
        V = graph.num_vertices

        def scores(labels: jax.Array) -> jax.Array:
            return ref.spinner_scores_ref(labels, src, dst, w, V, k)

        return scores

    def build_sharded(self, sg, k: int) -> Callable[..., jax.Array]:
        """Local scatter-add over this device's edge shard.

        Row-for-row ``spinner_scores_ref`` restricted to the local vertex
        range (zero-weight padding rows add 0 to row 0 and change
        nothing), so on a 1-device mesh -- where the shard is the whole
        CSR-ordered edge list -- the result is bit-identical to
        ``build``'s unsharded path.
        """
        vl = sg.v_per_dev

        def scores(labels_full: jax.Array, src_local: jax.Array,
                   dst: jax.Array, w: jax.Array) -> jax.Array:
            nbr = labels_full[dst]
            return jnp.zeros((vl, k), jnp.float32).at[src_local, nbr].add(w)

        return scores


@dataclasses.dataclass(frozen=True)
class PallasTiledBackend:
    """ComputeScores via the tiled one-hot-matmul Pallas kernel."""

    name: str = "pallas"
    tile_v: int = 128
    tile_e: int = 128
    interpret: Optional[bool] = None   # None -> compiled on TPU else interpret

    def build(self, graph: Graph, k: int) -> Callable[[jax.Array], jax.Array]:
        tiled = build_tiled_csr(graph, tile_v=self.tile_v, tile_e=self.tile_e)
        return functools.partial(spinner_scores_tiled, tiled=tiled, k=k,
                                 interpret=self.interpret)

    def build_sharded(self, sg, k: int) -> Callable[..., jax.Array]:
        raise NotImplementedError(
            "score backend 'pallas' has no sharded implementation yet: the "
            "tiled CSR would need to be rebuilt per edge shard and the "
            "kernel launched inside shard_map. Use score_backend='xla' "
            "with engine='sharded' (the backends are interchangeable "
            "oracles on the unsharded engines).")


SCORE_BACKENDS = {
    "xla": XlaScatterBackend(),
    "pallas": PallasTiledBackend(),
}


def get_score_backend(backend: Union[str, ScoreBackend]) -> ScoreBackend:
    """Resolve a backend name; backend instances pass through unchanged."""
    if isinstance(backend, str):
        try:
            return SCORE_BACKENDS[backend]
        except KeyError:
            raise ValueError(
                f"unknown score backend {backend!r}; "
                f"available: {sorted(SCORE_BACKENDS)}") from None
    return backend
