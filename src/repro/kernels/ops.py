"""Jit'd public wrappers for the Pallas kernels.

On a TPU backend the kernel runs compiled; everywhere else it runs in
``interpret=True`` mode (the kernel body executed op-by-op on the host),
which is how correctness is validated in this repository.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph, TiledCSR, build_tiled_csr

from . import ref
from .spinner_scores import spinner_scores_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnames=("tile_v", "k_pad", "k",
                                             "num_vertices", "interpret"))
def _scores_from_tiles(labels, src_local, dst, w, perm, *, tile_v: int,
                       k_pad: int, k: int, num_vertices: int,
                       interpret: bool):
    dst_label = labels[dst]                      # gather (T, C, TILE_E)
    scores_pad = spinner_scores_pallas(src_local, dst_label, w,
                                       tile_v=tile_v, k_pad=k_pad,
                                       interpret=interpret)
    return scores_pad[perm, :k]                  # back to original vertex order


def spinner_scores_tiled(labels: jax.Array, *, tiled: TiledCSR, k: int,
                         interpret: Optional[bool] = None) -> jax.Array:
    """(V, k) ComputeScores matrix via the Pallas kernel."""
    if interpret is None:
        interpret = _default_interpret()
    k_pad = round_up(max(k, 1), 128)
    return _scores_from_tiles(
        labels, jnp.asarray(tiled.src_local), jnp.asarray(tiled.dst),
        jnp.asarray(tiled.weight), jnp.asarray(tiled.perm),
        tile_v=tiled.tile_v, k_pad=k_pad, k=k,
        num_vertices=int(tiled.perm.shape[0]), interpret=interpret)


def spinner_scores(labels: jax.Array, graph: Graph, k: int,
                   tile_v: int = 128, tile_e: int = 128,
                   interpret: Optional[bool] = None) -> jax.Array:
    """Convenience: tile a Graph and compute its score matrix."""
    tiled = build_tiled_csr(graph, tile_v=tile_v, tile_e=tile_e)
    return spinner_scores_tiled(labels, tiled=tiled, k=k, interpret=interpret)
