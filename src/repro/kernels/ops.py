"""Jit'd public wrappers for the Pallas kernels + the score-backend registry.

On a TPU backend the kernel runs compiled; everywhere else it runs in
``interpret=True`` mode (the kernel body executed op-by-op on the host),
which is how correctness is validated in this repository.

The score-backend protocol at the bottom is how the device-resident engine
(``repro.core.engine``) picks its ComputeScores implementation: a backend is
built once per (graph, k) at trace time and the returned closure is inlined
into the fused ``lax.while_loop`` / ``lax.scan`` body, so the XLA
scatter-add path and the Pallas tiled kernel are interchangeable without
any per-call dispatch.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Protocol, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import (Graph, TiledCSR, build_sharded_tiled_csr,
                              build_tiled_csr, round_robin_perm)

from . import ref
from .spinner_scores import (fused_update_from_tiles, scores_from_tiles,
                             spinner_scores_pallas)


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnames=("tile_v", "k_pad", "k",
                                             "interpret"))
def _scores_from_tiles(labels, src_local, dst, w, perm, *, tile_v: int,
                       k_pad: int, k: int, interpret: bool):
    # jitted entry so standalone spinner_scores_tiled() calls cache their
    # compilation; engine traces inline scores_from_tiles directly
    return scores_from_tiles(labels, src_local, dst, w, perm, tile_v=tile_v,
                             k_pad=k_pad, k=k, interpret=interpret)


def spinner_scores_tiled(labels: jax.Array, *, tiled: TiledCSR, k: int,
                         interpret: Optional[bool] = None) -> jax.Array:
    """(V, k) ComputeScores matrix via the Pallas kernel."""
    if interpret is None:
        interpret = _default_interpret()
    k_pad = round_up(max(k, 1), 128)
    return _scores_from_tiles(
        labels, jnp.asarray(tiled.src_local), jnp.asarray(tiled.dst),
        jnp.asarray(tiled.weight), jnp.asarray(tiled.perm),
        tile_v=tiled.tile_v, k_pad=k_pad, k=k, interpret=interpret)


def spinner_scores(labels: jax.Array, graph: Graph, k: int,
                   tile_v: int = 128, tile_e: int = 128,
                   interpret: Optional[bool] = None) -> jax.Array:
    """Convenience: tile a Graph and compute its score matrix."""
    tiled = build_tiled_csr(graph, tile_v=tile_v, tile_e=tile_e)
    return spinner_scores_tiled(labels, tiled=tiled, k=k, interpret=interpret)


# ---------------------------------------------------------------------------
# Score-backend protocol: pluggable ComputeScores (Eq. 8 numerator)
# ---------------------------------------------------------------------------

class ScoreBackend(Protocol):
    """The Eq. 8 numerator as (graph-independent closure, per-graph args).

    The device-resident engine compiles runners once per SHAPE BUCKET and
    reuses them across graphs (see ``repro.core.session``), so a backend
    is split in two:

      * ``make_scores(k)`` / ``make_sharded_scores(k, v_local)`` return a
        pure traced closure ``(labels_or_lookup, *edge_args) -> scores``
        that reads only static python ints (k, tile sizes, interpret
        mode) off the backend -- its identity for the engine's program
        cache is ``signature()``;
      * ``graph_args(graph, k, pad)`` / ``sharded_graph_args(sg, k,
        dst_index, pad)`` build the per-graph device arrays the closure
        consumes.  ``pad=True`` buckets derived shapes (the Pallas chunk
        count) so a session rebinding a grown graph keeps the compile
        shape.  For the sharded form the arrays carry a leading ndev
        dimension and are threaded through ``shard_map`` with
        ``PartitionSpec(axis)``; ``dst_index`` is the exchange plan's
        per-edge index (global vertex ids for all-gather/delta,
        halo-remapped slots for halo);
      * ``make_sharded_scores_split(k, v_local)`` /
        ``sharded_graph_args_split(sg, k, dst_index, pad)`` are the
        TWO-PHASE form for the engine's overlap schedule
        (``EngineOptions.overlap``): the edge shard is split at
        ``ShardedGraph.e_interior`` into an interior segment (dst labels
        readable from the local label shard) and a frontier segment (dst
        labels arriving via the exchange plan's lookup).  The returned
        ``(interior_fn, frontier_fn)`` closures both take the full split
        arg tuple: ``interior_fn(labels_local, *args)`` accumulates the
        interior partial while the exchange is in flight, and
        ``frontier_fn(partial, lookup, *args)`` finishes the (v_local,
        k) block.  The integer Eq. 3 edge weights make both f32 phases
        exact, so interior + frontier is bit-identical to the
        single-phase sum.

    A backend may ADDITIONALLY implement the FUSED vertex-update protocol
    (``EngineOptions.fused_update``): ``make_fused_update(k, *,
    degree_weighted, current_bonus)`` returns a whole-iteration closure
    ``fused(lookup, labels, deg_w, loads, noise, u, valid, reduce_, C,
    *fused_graph_args) -> (new_labels, new_loads, score_g, n_mig,
    mig_mass)`` matching ``engine.make_vertex_update``'s output contract
    bit for bit, but free to keep the (V, k) score matrix out of HBM
    (the Pallas megakernel does).  The sharded forms
    ``make_sharded_fused_update(k, v_local, ...)`` /
    ``make_sharded_fused_update_split(k, v_local, ...)`` mirror the
    scores/scores_split pair (the split interior returns a RAW partial in
    whatever layout the backend's frontier closure expects), with
    ``sharded_fused_graph_args`` / ``sharded_fused_graph_args_split``
    building their per-graph arrays.  ``fused_auto = True`` opts the
    backend into ``fused_update="auto"`` selection.

    The legacy ``build`` / ``build_sharded`` closure forms (args baked
    in) are RETIRED: every in-repo caller uses the split protocol above,
    and the base class methods below raise with a pointer at it.
    """

    name: str

    def signature(self) -> tuple: ...

    def make_scores(self, k: int) -> Callable: ...

    def graph_args(self, graph: Graph, k: int, pad: bool = False
                   ) -> tuple: ...

    def make_sharded_scores(self, k: int, v_local: int) -> Callable: ...

    def sharded_graph_args(self, sg, k: int, dst_index: np.ndarray,
                           pad: bool = False) -> tuple: ...

    def make_sharded_scores_split(self, k: int, v_local: int
                                  ) -> tuple: ...

    def sharded_graph_args_split(self, sg, k: int, dst_index: np.ndarray,
                                 pad: bool = False) -> tuple: ...


def _legacy_build_error(name: str) -> NotImplementedError:
    return NotImplementedError(
        f"ScoreBackend.{name} was retired: the baked-in closure form kept "
        "per-graph arrays alive inside compiled programs.  Use the split "
        "protocol instead -- make_scores(k) / graph_args(graph, k, pad) "
        "(or the sharded/fused variants) -- and pass the args explicitly; "
        "see the ScoreBackend docstring in repro.kernels.ops.")


def _split_dst_views(sg, dst_index) -> tuple:
    """(interior dst as LOCAL vertex ids, frontier dst in plan layout).

    The interior conversion is plan-independent: an interior edge's dst
    lives on its own device by construction, so its local id is just the
    global id minus the owner offset (interior pad slots carry the
    owner's vertex 0 and land on local id 0).  The frontier half keeps
    whatever index the exchange plan's lookup array expects.
    """
    e = sg.e_interior
    offs = (np.arange(sg.ndev, dtype=np.int64) * sg.v_per_dev)[:, None]
    d_int = (sg.dst[:, :e].astype(np.int64) - offs).astype(np.int32)
    d_fro = np.asarray(dst_index)[:, e:].astype(np.int32)
    return d_int, d_fro


@dataclasses.dataclass(frozen=True)
class XlaScatterBackend:
    """ComputeScores via XLA scatter-add -- the Pallas kernel's oracle."""

    name: str = "xla"

    def signature(self) -> tuple:
        return ("xla",)

    def make_scores(self, k: int) -> Callable:
        def scores(labels, src, dst, w):
            return ref.spinner_scores_ref(labels, src, dst, w,
                                          labels.shape[0], k)
        return scores

    def graph_args(self, graph: Graph, k: int, pad: bool = False) -> tuple:
        from repro.core.engine import device_edges   # shared upload cache
        src, dst, w, _ = device_edges(graph)
        return (src, dst, w)

    def make_sharded_scores(self, k: int, v_local: int) -> Callable:
        """Local scatter-add over this device's edge shard.

        Row-for-row ``spinner_scores_ref`` restricted to the local vertex
        range (zero-weight padding rows add 0 to row 0 and change
        nothing), so on a 1-device mesh -- where the shard is the whole
        CSR-ordered edge list -- the result is bit-identical to the
        unsharded path.
        """
        def scores(lookup, src_local, dst_idx, w):
            nbr = lookup[dst_idx]
            return jnp.zeros((v_local, k),
                             jnp.float32).at[src_local, nbr].add(w)
        return scores

    def sharded_graph_args(self, sg, k: int, dst_index: np.ndarray,
                           pad: bool = False) -> tuple:
        from repro.core.distributed import device_upload   # lazy: no cycle
        # the allgather/delta plans index with the global dst ids verbatim
        # (dst_index IS sg.dst), so reuse the cached upload; halo's
        # remapped slots are a genuinely different array
        dst = (device_upload(sg, "dst") if dst_index is sg.dst
               else jnp.asarray(np.asarray(dst_index, np.int32)))
        return (device_upload(sg, "src_local"), dst,
                device_upload(sg, "weight"))

    def make_sharded_scores_split(self, k: int, v_local: int) -> tuple:
        """Two-phase scatter-add over the [interior | frontier] segments
        (see the protocol docstring): the interior half reads the local
        label shard, the frontier half the exchange plan's lookup."""
        def interior(labels_local, src_i, dst_i, w_i, src_f, dst_f, w_f):
            nbr = labels_local[dst_i]
            return jnp.zeros((v_local, k),
                             jnp.float32).at[src_i, nbr].add(w_i)

        def frontier(partial, lookup, src_i, dst_i, w_i, src_f, dst_f,
                     w_f):
            return partial.at[src_f, lookup[dst_f]].add(w_f)

        return interior, frontier

    def sharded_graph_args_split(self, sg, k: int, dst_index: np.ndarray,
                                 pad: bool = False) -> tuple:
        e = sg.e_interior
        d_int, d_fro = _split_dst_views(sg, dst_index)
        return (jnp.asarray(sg.src_local[:, :e]), jnp.asarray(d_int),
                jnp.asarray(sg.weight[:, :e]),
                jnp.asarray(sg.src_local[:, e:]), jnp.asarray(d_fro),
                jnp.asarray(sg.weight[:, e:]))

    # ---- fused vertex update: scatter scores + the reference halves ----
    # XLA has no VMEM residency to exploit, so the "fused" form is simply
    # the scatter-add composed with engine.make_update_parts -- the
    # reference implementation every fused kernel is measured against.
    fused_auto = False

    def make_fused_update(self, k: int, *, degree_weighted: bool,
                          current_bonus: float,
                          frontier: bool = False) -> Callable:
        from repro.core.engine import make_update_parts   # lazy: no cycle
        propose, finish = make_update_parts(
            k, degree_weighted=degree_weighted, current_bonus=current_bonus)

        def fused(lookup, labels, deg_w, loads, noise, u, valid, reduce_,
                  C, src, dst, w):
            scores = ref.spinner_scores_ref(lookup, src, dst, w,
                                            labels.shape[0], k)
            best, tb, tc, m = propose(scores, labels, deg_w, loads, noise,
                                      valid, C)
            out = finish(best, tb, tc, m, labels, deg_w, loads, u, valid,
                         reduce_, C)
            if frontier:
                # the frontier runner needs the pre-throttle want mask to
                # carry the active set forward and detect the drain
                return out + ((best != labels) & valid,)
            return out
        return fused

    def fused_graph_args(self, graph: Graph, k: int,
                         pad: bool = False) -> tuple:
        return self.graph_args(graph, k, pad=pad)

    def make_sharded_fused_update(self, k: int, v_local: int, *,
                                  degree_weighted: bool,
                                  current_bonus: float,
                                  frontier: bool = False) -> Callable:
        from repro.core.engine import make_update_parts
        propose, finish = make_update_parts(
            k, degree_weighted=degree_weighted, current_bonus=current_bonus)

        def fused(lookup, labels, deg_w, loads, noise, u, valid, reduce_,
                  C, src_local, dst_idx, w):
            nbr = lookup[dst_idx]
            scores = jnp.zeros((v_local, k),
                               jnp.float32).at[src_local, nbr].add(w)
            best, tb, tc, m = propose(scores, labels, deg_w, loads, noise,
                                      valid, C)
            out = finish(best, tb, tc, m, labels, deg_w, loads, u, valid,
                         reduce_, C)
            if frontier:
                return out + ((best != labels) & valid,)
            return out
        return fused

    def sharded_fused_graph_args(self, sg, k: int, dst_index: np.ndarray,
                                 pad: bool = False) -> tuple:
        return self.sharded_graph_args(sg, k, dst_index, pad=pad)

    def make_sharded_fused_update_split(self, k: int, v_local: int, *,
                                        degree_weighted: bool,
                                        current_bonus: float) -> tuple:
        from repro.core.engine import make_update_parts
        propose, finish = make_update_parts(
            k, degree_weighted=degree_weighted, current_bonus=current_bonus)

        def interior(labels_local, src_i, dst_i, w_i, src_f, dst_f, w_f):
            nbr = labels_local[dst_i]
            return jnp.zeros((v_local, k),
                             jnp.float32).at[src_i, nbr].add(w_i)

        def frontier(partial, lookup, labels, deg_w, loads, noise, u,
                     valid, reduce_, C, src_i, dst_i, w_i, src_f, dst_f,
                     w_f):
            scores = partial.at[src_f, lookup[dst_f]].add(w_f)
            best, tb, tc, m = propose(scores, labels, deg_w, loads, noise,
                                      valid, C)
            return finish(best, tb, tc, m, labels, deg_w, loads, u, valid,
                          reduce_, C)

        return interior, frontier

    def sharded_fused_graph_args_split(self, sg, k: int,
                                       dst_index: np.ndarray,
                                       pad: bool = False) -> tuple:
        return self.sharded_graph_args_split(sg, k, dst_index, pad=pad)

    def build(self, graph: Graph, k: int):
        raise _legacy_build_error("build")

    def build_sharded(self, sg, k: int, dst_index: np.ndarray):
        raise _legacy_build_error("build_sharded")


@dataclasses.dataclass(frozen=True)
class PallasTiledBackend:
    """ComputeScores via the tiled one-hot-matmul Pallas kernel.

    Edge weights are small integers ({1, 2}, Eq. 3), so the f32 MXU
    accumulation is exact and the result is bit-identical to the XLA
    scatter-add backend regardless of summation order -- including on
    per-shard retilings inside ``shard_map``.
    """

    name: str = "pallas"
    tile_v: int = 128
    tile_e: int = 128
    interpret: Optional[bool] = None   # None -> compiled on TPU else interpret

    def _interpret(self) -> bool:
        return (self.interpret if self.interpret is not None
                else _default_interpret())

    def signature(self) -> tuple:
        return ("pallas", self.tile_v, self.tile_e, self._interpret())

    def make_scores(self, k: int) -> Callable:
        k_pad = round_up(max(k, 1), 128)
        interpret = self._interpret()

        def scores(labels, src_local, dst, w, perm):
            return scores_from_tiles(labels, src_local, dst, w, perm,
                                     tile_v=self.tile_v, k_pad=k_pad, k=k,
                                     interpret=interpret)
        return scores

    def graph_args(self, graph: Graph, k: int, pad: bool = False) -> tuple:
        # pad mode floors the total slot count at the bucketed edge
        # capacity, so the tiled layout carries at least the COO bucket's
        # slack for the on-device delta merge (see repro.core.delta)
        tiled = build_tiled_csr(
            graph, tile_v=self.tile_v, tile_e=self.tile_e,
            pad_chunks=4 if pad else 1,
            min_total_slots=graph.num_directed_entries if pad else 0)
        return tuple(map(jnp.asarray, (tiled.src_local, tiled.dst,
                                       tiled.weight, tiled.perm)))

    def make_sharded_scores(self, k: int, v_local: int) -> Callable:
        return self.make_scores(k)     # perm is (v_local,): same pipeline

    def sharded_graph_args(self, sg, k: int, dst_index: np.ndarray,
                           pad: bool = False) -> tuple:
        st = build_sharded_tiled_csr(sg, dst_index, tile_v=self.tile_v,
                                     tile_e=self.tile_e,
                                     pad_chunks=4 if pad else 1)
        return tuple(map(jnp.asarray, (st.src_local, st.dst, st.weight,
                                       st.perm)))

    def make_sharded_scores_split(self, k: int, v_local: int) -> tuple:
        """Two kernel launches over independent segment tilings: the
        interior tiles gather from the local label shard (their dst ids
        are pre-remapped to local), the frontier tiles from the exchange
        lookup; the f32 MXU accumulations are exact on the integer
        weights, so the sum matches the single-tiling kernel bit for
        bit."""
        base = self.make_scores(k)

        def interior(labels_local, si, di, wi, pi, sf, df, wf, pf):
            return base(labels_local, si, di, wi, pi)

        def frontier(partial, lookup, si, di, wi, pi, sf, df, wf, pf):
            return partial + base(lookup, sf, df, wf, pf)

        return interior, frontier

    def sharded_graph_args_split(self, sg, k: int, dst_index: np.ndarray,
                                 pad: bool = False) -> tuple:
        e = sg.e_interior
        d_int, d_fro = _split_dst_views(sg, dst_index)
        seg_i = dataclasses.replace(sg, src_local=sg.src_local[:, :e],
                                    dst=sg.dst[:, :e],
                                    weight=sg.weight[:, :e], edge_perm=None)
        seg_f = dataclasses.replace(sg, src_local=sg.src_local[:, e:],
                                    dst=sg.dst[:, e:],
                                    weight=sg.weight[:, e:], edge_perm=None)
        st_i = build_sharded_tiled_csr(seg_i, d_int, tile_v=self.tile_v,
                                       tile_e=self.tile_e,
                                       pad_chunks=4 if pad else 1)
        st_f = build_sharded_tiled_csr(seg_f, d_fro, tile_v=self.tile_v,
                                       tile_e=self.tile_e,
                                       pad_chunks=4 if pad else 1)
        return tuple(map(jnp.asarray, (st_i.src_local, st_i.dst,
                                       st_i.weight, st_i.perm,
                                       st_f.src_local, st_f.dst,
                                       st_f.weight, st_f.perm)))

    # ---- fused vertex update: the megakernel (scores never hit HBM) ----
    # The (tile_v, k_pad) block stays in VMEM from edge reduction through
    # the Eq. 7-8 argmax proposal; only (tile_v,) vectors and the (1,
    # k_pad) M(l) partial come back.  The Eq. 11-12 migration test runs as
    # an XLA epilogue (engine.make_update_parts' ``finish``) because the
    # acceptance probability needs the globally reduced M(l).
    fused_auto = True

    def make_fused_update(self, k: int, *, degree_weighted: bool,
                          current_bonus: float,
                          frontier: bool = False) -> Callable:
        from repro.core.engine import make_update_parts   # lazy: no cycle
        _, finish = make_update_parts(
            k, degree_weighted=degree_weighted, current_bonus=current_bonus)
        k_pad = round_up(max(k, 1), 128)
        interpret = self._interpret()

        def fused(lookup, labels, deg_w, loads, noise, u, valid, reduce_,
                  C, src_local, dst, w, perm, inv_perm, deg_t):
            best, tb, tc, m = fused_update_from_tiles(
                lookup, labels, deg_t, noise, valid, loads / C,
                src_local, dst, w, perm, inv_perm, tile_v=self.tile_v,
                k_pad=k_pad, k=k, current_bonus=current_bonus,
                degree_weighted=degree_weighted, interpret=interpret,
                frontier=frontier)
            out = finish(best, tb, tc, m, labels, deg_w, loads, u, valid,
                         reduce_, C)
            if frontier:
                return out + ((best != labels) & valid,)
            return out
        return fused

    def fused_graph_args(self, graph: Graph, k: int,
                         pad: bool = False) -> tuple:
        tiled = build_tiled_csr(
            graph, tile_v=self.tile_v, tile_e=self.tile_e,
            pad_chunks=4 if pad else 1,
            min_total_slots=graph.num_directed_entries if pad else 0)
        return tuple(map(jnp.asarray, (tiled.src_local, tiled.dst,
                                       tiled.weight, tiled.perm,
                                       tiled.inv_perm, tiled.deg_t)))

    def make_sharded_fused_update(self, k: int, v_local: int, *,
                                  degree_weighted: bool,
                                  current_bonus: float,
                                  frontier: bool = False) -> Callable:
        # per-shard arrays are exactly a single-device tiling of the
        # shard's local vertex range: same closure
        return self.make_fused_update(k, degree_weighted=degree_weighted,
                                      current_bonus=current_bonus,
                                      frontier=frontier)

    def sharded_fused_graph_args(self, sg, k: int, dst_index: np.ndarray,
                                 pad: bool = False) -> tuple:
        st = build_sharded_tiled_csr(sg, dst_index, tile_v=self.tile_v,
                                     tile_e=self.tile_e,
                                     pad_chunks=4 if pad else 1)
        return tuple(map(jnp.asarray, (st.src_local, st.dst, st.weight,
                                       st.perm, st.inv_perm, st.deg_t)))

    def make_sharded_fused_update_split(self, k: int, v_local: int, *,
                                        degree_weighted: bool,
                                        current_bonus: float) -> tuple:
        """Overlap form: the interior kernel runs while the exchange is in
        flight and returns its RAW tiled (T * tile_v, k_pad) partial; the
        frontier megakernel seeds its VMEM accumulator with that partial
        (``acc_init``), which is row-compatible because both segments are
        tiled against ONE shared permutation (``ext_perm``)."""
        from repro.core.engine import make_update_parts
        _, finish = make_update_parts(
            k, degree_weighted=degree_weighted, current_bonus=current_bonus)
        k_pad = round_up(max(k, 1), 128)
        interpret = self._interpret()

        def interior(labels_local, si, di, wi, sf, df, wf, perm, inv_perm,
                     deg_t):
            return spinner_scores_pallas(si, labels_local[di], wi,
                                         tile_v=self.tile_v, k_pad=k_pad,
                                         interpret=interpret)

        def frontier(partial, lookup, labels, deg_w, loads, noise, u,
                     valid, reduce_, C, si, di, wi, sf, df, wf, perm,
                     inv_perm, deg_t):
            best, tb, tc, m = fused_update_from_tiles(
                lookup, labels, deg_t, noise, valid, loads / C,
                sf, df, wf, perm, inv_perm, tile_v=self.tile_v,
                k_pad=k_pad, k=k, current_bonus=current_bonus,
                degree_weighted=degree_weighted, interpret=interpret,
                acc_init=partial)
            return finish(best, tb, tc, m, labels, deg_w, loads, u, valid,
                          reduce_, C)

        return interior, frontier

    def sharded_fused_graph_args_split(self, sg, k: int,
                                       dst_index: np.ndarray,
                                       pad: bool = False) -> tuple:
        e = sg.e_interior
        d_int, d_fro = _split_dst_views(sg, dst_index)
        # one degree-balanced row layout shared by both segment tilings,
        # so interior partial rows line up with the frontier accumulator
        ext = np.stack([round_robin_perm(sg.deg_w[p], self.tile_v)
                        for p in range(sg.ndev)])
        seg_i = dataclasses.replace(sg, src_local=sg.src_local[:, :e],
                                    dst=sg.dst[:, :e],
                                    weight=sg.weight[:, :e], edge_perm=None)
        seg_f = dataclasses.replace(sg, src_local=sg.src_local[:, e:],
                                    dst=sg.dst[:, e:],
                                    weight=sg.weight[:, e:], edge_perm=None)
        st_i = build_sharded_tiled_csr(seg_i, d_int, tile_v=self.tile_v,
                                       tile_e=self.tile_e,
                                       pad_chunks=4 if pad else 1,
                                       ext_perm=ext)
        st_f = build_sharded_tiled_csr(seg_f, d_fro, tile_v=self.tile_v,
                                       tile_e=self.tile_e,
                                       pad_chunks=4 if pad else 1,
                                       ext_perm=ext)
        # shared layout -> one perm/inv_perm/deg_t triple serves both
        return tuple(map(jnp.asarray, (st_i.src_local, st_i.dst,
                                       st_i.weight,
                                       st_f.src_local, st_f.dst,
                                       st_f.weight, st_f.perm,
                                       st_f.inv_perm, st_f.deg_t)))

    def build(self, graph: Graph, k: int):
        raise _legacy_build_error("build")

    def build_sharded(self, sg, k: int, dst_index: np.ndarray):
        raise _legacy_build_error("build_sharded")


SCORE_BACKENDS = {
    "xla": XlaScatterBackend(),
    "pallas": PallasTiledBackend(),
}


def get_score_backend(backend: Union[str, ScoreBackend]) -> ScoreBackend:
    """Resolve a backend name; backend instances pass through unchanged."""
    if isinstance(backend, str):
        try:
            return SCORE_BACKENDS[backend]
        except KeyError:
            raise ValueError(
                f"unknown score backend {backend!r}; "
                f"available: {sorted(SCORE_BACKENDS)}") from None
    return backend
