"""Jit'd public wrappers for the Pallas kernels + the score-backend registry.

On a TPU backend the kernel runs compiled; everywhere else it runs in
``interpret=True`` mode (the kernel body executed op-by-op on the host),
which is how correctness is validated in this repository.

The score-backend protocol at the bottom is how the device-resident engine
(``repro.core.engine``) picks its ComputeScores implementation: a backend is
built once per (graph, k) at trace time and the returned closure is inlined
into the fused ``lax.while_loop`` / ``lax.scan`` body, so the XLA
scatter-add path and the Pallas tiled kernel are interchangeable without
any per-call dispatch.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Protocol, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import (Graph, TiledCSR, build_sharded_tiled_csr,
                              build_tiled_csr)

from . import ref
from .spinner_scores import scores_from_tiles, spinner_scores_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnames=("tile_v", "k_pad", "k",
                                             "interpret"))
def _scores_from_tiles(labels, src_local, dst, w, perm, *, tile_v: int,
                       k_pad: int, k: int, interpret: bool):
    # jitted entry so standalone spinner_scores_tiled() calls cache their
    # compilation; engine traces inline scores_from_tiles directly
    return scores_from_tiles(labels, src_local, dst, w, perm, tile_v=tile_v,
                             k_pad=k_pad, k=k, interpret=interpret)


def spinner_scores_tiled(labels: jax.Array, *, tiled: TiledCSR, k: int,
                         interpret: Optional[bool] = None) -> jax.Array:
    """(V, k) ComputeScores matrix via the Pallas kernel."""
    if interpret is None:
        interpret = _default_interpret()
    k_pad = round_up(max(k, 1), 128)
    return _scores_from_tiles(
        labels, jnp.asarray(tiled.src_local), jnp.asarray(tiled.dst),
        jnp.asarray(tiled.weight), jnp.asarray(tiled.perm),
        tile_v=tiled.tile_v, k_pad=k_pad, k=k, interpret=interpret)


def spinner_scores(labels: jax.Array, graph: Graph, k: int,
                   tile_v: int = 128, tile_e: int = 128,
                   interpret: Optional[bool] = None) -> jax.Array:
    """Convenience: tile a Graph and compute its score matrix."""
    tiled = build_tiled_csr(graph, tile_v=tile_v, tile_e=tile_e)
    return spinner_scores_tiled(labels, tiled=tiled, k=k, interpret=interpret)


# ---------------------------------------------------------------------------
# Score-backend protocol: pluggable ComputeScores (Eq. 8 numerator)
# ---------------------------------------------------------------------------

class ScoreBackend(Protocol):
    """Builds the Eq. 8 numerator ``labels -> (V, k) scores`` closure.

    ``build`` runs once per (graph, k) at trace time -- any preprocessing
    (tiling, padding, device upload) happens there, and the returned
    closure must be pure and jit-traceable so runners can inline it into
    ``lax.while_loop`` / ``lax.scan`` bodies.

    ``build_sharded`` is the mesh-parallel counterpart: given the
    ``ShardedGraph`` layout (see ``repro.core.distributed``) and the
    exchange plan's per-edge ``dst_index`` (global vertex ids for
    all-gather/delta, halo-remapped slots for halo), it returns
    ``(edge_arrays, scores_fn)``.  ``edge_arrays`` are device arrays with
    leading dimension ndev, threaded through ``shard_map`` with
    ``PartitionSpec(axis)`` on that dimension; ``scores_fn(lookup,
    *edge_blocks) -> (v_per_dev, k)`` computes the numerator for THIS
    device's vertex range from its edge blocks (leading dim stripped),
    indexing the plan's ``lookup`` array with the (blocked) ``dst_index``.
    Backends without a sharded path raise ``NotImplementedError`` at
    build time (a clear trace-time failure, not a silent fallback).
    """

    name: str

    def build(self, graph: Graph, k: int
              ) -> Callable[[jax.Array], jax.Array]: ...

    def build_sharded(self, sg, k: int, dst_index: np.ndarray
                      ) -> tuple: ...


@dataclasses.dataclass(frozen=True)
class XlaScatterBackend:
    """ComputeScores via XLA scatter-add -- the Pallas kernel's oracle."""

    name: str = "xla"

    def build(self, graph: Graph, k: int) -> Callable[[jax.Array], jax.Array]:
        from repro.core.engine import device_edges   # shared upload cache
        src, dst, w, _ = device_edges(graph)
        V = graph.num_vertices

        def scores(labels: jax.Array) -> jax.Array:
            return ref.spinner_scores_ref(labels, src, dst, w, V, k)

        return scores

    def build_sharded(self, sg, k: int, dst_index: np.ndarray) -> tuple:
        """Local scatter-add over this device's edge shard.

        Row-for-row ``spinner_scores_ref`` restricted to the local vertex
        range (zero-weight padding rows add 0 to row 0 and change
        nothing), so on a 1-device mesh -- where the shard is the whole
        CSR-ordered edge list -- the result is bit-identical to
        ``build``'s unsharded path.
        """
        from repro.core.distributed import device_upload   # lazy: no cycle
        vl = sg.v_per_dev
        # the allgather/delta plans index with the global dst ids verbatim
        # (dst_index IS sg.dst), so reuse the cached upload; halo's
        # remapped slots are a genuinely different array
        dst = (device_upload(sg, "dst") if dst_index is sg.dst
               else jnp.asarray(np.asarray(dst_index, np.int32)))
        args = (device_upload(sg, "src_local"), dst,
                device_upload(sg, "weight"))

        def scores(lookup: jax.Array, src_local: jax.Array,
                   dst_idx: jax.Array, w: jax.Array) -> jax.Array:
            nbr = lookup[dst_idx]
            return jnp.zeros((vl, k), jnp.float32).at[src_local, nbr].add(w)

        return args, scores


@dataclasses.dataclass(frozen=True)
class PallasTiledBackend:
    """ComputeScores via the tiled one-hot-matmul Pallas kernel."""

    name: str = "pallas"
    tile_v: int = 128
    tile_e: int = 128
    interpret: Optional[bool] = None   # None -> compiled on TPU else interpret

    def build(self, graph: Graph, k: int) -> Callable[[jax.Array], jax.Array]:
        tiled = build_tiled_csr(graph, tile_v=self.tile_v, tile_e=self.tile_e)
        return functools.partial(spinner_scores_tiled, tiled=tiled, k=k,
                                 interpret=self.interpret)

    def build_sharded(self, sg, k: int, dst_index: np.ndarray) -> tuple:
        """Per-shard retiled CSR + the kernel launched inside shard_map.

        Each device's edge shard is retiled over its local vertex range
        (``build_sharded_tiled_csr``) and the same tiled one-hot-matmul
        kernel runs per device against the exchange plan's lookup array.
        Edge weights are small integers ({1, 2}, Eq. 3), so the f32 MXU
        accumulation is exact and the result is bit-identical to the XLA
        scatter-add backend regardless of summation order.
        """
        st = build_sharded_tiled_csr(sg, dst_index, tile_v=self.tile_v,
                                     tile_e=self.tile_e)
        interpret = (self.interpret if self.interpret is not None
                     else _default_interpret())
        k_pad = round_up(max(k, 1), 128)
        args = tuple(map(jnp.asarray, (st.src_local, st.dst, st.weight,
                                       st.perm)))

        def scores(lookup: jax.Array, src_local: jax.Array, dst: jax.Array,
                   w: jax.Array, perm: jax.Array) -> jax.Array:
            return scores_from_tiles(lookup, src_local, dst, w, perm,
                                     tile_v=st.tile_v, k_pad=k_pad, k=k,
                                     interpret=interpret)

        return args, scores


SCORE_BACKENDS = {
    "xla": XlaScatterBackend(),
    "pallas": PallasTiledBackend(),
}


def get_score_backend(backend: Union[str, ScoreBackend]) -> ScoreBackend:
    """Resolve a backend name; backend instances pass through unchanged."""
    if isinstance(backend, str):
        try:
            return SCORE_BACKENDS[backend]
        except KeyError:
            raise ValueError(
                f"unknown score backend {backend!r}; "
                f"available: {sorted(SCORE_BACKENDS)}") from None
    return backend
