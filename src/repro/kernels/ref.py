"""Pure-jnp oracles for the kernels package."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def spinner_scores_ref(labels: jax.Array, src: jax.Array, dst: jax.Array,
                       w: jax.Array, num_vertices: int, k: int) -> jax.Array:
    """ComputeScores by scatter-add: scores[u, labels[v]] += w(u, v)."""
    nbr = labels[dst]
    return jnp.zeros((num_vertices, k), jnp.float32).at[src, nbr].add(w)


def spinner_scores_tiled_ref(labels: jax.Array, src_local: jax.Array,
                             dst: jax.Array, w: jax.Array, tile_v: int,
                             k: int) -> jax.Array:
    """Oracle operating directly on the tiled-CSR layout (incl. padding)."""
    t, c, tile_e = src_local.shape
    rows = (src_local
            + tile_v * jnp.arange(t, dtype=jnp.int32)[:, None, None]).reshape(-1)
    lbl = labels[dst.reshape(-1)]
    return jnp.zeros((t * tile_v, k), jnp.float32).at[rows, lbl].add(
        w.reshape(-1))
