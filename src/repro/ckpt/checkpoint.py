"""Atomic, sharding-aware checkpointing (no orbax dependency).

Layout: <dir>/step_<n>/ contains one .npy per leaf (path-encoded filename)
plus a msgpack manifest with the treedef and dtypes.  Writes go to a temp
directory renamed into place, so a crash mid-save never corrupts the latest
checkpoint (the fault-tolerance tests kill a training run mid-stream and
restart from here).  On restore, leaves are device_put against the caller's
shardings (if given), so a checkpoint written on one mesh can be restored
onto another -- the elastic-resize path.
"""
from __future__ import annotations

import os
import shutil
import time
from typing import Any, Optional

import jax
import msgpack
import numpy as np

PyTree = Any
_MANIFEST = "manifest.msgpack"

# A step_*.tmp directory younger than this may be a concurrent save still
# in flight (tmp written, rename pending); only colder ones are crashed
# half-saves that writers may sweep.
TMP_GC_AGE_S = 300.0


def _gc_stale_tmp(directory: str, age: float = TMP_GC_AGE_S) -> None:
    """Sweep crashed half-saves: ``step_*.tmp`` dirs older than ``age``
    seconds.  Called only from the writer-side paths (:func:`save`,
    :func:`gc_old`) -- read APIs must never delete a tmp dir another
    process may be about to rename into place."""
    now = time.time()
    for d in os.listdir(directory):
        if not (d.startswith("step_") and d.endswith(".tmp")):
            continue
        path = os.path.join(directory, d)
        try:
            if now - os.path.getmtime(path) > age:
                shutil.rmtree(path, ignore_errors=True)
        except OSError:
            pass                      # raced with the owner's rename


def _flatten(tree: PyTree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path) or "value"
        out[key] = leaf
    return out, treedef


def save(directory: str, step: int, tree: PyTree) -> str:
    """Atomically write checkpoint for ``step``; returns final path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat, _ = _flatten(tree)
    manifest = {"step": step, "keys": []}
    for key, leaf in flat.items():
        arr = np.asarray(leaf)
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["keys"].append({"key": key, "file": fname,
                                 "dtype": str(arr.dtype),
                                 "shape": list(arr.shape)})
    with open(os.path.join(tmp, _MANIFEST), "wb") as f:
        f.write(msgpack.packb(manifest))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc_stale_tmp(directory)
    return final


def latest_step(directory: str) -> Optional[int]:
    """Newest complete step (read-only; ``step_*.tmp`` dirs are skipped).

    A crash between :func:`save`'s tmp-dir write and its atomic rename
    leaves a ``step_*.tmp`` directory behind; such a directory is never
    a valid checkpoint (the rename IS the commit).  It is NOT deleted
    here: this is a read API that concurrent writers also race against
    (a fresh tmp may be a save mid-flight whose rename would then
    crash).  Writers sweep stale tmp dirs -- older than
    :data:`TMP_GC_AGE_S` -- in :func:`save` and :func:`gc_old`, so a
    crashed save for a step that is never re-attempted still gets
    garbage-collected on the next write-side call.
    """
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(directory: str, like: PyTree, step: Optional[int] = None,
            shardings: Optional[PyTree] = None) -> PyTree:
    """Restore into the structure of ``like`` (a pytree of arrays/specs)."""
    if step is None:
        step = latest_step(directory)
        assert step is not None, f"no checkpoints in {directory}"
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, _MANIFEST), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    by_key = {e["key"]: e for e in manifest["keys"]}

    flat_like, treedef = _flatten(like)
    flat_sh, _ = _flatten(shardings) if shardings is not None else ({}, None)
    leaves = []
    for key, leaf in flat_like.items():
        entry = by_key[key]
        arr = np.load(os.path.join(path, entry["file"]))
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape,
                                                       leaf.shape)
        if key in flat_sh:
            arr = jax.device_put(arr, flat_sh[key])
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def gc_old(directory: str, keep: int = 3,
           tmp_age: float = TMP_GC_AGE_S) -> None:
    """Delete all but the newest ``keep`` checkpoints, plus any crashed
    half-save tmp dirs older than ``tmp_age`` seconds."""
    if not os.path.isdir(directory):
        return
    _gc_stale_tmp(directory, age=tmp_age)
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)
