from . import checkpoint
