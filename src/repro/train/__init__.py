from . import steps
from .steps import (TrainState, init_train_state, make_decode_step,
                    make_eval_step, make_prefill_step, make_train_step,
                    train_state_specs)
