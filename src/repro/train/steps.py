"""Train / serve step factories, shared by the drivers and the dry-run."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model_zoo import ModelAPI
from repro.optim import adamw

PyTree = Any


class TrainState(NamedTuple):
    params: PyTree
    opt: adamw.AdamWState
    step: jax.Array     # () int32


def init_train_state(params: PyTree) -> TrainState:
    return TrainState(params=params, opt=adamw.init(params),
                      step=jnp.zeros((), jnp.int32))


def train_state_specs(param_specs: PyTree) -> TrainState:
    return TrainState(params=param_specs,
                      opt=adamw.state_specs(param_specs),
                      step=jax.ShapeDtypeStruct((), jnp.int32))


def make_train_step(api: ModelAPI, opt_cfg: adamw.AdamWConfig) -> Callable:
    bf16_grads = getattr(api.cfg, "bf16_grads", False)
    n_micro = max(1, getattr(api.cfg, "microbatch", 0))

    def grad_fn(params, batch):
        if bf16_grads:
            # differentiate w.r.t. bf16 copies: gradients (and their
            # cross-data-axis reduction) are bf16; AdamW math stays fp32
            # against the fp32 master params in ``state.params``.
            params = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16)
                if p.dtype == jnp.float32 else p, params)
        return jax.value_and_grad(api.loss)(params, batch)

    def train_step(state: TrainState, batch: dict
                   ) -> Tuple[TrainState, dict]:
        if n_micro > 1:
            # gradient accumulation: peak activation memory / n_micro,
            # identical collective volume per global batch.
            micro = jax.tree.map(
                lambda x: x.reshape(n_micro, x.shape[0] // n_micro,
                                    *x.shape[1:]), batch)

            def mstep(acc, mb):
                loss, g = grad_fn(state.params, mb)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32), acc, g)
                return acc, loss

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            gsum, losses = jax.lax.scan(mstep, zeros, micro)
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            loss = jnp.mean(losses)
        else:
            loss, grads = grad_fn(state.params, batch)
        params, opt, stats = adamw.update(opt_cfg, grads, state.opt,
                                          state.params)
        new_state = TrainState(params=params, opt=opt, step=state.step + 1)
        return new_state, {"loss": loss, **stats}

    return train_step


def make_eval_step(api: ModelAPI) -> Callable:
    def eval_step(params: PyTree, batch: dict) -> jax.Array:
        return api.loss(params, batch)

    return eval_step


def make_prefill_step(api: ModelAPI) -> Callable:
    def prefill_step(params: PyTree, batch: dict):
        return api.prefill(params, batch)

    return prefill_step


def make_decode_step(api: ModelAPI) -> Callable:
    def decode_step(params: PyTree, batch: dict, cache: PyTree):
        logits, new_cache = api.decode(params, batch, cache)
        next_token = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_token, new_cache

    return decode_step
