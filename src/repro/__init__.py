"""repro: Spinner (scalable graph partitioning) as a production JAX framework.

The partitioning core is ``repro.core`` (engines, sessions, deltas) and
the multi-tenant serving tier is ``repro.serve``.  The streaming-delta
surface in one sketch::

    from repro.core import SpinnerConfig, open_session
    from repro.core import DeltaTracker, apply_delta   # re-exported

    with open_session(graph, SpinnerConfig(k=16)) as s:
        s.partition()
        s.adapt(edge_updates=(src, dst))   # O(|delta|): one apply_delta

and the serving tier, which coalesces queued deltas and batches
same-bucket tenants into one device dispatch::

    from repro.serve import PartitionScheduler

    sched = PartitionScheduler(max_batch=8)
    sched.add_tenant("a", graph, SpinnerConfig(k=16), partition=True)
    tk = sched.submit("a", "edge_updates", edge_updates=(src, dst))
    sched.drain()
    labels = tk.result.labels

``repro.serve`` is imported lazily so ``import repro`` stays light.
"""
__version__ = "0.1.0"


def __getattr__(name):
    if name in ("serve", "core", "cluster"):
        import importlib
        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
