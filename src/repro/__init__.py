"""repro: Spinner (scalable graph partitioning) as a production JAX framework."""
__version__ = "0.1.0"
